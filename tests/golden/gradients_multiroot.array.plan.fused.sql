-- repro:plan v1
-- repro:step _sp_a_xh
create temp table _sp_a_xh as
with z_xh(m) as (
  select mm((select m from img), (select m from w_xh)) as m
),
a_xh(m) as (
  select msig((select m from z_xh)) as m
)
select m from a_xh;
-- repro:step _sp_a_ho
create temp table _sp_a_ho as
with z_ho(m) as (
  select mm((select m from _sp_a_xh), (select m from w_ho)) as m
),
a_ho(m) as (
  select msig((select m from z_ho)) as m
)
select m from a_ho;
-- repro:step _sp_diff
create temp table _sp_diff as
with diff(m) as (
  select msub((select m from _sp_a_ho), (select m from one_hot)) as m
)
select m from diff;
-- repro:step _sp_had_c3
create temp table _sp_had_c3 as
with had_c3(m) as (
  select mhad(mhad(mconst(4,2,1.0), msqrd((select m from _sp_diff))), msigd((select m from _sp_a_ho))) as m
)
select m from had_c3;
-- repro:main
with loss(m) as (
  select msqr((select m from _sp_diff)) as m
),
t_c0(m) as (
  select mt((select m from img)) as m
),
t_c4(m) as (
  select mt((select m from w_ho)) as m
),
mm_c5(m) as (
  select mm((select m from _sp_had_c3), (select m from t_c4)) as m
),
had_c6(m) as (
  select mhad((select m from mm_c5), msigd((select m from _sp_a_xh))) as m
),
mm_c7(m) as (
  select mm((select m from t_c0), (select m from had_c6)) as m
),
t_c8(m) as (
  select mt((select m from _sp_a_xh)) as m
),
mm_c9(m) as (
  select mm((select m from t_c8), (select m from _sp_had_c3)) as m
)
select 0 as r, m from loss
union all select 1 as r, m from mm_c7
union all select 2 as r, m from mm_c9;
