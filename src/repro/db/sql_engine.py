"""The third execution backend: evaluate the expression DAG *in a database*.

``SQLEngine`` exposes the same surface as :class:`repro.core.engine.Engine`
(``evaluate`` / ``eval_fn`` / ``value_and_grad_fn``) but instead of running
XLA ops it

1. pivots every leaf matrix into an ``{[i, j, v]}`` table with the
   vectorized ingestion path (:mod:`repro.db.relation_io`) — unchanged
   leaves (training data across iterations) are detected by content digest
   and not re-written,
2. renders the DAG — including Algorithm-1 gradient graphs — as one WITH
   query, one CTE per node, through the persistent plan cache
   (:mod:`repro.db.plan_cache`): rendering is paid once per topology ×
   dialect, across iterations AND processes, and
3. executes it on the connected engine and pivots the result tuples back
   into dense arrays (one fancy-indexed assignment per root).

It is reachable as ``Engine("sql")``; training loops route through
:mod:`repro.db.train` (the recursive-CTE loop runs entirely in-database).
Because every query is executed, this backend also golden-hardens the
transpiler: any ``sqlgen`` regression turns into a failing differential
test rather than a silently wrong string.
"""
from __future__ import annotations

import hashlib
from typing import Callable

import numpy as np

from ..core import autodiff
from ..core import expr as E
from ..core import sqlgen
from ..obs import tracer_of
from . import plan_cache, relation_io
from .adapter import Adapter, connect
from .dialect import get_dialect, json_to_matrix


def _split_tagged(rows, roots: list[E.Expr]) -> list[np.ndarray]:
    """``(r, i, j, v)`` union rows → a dense matrix per root (vectorized)."""
    outs = [np.zeros(root.shape, dtype=np.float64) for root in roots]
    if not len(rows):
        return outs
    arr = np.asarray(rows, dtype=np.float64)
    r = arr[:, 0].astype(np.int64)
    i = arr[:, 1].astype(np.int64) - 1
    j = arr[:, 2].astype(np.int64) - 1
    for k, out in enumerate(outs):
        m = r == k
        out[i[m], j[m]] = arr[m, 3]
    return outs


def _digest(x, representation: str = "relational") -> bytes:
    """Content digest of a leaf matrix.  The representation is folded in so
    an adapter shared between a relational and an array engine can never
    serve the unchanged-leaf skip across representations (the stored table
    layouts are incompatible)."""
    a = np.ascontiguousarray(np.asarray(x, dtype=np.float64))
    return hashlib.sha256(a.tobytes() + repr(a.shape).encode()
                          + representation.encode()).digest()


class SQLEngine:
    """Evaluate expression DAGs inside sqlite (default) or duckdb."""

    kind = "sql"

    def __init__(self, backend: str = "sqlite", path: str = ":memory:",
                 adapter: Adapter | None = None, plan_cache_=None,
                 dialect=None, tracer=None):
        """``plan_cache_``: a :class:`repro.db.plan_cache.PlanCache`,
        ``None`` for the shared persistent default, or ``False`` to render
        every query from scratch.

        ``dialect``: override the adapter's rendering dialect — pass
        ``"array"`` for the array-typed representation (paper §5/§7: same
        engine, one row per matrix, UDF calls per node) while the adapter
        still supplies the connection.  ``None`` keeps the adapter's
        native relational dialect.

        ``tracer``: a :class:`repro.obs.Tracer` to pin to this engine (and
        its adapter).  ``None`` (default) defers to the ambient tracer
        (:func:`repro.obs.use` / :func:`repro.obs.install`), which is a
        zero-cost no-op unless one was installed."""
        self.adapter = adapter if adapter is not None else connect(backend, path)
        if dialect is None:
            self.dialect = self.adapter.dialect
        else:
            self.dialect = get_dialect(dialect)
            if self.dialect is not self.adapter.dialect:
                self.dialect.prepare(self.adapter.conn)
        self.representation = self.dialect.representation
        self.plans = plan_cache.resolve(plan_cache_)
        self.tracer = tracer
        if tracer is not None:
            self.adapter.tracer = tracer

    # -- representation conversion (Engine-compatible no-ops) ---------------
    def lift(self, x):
        return x

    def lower(self, x):
        return x

    # -- evaluation ---------------------------------------------------------
    def _write_env(self, roots: list[E.Expr], env: dict) -> None:
        """Materialise every free Var of the DAG as its stored relation.
        Leaves whose content digest matches what is already in the database
        are skipped — in an iteration loop only the weights move, the data
        relations are ingested once.  Digests live on the adapter
        (``matrix_digests``) and are invalidated by any ``create_table``
        on the same name, so direct writes (db.train) can't go stale."""
        stored = self.adapter.matrix_digests
        write = (relation_io.write_matrix_array
                 if self.representation == "array"
                 else relation_io.write_matrix)
        for v in E.free_vars(*roots):
            if v.name not in env:
                raise KeyError(f"env missing leaf table {v.name!r}")
            d = _digest(env[v.name], self.representation)
            if stored.get(v.name) == d:
                continue
            write(self.adapter, v.name, env[v.name])
            stored[v.name] = d

    def _render(self, roots: list[E.Expr]) -> str:
        """Multi-root WITH query via the plan cache (or direct on miss)."""
        if self.plans is not None:
            return self.plans.dag_sql(roots, self.dialect, tail="multi_root")
        return sqlgen.to_sql(roots,
                             select=sqlgen.multi_root_tail(roots, self.dialect),
                             dialect=self.dialect)

    def _plan_key(self, roots: list[E.Expr]) -> str:
        """The cache key ``evaluate`` queries run under (multi-root tail)."""
        return plan_cache.plan_key(
            roots, extra=(self.dialect.name, "tail:multi_root"))

    def _ensure_explained(self, key: str, sql: str) -> None:
        """Capture the engine's EXPLAIN output for a cached plan, once.
        Must run *after* ``_write_env`` — sqlite's EXPLAIN QUERY PLAN
        resolves table names.  A failed capture records ``''`` so it is
        not retried on every call."""
        if self.plans is None or self.plans.get_explain(key) is not None:
            return
        try:
            text = self.adapter.explain_sql(sql)
        except Exception:
            text = ""
        self.plans.record_explain(key, text)

    def explain(self, roots: list[E.Expr]) -> str:
        """The engine's plan for this DAG (EXPLAIN QUERY PLAN on sqlite,
        EXPLAIN on duckdb).  Leaf tables must exist — evaluate the DAG (or
        call after a training run) first; returns ``''`` where the engine
        cannot explain the query."""
        sql = self._render(roots)
        if self.plans is not None:
            key = self._plan_key(roots)
            self._ensure_explained(key, sql)
            return self.plans.get_explain(key) or ""
        try:
            return self.adapter.explain_sql(sql)
        except Exception:
            return ""

    def _decode(self, rows, roots: list[E.Expr]) -> list[np.ndarray]:
        """Result rows → one dense matrix per root.  Relational: tagged
        ``(r, i, j, v)`` cell tuples.  Array: one ``(r, m)`` row per root,
        ``m`` the JSON array codec."""
        if self.representation != "array":
            return _split_tagged(rows, roots)
        outs = [np.zeros(root.shape, dtype=np.float64) for root in roots]
        for r, m in rows:
            outs[int(r)] = json_to_matrix(m)
        return outs

    def _root_attrs(self, roots: list[E.Expr]) -> dict:
        """Per-IR-node attribution carried by the evaluation root span.
        Only computed when a collecting tracer is active (dag_signature
        hashes the whole DAG — never on the no-op path)."""
        return {
            "root": getattr(roots[0], "name", None) or type(roots[0]).__name__,
            "n_roots": len(roots),
            "dag_signature": sqlgen.dag_signature(roots)[:16],
            "dialect": self.dialect.name,
            "representation": self.representation,
        }

    def evaluate(self, roots: list[E.Expr], env: dict) -> list[np.ndarray]:
        """One round trip: write leaves, run ONE multi-root query, read back.

        The query unions every root's tuples tagged with the root position,
        so shared CTEs (forward values reused by Algorithm 1's backward
        pass) are rendered — and executable by the engine — exactly once.
        """
        tr = tracer_of(self, self.adapter)
        if not tr.enabled:
            self._write_env(roots, env)
            rows = self.adapter.execute(self._render(roots))
            return self._decode(rows, roots)
        with tr.span("sql.evaluate", **self._root_attrs(roots)) as root_sp:
            bytes0 = self.adapter.db_bytes()
            with tr.span("sql.ingest"):
                self._write_env(roots, env)
            hits0 = self.plans.hits if self.plans is not None else 0
            with tr.span("sql.render") as sp:
                sql = self._render(roots)
                if self.plans is not None:
                    sp.set(cache="hit" if self.plans.hits > hits0 else "miss")
            if self.plans is not None:
                with tr.span("sql.explain"):
                    self._ensure_explained(self._plan_key(roots), sql)
            rows = self.adapter.execute(sql)
            with tr.span("sql.decode"):
                outs = self._decode(rows, roots)
            bytes1 = self.adapter.db_bytes()
            root_sp.set(rows_returned=len(rows),
                        db_bytes=(None if bytes0 is None or bytes1 is None
                                  else bytes1 - bytes0))
            return outs

    def eval_fn(self, roots: list[E.Expr]) -> Callable:
        """Evaluator with the Engine.eval_fn contract (no jit — the
        "compilation" is the SQL rendering, done once here and reused from
        the plan cache across topologically identical graphs)."""
        sql = self._render(roots)
        explained = [self.plans is None]  # explain once, after tables exist

        def fn(env: dict) -> list[np.ndarray]:
            tr = tracer_of(self, self.adapter)
            if not tr.enabled:
                self._write_env(roots, env)
                return self._decode(self.adapter.execute(sql), roots)
            with tr.span("sql.evaluate", **self._root_attrs(roots)) as root_sp:
                with tr.span("sql.ingest"):
                    self._write_env(roots, env)
                if not explained[0]:
                    with tr.span("sql.explain"):
                        self._ensure_explained(self._plan_key(roots), sql)
                    explained[0] = True
                rows = self.adapter.execute(sql)
                with tr.span("sql.decode"):
                    outs = self._decode(rows, roots)
                root_sp.set(rows_returned=len(rows))
                return outs

        return fn

    def value_and_grad_fn(self, loss: E.Expr, wrt: list[E.Var]) -> Callable:
        """env → (loss value, {var name: gradient}), gradients from
        Algorithm 1 rendered as CTEs and executed in-database."""
        grads = autodiff.gradients(loss, wrt)
        roots = [loss] + [grads[v] for v in wrt]
        fn = self.eval_fn(roots)

        def vg(env: dict):
            outs = fn(env)
            return outs[0], {v.name: g for v, g in zip(wrt, outs[1:])}

        return vg

    # -- introspection ------------------------------------------------------
    @property
    def stats(self) -> dict:
        """One merged counter view over the whole engine: plan-cache
        hit/miss/eviction counters (the LRU no longer evicts silently),
        adapter query/ingestion counters, and — when a collecting tracer is
        pinned — its counters/gauges.  Flat convenience keys up front for
        the common questions; the nested dicts carry everything."""
        cache = self.plans.stats if self.plans is not None else {}
        adapter = dict(self.adapter.counters)
        out = {
            "cache_hits": cache.get("hits", 0),
            "cache_misses": cache.get("misses", 0),
            "cache_evictions": (cache.get("evictions", 0)
                                + cache.get("evictions_disk", 0)),
            "queries": adapter.get("queries", 0),
            "ingest_bytes": adapter.get("ingest_bytes", 0),
            "plan_cache": cache,
            "adapter": adapter,
        }
        db_bytes = self.adapter.db_bytes()
        if db_bytes is not None:
            out["db_bytes"] = db_bytes
        tr = self.tracer
        if tr is not None and tr.enabled:
            out["tracer"] = {"spans": len(tr.spans),
                             "counters": tr.counters, "gauges": tr.gauges}
        return out

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self.adapter.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
