"""State-space models transpiled to SQL (the §8 outlook's recurrent tier).

Two SSM families over the zoo IR, both differentially tested against
``nn/ssm.py`` (:func:`repro.nn.ssm.ssd_naive` is the ground-truth oracle):

* **SSD / Mamba-2** — the scalar-decay matrix-state recurrence

      h_t = exp(a_t) · h_{t-1} + B_t x_tᵀ;     y_t = C_t · h_t

  Each (n, p) state cell evolves independently (scalar decay, rank-1
  additive update), so flattening (n, p) → column n·P+p turns the whole
  (N×P)-state scan into ONE elementwise affine ``Recurrence`` over an
  (S, N·P) relation — a single recursive CTE, exactly the RWKV-6
  machinery with the decay broadcast from a scalar instead of a vector.
  The flattening is relational: Kronecker index relations
  (:func:`ssd_kron_relations`) broadcast B over p and x over n via plain
  matmul joins; the output contraction Σ_n is the matmul against
  ``kron_pᵀ``.  **Chunked** execution (the Mamba-2 block decomposition's
  inter-chunk recurrence, arXiv:2405.21060) runs the sequence in
  fixed-size chunks — one query per chunk, the carried state folded into
  the next chunk's first step (b₁' = a₁ ∘ h₀ + b₁).

* **LRU** (Linear Recurrent Unit, and the S5-style dense-block variant) —
  the matrix-valued recurrence

      h_t = h_{t-1} · A + u_t · B;             y_t = h_t · C

  ``diagonal=True`` is the LRU/S5 fast path: diagonal A IS the
  elementwise ``Recurrence``.  ``diagonal=False`` carries a dense (D, D)
  block through ``MatRecurrence`` — the per-step blocks stacked into one
  (S·D, D) relation, lowered as a recursive CTE carrying the whole state
  row in one tuple (D columns relational, one array value in the array
  dialect).  Algorithm 1 differentiates both: the adjoint scan runs with
  transposed coefficients and the ∂A outer products stack via
  ``StepOuter``.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ...core import expr as E
from ...obs import tracer_of


# ---------------------------------------------------------------------------
# index relations
# ---------------------------------------------------------------------------

def ssd_kron_relations(n: int, p: int) -> dict[str, np.ndarray]:
    """The 0/1 broadcast relations of the (n, p) → n·P+p flattening:

    ``kron_n`` (N, N·P): [n, n·P+p] = 1 — left factor, repeats over p;
    ``kron_p`` (P, N·P): [p, n·P+p] = 1 — right factor, tiles over n.

    ``B @ kron_n`` spreads a length-N row over the N·P state columns by
    the *n* index, ``x @ kron_p`` by the *p* index; ``h @ kron_pᵀ`` sums
    a state row over *n* for each p — the C_t·h_t output contraction is
    ``(C@kron_n ∘ h) @ kron_pᵀ``."""
    kn = np.zeros((n, n * p))
    kp = np.zeros((p, n * p))
    for a in range(n):
        kn[a, a * p:(a + 1) * p] = 1.0
    for b in range(p):
        kp[b, b::p] = 1.0
    return {"kron_n": kn, "kron_p": kp}


def _first_row_indicator(rows: int) -> np.ndarray:
    e1 = np.zeros((rows, 1))
    e1[0, 0] = 1.0
    return e1


# ---------------------------------------------------------------------------
# SSD / Mamba-2: scalar-decay matrix state as ONE elementwise scan
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class SSDGraph:
    seq: int
    n: int                   # state size N
    p: int                   # head dim P
    y: E.Expr                # (S, P) per-token output
    h: E.Expr                # (S, N·P) post-update state trajectory
    leaves: tuple            # the xt/bt/ct/da/h0 Vars


def ssd_scan_graph(seq: int, n: int, p: int) -> SSDGraph:
    """One head's SSD recurrence as a single-scan DAG.

    Leaf relations: ``xt`` (S, P), ``bt``/``ct`` (S, N), ``da`` (S, 1)
    the *exponentiated* decay exp(a_t), ``h0`` (1, N·P) initial state
    (row-major flattened), plus the static index relations of
    :func:`ssd_static_env`."""
    np_ = n * p
    xt = E.var("xt", (seq, p))
    bt = E.var("bt", (seq, n))
    ct = E.var("ct", (seq, n))
    da = E.var("da", (seq, 1))
    h0 = E.var("h0", (1, np_))
    kn = E.var("kron_n", (n, np_))
    kp = E.var("kron_p", (p, np_))
    e1 = E.var("e_first", (seq, 1))

    decay = E.matmul(da, E.const(1.0, (1, np_)), name="decay_flat")
    kv = E.hadamard(E.matmul(bt, kn), E.matmul(xt, kp), name="bx_flat")
    h0_row1 = E.matmul(e1, h0)           # (S, N·P), h0 in row 1, else 0
    b_eff = E.add(kv, E.hadamard(decay, h0_row1))  # fold h0 into step 1
    h = E.recurrence(decay, b_eff, name="ssd_scan")  # h_t, post-update
    y = E.matmul(E.hadamard(E.matmul(ct, kn), h), E.transpose(kp),
                 name="ssd_y")
    return SSDGraph(seq=seq, n=n, p=p, y=y, h=h,
                    leaves=(xt, bt, ct, da, h0))


def ssd_static_env(seq: int, n: int, p: int) -> dict[str, np.ndarray]:
    env = ssd_kron_relations(n, p)
    env["e_first"] = _first_row_indicator(seq)
    return env


def ssd_env(x, a, b, c, h0=None) -> dict[str, np.ndarray]:
    """Leaf tables from the ``nn/ssm.ssd_naive`` single-head convention:
    x (S, P), a (S,) LOG decay (exponentiated host-side — the IR has no
    exp map), b/c (S, N), h0 (N, P) or None."""
    x = np.asarray(x)
    seq, p = x.shape
    n = np.asarray(b).shape[1]
    env = ssd_static_env(seq, n, p)
    env.update(xt=x, bt=np.asarray(b), ct=np.asarray(c),
               da=np.exp(np.asarray(a, dtype=np.float64)).reshape(seq, 1),
               h0=(np.zeros((1, n * p)) if h0 is None
                   else np.asarray(h0).reshape(1, n * p)))
    return env


def ssd_ref(x, a, b, c, h0=None) -> tuple[np.ndarray, np.ndarray]:
    """NumPy twin of ``nn/ssm.ssd_naive`` for one (batch, head): returns
    (y (S, P), h_fin (N, P))."""
    x = np.asarray(x, dtype=np.float64)
    seq, p = x.shape
    n = np.asarray(b).shape[1]
    h = np.zeros((n, p)) if h0 is None else np.asarray(h0, dtype=np.float64)
    ys = np.zeros((seq, p))
    for t in range(seq):
        h = np.exp(float(np.asarray(a)[t])) * h \
            + np.outer(np.asarray(b)[t], x[t])
        ys[t] = np.asarray(c)[t] @ h
    return ys, h


def run_ssd_in_db(x, a, b, c, h0=None, *, chunk: int | None = None,
                  backend: str = "sqlite", engine=None
                  ) -> tuple[np.ndarray, np.ndarray]:
    """The SSD scan inside the database: returns (y (S, P), h_fin (N, P)).

    ``chunk`` runs the Mamba-2-style chunked execution: the sequence is
    cut into fixed-size chunks, each chunk ONE query (its own recursive
    CTE), and the chunk-final state row is carried into the next chunk's
    ``h0`` leaf — the inter-chunk recurrence of the block decomposition,
    at query granularity.  ``engine`` may be any ``SQLEngine`` (pass
    ``SQLEngine(dialect="array")`` for the array representation)."""
    from ..sql_engine import SQLEngine

    x = np.asarray(x)
    a = np.asarray(a, dtype=np.float64)
    b, c = np.asarray(b), np.asarray(c)
    seq, p = x.shape
    n = b.shape[1]
    eng = engine if engine is not None else SQLEngine(backend=backend)
    tr = tracer_of(eng, eng.adapter)
    try:
        chunk = seq if not chunk else min(chunk, seq)
        carry = None if h0 is None else np.asarray(h0)
        ys = []
        with tr.span("zoo.ssd_scan", seq=seq, chunk=chunk, n=n, p=p):
            for s in range(0, seq, chunk):
                e = min(seq, s + chunk)
                graph = ssd_scan_graph(e - s, n, p)
                env = ssd_env(x[s:e], a[s:e], b[s:e], c[s:e], carry)
                with tr.span("zoo.ssd_chunk", start=s, stop=e):
                    y, h = eng.evaluate([graph.y, graph.h], env)
                ys.append(y)
                carry = h[-1].reshape(n, p)
        return np.concatenate(ys, axis=0), carry
    finally:
        if engine is None:
            eng.close()


# ---------------------------------------------------------------------------
# LRU / S5: matrix-valued (dense-block or diagonal) linear RNN layer
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class LRUGraph:
    seq: int
    d_in: int
    d_state: int
    d_out: int
    diagonal: bool
    y: E.Expr                # (S, d_out)
    h: E.Expr                # (S, d_state) state trajectory
    leaves: tuple            # u, a (stack or diag row), wb, wc


def lru_layer_graph(seq: int, d_in: int, d_state: int, d_out: int,
                    diagonal: bool = False) -> LRUGraph:
    """An LRU-style linear RNN layer: h_t = h_{t-1}·A + u_t·B, y = h·C.

    ``diagonal=True`` stores A as one (1, D) row ``lam`` and scans with
    the elementwise ``Recurrence`` (the LRU/S5 diagonal fast path);
    ``diagonal=False`` stores the per-step blocks as the (S·D, D)
    relation ``a_stack`` (time-invariant A = the same block tiled S
    times — data-dependent A_t drops in unchanged) and scans with
    ``MatRecurrence``."""
    u = E.var("u", (seq, d_in))
    wb = E.var("wb", (d_in, d_state))
    wc = E.var("wc", (d_state, d_out))
    b = E.matmul(u, wb, name="lru_b")
    if diagonal:
        lam = E.var("lam", (1, d_state))
        decay = E.matmul(E.const(1.0, (seq, 1)), lam)
        h = E.recurrence(decay, b, name="lru_scan")
        a_leaf = lam
    else:
        a_stack = E.var("a_stack", (seq * d_state, d_state))
        h = E.mat_recurrence(a_stack, b, name="lru_scan")
        a_leaf = a_stack
    y = E.matmul(h, wc, name="lru_y")
    return LRUGraph(seq=seq, d_in=d_in, d_state=d_state, d_out=d_out,
                    diagonal=diagonal, y=y, h=h,
                    leaves=(u, a_leaf, wb, wc))


def lru_env(graph: LRUGraph, u, a, wb, wc) -> dict[str, np.ndarray]:
    """Leaf tables: ``a`` is the (D, D) transition matrix (dense graph:
    tiled into the stack) or the (D,) diagonal (diagonal graph)."""
    a = np.asarray(a, dtype=np.float64)
    env = {"u": np.asarray(u), "wb": np.asarray(wb), "wc": np.asarray(wc)}
    if graph.diagonal:
        env["lam"] = a.reshape(1, graph.d_state)
    else:
        env["a_stack"] = np.tile(a, (graph.seq, 1))
    return env


def lru_ref(u, a, wb, wc, diagonal: bool = False
            ) -> tuple[np.ndarray, np.ndarray]:
    """NumPy oracle of :func:`lru_layer_graph`: (y, h trajectory)."""
    u = np.asarray(u, dtype=np.float64)
    a = np.asarray(a, dtype=np.float64)
    b = u @ np.asarray(wb)
    h = np.zeros(b.shape[1])
    hs = np.zeros_like(b)
    for t in range(u.shape[0]):
        h = (h * a if diagonal else h @ a) + b[t]
        hs[t] = h
    return hs @ np.asarray(wc), hs


def run_lru_in_db(u, a, wb, wc, *, diagonal: bool = False,
                  backend: str = "sqlite", engine=None) -> np.ndarray:
    """Forward LRU layer in-database: returns y (S, d_out)."""
    from ..sql_engine import SQLEngine

    u = np.asarray(u)
    graph = lru_layer_graph(u.shape[0], u.shape[1],
                            np.asarray(wb).shape[1],
                            np.asarray(wc).shape[1], diagonal=diagonal)
    eng = engine if engine is not None else SQLEngine(backend=backend)
    try:
        with tracer_of(eng, eng.adapter).span(
                "zoo.lru_forward", seq=graph.seq, d_state=graph.d_state,
                diagonal=diagonal):
            y, = eng.evaluate([graph.y], lru_env(graph, u, a, wb, wc))
            return y
    finally:
        if engine is None:
            eng.close()


def lru_grads_in_db(u, a, wb, wc, *, diagonal: bool = False,
                    backend: str = "sqlite", engine=None
                    ) -> tuple[np.ndarray, dict[str, np.ndarray]]:
    """Forward + Algorithm-1 backward of the squared-output loss
    Σ y², entirely in-database: returns (loss value matrix, {leaf:
    gradient}).  The gradient of the transition comes back in the stored
    layout — the (S·D, D) stack (dense; sum the per-step blocks for the
    time-invariant ∂A) or the (1, D) diagonal row."""
    from ..sql_engine import SQLEngine

    u = np.asarray(u)
    graph = lru_layer_graph(u.shape[0], u.shape[1],
                            np.asarray(wb).shape[1],
                            np.asarray(wc).shape[1], diagonal=diagonal)
    loss = E.square(graph.y, name="lru_loss")
    wrt = list(graph.leaves)
    eng = engine if engine is not None else SQLEngine(backend=backend)
    try:
        with tracer_of(eng, eng.adapter).span(
                "zoo.lru_grads", seq=graph.seq, d_state=graph.d_state,
                diagonal=diagonal):
            vg = eng.value_and_grad_fn(loss, wrt)
            return vg(lru_env(graph, u, a, wb, wc))
    finally:
        if engine is None:
            eng.close()
