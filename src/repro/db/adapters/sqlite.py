"""The stdlib ``sqlite3`` backend — always available, the default.

Ingestion strategy (the MNIST-scale bottleneck — see
``benchmarks/bench_mnist_db.py``): multi-row ``INSERT … VALUES (…),(…),…``
batches (fewer statement steps; ~3× over the flat per-cell path, which is
the floor the row-at-a-time storage model allows), with engine-side
``json_each`` expansion auto-selected on ≥ 3.38 builds where the JSON
table-functions are linear."""
from __future__ import annotations

import os
import sqlite3
from typing import Sequence

import numpy as np

from ...obs import tracer_of
from ..dialect import SqliteDialect
from .base import Adapter, _check_ident


class SQLiteAdapter(Adapter):
    dialect = SqliteDialect()

    #: rows per multi-row VALUES statement; sqlite's bound-parameter limit
    #: is 999 on older builds — 300 rows × 3 cols stays under it
    ROWS_PER_STMT = 300

    #: first sqlite release whose JSON table-functions extract values in
    #: linear time (the 3.38 JSON rewrite); before it ``json_each`` is
    #: O(array length) per row and the engine-side parse loses to VALUES
    #: (measured on this container's 3.34 — ``bench_mnist_db.py``)
    JSON_LINEAR_VERSION = (3, 38)

    #: milliseconds a statement waits on a sibling connection's write lock
    #: before ``database is locked`` — generous: pool writers serialize
    BUSY_TIMEOUT_MS = 30_000

    def __init__(self, path: str = ":memory:"):
        # check_same_thread=False: the adapter serializes every raw-
        # connection access on ``self.lock``, so handing the connection
        # across pool-worker threads is safe — sqlite's own affinity check
        # would raise ProgrammingError on the first cross-thread call
        super().__init__(sqlite3.connect(
            path, timeout=self.BUSY_TIMEOUT_MS / 1e3,
            check_same_thread=False))
        self.path = path
        if path != ":memory:":
            # sibling connections on one file share table generations
            self._db_key = "sqlite:" + os.path.abspath(path)
        #: runtime engine version — instance-level so tests can pin it
        self.sqlite_version = sqlite3.sqlite_version_info
        try:  # table-valued JSON ingestion needs the (default) JSON1 ext.
            # obs: exempt — capability probe at connect time, not a query
            self.conn.execute("select count(*) from json_each('[0]')")
            self.supports_json_ingest = True
        except sqlite3.Error:  # pragma: no cover - JSON1-less builds
            self.supports_json_ingest = False
        try:
            # obs: exempt — connection-mode pragmas at open, not queries
            self.conn.execute(f"pragma busy_timeout = {self.BUSY_TIMEOUT_MS}")
            if path != ":memory:":
                # WAL: many concurrent readers + one writer across the
                # pool's connections (a rollback-journal DB serializes
                # readers behind any writer)
                self.conn.execute("pragma journal_mode = wal")
        except sqlite3.Error:  # pragma: no cover - locked-down builds
            pass

    @property
    def prefers_json_ingest(self) -> bool:
        """Auto-select the engine-side ``json_each`` ingestion on builds
        where it is linear (≥ :data:`JSON_LINEAR_VERSION`); older engines
        keep the multi-row VALUES batching."""
        return (self.supports_json_ingest
                and self.sqlite_version >= self.JSON_LINEAR_VERSION)

    def explain_sql(self, sql: str) -> str:
        """``EXPLAIN QUERY PLAN`` rows as ``id parent: detail`` lines."""
        try:
            rows = self.execute("explain query plan " + sql)
        except Exception:
            return ""
        return "\n".join(f"{r[0]} {r[1]}: {r[-1]}" for r in rows)

    def db_bytes(self) -> int | None:
        try:
            # obs: exempt — size probe read by the tracer itself; spanning
            # it would pollute every evaluation trace with pragma queries
            with self.lock:
                page_count, = (self.conn.execute("pragma page_count")
                               .fetchone())
                page_size, = (self.conn.execute("pragma page_size")
                              .fetchone())
            return int(page_count) * int(page_size)
        except Exception:  # pragma: no cover - pragma-less builds
            return None

    #: cells per bound JSON array.  sqlite ≤3.37 extracts json_each values
    #: in O(array length) per row — one giant array is quadratic; bounded
    #: chunks keep the parse cost linear (and the win grows on ≥3.38
    #: builds, whose JSON table-functions are linear outright).
    JSON_CHUNK_CELLS = 4096

    def insert_matrix_json(self, name: str, x: np.ndarray) -> None:
        """JSON-array ingestion (the ROADMAP's table-valued lever): bind
        row-major JSON array chunks and let the engine expand them with the
        ``json_each`` table-valued function — index arithmetic on ``key``
        recovers the 1-based (i, j) pivot *inside* sqlite, eliminating the
        per-row Python binding of the VALUES path.  Values round-trip
        through sqlite's text→real parse, which may differ by ~1 ulp from
        the bound double (``bench_mnist_db.py`` races the two paths side
        by side and records the winner; on this container's 3.34 the
        engine-side parse roughly cancels the client-side saving — the
        lever pays off on JSON-optimised ≥ 3.38 builds)."""
        import json

        _check_ident(name)
        self._invalidate(name)
        a = np.asarray(x, dtype=np.float64)
        if a.ndim != 2:
            raise ValueError(f"expected a matrix, got shape {a.shape}")
        if not np.isfinite(a).all():
            # json.dumps would emit NaN/Infinity tokens, which sqlite's
            # JSON parser rejects mid-chunk (partial table); refuse up
            # front — the VALUES path (write_matrix) binds them fine
            raise ValueError("non-finite values cannot ride the JSON "
                             "ingestion path; use write_matrix")
        cols = a.shape[1]
        flat = a.reshape(-1)
        chunk = max(cols, (self.JSON_CHUNK_CELLS // cols) * cols)
        sql = (f"insert into {name} "
               f"select (key + ?) / {cols} + 1, key % {cols} + 1, value "
               f"from json_each(?)")
        tr = tracer_of(self)
        with tr.span("db.ingest_json", table=name, cells=int(a.size)), \
                self.lock:
            cur = self.conn.cursor()
            for s in range(0, flat.size, chunk):
                cur.execute(sql, (s, json.dumps(flat[s:s + chunk].tolist())))
                self.counters["statements"] += 1

    def insert_columns(self, name: str,
                       cols: Sequence[np.ndarray]) -> None:
        """Multi-row VALUES batching: one statement binds ROWS_PER_STMT
        rows, executemany streams the batches.  Parameters are interleaved
        into one flat float list by strided ndarray assignment (ints bind
        fine through float64 — sqlite is dynamically typed and the matrix
        schema only ever compares/joins on equality of exact small ints)."""
        cols, n = self._prepare_columns(name, cols, dtype=np.float64)
        if not n:
            return
        k = len(cols)
        flat = np.empty(n * k)
        for ci, c in enumerate(cols):
            flat[ci::k] = c
        flat = flat.tolist()
        row_ph = "(" + ", ".join(["?"] * k) + ")"
        # never exceed 999 bound parameters per statement, whatever the
        # column count (wider tables than {i,j,v} pass through here too)
        batch = max(1, min(self.ROWS_PER_STMT, 999 // k))
        full, rem = divmod(n, batch)
        tr = tracer_of(self)
        with tr.span("db.ingest_values", table=name, rows=n), self.lock:
            cur = self.conn.cursor()
            if full:
                stride = k * batch
                sql = (f"insert into {name} values "
                       + ", ".join([row_ph] * batch))
                cur.executemany(sql, (flat[s:s + stride]
                                      for s in range(0, full * stride,
                                                     stride)))
                self.counters["statements"] += 1
            if rem:
                sql = (f"insert into {name} values "
                       + ", ".join([row_ph] * rem))
                cur.execute(sql, flat[full * batch * k:])
                self.counters["statements"] += 1

    def update_cells(self, name: str, flat_index: np.ndarray,
                     values: np.ndarray, shape: Sequence[int]) -> None:
        """The rowid fast path: matrix tables are populated in canonical
        row-major order (``relation_io.matrix_to_columns``) and the delta
        path never deletes individual rows, so ``rowid == flat_index + 1``
        — one prepared two-parameter UPDATE per changed cell, no (i, j)
        predicate evaluation."""
        _check_ident(name)
        self.matrix_digests.pop(name, None)
        self.bump_gen(name)
        self.executemany(f"update {name} set v = ? where rowid = ?",
                         zip(values.tolist(), (flat_index + 1).tolist()))
