with scat_c0(i, j, v) as (
  select a.i, b.j, coalesce(acc.v, 0.0) as v
  from (select generate_series as i from generate_series(1,5)) a cross join
       (select generate_series as j from generate_series(1,3)) b
  left join (
    select cast(g.v as integer) + 1 as i, m.j, sum(m.v) as v
      from zidx as g inner join zx as m on m.i = g.i
     group by cast(g.v as integer) + 1, m.j
  ) acc on acc.i = a.i and acc.j = b.j
)
select 0 as r, i, j, v from scat_c0;
