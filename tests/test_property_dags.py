"""Property tests over RANDOM expression DAGs (the core contribution).

For arbitrary well-typed matrix expression graphs built from the paper's
building blocks (Listing 4):
  * the relational engine ≡ the dense engine (representation invariance);
  * Algorithm-1 gradients ≡ jax.grad of the dense evaluation;
  * the SQL-92 rendering is structurally well-formed.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install repro[test])")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import Engine, autodiff, dense
from repro.core import expr as E
from repro.core import sqlgen


def build_random_dag(draw, n_ops: int, dims: list[int]):
    """Grow a DAG of matrix ops over leaves of compatible shapes."""
    rng_shapes = lambda: (draw(st.sampled_from(dims)),
                          draw(st.sampled_from(dims)))
    leaves = {}
    nodes = []
    for i in range(draw(st.integers(2, 4))):
        shape = rng_shapes()
        v = E.var(f"x{i}", shape)
        leaves[f"x{i}"] = shape
        nodes.append(v)
    for _ in range(n_ops):
        op = draw(st.sampled_from(
            ["matmul", "hadamard", "add", "sub", "sigmoid", "square",
             "transpose", "scale"]))
        a = draw(st.sampled_from(nodes))
        if op == "matmul":
            compat = [n for n in nodes if n.shape[0] == a.shape[1]]
            if not compat:
                continue
            b = draw(st.sampled_from(compat))
            nodes.append(E.matmul(a, b))
        elif op in ("hadamard", "add", "sub"):
            compat = [n for n in nodes if n.shape == a.shape]
            if not compat:
                continue
            b = draw(st.sampled_from(compat))
            nodes.append(getattr(E, op)(a, b))
        elif op == "sigmoid":
            nodes.append(E.sigmoid(a))
        elif op == "square":
            nodes.append(E.square(a))
        elif op == "transpose":
            nodes.append(E.transpose(a))
        else:
            nodes.append(E.scale(draw(st.floats(-2, 2)), a))
    return nodes[-1], leaves


@st.composite
def dag_and_env(draw):
    root, leaves = build_random_dag(draw, draw(st.integers(2, 8)),
                                    dims=[2, 3, 4])
    seed = draw(st.integers(0, 2 ** 16))
    rng = np.random.RandomState(seed)
    env = {name: jnp.asarray(rng.randn(*shape) * 0.5, jnp.float32)
           for name, shape in leaves.items()}
    return root, env


@given(dag_and_env())
@settings(max_examples=25, deadline=None)
def test_engines_agree_on_random_dags(case):
    root, env = case
    (d,) = dense.evaluate([root], env)
    eng = Engine("relational")
    lifted = {k: eng.lift(v) for k, v in env.items()}
    (r,) = eng.evaluate([root], lifted)
    np.testing.assert_allclose(np.asarray(d), np.asarray(r.to_dense()),
                               rtol=2e-4, atol=1e-5)


@given(dag_and_env())
@settings(max_examples=25, deadline=None)
def test_algorithm1_matches_jax_grad_on_random_dags(case):
    root, env = case
    wrt = [v for v in E.free_vars(root)]
    grads = autodiff.derive(root, E.const(1.0, root.shape))
    flowing = [v for v in wrt if v in grads]
    if not flowing:
        return
    outs = dense.evaluate([grads[v] for v in flowing], env)

    def scalar(vals):
        e2 = dict(env)
        for v, val in zip(flowing, vals):
            e2[v.name] = val
        (out,) = dense.evaluate([root], e2)
        return jnp.sum(out)

    jgrads = jax.grad(scalar)([env[v.name] for v in flowing])
    for got, expect, v in zip(outs, jgrads, flowing):
        np.testing.assert_allclose(np.asarray(got), np.asarray(expect),
                                   rtol=5e-3, atol=5e-4, err_msg=v.name)


@given(dag_and_env())
@settings(max_examples=15, deadline=None)
def test_sqlgen_well_formed_on_random_dags(case):
    root, env = case
    sql = sqlgen.to_sql92([root])
    assert sql.count("(") == sql.count(")")
    assert sql.startswith("with ") or sql.startswith("select")
    assert sql.rstrip().endswith(";")
