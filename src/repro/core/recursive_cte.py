"""Recursive CTE — the paper's iteration construct, on TPU.

``WITH RECURSIVE w(iter, id, i, j, v) AS (base UNION ALL step)`` drives
gradient descent in Listings 1/7/10: the weight table is the recursion
variable, each recursion step emits the next weight version.

Two semantics are provided:

``recursive_cte(..., materialize_history=False)`` (default)
    ``lax.scan`` with a donated carry: only the latest weight version is
    live. This is the optimisation the paper's §8 asks database engines for
    ("optimisers should eliminate intermediate results within the CTE").

``materialize_history=True``
    Faithful UNION-ALL semantics: every iteration's weight table stays
    materialised (stacked along a leading ``iter`` axis), reproducing the
    paper's observation that "the recursive CTE grew with each iteration.
    This resulted in increased memory consumption per iteration, which
    limited the number of iterations and the model size."
    ``benchmarks/cte_growth.py`` measures the difference.
"""
from __future__ import annotations

from typing import Callable, TypeVar

import jax
import jax.numpy as jnp

T = TypeVar("T")


def recursive_cte(base: T, step: Callable[[T, int], T], n_iters: int,
                  materialize_history: bool = False):
    """Iterate ``step`` starting from ``base``.

    Returns ``(final, history)``; ``history`` is ``None`` unless
    ``materialize_history`` — then it stacks every iterate (incl. base row 0)
    along axis 0, like ``select * from w order by iter``.
    """

    def body(carry, it):
        nxt = step(carry, it)
        return nxt, (nxt if materialize_history else None)

    final, hist = jax.lax.scan(body, base, jnp.arange(n_iters))
    if materialize_history:
        hist = jax.tree.map(
            lambda b, h: jnp.concatenate([b[None], h], axis=0), base, hist)
        return final, hist
    return final, None


def recursive_cte_py(base: T, step: Callable[[T, int], T], n_iters: int,
                     materialize_history: bool = False):
    """Pure-Python twin of :func:`recursive_cte` for steps that are not
    jax-traceable — e.g. the in-database backend, where each step issues an
    ``INSERT INTO w … SELECT`` (``repro.db.train`` strategy "stepped") and
    the database holds the state.  Same contract: ``(final, history)``,
    ``history`` includes the base iterate or is ``None``."""
    state = base
    hist = [base] if materialize_history else None
    for it in range(n_iters):
        state = step(state, it)
        if materialize_history:
            hist.append(state)
    return state, hist


def history_bytes(tree, n_iters: int) -> int:
    """Memory the UNION-ALL table reaches after ``n_iters`` recursions."""
    per_iter = sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
    return per_iter * (n_iters + 1)
