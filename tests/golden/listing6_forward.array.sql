with z_xh(m) as (
  select mm((select m from img), (select m from w_xh)) as m
),
a_xh(m) as (
  select msig((select m from z_xh)) as m
),
z_ho(m) as (
  select mm((select m from a_xh), (select m from w_ho)) as m
),
a_ho(m) as (
  select msig((select m from z_ho)) as m
)
select m from a_ho;
