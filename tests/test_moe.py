"""MoE: the paper's two representations must agree exactly."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install repro[test])")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.nn.moe import MoEConfig, _route, init_moe, moe_ffn

RNG = np.random.RandomState(0)


def make(t=32, d=16, e=8, k=2, ff=32, n_shared=0, cf=1.25, rsm="pre",
         seed=0):
    cfg = lambda impl: MoEConfig(n_experts=e, top_k=k, d_model=d, d_ff=ff,
                                 n_shared=n_shared, capacity_factor=cf,
                                 router_softmax=rsm, impl=impl)
    p = init_moe(jax.random.PRNGKey(seed), cfg("einsum"))
    x = jnp.asarray(np.random.RandomState(seed).randn(t, d), jnp.float32)
    return cfg, p, x


def test_einsum_equals_sort():
    """Array representation ≡ relational representation (same drops)."""
    cfg, p, x = make()
    o1, a1 = moe_ffn(p, x, cfg("einsum"))
    o2, a2 = moe_ffn(p, x, cfg("sort"))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-4)
    np.testing.assert_allclose(float(a1), float(a2), rtol=1e-5)


def test_einsum_equals_sort_with_drops():
    """Tight capacity forces drops; priority must match between impls."""
    cfg, p, x = make(t=64, cf=0.5)
    o1, _ = moe_ffn(p, x, cfg("einsum"))
    o2, _ = moe_ffn(p, x, cfg("sort"))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-4)


def test_post_softmax_router_and_shared():
    cfg, p, x = make(n_shared=1, rsm="post", seed=3)
    o1, _ = moe_ffn(p, x, cfg("einsum"))
    o2, _ = moe_ffn(p, x, cfg("sort"))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=2e-3, atol=2e-4)
    assert jnp.isfinite(o1).all()


def test_route_gates_normalised():
    cfg, p, x = make()
    gates, idx, aux = _route(p, x, cfg("einsum"))
    np.testing.assert_allclose(np.asarray(gates.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (32, 2) and float(aux) > 0


def test_gradients_flow_both_impls():
    cfg, p, x = make()
    for impl in ("einsum", "sort"):
        g = jax.grad(lambda pp: jnp.sum(moe_ffn(pp, x, cfg(impl))[0] ** 2)
                     )(p)
        total = sum(float(jnp.sum(jnp.abs(l))) for l in jax.tree.leaves(g))
        assert np.isfinite(total) and total > 0, impl


@given(t=st.integers(8, 48), e=st.sampled_from([4, 8]),
       k=st.integers(1, 3), seed=st.integers(0, 99))
@settings(max_examples=10, deadline=None)
def test_property_impls_agree(t, e, k, seed):
    cfg, p, x = make(t=t, e=e, k=min(k, e), seed=seed)
    o1, _ = moe_ffn(p, x, cfg("einsum"))
    o2, _ = moe_ffn(p, x, cfg("sort"))
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2),
                               rtol=5e-3, atol=5e-4)


def test_shard_partials_sum_to_full():
    """Expert-owner partial combine: summing per-owner partials over a
    partition of the expert range equals the full relational result —
    the correctness core of the shard_map (impl='shard') plan."""
    from repro.nn.moe import _capacity, _moe_sort_local, _moe_sort_one, _route
    cfg_f, p, x = make(t=40, e=8, k=2, seed=5)
    cfg = cfg_f("sort")
    gates, idx, _ = _route(p, x, cfg)
    cap = _capacity(x.shape[0], cfg)
    full = _moe_sort_one(p, x, cfg, gates, idx)
    halves = sum(
        _moe_sort_local(p["wi"][lo:lo + 4], p["wg"][lo:lo + 4],
                        p["wo"][lo:lo + 4], x, cfg, gates, idx,
                        lo, 4, cap)
        for lo in (0, 4))
    np.testing.assert_allclose(np.asarray(full), np.asarray(halves),
                               rtol=2e-3, atol=2e-4)
