"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

Each function is the semantic ground truth; kernel tests sweep shapes and
dtypes asserting allclose against these.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def relational_matmul(row_ids: jax.Array, col_ids: jax.Array,
                      vals: jax.Array, b: jax.Array, m: int) -> jax.Array:
    """The paper's join + group-by matmul over a COO relation.

    out[i, :] = Σ_{t: row_ids[t]=i} vals[t] · b[col_ids[t], :]
    Padding tuples carry ``row_ids == m`` and are dropped.
    """
    joined = vals[:, None].astype(jnp.float32) * b[col_ids].astype(jnp.float32)
    return jax.ops.segment_sum(joined, row_ids, num_segments=m)


def fused_sigmoid_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """sig(X · W) — one forward CTE of the paper's model (Eq. 4)."""
    z = jnp.dot(x.astype(jnp.float32), w.astype(jnp.float32))
    return (1.0 / (1.0 + jnp.exp(-z))).astype(x.dtype)


def onehot_embed(ids: jax.Array, table: jax.Array) -> jax.Array:
    """onehot(ids) · table — the one-hot matmul is a row gather (§4.1)."""
    return table[ids]


def moe_dispatch(x: jax.Array, sort_idx: jax.Array,
                 gates: jax.Array) -> jax.Array:
    """Dispatch side of the token→expert relation: gather each assignment's
    token row and scale by its gate value (the join's select clause)."""
    return x[sort_idx] * gates[:, None].astype(x.dtype)


def moe_combine(expert_out: jax.Array, row_ids: jax.Array,
                n_tokens: int) -> jax.Array:
    """Combine side: group the relation by destination token and sum —
    identical to relational_matmul's aggregation with vals pre-applied."""
    return jax.ops.segment_sum(expert_out.astype(jnp.float32), row_ids,
                               num_segments=n_tokens).astype(expert_out.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                    causal: bool = True, scale: float | None = None
                    ) -> jax.Array:
    """Dense-softmax attention oracle. q: (B, Hq, S, D); k/v: (B, Hkv, S, D)
    with Hq a multiple of Hkv (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    if scale is None:
        scale = d ** -0.5
    group = hq // hkv
    k = jnp.repeat(k, group, axis=1)
    v = jnp.repeat(v, group, axis=1)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    if causal:
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        logits = jnp.where(mask, logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", probs, v.astype(jnp.float32))
    return out.astype(q.dtype)


def rwkv6_scan(r, k, v, w, u, s0):
    """RWKV-6 recurrence oracle. r/k/v/w: (BH,S,N); u: (BH,N); s0: (BH,N,N).
    o_t = r_t·(S + diag(u) k_t v_tᵀ); S ← diag(w_t) S + k_t v_tᵀ."""

    def step(S, inp):
        r_t, k_t, v_t, w_t = inp
        kv = k_t[:, :, None] * v_t[:, None, :]
        o = jnp.einsum("bi,bij->bj", r_t, S + u[:, :, None] * kv)
        return w_t[:, :, None] * S + kv, o

    seq = tuple(x.transpose(1, 0, 2).astype(jnp.float32)
                for x in (r, k, v, w))
    s_fin, outs = jax.lax.scan(step, s0.astype(jnp.float32), seq)
    return outs.transpose(1, 0, 2), s_fin
