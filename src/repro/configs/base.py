"""Architecture config schema + registry.

One ``<arch>.py`` per assigned architecture instantiates an ``ArchConfig``
with the exact published dimensions, and a ``reduced()`` variant for CPU
smoke tests. ``family`` selects the layer stack in ``nn.model``.
"""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    kv_lora: int = 512
    d_nope: int = 128
    d_rope: int = 64
    d_v: int = 128


@dataclasses.dataclass(frozen=True)
class MoESpec:
    n_experts: int
    top_k: int
    d_ff_expert: int
    n_shared: int = 0
    first_k_dense: int = 0        # leading dense-FFN layers (DeepSeek)
    d_ff_dense: int = 0           # FFN width of those layers
    router_softmax: str = "pre"
    impl: str = "einsum"          # "einsum" (array rep) | "sort" (relational)
    capacity_factor: float = 1.25


@dataclasses.dataclass(frozen=True)
class SSMSpec:
    d_state: int = 64
    head_dim: int = 64            # P per head (mamba2) / N per head (rwkv6)
    d_conv: int = 4
    expand: int = 2
    chunk: int = 64


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                   # dense | moe | audio | ssm | vlm | hybrid
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    d_head: int = 128
    norm: str = "rmsnorm"         # rmsnorm | layernorm
    mlp: str = "swiglu"           # swiglu | gelu
    rope: bool = True
    rope_theta: float = 1e4
    qk_norm: bool = False
    qkv_bias: bool = False
    tie_embeddings: bool = False
    moe: Optional[MoESpec] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMSpec] = None
    stub_frontend: Optional[str] = None   # "audio_frames" | "vision_patches"
    shared_attn_every: int = 0            # zamba2: shared block period
    sub_quadratic: bool = False           # may run long_500k
    # execution knobs (hillclimbed in §Perf)
    attn_impl: str = "flash"              # flash | chunked | dense
    attn_chunk: int = 0                   # 0 = auto
    remat: str = "full"                   # none | full | dots
    scan_layers: bool = True
    ssm_bf16: bool = False                # SSD chunk math in bf16 (§Perf)
    attn_bf16_scores: bool = False        # flash score/prob blocks in bf16
    flash_impl: str = "unrolled"          # unrolled (exact FLOP count) |
                                          # scan (bounded-liveness memory)
    ssd_impl: str = "parallel"            # parallel | scan (same trade)
    param_dtype: str = "float32"          # float32 | bfloat16 (f32 master
                                          # weights live in the optimizer)
    loss_impl: str = "full"               # full | chunked (vocab-streamed CE)
    loss_chunk: int = 16384

    def n_heads_mamba(self) -> int:
        return (self.ssm.expand * self.d_model) // self.ssm.head_dim

    @property
    def n_params(self) -> int:
        """Approximate parameter count (embeddings + blocks)."""
        d, L = self.d_model, self.n_layers
        emb = self.vocab * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            blk = 5 * d * d + d * d + 2 * d * self.d_ff + d * d  # rwkv6-ish
        elif self.family == "hybrid":
            di = self.ssm.expand * d
            blk = d * (2 * di + 2 * self.ssm.d_state +
                       di // self.ssm.head_dim) + di * d
        else:
            if self.mla is not None:
                h = self.n_heads
                m = self.mla
                att = (d * h * (m.d_nope + m.d_rope) + d * m.kv_lora +
                       m.kv_lora * h * (m.d_nope + m.d_v) + d * m.d_rope +
                       h * m.d_v * d)
            else:
                att = (d * self.n_heads * self.d_head * 2 +
                       d * self.n_kv_heads * self.d_head * 2)
            if self.moe is not None:
                ff = (3 * d * self.moe.d_ff_expert *
                      (self.moe.n_experts + self.moe.n_shared))
            elif self.mlp == "swiglu":
                ff = 3 * d * self.d_ff
            else:
                ff = 2 * d * self.d_ff
            blk = att + ff
        total = emb + L * blk
        if self.shared_attn_every:
            total += (2 * self.d_model) * self.n_heads * self.d_head * 2 \
                + self.n_heads * self.d_head * self.d_model \
                + 3 * self.d_model * self.d_ff
        return total

    def n_active_params(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.moe is None:
            return self.n_params
        d, L = self.d_model, self.n_layers
        full_ff = 3 * d * self.moe.d_ff_expert * (self.moe.n_experts +
                                                  self.moe.n_shared)
        act_ff = 3 * d * self.moe.d_ff_expert * (self.moe.top_k +
                                                 self.moe.n_shared)
        return self.n_params - L * (full_ff - act_ff)


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str                     # train | prefill | decode


SHAPES = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

ARCH_IDS = [
    "yi_6b", "qwen3_8b", "qwen2_5_14b", "granite_3_8b",
    "deepseek_v2_lite_16b", "dbrx_132b", "musicgen_medium", "rwkv6_7b",
    "internvl2_1b", "zamba2_2_7b",
]


def get_config(arch_id: str, reduced: bool = False) -> ArchConfig:
    mod = importlib.import_module(f"repro.configs.{arch_id}")
    return mod.reduced() if reduced else mod.CONFIG


def shape_applicable(cfg: ArchConfig, shape: ShapeSpec) -> bool:
    """long_500k only for sub-quadratic families (DESIGN.md §Arch-applicability)."""
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False
    return True
