import os
import sys

# NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
# benchmarks must see the single real CPU device (the 512-device mesh is
# exclusively the dry-run's, launched as its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
