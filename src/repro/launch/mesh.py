"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model) — 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes: ('pod', 'data') multi-pod, ('data',) single."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size
