"""SQL transpiler golden tests against the paper's listings' structure."""
import numpy as np

from repro.core import nn2sql, sqlgen
from repro.core import expr as E
from repro.core.autodiff import derive


def graph():
    return nn2sql.build_graph(nn2sql.MLPSpec(150, 4, 20, 3))


class TestBuildingBlocks:
    """Listing 4: matmul / hadamard / sigmoid / transpose renderings."""

    def test_matmul_is_join_groupby(self):
        m = E.var("m", (3, 4))
        n = E.var("n", (4, 5))
        sql = sqlgen.to_sql92([E.matmul(m, n, name="mm")])
        assert "sum(m.v*n.v)" in sql
        assert "inner join n as n on m.j = n.i" in sql
        assert "group by m.i, n.j" in sql

    def test_hadamard_is_two_index_join(self):
        m, n = E.var("m", (3, 4)), E.var("n", (3, 4))
        sql = sqlgen.to_sql92([E.hadamard(m, n, name="h")])
        assert "on m.i = n.i and m.j = n.j" in sql

    def test_sigmoid_is_select_map(self):
        sql = sqlgen.to_sql92([E.sigmoid(E.var("m", (2, 2)), name="s")])
        assert "1/(1+exp(-v))" in sql

    def test_transpose_is_index_rename(self):
        sql = sqlgen.to_sql92([E.transpose(E.var("m", (2, 3)), name="t")])
        assert "select j as i, i as j, v from m" in sql


class TestTrainingQuery:
    """Listing 7: the recursive training CTE."""

    def test_structure(self):
        sql = sqlgen.training_query_sql92(graph(), n_iters=20, lr=0.01)
        assert sql.startswith("with recursive w (iter, id, i, j, v) as (")
        # base case unions both weight tables with ids 0/1
        assert "select 0, 0, * from w_xh_init union all" in sql
        assert "select 0, 1, * from w_ho_init" in sql
        # recursive reference only once (PostgreSQL restriction, cf. paper)
        assert sql.count("from w\n") + sql.count("from w ") == 1
        # the forward CTEs appear, reusing cached a_xh / a_ho
        for cte in ("a_xh", "a_ho", "z_xh", "z_ho"):
            assert f"{cte}(i, j, v) as (" in sql
        # weight update: w - γ·d_w with join on id/i/j
        assert "w_.v - 0.01 * d_w.v" in sql
        assert "w_.iter < 20" in sql

    def test_sigmoid_derivative_uses_cached_cte(self):
        """Eq. 7/9: sig' from the cached output CTE, v*(1-v)."""
        sql = sqlgen.training_query_sql92(graph(), 10, 0.01)
        assert "v*(1-v)" in sql

    def test_executable_shape(self):
        # every '(' balances — cheap syntactic sanity for the generator
        sql = sqlgen.training_query_sql92(graph(), 5, 0.01)
        assert sql.count("(") == sql.count(")")
        assert sql.rstrip().endswith("select * from w;")


class TestArrayQuery:
    """Listing 10: SQL + Arrays rendering."""

    def test_operators(self):
        g = graph()
        sql = sqlgen.training_query_arrays(g, n_iters=20, lr=0.01)
        assert "with recursive w (id, w_xh, w_ho) as (" in sql
        assert "**" in sql                       # matmul operator
        assert "transpose(" in sql
        assert "sig(" in sql
        assert "id < 20" in sql
        assert sql.count("(") == sql.count(")")

    def test_gradient_expression_matches_eq10_11(self):
        g = graph()
        sql = sqlgen.training_query_arrays(g, 20, 0.01)
        # Eq. 11: transpose(img) ** d_xh, where sig' reuses the cached
        # forward expression: (a_xh * (1 - a_xh))
        assert "transpose(img)" in sql
        assert "(a_xh * (1 - a_xh))" in sql
        assert "(a_ho * (1 - a_ho))" in sql
        assert "transpose(w_ho)" in sql              # Eq. 8


class TestForwardInference:
    def test_inference_query(self):
        g = graph()
        sql = sqlgen.to_sql92([g.a_ho])
        assert "from img" in sql and "group by" in sql
        np = sqlgen.to_sql_arrays([g.a_ho])
        assert "sig((a_xh ** w_ho))" in np or "sig" in np
