"""Data pipeline: paper datasets (synthetic, shape-faithful) + LM token streams."""
from .pipeline import TokenPipeline, make_iris, make_mnist_like, one_hot_labels, replicate, stub_frontend_batch
