with z_xh(m) as (
  select mm((select m from img), (select m from w_xh)) as m
),
a_xh(m) as (
  select msig((select m from z_xh)) as m
),
z_ho(m) as (
  select mm((select m from a_xh), (select m from w_ho)) as m
),
a_ho(m) as (
  select msig((select m from z_ho)) as m
),
diff(m) as (
  select msub((select m from a_ho), (select m from one_hot)) as m
),
loss(m) as (
  select msqr((select m from diff)) as m
),
t_c0(m) as (
  select mt((select m from img)) as m
),
had_c3(m) as (
  select mhad(mhad(mconst(4,2,1.0), msqrd((select m from diff))), msigd((select m from a_ho))) as m
),
t_c4(m) as (
  select mt((select m from w_ho)) as m
),
mm_c5(m) as (
  select mm((select m from had_c3), (select m from t_c4)) as m
),
had_c6(m) as (
  select mhad((select m from mm_c5), msigd((select m from a_xh))) as m
),
mm_c7(m) as (
  select mm((select m from t_c0), (select m from had_c6)) as m
),
t_c8(m) as (
  select mt((select m from a_xh)) as m
),
mm_c9(m) as (
  select mm((select m from t_c8), (select m from had_c3)) as m
)
select 0 as r, m from loss
union all select 1 as r, m from mm_c7
union all select 2 as r, m from mm_c9;
