"""Property tests for the Gather/Scatter index-relation primitives.

The algebra the autodiff rules rely on, over random shapes/indices:

* permutation round-trip: scatter(gather(x, π), π) = x and
  gather(scatter(y, π), π) = y for any permutation index relation π;
* adjointness: ⟨gather(x, idx), y⟩ = ⟨x, scatter(y, idx)⟩ for *any*
  index multiset (duplicates and gaps included) — Gather and Scatter are
  exact transposes, which is why ``derive`` can swap them;
* dense ≡ sqlite on the same random relations.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install -e .[test])")
import hypothesis.strategies as st
from hypothesis import given, settings

import jax.numpy as jnp

from repro.core import dense
from repro.core import expr as E
from repro.db.sql_engine import SQLEngine

finite = st.floats(-1e4, 1e4, allow_nan=False, allow_infinity=False,
                   width=32)


@st.composite
def gather_case(draw):
    """x (R, C) plus an arbitrary (S, 1) index relation into its rows."""
    r = draw(st.integers(1, 6))
    c = draw(st.integers(1, 5))
    s = draw(st.integers(1, 8))
    vals = draw(st.lists(finite, min_size=r * c, max_size=r * c))
    idx = draw(st.lists(st.integers(0, r - 1), min_size=s, max_size=s))
    x = np.asarray(vals, dtype=np.float32).reshape(r, c)
    return x, np.asarray(idx, dtype=np.float64).reshape(s, 1)


@st.composite
def permutation_case(draw):
    r = draw(st.integers(1, 6))
    c = draw(st.integers(1, 5))
    vals = draw(st.lists(finite, min_size=r * c, max_size=r * c))
    perm = draw(st.permutations(list(range(r))))
    x = np.asarray(vals, dtype=np.float32).reshape(r, c)
    return x, np.asarray(perm, dtype=np.float64).reshape(r, 1)


def ev(roots, env):
    return [np.asarray(o) for o in dense.evaluate(
        roots, {k: jnp.asarray(v) for k, v in env.items()})]


@settings(max_examples=30, deadline=None)
@given(permutation_case())
def test_permutation_round_trips(case):
    x, perm = case
    r, c = x.shape
    xv = E.var("x", (r, c))
    iv = E.var("idx", (r, 1))
    back, = ev([E.scatter(E.gather(xv, iv), iv, r)],
               {"x": x, "idx": perm})
    np.testing.assert_allclose(back, x, atol=1e-5)
    fwd, = ev([E.gather(E.scatter(xv, iv, r), iv)],
              {"x": x, "idx": perm})
    want = np.zeros_like(x)
    want[perm[:, 0].astype(int)] = x
    got_scatter, = ev([E.scatter(xv, iv, r)], {"x": x, "idx": perm})
    np.testing.assert_allclose(got_scatter, want, atol=1e-5)
    np.testing.assert_allclose(fwd, x, atol=1e-5)


@settings(max_examples=30, deadline=None)
@given(gather_case(), st.integers(0, 2 ** 31 - 1))
def test_gather_scatter_adjoint(case, seed):
    x, idx = case
    r, c = x.shape
    s = idx.shape[0]
    y = np.asarray(np.random.RandomState(seed).randn(s, c), np.float32)
    xv = E.var("x", (r, c))
    yv = E.var("y", (s, c))
    iv = E.var("idx", (s, 1))
    gx, sy = ev([E.gather(xv, iv), E.scatter(yv, iv, r)],
                {"x": x, "y": y, "idx": idx})
    lhs = float((gx * y).sum())
    rhs = float((x * sy).sum())
    np.testing.assert_allclose(lhs, rhs, rtol=1e-4, atol=1e-2)


@settings(max_examples=10, deadline=None)
@given(gather_case())
def test_sqlite_matches_dense(case):
    x, idx = case
    r, c = x.shape
    s = idx.shape[0]
    xv = E.var("x", (r, c))
    iv = E.var("idx", (s, 1))
    roots = [E.gather(xv, iv), E.scatter(E.gather(xv, iv), iv, r)]
    want = ev(roots, {"x": x, "idx": idx})
    with SQLEngine(plan_cache_=False) as eng:
        got = eng.evaluate(roots, {"x": x, "idx": idx})
    for g, w in zip(got, want):
        np.testing.assert_allclose(g, w, atol=1e-4)
