"""The postgres backend — a psycopg2 session against a running server.

Unlike the embedded engines, postgres is client/server: the adapter holds
one session, identified by a libpq DSN (``connect("postgres", dsn)`` or the
``REPRO_PG_DSN`` environment variable — the CI ``postgres-extras`` job
points it at a service container).  Differences the contract absorbs:

* **param style** — psycopg2 is ``format`` (``%s``); every shared call
  site renders through ``Adapter.placeholder``.
* **no connection-level execute** — psycopg2 runs statements on cursors;
  only the ``_execute_raw`` / ``_executemany_raw`` seams are overridden,
  so the traced/locked/counted wrappers are untouched.
* **autocommit** — a failed statement would otherwise poison the session
  transaction (``InFailedSqlTransaction`` on every later statement, where
  sqlite/duckdb recover per-statement); autocommit matches their
  semantics.
* **no Python UDFs** — ``supports_python_udfs = False``: the server is
  plpython-free, so only pure-SQL paths (the relational representation,
  the sql92/window-function dialect machinery) run here.  The array-UDF
  zoo and Listing-7-style single-CTE recursion (postgres rejects the
  recursive self-reference inside a subquery) are unavailable; training
  uses the stepped driver.
* **temp tables** — ``create temp table`` is session-scoped and shadows
  the main schema via ``pg_temp`` leading the search path: exactly the
  shadowing semantics the shared ``create_table`` logic assumes.

Ingestion uses ``psycopg2.extras.execute_values`` — one multi-row VALUES
statement per page, the driver's bulk path."""
from __future__ import annotations

import os
from typing import Iterable, Sequence

import numpy as np

from ...obs import tracer_of
from ..dialect import PostgresDialect
from .base import Adapter, _check_ident

try:  # pragma: no cover - depends on environment
    import psycopg2
    HAVE_PSYCOPG2 = True
except ImportError:  # pragma: no cover - the container default
    psycopg2 = None
    HAVE_PSYCOPG2 = False

#: libpq DSN used when ``connect("postgres")`` is called without one —
#: how CI points the suite at its postgres service container
PG_DSN_ENV = "REPRO_PG_DSN"


def resolve_dsn(dsn: str | None = None) -> str:
    """An explicit DSN wins; ``None`` / ``""`` / ``":memory:"`` (the
    path-argument defaults of ``connect``/``ConnectionPool``) fall back to
    ``REPRO_PG_DSN``."""
    if dsn and dsn != ":memory:":
        return dsn
    env = os.environ.get(PG_DSN_ENV, "")
    if not env:
        raise ValueError(
            "postgres backend needs a DSN: pass one as the path argument "
            f"or set {PG_DSN_ENV}")
    return env


class PostgresAdapter(Adapter):  # pragma: no cover - needs a server
    placeholder = "%s"
    paramstyle = "format"
    supports_python_udfs = False

    #: rows per multi-row VALUES page in ``execute_values``
    PAGE_SIZE = 1000

    def __init__(self, dsn: str | None = None):
        if not HAVE_PSYCOPG2:
            raise ImportError(
                "psycopg2 is not installed; use backend='sqlite' or "
                "pip install psycopg2-binary")
        self.dialect = PostgresDialect()
        self.dsn = resolve_dsn(dsn)
        conn = psycopg2.connect(self.dsn)
        conn.autocommit = True
        super().__init__(conn)
        # sibling sessions on one DSN share a catalog (and generations);
        # temp tables stay per-adapter through _temp_tables as everywhere
        self._db_key = "postgres:" + self.dsn

    def _execute_raw(self, sql: str, params: Sequence):
        # obs: exempt — driver seam under Adapter.execute's span+lock;
        # psycopg2 has no connection-level execute, statements run on
        # cursors.  params=None when empty: with a (possibly empty)
        # params sequence psycopg2 %-interpolates the SQL, and rendered
        # plans legitimately contain % (modulo arithmetic)
        cur = self.conn.cursor()
        cur.execute(sql, tuple(params) if params else None)
        return cur

    def _executemany_raw(self, sql: str, rows: Iterable[Sequence]) -> None:
        # obs: exempt — driver seam under Adapter.executemany's span+lock
        cur = self.conn.cursor()
        cur.executemany(sql, [tuple(r) for r in rows])

    def explain_sql(self, sql: str) -> str:
        """postgres spells it plain ``EXPLAIN`` (cost-annotated plan)."""
        try:
            rows = self.execute("explain " + sql)
        except Exception:
            return ""
        return "\n".join(str(r[0]) for r in rows)

    def db_bytes(self) -> int | None:
        try:
            rows = self.execute(
                "select pg_database_size(current_database())")
            return int(rows[0][0])
        except Exception:
            return None

    def insert_columns(self, name: str,
                       cols: Sequence[np.ndarray]) -> None:
        """``execute_values`` bulk path: one multi-row VALUES statement
        per ``PAGE_SIZE`` rows, page assembly inside the driver."""
        try:
            from psycopg2.extras import execute_values
        except ImportError:
            return Adapter.insert_columns(self, name, cols)
        cols, n = self._prepare_columns(name, cols)
        if not n:
            return
        rows = list(zip(*(c.tolist() for c in cols)))
        tr = tracer_of(self)
        with tr.span("db.ingest_values", table=name, rows=n), self.lock:
            cur = self.conn.cursor()
            execute_values(cur, f"insert into {_check_ident(name)} values %s",
                           rows, page_size=self.PAGE_SIZE)
            self.counters["statements"] += 1
