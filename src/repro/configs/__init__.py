"""Architecture configs: one module per assigned arch + the paper's MLPs."""
from .base import ARCH_IDS, SHAPES, ArchConfig, ShapeSpec, get_config, shape_applicable

__all__ = ["ARCH_IDS", "SHAPES", "ArchConfig", "ShapeSpec", "get_config",
           "shape_applicable"]
