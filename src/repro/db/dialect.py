"""SQL dialects for the in-database execution backend.

The transpiler (``core.sqlgen``) renders the expression DAG against a
*dialect* object so the same generator serves several engines (§6 of the
paper evaluates DuckDB, HyPer and PostgreSQL; we target what the container
actually ships):

``Sql92Dialect``
    The paper's verbatim SQL-92: ``generate_series`` table function,
    ``exp`` / ``greatest`` builtins.  This is the golden-test dialect — its
    output matches the listings' structure exactly.

``SqliteDialect``
    stdlib ``sqlite3``, always available.  Two deviations are needed:

    * ``generate_series`` is a loadable extension sqlite3 does not ship, so
      constant matrices are built from an inline ``WITH RECURSIVE`` series
      (the emulation forces the top-level ``WITH`` to say ``RECURSIVE``);
    * ``exp`` and ``greatest`` are not built in — they are registered as
      deterministic Python UDFs on every connection (``prepare``).

    SQLite additionally restricts recursive CTEs: the recursive table may
    appear exactly once, in the *top-level* FROM clause of the recursive
    select — never inside a subquery ("circular reference") — and recursion
    is row-at-a-time queue semantics.  Listing 7's relational training query
    (which re-reads the whole previous weight *table* through a nested WITH)
    is therefore inexpressible; the training loop instead runs the paper's
    *array-data-type* variant (Listing 10): the whole weight state rides in
    ONE row of array-typed columns, and the matrix algebra is provided by
    registered UDFs over a JSON array encoding — ``create_function`` being
    sqlite's analogue of the paper's §5 DuckDB array-type extension.

``DuckDBDialect``
    Used when the ``duckdb`` package is importable (``pip install
    repro[db]``).  Stock SQL-92 rendering works unchanged (DuckDB has
    ``generate_series``, ``exp``, ``greatest``), and the Listing 7 / 10
    training queries are rendered by ``core.sqlgen`` verbatim.
"""
from __future__ import annotations

import json
import math

import numpy as np

from ..core import expr as E

try:  # optional dependency, gated — never required
    import duckdb  # type: ignore

    HAVE_DUCKDB = True
except ImportError:  # pragma: no cover - exercised when duckdb is absent
    duckdb = None
    HAVE_DUCKDB = False


# ---------------------------------------------------------------------------
# JSON array codec — the "array data type" as sqlite sees it
# ---------------------------------------------------------------------------

def matrix_to_json(x) -> str:
    """Encode a matrix as the array data type: row-major values + dims."""
    a = np.asarray(x, dtype=np.float64)
    return json.dumps({"r": a.shape[0], "c": a.shape[1],
                       "d": a.reshape(-1).tolist()})


def json_to_matrix(s: str) -> np.ndarray:
    o = json.loads(s)
    return np.asarray(o["d"], dtype=np.float64).reshape(o["r"], o["c"])


def _wrap2(f):
    return lambda x, y: matrix_to_json(f(json_to_matrix(x), json_to_matrix(y)))


def _wrap1(f):
    return lambda x: matrix_to_json(f(json_to_matrix(x)))


#: name → (nargs, python impl).  These are the matrix operations of the
#: paper's §5 array extension; ``core.sqlgen.array_call_expr`` (and the
#: ``training_query_array_calls`` recursion built on it) renders expression
#: DAGs as nested calls over exactly these names.
ARRAY_UDFS: dict[str, tuple[int, object]] = {
    "mm": (2, _wrap2(lambda a, b: a @ b)),
    "madd": (2, _wrap2(lambda a, b: a + b)),
    "msub": (2, _wrap2(lambda a, b: a - b)),
    "mhad": (2, _wrap2(lambda a, b: a * b)),
    "mscale": (2, lambda c, x: matrix_to_json(c * json_to_matrix(x))),
    "mt": (1, _wrap1(lambda a: a.T)),
    "mconst": (3, lambda r, c, v: matrix_to_json(np.full((int(r), int(c)), v))),
    "mmean": (1, lambda x: float(json_to_matrix(x).mean())),
    # elementwise maps and their derivatives (Algorithm 1's f / f')
    "msig": (1, _wrap1(lambda a: 1.0 / (1.0 + np.exp(-a)))),
    "msigd": (1, _wrap1(lambda a: a * (1.0 - a))),        # from cached f(x)
    "msqr": (1, _wrap1(lambda a: a * a)),
    "msqrd": (1, _wrap1(lambda a: 2.0 * a)),
    "mrelu": (1, _wrap1(lambda a: np.maximum(a, 0.0))),
    "mrelud": (1, _wrap1(lambda a: (a > 0.0).astype(np.float64))),
    "mone_minus": (1, _wrap1(lambda a: 1.0 - a)),
}


# ---------------------------------------------------------------------------
# dialects
# ---------------------------------------------------------------------------

class Sql92Dialect:
    """The paper's SQL-92 as written in the listings (golden dialect)."""

    name = "sql92"
    #: whether constant matrices need the RECURSIVE keyword on the WITH
    series_is_recursive = False

    # -- scalar rendering ---------------------------------------------------
    def map_sql(self, fn: E.MapFn, v: str) -> str:
        """Select-clause rendering of an elementwise function."""
        return fn.sql(v)

    def series_from(self, n: int, alias: str, col: str) -> str:
        """A from-clause term yielding the integers 1..n as column ``col``."""
        return (f"(select generate_series as {col}"
                f" from generate_series(1,{n})) {alias}")

    def const_select(self, rows: int, cols: int, value: float) -> str:
        """A constant matrix as the cross join of two series (Listing 5)."""
        return (f"select a.i, b.j, {value} as v\n"
                f"  from {self.series_from(rows, 'a', 'i')},\n"
                f"       {self.series_from(cols, 'b', 'j')}")

    def frame_from(self, rows: int, cols: int) -> str:
        """A from-clause term yielding the full (i, j) index frame — the
        outer-join skeleton that keeps Scatter/RowShift outputs dense.
        Explicit CROSS JOIN so a following LEFT JOIN's ON clause may
        reference both series (comma precedence differs across engines)."""
        return (f"{self.series_from(rows, 'a', 'i')} cross join\n"
                f"       {self.series_from(cols, 'b', 'j')}")

    def topk_mask_select(self, src: str, k: int) -> str:
        """The ArgTopK indicator: 1 where the cell ranks in its row's top
        ``k`` by value (ties toward the smaller j).  Strict SQL-92 has no
        window functions, so the rank is a correlated count — engines with
        windows override with ``row_number()``."""
        return (f"select m.i, m.j, case when (select count(*) from {src} n"
                f" where n.i = m.i and (n.v > m.v or (n.v = m.v and n.j < m.j))"
                f") < {k} then 1.0 else 0.0 end as v\n  from {src} as m")

    # -- connection preparation --------------------------------------------
    def prepare(self, conn) -> None:
        """Install anything the rendered SQL assumes (UDFs etc.)."""

    # -- capability flags ---------------------------------------------------
    #: can the engine run Listing 7 verbatim (recursive table in a nested
    #: WITH inside the recursive select)?
    supports_listing7 = True


def _windowed_topk_mask(src: str, k: int) -> str:
    """row_number() rendering of the ArgTopK indicator (sqlite ≥3.25 and
    duckdb both have window functions; the rank order matches the SQL-92
    correlated count and ``dense.topk_mask`` exactly)."""
    return (f"select q.i, q.j, case when q.rnk <= {k} then 1.0 else 0.0 end"
            f" as v\n  from (select i, j, v, row_number() over"
            f" (partition by i order by v desc, j asc) as rnk"
            f" from {src}) q")


class SqliteDialect(Sql92Dialect):
    name = "sqlite"
    series_is_recursive = True
    supports_listing7 = False  # "circular reference" — see module docstring

    def series_from(self, n: int, alias: str, col: str) -> str:
        return (f"(with recursive s(x) as"
                f" (select 1 union all select x+1 from s where x < {n})"
                f" select x as {col} from s) {alias}")

    def topk_mask_select(self, src: str, k: int) -> str:
        return _windowed_topk_mask(src, k)

    def prepare(self, conn) -> None:
        conn.create_function("exp", 1, math.exp, deterministic=True)
        conn.create_function("greatest", 2, max, deterministic=True)
        for name, (nargs, fn) in ARRAY_UDFS.items():
            conn.create_function(name, nargs, fn, deterministic=True)


class DuckDBDialect(Sql92Dialect):
    name = "duckdb"

    def topk_mask_select(self, src: str, k: int) -> str:
        return _windowed_topk_mask(src, k)

    def prepare(self, conn) -> None:
        # generate_series / exp / greatest are native; the array UDFs back
        # the same Listing-10 rendering as sqlite (stock DuckDB has list
        # types but no matrix operators — the paper used a patched build).
        # DuckDB's create_function needs explicit types for lambdas.
        try:  # pragma: no cover - needs the [db] extra
            from duckdb.typing import DOUBLE, VARCHAR
            types = {"mscale": ([DOUBLE, VARCHAR], VARCHAR),
                     "mconst": ([DOUBLE, DOUBLE, DOUBLE], VARCHAR),
                     "mmean": ([VARCHAR], DOUBLE)}
        except ImportError:  # pragma: no cover - older duckdb
            types = {}
        for name, (nargs, fn) in ARRAY_UDFS.items():  # pragma: no cover
            params, ret = types.get(name, ([VARCHAR] * nargs, VARCHAR)) \
                if types else (None, None)
            try:
                if params is not None:
                    conn.create_function(name, fn, params, ret)
                else:
                    conn.create_function(name, fn)
            except Exception:
                continue  # register what we can; Listing 7 needs none


_DIALECTS = {"sql92": Sql92Dialect, "sqlite": SqliteDialect,
             "duckdb": DuckDBDialect}


def get_dialect(name) -> Sql92Dialect:
    """Dialect registry: by name, or pass through an instance."""
    if isinstance(name, Sql92Dialect):
        return name
    try:
        return _DIALECTS[name]()
    except KeyError:
        raise ValueError(f"unknown dialect {name!r}; "
                         f"have {sorted(_DIALECTS)}") from None
