"""One benchmark per paper table/figure (DESIGN.md §7 experiment index).

Runtime is measured on CPU (jit-warmed); memory numbers follow the paper's
own accounting model (8 B per value, 8 B per index attribute — §6.1/Table 1)
so they are directly comparable with the published figures.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, nn2sql
from repro.core import expr as E
from repro.core.recursive_cte import history_bytes
from repro.core.relational import (RelTensor, array_bytes,
                                   join_intermediate_bytes, one_hot_dense,
                                   relation_bytes)
from repro.data import make_iris, make_mnist_like, one_hot_labels, replicate

from .common import row, timeit


# ---------------------------------------------------------------------------
# Fig. 5 — memory of a 1000×1000 matmul, relational vs arrays
# ---------------------------------------------------------------------------

def fig5_matmul_memory(n: int = 1000):
    rows = []
    rel_store = 3 * relation_bytes((n, n))          # M, N and the result
    arr_store = 3 * array_bytes((n, n))
    join_blowup = join_intermediate_bytes(n, n, n)
    rng = np.random.RandomState(0)
    a = jnp.asarray(rng.rand(n, n), jnp.float32)
    b = jnp.asarray(rng.rand(n, n), jnp.float32)
    ra, rb = RelTensor.from_dense(a), RelTensor.from_dense(b)
    t_rel = timeit(jax.jit(lambda x, y: x.matmul(y).v), ra, rb)
    t_arr = timeit(jax.jit(jnp.matmul), a, b)
    rows.append(row("fig5/relational_matmul_1k", t_rel,
                    f"store={rel_store / 2**20:.0f}MiB "
                    f"join_intermediate={join_blowup / 2**30:.1f}GiB"))
    rows.append(row("fig5/array_matmul_1k", t_arr,
                    f"store={arr_store / 2**20:.0f}MiB (paper: 24MB bare, "
                    f"3x relational, 1000x join blow-up)"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 6 — Iris training runtime/throughput vs #tuples × hidden size
# ---------------------------------------------------------------------------

def fig6_iris_training(iters: int = 10):
    rows = []
    x0, y0 = make_iris()
    for factor in (1, 10, 100):
        x, y = replicate(x0, y0, factor)
        n = x.shape[0]
        y_oh = one_hot_dense(y, 3).to_dense()
        for hidden in (20, 50):
            spec = nn2sql.MLPSpec(n, 4, hidden, 3)
            g = nn2sql.build_graph(spec)
            w0 = nn2sql.init_weights(spec)
            for kind in ("dense", "relational"):
                t = timeit(
                    lambda: nn2sql.train(g, w0, x, y_oh, iters,
                                         Engine(kind))[0], iters=1)
                rows.append(row(
                    f"fig6/{kind}_n{n}_h{hidden}", t,
                    f"tuples_per_s={n * iters / t:.0f}"))
            t0 = time.perf_counter()
            nn2sql.numpy_train(np.asarray(x), np.asarray(y_oh), hidden,
                               iters)
            t = time.perf_counter() - t0
            rows.append(row(f"fig6/numpy_n{n}_h{hidden}", t,
                            f"tuples_per_s={n * iters / t:.0f}"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 7/8 — training memory per iteration (Iris, batch 150)
# ---------------------------------------------------------------------------

def _graph_bytes(g: nn2sql.MLPGraph, relational: bool) -> int:
    """Paper accounting: every cached CTE (forward + backward) holds
    #entries × (24 B relational | 8 B array)."""
    from repro.core.autodiff import derive
    grads = derive(g.loss, E.const(1.0, g.loss.shape))
    roots = [g.loss] + [grads[v] for v in (g.w_xh, g.w_ho)]
    per_entry = 24 if relational else 8
    total = 0
    for node in E.topo_order(*roots):
        total += node.shape[0] * node.shape[1] * per_entry
    return total


def fig78_training_memory():
    rows = []
    for hidden in (20, 50):
        spec = nn2sql.MLPSpec(150, 4, hidden, 3)
        g = nn2sql.build_graph(spec)
        rel = _graph_bytes(g, relational=True)
        arr = _graph_bytes(g, relational=False)
        rows.append(row(f"fig7/sql92_train_mem_h{hidden}", 0.0,
                        f"MiB_per_iter={rel / 2**20:.2f}"))
        rows.append(row(f"fig8/arrays_train_mem_h{hidden}", 0.0,
                        f"MiB_per_iter={arr / 2**20:.2f} "
                        f"ratio={rel / arr:.1f}x"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 9 — MNIST training epoch time vs batch size
# ---------------------------------------------------------------------------

def fig9_mnist_training(hidden: int = 20):
    rows = []
    x_all, y_all = make_mnist_like(2000)
    for batch in (200, 1000, 2000):
        x = x_all[:batch]
        y_oh = jnp.asarray(one_hot_labels(y_all[:batch], 10))
        spec = nn2sql.MLPSpec(batch, 784, hidden, 10)
        g = nn2sql.build_graph(spec)
        w0 = nn2sql.init_weights(spec)
        steps = max(1, 2000 // batch)               # one "epoch" of 2000
        for kind in ("dense", "relational"):
            t = timeit(lambda: nn2sql.train(g, w0, x, y_oh, steps,
                                            Engine(kind))[0], iters=1)
            rows.append(row(f"fig9/{kind}_batch{batch}_h{hidden}", t,
                            f"tuples_per_s={batch * steps / t:.0f}"))
    return rows


# ---------------------------------------------------------------------------
# Fig. 10 — inference throughput vs hidden size
# ---------------------------------------------------------------------------

def fig10_inference(n: int = 2000):
    rows = []
    x, y = make_mnist_like(n)
    for hidden in (20, 200):
        spec = nn2sql.MLPSpec(n, 784, hidden, 10)
        g = nn2sql.build_graph(spec)
        w = nn2sql.init_weights(spec)
        for kind in ("dense", "relational"):
            run = nn2sql.infer(g, Engine(kind))
            t = timeit(run, w, x)
            rows.append(row(f"fig10/{kind}_h{hidden}", t,
                            f"tuples_per_s={n / t:.0f}"))
        # NumPy reference forward
        wx, wh = np.asarray(w["w_xh"]), np.asarray(w["w_ho"])
        xn = np.asarray(x)
        t0 = time.perf_counter()
        for _ in range(3):
            a = 1 / (1 + np.exp(-xn.dot(wx)))
            1 / (1 + np.exp(-a.dot(wh)))
        t = (time.perf_counter() - t0) / 3
        rows.append(row(f"fig10/numpy_h{hidden}", t,
                        f"tuples_per_s={n / t:.0f}"))
    return rows


# ---------------------------------------------------------------------------
# Figs. 11–13 — MNIST memory (training per batch size, inference)
# ---------------------------------------------------------------------------

def fig1113_mnist_memory():
    rows = []
    for batch in (200, 2000):
        for hidden in (20, 200):
            spec = nn2sql.MLPSpec(batch, 784, hidden, 10)
            g = nn2sql.build_graph(spec)
            rel = _graph_bytes(g, relational=True)
            arr = _graph_bytes(g, relational=False)
            # the join intermediate of the first matmul dominates (Fig. 4)
            join = join_intermediate_bytes(batch, 784, hidden)
            rows.append(row(
                f"fig11/sql92_train_b{batch}_h{hidden}", 0.0,
                f"MiB={rel / 2**20:.1f} join_peak={join / 2**20:.0f}MiB"))
            rows.append(row(
                f"fig12/arrays_train_b{batch}_h{hidden}", 0.0,
                f"MiB={arr / 2**20:.2f}"))
            fwd_nodes = E.topo_order(g.a_ho)
            fwd_rel = sum(n.shape[0] * n.shape[1] * 24 for n in fwd_nodes)
            fwd_arr = sum(n.shape[0] * n.shape[1] * 8 for n in fwd_nodes)
            rows.append(row(
                f"fig13/inference_b{batch}_h{hidden}", 0.0,
                f"sql92_MiB={fwd_rel / 2**20:.2f} "
                f"arrays_MiB={fwd_arr / 2**20:.2f}"))
    return rows


# ---------------------------------------------------------------------------
# Table 1 — matrix sizes for Iris, hidden 20 (exact assertion)
# ---------------------------------------------------------------------------

def table1_sizes():
    spec = nn2sql.MLPSpec(150, 4, 20, 3)
    g = nn2sql.build_graph(spec)
    sizes = {
        "x": g.img.shape[0] * g.img.shape[1],
        "a_xh": g.a_xh.shape[0] * g.a_xh.shape[1],
        "a_ho": g.a_ho.shape[0] * g.a_ho.shape[1],
        "w_xh": g.w_xh.shape[0] * g.w_xh.shape[1],
        "w_ho": g.w_ho.shape[0] * g.w_ho.shape[1],
    }
    expect = {"x": 600, "a_xh": 3000, "a_ho": 450, "w_xh": 80, "w_ho": 60}
    assert sizes == expect, sizes
    # paper: inference total (600+3000+450+450+80+20)·8B = 36.25 KiB —
    # wait, the paper sums 4640 entries; our forward graph entry count:
    total = (sizes["x"] + sizes["a_xh"] + sizes["a_ho"] + 450  # one_hot
             + sizes["w_xh"] + sizes["w_ho"])
    return [row("table1/entries_sum", 0.0,
                f"entries={total} bytes={total * 8} "
                f"(paper: 4640·8B, weights variant)")]


# ---------------------------------------------------------------------------
# §8 — recursive CTE growth: UNION-ALL history vs donated carry
# ---------------------------------------------------------------------------

def cte_growth(iters: int = 50):
    x, y = make_iris()
    spec = nn2sql.MLPSpec(150, 4, 20, 3)
    g = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)
    y_oh = one_hot_dense(y, 3).to_dense()
    _, hist = nn2sql.train(g, w0, x, y_oh, iters, Engine("dense"),
                           materialize_history=True)
    grow = sum(h.nbytes for h in jax.tree.leaves(hist))
    flat = sum(wv.nbytes for wv in w0.values())
    assert grow == history_bytes(w0, iters)
    return [row("cte_growth/union_all_vs_carry", 0.0,
                f"history_KiB={grow / 1024:.0f} carry_KiB={flat / 1024:.0f} "
                f"growth_per_iter_KiB={flat / 1024:.1f}")]
