"""Unified engine interface over the three representations.

``Engine("dense")``      — the array-data-type backend (paper Section 5).
``Engine("relational")`` — the SQL-92 relational backend (paper Section 4).
``Engine("sql")``        — the *in-database* backend: the same DAG rendered
                           as SQL and executed by sqlite/duckdb
                           (:mod:`repro.db.sql_engine`).

All three evaluate the same expression DAG; gradients come from Algorithm 1
(``core.autodiff``), *not* ``jax.grad`` — jax.grad is used only as a test
oracle. ``value_and_grad_fn`` returns a jit-compiled function for the JAX
backends and a plain function for the SQL backend (its "compilation" is the
one-time SQL rendering).
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from . import autodiff, dense, expr as E, rel_engine
from .relational import RelTensor

KINDS = ("dense", "relational", "sql")


class Engine:
    def __init__(self, kind: str, **db_opts):
        """``db_opts`` (``backend=``, ``path=``) reach
        :class:`repro.db.sql_engine.SQLEngine` when ``kind == "sql"``."""
        if kind not in KINDS:
            raise ValueError(f"unknown engine kind {kind!r}; have {KINDS}")
        if db_opts and kind != "sql":
            raise ValueError(f"db options {sorted(db_opts)} only apply to "
                             f"Engine('sql')")
        self.kind = kind
        self._sql = None
        if kind == "sql":
            from ..db.sql_engine import SQLEngine  # lazy: core ↛ db cycle

            self._sql = SQLEngine(**db_opts)

    # -- representation conversion ------------------------------------------
    def lift(self, x: jnp.ndarray):
        return RelTensor.from_dense(x) if self.kind == "relational" else x

    def lower(self, x) -> jnp.ndarray:
        return x.to_dense() if isinstance(x, RelTensor) else x

    # -- evaluation -----------------------------------------------------------
    def evaluate(self, roots: list[E.Expr], env: dict):
        if self.kind == "sql":
            return self._sql.evaluate(roots, env)
        ev = rel_engine.evaluate if self.kind == "relational" else dense.evaluate
        return ev(roots, env)

    def eval_fn(self, roots: list[E.Expr]) -> Callable:
        """jit-compiled evaluator: env dict (dense arrays) → dense outputs.
        For the SQL backend the query is rendered once and executed per
        call (no jit — the database is the executor)."""
        if self.kind == "sql":
            return self._sql.eval_fn(roots)

        @jax.jit
        def fn(env: dict[str, jnp.ndarray]):
            lifted = {k: self.lift(v) for k, v in env.items()}
            return [self.lower(o) for o in self.evaluate(roots, lifted)]

        return fn

    def value_and_grad_fn(self, loss: E.Expr, wrt: list[E.Var]) -> Callable:
        """jit fn: env → (loss value, {var name: gradient}) via Algorithm 1."""
        if self.kind == "sql":
            return self._sql.value_and_grad_fn(loss, wrt)
        grads = autodiff.gradients(loss, wrt)
        roots = [loss] + [grads[v] for v in wrt]

        @jax.jit
        def fn(env: dict[str, jnp.ndarray]):
            lifted = {k: self.lift(v) for k, v in env.items()}
            outs = self.evaluate(roots, lifted)
            loss_val = self.lower(outs[0])
            return loss_val, {v.name: self.lower(g)
                              for v, g in zip(wrt, outs[1:])}

        return fn

    def close(self) -> None:
        if self._sql is not None:
            self._sql.close()


def sgd_step_fn(loss: E.Expr, wrt: list[E.Var], lr: float, engine: Engine
                ) -> Callable:
    """One gradient-descent update — the recursive step of Listing 7/10:
    ``select iter+1, w.v - γ·d_w.v from w_, d_w where …``."""
    vg = engine.value_and_grad_fn(loss, wrt)

    if engine.kind == "sql":
        # every forward/backward evaluation runs in the database; the
        # weight update mirrors Listing 7's final select on the host
        def step(weights, data_env):
            env = {**weights, **data_env}
            loss_val, grads = vg(env)
            new_w = {k: np.asarray(weights[k]) - lr * grads[k]
                     for k in weights}
            return new_w, float(np.mean(loss_val))

        return step

    @jax.jit
    def step(weights: dict[str, jnp.ndarray], data_env: dict[str, jnp.ndarray]):
        env = {**weights, **data_env}
        loss_val, grads = vg(env)
        new_w = {k: weights[k] - lr * grads[k] for k in weights}
        return new_w, jnp.mean(loss_val)

    return step
