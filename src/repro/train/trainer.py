"""Training loop: microbatched grad accumulation, clipping, optimizer,
checkpoint/restart, straggler monitoring.

``make_train_step`` builds the pure step function the dry-run lowers; the
``Trainer`` class wraps it with the operational substrate (fault tolerance,
checkpoint cadence, metrics) for the runnable examples.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.checkpoint.checkpointer import Checkpointer
from repro.optim.optimizers import Optimizer, clip_by_global_norm


def make_train_step(loss_fn: Callable, optimizer: Optimizer,
                    grad_accum: int = 1, clip_norm: float = 1.0):
    """loss_fn(params, batch) → (loss, metrics). Returns
    step(params, opt_state, batch) → (params, opt_state, metrics).

    With ``grad_accum > 1`` the global batch is split along axis 0 into
    microbatches accumulated in a ``lax.scan`` — activation memory drops by
    the accumulation factor while keeping the same global batch (a standard
    memory-roofline lever, see §Perf).
    """
    vg = jax.value_and_grad(loss_fn, has_aux=True)

    def step(params, opt_state, batch):
        if grad_accum == 1:
            (loss, metrics), grads = vg(params, batch)
        else:
            micro = jax.tree.map(
                lambda x: x.reshape((grad_accum, x.shape[0] // grad_accum)
                                    + x.shape[1:]), batch)

            def acc(carry, mb):
                (loss, metrics), grads = vg(params, mb)
                g_acc, l_acc = carry
                g_acc = jax.tree.map(lambda a, g: a + g.astype(a.dtype),
                                     g_acc, grads)
                return (g_acc, l_acc + loss), metrics

            g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32),
                              params)
            (grads, loss), metrics = jax.lax.scan(
                acc, (g0, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / grad_accum, grads)
            loss = loss / grad_accum
            metrics = jax.tree.map(lambda m: m[-1], metrics)
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        params, opt_state = optimizer.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return step


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class StragglerMonitor:
    """Per-step wall-time tracker. On a real fleet the flag feeds the
    scheduler (preempt/replace the slow host); here the policy is the
    tested artifact: flag any step slower than ``threshold ×`` the running
    median over the trailing window."""

    window: int = 50
    threshold: float = 3.0

    def __post_init__(self):
        self.times: list[float] = []
        self.flagged: list[int] = []

    def record(self, step: int, seconds: float) -> bool:
        baseline = sorted(self.times[-self.window:])
        self.times.append(seconds)
        if len(baseline) >= 5:
            median = baseline[len(baseline) // 2]
            if seconds > self.threshold * median:
                self.flagged.append(step)
                return True
        return False


class Trainer:
    """Checkpointed, straggler-aware training driver."""

    def __init__(self, model, optimizer: Optimizer, data,
                 checkpoint_dir: Optional[str] = None,
                 checkpoint_every: int = 50, grad_accum: int = 1,
                 clip_norm: float = 1.0, donate: bool = True):
        self.model = model
        self.optimizer = optimizer
        self.data = data
        self.step_fn = jax.jit(
            make_train_step(model.loss_fn, optimizer, grad_accum, clip_norm),
            donate_argnums=(0, 1) if donate else ())
        self.ckpt = (Checkpointer(checkpoint_dir)
                     if checkpoint_dir else None)
        self.checkpoint_every = checkpoint_every
        self.monitor = StragglerMonitor()
        self.history: list[dict] = []

    def init_state(self, key):
        params = self.model.init(key)
        return params, self.optimizer.init(params)

    def restore_or_init(self, key):
        """Crash-restart entry point: resume from the latest checkpoint if
        one exists, else initialise fresh. The data pipeline is a pure
        function of the step, so the token stream resumes exactly."""
        params, opt_state = self.init_state(key)
        start = 0
        if self.ckpt and self.ckpt.latest_step() is not None:
            (params, opt_state), start = self.ckpt.restore(
                (params, opt_state))
        return params, opt_state, start

    def run(self, key, n_steps: int, log_every: int = 10,
            log_fn=print) -> dict:
        params, opt_state, start = self.restore_or_init(key)
        for step in range(start, n_steps):
            batch = self.data.batch_at(step)
            t0 = time.perf_counter()
            params, opt_state, metrics = self.step_fn(params, opt_state,
                                                      batch)
            metrics = jax.tree.map(float, metrics)
            dt = time.perf_counter() - t0
            straggle = self.monitor.record(step, dt)
            rec = dict(metrics, step=step, seconds=dt, straggler=straggle)
            self.history.append(rec)
            if log_every and step % log_every == 0:
                log_fn(f"step {step:5d} loss {metrics['loss']:.4f} "
                       f"({dt * 1e3:.0f} ms){' STRAGGLER' if straggle else ''}")
            if self.ckpt and (step + 1) % self.checkpoint_every == 0:
                self.ckpt.save(step + 1, (params, opt_state))
        if self.ckpt:
            self.ckpt.save(n_steps, (params, opt_state), blocking=True)
        return {"params": params, "opt_state": opt_state,
                "history": self.history}
