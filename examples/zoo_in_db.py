"""Run a real MoE layer and the RWKV recurrences entirely inside sqlite.

The §8 outlook made concrete: the same expression DAGs the JAX engines
execute are rendered to one WITH query each (window-function top-k,
GROUP-BY reductions, index-relation joins, a recursive-CTE scan) and
executed by the database — then checked against the jax/numpy references.

    PYTHONPATH=src python examples/zoo_in_db.py [--backend duckdb]
"""
import argparse

import numpy as np
import jax.numpy as jnp

from repro.core import sqlgen
from repro.db import zoo
from repro.db.sql_engine import SQLEngine
from repro.kernels import ref


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--backend", default="sqlite",
                    choices=["sqlite", "duckdb"])
    ap.add_argument("--show-sql", action="store_true",
                    help="print the rendered MoE routing query")
    args = ap.parse_args()
    rng = np.random.RandomState(0)

    # -- MoE: route → per-expert SwiGLU → gated combine, all in-DB --------
    cfg = zoo.MoESQLConfig(n_tokens=16, d_model=8, n_experts=4, top_k=2,
                           d_ff=16)
    params = zoo.init_moe_params(cfg)
    x = rng.randn(cfg.n_tokens, cfg.d_model).astype(np.float32)
    out_db = zoo.run_moe_in_db(cfg, params, x, backend=args.backend)
    out_ref = zoo.moe_ffn_ref(cfg, params, x)
    print(f"MoE({cfg.n_tokens} tok, {cfg.n_experts} experts, "
          f"top-{cfg.top_k}) in {args.backend}: "
          f"max|Δ| vs jax = {np.abs(out_db - out_ref).max():.2e}")

    if args.show_sql:
        graph = zoo.moe_ffn_graph(cfg)
        print(sqlgen.to_sql92([graph.gates], dialect=args.backend))

    # -- RWKV-6 time mix: the N²-state scan as ONE recursive CTE ----------
    s, n = 12, 4
    r, k, v = [rng.randn(s, n).astype(np.float32) * 0.5 for _ in range(3)]
    w = (rng.rand(s, n) * 0.5 + 0.3).astype(np.float32)
    u = (rng.randn(n) * 0.5).astype(np.float32)
    s0 = (rng.randn(n, n) * 0.3).astype(np.float32)
    o_db, sfin_db = zoo.run_rwkv6_in_db(r, k, v, w, u, s0,
                                        backend=args.backend)
    o_ref, sfin_ref = ref.rwkv6_scan(
        jnp.asarray(r[None]), jnp.asarray(k[None]), jnp.asarray(v[None]),
        jnp.asarray(w[None]), jnp.asarray(u[None]), jnp.asarray(s0[None]))
    print(f"RWKV-6 time mix (S={s}, N={n}) in {args.backend}: "
          f"max|Δo| = {np.abs(np.asarray(o_ref[0]) - o_db).max():.2e}, "
          f"max|ΔS| = {np.abs(np.asarray(sfin_ref[0]) - sfin_db).max():.2e}")

    # -- RWKV channel mix: token shift + relu² FFN ------------------------
    d, f = 6, 12
    xc = rng.randn(s, d).astype(np.float32)
    mu_k, mu_r = rng.rand(d), rng.rand(d)
    wk, wv, wr = (rng.randn(d, f) * .3, rng.randn(f, d) * .3,
                  rng.randn(d, d) * .3)
    cm_db = zoo.run_channel_mix_in_db(xc, mu_k, mu_r, wk, wv, wr,
                                      backend=args.backend)
    cm_ref = zoo.rwkv_channel_mix_ref(xc, mu_k, mu_r, wk, wv, wr)
    print(f"RWKV channel mix in {args.backend}: "
          f"max|Δ| = {np.abs(cm_db - cm_ref).max():.2e}")

    # -- gradients: Algorithm 1 over the zoo nodes, executed in-DB --------
    graph = zoo.moe_ffn_graph(cfg)
    eng = SQLEngine(backend=args.backend)
    vg = eng.value_and_grad_fn(graph.out, list(graph.weight_vars))
    loss, grads = vg(zoo.moe_env(cfg, params, x))
    eng.close()
    print(f"in-DB MoE gradients: {len(grads)} weight tables, "
          f"|∂router| max = {np.abs(grads['w_router']).max():.3f}")


if __name__ == "__main__":
    main()
