"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs ref.py oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.RandomState(42)


def rnd(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.randn(*shape), jnp.float32).astype(dtype)


def tol(dtype):
    return dict(rtol=2e-4, atol=2e-5) if dtype == jnp.float32 \
        else dict(rtol=6e-2, atol=3e-2)


@pytest.mark.parametrize("m,k,n,blk_t,blk_n",
                         [(8, 16, 128, 32, 64), (16, 32, 256, 128, 128),
                          (64, 64, 128, 256, 128), (12, 16, 384, 64, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32])
def test_relational_matmul_dense_coo(m, k, n, blk_t, blk_n, dtype):
    a = rnd((m, k), dtype)
    b = rnd((k, n), dtype)
    rows = jnp.repeat(jnp.arange(m, dtype=jnp.int32), k)
    cols = jnp.tile(jnp.arange(k, dtype=jnp.int32), m)
    vals = a.reshape(-1)
    out = ops.relational_matmul(rows, cols, vals, b, m, use_pallas=True,
                                blk_t=blk_t, blk_n=blk_n)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.relational_matmul(
                                   rows, cols, vals, b, m), np.float32),
                               **tol(dtype))


@pytest.mark.parametrize("nnz,pad", [(32, 0), (48, 16), (8, 56)])
def test_relational_matmul_sparse_padding(nnz, pad):
    m, k, n = 16, 32, 128
    b = rnd((k, n))
    rows = jnp.sort(jnp.asarray(RNG.randint(0, m, nnz), jnp.int32))
    rows = jnp.concatenate([rows, jnp.full((pad,), m, jnp.int32)])
    cols = jnp.asarray(RNG.randint(0, k, nnz + pad), jnp.int32)
    vals = rnd((nnz + pad,))
    out = ops.relational_matmul(rows, cols, vals, b, m, use_pallas=True,
                                blk_t=min(64, nnz + pad), blk_n=64)
    np.testing.assert_allclose(out, ref.relational_matmul(rows, cols, vals,
                                                          b, m),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("m,k,n", [(128, 128, 128), (256, 384, 256),
                                   (128, 512, 384)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_fused_sigmoid_matmul(m, k, n, dtype):
    x, w = rnd((m, k), dtype), rnd((k, n), dtype)
    out = ops.fused_sigmoid_matmul(x, w, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref.fused_sigmoid_matmul(x, w),
                                          np.float32), **tol(dtype))


@pytest.mark.parametrize("t,v,d", [(16, 100, 64), (64, 1000, 128),
                                   (128, 333, 256)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_onehot_embed(t, v, d, dtype):
    ids = jnp.asarray(RNG.randint(0, v, t), jnp.int32)
    table = rnd((v, d), dtype)
    out = ops.onehot_embed(ids, table, use_pallas=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(table[ids], np.float32))


@pytest.mark.parametrize("t,slots,d", [(32, 64, 64), (64, 96, 128)])
def test_moe_dispatch(t, slots, d):
    x = rnd((t, d))
    idx = jnp.asarray(RNG.randint(0, t, slots), jnp.int32)
    gates = jnp.asarray(RNG.rand(slots), jnp.float32)
    out = ops.moe_dispatch(x, idx, gates, use_pallas=True)
    np.testing.assert_allclose(out, ref.moe_dispatch(x, idx, gates),
                               rtol=1e-6)


@pytest.mark.parametrize("b,hq,hkv,s,d,blk",
                         [(1, 4, 4, 128, 64, 64), (2, 8, 2, 256, 64, 128),
                          (1, 8, 1, 256, 128, 128)])
@pytest.mark.parametrize("causal", [True, False])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(b, hq, hkv, s, d, blk, causal, dtype):
    q = rnd((b, hq, s, d), dtype)
    k = rnd((b, hkv, s, d), dtype)
    v = rnd((b, hkv, s, d), dtype)
    out = ops.flash_attention(q, k, v, causal=causal, use_pallas=True,
                              blk_q=blk, blk_k=blk)
    expect = ref.flash_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_flash_attention_matches_jnp_flash():
    """Pallas kernel ≡ the jnp online-softmax twin used by the models."""
    from repro.nn.layers import attend_flash
    q, k, v = rnd((2, 4, 256, 64)), rnd((2, 2, 256, 64)), rnd((2, 2, 256, 64))
    a = ops.flash_attention(q, k, v, causal=True, use_pallas=True)
    b = attend_flash(q, k, v, chunk=128)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=2e-4, atol=2e-5)


@pytest.mark.parametrize("bh,s,n,blk", [(2, 32, 16, 16), (4, 64, 32, 32),
                                        (1, 128, 64, 64)])
def test_rwkv6_scan(bh, s, n, blk):
    r = rnd((bh, s, n))
    k = rnd((bh, s, n))
    v = rnd((bh, s, n))
    w = jnp.asarray(RNG.rand(bh, s, n) * 0.5 + 0.4, jnp.float32)
    u = rnd((bh, n))
    s0 = rnd((bh, n, n)) * 0.1
    o, sf = ops.rwkv6_scan(r, k, v, w, u, s0, use_pallas=True, blk_t=blk)
    o_ref, s_ref = ref.rwkv6_scan(r, k, v, w, u, s0)
    np.testing.assert_allclose(np.asarray(o), np.asarray(o_ref),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(sf), np.asarray(s_ref),
                               rtol=3e-4, atol=3e-4)


def test_flash_bf16_scores_close_to_f32():
    """§Perf lever: bf16 score/prob blocks must stay within bf16 noise."""
    from repro.nn.layers import attend_flash
    q = rnd((1, 4, 256, 64), jnp.bfloat16)
    k = rnd((1, 2, 256, 64), jnp.bfloat16)
    v = rnd((1, 2, 256, 64), jnp.bfloat16)
    a = attend_flash(q, k, v, chunk=64)
    b = attend_flash(q, k, v, chunk=64, bf16_scores=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32),
                               rtol=6e-2, atol=3e-2)
