with scat_c0(m) as (
  select mscatter((select m from zx), (select m from zidx), 5) as m
)
select 0 as r, m from scat_c0;
