"""Zamba2-2.7B — Mamba-2 backbone + shared attention block every 6 layers
[arXiv:2411.15242]. Sub-quadratic backbone: runs long_500k (the shared
attention's KV cache is sequence-sharded at 500k). Per-application LoRA on
the shared block is omitted (DESIGN.md §8)."""
from .base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid", n_layers=54, d_model=2560,
    n_heads=32, n_kv_heads=32, d_head=80, d_ff=10240, vocab=32000,
    ssm=SSMSpec(d_state=64, head_dim=64, d_conv=4, expand=2),
    shared_attn_every=6, sub_quadratic=True, rope_theta=1e4)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="zamba2-reduced", family="hybrid", n_layers=4, d_model=64,
        n_heads=4, n_kv_heads=4, d_head=16, d_ff=128, vocab=256,
        ssm=SSMSpec(d_state=16, head_dim=16, d_conv=4, expand=2, chunk=16),
        shared_attn_every=2, sub_quadratic=True)
