"""Pallas TPU kernel: RWKV-6 time-mix recurrence with VMEM-resident state.

The jnp ``lax.scan`` implementation reads and writes the (H, N, N) matrix
state from HBM every token — 2·S·H·N²·4 B of traffic that dominates the
rwkv6 memory roofline term (EXPERIMENTS.md §Perf). On TPU the state is
small (N² f32 = 16 KiB per head): this kernel pins it in VMEM scratch
across a *sequential* time-block grid, so HBM traffic drops to the
r/k/v/w input stream + the output — the same accumulator pattern as
``relational_matmul``'s group-by.

    o_t = r_t · (S + diag(u) k_t v_tᵀ);   S ← diag(w_t) S + k_t v_tᵀ

grid = (B·H, S/blk_t); the t dimension is sequential (scratch carries S).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(r_ref, k_ref, v_ref, w_ref, u_ref, s0_ref, o_ref, sf_ref,
            s_scr, *, blk_t: int, n_t_blocks: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        s_scr[...] = s0_ref[0]

    u_col = u_ref[...].T                             # (N, 1): scales k-dim

    def step(i, S):
        r_i = r_ref[0, i][None, :]                   # (1, N)
        k_i = k_ref[0, i][None, :]
        v_i = v_ref[0, i][None, :]
        w_i = w_ref[0, i][None, :]
        kv = k_i.T @ v_i                             # (N, N) outer product
        o_i = r_i @ (S + u_col * kv)                 # (1, N)
        o_ref[0, i] = o_i[0]
        return w_i.T * S + kv

    s_fin = jax.lax.fori_loop(0, blk_t, step, s_scr[...])
    s_scr[...] = s_fin

    @pl.when(t == n_t_blocks - 1)
    def _flush():
        sf_ref[0] = s_fin


@functools.partial(jax.jit, static_argnames=("blk_t", "interpret"))
def rwkv6_scan(r, k, v, w, u, s0, *, blk_t: int = 128,
               interpret: bool = True):
    """r/k/v/w: (BH, S, N) f32; u: (BH, N); s0: (BH, N, N).
    Returns (o (BH, S, N), s_fin (BH, N, N))."""
    bh, s, n = r.shape
    blk_t = min(blk_t, s)
    if s % blk_t:
        raise ValueError(f"seq {s} % blk_t {blk_t}")
    n_t = s // blk_t
    grid = (bh, n_t)
    return pl.pallas_call(
        functools.partial(_kernel, blk_t=blk_t, n_t_blocks=n_t),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, blk_t, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, blk_t, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, blk_t, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, blk_t, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, n), lambda b, t: (b, 0)),
            pl.BlockSpec((1, n, n), lambda b, t: (b, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, blk_t, n), lambda b, t: (b, t, 0)),
            pl.BlockSpec((1, n, n), lambda b, t: (b, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, n), jnp.float32),
            jax.ShapeDtypeStruct((bh, n, n), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, w, u, s0)
