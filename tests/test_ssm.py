"""SSM layers: chunked forms vs naive recurrences; decode consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.nn.ssm import (mamba2_init, mamba2_mixer, rwkv6_channel_mix,
                          rwkv6_channel_mix_init, rwkv6_init,
                          rwkv6_time_mix, ssd_chunked, ssd_naive)

RNG = np.random.RandomState(1)


def rnd(*s):
    return jnp.asarray(RNG.randn(*s), jnp.float32)


class TestSSD:
    @pytest.mark.parametrize("b,s,h,p,n,chunk",
                             [(2, 64, 4, 8, 16, 16), (1, 128, 2, 16, 8, 32),
                              (2, 96, 3, 8, 8, 32)])
    def test_chunked_matches_naive(self, b, s, h, p, n, chunk):
        x = rnd(b, s, h, p)
        a = -jnp.abs(rnd(b, s, h)) * 0.1
        bi, ci = rnd(b, s, n), rnd(b, s, n)
        y1, h1 = ssd_chunked(x, a, bi, ci, chunk=chunk)
        y2, h2 = ssd_naive(x, a, bi, ci)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(h1), np.asarray(h2),
                                   rtol=2e-4, atol=2e-4)

    def test_initial_state_carried(self):
        b, s, h, p, n = 1, 32, 2, 8, 8
        x, a = rnd(b, s, h, p), -jnp.abs(rnd(b, s, h)) * 0.1
        bi, ci = rnd(b, s, n), rnd(b, s, n)
        h0 = rnd(b, h, n, p)
        y1, _ = ssd_chunked(x, a, bi, ci, chunk=16, h0=h0)
        y2, _ = ssd_naive(x, a, bi, ci, h0=h0)
        np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                                   rtol=2e-4, atol=2e-4)

    def test_decode_steps_match_full_sequence(self):
        """Running t single-token steps == one full-sequence pass."""
        b, s, h, p, n = 1, 16, 2, 8, 8
        x, a = rnd(b, s, h, p), -jnp.abs(rnd(b, s, h)) * 0.1
        bi, ci = rnd(b, s, n), rnd(b, s, n)
        y_full, _ = ssd_naive(x, a, bi, ci)
        hst, ys = None, []
        for t in range(s):
            y, hst = ssd_chunked(x[:, t:t + 1], a[:, t:t + 1],
                                 bi[:, t:t + 1], ci[:, t:t + 1], h0=hst)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=2e-4, atol=2e-4)


class TestMamba2Mixer:
    def test_prefill_then_decode_consistency(self):
        d, heads, dstate = 32, 4, 8
        dims = (2 * d, (2 * d) // heads, dstate, 4)
        p = mamba2_init(jax.random.PRNGKey(0), d, heads, dstate)
        x = rnd(1, 24, d)
        # full pass
        y_full, st_full = mamba2_mixer(p, x, dims, chunk=8)
        # step-by-step
        st, ys = None, []
        for t in range(24):
            y, st = mamba2_mixer(p, x[:, t:t + 1], dims, state=st)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st[1]), np.asarray(st_full[1]),
                                   rtol=2e-3, atol=2e-3)


class TestRWKV6:
    def test_prefill_then_decode_consistency(self):
        d, heads = 32, 4
        p = rwkv6_init(jax.random.PRNGKey(0), d, heads, lora_rank=8)
        x = rnd(2, 12, d)
        y_full, st_full = rwkv6_time_mix(p, x, heads)
        st, ys = None, []
        for t in range(12):
            y, st = rwkv6_time_mix(p, x[:, t:t + 1], heads, state=st)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=2e-3, atol=2e-3)
        np.testing.assert_allclose(np.asarray(st[1]), np.asarray(st_full[1]),
                                   rtol=2e-3, atol=2e-3)

    def test_channel_mix_shift_consistency(self):
        d = 16
        p = rwkv6_channel_mix_init(jax.random.PRNGKey(1), d, 32)
        x = rnd(1, 8, d)
        y_full, _ = rwkv6_channel_mix(p, x)
        st, ys = None, []
        for t in range(8):
            y, st = rwkv6_channel_mix(p, x[:, t:t + 1], state=st)
            ys.append(y)
        np.testing.assert_allclose(np.asarray(jnp.concatenate(ys, 1)),
                                   np.asarray(y_full), rtol=1e-4, atol=1e-5)

    def test_decay_in_unit_interval(self):
        d, heads = 32, 4
        p = rwkv6_init(jax.random.PRNGKey(0), d, heads, lora_rank=8)
        from repro.nn.ssm import _rwkv6_projections
        x = rnd(1, 6, d)
        xp = jnp.zeros((1, 1, d))
        *_, w = _rwkv6_projections(p, x, xp, heads)
        assert float(w.min()) > 0.0 and float(w.max()) < 1.0
