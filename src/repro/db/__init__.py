"""In-database execution backend (the paper's actual thesis, closed-loop).

``repro.db`` runs the generated SQL in a real engine instead of printing it:

* :mod:`~repro.db.dialect` — SQL dialects (sql92 golden, sqlite, duckdb)
  plus the UDF array extension (the §5 analogue for stock engines);
* :mod:`~repro.db.adapters` — one ``Adapter`` contract over ``sqlite3`` /
  ``duckdb`` / ``psycopg2`` (``adapter`` is the back-compat shim);
* :mod:`~repro.db.shard` — data-parallel training across a connection
  pool with a SQL AllReduce (``train_in_db(shards=N)``);
* :mod:`~repro.db.relation_io` — dense arrays ↔ ``{[i, j, v]}`` tables
  (vectorized pivots);
* :mod:`~repro.db.plan_cache` — persistent cache of rendered SQL plans;
* :mod:`~repro.db.sql_engine` — ``SQLEngine``, the ``Engine("sql")`` backend;
* :mod:`~repro.db.train` — Listing 7/10 training + Listing 8 inference
  executed inside the database.

* :mod:`~repro.db.zoo` — the DAG zoo in SQL: MoE dispatch/combine and the
  RWKV recurrences transpiled to executable queries (§8 outlook).

Submodules that depend on :mod:`repro.core` are loaded lazily so that
``core`` ↔ ``db`` imports cannot cycle.
"""
from . import adapter, adapters, dialect, relation_io
from .adapter import (Adapter, ConnectionPool, DuckDBAdapter,
                      PostgresAdapter, SQLiteAdapter, connect)
from .dialect import (ARRAY_UDFS, HAVE_DUCKDB, ArrayDialect, DuckDBDialect,
                      PostgresDialect, Sql92Dialect, SqliteDialect,
                      get_dialect, json_to_matrix, matrix_to_json)

__all__ = [
    "adapter", "adapters", "dialect", "relation_io", "plan_cache",
    "sql_engine", "train", "shard", "zoo",
    "Adapter", "SQLiteAdapter", "DuckDBAdapter", "PostgresAdapter",
    "ConnectionPool", "connect",
    "Sql92Dialect", "SqliteDialect", "DuckDBDialect", "PostgresDialect",
    "ArrayDialect", "get_dialect",
    "ARRAY_UDFS", "HAVE_DUCKDB", "matrix_to_json", "json_to_matrix",
    "SQLEngine", "PlanCache", "train_in_db", "infer_in_db", "predict_in_db",
    "train_in_db_sharded",
]

_LAZY = {
    "plan_cache": ("repro.db.plan_cache", None),
    "sql_engine": ("repro.db.sql_engine", None),
    "train": ("repro.db.train", None),
    "shard": ("repro.db.shard", None),
    "zoo": ("repro.db.zoo", None),
    "SQLEngine": ("repro.db.sql_engine", "SQLEngine"),
    "PlanCache": ("repro.db.plan_cache", "PlanCache"),
    "train_in_db": ("repro.db.train", "train_in_db"),
    "infer_in_db": ("repro.db.train", "infer_in_db"),
    "predict_in_db": ("repro.db.train", "predict_in_db"),
    "train_in_db_sharded": ("repro.db.shard", "train_in_db_sharded"),
}


def __getattr__(name):
    try:
        mod_name, attr = _LAZY[name]
    except KeyError:
        raise AttributeError(name) from None
    import importlib

    mod = importlib.import_module(mod_name)
    return getattr(mod, attr) if attr else mod
