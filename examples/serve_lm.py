"""Batched serving with continuous batching (deliverable b).

Model inference inside the system "avoids data extraction" (paper §6.3.2);
this driver serves a small LM with a continuously-batched decode loop:
requests of different lengths share fixed decode slots, finished sequences
immediately release their slot to the queue.

    PYTHONPATH=src python examples/serve_lm.py --requests 8 --slots 4
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.nn.model import LM
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi_6b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=True)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, max_len=args.max_len,
                        batch_slots=args.slots,
                        temperature=args.temperature)
    rng = np.random.RandomState(0)
    for uid in range(args.requests):
        plen = int(rng.randint(2, 10))
        eng.submit(Request(uid, rng.randint(0, cfg.vocab, plen)
                           .astype(np.int32),
                           max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done)
    print(f"served {len(done)} requests / {total} tokens in {dt:.2f}s "
          f"({total / dt:.1f} tok/s, {args.slots} slots)")
    for r in sorted(done, key=lambda r: r.uid)[:4]:
        print(f"  req {r.uid}: prompt {list(r.prompt)} → {r.generated}")


if __name__ == "__main__":
    main()
