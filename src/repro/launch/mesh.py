"""Production mesh construction.

A function (not a module-level constant) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before first init.
"""
from __future__ import annotations

import inspect

import jax

try:  # jax ≥ 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x has neither AxisType nor the kwarg
    AxisType = None

_MAKE_MESH_HAS_AXIS_TYPES = (
    AxisType is not None
    and "axis_types" in inspect.signature(jax.make_mesh).parameters)


def abstract_mesh(shape, axis_names):
    """``jax.sharding.AbstractMesh`` across jax versions: ≥0.5 takes
    ``(shape, axis_names)``; 0.4.x takes a tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axis_names)
    except TypeError:  # 0.4.x shape_tuple signature
        return AbstractMesh(tuple(zip(axis_names, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model) — 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)  # Auto is the 0.4.x default


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes: ('pod', 'data') multi-pod, ('data',) single."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size
