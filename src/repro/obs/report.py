"""Render an observability capture as a terminal report.

    python -m repro.obs.report trace.json          # Chrome-trace export
    python -m repro.obs.report run.db              # traced database
    python -m repro.obs.report run.db --top 15

Accepts either artifact the exporters produce — a Chrome-trace JSON
(:func:`repro.obs.export.write_chrome_trace`) or a database file whose
engine ran under tracing and received the ``trace_spans`` /
``profile_nodes`` / ``metric_points`` relations — and prints the same
three sections from both:

* **stage breakdown** — per-span-name totals, dominant first, with the
  share of top-level wall time attributed,
* **hottest IR nodes** — the top-N rows of the per-node profiler cost
  table (when a profiled run was captured),
* **metric percentiles** — histogram snapshots (from the trace export) or
  exact p50/p90/p95/p99 recomputed from the ``metric_points`` rows.

The detection is by content, not extension: a file starting with the
SQLite magic (or openable by duckdb) is treated as a database, JSON as a
trace export.
"""
from __future__ import annotations

import argparse
import json
import sys

from . import metrics as _metrics

_SQLITE_MAGIC = b"SQLite format 3\x00"


# ---------------------------------------------------------------------------
# capture loading: either artifact → one normalised dict
# ---------------------------------------------------------------------------

def _rows(conn, sql: str) -> list:
    try:
        cur = conn.execute(sql)
        return cur.fetchall()
    except Exception:
        return []                 # relation absent in this capture


def _load_db(path: str) -> dict:
    """Read the exported relations from a traced sqlite/duckdb file."""
    with open(path, "rb") as f:
        magic = f.read(16)
    if magic.startswith(_SQLITE_MAGIC):
        import sqlite3
        conn = sqlite3.connect(path)
    else:
        try:
            import duckdb
        except ImportError:
            raise SystemExit(f"{path}: not JSON, not sqlite, and the "
                             f"duckdb module is unavailable")
        conn = duckdb.connect(path)
    try:
        spans = [{"name": n, "parent_id": p, "dur_us": d}
                 for _sid, p, n, _path, _t0, d, _tid, _attrs in
                 _rows(conn, "select span_id, parent_id, name, path, t0_us,"
                             " dur_us, thread, attrs from trace_spans")]
        nodes = [{"node": r[0], "kind": r[1], "shape": r[2],
                  "self_us": r[3], "rows": r[4], "bytes": r[5], "pct": r[6]}
                 for r in _rows(conn, "select node, kind, shape, self_us,"
                                      " rows, bytes, pct from profile_nodes"
                                      " order by self_us desc")]
        points: dict[str, list[float]] = {}
        for metric_, value in _rows(
                conn, "select metric, value from metric_points"):
            points.setdefault(metric_, []).append(float(value))
        hists = {name: dict(_metrics.percentiles_from_values(vals),
                            count=len(vals),
                            mean=sum(vals) / len(vals),
                            min=min(vals), max=max(vals))
                 for name, vals in points.items()}
    finally:
        conn.close()
    return {"kind": "database", "spans": spans, "nodes": nodes,
            "histograms": hists}


def _load_trace(path: str, payload: dict) -> dict:
    """Normalise a Chrome-trace export (``write_chrome_trace`` output)."""
    events = payload.get("traceEvents", [])
    # interval containment per tid rebuilds the parent relation the flat
    # event list dropped: an event is a root iff no other event encloses it
    spans = []
    by_tid: dict = {}
    for e in events:
        if e.get("ph") != "X":
            continue
        by_tid.setdefault(e.get("tid", 0), []).append(e)
    for tid_events in by_tid.values():
        for e in tid_events:
            t0, t1 = e["ts"], e["ts"] + e.get("dur", 0.0)
            enclosed = any(
                o is not e and o["ts"] <= t0
                and o["ts"] + o.get("dur", 0.0) >= t1
                and (o["ts"], -(o["ts"] + o.get("dur", 0.0)))
                != (t0, -t1)
                for o in tid_events)
            spans.append({"name": e["name"],
                          "parent_id": 1 if enclosed else None,
                          "dur_us": e.get("dur", 0.0)})
    nodes = [{"node": e.get("args", {}).get("node", "?"),
              "kind": e.get("args", {}).get("kind", "?"),
              "shape": "", "self_us": e.get("dur", 0.0),
              "rows": e.get("args", {}).get("rows"),
              "bytes": None, "pct": None}
             for e in events if e.get("name") == "profile.node"]
    nodes.sort(key=lambda n: -(n["self_us"] or 0.0))
    other = payload.get("otherData", {})
    hists = dict(other.get("histograms", {}))
    points: dict[str, list[float]] = {}
    for p in other.get("metricPoints", []):
        points.setdefault(p["metric"], []).append(float(p["value"]))
    for name, vals in points.items():
        hists.setdefault(name, dict(
            _metrics.percentiles_from_values(vals), count=len(vals),
            mean=sum(vals) / len(vals), min=min(vals), max=max(vals)))
    return {"kind": "chrome-trace", "spans": spans, "nodes": nodes,
            "histograms": hists}


def load_capture(path: str) -> dict:
    """Path → ``{kind, spans, nodes, histograms}`` regardless of artifact
    flavour (trace JSON vs traced database file)."""
    with open(path, "rb") as f:
        head = f.read(16)
    if head.startswith(_SQLITE_MAGIC) or not head.lstrip()[:1] in (b"{",
                                                                   b"["):
        return _load_db(path)
    with open(path) as f:
        return _load_trace(path, json.load(f))


# ---------------------------------------------------------------------------
# rendering
# ---------------------------------------------------------------------------

def _fmt_stage_table(spans: list, top: int) -> list[str]:
    agg: dict[str, dict] = {}
    root_us = 0.0
    child_us = 0.0
    for s in spans:
        if s["parent_id"] is None:
            root_us += s["dur_us"] or 0.0
        else:
            child_us += s["dur_us"] or 0.0
        d = agg.setdefault(s["name"], {"count": 0, "total_us": 0.0})
        d["count"] += 1
        d["total_us"] += s["dur_us"] or 0.0
    ordered = sorted(agg.items(), key=lambda kv: -kv[1]["total_us"])[:top]
    if not ordered:
        return ["  (no spans captured)"]
    width = max(len(k) for k, _ in ordered)
    lines = [f"  {'span':<{width}} {'count':>6} {'total_ms':>10}"]
    for name, d in ordered:
        lines.append(f"  {name:<{width}} {d['count']:>6} "
                     f"{d['total_us'] / 1e3:>10.2f}")
    if root_us:
        lines.append(f"  top-level wall {root_us / 1e3:.2f} ms, "
                     f"{min(child_us / root_us, 1.0):.1%} in child spans")
    return lines


def _fmt_node_table(nodes: list, top: int) -> list[str]:
    nodes = nodes[:top]
    if not nodes:
        return ["  (no profiled run in this capture — see "
                "SQLEngine.profile / repro.obs.profiler)"]
    width = max(max(len(str(n["node"])) for n in nodes), 4)
    kwidth = max(max(len(str(n["kind"])) for n in nodes), 4)
    lines = [f"  {'node':<{width}} {'kind':<{kwidth}} {'self_ms':>9} "
             f"{'rows':>7} {'pct':>6}"]
    for n in nodes:
        pct = "" if n["pct"] is None else f"{n['pct']:.1f}%"
        rows = "" if n["rows"] is None else str(n["rows"])
        lines.append(f"  {n['node']:<{width}} {n['kind']:<{kwidth}} "
                     f"{(n['self_us'] or 0.0) / 1e3:>9.2f} {rows:>7} "
                     f"{pct:>6}")
    return lines


def _fmt_hist_table(hists: dict) -> list[str]:
    if not hists:
        return ["  (no histogram/metric-point data in this capture)"]
    width = max(max(len(k) for k in hists), 6)
    lines = [f"  {'metric':<{width}} {'count':>6} {'mean':>10} "
             f"{'p50':>10} {'p95':>10} {'p99':>10} {'max':>10}"]
    for name in sorted(hists):
        h = hists[name]
        if not h.get("count"):
            continue

        def g(key):
            v = h.get(key)
            return "-" if v is None else f"{v:.4g}"

        lines.append(f"  {name:<{width}} {h['count']:>6} {g('mean'):>10} "
                     f"{g('p50'):>10} {g('p95'):>10} {g('p99'):>10} "
                     f"{g('max'):>10}")
    return lines


def render(capture: dict, top: int = 10) -> str:
    """The three-section text report of one capture."""
    lines = [f"== observability report ({capture['kind']}) =="]
    lines.append("\n-- stage breakdown (per span name) --")
    lines += _fmt_stage_table(capture["spans"], top)
    lines.append(f"\n-- hottest IR nodes (top {top}) --")
    lines += _fmt_node_table(capture["nodes"], top)
    lines.append("\n-- metric percentiles --")
    lines += _fmt_hist_table(capture["histograms"])
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.obs.report",
        description="Print stage breakdown, hottest IR nodes and metric "
                    "percentiles from a Chrome-trace JSON or a traced "
                    "database file.")
    ap.add_argument("path", help="trace.json or sqlite/duckdb database")
    ap.add_argument("--top", type=int, default=10,
                    help="rows per section (default 10)")
    args = ap.parse_args(argv)
    print(render(load_capture(args.path), top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
