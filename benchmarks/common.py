"""Shared benchmark utilities."""
from __future__ import annotations

import time

import jax


def timeit(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Median wall seconds per call (jit-warmed, synchronised)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def row(name: str, seconds: float, derived: str = "") -> dict:
    return {"name": name, "us_per_call": seconds * 1e6, "derived": derived}
