"""Render the EXPERIMENTS.md roofline table from dry-run JSON results.

    PYTHONPATH=src python -m benchmarks.roofline_table \
        results/dryrun_baseline.json
"""
from __future__ import annotations

import json
import sys


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x * 1e6:.3g}µs"
    if x < 1:
        return f"{x * 1e3:.3g}ms"
    return f"{x:.3g}s"


def fix_note(rec) -> str:
    """What would move the dominant term down — wording reflects the §Perf
    evidence (confirmed levers only; refuted hypotheses excluded)."""
    b = rec["bottleneck"]
    arch, shape = rec["arch"], rec["shape"]
    moe = arch in ("dbrx_132b", "deepseek_v2_lite_16b")
    if moe:
        return "shard_map relational MoE plan (confirmed 3.3-3.8x, §Perf)"
    if "rwkv" in arch or "zamba" in arch:
        return "fused VMEM-resident state kernel (rwkv6_scan pattern)"
    if b == "memory":
        if shape == "prefill_32k":
            return "Pallas flash kernel: score blocks never reach HBM"
        if shape.startswith("decode") or shape == "long_500k":
            return "bf16/quantized weight+cache reads; fused decode kernel"
        return "drop full-remat recompute (+grad-accum to fit, -20-25%)"
    if b == "collective":
        return "fewer activation psums: fuse row-parallel pairs; " \
               "remat=none removes recompute psums (-25%)"
    return "near compute roof: raise arithmetic intensity per block"


def main():
    path = sys.argv[1] if len(sys.argv) > 1 else \
        "results/dryrun_baseline.json"
    with open(path) as f:
        recs = json.load(f)
    single = [r for r in recs if r["mesh"] == "16x16"]
    multi = {(r["arch"], r["shape"]): r for r in recs
             if r["mesh"] == "2x16x16"}
    print("| arch | shape | compute | memory | collective | bottleneck |"
          " useful FLOP ratio | roofline frac | multi-pod | note |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in single:
        key = (r["arch"], r["shape"])
        mp = multi.get(key, {}).get("status", "—")
        mp = "ok" if mp == "ok" else mp
        if r["status"] != "ok":
            print(f"| {r['arch']} | {r['shape']} | — | — | — |"
                  f" {r['status']} | — | — | {mp} | — |")
            continue
        t = r["terms_s"]
        print(f"| {r['arch']} | {r['shape']} | {fmt_s(t['compute'])} |"
              f" {fmt_s(t['memory'])} | {fmt_s(t['collective'])} |"
              f" {r['bottleneck']} | {r['useful_flop_ratio']:.3f} |"
              f" {r['roofline_fraction']:.4f} | {mp} | {fix_note(r)} |")
    # summary of per-device memory
    print("\n| arch | shape | args GiB/dev | temp GiB/dev | aliased GiB |")
    print("|---|---|---|---|---|")
    for r in single:
        if r["status"] != "ok":
            continue
        b = r["bytes_per_device"]
        print(f"| {r['arch']} | {r['shape']} |"
              f" {b['arguments'] / 2**30:.2f} | {b['temp'] / 2**30:.2f} |"
              f" {b['aliased'] / 2**30:.2f} |")


if __name__ == "__main__":
    main()
