"""Roofline machinery: collective parser, wire-byte factors, flop counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import SHAPES, get_config
from repro.roofline.analysis import (HBM_BW, LINK_BW, PEAK_FLOPS, Roofline,
                                     cost_analysis, model_flops,
                                     parse_collectives)

HLO_SAMPLE = """
HloModule test
%add { ... }
ENTRY %main {
  %ar = f32[1024,512]{1,0} all-reduce(%x), channel_id=1, replica_groups=[32,16]<=[512], use_global_device_ids=true, to_apply=%add
  %ag = bf16[256,256]{1,0} all-gather(%y), channel_id=2, replica_groups=[16,32]<=[512], dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%z), channel_id=3, replica_groups=[2,256]<=[512], to_apply=%add
  %cp = f32[128]{0} collective-permute(%w), channel_id=4, source_target_pairs={{0,1}}
  %nothing = f32[8,8]{1,0} add(%a, %b)
}
"""


class TestCollectiveParser:
    def test_kinds_and_counts(self):
        st = parse_collectives(HLO_SAMPLE)
        assert set(st.by_kind) == {"all-reduce", "all-gather",
                                   "reduce-scatter", "collective-permute"}
        assert all(v["count"] == 1 for v in st.by_kind.values())

    def test_wire_byte_factors(self):
        st = parse_collectives(HLO_SAMPLE)
        ar = 1024 * 512 * 4
        assert st.by_kind["all-reduce"]["wire"] == pytest.approx(
            2 * 15 / 16 * ar)
        ag = 256 * 256 * 2
        assert st.by_kind["all-gather"]["wire"] == pytest.approx(
            31 / 32 * ag)
        rs = 64 * 64 * 4
        assert st.by_kind["reduce-scatter"]["wire"] == pytest.approx(
            255 * rs)
        assert st.by_kind["collective-permute"]["wire"] == 128 * 4

    def test_real_compiled_hlo_has_collectives(self):
        """End-to-end on a real sharded executable (1-device degenerate
        mesh still emits no collectives — use replica groups check only
        when devices > 1, so here just assert the parse is clean)."""
        st = parse_collectives("no collectives here")
        assert st.wire_bytes == 0 and st.by_kind == {}


class TestScanAccounting:
    def test_cost_analysis_counts_scan_body_once(self):
        """The measured fact that motivates the dry-run's depth
        extrapolation (EXPERIMENTS.md §Dry-run): XLA cost analysis does
        NOT multiply a while-loop body by its trip count."""

        def scanned(x, w):
            return jax.lax.scan(lambda c, _: (jnp.tanh(c @ w), None), x,
                                None, length=8)[0]

        def unrolled(x, w):
            for _ in range(8):
                x = jnp.tanh(x @ w)
            return x

        xs = jax.ShapeDtypeStruct((64, 128), jnp.float32)
        ws = jax.ShapeDtypeStruct((128, 128), jnp.float32)
        # the analysis.cost_analysis shim unwraps jax 0.4.3x's list return
        f_scan = cost_analysis(jax.jit(scanned).lower(xs, ws).compile())
        f_unr = cost_analysis(jax.jit(unrolled).lower(xs, ws).compile())
        assert f_unr["flops"] == pytest.approx(8 * f_scan["flops"], rel=0.01)

    def test_depth_extrapolation_is_exact_for_identical_layers(self):
        """cost(L) is affine in L when layers are identical: c1 + (L-1)·Δ."""

        def model(n):
            def f(x, w):
                for _ in range(n):
                    x = jnp.tanh(x @ w)
                return x.sum()
            return f

        xs = jax.ShapeDtypeStruct((32, 64), jnp.float32)
        ws = jax.ShapeDtypeStruct((64, 64), jnp.float32)
        cost = lambda n: cost_analysis(
            jax.jit(model(n)).lower(xs, ws).compile())["flops"]
        c1, c2, c5 = cost(1), cost(2), cost(5)
        assert c5 == pytest.approx(c1 + 4 * (c2 - c1), rel=0.01)


class TestModelFlops:
    def test_dense_6nd(self):
        cfg = get_config("yi_6b")
        mf = model_flops(cfg, SHAPES["train_4k"], 256)
        n = cfg.n_params
        tokens = 4096 * 256
        assert mf == pytest.approx(6 * n * tokens / 256)

    def test_moe_uses_active_params(self):
        cfg = get_config("dbrx_132b")
        assert cfg.n_active_params() < 0.35 * cfg.n_params
        mf = model_flops(cfg, SHAPES["train_4k"], 256)
        assert mf == pytest.approx(6 * cfg.n_active_params() * 4096 * 256
                                   / 256)

    def test_param_counts_plausible(self):
        # total params should be in the ballpark of the checkpoint names
        expect = {"yi_6b": (5e9, 8e9), "qwen3_8b": (6e9, 10e9),
                  "qwen2_5_14b": (12e9, 17e9), "granite_3_8b": (7e9, 10e9),
                  "deepseek_v2_lite_16b": (13e9, 18e9),
                  "dbrx_132b": (115e9, 145e9),
                  "musicgen_medium": (1e9, 2.5e9), "rwkv6_7b": (6e9, 9e9),
                  "internvl2_1b": (0.4e9, 1.2e9),
                  "zamba2_2_7b": (2e9, 3.6e9)}
        for aid, (lo, hi) in expect.items():
            n = get_config(aid).n_params
            assert lo <= n <= hi, f"{aid}: {n / 1e9:.2f}B not in [{lo / 1e9}, {hi / 1e9}]"

    def test_roofline_bottleneck(self):
        r = Roofline(flops=1e15, hbm_bytes=1e12, wire_bytes=1e9,
                     compute_s=1e15 / PEAK_FLOPS, memory_s=1e12 / HBM_BW,
                     collective_s=1e9 / LINK_BW, bottleneck="compute",
                     model_flops=5e14)
        assert r.step_s == pytest.approx(1e15 / PEAK_FLOPS)
        assert 0.4 < r.useful_ratio <= 0.5
