"""End-to-end behaviour tests for the paper's system.

Reproduces the paper's pipeline: transform data into the relational
representation (§4.1), train the 2-layer sigmoid NN with gradient descent
inside a recursive CTE (§4.2), and evaluate prediction accuracy (§4.3) —
on both representations, checking they agree and actually learn.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import Engine, nn2sql
from repro.core.relational import one_hot_dense
from repro.data import make_iris, make_mnist_like, one_hot_labels


def _train_and_eval(engine_kind: str, n_iters=300, hidden=20):
    x, y = make_iris()
    spec = nn2sql.MLPSpec(n_rows=150, n_features=4, n_hidden=hidden,
                          n_classes=3, lr=0.05)
    g = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)
    y_oh = one_hot_dense(y, 3).to_dense()
    eng = Engine(engine_kind)
    wf, _ = nn2sql.train(g, w0, x, y_oh, n_iters, eng)
    probs = nn2sql.infer(g, eng)(wf, x)
    return float(nn2sql.accuracy(probs, y)), wf


def test_training_learns_iris_dense():
    acc, _ = _train_and_eval("dense")
    assert acc >= 0.9, acc


def test_training_learns_iris_relational():
    acc, _ = _train_and_eval("relational", n_iters=150)
    assert acc >= 0.85, acc


def test_engines_produce_identical_weights():
    x, y = make_iris()
    spec = nn2sql.MLPSpec(150, 4, 8, 3)
    g = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)
    y_oh = one_hot_dense(y, 3).to_dense()
    w_d, _ = nn2sql.train(g, w0, x, y_oh, 25, Engine("dense"))
    w_r, _ = nn2sql.train(g, w0, x, y_oh, 25, Engine("relational"))
    np.testing.assert_allclose(np.asarray(w_d["w_xh"]),
                               np.asarray(w_r["w_xh"]), rtol=1e-4,
                               atol=1e-5)


def test_mnist_shape_pipeline_runs():
    """The paper's second benchmark shape: 784 features, 10 classes."""
    x, y = make_mnist_like(256)
    spec = nn2sql.MLPSpec(256, 784, 20, 10, lr=0.05)
    g = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)
    y_oh = np.asarray(one_hot_labels(y, 10))
    wf, _ = nn2sql.train(g, w0, x, jnp.asarray(y_oh), 20, Engine("dense"))
    probs = nn2sql.infer(g, Engine("dense"))(wf, x)
    assert probs.shape == (256, 10)
    assert bool(jnp.isfinite(probs).all())


def test_union_all_history_reproduces_paper_memory_growth():
    """§8: the recursive CTE grows per iteration. The materialised-history
    mode must hold every weight version; the scan mode only the last."""
    x, y = make_iris()
    spec = nn2sql.MLPSpec(150, 4, 8, 3)
    g = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)
    y_oh = one_hot_dense(y, 3).to_dense()
    _, hist = nn2sql.train(g, w0, x, y_oh, 10, Engine("dense"),
                           materialize_history=True)
    assert hist["w_xh"].shape == (11, 4, 8)
    # iterations actually differ (the table grows with distinct versions)
    assert not np.allclose(hist["w_xh"][0], hist["w_xh"][-1])
