"""Batched serving engine: prefill + decode with continuous batching.

Model inference is the paper's second workload (§6.3.2: "inference alone is
worthful inside a database system to avoid data extraction"). The engine
serves a fixed decode batch of slots; finished sequences release their slot
to queued requests (continuous batching). Decode shapes are static —
(B, 1) token + fixed-capacity cache — so one compiled ``decode_step``
serves every request mix.
"""
from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from ..obs import tracer_of


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new_tokens: int
    generated: list = dataclasses.field(default_factory=list)
    done: bool = False


class ServingEngine:
    def __init__(self, model, params, max_len: int, batch_slots: int,
                 temperature: float = 0.0, seed: int = 0):
        self.model = model
        self.params = params
        self.max_len = max_len
        self.slots = batch_slots
        self.temperature = temperature
        self.key = jax.random.PRNGKey(seed)
        self.queue: deque[Request] = deque()
        self.active: list[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros((batch_slots,), np.int32)
        self.cache = model.init_cache(batch_slots, max_len)
        self.cur_token = np.zeros((batch_slots, 1), np.int32)
        self._decode = jax.jit(model.decode_step)
        self._steps = 0            # traced decode steps (metric_points)
        #: optional pinned :class:`repro.obs.Tracer`; ``None`` defers to
        #: the ambient tracer (no-op unless installed)
        self.tracer = None

    # ------------------------------------------------------------- requests
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        tr = tracer_of(self)
        for slot in range(self.slots):
            if self.active[slot] is None and self.queue:
                req = self.queue.popleft()
                self.active[slot] = req
                # per-slot prefill: feed prompt tokens through decode_step
                # (single compiled path; a bulk prefill() is used by the
                # benchmark harness where the whole batch arrives at once)
                with tr.span("serve.prefill", uid=req.uid, slot=slot,
                             prompt_len=len(req.prompt)):
                    for i, tok in enumerate(req.prompt):
                        logits, self.cache = self._decode(
                            self.params,
                            self._slot_batch(slot, int(tok)),
                            self.cache, jnp.int32(i))
                tr.inc("serve.admitted")
                tr.inc("serve.prefill_tokens", len(req.prompt))
                self.pos[slot] = len(req.prompt)
                nxt = self._sample(logits[slot, 0])
                req.generated.append(int(nxt))
                self.cur_token[slot, 0] = int(nxt)

    def _slot_batch(self, slot: int, tok: int) -> dict:
        t = self.cur_token.copy()
        t[slot, 0] = tok
        return {"tokens": jnp.asarray(t)}

    def _sample(self, logits) -> int:
        if self.temperature == 0.0:
            return int(jnp.argmax(logits))
        self.key, sub = jax.random.split(self.key)
        return int(jax.random.categorical(sub, logits / self.temperature))

    # ----------------------------------------------------------------- step
    def step(self) -> list[Request]:
        """One decode step for all active slots; returns finished requests."""
        tr = tracer_of(self)
        t0 = time.perf_counter()
        with tr.span("serve.step") as sp:
            self._admit()
            n_active = sum(r is not None for r in self.active)
            if not n_active:
                return []
            pos = int(max(self.pos[s] for s, r in enumerate(self.active)
                          if r is not None))
            logits, self.cache = self._decode(
                self.params, {"tokens": jnp.asarray(self.cur_token)},
                self.cache, jnp.int32(pos))
            finished = []
            for slot, req in enumerate(self.active):
                if req is None:
                    continue
                nxt = self._sample(logits[slot, 0])
                req.generated.append(nxt)
                self.pos[slot] += 1
                self.cur_token[slot, 0] = nxt
                if (len(req.generated) >= req.max_new_tokens
                        or self.pos[slot] >= self.max_len - 1):
                    req.done = True
                    finished.append(req)
                    self.active[slot] = None
            tr.inc("serve.decode_tokens", n_active)
            if tr.enabled:
                sp.set(active=n_active, finished=len(finished), pos=pos)
                dt = time.perf_counter() - t0
                self._steps += 1
                tr.observe("serve.step_ms", dt * 1e3)
                if dt > 0:
                    tr.point("serve.tokens_per_s", n_active / dt,
                             step=self._steps, active=n_active)
            return finished

    def run_to_completion(self, max_steps: int = 10_000) -> list[Request]:
        out = []
        for _ in range(max_steps):
            out.extend(self.step())
            if not self.queue and all(r is None for r in self.active):
                break
        return out
