"""The backend-agnostic adapter contract.

An :class:`Adapter` owns a prepared connection plus the matching
:mod:`repro.db.dialect`, and exposes exactly what the execution backend
needs: ``execute`` (rows back), ``create_table``, ``bulk_insert`` and the
vectorized ``insert_columns``.  Everything else (SQL rendering, array
pivoting) lives in ``dialect`` / ``relation_io`` so the adapters stay thin.

The contract a backend module (``sqlite.py`` / ``duckdb.py`` /
``postgres.py``) fills in:

* **statement execution** — ``_execute_raw`` / ``_executemany_raw`` are the
  only two places a raw connection runs SQL; DB-API drivers without a
  connection-level ``execute`` (psycopg2) override just these, and the
  traced/locked/counted ``execute`` / ``executemany`` wrappers stay shared.
* **param style** — ``placeholder`` / ``paramstyle``: every statement the
  shared code renders uses ``self.placeholder``, so qmark (sqlite, duckdb)
  and format (postgres) backends ride identical call sites.
* **ingestion** — ``insert_columns`` (vectorized bulk path; backends
  override with multi-row VALUES / Arrow registration / execute_values),
  optional ``insert_matrix_json`` behind ``supports_json_ingest`` /
  ``prefers_json_ingest``.
* **temp tables** — ``create_table(temp=True)`` scopes a relation to this
  connection; ``supports_temp_tables`` advertises it (all three backends).
* **UDF capability** — ``supports_python_udfs``: whether the connection can
  register Python scalar functions (sqlite/duckdb yes; postgres runs
  server-side and plpython-free, so the array representation's UDF zoo is
  unavailable there and callers must stay on pure-SQL relational paths).

Both matrix representations ride the same methods: cell-relational
``{[i, j, v]}`` tables through ``insert_columns``, array-representation
tables (ONE row, a JSON array-typed ``m`` column —
``relation_io.ARRAY_COLUMNS``) through ``bulk_insert``; ``matrix_digests``
entries embed the representation, so an engine switch on a shared
connection always rewrites the leaf.
"""
from __future__ import annotations

import itertools
import logging
import os
import re
import threading
import time
from typing import Iterable, Sequence

import numpy as np

from ...obs import tracer_of
from ..dialect import Sql92Dialect

_IDENT = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")

#: rows per executemany chunk (bounds peak Python-object materialisation)
CHUNK_ROWS = 100_000

#: queries slower than this many milliseconds are logged (rendered SQL head
#: + span path) through the ``repro.db`` logger; unset/invalid → disabled
SLOW_QUERY_ENV = "REPRO_SLOW_QUERY_MS"

#: characters of rendered SQL attached to spans and slow-query log lines
SQL_HEAD = 160

log = logging.getLogger("repro.db")


def _slow_threshold_s() -> float | None:
    """Parse ``REPRO_SLOW_QUERY_MS`` (read per query so tests and running
    processes can flip it); None disables the slow-query log."""
    v = os.environ.get(SLOW_QUERY_ENV)
    if not v:
        return None
    try:
        return float(v) / 1e3
    except ValueError:
        return None


def _check_ident(name: str) -> str:
    if not _IDENT.match(name):
        raise ValueError(f"bad SQL identifier: {name!r}")
    return name


#: process-wide table-generation registry: (db_key, table) → generation,
#: bumped by every structured mutation through ANY adapter of the same
#: logical database.  Pooled connections on one file see each other's
#: writes, so per-adapter caches (``matrix_cache`` / ``matrix_digests`` /
#: ``matrix_meta``) are trustworthy only while the generation they were
#: recorded at (``Adapter.matrix_gen``) still matches — the fix for the
#: two-connection stale-delta bug (``update_matrix_delta`` patching cells
#: on top of a sibling's rewrite).
_GEN_LOCK = threading.Lock()
_TABLE_GEN: dict[tuple[str, str], int] = {}
#: unique per-adapter token for non-shared registry keys (``:memory:``
#: databases, temp-table namespaces).  A plain ``id(self)`` is NOT unique
#: over time — CPython reuses addresses, so a fresh ``:memory:`` adapter
#: could inherit a dead sibling's generations/digests and "adopt" tables
#: it never wrote
_CONN_SEQ = itertools.count()
#: (db_key, table) → content digest as last written by ANY adapter.  A
#: pooled worker about to ingest a leaf whose digest already matches can
#: ADOPT the resident table instead of rewriting it — without this, two
#: workers alternating on one shared weight relation would invalidate each
#: other forever (write ping-pong).  Popped on every generation bump.
_TABLE_DIGEST: dict[tuple[str, str], bytes] = {}


class Adapter:
    """Base adapter: a prepared connection + its dialect."""

    dialect: Sql92Dialect
    #: literal spliced into rendered statements for one bound parameter
    placeholder = "?"
    #: DB-API paramstyle the placeholder belongs to ("qmark" / "format") —
    #: informational companion to ``placeholder`` for contract tests
    paramstyle = "qmark"
    #: whether ``create_table(temp=True)`` yields a connection-scoped table
    supports_temp_tables = True
    #: whether Python scalar functions can be registered on the connection
    #: (False on server-side backends — postgres — where the array
    #: representation's UDF zoo cannot run)
    supports_python_udfs = True
    #: whether ``insert_matrix_json`` (engine-side json_each expansion) is
    #: available — probed per connection where the backend supports it
    supports_json_ingest = False
    #: whether the engine-side JSON path should be the *default* matrix
    #: ingestion (``relation_io.write_matrix`` consults this) — only where
    #: the runtime engine expands JSON in linear time
    prefers_json_ingest = False

    def __init__(self, conn):
        self.conn = conn
        #: table → content digest of the matrix it stores, maintained by
        #: SQLEngine's leaf ingestion.  Lives on the adapter (not the
        #: engine) so every adapter-level mutation of a table — replace
        #: via create_table or append via bulk_insert/insert_columns, e.g.
        #: db.train writing `img` directly — invalidates the entry, and
        #: engines sharing one connection share the skip.  (Raw
        #: ``execute`` writes are untracked: mutate matrix tables through
        #: the structured methods.)
        self.matrix_digests: dict[str, bytes] = {}
        #: table → (representation, shape) of the matrix it stores — what
        #: the bound-parameter delta path (``relation_io.update_matrix_*``)
        #: checks before updating a resident relation in place
        self.matrix_meta: dict[str, tuple] = {}
        #: table → retained client-side copy of SMALL relational matrices
        #: (``relation_io.DELTA_MAX_CELLS`` gate) — the diff base that turns
        #: a leaf refresh into a prepared UPDATE of only the changed cells
        self.matrix_cache: dict[str, np.ndarray] = {}
        #: table → generation (``table_gen``) at which the caches above
        #: were recorded; ``cache_fresh`` compares it against the shared
        #: registry before any of them is trusted
        self.matrix_gen: dict[str, int] = {}
        #: tracer override for this connection's spans (None → the
        #: module-level active tracer, a no-op unless installed)
        self.tracer = None
        #: serializes ALL raw-connection access AND counter updates —
        #: sqlite connections opened ``check_same_thread=False`` and duckdb
        #: cursors are handed across pool-worker threads; re-entrant so
        #: span-wrapped fast paths may nest ``execute`` calls
        self.lock = threading.RLock()
        #: identity of the logical database for the shared generation
        #: registry; file-backed adapters override with a path key so
        #: sibling connections on one file share generations.  The token
        #: is a process-lifetime-unique sequence number, never id()
        self._conn_token = next(_CONN_SEQ)
        self._db_key = f"conn:{self._conn_token}"
        #: tables created ``temp=True`` — per-connection namespace, keyed
        #: per-adapter in the registry so temp churn never invalidates
        #: sibling connections
        self._temp_tables: set[str] = set()
        #: always-on cheap counters, merged into ``SQLEngine.stats``;
        #: mutate through ``add_counters`` (or under ``self.lock``) — plain
        #: ``+=`` from pool workers drops increments
        self.counters: dict[str, int] = {
            "queries": 0, "statements": 0, "rows_returned": 0,
            "ingest_bytes": 0, "ingest_cells": 0, "slow_queries": 0,
        }
        self.dialect.prepare(conn)

    # -- cross-connection cache coherence -----------------------------------
    def _gen_key(self, name: str) -> tuple[str, str]:
        """Registry key for a table: temp tables are invisible to sibling
        connections, so they key per-adapter; everything else keys per
        logical database."""
        if name in self._temp_tables:
            return (f"tmp:{self._conn_token}", name)
        return (self._db_key, name)

    def table_gen(self, name: str) -> int:
        with _GEN_LOCK:
            return _TABLE_GEN.get(self._gen_key(name), 0)

    def bump_gen(self, name: str) -> None:
        """Advance the table's shared generation (and drop its shared
        digest): every sibling adapter's caches for it become stale."""
        with _GEN_LOCK:
            k = self._gen_key(name)
            _TABLE_GEN[k] = _TABLE_GEN.get(k, 0) + 1
            _TABLE_DIGEST.pop(k, None)

    def cache_fresh(self, name: str) -> bool:
        """Were this adapter's cached digest/meta/diff-copy for ``name``
        recorded at the table's CURRENT generation?  False the moment any
        sibling adapter on the same database mutates the relation."""
        gen = self.matrix_gen.get(name)
        return gen is not None and gen == self.table_gen(name)

    def shared_digest(self, name: str) -> bytes | None:
        with _GEN_LOCK:
            return _TABLE_DIGEST.get(self._gen_key(name))

    def record_digest(self, name: str, digest: bytes) -> None:
        with _GEN_LOCK:
            _TABLE_DIGEST[self._gen_key(name)] = digest

    def add_counters(self, **deltas: int) -> None:
        """Locked read-modify-write of the always-on counters — exact
        totals even when pool workers ingest concurrently."""
        with self.lock:
            for k, v in deltas.items():
                self.counters[k] = self.counters.get(k, 0) + v

    # -- statement execution ------------------------------------------------
    #
    # EVERY statement the backend runs goes through ``execute`` /
    # ``executemany`` (or the span-wrapped fast paths in the backend
    # modules), so span coverage and the query counters cannot be bypassed
    # by new call sites — ``tests/test_obs_coverage.py`` statically
    # enforces both halves.  ``_execute_raw`` / ``_executemany_raw`` are
    # the driver seam: they run ONLY under the span+lock of the wrappers.

    def _execute_raw(self, sql: str, params: Sequence):
        """Run one statement on the raw connection, return a cursor-like
        with ``fetchall``.  Backends whose driver lacks a connection-level
        ``execute`` (psycopg2) override this single method."""
        # obs: exempt — driver seam; only ever called under the span and
        # lock of Adapter.execute
        return self.conn.execute(sql, tuple(params))

    def _executemany_raw(self, sql: str, rows: Iterable[Sequence]) -> None:
        # obs: exempt — driver seam; only ever called under the span and
        # lock of Adapter.executemany
        self.conn.executemany(sql, rows)

    def _finish_stmt(self, sql: str, dt: float, tracer) -> None:
        """Shared statement epilogue: slow-query log (``REPRO_SLOW_QUERY_MS``)
        with the rendered SQL head and the innermost span path."""
        thr = _slow_threshold_s()
        if thr is not None and dt >= thr:
            self.counters["slow_queries"] += 1
            head = " ".join(sql[:SQL_HEAD].split())
            log.warning("slow query %.1f ms (>= %s ms) span=%s sql=%s",
                        dt * 1e3, os.environ.get(SLOW_QUERY_ENV),
                        tracer.current_path() or "<untraced>", head)

    def execute(self, sql: str, params: Sequence = ()) -> list[tuple]:
        """Run one statement, return all result rows (possibly empty).
        Serialized on ``self.lock`` — one connection, many threads."""
        tr = tracer_of(self)
        with tr.span("db.execute") as sp, self.lock:
            t0 = time.perf_counter()
            cur = self._execute_raw(sql, params)
            try:
                rows = cur.fetchall()
            except Exception:  # statement without a result set
                rows = []
            dt = time.perf_counter() - t0
            self.counters["queries"] += 1
            self.counters["rows_returned"] += len(rows)
            if tr.enabled:
                sp.set(sql=" ".join(sql[:SQL_HEAD].split()), rows=len(rows))
                tr.observe("db.execute_ms", dt * 1e3)
            self._finish_stmt(sql, dt, tr)
        return rows

    def executemany(self, sql: str, rows: Iterable[Sequence]) -> None:
        tr = tracer_of(self)
        with tr.span("db.executemany") as sp, self.lock:
            t0 = time.perf_counter()
            self._executemany_raw(sql, rows)
            dt = time.perf_counter() - t0
            self.counters["statements"] += 1
            if tr.enabled:
                sp.set(sql=" ".join(sql[:SQL_HEAD].split()))
            self._finish_stmt(sql, dt, tr)

    # -- introspection ------------------------------------------------------
    def explain_sql(self, sql: str) -> str:
        """The engine's plan for ``sql`` as text ('' where unsupported) —
        captured once per cached plan by ``SQLEngine`` and stored alongside
        the plan-cache entry."""
        return ""

    def db_bytes(self) -> int | None:
        """Stored size of the database in bytes (None where unknowable) —
        the ``db_bytes`` delta attribute of evaluation spans."""
        return None

    # -- schema / data ------------------------------------------------------
    def forget(self, name: str) -> None:
        """Drop THIS adapter's caches for a table without advancing the
        shared generation — used when this adapter discovers its caches
        are stale: the resident content is a sibling's valid write, and
        bumping here would ping-pong invalidations between workers."""
        self.matrix_digests.pop(name, None)
        self.matrix_meta.pop(name, None)
        self.matrix_cache.pop(name, None)
        self.matrix_gen.pop(name, None)

    def _invalidate(self, name: str) -> None:
        """Forget everything cached about a matrix table — content digest,
        shape metadata and the client-side diff copy — so any structured
        mutation of the relation disables the unchanged-leaf skip AND the
        bound-parameter delta path until the next full registration.  Also
        advances the table's shared generation: sibling pooled adapters'
        caches go stale with ours."""
        self.forget(name)
        self.bump_gen(name)

    def create_table(self, name: str, columns: Sequence[tuple[str, str]],
                     replace: bool = True, temp: bool = False) -> None:
        """``columns`` is [(col_name, sql_type), ...].  ``temp=True``
        creates a per-connection temp table (batched request leaves, shard
        partitions): invisible to sibling connections, so its generation is
        keyed per-adapter and never invalidates their caches."""
        _check_ident(name)
        was_temp = name in self._temp_tables
        if replace and not temp and was_temp:
            # a temp table shadows the main-schema name on this
            # connection: DROP resolves to the shadow, so one drop below
            # would leave the resident main table colliding with CREATE
            self.execute(f"drop table if exists {name}")
        if temp:
            self._temp_tables.add(name)
        else:
            self._temp_tables.discard(name)
        self._invalidate(name)
        cols = ", ".join(f"{_check_ident(c)} {t}" for c, t in columns)
        kw = "temp table" if temp else "table"
        # creating a temp table over a name we never temp-created must NOT
        # drop first: unqualified DROP would resolve to (and destroy) the
        # MAIN relation the temp twin is supposed to shadow
        if replace and (not temp or was_temp):
            self.execute(f"drop table if exists {name}")
        self.execute(f"create {kw} {name} ({cols})")

    def bulk_insert(self, name: str, rows: Iterable[Sequence]) -> None:
        self._invalidate(name)
        rows = list(rows)
        if not rows:
            return
        ph = ", ".join([self.placeholder] * len(rows[0]))
        self.executemany(f"insert into {_check_ident(name)} values ({ph})",
                         rows)

    def _prepare_columns(self, name: str, cols: Sequence,
                         dtype=None) -> tuple[list[np.ndarray], int]:
        """Shared ``insert_columns`` preamble: identifier check, digest
        invalidation, array conversion, equal-length validation.  Returns
        ``(columns, n_rows)``; ``n_rows == 0`` means nothing to insert."""
        _check_ident(name)
        self._invalidate(name)
        cols = [np.asarray(c) if dtype is None else np.asarray(c, dtype)
                for c in cols]
        n = cols[0].shape[0] if cols else 0
        if n and any(c.shape != (n,) for c in cols):
            raise ValueError("insert_columns needs equal-length 1-D columns")
        return cols, n

    def insert_columns(self, name: str,
                       cols: Sequence[np.ndarray]) -> None:
        """Vectorized bulk ingestion: one ndarray per column, equal length.

        Generic implementation: chunked ``executemany`` over ``zip`` of
        ``tolist()`` slices — conversion to Python scalars happens in C,
        never per-cell in Python.  Backends override with faster native
        paths."""
        cols, n = self._prepare_columns(name, cols)
        if not n:
            return
        ph = ", ".join([self.placeholder] * len(cols))
        sql = f"insert into {name} values ({ph})"
        for s in range(0, n, CHUNK_ROWS):
            e = min(n, s + CHUNK_ROWS)
            self.executemany(sql, zip(*(c[s:e].tolist() for c in cols)))

    def update_cells(self, name: str, flat_index: np.ndarray,
                     values: np.ndarray, shape: Sequence[int]) -> None:
        """Bound-parameter in-place update of individual matrix cells,
        addressed by 0-based canonical row-major flat index — the prepared
        statement behind the small-leaf delta ingestion path.  Generic
        spelling keys on the (i, j) columns; sqlite overrides with the
        rowid fast path."""
        _check_ident(name)
        self.matrix_digests.pop(name, None)
        self.bump_gen(name)
        cols = int(shape[1])
        i = (flat_index // cols + 1).tolist()
        j = (flat_index % cols + 1).tolist()
        self.executemany(
            f"update {name} set v = {self.placeholder} where"
            f" i = {self.placeholder} and j = {self.placeholder}",
            zip(values.tolist(), i, j))

    # -- lifecycle ----------------------------------------------------------
    def commit(self) -> None:
        with self.lock:
            self.conn.commit()

    def close(self) -> None:
        with self.lock:
            try:  # flush pending inserts — sqlite3 rolls back open txns
                self.conn.commit()
            except Exception:  # pragma: no cover - autocommit (duckdb)
                pass
            self.conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
