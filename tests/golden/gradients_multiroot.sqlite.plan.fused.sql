-- repro:plan v1
-- repro:step _sp_a_xh
create temp table _sp_a_xh as
with recursive z_xh(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from img as m inner join w_xh as n on m.j = n.i
  group by m.i, n.j
),
a_xh(i, j, v) as (
  select f0.i, f0.j, (1/(1+exp(-f0.v))) as v
  from z_xh as f0
)
select i, j, v from a_xh;
-- repro:step _sp_a_ho
create temp table _sp_a_ho as
with recursive z_ho(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from _sp_a_xh as m inner join w_ho as n on m.j = n.i
  group by m.i, n.j
),
a_ho(i, j, v) as (
  select f0.i, f0.j, (1/(1+exp(-f0.v))) as v
  from z_ho as f0
)
select i, j, v from a_ho;
-- repro:step _sp_diff
create temp table _sp_diff as
with recursive diff(i, j, v) as (
  select f0.i, f0.j, (f0.v - f1.v) as v
  from _sp_a_ho as f0
  inner join one_hot as f1 on f1.i = f0.i and f1.j = f0.j
)
select i, j, v from diff;
-- repro:step _sp_had_c3
create temp table _sp_had_c3 as
with recursive had_c3(i, j, v) as (
  select f0.i, f0.j, ((1.0 * (2 * f0.v)) * (f1.v * (1 - f1.v))) as v
  from _sp_diff as f0
  inner join _sp_a_ho as f1 on f1.i = f0.i and f1.j = f0.j
)
select i, j, v from had_c3;
-- repro:main
with recursive loss(i, j, v) as (
  select f0.i, f0.j, (f0.v*f0.v) as v
  from _sp_diff as f0
),
t_c0(i, j, v) as (
  select j as i, i as j, v from img
),
t_c4(i, j, v) as (
  select j as i, i as j, v from w_ho
),
mm_c5(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from _sp_had_c3 as m inner join t_c4 as n on m.j = n.i
  group by m.i, n.j
),
had_c6(i, j, v) as (
  select f0.i, f0.j, (f0.v * (f1.v * (1 - f1.v))) as v
  from mm_c5 as f0
  inner join _sp_a_xh as f1 on f1.i = f0.i and f1.j = f0.j
),
mm_c7(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from t_c0 as m inner join had_c6 as n on m.j = n.i
  group by m.i, n.j
),
t_c8(i, j, v) as (
  select j as i, i as j, v from _sp_a_xh
),
mm_c9(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from t_c8 as m inner join _sp_had_c3 as n on m.j = n.i
  group by m.i, n.j
)
select 0 as r, i, j, v from loss
union all select 1 as r, i, j, v from mm_c7
union all select 2 as r, i, j, v from mm_c9;
