with recursive shift_c0(i, j, v) as (
  select a.i, b.j, coalesce(m.v, 0.0) as v
  from (with recursive s(x) as (select 1 union all select x+1 from s where x < 4) select x as i from s) a cross join
       (with recursive s(x) as (select 1 union all select x+1 from s where x < 3) select x as j from s) b
  left join zx as m on m.i = a.i - (1) and m.j = b.j
),
shift_c1(i, j, v) as (
  select a.i, b.j, coalesce(m.v, 0.0) as v
  from (with recursive s(x) as (select 1 union all select x+1 from s where x < 4) select x as i from s) a cross join
       (with recursive s(x) as (select 1 union all select x+1 from s where x < 3) select x as j from s) b
  left join zx as m on m.i = a.i - (-1) and m.j = b.j
)
select 0 as r, i, j, v from shift_c0
union all select 1 as r, i, j, v from shift_c1;
