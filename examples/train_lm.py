"""End-to-end LM training driver (deliverable b).

Trains a reduced-width decoder LM with the full production substrate:
token pipeline → scan-over-layers model → AdamW → grad clip → async
checkpointing → straggler monitoring → crash-safe restart.

    PYTHONPATH=src python examples/train_lm.py                  # ~2M params
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 200
    PYTHONPATH=src python examples/train_lm.py --arch dbrx_132b # reduced MoE

A few hundred steps on the default preset takes minutes on CPU; the 100m
preset is the "train a ~100M model for a few hundred steps" configuration
(expect ~1 s/step on a modern CPU core, faster on real accelerators).
"""
import argparse
import dataclasses

import jax

from repro.configs.base import ArchConfig, get_config
from repro.data import TokenPipeline
from repro.nn.model import LM
from repro.optim import adamw
from repro.train import Trainer

PRESETS = {
    "tiny": dict(n_layers=4, d_model=128, n_heads=4, n_kv_heads=2,
                 d_head=32, d_ff=512, vocab=2048),
    "20m": dict(n_layers=8, d_model=256, n_heads=8, n_kv_heads=4,
                d_head=32, d_ff=1024, vocab=8192),
    "100m": dict(n_layers=12, d_model=512, n_heads=8, n_kv_heads=4,
                 d_head=64, d_ff=2048, vocab=16384),
}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--preset", choices=list(PRESETS), default="tiny")
    ap.add_argument("--arch", default=None,
                    help="train a reduced assigned arch instead of a preset")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_lm_ckpt")
    args = ap.parse_args()

    if args.arch:
        cfg = get_config(args.arch, reduced=True)
    else:
        cfg = ArchConfig(name=f"lm-{args.preset}", family="dense",
                         **PRESETS[args.preset])
    lm = LM(cfg)
    n = cfg.n_params
    print(f"arch={cfg.name} params≈{n / 1e6:.1f}M "
          f"tokens/step={args.batch * args.seq}")
    data = TokenPipeline(vocab=cfg.vocab, seq_len=args.seq,
                         global_batch=args.batch)
    trainer = Trainer(lm, adamw(args.lr), data,
                      checkpoint_dir=args.ckpt_dir, checkpoint_every=100,
                      grad_accum=args.grad_accum)
    out = trainer.run(jax.random.PRNGKey(0), args.steps, log_every=10)
    hist = out["history"]
    print(f"\nloss {hist[0]['loss']:.4f} → {hist[-1]['loss']:.4f} over "
          f"{len(hist)} steps; stragglers flagged: "
          f"{sum(h['straggler'] for h in hist)}")


if __name__ == "__main__":
    main()
