"""Trainer, checkpoint/fault-tolerance, compression, data, serving."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import Checkpointer
from repro.configs.base import get_config
from repro.data import TokenPipeline, make_iris, make_mnist_like, replicate
from repro.nn.model import LM
from repro.optim import adamw, sgd
from repro.optim.compression import (compress_with_feedback,
                                     dequantize_int8, quantize_int8)
from repro.serving import Request, ServingEngine
from repro.train import StragglerMonitor, Trainer, make_train_step


class TestData:
    def test_iris_shapes(self):
        x, y = make_iris()
        assert x.shape == (150, 4) and y.shape == (150,)
        assert int(y.max()) == 2 and float(x.max()) <= 1.0

    def test_replication_scales_input(self):
        x, y = make_iris()
        x2, y2 = replicate(x, y, 4)
        assert x2.shape == (600, 4)

    def test_mnist_like(self):
        x, y = make_mnist_like(128)
        assert x.shape == (128, 784) and int(y.max()) <= 9

    def test_token_pipeline_deterministic_and_shardable(self):
        full = TokenPipeline(vocab=100, seq_len=8, global_batch=4)
        h0 = TokenPipeline(vocab=100, seq_len=8, global_batch=4,
                           host_id=0, n_hosts=2)
        h1 = TokenPipeline(vocab=100, seq_len=8, global_batch=4,
                           host_id=1, n_hosts=2)
        b_full = full.batch_at(3)
        np.testing.assert_array_equal(
            np.concatenate([h0.batch_at(3)["tokens"],
                            h1.batch_at(3)["tokens"]]),
            b_full["tokens"])
        np.testing.assert_array_equal(full.batch_at(3)["tokens"],
                                      b_full["tokens"])  # reproducible


class TestOptim:
    def test_sgd_matches_formula(self):
        opt = sgd(0.1)
        p = {"w": jnp.ones((3,))}
        g = {"w": jnp.full((3,), 2.0)}
        new, _ = opt.update(g, opt.init(p), p)
        np.testing.assert_allclose(new["w"], 0.8)

    def test_adamw_reduces_loss(self):
        opt = adamw(1e-1, weight_decay=0.0)
        p = {"w": jnp.asarray([5.0])}
        st = opt.init(p)
        for _ in range(50):
            g = {"w": 2 * p["w"]}
            p, st = opt.update(g, st, p)
        assert abs(float(p["w"][0])) < 1.0

    def test_int8_roundtrip_error_small(self):
        x = jnp.asarray(np.random.RandomState(0).randn(1000), jnp.float32)
        q, s, meta = quantize_int8(x)
        err = np.abs(np.asarray(dequantize_int8(q, s, meta) - x))
        assert err.max() < np.abs(np.asarray(x)).max() / 100

    def test_error_feedback_accumulates_to_zero(self):
        """Σ residuals stays bounded: compressed sum → true sum."""
        rng = np.random.RandomState(1)
        g = jnp.asarray(rng.randn(512), jnp.float32) * 1e-3
        err = jnp.zeros_like(g)
        total_sent = jnp.zeros_like(g)
        for _ in range(50):
            q, s, meta, err = compress_with_feedback(g, err)
            total_sent = total_sent + dequantize_int8(q, s, meta)
        np.testing.assert_allclose(np.asarray(total_sent + err),
                                   np.asarray(g * 50), rtol=1e-4, atol=1e-6)


class TestTrainerFaultTolerance:
    def _trainer(self, td):
        cfg = get_config("yi_6b", reduced=True)
        lm = LM(cfg)
        data = TokenPipeline(vocab=cfg.vocab, seq_len=16, global_batch=4)
        return Trainer(lm, adamw(1e-3), data, checkpoint_dir=td,
                       checkpoint_every=3), lm

    def test_loss_decreases_and_restart_resumes(self):
        with tempfile.TemporaryDirectory() as td:
            tr, lm = self._trainer(td)
            out = tr.run(jax.random.PRNGKey(0), 6, log_every=0)
            assert out["history"][-1]["loss"] < out["history"][0]["loss"]
            # simulated crash: a fresh trainer must resume at step 6
            tr2, _ = self._trainer(td)
            _, _, start = tr2.restore_or_init(jax.random.PRNGKey(9))
            assert start == 6

    def test_checkpoint_roundtrip_and_gc(self):
        with tempfile.TemporaryDirectory() as td:
            ck = Checkpointer(td, keep=2)
            tree = {"a": jnp.arange(6.0), "b": {"c": jnp.ones((2, 2))}}
            for step in (1, 2, 3):
                ck.save(step, tree, blocking=True)
            assert ck.list_steps() == [2, 3]          # gc keeps 2
            restored, step = ck.restore(tree)
            assert step == 3
            np.testing.assert_allclose(restored["a"], tree["a"])

    def test_grad_accum_matches_full_batch(self):
        cfg = get_config("yi_6b", reduced=True)
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        opt = sgd(0.1)
        batch = TokenPipeline(vocab=cfg.vocab, seq_len=16,
                              global_batch=8).batch_at(0)
        s1 = make_train_step(lm.loss_fn, opt, grad_accum=1)
        s2 = make_train_step(lm.loss_fn, opt, grad_accum=4)
        p1, _, m1 = jax.jit(s1)(params, opt.init(params), batch)
        p2, _, m2 = jax.jit(s2)(params, opt.init(params), batch)
        for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)

    def test_straggler_monitor(self):
        mon = StragglerMonitor(window=10, threshold=3.0)
        for i in range(10):
            assert not mon.record(i, 0.1)
        assert mon.record(10, 1.0)                   # 10× median
        assert mon.flagged == [10]


class TestServing:
    def test_continuous_batching_completes_all(self):
        cfg = get_config("yi_6b", reduced=True)
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        eng = ServingEngine(lm, params, max_len=32, batch_slots=2)
        for uid in range(4):
            eng.submit(Request(uid, np.arange(1 + uid, dtype=np.int32) + 1,
                               max_new_tokens=3 + uid))
        done = eng.run_to_completion()
        assert sorted(r.uid for r in done) == [0, 1, 2, 3]
        assert all(len(r.generated) >= r.max_new_tokens for r in done)

    def test_greedy_serving_matches_prefill(self):
        cfg = get_config("yi_6b", reduced=True)
        lm = LM(cfg)
        params = lm.init(jax.random.PRNGKey(0))
        prompt = np.array([3, 1, 4, 1, 5], np.int32)
        logits, _ = jax.jit(lm.prefill)(
            params, {"tokens": jnp.asarray(prompt)[None]})
        expect = int(jnp.argmax(logits[0, 0]))
        eng = ServingEngine(lm, params, max_len=16, batch_slots=1)
        eng.submit(Request(0, prompt, max_new_tokens=1))
        done = eng.run_to_completion()
        assert done[0].generated[0] == expect
