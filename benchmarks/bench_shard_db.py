"""Data-parallel in-DB training benchmark (SQL AllReduce across shards).

PR 10 partitions the training batch across N shard connections
(``db/shard.py``), evaluates the cached per-shard gradient plan on each,
and reduces the shipped gradient relations with ONE coordinator-side
``GROUP BY (r, i, j)`` statement — the AllReduce is itself SQL.  This
benchmark measures what sharding buys and emits ``BENCH_shard_db.json``.

What the sweep shows on a single-core runner is NOT thread parallelism
(sqlite releases the GIL, but one core runs one query at a time): the win
is the engine's superlinear cost in batch rows — the gradient query's
join/sort work grows faster than linearly, so N queries over n/N rows sum
to less than one query over n.  Measured here: ~2.0 ms/row at 32 rows
rising to ~3.7 ms/row at 1024, which makes the committed scale
(``--rows 1024``) improve monotonically from 1 to 4 shards while 8 shards
honestly regresses (per-query fixed cost wins).  The AllReduce itself is
attributed from tracer spans (``shard.ship`` / ``shard.allreduce`` /
``shard.broadcast``) — a few ms per iteration, orders below the gradient
queries.

Methodology: background load on a shared box drifts by tens of percent
over a multi-minute sweep, which would confound shard count with whatever
the machine was doing during that count's window.  So the sweep is
interleaved — shard counts are visited round-robin ``--repeats`` times —
and the headline per-iteration number is the MINIMUM warm iteration
observed (load only ever adds time, so the min estimates the uncontended
cost; medians across all warm iterations are reported alongside).

Run:  PYTHONPATH=src python benchmarks/bench_shard_db.py
CI smoke:  … bench_shard_db.py --rows 32 --iters 2 --shards 1,2 --repeats 1
           (below ``--monotone-min-rows`` the monotonicity check is
           vacuously true — at toy scale per-query overhead dominates and
           the superlinear term has nothing to amortise)
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

from repro import obs
from repro.core import nn2sql
from repro.db.plan_cache import PlanCache
from repro.db.shard import train_in_db_sharded
from repro.obs import regress


def run_one(graph, w, x, y, shards: int, iters: int, cache) -> dict:
    """One sharded training run under a collecting tracer; the first
    iteration (cold: leaf ingest + plan render) is reported separately
    from the warm iterations the scaling claim is about."""
    tr = obs.Tracer()
    with obs.use(tr):
        res = train_in_db_sharded(graph, w, x, y, iters, shards=shards,
                                  plan_cache_=cache)
    iter_ms = [p.value for p in tr.points if p.metric == "shard.iter_ms"]
    warm = iter_ms[1:] or iter_ms

    def span_ms(name):
        return sum(s.duration for s in tr.spans if s.name == name) \
            * 1e3 / max(iters, 1)

    return {
        "cold_iter_ms": iter_ms[0],
        "warm_iters_ms": warm,
        # the AllReduce, attributed per iteration from tracer spans
        "ship_ms": span_ms("shard.ship"),
        "allreduce_ms": span_ms("shard.allreduce"),
        "broadcast_ms": span_ms("shard.broadcast"),
        "grad_ms": span_ms("shard.grad"),   # summed across shard threads
        "shipped_bytes_per_iter": res.cte_bytes // max(iters, 1),
        "weights": res.weights,
    }


def run(args) -> dict:
    rng = np.random.default_rng(args.seed)
    spec = nn2sql.MLPSpec(n_rows=args.rows, n_features=args.features,
                          n_hidden=args.hidden, n_classes=args.classes,
                          lr=0.01)
    graph = nn2sql.build_graph(spec)
    w = {"w_xh": rng.normal(0, 0.3, (args.features, args.hidden)),
         "w_ho": rng.normal(0, 0.3, (args.hidden, args.classes))}
    x = rng.normal(0, 1, (args.rows, args.features))
    y = np.eye(args.classes)[rng.integers(0, args.classes, args.rows)]
    counts = [int(c) for c in args.shards.split(",") if c]
    cache = PlanCache(path=None)
    cores = os.cpu_count() or 1

    print(f"== sharded in-DB training: {args.rows}x{args.features} -> "
          f"{args.hidden} -> {args.classes}, {args.iters} iters x "
          f"{args.repeats} interleaved repeats, shards {counts}, "
          f"{cores} core(s) ==")

    # interleaved sweep: visit every shard count once per repeat so load
    # drift on the box lands on all counts alike, not on whichever count
    # happened to own a contiguous time window
    runs = {n: [] for n in counts}
    for rep in range(args.repeats):
        for n in counts:
            runs[n].append(run_one(graph, w, x, y, n, args.iters, cache))
            print(f"  repeat {rep}: shards={n:2d} warm "
                  f"{min(runs[n][-1]['warm_iters_ms']):8.1f} ms/iter",
                  flush=True)

    def med(vals):
        return sorted(vals)[len(vals) // 2]

    sweep = []
    for n in counts:
        rs = runs[n]
        warm_all = [t for r in rs for t in r["warm_iters_ms"]]
        sweep.append({
            "shards": n,
            "iters": args.iters,
            "repeats": args.repeats,
            "warm_iter_ms": min(warm_all),      # the headline: best observed
            "warm_iter_ms_median": med(warm_all),
            "warm_iters_ms": warm_all,
            "cold_iter_ms": min(r["cold_iter_ms"] for r in rs),
            "ship_ms": med([r["ship_ms"] for r in rs]),
            "allreduce_ms": med([r["allreduce_ms"] for r in rs]),
            "broadcast_ms": med([r["broadcast_ms"] for r in rs]),
            "grad_ms": med([r["grad_ms"] for r in rs]),
            "shipped_bytes_per_iter": rs[0]["shipped_bytes_per_iter"],
            "weights": rs[0]["weights"],
        })
    for r in sweep:
        print(f"shards={r['shards']:2d}: warm {r['warm_iter_ms']:8.1f} "
              f"ms/iter min ({r['warm_iter_ms_median']:8.1f} median)  "
              f"ship {r['ship_ms']:5.1f}  allreduce {r['allreduce_ms']:5.1f}"
              f"  broadcast {r['broadcast_ms']:4.1f} ms/iter", flush=True)

    # drop-in equivalence across the sweep: every shard count trains to
    # the same weights (float summation order is the only difference)
    base = sweep[0].pop("weights")
    max_diff = 0.0
    for r in sweep[1:]:
        wts = r.pop("weights")
        max_diff = max(max_diff,
                       max(float(np.abs(wts[k] - base[k]).max())
                           for k in base))
    print(f"max weight divergence across shard counts: {max_diff:.2e}")

    by_n = {r["shards"]: r for r in sweep}
    s1 = by_n.get(1) or sweep[0]
    s4 = by_n.get(4) or sweep[-1]

    # monotone 1 -> 4: only meaningful where the superlinear row cost has
    # something to amortise — below the gate (CI smoke scale) per-query
    # overhead dominates and the check is vacuously true
    gated = args.rows >= args.monotone_min_rows
    mono = True
    path = [r for r in sweep if r["shards"] <= 4]
    if gated:
        for a, b in zip(path, path[1:]):
            mono = mono and (b["warm_iter_ms"]
                             <= a["warm_iter_ms"] * (1 + args.monotone_slack))

    report = {
        "config": {"rows": args.rows, "features": args.features,
                   "hidden": args.hidden, "classes": args.classes,
                   "iters": args.iters, "repeats": args.repeats,
                   "shards": counts,
                   "seed": args.seed, "cores": cores,
                   "monotone_min_rows": args.monotone_min_rows,
                   "monotone_gated": gated},
        "sweep": sweep,
        "metrics": {
            "shard_db.iter_ms_s1":
                regress.metric(s1["warm_iter_ms"], "ms", "lower"),
            "shard_db.iter_ms_s4":
                regress.metric(s4["warm_iter_ms"], "ms", "lower"),
            "shard_db.speedup_s4":
                regress.metric(s1["warm_iter_ms"] / s4["warm_iter_ms"],
                               "x", "higher"),
            # coordinator-side costs are a few ms and scheduler-noisy —
            # wide band
            "shard_db.allreduce_ms_s4":
                regress.metric(s4["allreduce_ms"] + s4["ship_ms"]
                               + s4["broadcast_ms"], "ms", tolerance=4.0),
        },
        "checks": {
            # the sharded runs are drop-ins for each other (and, by
            # tests/test_shard_db.py, for the unsharded run) well inside
            # the 1e-4 acceptance bound
            "shard_counts_agree_1e4": max_diff <= 1e-4,
            "iter_time_monotone_1_to_4": mono,
            "allreduce_attributed_in_spans":
                all(r["allreduce_ms"] > 0 for r in sweep),
        },
    }
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=1024,
                    help="training batch rows (partitioned across shards)")
    ap.add_argument("--features", type=int, default=64)
    ap.add_argument("--hidden", type=int, default=32)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--iters", type=int, default=3,
                    help="training iterations per run (first is cold: "
                         "ingest + render)")
    ap.add_argument("--repeats", type=int, default=3,
                    help="interleaved round-robin visits per shard count")
    ap.add_argument("--shards", default="1,2,4,8",
                    help="comma-separated shard counts to sweep")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--monotone-min-rows", type=int, default=512,
                    help="rows below which the 1->4 monotonicity check is "
                         "vacuously true")
    ap.add_argument("--monotone-slack", type=float, default=0.05,
                    help="fractional tolerance per step of the "
                         "monotonicity check")
    ap.add_argument("--out", default="BENCH_shard_db.json")
    args = ap.parse_args()

    report = run(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nwrote {args.out}")
    ok = all(report["checks"].values())
    print("checks:", report["checks"])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
