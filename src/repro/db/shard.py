"""Data-parallel in-DB training: N shard connections + a SQL AllReduce.

This module mirrors :mod:`repro.launch.mesh`'s ``data`` axis in the
database tier.  The training batch is partitioned row-wise across N shard
connections (:func:`repro.launch.mesh.shard_slices` — the same contiguous
blocks a jax mesh would place along its data axis), each shard evaluates
the cached per-shard gradient plan on its own connection in a
thread-per-shard executor, and the reduction is *itself SQL*:

1. **ship** — every shard's tagged gradient rows (the raw
   ``SQLEngine.evaluate_rows`` output) are inserted into ONE coordinator
   relation ``shard_grads(r, s, i, j, v)``, stamped with the shard index
   ``s`` (the relational concatenation a ``UNION ALL`` over per-shard
   relations would produce);
2. **AllReduce + SGD** — the coordinator runs one statement that groups the
   concatenation on ``(r, i, j)``, sums across shards, and applies the
   update against the resident weight relation ``shard_w``::

       create temp table shard_w_next as
       select w.r, w.i, w.j, w.v - {lr} * coalesce(g.v, 0) as v
         from shard_w w
         left join (select r, i, j, sum(v) as v
                      from shard_grads group by r, i, j) g
           on g.r = w.r and g.i = w.i and g.j = w.j

   (array dialect: ``msum(group_concat(m, '|'))`` per weight —
   the ``magg``-style reduction — followed by ``madd``/``mscale``);
3. **broadcast** — the updated weights are read back once and re-ingested
   into every shard's temp leaves through the bound-parameter delta path.

The gradient of the unreduced square loss is a SUM over examples, so the
sum-reduction makes ``train_in_db(shards=N)`` a drop-in for unsharded
training: same update, the only difference is float summation order.

Every per-shard graph with the same row count renders to the SAME plan —
``build_graph`` is memoised per spec and the plan cache keys on DAG
structure × dialect, never on shard count — so one cached plan serves
every shard (two for an uneven split).

All shard state (weights, batch partition) lives in per-connection TEMP
tables (``SQLEngine(temp_leaves=True)``): shards never collide on a shared
catalog, never contend for the main database's write lock, and never
invalidate each other's matrix caches.  This works identically for N
sqlite files, N ``:memory:`` databases, duckdb cursors over one catalog,
and N postgres sessions.
"""
from __future__ import annotations

import dataclasses
from concurrent.futures import ThreadPoolExecutor
import time

import numpy as np

from ..core import autodiff, nn2sql
from ..launch.mesh import AxisSpec, shard_slices
from ..obs import tracer_of
from . import relation_io
from .adapters import ConnectionPool
from .dialect import json_to_matrix, matrix_to_json
from .sql_engine import SQLEngine
from .train import DBTrainResult

#: coordinator relation names (temp tables on the coordinator connection)
GRAD_TABLE = "shard_grads"
WEIGHT_TABLE = "shard_w"
WEIGHT_NEXT = "shard_w_next"

#: the wrt weight leaves, in multi-root tag order (root 0 is the loss,
#: root k is the gradient wrt WEIGHT_NAMES[k-1])
WEIGHT_NAMES = ("w_xh", "w_ho")


def allreduce_statements(representation: str, lr: float
                         ) -> tuple[list[str], str]:
    """The SQL AllReduce + SGD step as coordinator statements, plus the
    read-back query for the broadcast.  Pure SQL in both representations;
    the relational form runs unchanged on sqlite, duckdb and postgres."""
    lr = float(lr)
    if representation == "relational":
        reduce_stmt = (
            f"create temp table {WEIGHT_NEXT} as\n"
            f"select w.r as r, w.i as i, w.j as j,"
            f" w.v - {lr!r} * coalesce(g.v, 0) as v\n"
            f"  from {WEIGHT_TABLE} w\n"
            f"  left join (select r, i, j, sum(v) as v\n"
            f"               from {GRAD_TABLE} group by r, i, j) g\n"
            f"    on g.r = w.r and g.i = w.i and g.j = w.j")
        read_back = f"select r, i, j, v from {WEIGHT_TABLE}"
    else:
        reduce_stmt = (
            f"create temp table {WEIGHT_NEXT} as\n"
            f"select w.r as r, madd(w.m, mscale({-lr!r}, g.m)) as m\n"
            f"  from {WEIGHT_TABLE} w\n"
            f"  join (select r, msum(group_concat(m, '|')) as m\n"
            f"          from {GRAD_TABLE} group by r) g\n"
            f"    on g.r = w.r")
        read_back = f"select r, m from {WEIGHT_TABLE}"
    stmts = [
        f"drop table if exists {WEIGHT_NEXT}",
        reduce_stmt,
        f"delete from {WEIGHT_TABLE}",
        f"insert into {WEIGHT_TABLE} select * from {WEIGHT_NEXT}",
    ]
    return stmts, read_back


def _init_coord_weights(coord, weights, representation: str) -> None:
    """The coordinator's resident weight relation, tagged by root index."""
    if representation == "relational":
        coord.create_table(WEIGHT_TABLE,
                           (("r", "integer"),) + relation_io.MATRIX_COLUMNS,
                           temp=True)
        for k, nm in enumerate(WEIGHT_NAMES, start=1):
            i, j, v = relation_io.matrix_to_columns(weights[nm])
            coord.insert_columns(WEIGHT_TABLE,
                                 (np.full_like(i, k), i, j, v))
    else:
        coord.create_table(WEIGHT_TABLE,
                           (("r", "integer"),) + relation_io.ARRAY_COLUMNS,
                           temp=True)
        coord.bulk_insert(WEIGHT_TABLE,
                          [(k, matrix_to_json(weights[nm]))
                           for k, nm in enumerate(WEIGHT_NAMES, start=1)])


def _decode_weights(rows, shapes: dict, representation: str) -> dict:
    """Read-back rows → ``{name: dense}`` (the broadcast payload)."""
    out = {nm: np.zeros(shapes[nm], dtype=np.float64) for nm in WEIGHT_NAMES}
    if representation == "relational":
        arr = np.asarray(rows, dtype=np.float64)
        r = arr[:, 0].astype(np.int64)
        i = arr[:, 1].astype(np.int64) - 1
        j = arr[:, 2].astype(np.int64) - 1
        for k, nm in enumerate(WEIGHT_NAMES, start=1):
            m = r == k
            out[nm][i[m], j[m]] = arr[m, 3]
    else:
        for r, m in rows:
            out[WEIGHT_NAMES[int(r) - 1]] = json_to_matrix(m)
    return out


def _loss_sum(rows_per_shard, representation: str) -> float:
    """Total of the (unreduced, elementwise-square) loss cells across
    every shard's result rows — tagged ``r == 0`` in the multi-root
    output.  Divided by the full batch's cell count it is exactly the
    mean loss unsharded training reports."""
    total = 0.0
    for rows in rows_per_shard:
        for row in rows:
            if int(row[0]) == 0:
                if representation == "relational":
                    total += float(row[3])
                else:
                    total += float(json_to_matrix(row[1]).sum())
    return total


def train_in_db_sharded(graph, weights, x, y_onehot, n_iters: int, *,
                        shards: int, backend: str = "sqlite",
                        path: str = ":memory:",
                        representation: str = "auto",
                        plan_cache_=None,
                        pool: ConnectionPool | None = None
                        ) -> DBTrainResult:
    """Data-parallel ``train_in_db``: partition the batch across ``shards``
    connections, evaluate the cached per-shard gradient plan concurrently,
    AllReduce + SGD in SQL on a coordinator connection, broadcast.

    A drop-in for unsharded training — reached as
    ``train_in_db(..., shards=N)`` — matching it ≤ 1e-4 (only float
    summation order differs; with a fixed partition the run itself is
    deterministic).  ``representation="auto"`` uses the relational cell
    representation, which runs on every backend including UDF-less
    postgres; ``"array"`` rides the §5 array codec where Python UDFs
    register."""
    if shards < 1:
        raise ValueError(f"need shards >= 1, got {shards}")
    if representation not in ("auto", "relational", "array"):
        raise ValueError(f"unknown representation {representation!r}")
    rep = "relational" if representation == "auto" else representation

    x = np.asarray(x, dtype=np.float64)
    y_onehot = np.asarray(y_onehot, dtype=np.float64)
    axis = AxisSpec("data", shards)
    slices = shard_slices(x.shape[0], axis.size)

    # one gradient DAG per DISTINCT shard size: equal-size shards share the
    # graph object (build_graph is memoised) and therefore ONE cached plan
    roots_by_size: dict[int, list] = {}
    for sl in slices:
        n = sl.stop - sl.start
        if n not in roots_by_size:
            sg = nn2sql.build_graph(
                dataclasses.replace(graph.spec, n_rows=n))
            grads = autodiff.gradients(sg.loss, [sg.w_xh, sg.w_ho])
            roots_by_size[n] = [sg.loss, grads[sg.w_xh], grads[sg.w_ho]]

    owned = pool is None
    if owned:
        pool = ConnectionPool(backend, path, size=shards)
    elif len(pool) < shards:
        raise ValueError(f"pool has {len(pool)} connections, need {shards}")
    coord = pool[0]
    if rep == "array" and not getattr(coord, "supports_python_udfs", True):
        raise ValueError(
            f"the array representation needs Python UDFs, which the "
            f"{type(coord).__name__} backend cannot register — use "
            f"representation='relational'")
    dialect = "array" if rep == "array" else None
    engines = [SQLEngine(adapter=pool[k], plan_cache_=plan_cache_,
                         dialect=dialect, temp_leaves=True)
               for k in range(shards)]

    cur = {nm: np.asarray(weights[nm], dtype=np.float64)
           for nm in WEIGHT_NAMES}
    shapes = {nm: cur[nm].shape for nm in WEIGHT_NAMES}
    loss_cells = float(y_onehot.size)
    stmts, read_back = allreduce_statements(rep, graph.spec.lr)
    tr = tracer_of(coord)
    traffic_rows = 0
    t0 = time.perf_counter()
    try:
        with tr.span("train.in_db", strategy="sharded", representation=rep,
                     n_iters=n_iters, backend=coord.dialect.name,
                     shards=shards, axis=axis.name):
            relation_io.create_shard_grads(coord, GRAD_TABLE, rep)
            _init_coord_weights(coord, cur, rep)
            # warm the shared plan cache on the main thread so shard
            # threads never race the same miss
            for roots in roots_by_size.values():
                engines[0]._render(roots)
            history = [dict(cur)]

            def grad_rows(k: int) -> list[tuple]:
                sl = slices[k]
                eng = engines[k]
                env = {**cur, "img": x[sl], "one_hot": y_onehot[sl]}
                with tracer_of(eng.adapter).span(
                        "shard.grad", shard=k, rows=sl.stop - sl.start):
                    return eng.evaluate_rows(
                        roots_by_size[sl.stop - sl.start], env)

            with ThreadPoolExecutor(max_workers=shards) as executor:
                for it in range(n_iters):
                    t_it = time.perf_counter()
                    with tr.span("shard.step", iter=it, shards=shards):
                        results = list(executor.map(grad_rows,
                                                    range(shards)))
                        with tr.span("shard.ship") as sp:
                            coord.execute(f"delete from {GRAD_TABLE}")
                            shipped = 0
                            for k, rows in enumerate(results):
                                shipped += relation_io.ship_grad_rows(
                                    coord, GRAD_TABLE, k, rows, rep)
                            sp.set(rows=shipped)
                            traffic_rows += shipped
                        with tr.span("shard.allreduce", shards=shards,
                                     op="sum"):
                            for stmt in stmts:
                                coord.execute(stmt)
                        with tr.span("shard.broadcast"):
                            cur = _decode_weights(coord.execute(read_back),
                                                  shapes, rep)
                        history.append(dict(cur))
                    if tr.enabled:
                        dt = time.perf_counter() - t_it
                        tr.observe("shard.iter_ms", dt * 1e3)
                        tr.point("shard.iter_ms", dt * 1e3, step=it,
                                 shards=shards)
                        tr.point("train.loss",
                                 _loss_sum(results, rep) / loss_cells,
                                 step=it, strategy="sharded")
        if tr.enabled:
            dt = time.perf_counter() - t0
            tr.point("train.iter_ms", dt * 1e3 / max(n_iters, 1),
                     step=n_iters, strategy="sharded")
            stats = SQLEngine.merged_stats(engines)
            cells = stats.get("adapter", {}).get("ingest_cells")
            if cells:
                tr.point("train.rows_ingested", cells, step=n_iters)
        return DBTrainResult(
            weights=history[-1], history=history, strategy="sharded",
            sql=stmts[1],
            # cross-connection AllReduce traffic: every shipped gradient
            # row is (r, s, i, j, v) — the sharded twin of the recursive
            # strategies' materialised-iterate accounting
            cte_bytes=traffic_rows * 5 * 8)
    finally:
        if owned:
            pool.close()
