with recursive const_c0(i, j, v) as (
  select a.i, b.j, 1.0 as v
  from (with recursive s(x) as (select 1 union all select x+1 from s where x < 3) select x as i from s) a,
       (with recursive s(x) as (select 1 union all select x+1 from s where x < 2) select x as j from s) b
)
select * from const_c0 order by i, j;
