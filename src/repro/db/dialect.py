"""SQL dialects for the in-database execution backend.

The transpiler (``core.sqlgen``) renders the expression DAG against a
*dialect* object so the same generator serves several engines (§6 of the
paper evaluates DuckDB, HyPer and PostgreSQL; we target what the container
actually ships):

``Sql92Dialect``
    The paper's verbatim SQL-92: ``generate_series`` table function,
    ``exp`` / ``greatest`` builtins.  This is the golden-test dialect — its
    output matches the listings' structure exactly.

``SqliteDialect``
    stdlib ``sqlite3``, always available.  Two deviations are needed:

    * ``generate_series`` is a loadable extension sqlite3 does not ship, so
      constant matrices are built from an inline ``WITH RECURSIVE`` series
      (the emulation forces the top-level ``WITH`` to say ``RECURSIVE``);
    * ``exp`` and ``greatest`` are not built in — they are registered as
      deterministic Python UDFs on every connection (``prepare``).

    SQLite additionally restricts recursive CTEs: the recursive table may
    appear exactly once, in the *top-level* FROM clause of the recursive
    select — never inside a subquery ("circular reference") — and recursion
    is row-at-a-time queue semantics.  Listing 7's relational training query
    (which re-reads the whole previous weight *table* through a nested WITH)
    is therefore inexpressible; the training loop instead runs the paper's
    *array-data-type* variant (Listing 10): the whole weight state rides in
    ONE row of array-typed columns, and the matrix algebra is provided by
    registered UDFs over a JSON array encoding — ``create_function`` being
    sqlite's analogue of the paper's §5 DuckDB array-type extension.

``DuckDBDialect``
    Used when the ``duckdb`` package is importable (``pip install
    repro[db]``).  Stock SQL-92 rendering works unchanged (DuckDB has
    ``generate_series``, ``exp``, ``greatest``), and the Listing 7 / 10
    training queries are rendered by ``core.sqlgen`` verbatim.

``ArrayDialect``
    The paper's §5 *array data type* as a first-class fourth dialect
    (``representation = "array"``): one row per matrix, UDF calls per IR
    node, recursive-CTE scans over one array-typed state row.  See the
    class docstring and ``core.sqlgen``'s array renderer.
"""
from __future__ import annotations

import base64
import collections
import json
import math
import os

import numpy as np

from ..core import expr as E

try:  # optional dependency, gated — never required
    import duckdb  # type: ignore

    HAVE_DUCKDB = True
except ImportError:  # pragma: no cover - exercised when duckdb is absent
    duckdb = None
    HAVE_DUCKDB = False


# ---------------------------------------------------------------------------
# JSON array codec — the "array data type" as sqlite sees it
# ---------------------------------------------------------------------------

def matrix_to_json(x) -> str:
    """Encode a matrix as the array data type: row-major values + dims.
    This is the STORAGE codec — what ``write_matrix_array`` puts in the
    one-row array tables and what the documentation (paper §5) shows."""
    a = np.asarray(x, dtype=np.float64)
    return json.dumps({"r": a.shape[0], "c": a.shape[1],
                       "d": a.reshape(-1).tolist()})


def _matrix_to_wire(x) -> str:
    """The intra-query WIRE codec: ``b:<r>,<c>;<base64 float64 bytes>``.

    UDF→UDF exchange inside one statement never touches storage, so the
    array extension trades the human-readable JSON for a binary codec
    there — encode/decode is a memcpy + base64 pass instead of per-float
    text formatting, which dominated the recursive-training iteration
    (``json.dumps``+``json.loads`` were ~80% of its wall time).  base64's
    alphabet avoids the ``|``/``,`` separators of the scan string
    aggregation, and ``mrowcat``'s ``split(':', 1)`` keeps the payload
    intact.  NaN/±inf ride the IEEE bytes exactly — no printf spelling.
    ``json_to_matrix`` sniffs the prefix and accepts both codecs."""
    a = np.ascontiguousarray(x, dtype=np.float64)
    return (f"b:{a.shape[0]},{a.shape[1]};"
            + base64.b64encode(a.tobytes()).decode("ascii"))


def json_to_matrix(s) -> np.ndarray:
    """Decode either array codec (JSON storage or binary wire format)."""
    if isinstance(s, bytes):
        s = s.decode("ascii")
    if s.startswith("b:"):
        head, payload = s[2:].split(";", 1)
        r, c = head.split(",")
        a = np.frombuffer(base64.b64decode(payload), dtype=np.float64)
        return a.reshape(int(r), int(c))
    o = json.loads(s)
    return np.asarray(o["d"], dtype=np.float64).reshape(o["r"], o["c"])


def _wrap2(f):
    return lambda x, y: _matrix_to_wire(
        f(json_to_matrix(x), json_to_matrix(y)))


def _wrap1(f):
    return lambda x: _matrix_to_wire(f(json_to_matrix(x)))


# -- zoo-tier array semantics (numpy twins of core.dense.eval_node) ---------

def _np_topk_mask(a: np.ndarray, k) -> np.ndarray:
    """0/1 indicator of each row's k largest entries, ties toward the
    smaller column index — the exact order of ``dense.topk_mask`` and the
    relational ``order by v desc, j asc`` rank."""
    c = a.shape[1]
    gt = (a[:, None, :] > a[:, :, None]).sum(-1)
    tri = np.tril(np.ones((c, c), dtype=bool), -1)
    eq = ((a[:, None, :] == a[:, :, None]) & tri[None]).sum(-1)
    return ((gt + eq) < int(k)).astype(np.float64)


def _np_row_shift(a: np.ndarray, offset) -> np.ndarray:
    offset = int(offset)
    if offset == 0:
        return a
    out = np.zeros_like(a)
    if abs(offset) >= a.shape[0]:
        return out
    if offset > 0:
        out[offset:] = a[:-offset]
    else:
        out[:offset] = a[-offset:]
    return out


def _udf_mreduce(m: str, kind: str, axis) -> str:
    a = json_to_matrix(m)
    red = a.sum if kind == "sum" else a.max
    return _matrix_to_wire(red(axis=int(axis), keepdims=True))


def _udf_msoftmax(m: str) -> str:
    a = json_to_matrix(m)
    e = np.exp(a - a.max(axis=1, keepdims=True))
    return _matrix_to_wire(e / e.sum(axis=1, keepdims=True))


def _udf_mgather(x: str, idx: str) -> str:
    a = json_to_matrix(x)
    s = json_to_matrix(idx)[:, 0].astype(np.int64)
    if s.size and (s.min() < 0 or s.max() >= a.shape[0]):
        raise ValueError(f"mgather index out of range: valid rows "
                         f"0..{a.shape[0] - 1}")
    return _matrix_to_wire(a[s])


def _udf_mscatter(x: str, idx: str, n_rows) -> str:
    a = json_to_matrix(x)
    s = json_to_matrix(idx)[:, 0].astype(np.int64)
    n_rows = int(n_rows)
    if s.size and (s.min() < 0 or s.max() >= n_rows):
        # np.add.at would wrap negative indices silently — mirror mgather
        # (and eager dense evaluation), which raise on the contract breach
        raise ValueError(f"mscatter index out of range: valid rows "
                         f"0..{n_rows - 1}")
    out = np.zeros((n_rows, a.shape[1]))
    np.add.at(out, s, a)
    return _matrix_to_wire(out)


def _udf_mrow(m: str, t) -> str:
    """Row ``t`` (1-based) as a (1, C) matrix — the scan CTE's state row."""
    t = int(t)
    return _matrix_to_wire(json_to_matrix(m)[t - 1:t, :])


def _udf_mmaxind(x: str, red: str) -> str:
    """The argmax indicator of a cached keepdims max (``ReduceDeriv``):
    broadcasting handles both axes."""
    return _matrix_to_wire(
        (json_to_matrix(x) == json_to_matrix(red)).astype(np.float64))


def _udf_mrecurstep(a: str, s: str, b: str, t, trans) -> str:
    """One step of the matrix-valued scan (``MatRecurrence``): slice block
    ``t`` (1-based) out of the (T·D, D) stack, return the (1, D) row
    ``s · A_t + b_t`` (``trans`` ≠ 0 uses A_tᵀ — the Algorithm-1 adjoint
    scan's transposed coefficients).  Keeping the matvec inside one scalar
    call is what lets the array dialect run the scan as a genuine
    recursive CTE: the recursive member stays aggregate-free."""
    t = int(t)
    av, sv, bv = json_to_matrix(a), json_to_matrix(s), json_to_matrix(b)
    d = av.shape[1]
    blk = av[(t - 1) * d:t * d, :]
    if int(trans):
        blk = blk.T
    return _matrix_to_wire(sv @ blk + bv[t - 1:t, :])


def _udf_mstepouter(x: str, y: str) -> str:
    """The stacked per-step outer product (``StepOuter``): x (T, K),
    y (T, J) → (T·K, J) with out[(t-1)K+k, j] = x[t,k]·y[t,j]."""
    xv, yv = json_to_matrix(x), json_to_matrix(y)
    return _matrix_to_wire(
        (xv[:, :, None] * yv[:, None, :]).reshape(-1, yv.shape[1]))


def _udf_mcellcat(concat, r, c) -> str:
    """Reassemble a CELL relation from concatenated ``i,j,v`` tags (the
    packed MatRecurrence lowering's child ingestion): order-independent,
    missing cells zero-fill — the outer-join semantics of the dense
    relation invariant.  ``%.17g`` tags round-trip float64 exactly."""
    out = np.zeros((int(r), int(c)))
    if concat:
        for tok in concat.split("|"):
            i, j, v = tok.split(",")
            try:
                out[int(i) - 1, int(j) - 1] = float(v)
            except ValueError as exc:
                raise ValueError(
                    f"mcellcat: unparseable cell tag {tok!r} — the packed "
                    f"codec expects '%.17g' or nan/inf spellings") from exc
    return _matrix_to_wire(out)


def _udf_mcell(m: str, i, j) -> float:
    """One cell (1-based) of an array codec — the packed scan's unpivot."""
    return float(json_to_matrix(m)[int(i) - 1, int(j) - 1])


def _udf_mrowcat(concat) -> str:
    """Reassemble a scan trajectory from the concatenated ``t:<codec>``
    tags (``group_concat(cast(t as text) || ':' || s, '|')``): split,
    sort by t, vstack.  Order-independent — forward scans, reverse scans
    and duckdb's unordered ``string_agg`` all land in the same matrix.
    This scalar UDF replaces the former ``magg_rows`` Python aggregate:
    duckdb has no Python aggregate API, but native string aggregation +
    one scalar call it can run."""
    if concat is None:  # empty scan (never rendered, but NULL-safe)
        return _matrix_to_wire(np.zeros((0, 0)))
    rows = []
    for tok in concat.split("|"):
        t, m = tok.split(":", 1)
        rows.append((int(t), m))
    rows.sort()
    return _matrix_to_wire(np.vstack([json_to_matrix(m) for _t, m in rows]))


def _udf_msum(concat) -> str:
    """Matrix sum over a ``'|'``-joined concatenation of array codecs
    (``msum(group_concat(m, '|'))``) — the array-representation AllReduce
    reducer of ``db/shard.py``: per-shard gradient rows are string-
    aggregated per weight relation and summed in ONE scalar call.  ``'|'``
    is collision-free: neither codec (base64 wire, JSON) emits it."""
    if concat is None:  # empty group (never rendered, but NULL-safe)
        return _matrix_to_wire(np.zeros((0, 0)))
    parts = [json_to_matrix(tok) for tok in concat.split("|")]
    out = parts[0].astype(np.float64, copy=True)
    for p in parts[1:]:
        out += p
    return _matrix_to_wire(out)


#: name → (nargs, python impl).  These are the matrix operations of the
#: paper's §5 array extension; ``core.sqlgen.array_call_expr`` (and the
#: ``training_query_array_calls`` recursion built on it) renders expression
#: DAGs as nested calls over exactly these names.
ARRAY_UDFS: dict[str, tuple[int, object]] = {
    "mm": (2, _wrap2(lambda a, b: a @ b)),
    "madd": (2, _wrap2(lambda a, b: a + b)),
    "msum": (1, _udf_msum),
    "msub": (2, _wrap2(lambda a, b: a - b)),
    "mhad": (2, _wrap2(lambda a, b: a * b)),
    "mscale": (2, lambda c, x: _matrix_to_wire(c * json_to_matrix(x))),
    "mt": (1, _wrap1(lambda a: a.T)),
    "mconst": (3, lambda r, c, v: _matrix_to_wire(np.full((int(r), int(c)), v))),
    "mmean": (1, lambda x: float(json_to_matrix(x).mean())),
    # elementwise maps and their derivatives (Algorithm 1's f / f')
    "msig": (1, _wrap1(lambda a: 1.0 / (1.0 + np.exp(-a)))),
    "msigd": (1, _wrap1(lambda a: a * (1.0 - a))),        # from cached f(x)
    "msqr": (1, _wrap1(lambda a: a * a)),
    "msqrd": (1, _wrap1(lambda a: 2.0 * a)),
    "mrelu": (1, _wrap1(lambda a: np.maximum(a, 0.0))),
    "mrelud": (1, _wrap1(lambda a: (a > 0.0).astype(np.float64))),
    "mone_minus": (1, _wrap1(lambda a: 1.0 - a)),
    "mrecip": (1, _wrap1(lambda a: 1.0 / a)),
    "mrecipd": (1, _wrap1(lambda a: -(a * a))),           # from cached f(x)
    # zoo tier (PR 3 IR nodes) — the array-dialect lowering of RowReduce /
    # Softmax / ArgTopK / Gather / Scatter / RowShift and the scan-state
    # helpers of the Recurrence recursive CTE
    "mreduce": (3, _udf_mreduce),
    "msoftmax": (1, _udf_msoftmax),
    "mtopk": (2, lambda m, k: _matrix_to_wire(_np_topk_mask(json_to_matrix(m),
                                                           k))),
    "mgather": (2, _udf_mgather),
    "mscatter": (3, _udf_mscatter),
    "mrowshift": (2, lambda m, off: _matrix_to_wire(
        _np_row_shift(json_to_matrix(m), off))),
    "mrow": (2, _udf_mrow),
    "mmaxind": (2, _udf_mmaxind),
    # matrix-valued recurrence tier: the scan step, the stacked outer
    # product of its VJP, and the portable trajectory reassembly
    "mrecurstep": (5, _udf_mrecurstep),
    "mstepouter": (2, _udf_mstepouter),
    "mrowcat": (1, _udf_mrowcat),
    "mcellcat": (3, _udf_mcellcat),
    "mcell": (3, _udf_mcell),
}


# ---------------------------------------------------------------------------
# UDF memoization
# ---------------------------------------------------------------------------
#
# ``training_query_array_calls`` inlines every shared subexpression (the
# recursion is one query text, there is no CSE across the inlined copies),
# so the engine evaluates the SAME pure UDF call — same name, same JSON
# codec arguments — many times per iteration.  Every ARRAY_UDFS entry is a
# pure function of its arguments, so a byte-bounded memo over
# ``(name, *args)`` turns that duplication factor into cache hits.

class _ByteLRU:
    """LRU keyed on UDF call signatures, bounded by total result bytes."""

    def __init__(self, max_bytes: int):
        self.max_bytes = max_bytes
        self._d: collections.OrderedDict = collections.OrderedDict()
        self._bytes = 0
        self.hits = 0
        self.misses = 0

    def get(self, key):
        try:
            value, _n = self._d[key]
        except KeyError:
            self.misses += 1
            return None
        self._d.move_to_end(key)
        self.hits += 1
        return value

    def put(self, key, value) -> None:
        nbytes = len(value) if isinstance(value, str) else 8
        if nbytes > self.max_bytes:
            return
        old = self._d.pop(key, None)
        if old is not None:
            self._bytes -= old[1]
        self._d[key] = (value, nbytes)
        self._bytes += nbytes
        while self._bytes > self.max_bytes:
            _k, (_v, n) = self._d.popitem(last=False)
            self._bytes -= n


_UDF_CACHE: _ByteLRU | None = None


def _udf_cache() -> _ByteLRU | None:
    """The process-wide UDF memo (``REPRO_UDF_CACHE_MB``, default 256;
    0 disables).  Shared across connections — the UDFs are pure, so a
    hit from another engine's workload is still correct."""
    global _UDF_CACHE
    if _UDF_CACHE is None:
        mb = float(os.environ.get("REPRO_UDF_CACHE_MB", "256"))
        _UDF_CACHE = _ByteLRU(int(mb * 1024 * 1024)) if mb > 0 else None
    return _UDF_CACHE


def _memoized(name: str, fn):
    """Wrap a pure ARRAY_UDFS impl with the byte-bounded memo.  Results
    are cached only on success; calls with non-scalar/str arguments (none
    exist today) bypass the cache rather than risk an unhashable key."""

    def wrapper(*args):
        cache = _udf_cache()
        if cache is None or not all(
                isinstance(a, (str, int, float)) or a is None for a in args):
            return fn(*args)
        key = (name, *args)
        value = cache.get(key)
        if value is None:
            value = fn(*args)
            cache.put(key, value)
        return value

    return wrapper


# ---------------------------------------------------------------------------
# dialects
# ---------------------------------------------------------------------------

def _register_sqlite_udfs(conn) -> None:
    """The scalar builtins sqlite lacks + the whole UDF array extension —
    shared by the sqlite and array dialects.  All scalars: the scan
    reassembly is native string aggregation + the ``mrowcat`` scalar, so
    no Python aggregate exists anywhere (duckdb has no aggregate API —
    one registration surface serves both engines)."""
    conn.create_function("exp", 1, math.exp, deterministic=True)
    conn.create_function("greatest", 2, max, deterministic=True)
    for name, (nargs, fn) in ARRAY_UDFS.items():
        conn.create_function(name, nargs, _memoized(name, fn),
                             deterministic=True)


def _register_duckdb_udfs(conn) -> None:  # pragma: no cover - needs duckdb
    """Register the array extension on a duckdb connection.  duckdb's
    ``create_function`` needs explicit types for lambdas; aggregates have
    no Python API — which is why the scan reassembly renders as native
    ``group_concat`` + the ``mrowcat`` scalar, so the Recurrence (and
    MatRecurrence) CTEs execute on duckdb with no Python aggregate."""
    try:
        from duckdb.typing import DOUBLE, VARCHAR
        types = {"mscale": ([DOUBLE, VARCHAR], VARCHAR),
                 "mconst": ([DOUBLE, DOUBLE, DOUBLE], VARCHAR),
                 "mmean": ([VARCHAR], DOUBLE),
                 "mreduce": ([VARCHAR, VARCHAR, DOUBLE], VARCHAR),
                 "mtopk": ([VARCHAR, DOUBLE], VARCHAR),
                 "mscatter": ([VARCHAR, VARCHAR, DOUBLE], VARCHAR),
                 "mrowshift": ([VARCHAR, DOUBLE], VARCHAR),
                 "mrow": ([VARCHAR, DOUBLE], VARCHAR),
                 "mrecurstep": ([VARCHAR, VARCHAR, VARCHAR, DOUBLE, DOUBLE],
                                VARCHAR),
                 "mcellcat": ([VARCHAR, DOUBLE, DOUBLE], VARCHAR),
                 "mcell": ([VARCHAR, DOUBLE, DOUBLE], DOUBLE)}
    except ImportError:  # older duckdb
        types = {}
    for name, (nargs, fn) in ARRAY_UDFS.items():
        params, ret = types.get(name, ([VARCHAR] * nargs, VARCHAR)) \
            if types else (None, None)
        fn = _memoized(name, fn)
        try:
            if params is not None:
                conn.create_function(name, fn, params, ret)
            else:
                conn.create_function(name, fn)
        except Exception:
            continue  # register what we can; Listing 7 needs none


class Sql92Dialect:
    """The paper's SQL-92 as written in the listings (golden dialect)."""

    name = "sql92"
    #: which matrix representation the rendered SQL computes over:
    #: ``"relational"`` — one ``{[i, j, v]}`` tuple per cell (Listing 4);
    #: ``"array"`` — ONE row per matrix, an array-typed column (Listing 10)
    representation = "relational"
    #: whether constant matrices need the RECURSIVE keyword on the WITH
    series_is_recursive = False
    #: MatRecurrence rendering — ``"columns"``: the pure-SQL recursive CTE
    #: carrying the state row as D columns (golden, but its O(D²)
    #: coefficient references multiply under sqlite's substitution-based
    #: CTE expansion); ``"packed"``: children packed once into array
    #: codecs (``mcellcat``), stepped by ``mrecurstep`` — what the
    #: executable engines run (see ``core.sqlgen._mat_scan_ctes_packed``)
    mat_scan_rendering = "columns"
    #: how the engine expands multiply-referenced CTEs — ``"native"``:
    #: each CTE is evaluated once however often referenced (duckdb, and
    #: what SQL-92 text promises); ``"substitution"``: every textual
    #: reference re-executes the CTE body (sqlite).  Drives the default
    #: of ``SQLEngine(spool=...)``: under substitution, shared non-leaf
    #: nodes are materialised as temp tables before the main statement.
    cte_materialization = "native"

    # -- scalar rendering ---------------------------------------------------
    def map_sql(self, fn: E.MapFn, v: str) -> str:
        """Select-clause rendering of an elementwise function."""
        return fn.sql(v)

    def series_from(self, n: int, alias: str, col: str) -> str:
        """A from-clause term yielding the integers 1..n as column ``col``."""
        return (f"(select generate_series as {col}"
                f" from generate_series(1,{n})) {alias}")

    def const_select(self, rows: int, cols: int, value: float) -> str:
        """A constant matrix as the cross join of two series (Listing 5)."""
        return (f"select a.i, b.j, {value} as v\n"
                f"  from {self.series_from(rows, 'a', 'i')},\n"
                f"       {self.series_from(cols, 'b', 'j')}")

    def frame_from(self, rows: int, cols: int) -> str:
        """A from-clause term yielding the full (i, j) index frame — the
        outer-join skeleton that keeps Scatter/RowShift outputs dense.
        Explicit CROSS JOIN so a following LEFT JOIN's ON clause may
        reference both series (comma precedence differs across engines)."""
        return (f"{self.series_from(rows, 'a', 'i')} cross join\n"
                f"       {self.series_from(cols, 'b', 'j')}")

    def topk_mask_select(self, src: str, k: int) -> str:
        """The ArgTopK indicator: 1 where the cell ranks in its row's top
        ``k`` by value (ties toward the smaller j).  Strict SQL-92 has no
        window functions, so the rank is a correlated count — engines with
        windows override with ``row_number()``."""
        return (f"select m.i, m.j, case when (select count(*) from {src} n"
                f" where n.i = m.i and (n.v > m.v or (n.v = m.v and n.j < m.j))"
                f") < {k} then 1.0 else 0.0 end as v\n  from {src} as m")

    def topk_mask_select_b(self, src: str, k: int) -> str:
        """Batched ArgTopK indicator: the rank is per (request, row) — the
        correlated count additionally pins ``n.b = m.b`` so requests never
        see each other's values."""
        return (f"select m.b, m.i, m.j, case when (select count(*) from"
                f" {src} n where n.b = m.b and n.i = m.i and (n.v > m.v or"
                f" (n.v = m.v and n.j < m.j))) < {k} then 1.0 else 0.0 end"
                f" as v\n  from {src} as m")

    # -- connection preparation --------------------------------------------
    def prepare(self, conn) -> None:
        """Install anything the rendered SQL assumes (UDFs etc.)."""

    # -- capability flags ---------------------------------------------------
    #: can the engine run Listing 7 verbatim (recursive table in a nested
    #: WITH inside the recursive select)?
    supports_listing7 = True
    #: can the §5 array representation's UDF zoo run on this dialect's
    #: engines?  True wherever Python scalar functions register (sqlite,
    #: duckdb); False on server-side plpython-free backends (postgres),
    #: which must stay on the pure-SQL relational paths
    supports_array_udfs = True


def _windowed_topk_mask(src: str, k: int) -> str:
    """row_number() rendering of the ArgTopK indicator (sqlite ≥3.25 and
    duckdb both have window functions; the rank order matches the SQL-92
    correlated count and ``dense.topk_mask`` exactly)."""
    return (f"select q.i, q.j, case when q.rnk <= {k} then 1.0 else 0.0 end"
            f" as v\n  from (select i, j, v, row_number() over"
            f" (partition by i order by v desc, j asc) as rnk"
            f" from {src}) q")


def _windowed_topk_mask_b(src: str, k: int) -> str:
    """Batched twin of :func:`_windowed_topk_mask`: the window partitions
    by (b, i) so each request ranks its own rows."""
    return (f"select q.b, q.i, q.j, case when q.rnk <= {k} then 1.0 else"
            f" 0.0 end as v\n  from (select b, i, j, v, row_number() over"
            f" (partition by b, i order by v desc, j asc) as rnk"
            f" from {src}) q")


class SqliteDialect(Sql92Dialect):
    name = "sqlite"
    series_is_recursive = True
    supports_listing7 = False  # "circular reference" — see module docstring
    mat_scan_rendering = "packed"
    cte_materialization = "substitution"

    def series_from(self, n: int, alias: str, col: str) -> str:
        return (f"(with recursive s(x) as"
                f" (select 1 union all select x+1 from s where x < {n})"
                f" select x as {col} from s) {alias}")

    def topk_mask_select(self, src: str, k: int) -> str:
        return _windowed_topk_mask(src, k)

    def topk_mask_select_b(self, src: str, k: int) -> str:
        return _windowed_topk_mask_b(src, k)

    def prepare(self, conn) -> None:
        _register_sqlite_udfs(conn)


class DuckDBDialect(Sql92Dialect):
    name = "duckdb"
    mat_scan_rendering = "packed"

    def topk_mask_select(self, src: str, k: int) -> str:
        return _windowed_topk_mask(src, k)

    def topk_mask_select_b(self, src: str, k: int) -> str:
        return _windowed_topk_mask_b(src, k)

    def prepare(self, conn) -> None:  # pragma: no cover - needs the extra
        # generate_series / exp / greatest are native; the array UDFs back
        # the same Listing-10 rendering as sqlite (stock DuckDB has list
        # types but no matrix operators — the paper used a patched build).
        _register_duckdb_udfs(conn)


class PostgresDialect(Sql92Dialect):
    """Server-side postgres: the SQL-92 rendering runs nearly verbatim —
    ``generate_series`` / ``exp`` / ``greatest`` are native, window
    functions replace the correlated top-k count — and everything stays
    pure SQL (the server is plpython-free, so no UDF registration at all;
    ``supports_array_udfs = False`` keeps callers on the relational
    representation).  Listing 7 is off: postgres rejects the recursive
    self-reference inside a subquery of the recursive member ("recursive
    reference … must not appear within a subquery"), so training uses the
    stepped driver.  CTEs materialise natively (each evaluated once
    however often referenced — postgres ≥ 12 inlines single-reference
    CTEs and materialises shared ones)."""

    name = "postgres"
    supports_listing7 = False  # recursive ref inside a subquery is rejected
    supports_array_udfs = False

    def topk_mask_select(self, src: str, k: int) -> str:
        return _windowed_topk_mask(src, k)

    def topk_mask_select_b(self, src: str, k: int) -> str:
        return _windowed_topk_mask_b(src, k)


class ArrayDialect(Sql92Dialect):
    """The array-typed representation as a first-class dialect (paper §5,
    Listing 10): every matrix — leaf table, CTE, query result — is ONE row
    whose single column ``m`` holds the JSON array codec, and every IR node
    is a call into the UDF array extension instead of a join over cells.
    The scans (``Recurrence``/``MatRecurrence``) are the exception: they
    render as recursive CTEs whose state is one array-typed row per step
    (``mrow``/``mrecurstep``), the Listing-7 machinery at matrix
    granularity, reassembled by native string aggregation + the
    ``mrowcat`` scalar.

    The dialect rides an existing *engine* connection — sqlite by
    default, duckdb for the whole IR including the scans (nothing needs
    a Python aggregate) — pass ``SQLEngine(dialect="array")``.
    """

    name = "array"
    representation = "array"
    series_is_recursive = False   # constants are mconst() calls, no series
    supports_listing7 = False     # training runs the Listing-10 recursion
    cte_materialization = "substitution"  # rides a sqlite engine by default

    def prepare(self, conn) -> None:
        import sqlite3

        if isinstance(conn, sqlite3.Connection):
            _register_sqlite_udfs(conn)
        else:  # pragma: no cover - needs duckdb
            _register_duckdb_udfs(conn)


_DIALECTS = {"sql92": Sql92Dialect, "sqlite": SqliteDialect,
             "duckdb": DuckDBDialect, "postgres": PostgresDialect,
             "array": ArrayDialect}


def get_dialect(name) -> Sql92Dialect:
    """Dialect registry: by name, or pass through an instance."""
    if isinstance(name, Sql92Dialect):
        return name
    try:
        return _DIALECTS[name]()
    except KeyError:
        raise ValueError(f"unknown dialect {name!r}; "
                         f"have {sorted(_DIALECTS)}") from None
