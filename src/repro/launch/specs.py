"""ShapeDtypeStruct stand-ins for every model input (no device allocation).

``input_specs(cfg, shape)`` returns the batch spec for the shape's kind:
  train    {tokens|embeds, labels}          (global_batch, seq)
  prefill  {tokens|embeds}                  (global_batch, seq)
  decode   {tokens|embeds} one new token + KV cache of seq_len

Stub frontends ([audio]/[vlm]) provide precomputed frame/patch embeddings,
per the assignment.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, ShapeSpec
from repro.nn.model import LM

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ArchConfig, shape: ShapeSpec) -> dict:
    b = shape.global_batch
    s = shape.seq_len if shape.kind != "decode" else 1
    if cfg.stub_frontend:
        batch = {"embeds": SDS((b, s, cfg.d_model), jnp.float32)}
    else:
        batch = {"tokens": SDS((b, s), jnp.int32)}
    if shape.kind == "train":
        batch["labels"] = SDS((b, s), jnp.int32)
    return batch


def cache_specs(cfg: ArchConfig, shape: ShapeSpec):
    lm = LM(cfg)
    return jax.eval_shape(
        lambda: lm.init_cache(shape.global_batch, shape.seq_len))


def params_specs(cfg: ArchConfig):
    lm = LM(cfg)
    return jax.eval_shape(lambda: lm.init(jax.random.PRNGKey(0)))
