"""Back-compat shim — the adapter tier lives in :mod:`repro.db.adapters`.

Historical import sites (``from repro.db.adapter import connect``) keep
working; new code should import from ``repro.db.adapters`` directly, where
the contract (``adapters/base.py``) and the per-backend modules
(``sqlite`` / ``duckdb`` / ``postgres``) are split out."""
from __future__ import annotations

from .adapters import (CHUNK_ROWS, SLOW_QUERY_ENV, SQL_HEAD, Adapter,
                       ConnectionPool, DuckDBAdapter, HAVE_PSYCOPG2,
                       PG_DSN_ENV, PostgresAdapter, SQLiteAdapter,
                       _check_ident, connect, log)
from .adapters.base import (_CONN_SEQ, _GEN_LOCK, _IDENT, _TABLE_DIGEST,
                            _TABLE_GEN, _slow_threshold_s)

__all__ = [
    "Adapter", "SQLiteAdapter", "DuckDBAdapter", "PostgresAdapter",
    "HAVE_PSYCOPG2", "PG_DSN_ENV", "connect", "ConnectionPool",
    "CHUNK_ROWS", "SLOW_QUERY_ENV", "SQL_HEAD", "log",
]
