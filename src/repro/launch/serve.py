"""Production serving launcher (reduced configs runnable on CPU).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3_8b --reduced
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import get_config
from repro.nn.model import LM
from repro.serving import Request, ServingEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--slots", type=int, default=3)
    ap.add_argument("--max-new", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=64)
    args = ap.parse_args()

    cfg = get_config(args.arch, reduced=args.reduced)
    lm = LM(cfg)
    params = lm.init(jax.random.PRNGKey(0))
    eng = ServingEngine(lm, params, max_len=args.max_len,
                        batch_slots=args.slots)
    rng = np.random.RandomState(0)
    for uid in range(args.requests):
        eng.submit(Request(uid,
                           rng.randint(0, cfg.vocab,
                                       int(rng.randint(2, 8)))
                           .astype(np.int32),
                           max_new_tokens=args.max_new))
    t0 = time.perf_counter()
    done = eng.run_to_completion()
    dt = time.perf_counter() - t0
    total = sum(len(r.generated) for r in done)
    print(f"{cfg.name}: {len(done)} requests, {total} tokens, "
          f"{total / dt:.1f} tok/s")


if __name__ == "__main__":
    main()
