"""Data-parallel in-DB training: determinism, equivalence, plan reuse.

The contract under test (``db/shard.py``): ``train_in_db(shards=N)`` is a
drop-in for the unsharded run.  The gradient of the unreduced square loss
is a SUM over examples, so the SQL AllReduce's sum across shard gradient
relations reconstructs the full-batch update exactly — sharded vs
unsharded differs only in float summation order (≤ 1e-4 at benchmark
scale; at the scales here it is ≤ 1e-9), and a fixed partition
(``launch.mesh.shard_slices``) makes the sharded run itself bitwise
deterministic across repeats and shard counts.
"""
from __future__ import annotations

import numpy as np
import pytest

from repro.core import nn2sql
from repro.db.shard import (WEIGHT_NAMES, allreduce_statements,
                            train_in_db_sharded)
from repro.db.train import train_in_db
from repro.launch.mesh import AxisSpec, shard_slices

RNG = np.random.RandomState(11)


def _problem(n_rows=12, lr=0.05):
    spec = nn2sql.MLPSpec(n_rows=n_rows, n_features=6, n_hidden=5,
                          n_classes=3, lr=lr)
    g = nn2sql.build_graph(spec)
    w = {"w_xh": RNG.randn(6, 5) * 0.3, "w_ho": RNG.randn(5, 3) * 0.3}
    x = RNG.randn(n_rows, 6)
    y = np.eye(3)[RNG.randint(0, 3, n_rows)]
    return g, w, x, y


# ---------------------------------------------------------------------------
# the partition
# ---------------------------------------------------------------------------

class TestShardSlices:
    def test_balanced_contiguous_cover(self):
        sl = shard_slices(10, 4)
        assert [s.stop - s.start for s in sl] == [3, 3, 2, 2]
        assert sl[0].start == 0 and sl[-1].stop == 10
        for a, b in zip(sl, sl[1:]):
            assert a.stop == b.start

    def test_exact_division(self):
        assert shard_slices(8, 4) == [slice(0, 2), slice(2, 4),
                                      slice(4, 6), slice(6, 8)]

    def test_rejects_bad_counts(self):
        with pytest.raises(ValueError):
            shard_slices(4, 0)
        with pytest.raises(ValueError):
            shard_slices(3, 4)

    def test_axis_spec_validates(self):
        assert AxisSpec("data", 4).size == 4
        with pytest.raises(ValueError):
            AxisSpec("data", 0)


# ---------------------------------------------------------------------------
# the AllReduce SQL
# ---------------------------------------------------------------------------

class TestAllReduceSQL:
    def test_relational_groups_and_applies_sgd(self):
        stmts, read_back = allreduce_statements("relational", 0.05)
        reduce_stmt = stmts[1]
        assert "group by r, i, j" in reduce_stmt
        assert "sum(v)" in reduce_stmt
        assert "0.05" in reduce_stmt
        assert "select r, i, j, v from shard_w" == read_back

    def test_array_reduces_with_msum(self):
        stmts, read_back = allreduce_statements("array", 0.05)
        reduce_stmt = stmts[1]
        assert "msum(group_concat(m, '|'))" in reduce_stmt
        assert "madd" in reduce_stmt and "mscale" in reduce_stmt
        assert read_back == "select r, m from shard_w"


# ---------------------------------------------------------------------------
# determinism + equivalence
# ---------------------------------------------------------------------------

class TestShardedTraining:
    def test_shards_1_equals_shards_4(self):
        """The fixed partition order makes shard counts interchangeable to
        float-summation noise (≤ 1e-9 at this scale)."""
        g, w, x, y = _problem()
        r1 = train_in_db_sharded(g, w, x, y, 3, shards=1,
                                 plan_cache_=False)
        r4 = train_in_db_sharded(g, w, x, y, 3, shards=4,
                                 plan_cache_=False)
        for k in WEIGHT_NAMES:
            np.testing.assert_allclose(r4.weights[k], r1.weights[k],
                                       atol=1e-9)

    def test_repeat_runs_are_bitwise_identical(self):
        g, w, x, y = _problem()
        a = train_in_db_sharded(g, w, x, y, 2, shards=3, plan_cache_=False)
        b = train_in_db_sharded(g, w, x, y, 2, shards=3, plan_cache_=False)
        for k in WEIGHT_NAMES:
            assert np.array_equal(a.weights[k], b.weights[k])

    def test_sharded_matches_unsharded(self):
        """The ISSUE acceptance bound: shards=4 ≡ the unsharded stepped
        run ≤ 1e-4 (here ≤ 1e-9 — only summation order differs)."""
        g, w, x, y = _problem()
        ref = train_in_db(g, w, x, y, 3, strategy="stepped",
                          plan_cache_=False)
        got = train_in_db(g, w, x, y, 3, shards=4, plan_cache_=False)
        assert got.strategy == "sharded"
        assert got.n_iters == 3
        assert len(got.history) == len(ref.history)
        for k in WEIGHT_NAMES:
            np.testing.assert_allclose(got.weights[k], ref.weights[k],
                                       atol=1e-9)

    def test_uneven_partition_matches_unsharded(self):
        g, w, x, y = _problem(n_rows=11)
        ref = train_in_db(g, w, x, y, 2, strategy="stepped",
                          plan_cache_=False)
        got = train_in_db_sharded(g, w, x, y, 2, shards=3,
                                  plan_cache_=False)
        for k in WEIGHT_NAMES:
            np.testing.assert_allclose(got.weights[k], ref.weights[k],
                                       atol=1e-9)

    def test_array_representation_matches_relational(self):
        g, w, x, y = _problem()
        rel = train_in_db_sharded(g, w, x, y, 2, shards=2,
                                  representation="relational",
                                  plan_cache_=False)
        arr = train_in_db_sharded(g, w, x, y, 2, shards=2,
                                  representation="array",
                                  plan_cache_=False)
        for k in WEIGHT_NAMES:
            np.testing.assert_allclose(arr.weights[k], rel.weights[k],
                                       atol=1e-9)

    def test_traffic_accounted(self):
        g, w, x, y = _problem()
        res = train_in_db_sharded(g, w, x, y, 2, shards=2,
                                  plan_cache_=False)
        # 2 iterations × 2 shards × (30 + 15) gradient cells, 5 values/row
        assert res.cte_bytes == 2 * 2 * (6 * 5 + 5 * 3) * 5 * 8
        assert "group by r, i, j" in res.sql

    def test_guard_rails(self):
        g, w, x, y = _problem()
        with pytest.raises(ValueError):
            train_in_db_sharded(g, w, x, y, 1, shards=0)
        with pytest.raises(ValueError):
            train_in_db(g, w, x, y, 1, shards=2, strategy="stepped")
        from repro.db import connect
        ad = connect("sqlite")
        try:
            with pytest.raises(ValueError):
                train_in_db(g, w, x, y, 1, shards=2, adapter=ad)
        finally:
            ad.close()


# ---------------------------------------------------------------------------
# plan-cache behaviour: shard count NEVER enters the key
# ---------------------------------------------------------------------------

class TestShardPlanCache:
    def test_equal_shards_share_one_plan(self, tmp_path):
        """4 equal shards render ONE plan (3-row per-shard graph): one
        miss, every other shard (and every later iteration) hits."""
        from repro.db.plan_cache import PlanCache
        cache = PlanCache(path=str(tmp_path / "plans.db"))
        g, w, x, y = _problem(n_rows=12)
        train_in_db_sharded(g, w, x, y, 2, shards=4, plan_cache_=cache)
        assert cache.misses == 1
        assert cache.hits >= 3

    def test_shard_count_not_in_key(self, tmp_path):
        """shards=2 on 12 rows and shards=4 on 24 rows both run 6-row
        shard plans — the second training run must be all cache hits."""
        from repro.db.plan_cache import PlanCache
        cache = PlanCache(path=str(tmp_path / "plans.db"))
        g, w, x, y = _problem(n_rows=12)
        train_in_db_sharded(g, w, x, y, 1, shards=2, plan_cache_=cache)
        misses_after_first = cache.misses
        g24, _, _, _ = _problem(n_rows=24)
        x24 = RNG.randn(24, 6)
        y24 = np.eye(3)[RNG.randint(0, 3, 24)]
        train_in_db_sharded(g24, w, x24, y24, 1, shards=4,
                            plan_cache_=cache)
        assert cache.misses == misses_after_first
