"""Production mesh construction + the shared data-parallel axis spec.

Mesh builders are functions (not module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before first init.

:class:`AxisSpec` / :func:`shard_slices` are the mesh-tier language the DB
shard tier reuses: ``db/shard.py`` mirrors the ``data`` axis across N
database connections with exactly the partitioning a jax mesh would apply
along its data axis, so a model trained in-DB with ``shards=N`` sees the
same per-shard batches as its dense data-parallel twin.
"""
from __future__ import annotations

import dataclasses
import inspect

import jax


@dataclasses.dataclass(frozen=True)
class AxisSpec:
    """One named parallel axis — the piece of a mesh both tiers agree on."""

    name: str
    size: int

    def __post_init__(self):
        if self.size < 1:
            raise ValueError(f"axis {self.name!r} needs size >= 1, "
                             f"got {self.size}")


def data_axis_spec(mesh) -> AxisSpec:
    """The mesh's data-parallel axis as a spec (pod × data collapsed)."""
    return AxisSpec("data", axis_size(mesh, data_axes(mesh)))


def shard_slices(n_rows: int, n_shards: int) -> list[slice]:
    """Deterministic contiguous partition of ``n_rows`` batch rows across
    ``n_shards``: shard k takes the k-th contiguous block, blocks differ
    by at most one row (the first ``n_rows % n_shards`` shards carry the
    extra).  Fixed order is load-bearing — the shard trainer's AllReduce
    and its determinism guarantee (shards=1 ≡ shards=N) both assume shard
    k always sees the same rows."""
    if n_shards < 1:
        raise ValueError(f"need n_shards >= 1, got {n_shards}")
    if n_rows < n_shards:
        raise ValueError(
            f"cannot partition {n_rows} rows across {n_shards} shards "
            f"(every shard needs at least one row)")
    base, extra = divmod(n_rows, n_shards)
    out, start = [], 0
    for k in range(n_shards):
        stop = start + base + (1 if k < extra else 0)
        out.append(slice(start, stop))
        start = stop
    return out

try:  # jax ≥ 0.5: explicit axis types
    from jax.sharding import AxisType
except ImportError:  # jax 0.4.x has neither AxisType nor the kwarg
    AxisType = None

_MAKE_MESH_HAS_AXIS_TYPES = (
    AxisType is not None
    and "axis_types" in inspect.signature(jax.make_mesh).parameters)


def abstract_mesh(shape, axis_names):
    """``jax.sharding.AbstractMesh`` across jax versions: ≥0.5 takes
    ``(shape, axis_names)``; 0.4.x takes a tuple of (name, size) pairs."""
    from jax.sharding import AbstractMesh

    try:
        return AbstractMesh(shape, axis_names)
    except TypeError:  # 0.4.x shape_tuple signature
        return AbstractMesh(tuple(zip(axis_names, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    """Single pod: (16, 16) = (data, model) — 256 chips (v5e pod).
    Multi-pod: (2, 16, 16) = (pod, data, model) — 512 chips across DCI."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    if _MAKE_MESH_HAS_AXIS_TYPES:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)  # Auto is the 0.4.x default


def data_axes(mesh) -> tuple[str, ...]:
    """The data-parallel axes: ('pod', 'data') multi-pod, ('data',) single."""
    return tuple(n for n in mesh.axis_names if n in ("pod", "data"))


def axis_size(mesh, names) -> int:
    if isinstance(names, str):
        names = (names,)
    size = 1
    for n in names:
        size *= mesh.shape[n]
    return size
