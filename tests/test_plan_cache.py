"""The persistent rendered-SQL plan cache (repro.db.plan_cache) and the
deterministic rendering it depends on (sqlgen.assign_names/dag_signature).

The differential guarantee: results served through a warm cache — including
one persisted by a *different* "session" (a different DAG build with a
different name-counter state) — still match Engine("dense") ≤1e-4.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Engine, nn2sql, sqlgen
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db.plan_cache import PlanCache, default_path
from repro.db.sql_engine import SQLEngine
from repro.db.train import train_in_db

RNG = np.random.RandomState(3)
TOL = 1e-4


def grad_roots():
    """A loss + gradients DAG full of auto-named nodes (the hard case)."""
    g = nn2sql.build_graph(nn2sql.MLPSpec(8, 4, 3, 2, lr=0.05))
    grads = gradients(g.loss, [g.w_xh, g.w_ho])
    return g, [g.loss, grads[g.w_xh], grads[g.w_ho]]


def fresh_structural_twin():
    """Structurally identical DAG built from scratch (new counter state)."""
    for _ in range(7):   # shift the global name counter
        E.const(0.0, (1, 1))
    img = E.var("img", (8, 4))
    one_hot = E.var("one_hot", (8, 2))
    w_xh = E.var("w_xh", (4, 3))
    w_ho = E.var("w_ho", (3, 2))
    a_xh = E.sigmoid(E.matmul(img, w_xh, name="z_xh"), name="a_xh")
    a_ho = E.sigmoid(E.matmul(a_xh, w_ho, name="z_ho"), name="a_ho")
    loss = E.square(E.sub(a_ho, one_hot, name="diff"), name="loss")
    grads = gradients(loss, [w_xh, w_ho])
    return [loss, grads[w_xh], grads[w_ho]]


class TestSignature:
    def test_structural_twins_share_signature_and_sql(self):
        _, roots = grad_roots()
        twins = fresh_structural_twin()
        assert sqlgen.dag_signature(roots) == sqlgen.dag_signature(twins)
        s1 = sqlgen.to_sql92(roots, select=sqlgen.multi_root_select(roots),
                             dialect="sqlite")
        s2 = sqlgen.to_sql92(twins, select=sqlgen.multi_root_select(twins),
                            dialect="sqlite")
        assert s1 == s2

    def test_signature_separates_structure_and_extras(self):
        a, b = E.var("a", (2, 3)), E.var("b", (3, 2))
        mm = [E.matmul(a, b)]
        assert sqlgen.dag_signature(mm) != sqlgen.dag_signature(
            [E.matmul(a, b), E.transpose(a)])
        assert sqlgen.dag_signature(mm) \
            != sqlgen.dag_signature([E.matmul(E.var("a", (2, 4)),
                                              E.var("b", (4, 2)))])
        assert sqlgen.dag_signature(mm, extra=("sqlite",)) \
            != sqlgen.dag_signature(mm, extra=("duckdb",))
        # explicit names are semantic (they name result tables/CTEs)
        assert sqlgen.dag_signature([E.matmul(a, b, name="p")]) \
            != sqlgen.dag_signature([E.matmul(a, b, name="q")])

    def test_auto_names_do_not_leak_into_signature(self):
        a, b = E.var("a", (2, 3)), E.var("b", (3, 2))
        assert sqlgen.dag_signature([E.matmul(a, b)]) \
            == sqlgen.dag_signature([E.matmul(a, b)])

    def test_assign_names_keeps_explicit_and_avoids_collisions(self):
        a = E.var("mm_c0", (2, 2))          # explicit name shaped like a
        m = E.matmul(a, a)                  # canonical candidate
        nm = sqlgen.assign_names(E.topo_order(m))
        assert nm[id(a)] == "mm_c0"
        assert nm[id(m)] != "mm_c0" and nm[id(m)].startswith("mm_c")

    def test_zoo_static_attributes_in_signature(self):
        """Plan-cache staleness regression: a zoo op's static attributes
        (k, reduce kind, axis, shift offset, scan direction) are part of
        the rendered SQL, so DAGs differing only there must not share a
        signature — or a cached plan."""
        x = E.var("x", (4, 4))
        idx = E.var("idx", (4, 1))
        a, b = E.var("a", (4, 4)), E.var("b", (4, 4))
        sig = lambda *roots: sqlgen.dag_signature(list(roots))
        assert sig(E.argtopk(x, 2)) != sig(E.argtopk(x, 3))
        assert sig(E.row_reduce(x, "sum")) != sig(E.row_reduce(x, "max"))
        assert sig(E.row_reduce(x, "sum", 1)) != sig(E.row_reduce(x, "sum", 0))
        assert sig(E.row_shift(x, 1)) != sig(E.row_shift(x, -1))
        assert sig(E.recurrence(a, b)) != sig(E.recurrence(a, b,
                                                           reverse=True))
        # same-structure twins DO share (the cache hit still works)
        assert sig(E.argtopk(x, 2)) == sig(E.argtopk(x, 2))
        assert sig(E.gather(x, idx)) == sig(E.gather(x, idx))

    def test_two_topk_dags_do_not_share_cached_plan(self, tmp_path):
        """End to end: render k=2 through a cache, then ask for k=3 — the
        cache must miss and the two plans must differ (before the
        signature fix both DAGs hashed identically and k=3 silently
        executed the k=2 plan)."""
        from repro.db.sql_engine import SQLEngine

        pc = PlanCache(path=str(tmp_path / "plans.db"))
        d = SQLEngine(plan_cache_=False).dialect
        x = E.var("x", (4, 4))
        sql2 = pc.dag_sql([E.argtopk(x, 2)], d, tail="multi_root")
        misses = pc.misses
        sql3 = pc.dag_sql([E.argtopk(x, 3)], d, tail="multi_root")
        assert pc.misses == misses + 1      # k=3 is a distinct plan
        assert sql2 != sql3
        np_x = np.arange(16, dtype=np.float64).reshape(4, 4)
        eng2 = SQLEngine(plan_cache_=pc)
        out2, = eng2.evaluate([E.argtopk(x, 2)], {"x": np_x})
        out3, = eng2.evaluate([E.argtopk(x, 3)], {"x": np_x})
        assert out2.sum() == 2 * 4 and out3.sum() == 3 * 4

    def test_representation_keys_distinct_plans(self, tmp_path):
        """Representation-staleness regression: the same DAG rendered under
        the cell-relational ``sqlite`` dialect and the ``array`` dialect
        must occupy distinct cache entries — a warm hit may never hand an
        array-representation engine a relational plan (or vice versa)."""
        from repro.db.dialect import get_dialect

        pc = PlanCache(path=str(tmp_path / "plans.db"))
        a, b = E.var("a", (3, 4)), E.var("b", (4, 2))
        roots = [E.matmul(a, b)]
        d_rel, d_arr = get_dialect("sqlite"), get_dialect("array")
        sql_rel = pc.dag_sql(roots, d_rel, tail="multi_root")
        misses = pc.misses
        sql_arr = pc.dag_sql(roots, d_arr, tail="multi_root")
        assert pc.misses == misses + 1          # distinct entry, no cross-hit
        assert sql_rel != sql_arr
        assert "sum(m.v*n.v)" in sql_rel and "mm(" not in sql_rel
        assert "mm(" in sql_arr and "sum(m.v*n.v)" not in sql_arr
        # warm re-requests stay within their representation
        hits = pc.hits
        assert pc.dag_sql(roots, d_rel, tail="multi_root") == sql_rel
        assert pc.dag_sql(roots, d_arr, tail="multi_root") == sql_arr
        assert pc.hits == hits + 2 and pc.misses == misses + 1

    def test_engines_sharing_cache_never_cross_representations(self,
                                                               tmp_path):
        """End to end: a relational and an array engine over ONE warm store
        both execute correctly — each representation's plan round-trips
        through its own entry."""
        pc = PlanCache(path=str(tmp_path / "plans.db"))
        a, b = E.var("a", (3, 4)), E.var("b", (4, 2))
        roots = [E.matmul(a, b)]
        env = {"a": RNG.randn(3, 4), "b": RNG.randn(4, 2)}
        want = env["a"] @ env["b"]
        out_rel, = SQLEngine(plan_cache_=pc).evaluate(roots, env)
        out_arr, = SQLEngine(dialect="array",
                             plan_cache_=pc).evaluate(roots, env)
        # a second pair over the same store: pure hits, same results
        before = pc.misses
        out_rel2, = SQLEngine(plan_cache_=pc).evaluate(roots, env)
        out_arr2, = SQLEngine(dialect="array",
                              plan_cache_=pc).evaluate(roots, env)
        assert pc.misses == before
        for out in (out_rel, out_arr, out_rel2, out_arr2):
            np.testing.assert_allclose(out, want, atol=TOL)


class TestPlanCacheStore:
    def test_memory_roundtrip_and_stats(self):
        pc = PlanCache(path=None)
        assert pc.get("k") is None
        pc.put("k", "select 1;")
        assert pc.get("k") == "select 1;"
        assert pc.stats["hits"] == 1 and pc.stats["misses"] == 1
        assert len(pc) == 1
        pc.clear()
        assert pc.get("k") is None

    def test_persistent_across_instances(self, tmp_path):
        p = str(tmp_path / "plans.db")
        pc1 = PlanCache(path=p)
        pc1.put("k", "select 42;", dialect="sqlite")
        pc1.close()
        pc2 = PlanCache(path=p)     # a new "session"
        assert pc2.get("k") == "select 42;"
        assert pc2.stats["entries"] == 1
        pc2.close()

    def test_default_path_env_off(self, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_CACHE", "off")
        assert default_path() is None
        monkeypatch.setenv("REPRO_PLAN_CACHE", "/tmp/x.db")
        assert default_path() == "/tmp/x.db"

    def test_renderer_fingerprint_part_of_key(self, monkeypatch):
        """A plan must not outlive the transpiler that rendered it: the
        sqlgen source fingerprint is folded into every key."""
        from repro.db import plan_cache as pc
        _, roots = grad_roots()
        k1 = pc.plan_key(roots, extra=("sqlite",))
        monkeypatch.setattr(pc, "_FINGERPRINT", "0123456789abcdef")
        k2 = pc.plan_key(roots, extra=("sqlite",))
        assert k1 != k2

    def test_train_in_db_cache_opt_out(self):
        """plan_cache_=False renders fresh — no default-cache traffic."""
        from repro.db import plan_cache as pc
        g = nn2sql.build_graph(nn2sql.MLPSpec(5, 4, 3, 2, lr=0.05))
        w0 = {k: np.asarray(v)
              for k, v in nn2sql.init_weights(g.spec).items()}
        x = RNG.rand(5, 4).astype(np.float32)
        y = np.eye(2, dtype=np.float32)[RNG.randint(0, 2, 5)]
        cache = pc.default_cache()
        h0, m0 = cache.hits, cache.misses
        train_in_db(g, w0, x, y, 1, plan_cache_=False)
        assert (cache.hits, cache.misses) == (h0, m0)

    def test_dag_sql_caches_render(self, tmp_path):
        pc = PlanCache(path=str(tmp_path / "plans.db"))
        _, roots = grad_roots()
        d = Engine("sql")._sql.dialect
        s1 = pc.dag_sql(roots, d, tail="multi_root")
        s2 = pc.dag_sql(roots, d, tail="multi_root")
        assert s1 == s2 and pc.hits == 1 and pc.misses == 1
        assert pc.dag_sql(roots, d, tail="last") != s1  # tail kind keyed
        with pytest.raises(ValueError):
            pc.dag_sql(roots, d, tail="sideways")


class TestLRUCap:
    """The eviction satellite: an uncapped cache grows without bound under
    topology-churning workloads (per-(T, D) scan plans).  Both layers hold
    an LRU cap; the hottest keys survive insert pressure."""

    def test_mem_layer_holds_cap_and_keeps_hot_keys(self):
        cap = 8
        pc = PlanCache(path=None, cap=cap)
        for k in range(cap):
            pc.put(f"k{k}", f"select {k};")
        hot = ["k0", "k1", "k2"]
        for k in hot:                     # touch → most-recently-used
            assert pc.get(k) is not None
        for k in range(cap, cap + 5):     # 5 over cap: evict 5 coldest
            pc.put(f"k{k}", f"select {k};")
        assert len(pc) == cap
        for k in hot:
            assert pc.get(k) == f"select {k[1:]};", f"hot {k} evicted"
        # k3..k7 were the least recently used — all gone
        assert all(pc.get(f"k{k}") is None for k in range(3, 8))

    def test_persistent_layer_pruned_on_insert(self, tmp_path):
        p = str(tmp_path / "plans.db")
        cap = 6
        pc = PlanCache(path=p, cap=cap)
        for k in range(cap + 10):
            pc.put(f"k{k}", "select 1;")
        assert len(pc) == cap             # len counts the persistent table
        pc.close()
        pc2 = PlanCache(path=p, cap=cap)  # a later session sees cap entries
        assert pc2.stats["entries"] == cap
        assert pc2.get(f"k{cap + 9}") is not None   # newest survived
        assert pc2.get("k0") is None                # oldest pruned
        pc2.close()

    def test_hot_key_survives_persistent_pruning(self, tmp_path):
        p = str(tmp_path / "plans.db")
        cap = 4
        pc = PlanCache(path=p, cap=cap)
        pc.put("hot", "select 'hot';")
        for k in range(cap + 6):          # keep touching the hot key
            pc.put(f"k{k}", "select 1;")
            assert pc.get("hot") is not None
        pc.close()
        pc2 = PlanCache(path=p, cap=cap)
        assert pc2.get("hot") == "select 'hot';"
        pc2.close()

    def test_disk_loaded_hit_updates_recency_before_prune(self, tmp_path):
        """Regression: a hit served from the persistent layer by a FRESH
        process (nothing in the memory layer yet) must count as a use —
        the next at-cap insert prunes by ``last_used``, and a disk-loaded
        hot key must outlive entries that were merely written later."""
        p = str(tmp_path / "plans.db")
        cap = 2
        pc = PlanCache(path=p, cap=cap)
        pc.put("a", "select 'a';")
        pc.put("b", "select 'b';")        # disk: a (colder), b (warmer)
        pc.close()
        pc2 = PlanCache(path=p, cap=cap)  # fresh session, empty mem layer
        assert pc2.get("a") == "select 'a';"   # disk hit → a is now hottest
        pc2.put("c", "select 'c';")       # at cap: prune must drop b, not a
        pc2.close()
        pc3 = PlanCache(path=p, cap=cap)
        assert pc3.get("a") == "select 'a';"
        assert pc3.get("c") == "select 'c';"
        assert pc3.get("b") is None
        pc3.close()

    def test_cap_env_override_and_default(self, monkeypatch):
        assert PlanCache(path=None).cap == 512
        monkeypatch.setenv("REPRO_PLAN_CACHE_CAP", "17")
        assert PlanCache(path=None).cap == 17
        assert PlanCache(path=None, cap=3).cap == 3   # arg beats env
        # cache trouble never breaks the backend — malformed env included
        monkeypatch.setenv("REPRO_PLAN_CACHE_CAP", "lots")
        assert PlanCache(path=None).cap == 512

    def test_memory_only_mode_does_not_accumulate_touches(self):
        """Regression: with no persistent store there is no flush, so hit
        keys must not pile up in the pending-touch set forever."""
        pc = PlanCache(path=None, cap=2)
        for k in range(50):
            pc.put(f"k{k}", "select 1;")
            pc.get(f"k{k}")
        assert len(pc._touched) == 0 and len(pc) == 2

    def test_new_plan_survives_prune_when_working_set_is_hot(self, tmp_path):
        """Regression: put() must stamp the insert AFTER flushing hit
        recency — at cap with every resident key just hit, the new plan
        itself would otherwise be the prune victim (and every future
        session would re-render it)."""
        p = str(tmp_path / "plans.db")
        pc = PlanCache(path=p, cap=2)
        pc.put("k0", "select 0;")
        pc.put("k1", "select 1;")
        assert pc.get("k0") and pc.get("k1")    # whole store hot
        pc.put("k2", "select 2;")
        pc.close()
        pc2 = PlanCache(path=p, cap=2)
        assert pc2.get("k2") == "select 2;"     # newest survived the prune
        pc2.close()

    def test_pre_lru_store_migrates_in_place(self, tmp_path):
        """Stores persisted before the cap (no last_used column) open
        cleanly and keep serving their plans."""
        import sqlite3 as sq
        p = str(tmp_path / "plans.db")
        conn = sq.connect(p)
        conn.execute("create table plans (key text primary key,"
                     " dialect text, sql text, created real)")
        conn.execute("insert into plans values ('old', 'sqlite',"
                     " 'select 9;', 1.0)")
        conn.commit()
        conn.close()
        pc = PlanCache(path=p, cap=4)
        assert pc.get("old") == "select 9;"
        pc.put("new", "select 10;")
        assert len(pc) == 2
        pc.close()

    def test_capped_engine_stays_correct_under_churn(self, tmp_path):
        """End to end under the new scan workload: more distinct scan
        topologies than the cap, every result still ≤1e-4 vs dense."""
        pc = PlanCache(path=str(tmp_path / "plans.db"), cap=3)
        eng = SQLEngine(plan_cache_=pc)
        rng = np.random.RandomState(0)
        for t in range(2, 8):             # 6 distinct Recurrence shapes
            a, b = E.var("a", (t, 2)), E.var("b", (t, 2))
            env = {"a": rng.rand(t, 2) * 0.5, "b": rng.randn(t, 2)}
            out, = eng.evaluate([E.recurrence(a, b)], env)
            s = np.zeros(2)
            for i in range(t):
                s = env["a"][i] * s + env["b"][i]
            np.testing.assert_allclose(out[-1], s, atol=TOL)
        assert len(pc) == 3
        eng.close()


class TestCachedDifferential:
    def env(self, g):
        w0 = {k: np.asarray(v) for k, v in nn2sql.init_weights(g.spec).items()}
        x = RNG.rand(g.spec.n_rows, g.spec.n_features).astype(np.float32)
        y = np.eye(g.spec.n_classes,
                   dtype=np.float32)[RNG.randint(0, g.spec.n_classes,
                                                 g.spec.n_rows)]
        return {**w0, "img": x, "one_hot": y}

    def test_warm_cache_results_match_dense(self, tmp_path):
        g, roots = grad_roots()
        env = self.env(g)
        jenv = {k: jnp.asarray(v) for k, v in env.items()}
        ref = [np.asarray(o) for o in Engine("dense").eval_fn(roots)(jenv)]
        pc = PlanCache(path=str(tmp_path / "plans.db"))
        cold = SQLEngine(plan_cache_=pc)
        outs_cold = cold.evaluate(roots, env)
        assert pc.misses >= 1
        # a second engine over the same store: rendering fully cached
        warm = SQLEngine(plan_cache_=pc)
        before = pc.misses
        outs_warm = warm.evaluate(roots, env)
        assert pc.misses == before and pc.hits >= 1
        for a, b, r in zip(outs_cold, outs_warm, ref):
            np.testing.assert_array_equal(a, b)
            np.testing.assert_allclose(a, r, atol=TOL)

    def test_cross_session_plan_executes_identically(self, tmp_path):
        """A plan persisted under one DAG build must be byte-valid for a
        structural twin built in a 'later session'."""
        g, roots = grad_roots()
        env = self.env(g)
        pc = PlanCache(path=str(tmp_path / "plans.db"))
        outs1 = SQLEngine(plan_cache_=pc).evaluate(roots, env)
        twins = fresh_structural_twin()
        warm = SQLEngine(plan_cache_=pc)
        before = pc.misses
        outs2 = warm.evaluate(twins, env)
        assert pc.misses == before   # pure hit
        for a, b in zip(outs1, outs2):
            np.testing.assert_array_equal(a, b)

    def test_disabled_cache_still_correct(self):
        g, roots = grad_roots()
        env = self.env(g)
        eng = SQLEngine(plan_cache_=False)
        assert eng.plans is None
        outs = eng.evaluate(roots, env)
        ref = SQLEngine(plan_cache_=PlanCache(path=None)).evaluate(roots, env)
        for a, b in zip(outs, ref):
            np.testing.assert_array_equal(a, b)

    def test_unchanged_leaves_not_rewritten(self):
        g, roots = grad_roots()
        env = self.env(g)
        eng = SQLEngine(plan_cache_=PlanCache(path=None))
        fn = eng.eval_fn(roots)
        fn(env)
        writes = []
        orig = eng.adapter.insert_columns
        eng.adapter.insert_columns = (
            lambda name, cols: (writes.append(name), orig(name, cols)))
        orig_upd = eng.adapter.update_cells
        eng.adapter.update_cells = (
            lambda name, *a, **k: (writes.append(name),
                                   orig_upd(name, *a, **k)))
        fn(env)                      # identical env — no table rewritten
        assert writes == []
        env2 = dict(env, w_xh=env["w_xh"] + 1.0)
        fn(env2)                     # only the changed leaf is touched —
        assert writes == ["w_xh"]    # via bound-parameter deltas or rewrite

    def test_train_in_db_rendering_cached(self, tmp_path, monkeypatch):
        monkeypatch.setenv("REPRO_PLAN_CACHE",
                           str(tmp_path / "train_plans.db"))
        from repro.db import plan_cache as pc_mod
        monkeypatch.setattr(pc_mod, "_default", None)   # fresh singleton
        g = nn2sql.build_graph(nn2sql.MLPSpec(6, 4, 3, 2, lr=0.05))
        w0 = {k: np.asarray(v) for k, v in nn2sql.init_weights(g.spec).items()}
        env = self.env(g)
        r1 = train_in_db(g, w0, env["img"], env["one_hot"], 2)
        cache = pc_mod.default_cache()
        miss0 = cache.misses
        r2 = train_in_db(g, w0, env["img"], env["one_hot"], 2)
        assert cache.misses == miss0 and cache.hits >= 1
        assert r1.sql == r2.sql
        for k in ("w_xh", "w_ho"):
            np.testing.assert_array_equal(r1.weights[k], r2.weights[k])
        monkeypatch.setattr(pc_mod, "_default", None)   # don't leak singleton


class TestConcurrency:
    def test_hammer_pooled_workers(self, tmp_path):
        """N threads × hot/cold keys against one capped store: exact
        hit+miss accounting, no exceptions, no lost hot plans, and both
        layers end at/below the cap — the eviction-vs-disk-hit and
        double-insert races the lock closes."""
        import threading

        cache = PlanCache(path=str(tmp_path / "hammer.db"), cap=8)
        rounds, workers = 60, 6
        errs = []

        def work(wid):
            try:
                for k in range(rounds):
                    key = f"k{(wid * rounds + k) % 24}"
                    sql = cache.get(key)
                    if sql is None:
                        cache.put(key, f"select {key}")
                    cache.rendered(f"hot{k % 2}", "sqlite",
                                   lambda: "select 1")
            except Exception as exc:  # pragma: no cover - the bug
                errs.append(exc)

        ts = [threading.Thread(target=work, args=(w,))
              for w in range(workers)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        # every get() and every rendered() accounted exactly once
        assert cache.hits + cache.misses == workers * rounds * 2
        assert len(cache) <= cache.cap and len(cache._mem) <= cache.cap
        # the hot keys must have survived the churn
        assert cache.get("hot0") == "select 1"
        cache.close()

    def test_rendered_single_render_per_key(self):
        """Concurrent misses on one key render once — the second worker
        hits the first one's insert instead of double-rendering."""
        import threading

        cache = PlanCache(path=None)
        calls = []

        def render():
            calls.append(1)
            return "select 42"

        barrier = threading.Barrier(4)

        def work():
            barrier.wait()
            assert cache.rendered("the-key", "sqlite", render) == "select 42"

        ts = [threading.Thread(target=work) for _ in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert len(calls) == 1
        assert cache.hits == 3 and cache.misses == 1
