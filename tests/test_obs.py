"""The observability subsystem: spans, exporters, counters, slow-query log.

Covers the ISSUE-6 satellite checklist: span nesting/ordering, the no-op
overhead guard (< 2% of a warm ``SQLEngine.evaluate``), a Chrome-trace
export golden (deterministic via an injected clock), the ``trace_spans``
relation round-trip on sqlite (and duckdb where installed), the
``REPRO_SLOW_QUERY_MS`` logging knob, plan-cache eviction counters, the
merged ``SQLEngine.stats`` view, and EXPLAIN capture per cached plan.

Regenerate the golden after an INTENTIONAL exporter change with:

    REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_obs.py
"""
import json
import logging
import os
import pathlib
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import expr as E
from repro.db.plan_cache import PlanCache
from repro.db.sql_engine import SQLEngine

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
UPDATE = os.environ.get("REPRO_UPDATE_GOLDEN", "") not in ("", "0")


def small_dag():
    a = E.var("a", (3, 4))
    b = E.var("b", (4, 2))
    return E.matmul(a, b, name="c"), {
        "a": np.arange(12.0).reshape(3, 4), "b": np.ones((4, 2))}


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_order_and_paths():
    tr = obs.Tracer()
    with tr.span("outer", k=1):
        with tr.span("mid"):
            with tr.span("inner"):
                pass
        with tr.span("mid2"):
            pass
    names = [s.name for s in tr.spans]          # completion order
    assert names == ["inner", "mid", "mid2", "outer"]
    paths = {s.name: s.path for s in tr.spans}
    assert paths["inner"] == "outer/mid/inner"
    assert paths["mid2"] == "outer/mid2"
    by_name = {s.name: s for s in tr.spans}
    assert by_name["inner"].parent_id == by_name["mid"].span_id
    assert by_name["mid"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].parent_id is None
    assert by_name["outer"].attrs == {"k": 1}
    # children are contained in the parent interval
    assert by_name["outer"].t0 <= by_name["inner"].t0
    assert by_name["inner"].t1 <= by_name["outer"].t1


def test_span_set_and_duration():
    tr = obs.Tracer()
    with tr.span("s") as sp:
        sp.set(rows=7)
    assert tr.spans[0].attrs["rows"] == 7
    assert tr.spans[0].duration >= 0.0


def test_thread_safety_per_thread_stacks():
    tr = obs.Tracer()
    barrier = threading.Barrier(2)

    def work(tag):
        with tr.span(f"root-{tag}"):
            barrier.wait()
            with tr.span(f"child-{tag}"):
                pass

    ts = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(tr.spans) == 4
    by_name = {s.name: s for s in tr.spans}
    for i in range(2):
        # nesting never crosses threads, even with interleaved opens
        assert by_name[f"child-{i}"].parent_id == by_name[f"root-{i}"].span_id
        assert by_name[f"child-{i}"].path == f"root-{i}/child-{i}"
    assert len({s.span_id for s in tr.spans}) == 4


def test_counters_and_gauges():
    tr = obs.Tracer()
    tr.inc("q")
    tr.inc("q", 2)
    tr.gauge("depth", 5)
    tr.gauge("depth", 9)
    assert tr.counters == {"q": 3}
    assert tr.gauges == {"depth": 9}
    tr.clear()
    assert tr.counters == {} and tr.gauges == {} and tr.spans == []


def test_use_restores_previous_tracer():
    assert not obs.current().enabled
    tr = obs.Tracer()
    with obs.use(tr):
        assert obs.current() is tr
        with tr.span("x"):
            pass
    assert not obs.current().enabled
    assert [s.name for s in tr.spans] == ["x"]


def test_tracer_of_prefers_pinned_attribute():
    class Holder:
        tracer = None

    h = Holder()
    assert obs.tracer_of(h) is obs.current()
    h.tracer = tr = obs.Tracer()
    assert obs.tracer_of(h) is tr
    assert obs.tracer_of(object(), h) is tr


# ---------------------------------------------------------------------------
# chrome-trace export (golden, deterministic clock)
# ---------------------------------------------------------------------------

def test_chrome_trace_golden():
    t = [0.0]

    def clock():
        t[0] += 0.001      # every timestamp read advances exactly 1 ms
        return t[0]

    tr = obs.Tracer(clock=clock)
    with tr.span("sql.evaluate", root="c", dialect="sqlite"):
        with tr.span("sql.ingest"):
            pass
        with tr.span("db.execute", rows=6):
            pass
    tr.inc("queries", 2)
    tr.gauge("recursive_cte_depth", 3)
    text = json.dumps(obs.chrome_trace(tr), indent=1, sort_keys=True) + "\n"
    path = GOLDEN_DIR / "obs_chrome_trace.json"
    if UPDATE:
        path.write_text(text)
    assert path.exists(), "golden missing — run with REPRO_UPDATE_GOLDEN=1"
    assert text == path.read_text()


def test_write_chrome_trace_loads_back(tmp_path):
    tr = obs.Tracer()
    with tr.span("a"):
        pass
    out = obs.write_chrome_trace(tr, str(tmp_path / "t.json"))
    data = json.loads(pathlib.Path(out).read_text())
    assert data["traceEvents"][0]["name"] == "a"
    assert data["traceEvents"][0]["ph"] == "X"


# ---------------------------------------------------------------------------
# trace_spans relation round-trip
# ---------------------------------------------------------------------------

def _roundtrip_trace_spans(backend):
    root, env = small_dag()
    tr = obs.Tracer()
    eng = SQLEngine(backend=backend, plan_cache_=False, tracer=tr)
    with eng:
        out, = eng.evaluate([root], env)
        assert np.allclose(out, env["a"] @ env["b"])
        n_before = len(tr.spans)
        n = obs.write_trace_spans(eng.adapter, tr)
        # the write itself runs through the traced adapter — the exported
        # snapshot is everything finished *before* it
        assert n == n_before > 0
        rows = eng.adapter.execute(
            "select count(*), count(distinct span_id) from trace_spans")
        assert rows[0][0] == rows[0][1] == n
        stages = eng.adapter.execute(obs.STAGE_SQL)
        names = [r[0] for r in stages]
        assert "db.execute" in names
        # root spans excluded, children attributed
        assert "sql.evaluate" not in names
        # attrs column is valid JSON
        attrs = eng.adapter.execute(
            "select attrs from trace_spans where name = 'sql.evaluate'")
        assert json.loads(attrs[0][0])["dialect"] == eng.dialect.name


def test_trace_spans_relation_sqlite():
    _roundtrip_trace_spans("sqlite")


def test_trace_spans_relation_duckdb():
    pytest.importorskip("duckdb")
    _roundtrip_trace_spans("duckdb")


# ---------------------------------------------------------------------------
# engine integration: span topology, stats, explain
# ---------------------------------------------------------------------------

def test_evaluate_span_topology_and_attribution():
    root, env = small_dag()
    tr = obs.Tracer()
    eng = SQLEngine(plan_cache_=PlanCache(path=None), tracer=tr)
    with eng:
        eng.evaluate([root], env)
    roots = [s for s in tr.spans if s.name == "sql.evaluate"]
    assert len(roots) == 1
    assert roots[0].attrs["root"] == "c"
    assert roots[0].attrs["representation"] == "relational"
    assert roots[0].attrs["rows_returned"] == 6
    assert len(roots[0].attrs["dag_signature"]) == 16
    child_names = {s.name for s in tr.spans
                   if s.parent_id == roots[0].span_id}
    assert {"sql.ingest", "sql.render", "sql.explain",
            "db.execute", "sql.decode"} <= child_names
    bd = obs.stage_breakdown(tr, root="sql.evaluate")
    assert bd["root_count"] == 1
    assert 0.0 < bd["attribution"] <= 1.0
    assert set(bd["stages"]) == child_names


def test_engine_stats_merged_view():
    root, env = small_dag()
    cache = PlanCache(path=None)
    tr = obs.Tracer()
    eng = SQLEngine(plan_cache_=cache, tracer=tr)
    with eng:
        eng.evaluate([root], env)
        eng.evaluate([root], env)
        st = eng.stats
    assert st["cache_misses"] == 1 and st["cache_hits"] == 1
    assert st["queries"] >= 2
    assert st["ingest_bytes"] > 0
    assert st["plan_cache"]["entries"] == 1
    assert st["adapter"]["rows_returned"] >= 12
    assert st["db_bytes"] > 0
    assert st["tracer"]["spans"] == len(tr.spans)


def test_plan_cache_eviction_counters():
    cache = PlanCache(path=None, cap=2)
    cache.put("k1", "sql1")
    cache.put("k2", "sql2")
    assert cache.evictions == 0
    cache.put("k3", "sql3")
    assert cache.evictions == 1
    assert cache.get("k1") is None          # the LRU victim
    st = cache.stats
    assert st["evictions"] == 1 and st["entries"] == 2
    # misses counted for the failed get above
    assert st["misses"] == 1


def test_plan_cache_disk_eviction_counter(tmp_path):
    cache = PlanCache(path=str(tmp_path / "plans.db"), cap=2)
    for k in ("k1", "k2", "k3", "k4"):
        cache.put(k, "select 1")
    assert cache.evictions_disk >= 2
    assert len(cache) == 2
    cache.close()


def test_explain_captured_once_per_plan(tmp_path):
    root, env = small_dag()
    cache = PlanCache(path=str(tmp_path / "plans.db"))
    eng = SQLEngine(plan_cache_=cache, tracer=obs.Tracer())
    with eng:
        eng.evaluate([root], env)
        key = eng._plan_key([root])
        text = cache.get_explain(key)
        assert text and "scan" in text.lower()
        assert eng.explain([root]) == text
        # persisted alongside the plan: a fresh cache on the same file
        # serves the explain without re-capturing
        eng.evaluate([root], env)
        assert cache.stats["explains"] == 1
    reopened = PlanCache(path=str(tmp_path / "plans.db"))
    assert reopened.get_explain(key) == text
    reopened.close()
    cache.close()


def test_explain_without_cache_direct():
    root, env = small_dag()
    eng = SQLEngine(plan_cache_=False)
    with eng:
        eng.evaluate([root], env)
        assert "scan" in eng.explain([root]).lower()


# ---------------------------------------------------------------------------
# slow-query logging (REPRO_SLOW_QUERY_MS)
# ---------------------------------------------------------------------------

def test_slow_query_logging(monkeypatch, caplog):
    root, env = small_dag()
    monkeypatch.setenv("REPRO_SLOW_QUERY_MS", "0")
    tr = obs.Tracer()
    eng = SQLEngine(plan_cache_=False, tracer=tr)
    with eng, caplog.at_level(logging.WARNING, logger="repro.db"):
        eng.evaluate([root], env)
    assert caplog.records, "threshold 0 must flag every query"
    msg = caplog.records[-1].getMessage()
    assert "slow query" in msg
    assert "span=" in msg and "sql.evaluate" in msg   # span path attribution
    assert "sql=" in msg
    assert eng.adapter.counters["slow_queries"] > 0


def test_slow_query_disabled_by_default(monkeypatch, caplog):
    root, env = small_dag()
    monkeypatch.delenv("REPRO_SLOW_QUERY_MS", raising=False)
    eng = SQLEngine(plan_cache_=False)
    with eng, caplog.at_level(logging.WARNING, logger="repro.db"):
        eng.evaluate([root], env)
    assert not caplog.records


def test_slow_query_untraced_path(monkeypatch, caplog):
    root, env = small_dag()
    monkeypatch.setenv("REPRO_SLOW_QUERY_MS", "0")
    eng = SQLEngine(plan_cache_=False)       # no tracer anywhere
    with eng, caplog.at_level(logging.WARNING, logger="repro.db"):
        eng.evaluate([root], env)
    assert "span=<untraced>" in caplog.records[-1].getMessage()


# ---------------------------------------------------------------------------
# no-op overhead guard
# ---------------------------------------------------------------------------

class _CountingNull(obs.NullTracer):
    """Disabled tracer that counts no-op span constructions — measures the
    exact number of no-op spans a disabled warm evaluate pays for."""

    def __init__(self):
        self.calls = 0

    def span(self, name, **attrs):
        self.calls += 1
        return obs.NOOP_SPAN


def test_noop_overhead_under_budget():
    """Disabled-tracer cost must stay < 2% of a warm evaluate.

    Measured deterministically: count the no-op spans the *disabled* warm
    path actually constructs (the enabled path takes different branches),
    multiply by the isolated per-span no-op cost, and compare against the
    measured warm evaluate time — no A/B timing race."""
    root, env = small_dag()
    eng = SQLEngine(plan_cache_=PlanCache(path=None))
    with eng:
        eng.evaluate([root], env)            # cold: render + explain
        counting = _CountingNull()
        eng.tracer = counting
        eng.adapter.tracer = counting
        eng.evaluate([root], env)
        spans_per_eval = counting.calls
        eng.tracer = None
        eng.adapter.tracer = None
        eng.evaluate([root], env)            # warm up the default path
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            eng.evaluate([root], env)
        warm_s = (time.perf_counter() - t0) / reps

    null = obs.current()
    assert not null.enabled
    n = 10_000
    t0 = time.perf_counter()
    for _ in range(n):
        with null.span("x", a=1):
            pass
    per_span = (time.perf_counter() - t0) / n
    overhead = per_span * spans_per_eval
    assert overhead < 0.02 * warm_s, (
        f"no-op span overhead {overhead * 1e6:.1f}µs ≥ 2% of warm "
        f"evaluate {warm_s * 1e3:.2f}ms ({spans_per_eval} spans)")


# ---------------------------------------------------------------------------
# summarize / stage_breakdown shapes
# ---------------------------------------------------------------------------

def test_summarize_orders_by_total():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = obs.Tracer(clock=clock)
    with tr.span("big"):            # 5 clock ticks inside → longest
        with tr.span("small"):
            pass
        with tr.span("small"):
            pass
    s = obs.summarize(tr)
    assert list(s) == ["big", "small"]
    assert s["small"]["count"] == 2
    assert s["small"]["mean_s"] == pytest.approx(s["small"]["total_s"] / 2)
    assert list(obs.summarize(tr, top=1)) == ["big"]


def test_stage_breakdown_empty_tracer():
    bd = obs.stage_breakdown(obs.Tracer(), root="nope")
    assert bd["root_count"] == 0 and bd["attribution"] == 0.0


# ---------------------------------------------------------------------------
# training-loop spans
# ---------------------------------------------------------------------------

def test_train_in_db_span_attribution():
    from repro.core import nn2sql
    from repro.db.train import train_in_db

    spec = nn2sql.MLPSpec(n_rows=4, n_features=4, n_hidden=3, n_classes=2,
                          lr=0.05)
    graph = nn2sql.build_graph(spec)
    rng = np.random.default_rng(0)
    weights = {"w_xh": rng.normal(size=(4, 3)) * 0.1,
               "w_ho": rng.normal(size=(3, 2)) * 0.1}
    x = rng.normal(size=(4, 4))
    y = np.eye(2)[rng.integers(0, 2, size=4)]
    tr = obs.Tracer()
    with obs.use(tr):
        res = train_in_db(graph, weights, x, y, n_iters=2,
                          plan_cache_=False)
    assert res.n_iters == 2
    roots = [s for s in tr.spans if s.name == "train.in_db"]
    assert len(roots) == 1 and roots[0].attrs["n_iters"] == 2
    bd = obs.stage_breakdown(tr, root="train.in_db")
    assert {"train.ingest", "sql.render", "db.execute",
            "train.decode"} <= set(bd["stages"])
    assert bd["attribution"] >= 0.9          # the acceptance criterion
    assert tr.gauges.get("recursive_cte_depth") == 2
