"""Core: the paper's contribution as a composable JAX module.

Expression IR (CTE graph) + Algorithm-1 reverse-mode autodiff + two
execution engines — relational (SQL-92, COO join/group-by) and dense
(array data type) — plus the recursive-CTE iteration construct and the
SQL transpiler.
"""
from . import autodiff, dense, expr, nn2sql, rel_engine, relational, sqlgen
from .engine import Engine, sgd_step_fn
from .recursive_cte import history_bytes, recursive_cte, recursive_cte_py
from .relational import RelTensor, one_hot, one_hot_dense

__all__ = [
    "autodiff", "dense", "expr", "nn2sql", "rel_engine", "relational",
    "sqlgen", "Engine", "sgd_step_fn", "recursive_cte", "recursive_cte_py",
    "history_bytes", "RelTensor", "one_hot", "one_hot_dense",
]
