"""MNIST-scale in-database benchmark (paper §6, Fig. 4/5 axes).

The paper evaluates a 784-feature MLP (784 → hidden → 10); this benchmark
runs that workload through the in-DB backend and emits
``BENCH_db_mnist.json`` so the performance trajectory has data:

* **ingestion** — pivoting + bulk-loading the 784×hidden weight relation,
  per-cell baseline (the seed's Python ``[(i, j, v)]`` loop +  flat
  executemany) vs the vectorized path (meshgrid/ravel pivot + multi-row
  VALUES batches on sqlite, Arrow/ndarray registration on duckdb).  The
  pivot stage — the Python-side per-cell work the vectorization removes —
  is reported separately from the end-to-end write: physical row insertion
  inside sqlite has a hard floor that no client-side change moves.
* **forward+gradient** — one Algorithm-1 value-and-gradient evaluation,
  ``Engine("dense")`` vs the database (cold = includes plan rendering,
  warm = plan cache + unchanged-leaf skip).
* **training** — the fully-in-DB recursive-CTE loop (array variant on
  sqlite) per-iteration cost; optional stepped Listing-7 cross-check.
* **CTE growth** — database bytes and history rows as the recursion
  deepens (the Fig. 5 memory-curve axis): the weight relation keeps every
  iterate, so the database grows linearly with iteration count.

Run:  PYTHONPATH=src python benchmarks/bench_mnist_db.py
CI smoke:  … bench_mnist_db.py --rows 8 --hidden 32 --iters 1
"""
from __future__ import annotations

import argparse
import json
import os
import tempfile
import time

import numpy as np

import jax

from repro import obs
from repro.obs import regress
from repro.core import Engine, nn2sql
from repro.db import HAVE_DUCKDB, connect, plan_cache, relation_io
from repro.db.plan_cache import PlanCache
from repro.db.sql_engine import SQLEngine
from repro.db.train import train_in_db


def wall(fn, iters=3, warmup=True):
    if warmup:
        fn()
    times = []
    for _ in range(max(iters, 1)):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def once(fn):
    t0 = time.perf_counter()
    fn()
    return time.perf_counter() - t0


def mnist_like(spec, seed=0):
    """Synthetic MNIST-shaped batch: 784 pixel features in [0, 1), one-hot
    labels over 10 classes (no dataset download in the benchmark)."""
    rng = np.random.RandomState(seed)
    x = rng.rand(spec.n_rows, spec.n_features).astype(np.float32)
    labels = rng.randint(0, spec.n_classes, spec.n_rows)
    y = np.eye(spec.n_classes, dtype=np.float32)[labels]
    return x, y, labels


# ---------------------------------------------------------------------------
# sections
# ---------------------------------------------------------------------------

def bench_ingestion(w, backend: str, timing_iters: int) -> dict:
    """Per-cell baseline vs vectorized ingestion of the weight relation,
    plus the table-valued JSON path (``json_each`` expansion inside the
    engine) raced against the multi-row VALUES path where available."""
    pivot_percell = wall(lambda: relation_io.matrix_to_rows_percell(w),
                         timing_iters)
    pivot_vec = wall(lambda: relation_io.matrix_to_columns(w), timing_iters)
    with connect(backend) as ad:
        write_percell = wall(
            lambda: relation_io.write_matrix_percell(ad, "w_ing", w),
            timing_iters)
        write_vec = wall(lambda: relation_io.write_matrix(ad, "w_ing", w),
                         timing_iters)
        write_json = None
        if ad.supports_json_ingest:
            write_json = wall(
                lambda: relation_io.write_matrix_json(ad, "w_ing", w),
                timing_iters)
        engine_version = ".".join(map(str, ad.sqlite_version)) \
            if hasattr(ad, "sqlite_version") else None
        json_preferred = bool(getattr(ad, "prefers_json_ingest", False))
        n, = ad.execute("select count(*) from w_ing")[0]
    assert n == w.size
    out = {
        "matrix": f"{w.shape[0]}x{w.shape[1]}",
        "cells": int(w.size),
        "backend": backend,
        # the json_each-vs-VALUES race context: engine version, whether
        # the adapter auto-selects json (≥ 3.38, where json parsing is
        # linear), and which path actually won THIS run's race
        "engine_version": engine_version,
        "json_preferred": json_preferred,
        "pivot_percell_s": pivot_percell,
        "pivot_vectorized_s": pivot_vec,
        # the per-cell Python data path the vectorization removes — this is
        # the acceptance number (client-side ingestion work per matrix)
        "speedup": pivot_percell / pivot_vec,
        "write_percell_s": write_percell,
        "write_vectorized_s": write_vec,
        # end-to-end including the engine's physical row insert (floored
        # by the row-at-a-time storage model on sqlite)
        "write_speedup": write_percell / write_vec,
    }
    if write_json is not None:
        out["write_json_s"] = write_json
        # >1 means the engine-side json_each expansion beats client-side
        # multi-row VALUES (expected on JSON-optimised sqlite ≥3.38)
        out["json_vs_values"] = write_vec / write_json
        out["ingest_winner"] = ("json_each" if write_json < write_vec
                                else "values")
    else:
        out["ingest_winner"] = "values"  # no JSON1: nothing to race
    return out


def bench_forward_grad(graph, w0, x, y, backend: str, timing_iters: int,
                       with_relational: bool) -> dict:
    env = {**w0, "img": x, "one_hot": y}
    out = {}

    import jax.numpy as jnp
    jenv = {k: jnp.asarray(v) for k, v in env.items()}
    vg_dense = Engine("dense").value_and_grad_fn(graph.loss,
                                                 [graph.w_xh, graph.w_ho])
    out["dense_s"] = wall(lambda: jax.block_until_ready(vg_dense(jenv)),
                          timing_iters)
    if with_relational:
        vg_rel = Engine("relational").value_and_grad_fn(
            graph.loss, [graph.w_xh, graph.w_ho])
        out["relational_s"] = wall(
            lambda: jax.block_until_ready(vg_rel(jenv)), timing_iters)

    # one cold + one warm evaluation: at 784 features one in-DB
    # forward+gradient is tens of seconds — repeated medians would
    # dominate the whole benchmark for no extra signal.  plan_cache_=False
    # keeps "cold" honest: with the shared persistent cache a re-run would
    # serve the rendered plan and erase the cold-vs-warm distinction
    eng = SQLEngine(backend=backend, plan_cache_=False)
    t_cold = once(lambda: eng.value_and_grad_fn(
        graph.loss, [graph.w_xh, graph.w_ho])(env))
    vg_sql = eng.value_and_grad_fn(graph.loss, [graph.w_xh, graph.w_ho])
    t_warm = once(lambda: vg_sql(env))
    eng.close()
    # the same warm evaluation with the fusion/spool renderers off — the
    # before/after pair of the CTE-fusion work (fused is the default)
    eng_uf = SQLEngine(backend=backend, plan_cache_=False,
                       fuse=False, spool=False)
    vg_uf = eng_uf.value_and_grad_fn(graph.loss, [graph.w_xh, graph.w_ho])
    vg_uf(env)                                 # ingest + render once
    t_warm_unfused = once(lambda: vg_uf(env))
    eng_uf.close()
    out[f"{backend}_cold_s"] = t_cold          # incl. rendering + ingest
    out[f"{backend}_warm_s"] = t_warm          # plan cache + leaf skip
    out[f"{backend}_warm_unfused_s"] = t_warm_unfused
    out["fused_speedup"] = t_warm_unfused / t_warm
    out["completed_784_forward_grad"] = graph.spec.n_features == 784
    return out


def bench_training(graph, w0, x, y, n_iters: int, backend: str,
                   with_stepped: bool) -> dict:
    t_rec = once(lambda: train_in_db(graph, w0, x, y, n_iters,
                                     backend=backend))
    out = {"backend": backend, "iters": n_iters,
           "recursive_total_s": t_rec,
           "recursive_per_iter_s": t_rec / max(n_iters, 1)}
    if with_stepped:
        t_step = once(lambda: train_in_db(graph, w0, x, y, n_iters,
                                          backend=backend,
                                          strategy="stepped"))
        out["stepped_total_s"] = t_step
        out["stepped_per_iter_s"] = t_step / max(n_iters, 1)
    return out


def bench_cte_growth(graph, w0, x, y, points, backend: str) -> list[dict]:
    """Growth of the training recursion as it deepens (the Fig. 5 memory
    axis): every iterate stays in the recursive weight relation, so the
    bytes it materialises (``DBTrainResult.cte_bytes``) grow linearly with
    the iteration count; ``db_bytes`` is the stored base-table footprint."""
    curve = []
    for n in points:
        fd, path = tempfile.mkstemp(suffix=".db")
        os.close(fd)
        os.unlink(path)
        try:
            ad = connect(backend, path)
            t = time.perf_counter()
            res = train_in_db(graph, w0, x, y, n, adapter=ad)
            t = time.perf_counter() - t
            try:
                page_count, = ad.execute("pragma page_count")[0]
                page_size, = ad.execute("pragma page_size")[0]
                db_bytes = page_count * page_size
            except Exception:  # pragma: no cover - non-sqlite pragma
                db_bytes = None
            ad.close()
            if db_bytes is None and os.path.exists(path):
                db_bytes = os.path.getsize(path)  # pragma: no cover
            curve.append({"iters": n,
                          "history_iterates": len(res.history),
                          "cte_bytes": res.cte_bytes,
                          "db_bytes": db_bytes,
                          "train_s": t})
        finally:
            if os.path.exists(path):
                os.unlink(path)
    return curve


def bench_trace(graph, w0, x, y, backend: str) -> tuple[dict, obs.Tracer]:
    """Per-stage attribution via the tracing subsystem (``repro.obs``):
    ONE traced in-DB training iteration plus a cold+warm traced
    forward+gradient pair.  The acceptance bar: ≥ 90% of the training
    iteration's wall time attributed to named stages (ingest / render /
    execute / decode)."""
    tracer = obs.Tracer()
    env = {**w0, "img": x, "one_hot": y}
    with obs.use(tracer):
        train_in_db(graph, w0, x, y, 1, backend=backend, plan_cache_=False)
    train_bd = obs.stage_breakdown(tracer, root="train.in_db")
    eng = SQLEngine(backend=backend, plan_cache_=PlanCache(path=None),
                    tracer=tracer)
    vg = eng.value_and_grad_fn(graph.loss, [graph.w_xh, graph.w_ho])
    vg(env)                                # cold: ingest + explain
    vg(env)                                # warm: digest-skip + cached plan
    stats = eng.stats
    eng.close()
    eval_bd = obs.stage_breakdown(tracer, root="sql.evaluate")
    return {
        "train_iteration": train_bd,
        "forward_grad": eval_bd,
        "stage_totals": obs.summarize(tracer, top=12),
        "counters": tracer.counters,
        "gauges": tracer.gauges,
        "engine_stats": {k: stats[k] for k in
                         ("cache_hits", "cache_misses", "cache_evictions",
                          "queries", "ingest_bytes")},
        "metric_points": sorted({p.metric for p in tracer.points}),
    }, tracer


def bench_profile(graph, w0, x, y, backend: str) -> dict:
    """Per-IR-node attribution of the training-step DAG (loss +
    Algorithm-1 gradients — the exact multi-root query one ``train.in_db``
    iteration executes) via the profiled execution mode.  The acceptance
    bar: ≥ 95% of the profiled wall time lands on named nodes/stages."""
    env = {**w0, "img": x, "one_hot": y}
    eng = SQLEngine(backend=backend, plan_cache_=False)
    res = eng.profile_value_and_grad(graph.loss, [graph.w_xh, graph.w_ho],
                                    env)
    obs.write_profile_nodes(eng.adapter, res)
    by_kind = eng.adapter.execute(obs.NODE_SQL)
    eng.close()
    return {
        "attribution": res.attribution,
        "wall_s": res.wall_s,
        "nodes": len(res.nodes),
        "top_nodes": res.as_dict(top=10)["nodes"],
        "by_kind": [{"kind": k, "n": n, "total_ms": ms, "rows": r,
                     "pct": p} for k, n, ms, r, p in by_kind],
        "stages_s": res.stages,
        "report": res.report(top=10),
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def run(args) -> dict:
    spec = nn2sql.MLPSpec(n_rows=args.rows, n_features=args.features,
                          n_hidden=args.hidden, n_classes=args.classes,
                          lr=0.05)
    graph = nn2sql.build_graph(spec)
    w0 = {k: np.asarray(v) for k, v in nn2sql.init_weights(spec).items()}
    x, y, _ = mnist_like(spec)
    requested = args.backend
    backend = ("duckdb" if HAVE_DUCKDB else "sqlite") \
        if args.backend == "auto" else args.backend
    if backend == "duckdb" and not HAVE_DUCKDB:
        if not args.fallback_sqlite:
            raise SystemExit("duckdb is not importable; rerun with "
                             "--fallback-sqlite to record a sqlite run")
        print("!! duckdb wheel not importable in this environment — "
              "falling back to sqlite (recorded in the report)", flush=True)
        backend = "sqlite"

    print(f"== MNIST-scale in-DB benchmark: {spec.n_rows}x{spec.n_features}"
          f" -> {spec.n_hidden} -> {spec.n_classes}, backend={backend} ==")

    ingestion = bench_ingestion(w0["w_xh"], backend, args.timing_iters)
    print(f"ingestion {ingestion['matrix']}: per-cell pivot "
          f"{ingestion['pivot_percell_s']*1e3:.1f} ms -> vectorized "
          f"{ingestion['pivot_vectorized_s']*1e3:.2f} ms "
          f"({ingestion['speedup']:.0f}x); end-to-end write "
          f"{ingestion['write_percell_s']*1e3:.1f} -> "
          f"{ingestion['write_vectorized_s']*1e3:.1f} ms "
          f"({ingestion['write_speedup']:.1f}x)", flush=True)
    if "write_json_s" in ingestion:
        print(f"ingestion json_each: {ingestion['write_json_s']*1e3:.1f} ms "
              f"({ingestion['json_vs_values']:.2f}x vs VALUES); winner "
              f"{ingestion['ingest_winner']} on engine "
              f"{ingestion['engine_version']}", flush=True)

    fwd = bench_forward_grad(graph, w0, x, y, backend, args.timing_iters,
                             args.with_relational)
    for k, v in fwd.items():
        if isinstance(v, float) and k.endswith("_s"):
            print(f"value_and_grad[{k:>16s}] {v*1e3:10.1f} ms", flush=True)
    print(f"value_and_grad fused speedup {fwd['fused_speedup']:.1f}x "
          f"(warm, vs fuse/spool off)", flush=True)

    training = bench_training(graph, w0, x, y, args.iters, backend,
                              args.with_stepped)
    print(f"train[{backend} recursive, {args.iters} it] "
          f"{training['recursive_total_s']*1e3:.1f} ms "
          f"({training['recursive_per_iter_s']*1e3:.1f} ms/iter)", flush=True)

    points = [int(p) for p in args.curve.split(",") if p] \
        if args.curve else []
    curve = bench_cte_growth(graph, w0, x, y, points, backend) \
        if points else []
    for c in curve:
        print(f"cte-growth iters={c['iters']:3d}: "
              f"{c['cte_bytes']/1e6:8.1f} MB materialised, "
              f"{c['db_bytes']} db bytes, "
              f"{c['train_s']*1e3:.0f} ms", flush=True)

    trace, tracer = bench_trace(graph, w0, x, y, backend)
    print(f"trace[train 1 it] {trace['train_iteration']['wall_s']*1e3:.1f} ms"
          f" wall, {trace['train_iteration']['attribution']:.1%} attributed; "
          f"forward_grad {trace['forward_grad']['attribution']:.1%}",
          flush=True)
    trace_path = os.path.splitext(args.out)[0] + ".trace.json"
    obs.write_chrome_trace(tracer, trace_path)
    print(f"perfetto trace -> {trace_path}", flush=True)

    profile = bench_profile(graph, w0, x, y, backend)
    print(f"profile[train-step DAG] {profile['nodes']} nodes, "
          f"{profile['wall_s']*1e3:.1f} ms, "
          f"{profile['attribution']:.1%} attributed", flush=True)
    print(profile["report"], flush=True)

    cache = plan_cache.default_cache()
    report = {
        "config": {"rows": spec.n_rows, "features": spec.n_features,
                   "hidden": spec.n_hidden, "classes": spec.n_classes,
                   "lr": spec.lr, "iters": args.iters, "backend": backend,
                   "requested_backend": requested,
                   "have_duckdb": HAVE_DUCKDB},
        "ingestion": ingestion,
        "forward_grad": fwd,
        "training": training,
        "cte_memory_curve": curve,
        "trace": trace,
        "profile": profile,
        "plan_cache": cache.stats,
        "metrics": {
            "ingestion.pivot_speedup":
                regress.metric(ingestion["speedup"], "x", "higher"),
            "forward_grad.warm_s":
                regress.metric(fwd[f"{backend}_warm_s"]),
            "forward_grad.cold_s":
                regress.metric(fwd[f"{backend}_cold_s"]),
            "forward_grad.fused_speedup":
                regress.metric(fwd["fused_speedup"], "x", "higher"),
            "training.recursive_per_iter_s":
                regress.metric(training["recursive_per_iter_s"]),
            "trace.train_attribution":
                regress.metric(trace["train_iteration"]["attribution"],
                               "frac", "higher"),
            "profile.attribution":
                regress.metric(profile["attribution"], "frac", "higher"),
        },
        "checks": {
            "ingest_speedup_ge_10x": ingestion["speedup"] >= 10.0,
            "forward_grad_784_completed":
                bool(fwd.get("completed_784_forward_grad")),
            "trace_attribution_ge_90":
                trace["train_iteration"]["attribution"] >= 0.9,
            "profile_attribution_ge_95": profile["attribution"] >= 0.95,
            # the fusion/spool renderers (default-on) must beat the
            # unfused rendering of the same warm evaluation in-run
            "fused_warm_beats_unfused": fwd["fused_speedup"] > 1.0,
        },
    }
    if backend != requested:
        # a plain string among the metric dicts: ``metrics_from_report``
        # filters to dicts with a "value", so comparisons never see it,
        # but the perf gate reads it to refuse cross-backend gating (a
        # sqlite fallback run judged against a duckdb baseline — or vice
        # versa — measures the backend swap, not a regression)
        report["metrics"]["fallback_backend"] = backend
    return report


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=32,
                    help="batch of input tuples (paper Fig. 4 x-axis)")
    ap.add_argument("--features", type=int, default=784)
    ap.add_argument("--hidden", type=int, default=256)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--iters", type=int, default=3,
                    help="in-DB training iterations")
    ap.add_argument("--timing-iters", type=int, default=3)
    ap.add_argument("--backend", default="sqlite",
                    choices=["sqlite", "duckdb", "auto"])
    ap.add_argument("--curve", default="1,2,4,8",
                    help="comma-separated iteration counts for the CTE "
                         "memory curve ('' disables)")
    ap.add_argument("--with-stepped", action="store_true",
                    help="also time strategy='stepped' (heavy at 784)")
    ap.add_argument("--with-relational", action="store_true",
                    help="also time Engine('relational') (memory-hungry "
                         "at MNIST scale)")
    ap.add_argument("--fallback-sqlite", action="store_true",
                    help="when --backend duckdb but the wheel is missing, "
                         "run sqlite and record the fallback instead of "
                         "failing (used to commit a placeholder artifact "
                         "in containers without the wheel; the CI "
                         "duckdb-extras job regenerates the real one)")
    ap.add_argument("--out", default="BENCH_db_mnist.json")
    args = ap.parse_args()

    report = run(args)
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"\nwrote {args.out}")
    ok = all(report["checks"].values())
    print("checks:", report["checks"])
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
