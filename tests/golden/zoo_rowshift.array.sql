with shift_c0(m) as (
  select mrowshift((select m from zx), 1) as m
),
shift_c1(m) as (
  select mrowshift((select m from zx), -1) as m
)
select 0 as r, m from shift_c0
union all select 1 as r, m from shift_c1;
