"""Tests for the benchmark regression gate (repro.obs.regress +
benchmarks/check_regression.py).

The gate's contract: benchmark reports carry a normalised ``metrics``
block (falling back to legacy key extraction for committed baselines),
``compare`` turns a baseline/fresh pair into per-metric deltas with
tolerance bands, and the CLI exits non-zero exactly when a gated metric
regressed.
"""
import json
import pathlib
import subprocess
import sys

import pytest

from repro.obs import regress

ROOT = pathlib.Path(__file__).resolve().parents[1]
CHECK = ROOT / "benchmarks" / "check_regression.py"


def _report(**metrics):
    return {"metrics": {k: regress.metric(*v) if isinstance(v, tuple)
                        else regress.metric(v) for k, v in metrics.items()}}


# ---------------------------------------------------------------------------
# metric extraction
# ---------------------------------------------------------------------------

def test_metric_constructor_defaults():
    m = regress.metric(1.5)
    assert m == {"value": 1.5, "unit": "s", "direction": "lower"}
    m = regress.metric(4.0, "x", "higher", tolerance=2.0)
    assert m["direction"] == "higher" and m["tolerance"] == 2.0


def test_metrics_from_report_prefers_embedded_block():
    rep = _report(**{"a.t": 1.0})
    rep["forward_grad"] = {"warm_s": 9.9}       # legacy key must be ignored
    got = regress.metrics_from_report(rep)
    assert set(got) == {"a.t"}


@pytest.mark.parametrize("name", [
    "BENCH_db_mnist.json", "BENCH_db_mnist_duckdb.json",
    "BENCH_array_vs_rel.json", "BENCH_zoo_db.json", "BENCH_ssm_db.json",
])
def test_committed_baselines_yield_metrics(name):
    path = ROOT / name
    if not path.exists():
        pytest.skip(f"{name} not committed")
    got = regress.metrics_from_report(json.loads(path.read_text()))
    assert got, f"no metrics extracted from {name}"
    for m in got.values():
        assert m["direction"] in ("lower", "higher")
        assert isinstance(m["value"], (int, float))


def test_legacy_mnist_extraction_backend_prefixed_keys():
    rep = {
        "config": {"backend": "sqlite"},
        "ingestion": {"speedup": 3.0},
        "forward_grad": {"sqlite_warm_s": 0.2, "sqlite_cold_s": 0.5,
                         "fused_speedup": 1.4},
        "training": {"recursive_per_iter_s": 0.1},
        "trace": {"train_iteration": {"attribution": 0.97}},
    }
    got = regress.metrics_from_report(rep)
    assert got["forward_grad.warm_s"]["value"] == 0.2
    assert got["forward_grad.cold_s"]["value"] == 0.5
    assert got["trace.train_attribution"]["direction"] == "higher"
    assert got["ingestion.pivot_speedup"]["direction"] == "higher"


# ---------------------------------------------------------------------------
# compare semantics
# ---------------------------------------------------------------------------

def test_compare_identity_is_all_ok():
    rep = _report(**{"a.t": 1.0, "b.speedup": (4.0, "x", "higher")})
    deltas = regress.compare(rep, rep)
    assert all(d.status == "ok" for d in deltas)
    assert not any(d.failed for d in deltas)


def test_compare_flags_lower_metric_slowdown():
    base = _report(**{"train.s": 1.0})
    fresh = _report(**{"train.s": 2.0})
    d, = regress.compare(base, fresh)
    assert d.status == "regressed" and d.failed
    assert d.ratio == pytest.approx(2.0)
    # within the tolerance band it is only "warn", never a failure
    d, = regress.compare(base, _report(**{"train.s": 1.4}))
    assert d.status in ("ok", "warn") and not d.failed


def test_compare_flags_higher_metric_drop():
    base = _report(**{"fused.speedup": (3.0, "x", "higher")})
    fresh = _report(**{"fused.speedup": (1.0, "x", "higher")})
    d, = regress.compare(base, fresh)
    assert d.status == "regressed" and d.failed
    # gate_directions excludes "higher" → skipped, not failed (smoke mode)
    d, = regress.compare(base, fresh, gate_directions=("lower",))
    assert d.status == "skipped" and not d.failed


def test_compare_per_metric_tolerance_override():
    base = _report(**{"noisy.s": (1.0, "s", "lower", 3.0)})
    fresh = _report(**{"noisy.s": (2.5, "s", "lower", 3.0)})
    d, = regress.compare(base, fresh, tolerance=1.5)
    assert d.status != "regressed"          # 3.0 override beats global 1.5


def test_compare_missing_and_new_metrics():
    base = _report(**{"gone.s": 1.0, "kept.s": 1.0})
    fresh = _report(**{"kept.s": 1.0, "added.s": 2.0})
    by_name = {d.name: d for d in regress.compare(base, fresh)}
    assert by_name["gone.s"].status == "missing" and by_name["gone.s"].failed
    assert by_name["added.s"].status == "new"
    deltas = regress.compare(base, fresh, fail_on_missing=False)
    assert not any(d.failed for d in deltas)


def test_delta_table_renders_every_row():
    base = _report(**{"a.s": 1.0, "b.s": 1.0})
    fresh = _report(**{"a.s": 1.0, "b.s": 5.0})
    text = regress.delta_table(regress.compare(base, fresh), title="t")
    assert "a.s" in text and "b.s" in text and "regressed" in text
    assert "5.00" in text or "5.0" in text


# ---------------------------------------------------------------------------
# the CLI gate
# ---------------------------------------------------------------------------

def _run_cli(*args):
    env = {"PYTHONPATH": str(ROOT / "src"), "PATH": "/usr/bin:/bin"}
    return subprocess.run([sys.executable, str(CHECK), *args],
                          capture_output=True, text=True, env=env)


def test_cli_passes_on_identical_reports(tmp_path):
    rep = _report(**{"train.s": 1.0})
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(rep))
    fresh.write_text(json.dumps(rep))
    r = _run_cli("--baseline", str(base), "--fresh", str(fresh))
    assert r.returncode == 0, r.stdout + r.stderr
    assert "train.s" in r.stdout


def test_cli_fails_on_injected_slowdown(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_report(**{"train.s": 1.0})))
    fresh.write_text(json.dumps(_report(**{"train.s": 2.0})))
    out = tmp_path / "delta"
    r = _run_cli("--baseline", str(base), "--fresh", str(fresh),
                 "--out", str(out))
    assert r.returncode == 1, r.stdout + r.stderr
    assert "regressed" in r.stdout
    # the delta artifact is written even (especially) on failure
    payload = json.loads((tmp_path / "delta.json").read_text())
    rows = [d for sec in payload["sections"] for d in sec["deltas"]]
    assert any(d["status"] == "regressed" for d in rows)
    assert (tmp_path / "delta.md").exists()


def test_cli_respects_tolerance_flag(tmp_path):
    base = tmp_path / "base.json"
    fresh = tmp_path / "fresh.json"
    base.write_text(json.dumps(_report(**{"train.s": 1.0})))
    fresh.write_text(json.dumps(_report(**{"train.s": 2.0})))
    r = _run_cli("--baseline", str(base), "--fresh", str(fresh),
                 "--tolerance", "3.0")
    assert r.returncode == 0, r.stdout + r.stderr
