"""The elementwise CTE-fusion pass and the spooled evaluation plan.

Differential guarantee: for every dialect, a fused (and, under
substitution CTE semantics, spooled) plan computes the same values as the
unfused rendering within 1e-4 — over seeded random elementwise-heavy DAGs
with fan-out and over the MLP forward/backward graph.  Structural
guarantees: fusion never duplicates a multi-consumer subexpression, and
the plan-cache key separates fused from unfused renderings.
"""
import numpy as np
import pytest

from repro.core import nn2sql, sqlgen
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db import HAVE_DUCKDB
from repro.db.plan_cache import PlanCache, plan_key
from repro.db.sql_engine import SQLEngine

TOL = 1e-4

#: dialect → engine kwargs; sql92 renders generate_series so it needs the
#: duckdb engine (CI); sqlite and array always run
ENGINES = {
    "sqlite": dict(backend="sqlite"),
    "array": dict(backend="sqlite", dialect="array"),
    "duckdb": dict(backend="duckdb"),
    "sql92": dict(backend="duckdb", dialect="sql92"),
}
DIALECTS = sorted(ENGINES)


def _engine(dialect, **kw):
    if ENGINES[dialect].get("backend") == "duckdb" and not HAVE_DUCKDB:
        pytest.skip("duckdb not importable")
    return SQLEngine(plan_cache_=False, **ENGINES[dialect], **kw)


def random_elementwise_dag(seed, n_ops=9):
    """A seeded DAG mixing matmuls with elementwise chains; drawing
    operands from the whole pool produces genuine fan-out (nodes with
    several consumers) so absorption limits are exercised."""
    rng = np.random.RandomState(seed)
    x = E.var("fx", (5, 4))
    w = E.var("fw", (4, 4))
    pool = [E.matmul(x, w)]
    unary = [E.sigmoid, E.relu, E.square,
             lambda a: E.scale(float(rng.uniform(-2, 2)), a)]
    binary = [E.add, E.sub, E.hadamard]
    for _ in range(n_ops):
        if rng.rand() < 0.55:
            pool.append(unary[rng.randint(len(unary))](
                pool[rng.randint(len(pool))]))
        else:
            a = pool[rng.randint(len(pool))]
            b = pool[rng.randint(len(pool))]
            pool.append(binary[rng.randint(len(binary))](a, b))
    # two roots so multi-root fan-out counting is exercised as well
    return [pool[-1], pool[rng.randint(len(pool))]], {
        "fx": rng.randn(5, 4), "fw": rng.randn(4, 4)}


def mlp_roots():
    g = nn2sql.build_graph(nn2sql.MLPSpec(6, 5, 4, 3, lr=0.05))
    grads = gradients(g.loss, [g.w_xh, g.w_ho])
    rng = np.random.RandomState(7)
    env = {"img": rng.rand(6, 5), "one_hot": np.eye(3)[rng.randint(0, 3, 6)],
           "w_xh": rng.randn(5, 4) * 0.3, "w_ho": rng.randn(4, 3) * 0.3}
    return [g.loss, grads[g.w_xh], grads[g.w_ho]], env


def _evaluate(dialect, roots, env, **kw):
    eng = _engine(dialect, **kw)
    try:
        return eng.evaluate(roots, env)
    finally:
        eng.close()


class TestDifferential:
    @pytest.mark.parametrize("dialect", DIALECTS)
    @pytest.mark.parametrize("seed", [0, 1, 2, 3])
    def test_random_dags_fused_matches_unfused(self, dialect, seed):
        roots, env = random_elementwise_dag(seed)
        base = _evaluate(dialect, roots, env, fuse=False, spool=False)
        fused = _evaluate(dialect, roots, env, fuse=True, spool=False)
        both = _evaluate(dialect, roots, env, fuse=True, spool=True)
        for b, f, s in zip(base, fused, both):
            np.testing.assert_allclose(f, b, atol=TOL)
            np.testing.assert_allclose(s, b, atol=TOL)

    @pytest.mark.parametrize("dialect", DIALECTS)
    def test_mlp_forward_backward_fused_matches_unfused(self, dialect):
        roots, env = mlp_roots()
        base = _evaluate(dialect, roots, env, fuse=False, spool=False)
        fused = _evaluate(dialect, roots, env, fuse=True, spool=True)
        for b, f in zip(base, fused):
            np.testing.assert_allclose(f, b, atol=TOL)


class TestStructure:
    def test_multi_consumer_subexpression_not_duplicated(self):
        """``h`` feeds two elementwise consumers: it must survive as its
        own CTE (referenced by name), never be inlined into both."""
        x, w = E.var("x", (3, 3)), E.var("w", (3, 3))
        h = E.sigmoid(E.matmul(x, w), name="h")
        roots = [E.add(E.square(h), E.relu(h))]
        sql = sqlgen.to_sql(roots, dialect="sqlite", fuse=True)
        assert "h(i, j, v) as" in sql
        # the sigmoid body renders exactly once despite two consumers
        assert sql.count("exp(") == 1

    def test_single_consumer_chain_collapses(self):
        x, w = E.var("x", (3, 3)), E.var("w", (3, 3))
        chain = E.scale(2.0, E.relu(E.square(E.sigmoid(E.matmul(x, w)))))
        fused = sqlgen.to_sql([chain], dialect="sqlite", fuse=True)
        unfused = sqlgen.to_sql([chain], dialect="sqlite", fuse=False)
        # four elementwise CTEs collapse into the one fused root CTE
        assert fused.count(") as (") == unfused.count(") as (") - 3

    def test_fuse_dag_respects_roots(self):
        """A query root is never absorbed into its consumer — its relation
        must exist for the result decode."""
        x = E.var("x", (2, 2))
        a = E.sigmoid(x, name="a")
        b = E.square(a, name="b")
        regions, skip = sqlgen.fuse_dag([a, b])
        assert id(a) not in skip

    def test_plan_text_round_trip(self):
        roots, _env = mlp_roots()
        plan = sqlgen.render_plan(
            roots, select=sqlgen.multi_root_tail(roots, "sqlite"),
            dialect="sqlite", fuse=True, spool=True)
        assert plan.steps, "MLP backward has shared intermediates to spool"
        back = sqlgen.Plan.from_text(plan.to_text())
        assert back == plan


class TestPlanKeys:
    def test_fused_and_unfused_never_share_a_key(self):
        roots, _env = mlp_roots()
        keys = {plan_key(roots, extra=("sqlite", "tail:multi_root",
                                       f"fuse:{int(f)}", f"spool:{int(s)}"))
                for f in (0, 1) for s in (0, 1)}
        assert len(keys) == 4

    def test_engine_plan_keys_distinguish_renderers(self):
        roots, env = mlp_roots()
        cache = PlanCache(path=None)
        e1 = SQLEngine(plan_cache_=cache, fuse=False, spool=False)
        e2 = SQLEngine(plan_cache_=cache, fuse=True, spool=True)
        r1 = e1.evaluate(roots, env)
        r2 = e2.evaluate(roots, env)
        e1.close(), e2.close()
        assert cache.misses == 2 and cache.hits == 0
        for a, b in zip(r1, r2):
            np.testing.assert_allclose(a, b, atol=TOL)
