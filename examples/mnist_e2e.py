"""The paper's MNIST image-classification benchmark, end to end (§6.3).

Trains the one-hidden-layer network on MNIST-shaped data at a chosen batch
size on both representations, then measures inference throughput — the
workload of the paper's Figures 9 and 10 — and reports accuracy (the paper
evaluates runtime/memory; accuracy here just proves learning happens).

    PYTHONPATH=src python examples/mnist_e2e.py --batch 1000 --hidden 20
"""
import argparse
import time

import jax.numpy as jnp

from repro.core import Engine, nn2sql
from repro.data import make_mnist_like, one_hot_labels


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--batch", type=int, default=1000)
    ap.add_argument("--hidden", type=int, default=20)
    ap.add_argument("--epochs", type=int, default=30)
    args = ap.parse_args()

    x, y = make_mnist_like(args.batch)
    y_oh = jnp.asarray(one_hot_labels(y, 10))
    spec = nn2sql.MLPSpec(args.batch, 784, args.hidden, 10, lr=0.1)
    g = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)

    for kind in ("dense", "relational"):
        eng = Engine(kind)
        t0 = time.perf_counter()
        wf, _ = nn2sql.train(g, w0, x, y_oh, args.epochs, eng)
        t_train = time.perf_counter() - t0
        infer = nn2sql.infer(g, eng)
        infer(wf, x)                                   # warm
        t0 = time.perf_counter()
        probs = infer(wf, x)
        t_inf = time.perf_counter() - t0
        acc = float(nn2sql.accuracy(probs, y))
        print(f"[{kind:10s}] train {args.epochs} iters: {t_train:6.2f}s "
              f"({args.batch * args.epochs / t_train:8.0f} tuples/s) | "
              f"inference: {args.batch / max(t_inf, 1e-9):9.0f} tuples/s | "
              f"acc {acc:.3f}")


if __name__ == "__main__":
    main()
