"""Pallas TPU kernel: the paper's join + group-by matmul (relational SpMM).

The paper executes ``γ_{m.i,n.j,sum(m.v·n.v)}(m ⋈_{m.j=n.i} n)`` with a hash
join and hash aggregation — a full pipeline breaker that materialises the
joined intermediate (Fig. 4/5). The TPU-native adaptation streams the sorted
relation through VMEM and keeps only an O(block) accumulator — the
"sort-based aggregation with continuous output" of the paper's §8:

  grid = (n/blk_n, nnz/blk_t); for each tuple block
    1. JOIN      gather the matching rhs rows (``b[col_ids]``) from the
                 VMEM-resident rhs column block         (HBM→VMEM once per j)
    2. SELECT    scale by the tuple values
    3. GROUP BY  one-hot(row_ids)ᵀ · scaled — the segment sum expressed as an
                 MXU matmul, so the aggregation runs on the systolic array
                 instead of a hash table.

Padding tuples carry ``row_ids == m`` → their one-hot row is all-zero, which
drops them exactly like a non-matching inner-join tuple.

VMEM working set per grid cell:
  rhs block (k × blk_n) + tuple block (3 × blk_t) + one-hot (blk_t × m)
  + accumulator (m × blk_n);  defaults keep this ≲ 8 MiB for m, k ≤ 2048.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(rows_ref, cols_ref, vals_ref, b_ref, o_ref, acc_ref, *,
            m: int, n_tuple_blocks: int):
    t = pl.program_id(1)

    @pl.when(t == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    rows = rows_ref[...]                       # (blk_t,) sorted row ids
    cols = cols_ref[...]                       # (blk_t,) inner index
    vals = vals_ref[...]                       # (blk_t,)
    rhs = b_ref[...]                           # (k, blk_n) clustered rhs

    joined = rhs[cols]                         # JOIN: gather matching rows
    scaled = joined * vals[:, None].astype(jnp.float32)   # SELECT m.v·n.v
    # GROUP BY m.i via one-hot · MXU: padding rows (== m) vanish.
    onehot = (rows[:, None] ==
              jax.lax.broadcasted_iota(jnp.int32, (1, m), 1)).astype(jnp.float32)
    acc_ref[...] += jnp.dot(onehot.T, scaled,
                            preferred_element_type=jnp.float32)

    @pl.when(t == n_tuple_blocks - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("m", "blk_t", "blk_n", "interpret"))
def relational_matmul(row_ids: jax.Array, col_ids: jax.Array, vals: jax.Array,
                      b: jax.Array, m: int, *, blk_t: int = 256,
                      blk_n: int = 128, interpret: bool = True) -> jax.Array:
    """out (m, n) = group-by-sum of the joined relation; b is (k, n)."""
    nnz = row_ids.shape[0]
    k, n = b.shape
    blk_t = min(blk_t, nnz)
    blk_n = min(blk_n, n)
    if nnz % blk_t or n % blk_n:
        raise ValueError(f"nnz {nnz} % blk_t {blk_t} or n {n} % blk_n {blk_n}")
    n_tuple_blocks = nnz // blk_t
    grid = (n // blk_n, n_tuple_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, m=m, n_tuple_blocks=n_tuple_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_t,), lambda jn, t: (t,)),
            pl.BlockSpec((blk_t,), lambda jn, t: (t,)),
            pl.BlockSpec((blk_t,), lambda jn, t: (t,)),
            pl.BlockSpec((k, blk_n), lambda jn, t: (0, jn)),
        ],
        out_specs=pl.BlockSpec((m, blk_n), lambda jn, t: (0, jn)),
        out_shape=jax.ShapeDtypeStruct((m, n), vals.dtype),
        scratch_shapes=[pltpu.VMEM((m, blk_n), jnp.float32)],
        interpret=interpret,
    )(row_ids, col_ids, vals, b)
