"""Relational (SQL-92) engine: evaluates the expression DAG over RelTensors.

Mirrors ``core.dense`` but every node is computed with the relational
building blocks of Listing 4; each memoised node is one CTE of the generated
query (``core.sqlgen`` prints the actual SQL for the same DAG).

The DAG-zoo tier (RowReduce/Softmax/ArgTopK/Gather/Scatter/RowShift/
Recurrence) evaluates through ``dense.eval_node`` on the densified children
and re-pivots the result — the relations stay canonical (dense cell set),
so the round trip is exact; the genuinely relational execution of these
nodes is the generated SQL itself (``core.sqlgen`` → ``repro.db``).
"""
from __future__ import annotations

import jax.numpy as jnp

from . import dense
from . import expr as E
from .autodiff import MapDeriv
from .relational import RelTensor


def evaluate(roots: list[E.Expr], env: dict[str, RelTensor]) -> list[RelTensor]:
    cache: dict[int, RelTensor] = {}

    def ev(node: E.Expr) -> RelTensor:
        if id(node) in cache:
            return cache[id(node)]
        if isinstance(node, E.Var):
            out = env[node.name]
            if not isinstance(out, RelTensor):
                raise TypeError(f"relational engine needs RelTensor for {node.name}")
        elif isinstance(node, E.Const):
            out = RelTensor.from_dense(
                jnp.full(node.shape, node.value, dtype=jnp.float32))
        elif isinstance(node, E.MatMul):
            out = ev(node.x).matmul(ev(node.y))
        elif isinstance(node, E.Hadamard):
            out = ev(node.x).hadamard(ev(node.y))
        elif isinstance(node, E.Add):
            out = ev(node.x).add(ev(node.y))
        elif isinstance(node, E.Sub):
            out = ev(node.x).sub(ev(node.y))
        elif isinstance(node, E.Scale):
            out = ev(node.x).scale(node.c)
        elif isinstance(node, E.Transpose):
            out = ev(node.x).transpose()
        elif isinstance(node, MapDeriv):
            xv, fxv = ev(node.x), ev(node.fx)
            out = RelTensor(i=xv.i, j=xv.j, v=node.fn.df(xv.v, fxv.v),
                            shape=xv.shape)
        elif isinstance(node, E.Map):
            out = ev(node.x).map(node.fn.fn)
        else:  # zoo tier (and ReduceDeriv): shared dense semantics
            out = RelTensor.from_dense(
                dense.eval_node(node, lambda c: ev(c).to_dense()))
        cache[id(node)] = out
        return out

    return [ev(r) for r in roots]
