"""SQL generation from the expression DAG.

The paper's §8 outlook: "a transpiler that automatically generates the
corresponding SQL queries from common array query languages … could offer
additional features such as automatic differentiation for the generation of
queries for model training and inference." This module is that transpiler:
the same DAG the JAX engines execute is rendered as

* **SQL-92** — one CTE per node using the relational representation
  (Listing 4 building blocks, Listing 7 training query), and
* **SQL + Arrays** — the nested-subquery style over an array data type
  (Listing 10), with ``**`` matmul, ``*`` Hadamard, ``transpose``, ``sig``,
  plus a function-call rendering (``mm``/``mhad``/``msig`` …) over the UDF
  array extension that :mod:`repro.db.dialect` installs on sqlite/duckdb.

Rendering is **dialect-aware**: every generator takes an optional
``dialect`` (name or :class:`repro.db.dialect.Sql92Dialect` instance) that
decides how constant matrices (``generate_series`` vs. an emulated
recursive series) and map functions are spelled.  The default dialect is
the paper's verbatim SQL-92, golden-tested in ``tests/test_sqlgen.py``;
the ``sqlite`` / ``duckdb`` dialects make the output *executable* — see
:mod:`repro.db.sql_engine` and :mod:`repro.db.train`.

The ``array`` dialect is the fourth first-class target: the same entry
point (:func:`to_sql`) renders every IR node — zoo tier included — as one
single-row CTE over the UDF array extension instead of a cell relation,
and ``Recurrence`` as a recursive CTE carrying one array-typed state row
(:func:`to_sql_array_ctes`).  This is the paper's §5/§7 comparison axis:
same DAG, same engine, two representations.
"""
from __future__ import annotations

import dataclasses
import hashlib

from . import expr as E
from .autodiff import MapDeriv, ReduceDeriv, derive


def _get_dialect(dialect):
    """Resolve a dialect lazily (keeps ``core`` importable without ``db``)."""
    from ..db.dialect import Sql92Dialect, get_dialect

    return Sql92Dialect() if dialect is None else get_dialect(dialect)


# ---------------------------------------------------------------------------
# deterministic naming + structural signatures (plan-cache foundation)
# ---------------------------------------------------------------------------

def assign_names(order: list[E.Expr]) -> dict[int, str]:
    """id → SQL name for every node of a topo order.

    Explicitly named nodes (``a_xh``, Var table names, …) keep their names;
    auto-named nodes (``mm_37`` — global-counter suffixes) are renamed by
    topo position (``mm_c0``, ``had_c1``, …).  Rendering therefore depends
    only on DAG *structure* and the explicit names: two structurally
    identical DAGs built in different sessions produce byte-identical SQL,
    which is what lets :mod:`repro.db.plan_cache` reuse rendered plans
    across processes.
    """
    taken = {n.name for n in order if not E.is_auto_named(n)}
    nm: dict[int, str] = {}
    k = 0
    for node in order:
        if not E.is_auto_named(node):
            nm[id(node)] = node.name
            continue
        stem = node.name.rsplit("_", 1)[0] or "n"
        while True:  # deterministic collision bump against explicit names
            cand = f"{stem}_c{k}"
            k += 1
            if cand not in taken:
                break
        taken.add(cand)
        nm[id(node)] = cand
    return nm


def dag_signature(roots: list[E.Expr], extra=()) -> str:
    """Structural sha256 of a DAG: node types, shapes, constants, edges and
    *explicit* names (auto-generated names are anonymised, matching
    :func:`assign_names`).  Identical signature ⇒ identical rendered SQL,
    so this — together with the dialect name and the select-tail kind — is
    the plan-cache key.  ``extra`` items are folded into the hash verbatim.
    """
    order = E.topo_order(*roots)
    idx = {id(n): k for k, n in enumerate(order)}
    parts = []
    for n in order:
        fields = [type(n).__name__,
                  "@" if E.is_auto_named(n) else n.name,
                  repr(tuple(n.shape))]
        if isinstance(n, E.Const):
            fields.append(repr(n.value))
        elif isinstance(n, E.Scale):
            fields.append(repr(n.c))
        elif isinstance(n, (E.Map, MapDeriv)):
            fields.append(n.fn.name)
        # zoo tier: static attributes are part of the rendered SQL, so two
        # DAGs differing only in (k, kind, axis, offset, direction) must
        # never share a cached plan
        elif isinstance(n, E.RowReduce):
            fields.append(f"{n.kind}:{n.axis}")
        elif isinstance(n, E.ArgTopK):
            fields.append(f"k={n.k}")
        elif isinstance(n, E.RowShift):
            fields.append(f"off={n.offset}")
        elif isinstance(n, E.Recurrence):
            fields.append(f"rev={int(n.reverse)}")
        elif isinstance(n, E.MatRecurrence):
            fields.append(f"rev={int(n.reverse)},tr={int(n.transposed)}")
        elif isinstance(n, ReduceDeriv):
            fields.append(f"axis={n.axis}")
        fields += [str(idx[id(c)]) for c in n.children()]
        parts.append("|".join(fields))
    parts.append("roots:" + ",".join(str(idx[id(r)]) for r in roots))
    parts += [repr(e) for e in extra]
    return hashlib.sha256("\n".join(parts).encode()).hexdigest()


# ---------------------------------------------------------------------------
# SQL-92: relational representation
# ---------------------------------------------------------------------------

def _cte_sql(node: E.Expr, nm: dict[int, str], dialect) -> str:
    """Render one node as a select over its children's CTEs (Listing 4)."""
    n = lambda c: nm[id(c)]
    if isinstance(node, E.MatMul):
        return (f"select m.i, n.j, sum(m.v*n.v) as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.j = n.i\n  group by m.i, n.j")
    if isinstance(node, (E.Hadamard, E.Add, E.Sub)):
        op = {"Hadamard": "*", "Add": "+", "Sub": "-"}[type(node).__name__]
        return (f"select m.i, m.j, m.v {op} n.v as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.i = n.i and m.j = n.j")
    if isinstance(node, E.Scale):
        return f"select i, j, {node.c} * v as v from {n(node.x)}"
    if isinstance(node, E.Transpose):
        return f"select j as i, i as j, v from {n(node.x)}"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:  # out·(1-out) from the cached CTE
            return (f"select i, j, v*(1-v) as v from {n(node.fx)}")
        if node.fn is E.SQUARE:
            return f"select i, j, 2*v as v from {n(node.x)}"
        if node.fn is E.RELU:
            return (f"select i, j, case when v > 0 then 1 else 0 end as v"
                    f" from {n(node.x)}")
        if node.fn is E.RECIP:    # -1/x² = -out² from the cached CTE
            return f"select i, j, -(v*v) as v from {n(node.fx)}"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, ReduceDeriv):  # argmax indicator from the cached max
        on = "i" if node.axis == 1 else "j"
        return (f"select m.i, m.j, case when m.v = r.v then 1.0 else 0.0 end"
                f" as v\n  from {n(node.x)} as m inner join {n(node.red)}"
                f" as r on m.{on} = r.{on}")
    if isinstance(node, E.Map):
        return f"select i, j, {dialect.map_sql(node.fn, 'v')} as v from {n(node.x)}"
    if isinstance(node, E.Const):
        rows, cols = node.shape
        return dialect.const_select(rows, cols, node.value)
    if isinstance(node, E.RowReduce):
        if node.axis == 1:
            return (f"select i, 1 as j, {node.kind}(v) as v"
                    f" from {n(node.x)}\n  group by i")
        return (f"select 1 as i, j, {node.kind}(v) as v"
                f" from {n(node.x)}\n  group by j")
    if isinstance(node, E.Softmax):
        # stable row softmax: subtract the row max, normalise by the row
        # sum — both aggregates in one derived table joined back on i
        src = n(node.x)
        return (f"select m.i, m.j, exp(m.v - d.mx) / d.den as v\n"
                f"  from {src} as m inner join (\n"
                f"    select e.i, e.mx, sum(exp(e2.v - e.mx)) as den\n"
                f"      from (select i, max(v) as mx from {src}"
                f" group by i) e\n"
                f"      inner join {src} as e2 on e2.i = e.i\n"
                f"     group by e.i, e.mx\n"
                f"  ) d on m.i = d.i")
    if isinstance(node, E.ArgTopK):
        return dialect.topk_mask_select(n(node.x), node.k)
    if isinstance(node, E.Gather):
        # self-join on the index relation: idx values are 0-based row
        # numbers, storage is 1-based
        return (f"select g.i, m.j, m.v\n"
                f"  from {n(node.idx)} as g inner join {n(node.x)} as m"
                f" on m.i = cast(g.v as integer) + 1")
    if isinstance(node, E.Scatter):
        rows, cols = node.shape
        return (f"select a.i, b.j, coalesce(acc.v, 0.0) as v\n"
                f"  from {dialect.frame_from(rows, cols)}\n"
                f"  left join (\n"
                f"    select cast(g.v as integer) + 1 as i, m.j,"
                f" sum(m.v) as v\n"
                f"      from {n(node.idx)} as g inner join {n(node.x)} as m"
                f" on m.i = g.i\n"
                f"     group by cast(g.v as integer) + 1, m.j\n"
                f"  ) acc on acc.i = a.i and acc.j = b.j")
    if isinstance(node, E.RowShift):
        rows, cols = node.shape
        return (f"select a.i, b.j, coalesce(m.v, 0.0) as v\n"
                f"  from {dialect.frame_from(rows, cols)}\n"
                f"  left join {n(node.x)} as m"
                f" on m.i = a.i - ({node.offset}) and m.j = b.j")
    if isinstance(node, E.Recurrence):
        # the Listing-7 machinery: anchor row + self-joining recursive
        # member; each (t, j) tuple walks its own column chain, so sqlite's
        # row-at-a-time queue semantics and duckdb's set semantics agree
        me, a, b = nm[id(node)], n(node.a), n(node.b)
        t_rows = node.shape[0]
        anchor, nxt = (1, "r.i + 1") if not node.reverse \
            else (t_rows, "r.i - 1")
        return (f"select m.i, m.j, m.v from {b} as m where m.i = {anchor}\n"
                f"  union all\n"
                f"  select {nxt}, r.j, am.v * r.v + bm.v\n"
                f"    from {me} as r\n"
                f"    inner join {a} as am on am.i = {nxt} and am.j = r.j\n"
                f"    inner join {b} as bm on bm.i = {nxt} and bm.j = r.j")
    if isinstance(node, E.StepOuter):
        # stacked per-step outer product: one equi-join on the step index,
        # the block row recovered by index arithmetic (matches the (T·D, D)
        # stacking convention of MatRecurrence's coefficient relation)
        k = node.x.shape[1]
        return (f"select ({k} * (m.i - 1)) + m.j as i, n.j, m.v * n.v as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.i = n.i")
    raise TypeError(type(node))


# ---------------------------------------------------------------------------
# batched rendering: one plan, B independent requests (multi-tenant serving)
# ---------------------------------------------------------------------------
#
# A *batched* relation carries a leading ``b`` request-index column next to
# the cell tuple — ``{[b, i, j, v]}`` relational, ``(b, m)`` array — so ONE
# rendered statement evaluates the same DAG for B independent leaf
# environments.  Batched-ness flows from the batched leaf Vars through every
# rendered reference; constants and shared leaves (weights) stay unbatched
# and broadcast through the joins, which is what keeps the rendered text
# free of any literal B: the same cached plan serves B = 1, a ragged last
# micro-batch, and B = 64 alike (the batch size lives in the leaf DATA).

def batched_ids(roots: list[E.Expr], batch_vars) -> frozenset:
    """ids of the nodes whose rendered relation carries the batch column:
    a Var named in ``batch_vars``, or any node one of whose *rendered*
    references (:func:`_used_children`) is batched.  The scans cannot ride
    a batch column (their recursion walks t, not b) — batching one raises."""
    bt: set[int] = set()
    if not batch_vars:
        return frozenset()
    for node in E.topo_order(*roots):
        if isinstance(node, E.Var):
            if node.name in batch_vars:
                bt.add(id(node))
        elif any(id(c) in bt for c in _used_children(node)):
            if isinstance(node, (E.Recurrence, E.MatRecurrence)):
                raise NotImplementedError(
                    f"{type(node).__name__} cannot carry a batch column; "
                    f"keep scan inputs out of the batched leaf set")
            bt.add(id(node))
    return frozenset(bt)


def _cte_sql_b(node: E.Expr, nm: dict[int, str], dialect, bt) -> str:
    """Batched relational rendering of one node (:func:`_cte_sql`'s twin):
    the output carries a leading ``b``; a batched child contributes it, an
    unbatched child broadcasts (no ``b`` predicate on its join leg)."""
    n = lambda c: nm[id(c)]
    isb = lambda c: id(c) in bt
    if isinstance(node, E.MatMul):
        xb, yb = isb(node.x), isb(node.y)
        bsrc = "m.b" if xb else "n.b"
        bjoin = " and m.b = n.b" if xb and yb else ""
        return (f"select {bsrc} as b, m.i, n.j, sum(m.v*n.v) as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.j = n.i{bjoin}\n  group by {bsrc}, m.i, n.j")
    if isinstance(node, (E.Hadamard, E.Add, E.Sub)):
        op = {"Hadamard": "*", "Add": "+", "Sub": "-"}[type(node).__name__]
        xb, yb = isb(node.x), isb(node.y)
        bsrc = "m.b" if xb else "n.b"
        bjoin = " and m.b = n.b" if xb and yb else ""
        return (f"select {bsrc} as b, m.i, m.j, m.v {op} n.v as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.i = n.i and m.j = n.j{bjoin}")
    if isinstance(node, E.Scale):
        return f"select b, i, j, {node.c} * v as v from {n(node.x)}"
    if isinstance(node, E.Transpose):
        return f"select b, j as i, i as j, v from {n(node.x)}"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:
            return f"select b, i, j, v*(1-v) as v from {n(node.fx)}"
        if node.fn is E.SQUARE:
            return f"select b, i, j, 2*v as v from {n(node.x)}"
        if node.fn is E.RELU:
            return (f"select b, i, j, case when v > 0 then 1 else 0 end as v"
                    f" from {n(node.x)}")
        if node.fn is E.RECIP:
            return f"select b, i, j, -(v*v) as v from {n(node.fx)}"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, ReduceDeriv):
        on = "i" if node.axis == 1 else "j"
        xb, rb = isb(node.x), isb(node.red)
        bsrc = "m.b" if xb else "r.b"
        bjoin = " and m.b = r.b" if xb and rb else ""
        return (f"select {bsrc} as b, m.i, m.j, case when m.v = r.v then 1.0"
                f" else 0.0 end as v\n  from {n(node.x)} as m inner join"
                f" {n(node.red)} as r on m.{on} = r.{on}{bjoin}")
    if isinstance(node, E.Map):
        return (f"select b, i, j, {dialect.map_sql(node.fn, 'v')} as v"
                f" from {n(node.x)}")
    if isinstance(node, E.RowReduce):
        if node.axis == 1:
            return (f"select b, i, 1 as j, {node.kind}(v) as v"
                    f" from {n(node.x)}\n  group by b, i")
        return (f"select b, 1 as i, j, {node.kind}(v) as v"
                f" from {n(node.x)}\n  group by b, j")
    if isinstance(node, E.Softmax):
        src = n(node.x)
        return (f"select m.b, m.i, m.j, exp(m.v - d.mx) / d.den as v\n"
                f"  from {src} as m inner join (\n"
                f"    select e.b, e.i, e.mx, sum(exp(e2.v - e.mx)) as den\n"
                f"      from (select b, i, max(v) as mx from {src}"
                f" group by b, i) e\n"
                f"      inner join {src} as e2 on e2.b = e.b and e2.i = e.i\n"
                f"     group by e.b, e.i, e.mx\n"
                f"  ) d on m.b = d.b and m.i = d.i")
    if isinstance(node, E.ArgTopK):
        return dialect.topk_mask_select_b(n(node.x), node.k)
    if isinstance(node, E.Gather):
        gb, xb = isb(node.idx), isb(node.x)
        bsrc = "g.b" if gb else "m.b"
        bjoin = " and m.b = g.b" if gb and xb else ""
        return (f"select {bsrc} as b, g.i, m.j, m.v\n"
                f"  from {n(node.idx)} as g inner join {n(node.x)} as m"
                f" on m.i = cast(g.v as integer) + 1{bjoin}")
    if isinstance(node, E.Scatter):
        rows, cols = node.shape
        gb, xb = isb(node.idx), isb(node.x)
        bsrc = "g.b" if gb else "m.b"
        bjoin = " and m.b = g.b" if gb and xb else ""
        dom = n(node.x) if xb else n(node.idx)
        return (f"select bb.b, a.i, b.j, coalesce(acc.v, 0.0) as v\n"
                f"  from (select distinct b from {dom}) bb cross join\n"
                f"       {dialect.frame_from(rows, cols)}\n"
                f"  left join (\n"
                f"    select {bsrc} as b, cast(g.v as integer) + 1 as i,"
                f" m.j, sum(m.v) as v\n"
                f"      from {n(node.idx)} as g inner join {n(node.x)} as m"
                f" on m.i = g.i{bjoin}\n"
                f"     group by {bsrc}, cast(g.v as integer) + 1, m.j\n"
                f"  ) acc on acc.b = bb.b and acc.i = a.i and acc.j = b.j")
    if isinstance(node, E.RowShift):
        rows, cols = node.shape
        return (f"select bb.b, a.i, b.j, coalesce(m.v, 0.0) as v\n"
                f"  from (select distinct b from {n(node.x)}) bb cross join\n"
                f"       {dialect.frame_from(rows, cols)}\n"
                f"  left join {n(node.x)} as m"
                f" on m.b = bb.b and m.i = a.i - ({node.offset})"
                f" and m.j = b.j")
    if isinstance(node, E.StepOuter):
        k = node.x.shape[1]
        xb, yb = isb(node.x), isb(node.y)
        bsrc = "m.b" if xb else "n.b"
        bjoin = " and m.b = n.b" if xb and yb else ""
        return (f"select {bsrc} as b, ({k} * (m.i - 1)) + m.j as i, n.j,"
                f" m.v * n.v as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.i = n.i{bjoin}")
    raise TypeError(type(node))


def _mat_scan_bounds(node: E.MatRecurrence) -> tuple[int, str, str]:
    """(anchor step, next-step expression, continue guard) of the scan's
    t-walk, shared by every MatRecurrence rendering."""
    t_rows = node.shape[0]
    if not node.reverse:
        return 1, "r.t + 1", f"r.t < {t_rows}"
    return t_rows, "r.t - 1", "r.t > 1"


def _mat_scan_ctes_columns(node: E.MatRecurrence, nm: dict[int, str]
                           ) -> list[str]:
    """The matrix-valued scan as PURE SQL (the sql92 golden rendering):
    ONE genuine recursive CTE whose tuple carries the WHOLE (1, D) state
    row as D columns (``{me}_scan(t, s1..sD)``), plus the unpivot back to
    cells.  The matvec s·A_t is spelled as D² correlated scalar
    subqueries against the (T·D, D) coefficient stack — every engine's
    recursive-CTE restrictions are satisfied at once: the recursive
    table is referenced exactly once, the recursive select is
    aggregate-free, and no self-join is needed because the row rides one
    tuple.  (Cell-granularity recursion cannot express the matvec at
    all: mixing the D previous-state cells needs an aggregate over — or
    a second reference to — the recursive table, both forbidden.)

    This rendering references the coefficient child O(D²) times, which
    engines that expand CTE references by substitution (sqlite) multiply
    through nested scans — the executable dialects therefore render the
    packed form (:func:`_mat_scan_ctes_packed`) instead."""
    me, a, b = nm[id(node)], nm[id(node.a)], nm[id(node.b)]
    t_rows, d = node.shape
    cols = ", ".join(f"s{j}" for j in range(1, d + 1))
    anchor_t, nxt, guard = _mat_scan_bounds(node)
    base = f"r.t*{d}" if not node.reverse else f"(r.t - 2)*{d}"
    anchor = ", ".join(
        f"(select v from {b} as bm where bm.i = {anchor_t} and bm.j = {j})"
        for j in range(1, d + 1))
    exprs = []
    for j in range(1, d + 1):
        if node.transposed:  # s·A_tᵀ: a[base+j, k]
            cell = lambda k: f"am.i = {base} + {j} and am.j = {k}"
        else:                # s·A_t:  a[base+k, j]
            cell = lambda k: f"am.i = {base} + {k} and am.j = {j}"
        terms = "\n      + ".join(
            f"r.s{k} * (select v from {a} as am where {cell(k)})"
            for k in range(1, d + 1))
        exprs.append(
            f"{terms}\n      + (select v from {b} as bm"
            f" where bm.i = {nxt} and bm.j = {j})")
    scan = (f"{me}_scan(t, {cols}) as (\n"
            f"  select {anchor_t}, {anchor}\n"
            f"  union all\n"
            f"  select {nxt},\n    " + ",\n    ".join(exprs) + "\n"
            f"    from {me}_scan as r\n"
            f"   where {guard}\n)")
    unpivot = "\n  union all ".join(
        f"select t as i, {j} as j, s{j} as v from {me}_scan"
        for j in range(1, d + 1))
    return [scan, f"{me}(i, j, v) as (\n  {unpivot}\n)"]


def _mat_scan_ctes_packed(node: E.MatRecurrence, nm: dict[int, str],
                          dialect) -> list[str]:
    """The matrix-valued scan for the EXECUTABLE relational dialects
    (sqlite/duckdb): each child relation is packed ONCE into an array
    codec inside the statement (order-independent ``group_concat`` of
    ``i,j,v`` cell tags, reassembled by the ``mcellcat`` UDF at exact
    %.17g float round-trip), the recursion carries one packed (1, D)
    state row stepped by ``mrecurstep``, and the unpivot joins the scan
    against a series on j (``mcell``).  Every CTE here references each
    child exactly once — sqlite expands CTE references by textual
    substitution, so the pure-SQL column rendering's O(D²) coefficient
    references multiply through nested scans (Algorithm 1 nests the
    adjoint scan's seed over the forward scan) until the 65535-reference
    hard limit or the 3.34 flattener's LEFT-JOIN mis-ordering; packing
    keeps composition linear."""
    me, a, b = nm[id(node)], nm[id(node.a)], nm[id(node.b)]
    t_rows, d = node.shape
    tr = int(node.transposed)
    anchor_t, nxt, guard = _mat_scan_bounds(node)
    # %.17g round-trips every finite double but NOT the non-finite ones:
    # sqlite stores a bound NaN as NULL (printf then renders the value as
    # 0 — a silent wrong answer), and both engines spell infinities in
    # ways float() happens to accept ("Inf").  Tag NULL/NaN cells
    # explicitly so the mcellcat codec sees the same spellings the VALUES
    # gate produces.
    tag = ("case when v is null or v != v then printf('%d,%d,nan', i, j)"
           " else printf('%d,%d,%.17g', i, j, v) end")
    packs = [
        f"{me}_pa(m) as (\n  select mcellcat(group_concat({tag}, '|'),"
        f" {t_rows * d}, {d}) as m from {a}\n)",
        f"{me}_pb(m) as (\n  select mcellcat(group_concat({tag}, '|'),"
        f" {t_rows}, {d}) as m from {b}\n)",
    ]
    pa, pb = f"(select m from {me}_pa)", f"(select m from {me}_pb)"
    scan = (f"{me}_scan(t, s) as (\n"
            f"  select {anchor_t},"
            f" mrecurstep({pa}, mconst(1,{d},0.0), {pb}, {anchor_t}, {tr})\n"
            f"  union all\n"
            f"  select {nxt}, mrecurstep({pa}, r.s, {pb}, {nxt}, {tr})\n"
            f"    from {me}_scan as r\n"
            f"   where {guard}\n)")
    unpivot = (f"{me}(i, j, v) as (\n"
               f"  select r.t, q.j, mcell(r.s, 1, q.j) as v\n"
               f"  from {me}_scan as r cross join\n"
               f"       {dialect.series_from(d, 'q', 'j')}\n)")
    return packs + [scan, unpivot]


def _mat_scan_ctes(node: E.MatRecurrence, nm: dict[int, str],
                   dialect) -> list[str]:
    """Dialect-dispatching MatRecurrence lowering — both forms are ONE
    genuine recursive CTE carrying the whole state row per tuple."""
    if dialect.mat_scan_rendering == "packed":
        return _mat_scan_ctes_packed(node, nm, dialect)
    return _mat_scan_ctes_columns(node, nm)


def _with_keyword(dialect, recursive: bool = False) -> str:
    """``with`` / ``with recursive`` as the dialect requires.  sqlite's
    emulated series CTEs make the whole statement recursive."""
    return "with recursive" if (recursive or dialect.series_is_recursive) \
        else "with"


# ---------------------------------------------------------------------------
# peephole fusion: collapse single-consumer elementwise chains into one
# SQL expression (ROADMAP "raw speed" item — sqlite's substitution-based
# CTE flattener re-executes every textual reference, so fewer CTEs means
# measurably fewer passes over the same cells)
# ---------------------------------------------------------------------------

_FUSIBLE_DERIVS = (E.SIGMOID, E.SQUARE, E.RELU, E.RECIP)


def _fusible(node) -> bool:
    """Nodes the peephole pass may collapse into a parent's expression:
    the shape-preserving elementwise tier with a per-cell spelling in BOTH
    representations (``MapDeriv``/ONE_MINUS is array-only — excluded)."""
    if isinstance(node, (E.Add, E.Sub, E.Hadamard, E.Scale, E.Map)):
        return True
    return isinstance(node, MapDeriv) and node.fn in _FUSIBLE_DERIVS


def _used_children(node):
    """Children the RENDERED SQL actually references — ``MapDeriv`` keeps
    both ``x`` and ``fx`` pointers but each fn's spelling reads one."""
    if isinstance(node, MapDeriv):
        return (node.fx,) if node.fn in (E.SIGMOID, E.RECIP) else (node.x,)
    return node.children()


def fuse_dag(roots: list[E.Expr]):
    """The fusion analysis: partition the DAG into single-consumer
    elementwise REGIONS, each rendered as ONE SQL expression instead of
    one CTE per node.

    Returns ``(regions, skip)``: ``regions[id(root)] = (members, inputs)``
    — ``members`` the region's nodes (region root first), ``inputs`` the
    deduped boundary nodes its fused expression references (one join leg /
    scalar subquery each); ``skip`` the ids that no longer render a CTE
    (absorbed members, plus constants inlined by every consumer).

    Fan-out safety: a node is absorbed only when the region holds its ONLY
    rendered reference and it is not itself a query root — a multi-consumer
    subexpression is never duplicated.  ``Const`` leaves are the exception:
    they inline as literals (duplicating a literal is free), but a region
    keeps at least one non-Const input so the row frame always comes from a
    real relation, never from a folded-away constant CTE.
    """
    order = E.topo_order(*roots)
    consumers: dict[int, int] = {}
    for nd in order:
        for c in _used_children(nd):
            consumers[id(c)] = consumers.get(id(c), 0) + 1
    root_ids = {id(r) for r in roots}
    absorbed: set[int] = set()
    const_inlined: dict[int, int] = {}
    regions: dict[int, tuple[list, list]] = {}
    for nd in reversed(order):
        if not _fusible(nd) or id(nd) in absorbed:
            continue
        members: list[E.Expr] = []
        inputs: list[E.Expr] = []
        consts: list[E.Expr] = []
        seen: set[int] = set()

        def grow(n):
            members.append(n)
            for c in _used_children(n):
                if isinstance(c, E.Const):
                    consts.append(c)
                elif (_fusible(c) and id(c) not in root_ids
                        and consumers.get(id(c), 0) == 1):
                    grow(c)
                elif id(c) not in seen:
                    seen.add(id(c))
                    inputs.append(c)

        grow(nd)
        if not inputs:  # all-constant region: keep the frame CTEs as-is
            continue
        for c in consts:
            const_inlined[id(c)] = const_inlined.get(id(c), 0) + 1
        absorbed.update(id(m) for m in members[1:])
        regions[id(nd)] = (members, inputs)
    skip = set(absorbed)
    for nd in order:
        if (isinstance(nd, E.Const) and id(nd) not in root_ids
                and 0 < consumers.get(id(nd), 0)
                <= const_inlined.get(id(nd), 0)):
            skip.add(id(nd))
    return regions, skip


def _fused_expr(node: E.Expr, alias: dict[int, str], dialect) -> str:
    """The per-cell expression of a fused region (relational spelling).
    Every non-atomic result is parenthesised, so nesting into duplicating
    map templates (``{v}*{v}``) stays precedence-safe."""
    if id(node) in alias:
        return f"{alias[id(node)]}.v"
    if isinstance(node, E.Const):
        return repr(float(node.value))
    if isinstance(node, (E.Add, E.Sub, E.Hadamard)):
        op = {"Hadamard": "*", "Add": "+", "Sub": "-"}[type(node).__name__]
        return (f"({_fused_expr(node.x, alias, dialect)} {op} "
                f"{_fused_expr(node.y, alias, dialect)})")
    if isinstance(node, E.Scale):
        return f"({node.c} * {_fused_expr(node.x, alias, dialect)})"
    if isinstance(node, E.Map):
        inner = _fused_expr(node.x, alias, dialect)
        return f"({dialect.map_sql(node.fn, inner)})"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:   # out·(1-out) from the cached expression
            fx = _fused_expr(node.fx, alias, dialect)
            return f"({fx} * (1 - {fx}))"
        if node.fn is E.SQUARE:
            return f"(2 * {_fused_expr(node.x, alias, dialect)})"
        if node.fn is E.RELU:
            inner = _fused_expr(node.x, alias, dialect)
            return f"(case when {inner} > 0 then 1 else 0 end)"
        if node.fn is E.RECIP:     # -1/x² = -out² from the cached expression
            fx = _fused_expr(node.fx, alias, dialect)
            return f"(-({fx} * {fx}))"
    raise TypeError(type(node))


def _fused_cte_sql(node: E.Expr, inputs: list[E.Expr],
                   nm: dict[int, str], dialect) -> str:
    """One region, one select: the first boundary input provides the row
    frame, every further input joins on (i, j) exactly once — fan-in
    without fan-out, so no subexpression is ever recomputed."""
    alias = {id(c): f"f{k}" for k, c in enumerate(inputs)}
    expr = _fused_expr(node, alias, dialect)
    frm = f"{nm[id(inputs[0])]} as f0"
    for k, c in enumerate(inputs[1:], start=1):
        frm += (f"\n  inner join {nm[id(c)]} as f{k}"
                f" on f{k}.i = f0.i and f{k}.j = f0.j")
    return f"select f0.i, f0.j, {expr} as v\n  from {frm}"


def _fused_cte_sql_b(node: E.Expr, inputs: list[E.Expr],
                     nm: dict[int, str], dialect, bt) -> str:
    """Batched fused region: batched boundary inputs are reordered first so
    ``f0`` supplies both the row frame and the ``b`` column; further batched
    inputs join on (b, i, j), unbatched inputs broadcast on (i, j)."""
    ordered = ([c for c in inputs if id(c) in bt]
               + [c for c in inputs if id(c) not in bt])
    if id(ordered[0]) not in bt:  # defensive: region root batched ⇒ an input is
        return _fused_cte_sql(node, inputs, nm, dialect)
    alias = {id(c): f"f{k}" for k, c in enumerate(ordered)}
    expr = _fused_expr(node, alias, dialect)
    frm = f"{nm[id(ordered[0])]} as f0"
    for k, c in enumerate(ordered[1:], start=1):
        cond = f"f{k}.i = f0.i and f{k}.j = f0.j"
        if id(c) in bt:
            cond = f"f{k}.b = f0.b and " + cond
        frm += f"\n  inner join {nm[id(c)]} as f{k} on {cond}"
    return f"select f0.b, f0.i, f0.j, {expr} as v\n  from {frm}"


def _fused_array_cte_sql(node: E.Expr, inputs: list[E.Expr],
                         nm: dict[int, str]) -> str:
    """The array-representation fused spelling: the region renders as one
    nested UDF call chain; boundary inputs stay scalar subqueries against
    their CTEs, exactly like the unfused rendering's child references."""
    input_ids = {id(c) for c in inputs}

    def ref(c):
        if id(c) in input_ids:
            return f"(select m from {nm[id(c)]})"
        sql = _array_call(c, ref)
        if sql is None:
            raise TypeError(type(c))
        return sql

    sql = _array_call(node, ref)
    if sql is None:
        raise TypeError(type(node))
    return sql


def _node_ctes(node: E.Expr, nm: dict[int, str], dialect, regions,
               representation: str, bt=frozenset()) -> list[str]:
    """The CTE strings one surviving node renders to (a MatRecurrence
    lowers to several; a fused region root carries its whole region).
    Nodes in ``bt`` render the batched spelling — ``(b, i, j, v)`` /
    ``(b, m)`` columns."""
    batched = id(node) in bt
    if representation == "array":
        if isinstance(node, E.Recurrence):
            return _array_scan_ctes(node, nm)
        if isinstance(node, E.MatRecurrence):
            return _array_mat_scan_ctes(node, nm)
        if batched:
            if id(node) in regions:
                body = _fused_array_cte_sql_b(node, regions[id(node)][1],
                                              nm, bt)
            else:
                body = _array_cte_sql_b(node, nm, bt)
            return [f"{nm[id(node)]}(b, m) as (\n  {body}\n)"]
        if id(node) in regions:
            body = _fused_array_cte_sql(node, regions[id(node)][1], nm)
        else:
            body = _array_cte_sql(node, nm)
        return [f"{nm[id(node)]}(m) as (\n  select {body} as m\n)"]
    if isinstance(node, E.MatRecurrence):
        return _mat_scan_ctes(node, nm, dialect)
    if batched:
        if id(node) in regions:
            body = _fused_cte_sql_b(node, regions[id(node)][1], nm,
                                    dialect, bt)
        else:
            body = _cte_sql_b(node, nm, dialect, bt)
        return [f"{nm[id(node)]}(b, i, j, v) as (\n  {body}\n)"]
    if id(node) in regions:
        body = _fused_cte_sql(node, regions[id(node)][1], nm, dialect)
    else:
        body = _cte_sql(node, nm, dialect)
    return [f"{nm[id(node)]}(i, j, v) as (\n  {body}\n)"]


def _render_ctes(roots: list[E.Expr], dialect, fuse: bool = False,
                 representation: str = "relational", batch=None
                 ) -> tuple[list[str], dict[int, str], bool]:
    """(ctes, id→name map, whether a self-referencing scan is present).
    ``batch`` is the set of batched leaf Var names (None/empty: the plain
    rendering, byte-identical to pre-batch output)."""
    order = E.topo_order(*roots)
    nm = assign_names(order)
    bt = batched_ids(roots, batch) if batch else frozenset()
    regions, skip = fuse_dag(roots) if fuse else ({}, set())
    ctes: list[str] = []
    has_scan = False
    for node in order:
        has_scan = has_scan or isinstance(node, (E.Recurrence,
                                                 E.MatRecurrence))
        if isinstance(node, E.Var) or id(node) in skip:
            continue
        ctes += _node_ctes(node, nm, dialect, regions, representation, bt)
    return ctes, nm, has_scan


def render_ctes(roots: list[E.Expr], dialect=None
                ) -> tuple[list[str], dict[int, str]]:
    """One CTE string per non-leaf node, topologically ordered, plus the
    id→name map used to reference any node (Vars map to their table name;
    auto-named nodes get deterministic names — :func:`assign_names`)."""
    ctes, nm, _ = _render_ctes(roots, _get_dialect(dialect))
    return ctes, nm


def to_sql92(roots: list[E.Expr], select=None, dialect=None,
             fuse: bool = False, batch=None) -> str:
    """Emit a WITH query: one CTE per non-leaf node, topologically ordered.

    ``select`` is the query tail: a literal string, or a callable
    ``select(nm)`` receiving the id→name map (use the callable form for
    tails that reference auto-named roots — their CTE names are assigned at
    render time).  ``fuse=True`` runs the :func:`fuse_dag` peephole pass
    first: single-consumer elementwise chains collapse into one CTE.
    ``batch`` names the batched leaf Vars (see :func:`batched_ids`)."""
    dialect = _get_dialect(dialect)
    # has_scan: a Recurrence CTE references itself — WITH must say RECURSIVE
    ctes, nm, has_scan = _render_ctes(roots, dialect, fuse=fuse, batch=batch)
    if callable(select):
        select = select(nm)
    root_batched = batch and id(roots[-1]) in batched_ids(roots, batch)
    order_cols = "b, i, j" if root_batched else "i, j"
    tail = select or (f"select * from {nm[id(roots[-1])]} "
                      f"order by {order_cols}")
    if not ctes:  # every root is a stored table
        return f"{tail};"
    body = ",\n".join(ctes)
    return f"{_with_keyword(dialect, recursive=has_scan)} {body}\n{tail};"


def multi_root_select(roots: list[E.Expr], batch=None):
    """A union-all tail tagging each root's tuples with its position — lets
    one statement return every output of a multi-root DAG (loss + grads).
    Returns a callable for :func:`to_sql92`'s ``select`` so each root is
    addressed by its render-time name (its CTE, or its table if a Var).
    With ``batch`` the tail carries the request index next to the root tag
    — ``(r, b, i, j, v)`` — and unbatched roots emit ``-1`` (broadcast to
    every request at decode time)."""
    bt = batched_ids(roots, batch) if batch else None

    def tail(nm: dict[int, str]) -> str:
        if bt is None:
            return "\nunion all ".join(
                f"select {k} as r, i, j, v from {nm[id(r)]}"
                for k, r in enumerate(roots))
        return "\nunion all ".join(
            (f"select {k} as r, b, i, j, v from {nm[id(r)]}"
             if id(r) in bt else
             f"select {k} as r, -1 as b, i, j, v from {nm[id(r)]}")
            for k, r in enumerate(roots))

    return tail


def multi_root_select_array(roots: list[E.Expr], batch=None):
    """The array-representation multi-root tail: one ``(r, m)`` row per
    root, ``m`` the JSON array codec of the whole matrix — ``(r, b, m)``
    with a batch, ``b = -1`` for unbatched (broadcast) roots."""
    bt = batched_ids(roots, batch) if batch else None

    def tail(nm: dict[int, str]) -> str:
        if bt is None:
            return "\nunion all ".join(
                f"select {k} as r, m from {nm[id(r)]}"
                for k, r in enumerate(roots))
        return "\nunion all ".join(
            (f"select {k} as r, b, m from {nm[id(r)]}"
             if id(r) in bt else
             f"select {k} as r, -1 as b, m from {nm[id(r)]}")
            for k, r in enumerate(roots))

    return tail


def multi_root_tail(roots: list[E.Expr], dialect=None, batch=None):
    """The multi-root union tail matching the dialect's representation."""
    if _get_dialect(dialect).representation == "array":
        return multi_root_select_array(roots, batch=batch)
    return multi_root_select(roots, batch=batch)


def to_sql(roots: list[E.Expr], select=None, dialect=None,
           fuse: bool = False, batch=None) -> str:
    """The representation-dispatching entry point: relational dialects
    render through :func:`to_sql92` (one cell-relation CTE per node), the
    array dialect through :func:`to_sql_array_ctes` (one array-typed row
    per node).  This is what :meth:`repro.db.plan_cache.PlanCache.dag_sql`
    and ``SQLEngine`` call."""
    dialect = _get_dialect(dialect)
    if dialect.representation == "array":
        return to_sql_array_ctes(roots, select=select, fuse=fuse,
                                 batch=batch)
    return to_sql92(roots, select=select, dialect=dialect, fuse=fuse,
                    batch=batch)


# ---------------------------------------------------------------------------
# spooled plans: materialise multi-referenced subplans as temp tables
# ---------------------------------------------------------------------------

_PLAN_HEADER = "-- repro:plan v1"
_STEP_MARK = "-- repro:step "
_MAIN_MARK = "-- repro:main"


@dataclasses.dataclass(frozen=True)
class Plan:
    """A rendered evaluation plan: ordered spool ``steps`` — ``(temp
    table, create-statement)`` pairs materialising multi-referenced
    subplans — followed by the main statement ``sql``.  Engines without
    the substitution-flattener pathology get zero steps.  Text round-trip
    (:meth:`to_text` / :meth:`from_text`) is what the plan cache stores."""
    sql: str
    steps: tuple = ()

    def to_text(self) -> str:
        if not self.steps:
            return self.sql
        parts = [_PLAN_HEADER]
        for tbl, sql in self.steps:
            parts.append(f"{_STEP_MARK}{tbl}")
            parts.append(sql)
        parts.append(_MAIN_MARK)
        parts.append(self.sql)
        return "\n".join(parts)

    @classmethod
    def from_text(cls, text) -> "Plan":
        if isinstance(text, Plan):
            return text
        if not text.startswith(_PLAN_HEADER):
            return cls(sql=text)
        steps: list[tuple[str, str]] = []
        table, buf, main = None, [], None
        for line in text.split("\n")[1:]:
            if line.startswith(_STEP_MARK) or line == _MAIN_MARK:
                if table is not None:
                    steps.append((table, "\n".join(buf)))
                table, buf = (line[len(_STEP_MARK):], []) \
                    if line != _MAIN_MARK else (None, [])
                if line == _MAIN_MARK:
                    main = []
                    buf = main
            else:
                buf.append(line)
        if main is None:
            raise ValueError("malformed plan text: missing main statement")
        return cls(sql="\n".join(main), steps=tuple(steps))


def _render_refs(node: E.Expr, regions, representation: str):
    """(child, multiplicity) pairs of the table references ``node``'s
    rendered SQL makes — the spool pass's cost model.  Overcounting is
    harmless (a relation gets spooled that did not strictly need it);
    undercounting re-executes a CTE under substitution semantics."""
    if id(node) in regions:
        return [(c, 1) for c in regions[id(node)][1]]
    if isinstance(node, MapDeriv):
        return [(c, 1) for c in _used_children(node)]
    if isinstance(node, E.Softmax) and representation == "relational":
        return [(node.x, 3)]     # row max, denominator, and the cell scan
    if isinstance(node, E.Recurrence):
        # b seeds the anchor AND steps; a is counted twice ON PURPOSE —
        # under substitution CTE semantics (sqlite) the recursive member
        # re-executes its reference to a at every step, so the spool pass
        # must materialise the scan INPUT as a temp table.  That is also
        # what makes scans COMPOSE: a nested scan's inner recursion runs
        # once as its own spooled statement instead of being substituted
        # into the outer recursive member.
        return [(node.a, 2), (node.b, 2)]
    if isinstance(node, E.MatRecurrence) and representation == "array":
        return [(node.a, 2), (node.b, 2)]   # anchor + recursive member
    return [(c, 1) for c in node.children()]


def render_plan(roots: list[E.Expr], select=None, dialect=None,
                fuse: bool = False, spool: bool = False,
                spool_threshold: int = 2, batch=None) -> Plan:
    """Render a DAG as a :class:`Plan`.  With ``spool=False`` this is
    :func:`to_sql` in a one-statement plan.  With ``spool=True`` every
    non-leaf relation referenced >= ``spool_threshold`` times across the
    statement is materialised first as a ``create temp table`` step and the
    remaining statements reference the table — on engines that flatten CTEs
    by textual substitution (sqlite < 3.35, no MATERIALIZED hint) each
    reference re-executes the subplan, so a shared matmul otherwise runs
    once per consumer.  ``spool_threshold=1`` spools *every* non-leaf node
    (one step per IR node) — the per-node profiled execution mode of
    :mod:`repro.obs.profiler`.  ``batch`` names the batched leaf Vars:
    batched spool steps carry the ``b`` column through their temp tables."""
    dialect = _get_dialect(dialect)
    rep = dialect.representation
    if not spool:
        return Plan(sql=to_sql(roots, select=select, dialect=dialect,
                               fuse=fuse, batch=batch))
    order = E.topo_order(*roots)
    nm = assign_names(order)
    bt = batched_ids(roots, batch) if batch else frozenset()
    regions, skip = fuse_dag(roots) if fuse else ({}, set())
    nodes = [n for n in order
             if not isinstance(n, E.Var) and id(n) not in skip]
    refs: dict[int, int] = {}
    for n in nodes:
        for c, k in _render_refs(n, regions, rep):
            if not isinstance(c, E.Var):
                refs[id(c)] = refs.get(id(c), 0) + k
    for r in roots:                      # the tail references each root
        if not isinstance(r, E.Var):
            refs[id(r)] = refs.get(id(r), 0) + 1
    spooled = [n for n in nodes if refs.get(id(n), 0) >= spool_threshold]
    spooled_ids = {id(n) for n in spooled}
    sp_name = {id(n): f"_sp_{nm[id(n)]}" for n in spooled}

    def member_nodes(starts, target_id=None):
        """The nodes whose CTEs one statement needs: the render-reference
        closure of ``starts``, stopping at leaves and at OTHER spooled
        relations (those are plain tables by the time this runs)."""
        seen: set[int] = set()

        def visit(n):
            if isinstance(n, E.Var) or id(n) in seen:
                return
            if id(n) in spooled_ids and id(n) != target_id:
                return
            seen.add(id(n))
            for c, _ in _render_refs(n, regions, rep):
                visit(c)

        for s in starts:
            visit(s)
        return [n for n in nodes if id(n) in seen]

    def statement(member, nm_use, tail):
        ctes: list[str] = []
        has_scan = False
        for n in member:
            has_scan = has_scan or isinstance(n, (E.Recurrence,
                                                  E.MatRecurrence))
            ctes += _node_ctes(n, nm_use, dialect, regions, rep, bt)
        if not ctes:
            return f"{tail};"
        body = ",\n".join(ctes)
        kw = ("with recursive" if has_scan else "with") if rep == "array" \
            else _with_keyword(dialect, recursive=has_scan)
        return f"{kw} {body}\n{tail};"

    steps: list[tuple[str, str]] = []
    for s in spooled:
        nm_s = dict(nm)
        for t in spooled:
            if t is not s:
                nm_s[id(t)] = sp_name[id(t)]
        if id(s) in bt:
            tail_s = (f"select b, m from {nm[id(s)]}" if rep == "array"
                      else f"select b, i, j, v from {nm[id(s)]}")
        else:
            tail_s = (f"select m from {nm[id(s)]}" if rep == "array"
                      else f"select i, j, v from {nm[id(s)]}")
        body = statement(member_nodes([s], id(s)), nm_s, tail_s)
        steps.append((sp_name[id(s)],
                      f"create temp table {sp_name[id(s)]} as\n{body}"))
    nm_main = dict(nm)
    for t in spooled:
        nm_main[id(t)] = sp_name[id(t)]
    if callable(select):
        tail_main = select(nm_main)
    elif select:
        tail_main = select
    elif rep == "array":
        cols = "b, m" if id(roots[-1]) in bt else "m"
        tail_main = f"select {cols} from {nm_main[id(roots[-1])]}"
    else:
        order_by = "b, i, j" if id(roots[-1]) in bt else "i, j"
        tail_main = (f"select * from {nm_main[id(roots[-1])]} "
                     f"order by {order_by}")
    main = statement(member_nodes(roots), nm_main, tail_main)
    return Plan(sql=main, steps=tuple(steps))


def _training_step_parts(graph, lr: float, dialect,
                         iter_guard: str | None = None
                         ) -> tuple[list[str], str]:
    """The shared body of one Listing-7 gradient step: the forward/backward
    CTEs (weights read from ``w_``) and the weight-update select.  Used by
    both the recursive training query and the stepped INSERT…SELECT
    execution (:func:`training_step_sql92`)."""
    grads = derive(graph.loss, E.const(1.0, graph.loss.shape))
    g_xh, g_ho = grads[graph.w_xh], grads[graph.w_ho]
    order = E.topo_order(graph.loss, g_xh, g_ho)
    nm = assign_names(order)
    ctes: list[str] = []
    for node in order:
        if isinstance(node, E.Var):
            if node.name in ("w_xh", "w_ho"):
                wid = 0 if node.name == "w_xh" else 1
                ctes.append(
                    f"{node.name}(i, j, v) as (\n"
                    f"  select i, j, v from w_ where id = {wid}\n"
                    f"   and iter = (select max(iter) from w_)\n)")
            continue
        ctes.append(f"{nm[id(node)]}(i, j, v) as "
                    f"(\n  {_cte_sql(node, nm, dialect)}\n)")
    ctes.append(
        "d_w(id, i, j, v) as (\n"
        f"    select 0, i, j, v from {nm[id(g_xh)]} union all\n"
        f"    select 1, i, j, v from {nm[id(g_ho)]}\n"
        "  )")
    guard = f"\n   where {iter_guard}" if iter_guard else "\n   where 1 = 1"
    update = (
        "select w_.iter + 1, w_.id, w_.i, w_.j,\n"
        f"         w_.v - {lr} * d_w.v\n"
        "    from w_, d_w"
        f"{guard} and w_.id = d_w.id\n"
        "     and w_.i = d_w.i and w_.j = d_w.j")
    return ctes, update


def training_query_sql92(graph, n_iters: int, lr: float, dialect=None) -> str:
    """Listing 7: the recursive CTE whose step evaluates the model, runs
    Algorithm 1's CTEs, and emits the updated weight table.

    Note: sqlite cannot execute this shape (the recursive table appears
    inside a nested WITH — ``dialect.supports_listing7``); there the
    training loop runs :func:`training_query_array_calls` or the stepped
    :func:`training_step_sql92` instead.
    """
    dialect = _get_dialect(dialect)
    ctes, update = _training_step_parts(graph, lr, dialect,
                                        iter_guard=f"w_.iter < {n_iters}")
    body = ",\n".join(ctes)
    return (
        "with recursive w (iter, id, i, j, v) as (\n"
        "  (select 0, 0, * from w_xh_init union all\n"
        "   select 0, 1, * from w_ho_init)\n"
        "  union all\n"
        "  select * from (\n"
        "  with w_(iter, id, i, j, v) as (\n"
        "    select * from w  -- recursive reference only allowed once\n"
        f"  ),\n{body}\n"
        f"  {update}\n"
        "  ) step\n"
        ")\nselect * from w;")


def training_step_sql92(graph, lr: float, dialect=None,
                        weights_table: str = "w") -> str:
    """One Listing-7 step as ``INSERT INTO w … SELECT``: reads the latest
    weight version from the history table, appends the updated one.  This is
    the recursive step *materialised* — semantically the body of Listing 7's
    recursion, executable on engines (sqlite) whose recursive CTEs cannot
    re-read the whole previous weight table."""
    dialect = _get_dialect(dialect)
    ctes, update = _training_step_parts(graph, lr, dialect)
    w_ = (f"w_(iter, id, i, j, v) as (\n"
          f"  select iter, id, i, j, v from {weights_table}\n"
          f"   where iter = (select max(iter) from {weights_table})\n)")
    body = ",\n".join([w_] + ctes)
    return (f"{_with_keyword(dialect, recursive=True)} {body}\n"
            f"insert into {weights_table}\n{update};")


# ---------------------------------------------------------------------------
# SQL + Arrays (Listing 10 style)
# ---------------------------------------------------------------------------

def _array_expr(node: E.Expr) -> str:
    a = _array_expr
    if isinstance(node, E.Var):
        return node.name
    if isinstance(node, E.Const):
        return str(node.value)  # broadcast scalar, as in ``1 - a_ho``
    if isinstance(node, E.MatMul):
        return f"({a(node.x)} ** {a(node.y)})"
    if isinstance(node, E.Hadamard):
        return f"({a(node.x)} * {a(node.y)})"
    if isinstance(node, E.Add):
        return f"({a(node.x)} + {a(node.y)})"
    if isinstance(node, E.Sub):
        return f"({a(node.x)} - {a(node.y)})"
    if isinstance(node, E.Scale):
        return f"({node.c} * {a(node.x)})"
    if isinstance(node, E.Transpose):
        return f"transpose({a(node.x)})"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:
            return f"({a(node.fx)} * (1 - {a(node.fx)}))"
        if node.fn is E.SQUARE:
            return f"(2 * {a(node.x)})"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, E.Map):
        return f"{node.fn.name}({a(node.x)})"
    raise TypeError(type(node))


def to_sql_arrays(roots: list[E.Expr]) -> str:
    """Nested select with one derived-table level per CTE (Listing 10)."""
    full_order = E.topo_order(*roots)
    nm = assign_names(full_order)
    order = [n for n in full_order if not isinstance(n, (E.Var, E.Const))]
    # innermost: the raw tables; each level materialises one named expression
    inner = "select * from data, weights"
    for node in order:
        expr_sql = _array_expr_shallow(node, nm)
        inner = (f"select {expr_sql} as {nm[id(node)]}, *"
                 f" from (\n{inner}) q_{nm[id(node)]}")
    return inner + ";"


def _array_expr_shallow(node: E.Expr, nm: dict[int, str]) -> str:
    """Like _array_expr but children referenced by their CTE names."""
    name = lambda c: (str(c.value) if isinstance(c, E.Const) else nm[id(c)])
    if isinstance(node, E.MatMul):
        return f"({name(node.x)} ** {name(node.y)})"
    if isinstance(node, E.Hadamard):
        return f"({name(node.x)} * {name(node.y)})"
    if isinstance(node, E.Add):
        return f"({name(node.x)} + {name(node.y)})"
    if isinstance(node, E.Sub):
        return f"({name(node.x)} - {name(node.y)})"
    if isinstance(node, E.Scale):
        return f"({node.c} * {name(node.x)})"
    if isinstance(node, E.Transpose):
        return f"transpose({name(node.x)})"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:
            return f"({name(node.fx)} * (1 - {name(node.fx)}))"
        if node.fn is E.SQUARE:
            return f"(2 * {name(node.x)})"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, E.Map):
        return f"{node.fn.name}({name(node.x)})"
    raise TypeError(type(node))


def training_query_arrays(graph, n_iters: int, lr: float) -> str:
    """Listing 10: recursive table over array-typed weight columns, with one
    named derived-table level per cached expression (a_xh, a_ho, l_ho, …) so
    the backward pass reuses the forward CTEs exactly as the paper does."""
    grads = derive(graph.loss, E.const(1.0, graph.loss.shape))
    g_xh, g_ho = grads[graph.w_xh], grads[graph.w_ho]
    full_order = E.topo_order(g_xh, g_ho)
    nm = assign_names(full_order)
    order = [n for n in full_order if not isinstance(n, (E.Var, E.Const))]
    inner = f"select * from data, w where id < {n_iters}"
    for node in order:
        inner = (f"select {_array_expr_shallow(node, nm)} as {nm[id(node)]}, *"
                 f" from (\n{inner}) q_{nm[id(node)]}")
    return (
        "with recursive w (id, w_xh, w_ho) as (\n"
        "  select 0, w_xh, w_ho from weights\n"
        "  union all\n"
        "  select id + 1,\n"
        f"         w_xh - {lr} * {nm[id(g_xh)]},\n"
        f"         w_ho - {lr} * {nm[id(g_ho)]}\n"
        f"    from (\n{inner})\n"
        ")\nselect * from w;")


# ---------------------------------------------------------------------------
# SQL + Arrays, function-call rendering (executable UDF array extension)
# ---------------------------------------------------------------------------

def _array_call(node: E.Expr, ref):
    """The shared UDF-call spelling of the dense 2-D algebra + Map/MapDeriv
    tier; ``ref(child)`` renders a child reference — the inline recursion
    of :func:`array_call_expr` or the scalar subquery of the array-dialect
    CTE rendering.  Returns ``None`` for node types outside this tier (the
    zoo primitives and ``ReduceDeriv``, handled per renderer)."""
    if isinstance(node, E.Const):
        r, c = node.shape
        return f"mconst({r},{c},{node.value})"
    if isinstance(node, E.MatMul):
        return f"mm({ref(node.x)}, {ref(node.y)})"
    if isinstance(node, E.Hadamard):
        return f"mhad({ref(node.x)}, {ref(node.y)})"
    if isinstance(node, E.Add):
        return f"madd({ref(node.x)}, {ref(node.y)})"
    if isinstance(node, E.Sub):
        return f"msub({ref(node.x)}, {ref(node.y)})"
    if isinstance(node, E.Scale):
        return f"mscale({node.c}, {ref(node.x)})"
    if isinstance(node, E.Transpose):
        return f"mt({ref(node.x)})"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:      # out·(1-out) from the cached output
            return f"msigd({ref(node.fx)})"
        if node.fn is E.SQUARE:
            return f"msqrd({ref(node.x)})"
        if node.fn is E.RELU:
            return f"mrelud({ref(node.x)})"
        if node.fn is E.RECIP:        # -1/x² = -out² from the cached output
            return f"mrecipd({ref(node.fx)})"
        if node.fn is E.ONE_MINUS:
            r, c = node.shape
            return f"mconst({r},{c},-1.0)"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, E.Map):
        return f"{node.fn.udf}({ref(node.x)})"
    return None


def array_call_expr(node: E.Expr, leaf) -> str:
    """Render a DAG as nested calls over the UDF array extension
    (:data:`repro.db.dialect.ARRAY_UDFS`).  ``leaf(name)`` maps a Var to a
    column reference (e.g. ``w_xh`` → ``w.w_xh``).

    Unlike the CTE renderings this *inlines* shared subexpressions — the
    price of sqlite's recursive-select restrictions, which forbid the
    derived-table levels Listing 10 uses for reuse.
    """
    if isinstance(node, E.Var):
        return leaf(node.name)
    sql = _array_call(node, lambda n: array_call_expr(n, leaf))
    if sql is None:
        raise TypeError(type(node))
    return sql


# ---------------------------------------------------------------------------
# the array dialect: one CTE per node, each ONE array-typed row
# ---------------------------------------------------------------------------

def _array_node_sql(node: E.Expr, ref) -> str:
    """The UDF-call spelling of any non-scan node over an arbitrary child
    reference renderer ``ref`` — the algebra/Map tier from the shared
    :func:`_array_call` table, the zoo primitives spelled here.  Both the
    plain and the batched array CTE renderings delegate to this."""
    sql = _array_call(node, ref)
    if sql is not None:
        return sql
    if isinstance(node, ReduceDeriv):
        return f"mmaxind({ref(node.x)}, {ref(node.red)})"
    if isinstance(node, E.RowReduce):
        return f"mreduce({ref(node.x)}, '{node.kind}', {node.axis})"
    if isinstance(node, E.Softmax):
        return f"msoftmax({ref(node.x)})"
    if isinstance(node, E.ArgTopK):
        return f"mtopk({ref(node.x)}, {node.k})"
    if isinstance(node, E.Gather):
        return f"mgather({ref(node.x)}, {ref(node.idx)})"
    if isinstance(node, E.Scatter):
        return f"mscatter({ref(node.x)}, {ref(node.idx)}, {node.shape[0]})"
    if isinstance(node, E.RowShift):
        return f"mrowshift({ref(node.x)}, {node.offset})"
    if isinstance(node, E.StepOuter):
        return f"mstepouter({ref(node.x)}, {ref(node.y)})"
    raise TypeError(type(node))


def _array_cte_sql(node: E.Expr, nm: dict[int, str]) -> str:
    """Render one node's matrix as a select-clause expression over the UDF
    array extension — the array-dialect twin of :func:`_cte_sql`.  Children
    are scalar subqueries against their CTEs (or leaf tables), so shared
    subexpressions stay shared exactly as in the relational rendering."""
    return _array_node_sql(node, lambda c: f"(select m from {nm[id(c)]})")


def _batched_array_legs(children, nm: dict[int, str], bt):
    """(alias map, FROM clause) over the *batched* children of an array
    node: each becomes a join leg equated on ``b``; order deduped by id."""
    legs, seen = [], set()
    for c in children:
        if id(c) in bt and id(c) not in seen:
            seen.add(id(c))
            legs.append(c)
    alias = {id(c): f"f{k}" for k, c in enumerate(legs)}
    frm = f"{nm[id(legs[0])]} as f0"
    for k, c in enumerate(legs[1:], start=1):
        frm += f" inner join {nm[id(c)]} as f{k} on f{k}.b = f0.b"
    return alias, frm


def _array_cte_sql_b(node: E.Expr, nm: dict[int, str], bt) -> str:
    """Batched array rendering: one ``(b, m)`` row per request.  Batched
    children ride as join legs on ``b`` (their ``m`` referenced per row);
    unbatched children stay the scalar subqueries of the plain rendering —
    shared weights are read once per request row, same values each time."""
    alias, frm = _batched_array_legs(_used_children(node), nm, bt)
    ref = lambda c: (f"{alias[id(c)]}.m" if id(c) in alias
                     else f"(select m from {nm[id(c)]})")
    return f"select f0.b as b, {_array_node_sql(node, ref)} as m\n  from {frm}"


def _fused_array_cte_sql_b(node: E.Expr, inputs: list[E.Expr],
                           nm: dict[int, str], bt) -> str:
    """Batched fused array region: the region's call chain inlines as in
    the unbatched spelling, but batched boundary inputs become join legs
    on ``b`` instead of scalar subqueries."""
    input_ids = {id(c) for c in inputs}
    alias, frm = _batched_array_legs(inputs, nm, bt)

    def ref(c):
        if id(c) in alias:
            return f"{alias[id(c)]}.m"
        if id(c) in input_ids:
            return f"(select m from {nm[id(c)]})"
        sql = _array_call(c, ref)
        if sql is None:
            raise TypeError(type(c))
        return sql

    sql = _array_call(node, ref)
    if sql is None:
        raise TypeError(type(node))
    return f"select f0.b as b, {sql} as m\n  from {frm}"


def _array_rows_reassembly(me: str) -> str:
    """The trajectory-reassembly CTE shared by both scan lowerings: each
    scan row's (t, state) pair is tagged ``t:<codec>`` and concatenated
    with the engine's NATIVE string aggregate (``group_concat`` — sqlite
    builtin, duckdb ``string_agg`` alias), then one scalar UDF
    (``mrowcat``) splits, sorts by t and vstacks.  Order-independent, so
    forward/reverse scans and duckdb's unordered aggregation all
    reassemble correctly — and, unlike the former ``magg_rows`` Python
    aggregate (sqlite-only: duckdb has no Python aggregate API), it runs
    on every connection the array dialect rides."""
    return (f"{me}(m) as (\n"
            f"  select mrowcat(group_concat(cast(t as text) || ':' || s,"
            f" '|')) as m from {me}_scan\n)")


def _array_scan_ctes(node: E.Recurrence, nm: dict[int, str]) -> list[str]:
    """The Recurrence as TWO array-dialect CTEs: a recursive scan whose
    state is ONE array-typed row per step (``s_t`` as a (1, C) matrix — not
    the relational recursion's C cells per step), and the dialect-portable
    reassembly of the (T, C) trajectory (:func:`_array_rows_reassembly`)."""
    me = nm[id(node)]
    a, b = (f"(select m from {nm[id(node.a)]})",
            f"(select m from {nm[id(node.b)]})")
    t_rows = node.shape[0]
    anchor, nxt, guard = (1, "r.t + 1", f"r.t < {t_rows}") \
        if not node.reverse else (t_rows, "r.t - 1", "r.t > 1")
    step = f"madd(mhad(mrow({a}, {nxt}), r.s), mrow({b}, {nxt}))"
    scan = (f"{me}_scan(t, s) as (\n"
            f"  select {anchor}, mrow({b}, {anchor})\n"
            f"  union all\n"
            f"  select {nxt}, {step}\n"
            f"    from {me}_scan as r\n"
            f"   where {guard}\n)")
    return [scan, _array_rows_reassembly(me)]


def _array_mat_scan_ctes(node: E.MatRecurrence, nm: dict[int, str]
                         ) -> list[str]:
    """The matrix-valued scan in the array dialect: ONE genuine recursive
    CTE whose state is a single array-typed (1, D) row, each step one
    ``mrecurstep`` call (s·A_t + b_t, block sliced from the stack inside
    the UDF; the `transposed` flag rides as the last argument), then the
    shared trajectory reassembly.  This is the lowering the relational
    representation cannot express recursively — the matvec lives inside
    the scalar UDF, so the recursive member stays aggregate-free."""
    me = nm[id(node)]
    a, b = (f"(select m from {nm[id(node.a)]})",
            f"(select m from {nm[id(node.b)]})")
    d = node.shape[1]
    tr = int(node.transposed)
    anchor, nxt, guard = _mat_scan_bounds(node)
    scan = (f"{me}_scan(t, s) as (\n"
            f"  select {anchor},"
            f" mrecurstep({a}, mconst(1,{d},0.0), {b}, {anchor}, {tr})\n"
            f"  union all\n"
            f"  select {nxt}, mrecurstep({a}, r.s, {b}, {nxt}, {tr})\n"
            f"    from {me}_scan as r\n"
            f"   where {guard}\n)")
    return [scan, _array_rows_reassembly(me)]


def to_sql_array_ctes(roots: list[E.Expr], select=None,
                      fuse: bool = False, batch=None) -> str:
    """Emit the array-dialect WITH query: one single-row CTE per non-leaf
    node, topologically ordered — Listing 10's named-expression reuse with
    the executable UDF spelling.  ``select`` follows the :func:`to_sql92`
    contract (string, or callable over the id→name map); the default tail
    returns the last root's array value.  ``fuse=True`` collapses
    single-consumer elementwise chains into nested UDF calls.  ``batch``
    names the batched leaf Vars (their tables carry ``(b, m)`` rows)."""
    ctes, nm, has_scan = _render_ctes(roots, None, fuse=fuse,
                                      representation="array", batch=batch)
    if callable(select):
        select = select(nm)
    root_batched = batch and id(roots[-1]) in batched_ids(roots, batch)
    root_cols = "b, m" if root_batched else "m"
    tail = select or f"select {root_cols} from {nm[id(roots[-1])]}"
    if not ctes:  # every root is a stored table
        return f"{tail};"
    body = ",\n".join(ctes)
    return f"{'with recursive' if has_scan else 'with'} {body}\n{tail};"


def training_query(graph, n_iters: int, lr: float, dialect=None) -> str:
    """The fully-in-database training recursion for a dialect: Listing 7
    verbatim where the engine can run it, the Listing-10 array recursion
    for the array dialect.  (sqlite's relational representation has no
    single-query recursion — use :func:`training_step_sql92` stepped.)"""
    dialect = _get_dialect(dialect)
    if dialect.representation == "array":
        return training_query_array_calls(graph, n_iters, lr)
    if dialect.supports_listing7:
        return training_query_sql92(graph, n_iters, lr, dialect)
    raise ValueError(
        f"dialect {dialect.name!r} cannot run a single-query training "
        f"recursion in the relational representation; use the stepped "
        f"strategy (training_step_sql92) or the array representation")


def training_query_array_calls(graph, n_iters: int, lr: float) -> str:
    """The Listing-10 training recursion in the shape sqlite can execute:
    the whole weight state is ONE row of array-typed columns, the recursive
    table appears exactly once in the top-level FROM, and each new weight
    column is a single inlined expression over the UDF array extension.

    ``weights(w_xh, w_ho)`` and ``data(img, one_hot)`` are single-row tables
    of JSON-encoded matrices (``repro.db.dialect.matrix_to_json``).
    """
    grads = derive(graph.loss, E.const(1.0, graph.loss.shape))
    g_xh, g_ho = grads[graph.w_xh], grads[graph.w_ho]
    data_vars = {graph.img.name, graph.one_hot.name}

    def leaf(name: str) -> str:
        return f"data.{name}" if name in data_vars else f"w.{name}"

    g_xh_sql = array_call_expr(g_xh, leaf)
    g_ho_sql = array_call_expr(g_ho, leaf)
    return (
        "with recursive w (iter, w_xh, w_ho) as (\n"
        "  select 0, w_xh, w_ho from weights\n"
        "  union all\n"
        "  select w.iter + 1,\n"
        f"         msub(w.w_xh, mscale({lr}, {g_xh_sql})),\n"
        f"         msub(w.w_ho, mscale({lr}, {g_ho_sql}))\n"
        "    from w, data\n"
        f"   where w.iter < {n_iters}\n"
        ")\nselect iter, w_xh, w_ho from w;")
