with recursive w (id, w_xh, w_ho) as (
  select 0, w_xh, w_ho from weights
  union all
  select id + 1,
         w_xh - 0.05 * mm_c7,
         w_ho - 0.05 * mm_c9
    from (
select (t_c8 ** had_c3) as mm_c9, * from (
select transpose(a_xh) as t_c8, * from (
select (t_c0 ** had_c6) as mm_c7, * from (
select (mm_c5 * dsig_a_xh) as had_c6, * from (
select (a_xh * (1 - a_xh)) as dsig_a_xh, * from (
select (had_c3 ** t_c4) as mm_c5, * from (
select transpose(w_ho) as t_c4, * from (
select (had_c2 * dsig_a_ho) as had_c3, * from (
select (a_ho * (1 - a_ho)) as dsig_a_ho, * from (
select (1.0 * dsqr_loss) as had_c2, * from (
select (2 * diff) as dsqr_loss, * from (
select sqr(diff) as loss, * from (
select (a_ho - one_hot) as diff, * from (
select sig(z_ho) as a_ho, * from (
select (a_xh ** w_ho) as z_ho, * from (
select sig(z_xh) as a_xh, * from (
select (img ** w_xh) as z_xh, * from (
select transpose(img) as t_c0, * from (
select * from data, w where id < 10) q_t_c0) q_z_xh) q_a_xh) q_z_ho) q_a_ho) q_diff) q_loss) q_dsqr_loss) q_had_c2) q_dsig_a_ho) q_had_c3) q_t_c4) q_mm_c5) q_dsig_a_xh) q_had_c6) q_mm_c7) q_t_c8) q_mm_c9)
)
select * from w;
