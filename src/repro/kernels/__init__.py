"""Pallas TPU kernels for the paper's compute hot-spots.

Each kernel: <name>.py (pl.pallas_call + BlockSpec), with its jit wrapper in
ops.py and its pure-jnp oracle in ref.py. Validated in interpret mode on CPU;
TPU (v5e) is the compilation target.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
