"""The "array data type" engine (paper Section 5).

The paper's second backend extends SQL arrays (``float[][]``) with matrix
algebra: ``**`` (matmul), ``*`` (Hadamard), ``-``, ``transpose``, ``sig`` and
elementwise aggregation. Here the array data type is simply a dense
``jnp.ndarray`` and the operations map 1:1 onto XLA ops; XLA's fusion pass
performs the "condensing of subsequent calls" that §6.3.2 plans as future
work for the database's query optimiser.
"""
from __future__ import annotations

import jax.numpy as jnp

from . import expr as E
from .autodiff import MapDeriv


def evaluate(roots: list[E.Expr], env: dict[str, jnp.ndarray]) -> list[jnp.ndarray]:
    """Evaluate expression DAG(s) with per-node memoisation (CTE caching)."""
    cache: dict[int, jnp.ndarray] = {}

    def ev(node: E.Expr) -> jnp.ndarray:
        if id(node) in cache:
            return cache[id(node)]
        if isinstance(node, E.Var):
            out = env[node.name]
        elif isinstance(node, E.Const):
            out = jnp.full(node.shape, node.value, dtype=jnp.float32)
        elif isinstance(node, E.MatMul):
            out = ev(node.x) @ ev(node.y)
        elif isinstance(node, E.Hadamard):
            out = ev(node.x) * ev(node.y)
        elif isinstance(node, E.Add):
            out = ev(node.x) + ev(node.y)
        elif isinstance(node, E.Sub):
            out = ev(node.x) - ev(node.y)
        elif isinstance(node, E.Scale):
            out = node.c * ev(node.x)
        elif isinstance(node, E.Transpose):
            out = ev(node.x).T
        elif isinstance(node, MapDeriv):
            out = node.fn.df(ev(node.x), ev(node.fx))
        elif isinstance(node, E.Map):
            out = node.fn.fn(ev(node.x))
        else:  # pragma: no cover
            raise TypeError(f"unknown node {type(node)}")
        cache[id(node)] = out
        return out

    return [ev(r) for r in roots]
