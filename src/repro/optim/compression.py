"""Gradient compression for the cross-pod hop (distributed-optimization).

int8 block quantisation with error feedback: the quantisation residual is
carried to the next step, so compression error accumulates to zero in
expectation (1-bit Adam / EF-SGD lineage). Used by the trainer for the
``pod`` axis all-reduce — the DCI link between pods is the thinnest pipe in
the production mesh, and int8 cuts its traffic 4× vs f32 (2× vs bf16).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

BLOCK = 256


def _pad_to_block(x):
    flat = x.reshape(-1)
    pad = (-flat.shape[0]) % BLOCK
    return jnp.pad(flat, (0, pad)), pad


def quantize_int8(x: jax.Array):
    """→ (q int8 [n/B, B], scales f32 [n/B, 1], meta) block-wise symmetric."""
    flat, pad = _pad_to_block(x.astype(jnp.float32))
    blocks = flat.reshape(-1, BLOCK)
    scale = jnp.max(jnp.abs(blocks), axis=1, keepdims=True) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(blocks / scale), -127, 127).astype(jnp.int8)
    return q, scale, (x.shape, pad)


def dequantize_int8(q, scale, meta):
    shape, pad = meta
    flat = (q.astype(jnp.float32) * scale).reshape(-1)
    if pad:
        flat = flat[:-pad]
    return flat.reshape(shape)


def compress_with_feedback(grad: jax.Array, error: jax.Array):
    """Quantise (grad + carried error); return (q, scale, meta, new_error)."""
    target = grad.astype(jnp.float32) + error
    q, scale, meta = quantize_int8(target)
    recon = dequantize_int8(q, scale, meta)
    return q, scale, meta, target - recon


def init_error(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compressed_psum(grads, errors, axis_name: str):
    """Error-feedback int8 all-reduce over ``axis_name`` (use inside
    shard_map/pmap). Returns (reduced_grads, new_errors)."""

    def one(g, e):
        q, scale, meta, new_e = compress_with_feedback(g, e)
        # reduce the dequantised blocks (int8 summation would overflow;
        # the wire format is int8 + per-block scale)
        deq = dequantize_int8(q, scale, meta)
        return jax.lax.pmean(deq, axis_name), new_e

    out = jax.tree.map(one, grads, errors)
    red = jax.tree.map(lambda o: o[0], out,
                       is_leaf=lambda x: isinstance(x, tuple))
    errs = jax.tree.map(lambda o: o[1], out,
                        is_leaf=lambda x: isinstance(x, tuple))
    return red, errs
