"""SQL generation from the expression DAG.

The paper's §8 outlook: "a transpiler that automatically generates the
corresponding SQL queries from common array query languages … could offer
additional features such as automatic differentiation for the generation of
queries for model training and inference." This module is that transpiler:
the same DAG the JAX engines execute is rendered as

* **SQL-92** — one CTE per node using the relational representation
  (Listing 4 building blocks, Listing 7 training query), and
* **SQL + Arrays** — the nested-subquery style over an array data type
  (Listing 10), with ``**`` matmul, ``*`` Hadamard, ``transpose``, ``sig``.

Generated queries are golden-tested against the paper's listings' structure
in ``tests/test_sqlgen.py``.
"""
from __future__ import annotations

from . import expr as E
from .autodiff import MapDeriv, derive


# ---------------------------------------------------------------------------
# SQL-92: relational representation
# ---------------------------------------------------------------------------

def _cte_sql(node: E.Expr, nm: dict[int, str]) -> str:
    """Render one node as a select over its children's CTEs (Listing 4)."""
    n = lambda c: nm[id(c)]
    if isinstance(node, E.MatMul):
        return (f"select m.i, n.j, sum(m.v*n.v) as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.j = n.i\n  group by m.i, n.j")
    if isinstance(node, (E.Hadamard, E.Add, E.Sub)):
        op = {"Hadamard": "*", "Add": "+", "Sub": "-"}[type(node).__name__]
        return (f"select m.i, m.j, m.v {op} n.v as v\n"
                f"  from {n(node.x)} as m inner join {n(node.y)} as n"
                f" on m.i = n.i and m.j = n.j")
    if isinstance(node, E.Scale):
        return f"select i, j, {node.c} * v as v from {n(node.x)}"
    if isinstance(node, E.Transpose):
        return f"select j as i, i as j, v from {n(node.x)}"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:  # out·(1-out) from the cached CTE
            return (f"select i, j, v*(1-v) as v from {n(node.fx)}")
        if node.fn is E.SQUARE:
            return f"select i, j, 2*v as v from {n(node.x)}"
        if node.fn is E.RELU:
            return (f"select i, j, case when v > 0 then 1 else 0 end as v"
                    f" from {n(node.x)}")
        raise NotImplementedError(node.fn.name)
    if isinstance(node, E.Map):
        return f"select i, j, {node.fn.sql('v')} as v from {n(node.x)}"
    if isinstance(node, E.Const):
        rows, cols = node.shape
        return (f"select a.i, b.j, {node.value} as v\n"
                f"  from (select generate_series as i from"
                f" generate_series(1,{rows})) a,\n"
                f"       (select generate_series as j from"
                f" generate_series(1,{cols})) b")
    raise TypeError(type(node))


def to_sql92(roots: list[E.Expr], select: str | None = None) -> str:
    """Emit a WITH query: one CTE per non-leaf node, topologically ordered."""
    order = E.topo_order(*roots)
    nm: dict[int, str] = {}
    ctes: list[str] = []
    for node in order:
        if isinstance(node, E.Var):
            nm[id(node)] = node.name
            continue
        nm[id(node)] = node.name
        ctes.append(f"{node.name}(i, j, v) as (\n  {_cte_sql(node, nm)}\n)")
    body = ",\n".join(ctes)
    tail = select or f"select * from {nm[id(roots[-1])]} order by i, j"
    return f"with {body}\n{tail};"


def training_query_sql92(graph, n_iters: int, lr: float) -> str:
    """Listing 7: the recursive CTE whose step evaluates the model, runs
    Algorithm 1's CTEs, and emits the updated weight table."""
    grads = derive(graph.loss, E.const(1.0, graph.loss.shape))
    g_xh, g_ho = grads[graph.w_xh], grads[graph.w_ho]
    order = E.topo_order(graph.loss, g_xh, g_ho)
    nm: dict[int, str] = {}
    ctes: list[str] = []
    for node in order:
        if isinstance(node, E.Var):
            if node.name in ("w_xh", "w_ho"):
                wid = 0 if node.name == "w_xh" else 1
                nm[id(node)] = node.name
                ctes.append(
                    f"{node.name}(i, j, v) as (\n"
                    f"  select i, j, v from w_ where id = {wid}\n"
                    f"   and iter = (select max(iter) from w_)\n)")
            else:
                nm[id(node)] = node.name
            continue
        nm[id(node)] = node.name
        ctes.append(f"{node.name}(i, j, v) as (\n  {_cte_sql(node, nm)}\n)")
    body = ",\n".join(ctes)
    return (
        "with recursive w (iter, id, i, j, v) as (\n"
        "  (select 0, 0, * from w_xh_init union all\n"
        "   select 0, 1, * from w_ho_init)\n"
        "  union all\n"
        "  select * from (\n"
        "  with w_(iter, id, i, j, v) as (\n"
        "    select * from w  -- recursive reference only allowed once\n"
        f"  ),\n{body},\n"
        "  d_w(id, i, j, v) as (\n"
        f"    select 0, i, j, v from {nm[id(g_xh)]} union all\n"
        f"    select 1, i, j, v from {nm[id(g_ho)]}\n"
        "  )\n"
        "  select w_.iter + 1, w_.id, w_.i, w_.j,\n"
        f"         w_.v - {lr} * d_w.v\n"
        "    from w_, d_w\n"
        f"   where w_.iter < {n_iters} and w_.id = d_w.id\n"
        "     and w_.i = d_w.i and w_.j = d_w.j\n"
        "  ) step\n"
        ")\nselect * from w;")


# ---------------------------------------------------------------------------
# SQL + Arrays (Listing 10 style)
# ---------------------------------------------------------------------------

def _array_expr(node: E.Expr) -> str:
    a = _array_expr
    if isinstance(node, E.Var):
        return node.name
    if isinstance(node, E.Const):
        return str(node.value)  # broadcast scalar, as in ``1 - a_ho``
    if isinstance(node, E.MatMul):
        return f"({a(node.x)} ** {a(node.y)})"
    if isinstance(node, E.Hadamard):
        return f"({a(node.x)} * {a(node.y)})"
    if isinstance(node, E.Add):
        return f"({a(node.x)} + {a(node.y)})"
    if isinstance(node, E.Sub):
        return f"({a(node.x)} - {a(node.y)})"
    if isinstance(node, E.Scale):
        return f"({node.c} * {a(node.x)})"
    if isinstance(node, E.Transpose):
        return f"transpose({a(node.x)})"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:
            return f"({a(node.fx)} * (1 - {a(node.fx)}))"
        if node.fn is E.SQUARE:
            return f"(2 * {a(node.x)})"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, E.Map):
        return f"{node.fn.name}({a(node.x)})"
    raise TypeError(type(node))


def to_sql_arrays(roots: list[E.Expr]) -> str:
    """Nested select with one derived-table level per CTE (Listing 10)."""
    order = [n for n in E.topo_order(*roots)
             if not isinstance(n, (E.Var, E.Const))]
    # innermost: the raw tables; each level materialises one named expression
    inner = "select * from data, weights"
    for node in order:
        expr_sql = _array_expr_shallow(node)
        inner = f"select {expr_sql} as {node.name}, * from (\n{inner}) q_{node.name}"
    return inner + ";"


def _array_expr_shallow(node: E.Expr) -> str:
    """Like _array_expr but children referenced by their CTE names."""
    name = lambda c: (str(c.value) if isinstance(c, E.Const) else c.name)
    if isinstance(node, E.MatMul):
        return f"({name(node.x)} ** {name(node.y)})"
    if isinstance(node, E.Hadamard):
        return f"({name(node.x)} * {name(node.y)})"
    if isinstance(node, E.Add):
        return f"({name(node.x)} + {name(node.y)})"
    if isinstance(node, E.Sub):
        return f"({name(node.x)} - {name(node.y)})"
    if isinstance(node, E.Scale):
        return f"({node.c} * {name(node.x)})"
    if isinstance(node, E.Transpose):
        return f"transpose({name(node.x)})"
    if isinstance(node, MapDeriv):
        if node.fn is E.SIGMOID:
            return f"({name(node.fx)} * (1 - {name(node.fx)}))"
        if node.fn is E.SQUARE:
            return f"(2 * {name(node.x)})"
        raise NotImplementedError(node.fn.name)
    if isinstance(node, E.Map):
        return f"{node.fn.name}({name(node.x)})"
    raise TypeError(type(node))


def training_query_arrays(graph, n_iters: int, lr: float) -> str:
    """Listing 10: recursive table over array-typed weight columns, with one
    named derived-table level per cached expression (a_xh, a_ho, l_ho, …) so
    the backward pass reuses the forward CTEs exactly as the paper does."""
    grads = derive(graph.loss, E.const(1.0, graph.loss.shape))
    g_xh, g_ho = grads[graph.w_xh], grads[graph.w_ho]
    order = [n for n in E.topo_order(g_xh, g_ho)
             if not isinstance(n, (E.Var, E.Const))]
    inner = f"select * from data, w where id < {n_iters}"
    for node in order:
        inner = (f"select {_array_expr_shallow(node)} as {node.name}, *"
                 f" from (\n{inner}) q_{node.name}")
    return (
        "with recursive w (id, w_xh, w_ho) as (\n"
        "  select 0, w_xh, w_ho from weights\n"
        "  union all\n"
        "  select id + 1,\n"
        f"         w_xh - {lr} * {g_xh.name},\n"
        f"         w_ho - {lr} * {g_ho.name}\n"
        f"    from (\n{inner})\n"
        ")\nselect * from w;")
