"""Batched serving engine with continuous batching."""
from .engine import Request, ServingEngine
