with recursive rec_c0_scan(t, s) as (
  select 1, mrow((select m from zb), 1)
  union all
  select r.t + 1, madd(mhad(mrow((select m from za), r.t + 1), r.s), mrow((select m from zb), r.t + 1))
    from rec_c0_scan as r
   where r.t < 4
),
rec_c0(m) as (
  select mrowcat(group_concat(cast(t as text) || ':' || s, '|')) as m from rec_c0_scan
),
rec_c1_scan(t, s) as (
  select 4, mrow((select m from zb), 4)
  union all
  select r.t - 1, madd(mhad(mrow((select m from za), r.t - 1), r.s), mrow((select m from zb), r.t - 1))
    from rec_c1_scan as r
   where r.t > 1
),
rec_c1(m) as (
  select mrowcat(group_concat(cast(t as text) || ':' || s, '|')) as m from rec_c1_scan
)
select 0 as r, m from rec_c0
union all select 1 as r, m from rec_c1;
