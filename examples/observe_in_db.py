"""Trace an in-database training run — and query the trace *with SQL*.

The observability loop closed on itself: a :class:`repro.obs.Tracer`
collects nested spans from every layer of the execution stack (leaf
ingestion, plan render + cache lookup, EXPLAIN capture, query execution,
result decode), then the spans are written back into the very database
that ran the workload as a ``trace_spans`` relation — so "which stage
dominates a training step" is answered by the engine itself, with the
same SQL surface that trained the model.

Also shows ``SQLEngine.stats`` (plan-cache hit/miss/eviction counters —
the LRU no longer evicts silently), the engine's EXPLAIN output for the
cached plan, the Chrome-trace export (load the JSON at
https://ui.perfetto.dev), the per-IR-node profiled execution mode
(``SQLEngine.profile_value_and_grad`` → ``profile_nodes`` relation), the
``metric_points`` time-series (training loss, grad norm, cache hit rate),
and the one-command terminal report over either artifact::

    python -m repro.obs.report observe_in_db.trace.json
    python -m repro.obs.report observe_in_db.sqlite

Run:  PYTHONPATH=src python examples/observe_in_db.py
"""
import numpy as np

from repro import obs
from repro.core import nn2sql
from repro.db.adapter import connect
from repro.db.plan_cache import PlanCache
from repro.db.sql_engine import SQLEngine
from repro.db.train import train_in_db

spec = nn2sql.MLPSpec(n_rows=60, n_features=4, n_hidden=10, n_classes=3,
                      lr=0.1)


def iris_like(spec, seed=0):
    rng = np.random.RandomState(seed)
    centers = rng.rand(spec.n_classes, spec.n_features)
    labels = rng.randint(0, spec.n_classes, spec.n_rows)
    x = centers[labels] + 0.08 * rng.randn(spec.n_rows, spec.n_features)
    return x.astype(np.float32), np.eye(spec.n_classes)[labels]


def main():
    graph = nn2sql.build_graph(spec)
    weights = {k: np.asarray(v) for k, v in nn2sql.init_weights(spec).items()}
    x, y = iris_like(spec)

    tracer = obs.Tracer()
    adapter = connect("sqlite")
    cache = PlanCache(path=None)

    # -- 1. trace a training run + a traced forward evaluation ---------------
    with obs.use(tracer):
        train_in_db(graph, weights, x, y, n_iters=10, adapter=adapter,
                    plan_cache_=cache)
    eng = SQLEngine(adapter=adapter, plan_cache_=cache, tracer=tracer)
    eng.evaluate([graph.loss], {**weights, "img": x, "one_hot": y})
    eng.evaluate([graph.loss], {**weights, "img": x, "one_hot": y})  # warm

    # -- 2. the spans become a relation in the SAME database -----------------
    n = obs.write_trace_spans(adapter, tracer)
    print(f"wrote {n} spans into trace_spans — per-stage totals via SQL:\n")
    print("    " + obs.STAGE_SQL.replace("\n", "\n    "), "\n")
    for name, count, total_ms in adapter.execute(obs.STAGE_SQL):
        print(f"  {name:<22s} n={int(count):<4d} {total_ms:9.3f} ms")

    # -- 3. per-stage attribution of the training iteration ------------------
    bd = obs.stage_breakdown(tracer, root="train.in_db")
    print(f"\ntrain.in_db: {bd['wall_s'] * 1e3:.2f} ms wall, "
          f"{bd['attribution']:.1%} attributed to named stages:")
    for stage, d in bd["stages"].items():
        print(f"  {stage:<22s} {d['pct_of_root']:5.1f}%")

    # -- 4. merged counters + the engine's own plan for the cached query -----
    st = eng.stats
    print(f"\nSQLEngine.stats: cache {st['cache_hits']} hits / "
          f"{st['cache_misses']} misses / {st['cache_evictions']} evictions; "
          f"{st['queries']} queries, {st['ingest_bytes']} bytes ingested")
    print("\nEXPLAIN QUERY PLAN of the cached forward query:")
    for line in eng.explain([graph.loss]).splitlines()[:6]:
        print("  " + line)

    # -- 5. per-IR-node profile: every node its own timed temp-table step ----
    res = eng.profile_value_and_grad(graph.loss, [graph.w_xh, graph.w_ho],
                                     {**weights, "img": x, "one_hot": y})
    print(f"\nprofiled training-step DAG "
          f"({res.attribution:.1%} of wall attributed):")
    print(res.report(top=8))
    obs.write_profile_nodes(adapter, res)
    print("\ncost by IR node kind, via SQL on profile_nodes:")
    for kind, n_, ms, rows, pct in adapter.execute(obs.NODE_SQL)[:5]:
        print(f"  {kind:<22s} n={int(n_):<3d} {ms:8.3f} ms  {pct:5.1f}%")

    # -- 6. the metric_points time-series lands in the database too ----------
    n = obs.write_metric_points(adapter, tracer)
    print(f"\nwrote {n} metric points — per-metric summary via SQL:")
    for metric, cnt, lo, hi, mean in adapter.execute(obs.METRIC_SQL):
        print(f"  {metric:<22s} n={int(cnt):<4d} mean={mean:.4g} "
              f"[{lo:.4g}, {hi:.4g}]")
    h = tracer.histograms.get("db.execute_ms")
    if h:
        print(f"db.execute_ms histogram: n={h['count']} "
              f"p50={h['p50']:.3f} p95={h['p95']:.3f} p99={h['p99']:.3f} ms")

    # -- 7. Perfetto-loadable export + the terminal report CLI ---------------
    path = obs.write_chrome_trace(tracer, "observe_in_db.trace.json")
    print(f"\nChrome trace written to {path} (open in ui.perfetto.dev)")
    print("inspect either artifact with: "
          "python -m repro.obs.report observe_in_db.trace.json")
    from repro.obs import report as obs_report
    print("\n" + obs_report.render(obs_report.load_capture(path), top=5))
    eng.close()


if __name__ == "__main__":
    main()
