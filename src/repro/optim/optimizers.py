"""Optimizers (pure-JAX, optax-free): SGD (the paper's) and AdamW.

Interface: ``opt.init(params) → state``; ``opt.update(grads, state, params)
→ (new_params, new_state)``. All update math is elementwise, so GSPMD
shards the optimizer step exactly like the parameters (ZeRO-style when
params are data-sharded — see launch/sharding.py).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], tuple[Any, Any]]


def sgd(lr: float) -> Optimizer:
    """Plain gradient descent — Listing 1/7/10's ``w - γ·d_w``."""

    def init(params):
        return ()

    def update(grads, state, params):
        new = jax.tree.map(lambda p, g: p - lr * g.astype(p.dtype),
                           params, grads)
        return new, state

    return Optimizer(init, update)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1,
          mixed_precision: bool = False) -> Optimizer:
    """AdamW. With ``mixed_precision`` the optimizer carries f32 MASTER
    weights and the (bf16) params are re-cast from them each step — the
    standard low-precision-parameter scheme: collectives and forward reads
    move bf16, optimizer math stays exact."""

    def init(params):
        zeros = lambda p: jnp.zeros_like(p, dtype=jnp.float32)
        st = {"m": jax.tree.map(zeros, params),
              "v": jax.tree.map(zeros, params),
              "t": jnp.zeros((), jnp.int32)}
        if mixed_precision:
            st["master"] = jax.tree.map(
                lambda p: p.astype(jnp.float32), params)
        return st

    def update(grads, state, params):
        t = state["t"] + 1
        b1t = 1.0 - b1 ** t.astype(jnp.float32)
        b2t = 1.0 - b2 ** t.astype(jnp.float32)
        masters = state.get("master", params)

        def upd(p, mast, g, m, v):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / b1t) / (jnp.sqrt(v / b2t) + eps)
            new_mast = mast.astype(jnp.float32) - lr * (
                step + weight_decay * mast.astype(jnp.float32))
            return new_mast.astype(p.dtype), m, v, new_mast

        out = jax.tree.map(upd, params, masters, grads, state["m"],
                           state["v"])
        pick = lambda i: jax.tree.map(
            lambda o: o[i], out, is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"m": pick(1), "v": pick(2), "t": t}
        if mixed_precision:
            new_state["master"] = pick(3)
        return pick(0), new_state

    return Optimizer(init, update)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def clip_by_global_norm(grads, max_norm: float):
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-9))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm
