"""Differential tests: the DAG zoo in SQL vs the jax kernel references.

The acceptance contract of the zoo transpiler (``repro.db.zoo``): MoE
dispatch+combine and the RWKV recurrences executed by sqlite match
``kernels/ref.py`` (and the ``nn/moe.py`` routing they mirror) within
1e-4 — including Algorithm-1 gradients of the full MoE layer executed as
SQL.  duckdb runs the same assertions when the wheel is importable (the
CI duckdb-extras job).
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.db import HAVE_DUCKDB, zoo
from repro.db.sql_engine import SQLEngine
from repro.kernels import ref
from repro.nn import moe as nnmoe

TOL = 1e-4
RNG = np.random.RandomState(7)

BACKENDS = ["sqlite"] + (["duckdb"] if HAVE_DUCKDB else [])


def moe_setup():
    cfg = zoo.MoESQLConfig(n_tokens=8, d_model=6, n_experts=4, top_k=2,
                           d_ff=8)
    params = zoo.init_moe_params(cfg)
    x = RNG.randn(cfg.n_tokens, cfg.d_model).astype(np.float32)
    return cfg, params, x


def slot_relation(cfg, params, x, router_softmax: str):
    """Route with ``nn/moe.py`` and lay the relation out token-major."""
    mcfg = nnmoe.MoEConfig(n_experts=cfg.n_experts, top_k=cfg.top_k,
                           d_model=cfg.d_model, d_ff=cfg.d_ff,
                           router_softmax=router_softmax)
    gates, idx, _ = nnmoe._route({"router": jnp.asarray(params["router"])},
                                 jnp.asarray(x), mcfg)
    gates, idx = np.asarray(gates), np.asarray(idx)
    t, k = idx.shape
    tok = np.tile(np.arange(t, dtype=np.int32), (k, 1)).T.reshape(-1)
    return tok, idx.reshape(-1), gates.reshape(-1)


def ref_moe_chain(cfg, params, x, tok, exp, gates):
    """kernels/ref dispatch → per-expert SwiGLU → kernels/ref combine
    (no capacity dropping — the config never overflows)."""
    xs = np.asarray(ref.moe_dispatch(jnp.asarray(x), jnp.asarray(tok),
                                     jnp.ones(len(tok), np.float32)))

    def silu(z):
        return z / (1.0 + np.exp(-z))

    ys = np.stack([
        (xs[s] @ params["wi"][exp[s]]
         * silu(xs[s] @ params["wg"][exp[s]])) @ params["wo"][exp[s]]
        for s in range(len(tok))])
    weighted = (ys * gates[:, None]).astype(np.float32)
    return np.asarray(ref.moe_combine(jnp.asarray(weighted),
                                      jnp.asarray(tok), cfg.n_tokens))


@pytest.mark.parametrize("backend", BACKENDS)
class TestMoE:
    def test_layer_matches_ref_chain_pre_and_post(self, backend):
        """One SQL graph ≡ nn/moe routing (both conventions) + kernels/ref
        dispatch/combine: pre and post renormalise to the same gates."""
        cfg, params, x = moe_setup()
        out_db = zoo.run_moe_in_db(cfg, params, x, backend=backend)
        for mode in ("pre", "post"):
            tok, exp, gates = slot_relation(cfg, params, x, mode)
            out_ref = ref_moe_chain(cfg, params, x, tok, exp, gates)
            np.testing.assert_allclose(out_db, out_ref, atol=TOL,
                                       err_msg=f"router mode {mode}")

    def test_layer_matches_jnp_oracle(self, backend):
        cfg, params, x = moe_setup()
        out_db = zoo.run_moe_in_db(cfg, params, x, backend=backend)
        np.testing.assert_allclose(out_db, zoo.moe_ffn_ref(cfg, params, x),
                                   atol=TOL)

    def test_dispatch_graph_matches_kernel_ref(self, backend):
        cfg, params, x = moe_setup()
        tok, _exp, gates = slot_relation(cfg, params, x, "pre")
        out, _x, _tok, _gate = zoo.moe_dispatch_graph(
            cfg.n_tokens, cfg.d_model, len(tok))
        with SQLEngine(backend=backend) as eng:
            got, = eng.evaluate([out], {
                "x": x, "slot_token": tok.reshape(-1, 1).astype(np.float64),
                "slot_gate": gates.reshape(-1, 1).astype(np.float64)})
        want = np.asarray(ref.moe_dispatch(jnp.asarray(x), jnp.asarray(tok),
                                           jnp.asarray(gates)))
        np.testing.assert_allclose(got, want, atol=TOL)

    def test_combine_graph_matches_kernel_ref(self, backend):
        cfg, params, x = moe_setup()
        tok, _exp, gates = slot_relation(cfg, params, x, "pre")
        y = RNG.randn(len(tok), cfg.d_model).astype(np.float32)
        out, _y, _tok = zoo.moe_combine_graph(len(tok), cfg.d_model,
                                              cfg.n_tokens)
        with SQLEngine(backend=backend) as eng:
            got, = eng.evaluate([out], {
                "expert_out": y,
                "slot_token": tok.reshape(-1, 1).astype(np.float64)})
        want = np.asarray(ref.moe_combine(jnp.asarray(y), jnp.asarray(tok),
                                          cfg.n_tokens))
        np.testing.assert_allclose(got, want, atol=TOL)

    def test_gates_match_nn_moe_routing(self, backend):
        """The in-DB gate matrix scattered back equals nn/moe's (gates,
        idx) pairs for both router conventions."""
        cfg, params, x = moe_setup()
        graph = zoo.moe_ffn_graph(cfg)
        with SQLEngine(backend=backend) as eng:
            gm, = eng.evaluate([graph.gates], zoo.moe_env(cfg, params, x))
        for mode in ("pre", "post"):
            tok, exp, gates = slot_relation(cfg, params, x, mode)
            want = np.zeros_like(gm)
            want[tok, exp] = gates
            np.testing.assert_allclose(gm, want, atol=TOL,
                                       err_msg=f"router mode {mode}")

    def test_moe_gradients_execute_in_db(self, backend):
        """Algorithm 1 over Softmax/ArgTopK/RowReduce/recip — the full MoE
        backward as SQL — matches Engine('dense') on the same graphs."""
        from repro.core import Engine
        from repro.core.autodiff import gradients

        cfg, params, x = moe_setup()
        graph = zoo.moe_ffn_graph(cfg)
        env = zoo.moe_env(cfg, params, x)
        wrt = list(graph.weight_vars)
        grads = gradients(graph.out, wrt)
        roots = [graph.out] + [grads[v] for v in wrt]
        jenv = {k: jnp.asarray(v) for k, v in env.items()}
        want = [np.asarray(o) for o in
                Engine("dense").eval_fn(roots)(jenv)]
        with SQLEngine(backend=backend) as eng:
            got = eng.evaluate(roots, env)
        for g, w in zip(got, want):
            np.testing.assert_allclose(g, w, atol=TOL)


@pytest.mark.parametrize("backend", BACKENDS)
class TestRWKV:
    def test_time_mix_matches_kernel_ref(self, backend):
        s, n = 6, 4
        r, k, v = [RNG.randn(s, n).astype(np.float32) * 0.5
                   for _ in range(3)]
        w = (RNG.rand(s, n) * 0.5 + 0.3).astype(np.float32)
        u = (RNG.randn(n) * 0.5).astype(np.float32)
        s0 = (RNG.randn(n, n) * 0.3).astype(np.float32)
        o_db, sfin_db = zoo.run_rwkv6_in_db(r, k, v, w, u, s0,
                                            backend=backend)
        o_ref, sfin_ref = ref.rwkv6_scan(
            jnp.asarray(r[None]), jnp.asarray(k[None]),
            jnp.asarray(v[None]), jnp.asarray(w[None]),
            jnp.asarray(u[None]), jnp.asarray(s0[None]))
        np.testing.assert_allclose(o_db, np.asarray(o_ref[0]), atol=TOL)
        np.testing.assert_allclose(sfin_db, np.asarray(sfin_ref[0]),
                                   atol=TOL)

    def test_time_mix_zero_state_anchor(self, backend):
        """s0 = 0 exercises the recursion anchor row exactly."""
        s, n = 4, 3
        r, k, v = [RNG.randn(s, n).astype(np.float32) * 0.5
                   for _ in range(3)]
        w = (RNG.rand(s, n) * 0.5 + 0.3).astype(np.float32)
        u = (RNG.randn(n) * 0.5).astype(np.float32)
        s0 = np.zeros((n, n), np.float32)
        o_db, sfin_db = zoo.run_rwkv6_in_db(r, k, v, w, u, s0,
                                            backend=backend)
        o_ref, sfin_ref = ref.rwkv6_scan(
            jnp.asarray(r[None]), jnp.asarray(k[None]),
            jnp.asarray(v[None]), jnp.asarray(w[None]),
            jnp.asarray(u[None]), jnp.asarray(s0[None]))
        np.testing.assert_allclose(o_db, np.asarray(o_ref[0]), atol=TOL)
        np.testing.assert_allclose(sfin_db, np.asarray(sfin_ref[0]),
                                   atol=TOL)

    def test_channel_mix_matches_oracle(self, backend):
        s, d, f = 6, 5, 8
        x = RNG.randn(s, d).astype(np.float32)
        mu_k, mu_r = RNG.rand(d), RNG.rand(d)
        wk = RNG.randn(d, f) * 0.3
        wv = RNG.randn(f, d) * 0.3
        wr = RNG.randn(d, d) * 0.3
        got = zoo.run_channel_mix_in_db(x, mu_k, mu_r, wk, wv, wr,
                                        backend=backend)
        want = zoo.rwkv_channel_mix_ref(x, mu_k, mu_r, wk, wv, wr)
        np.testing.assert_allclose(got, want, atol=TOL)

    def test_kron_index_relations(self, backend):
        n = 3
        rel = zoo.kron_index_relations(n)
        k_ = RNG.randn(2, n)
        v_ = RNG.randn(2, n)
        flat = (k_ @ rel["kron_a"]) * (v_ @ rel["kron_b"])
        want = np.einsum("ta,tb->tab", k_, v_).reshape(2, n * n)
        np.testing.assert_allclose(flat, want, atol=1e-12)
