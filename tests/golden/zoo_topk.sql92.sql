with topk_c0(i, j, v) as (
  select m.i, m.j, case when (select count(*) from zx n where n.i = m.i and (n.v > m.v or (n.v = m.v and n.j < m.j))) < 2 then 1.0 else 0.0 end as v
  from zx as m
)
select 0 as r, i, j, v from topk_c0;
