"""Reverse-mode automatic differentiation over matrix expressions.

This is the paper's Algorithm 1 verbatim::

    function DERIVE(Z, seed)
      if   Z = X + Y  then DERIVE(X, seed); DERIVE(Y, seed)
      elif Z = X ∘ Y  then DERIVE(X, seed ∘ y); DERIVE(Y, seed ∘ x)
      elif Z = X · Y  then DERIVE(X, seed · yᵀ); DERIVE(Y, xᵀ · seed)
      elif Z = f(X)   then DERIVE(X, seed ∘ f'(x))
      else  ∂/∂Z ← ∂/∂Z + seed

Lower-case letters (``x``, ``y``) are the *cached forward values*: in the
output gradient graph they appear as references to forward-pass nodes, which
the engines evaluate once and memoise — each shared node is one CTE, and the
derivative CTEs reuse it, exactly as Listing 7 reuses ``a_xh``/``a_ho``.

``f'(x)`` needs access to both the input value and the cached output value
(sigmoid: ``out ∘ (1-out)``); we introduce a ``MapDeriv`` marker node that the
engines evaluate from the memoised forward values.
"""
from __future__ import annotations

import dataclasses

from . import expr as E


@dataclasses.dataclass(frozen=True, eq=False)
class MapDeriv(E.Expr):
    """f'(x) evaluated from the cached forward values of ``x`` (and ``f(x)``)."""

    fn: E.MapFn = None
    x: E.Expr = None          # the input of the Map node
    fx: E.Expr = None         # the Map node itself (cached output)

    def children(self):
        # Both are forward nodes; listing them keeps topo_order correct.
        return (self.x, self.fx)


def derive(z: E.Expr, seed: E.Expr, grads: dict[E.Var, E.Expr] | None = None
           ) -> dict[E.Var, E.Expr]:
    """Algorithm 1. Returns {leaf Var: gradient expression}."""
    if grads is None:
        grads = {}

    if isinstance(z, E.Add):
        derive(z.x, seed, grads)
        derive(z.y, seed, grads)
    elif isinstance(z, E.Sub):
        derive(z.x, seed, grads)
        derive(z.y, E.scale(-1.0, seed), grads)
    elif isinstance(z, E.Hadamard):
        derive(z.x, E.hadamard(seed, z.y), grads)
        derive(z.y, E.hadamard(seed, z.x), grads)
    elif isinstance(z, E.MatMul):
        derive(z.x, E.matmul(seed, E.transpose(z.y)), grads)
        derive(z.y, E.matmul(E.transpose(z.x), seed), grads)
    elif isinstance(z, E.Map):
        fprime = MapDeriv(name=f"d{z.fn.name}_{z.name}", shape=z.shape,
                          fn=z.fn, x=z.x, fx=z)
        if E.is_auto_named(z):  # name embeds z's counter suffix
            E.mark_auto_named(fprime)
        derive(z.x, E.hadamard(seed, fprime), grads)
    elif isinstance(z, E.Scale):
        derive(z.x, E.scale(z.c, seed), grads)
    elif isinstance(z, E.Transpose):
        derive(z.x, E.transpose(seed), grads)
    elif isinstance(z, E.Const):
        pass  # constants carry no gradient
    elif isinstance(z, E.Var):
        if z in grads:
            grads[z] = E.add(grads[z], seed)
        else:
            grads[z] = seed
    else:  # pragma: no cover
        raise TypeError(f"unknown node {type(z)}")
    return grads


def gradients(loss: E.Expr, wrt: list[E.Var]) -> dict[E.Var, E.Expr]:
    """Gradient graphs of a scalar-per-entry loss w.r.t. ``wrt``.

    The paper seeds with the derivative of the mean-squared-error
    (Equation 6, ``l_ho = 2(a_ho - y)``); calling ``derive`` on the full loss
    expression ``(m(x)-y)^∘2`` with an all-ones seed produces the identical
    graph via the f(X) rule on ``sqr``.
    """
    ones = E.const(1.0, loss.shape)
    grads = derive(loss, ones)
    missing = [v for v in wrt if v not in grads]
    if missing:
        raise ValueError(f"no gradient flows to {[v.name for v in missing]}")
    return {v: grads[v] for v in wrt}
