"""Sharding rules: params (TP + FSDP), optimizer state (ZeRO), batches,
and serving caches, for every architecture family.

Parallelism map (DESIGN.md §5):
  * DP    — batch over ('pod', 'data')
  * TP    — attention heads / FFN hidden / vocab over 'model'
  * EP    — routed experts over 'model'
  * SP    — KV-cache sequence over spare axes when batch/heads don't divide
  * FSDP  — weight dim-0 over 'data' (within-pod only; cross-pod stays
            replicated so DCI never carries weight gathers)
  * ZeRO  — optimizer state inherits the param sharding (elementwise update)

Rules are name-based over the param tree; any dim is sharded only when
divisible by the axis size, so one rule set covers all ten configs.
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from .mesh import axis_size, data_axes

# leaf-name → which dim prefers the 'model' axis (before any leading L axis)
_COL = {"wq", "wk", "wv", "wg", "wi", "wkv_a", "wk_b", "wv_b", "wk_rope",
        "in_proj", "lm_head", "wr", "conv_w"}     # output-dim sharded (last)
_ROW = {"wo", "out_proj"}                          # contraction-dim (first)
_EXPERT = {"wi", "wg", "wo"}                       # under a "moe" parent: dim 0
_VOCAB = {"embed"}                                 # dim 0 (vocab)
_REPLICATED = {"w0", "u", "a_log", "dt_bias", "d_skip", "mu", "mu_k", "mu_r",
               "w_lora_a", "w_lora_b", "router", "bq", "bk", "bv", "bi", "bo",
               "b"}


def _path_names(path) -> list[str]:
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
    return out


def _divisible(dim: int, size: int) -> bool:
    return size > 0 and dim % size == 0


def param_spec(path, shape: tuple[int, ...], mesh, *, fsdp: bool = True,
               stacked: bool = False) -> P:
    names = _path_names(path)
    leaf = names[-1] if names else ""
    model_n = axis_size(mesh, "model")
    data_n = axis_size(mesh, "data")
    off = 1 if stacked else 0          # leading L axis of scanned stacks
    nd = len(shape)
    spec: list[Any] = [None] * nd
    body = list(range(off, nd))
    if not body:
        return P()

    model_dim = None
    if "moe" in names and leaf in _EXPERT and nd - off == 3:
        model_dim = body[0]            # expert parallelism
    elif leaf in _VOCAB:
        model_dim = body[0]
    elif leaf in _ROW:
        model_dim = body[0]
    elif leaf in _COL and leaf not in _REPLICATED:
        model_dim = body[-1]
    if (model_dim is not None and
            _divisible(shape[model_dim], model_n)):
        spec[model_dim] = "model"
    else:
        model_dim = None

    if fsdp and nd - off >= 2:
        # FSDP: biggest remaining dim divisible by the in-pod data axis
        cands = sorted((d for d in body if d != model_dim),
                       key=lambda d: -shape[d])
        for d in cands:
            if _divisible(shape[d], data_n) and shape[d] >= data_n * 8:
                spec[d] = "data"
                break
    return P(*spec)


def param_shardings(params_shapes, mesh, *, fsdp: bool = True):
    """ShapeDtypeStruct tree → NamedSharding tree (same structure)."""

    def one(path, leaf):
        names = _path_names(path)
        stacked = any(n in ("layers", "prologue") for n in names)
        return NamedSharding(mesh,
                             param_spec(path, leaf.shape, mesh, fsdp=fsdp,
                                        stacked=stacked))

    return jax.tree_util.tree_map_with_path(one, params_shapes)


def opt_shardings(opt_shapes, param_sh, mesh):
    """ZeRO: m/v mirror the param shardings; scalars replicated."""

    def one(path, leaf):
        names = _path_names(path)
        if names and names[0] in ("m", "v", "master"):
            sub = [k for k in path[1:]]
            stacked = any((hasattr(k, "key") and str(k.key) in
                           ("layers", "prologue")) for k in sub)
            return NamedSharding(mesh, param_spec(sub, leaf.shape, mesh,
                                                  stacked=stacked))
        return NamedSharding(mesh, P())

    return jax.tree_util.tree_map_with_path(one, opt_shapes)


# ---------------------------------------------------------------------------
# batches and caches
# ---------------------------------------------------------------------------

def batch_shardings(batch_shapes, mesh, global_batch: int):
    dp = data_axes(mesh)
    dp_n = axis_size(mesh, dp)
    bspec = dp if _divisible(global_batch, dp_n) else None

    def one(leaf):
        nd = len(leaf.shape)
        if nd >= 1 and leaf.shape[0] == global_batch and bspec:
            return NamedSharding(mesh, P(bspec, *([None] * (nd - 1))))
        return NamedSharding(mesh, P())

    return jax.tree.map(one, batch_shapes)


def cache_shardings(cache_shapes, mesh, batch_size: int, max_len: int,
                    cfg) -> Any:
    """KV caches / recurrent states. Priority: batch over DP axes; heads
    over 'model' when divisible; otherwise the sequence dim picks up the
    unused axis (sequence parallelism — flash-decoding style)."""
    dp = data_axes(mesh)
    dp_n = axis_size(mesh, dp)
    model_n = axis_size(mesh, "model")
    batch_ok = _divisible(batch_size, dp_n) and batch_size >= dp_n

    def one(leaf):
        shape = leaf.shape
        nd = len(shape)
        spec: list[Any] = [None] * nd
        # dim 0 is the layer stack; identify batch / sequence / head dims
        batch_dim = None
        if batch_size > 1:
            batch_dim = next((i for i in range(1, nd)
                              if shape[i] == batch_size), None)
        seq_dim = next((i for i in range(1, nd)
                        if shape[i] == max_len and i != batch_dim), None)
        head_dim = None
        for i in range(1, nd - 1):                 # last dim = feature width
            if i in (batch_dim, seq_dim):
                continue
            if _divisible(shape[i], model_n) and shape[i] >= model_n:
                head_dim = i
                break
        if batch_dim is not None and batch_ok:
            spec[batch_dim] = dp
        if head_dim is not None:
            spec[head_dim] = "model"
        if seq_dim is not None:                    # SP picks up free axes
            free: list[str] = []
            if batch_dim is None or not batch_ok:
                free += list(dp)
            if head_dim is None:
                free.append("model")
            if free and _divisible(shape[seq_dim],
                                   int(np.prod([axis_size(mesh, a)
                                                for a in free]))):
                spec[seq_dim] = tuple(free)
        return NamedSharding(mesh, P(*spec))

    return jax.tree.map(one, cache_shapes)
