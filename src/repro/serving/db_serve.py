"""Multi-tenant in-database serving: one plan, B requests.

The dense :class:`repro.serving.engine.ServingEngine` does continuous
batching over a jitted decode step; this module gives ``SQLEngine`` the
same shape (the ROADMAP's "millions of users" direction — the unit of
scaling becomes requests-per-plan, not queries-per-request):

* a :class:`repro.db.adapter.ConnectionPool` of worker adapters over ONE
  logical database (sqlite WAL one-writer/many-readers, duckdb
  cursor-per-worker),
* an async request queue with a **micro-batching window**: the dispatcher
  blocks on the first request, then gathers arrivals for ``window_ms``
  (up to ``max_batch``) and evaluates the whole group as ONE batched
  query — ``SQLEngine.evaluate_batched`` folds the ``b`` request-index
  column through the cached plan, so a group of any size rides the same
  rendered SQL,
* per-request ``concurrent.futures.Future`` results and per-tenant
  ``serve.*`` metric points on the ambient tracer.

Request leaves batch per group; shared leaves (weights) are ingested into
every pool worker once at :meth:`SQLBatchServer.start` and skipped by
content digest afterwards.
"""
from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np

from ..core import expr as E
from ..db.adapter import ConnectionPool
from ..db.sql_engine import SQLEngine
from ..obs import tracer_of

#: dispatcher default: how long the gatherer waits for co-batchable
#: arrivals after the first request of a group (milliseconds)
WINDOW_MS = 2.0

#: dispatcher default: largest request group one query evaluates
MAX_BATCH = 16

_STOP = object()


@dataclass
class _Pending:
    """One queued request: its per-request leaves and the future the
    caller is waiting on."""
    leaves: dict
    future: Future
    tenant: str | None
    t_enqueued: float = field(default_factory=time.perf_counter)


class SQLBatchServer:
    """Micro-batching request front over a pool of in-DB engines.

    ``roots`` fixes the served DAG; ``batch_vars`` names the leaves that
    vary per request (everything else is shared and supplied via
    ``shared_env``).  ``submit`` returns a Future resolving to one dense
    array per root for THAT request — results are split back out of the
    batched stacks, so callers never see each other.

    Knobs: ``pool_size`` workers (each its own connection + dispatcher
    thread), ``window_ms`` gather window, ``max_batch`` group cap.
    """

    def __init__(self, roots: Sequence[E.Expr], batch_vars: Sequence[str],
                 shared_env: dict, backend: str = "sqlite",
                 path: str = ":memory:", pool_size: int = 2,
                 window_ms: float = WINDOW_MS, max_batch: int = MAX_BATCH,
                 dialect=None, plan_cache_=None):
        self.roots = list(roots)
        self.batch_vars = tuple(sorted(batch_vars))
        free = {v.name for v in E.free_vars(*self.roots)}
        unknown = set(self.batch_vars) - free
        if unknown:
            raise KeyError(f"batch_vars not free in the DAG: "
                           f"{sorted(unknown)}")
        missing = free - set(self.batch_vars) - set(shared_env)
        if missing:
            raise KeyError(f"shared_env missing leaves: {sorted(missing)}")
        self.shared_env = {k: np.asarray(v) for k, v in shared_env.items()}
        self.window_ms = float(window_ms)
        self.max_batch = int(max_batch)
        self.pool = ConnectionPool(backend, path, size=pool_size)
        self.engines = [SQLEngine(adapter=a, dialect=dialect,
                                  plan_cache_=plan_cache_)
                        for a in self.pool]
        self._q: queue.Queue = queue.Queue()
        self._threads: list[threading.Thread] = []
        self._started = False
        self._stopping = False

    # -- lifecycle ----------------------------------------------------------
    def start(self) -> "SQLBatchServer":
        """Ingest the shared leaves into every worker (a ``:memory:``
        sqlite pool is N independent databases; file/duckdb pools skip
        all but the first by shared digest) and launch one dispatcher
        thread per worker."""
        if self._started:
            return self
        for eng in self.engines:
            eng._write_env(self.roots, self.shared_env,
                           names=set(self.shared_env))
        for k, eng in enumerate(self.engines):
            t = threading.Thread(target=self._worker_loop, args=(eng,),
                                 name=f"sql-serve-{k}", daemon=True)
            t.start()
            self._threads.append(t)
        self._started = True
        return self

    def stop(self) -> None:
        if not self._started or self._stopping:
            return
        self._stopping = True
        for _ in self._threads:
            self._q.put(_STOP)
        for t in self._threads:
            t.join(timeout=30)
        self.pool.close()

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()

    # -- requests -----------------------------------------------------------
    def submit(self, leaves: dict, tenant: str | None = None) -> Future:
        """Enqueue one request.  ``leaves`` maps every name in
        ``batch_vars`` to that request's matrix; the Future resolves to
        ``[array per root]`` (each of the root's own unbatched shape)."""
        if not self._started:
            raise RuntimeError("server not started — call start()")
        if set(leaves) != set(self.batch_vars):
            raise KeyError(f"request leaves {sorted(leaves)} != "
                           f"batch_vars {list(self.batch_vars)}")
        p = _Pending({k: np.asarray(v, dtype=np.float64)
                      for k, v in leaves.items()}, Future(), tenant)
        tracer_of(self).inc("serve.db_submitted")
        self._q.put(p)
        return p.future

    def __call__(self, leaves: dict, tenant: str | None = None):
        """Synchronous convenience: submit and wait."""
        return self.submit(leaves, tenant=tenant).result()

    # -- dispatcher ---------------------------------------------------------
    def _gather(self) -> list[_Pending] | None:
        """Block for the first request, then collect co-batchable arrivals
        until the window closes or the group is full.  None → shut down
        (the stop sentinel is re-queued so sibling workers see it too)."""
        first = self._q.get()
        if first is _STOP:
            return None
        group = [first]
        deadline = time.perf_counter() + self.window_ms / 1e3
        while len(group) < self.max_batch:
            left = deadline - time.perf_counter()
            if left <= 0:
                break
            try:
                nxt = self._q.get(timeout=left)
            except queue.Empty:
                break
            if nxt is _STOP:
                self._q.put(_STOP)   # sibling dispatchers still need it
                break
            group.append(nxt)
        return group

    def _worker_loop(self, eng: SQLEngine) -> None:
        tr = tracer_of(self)
        while True:
            group = self._gather()
            if group is None:
                return
            t0 = time.perf_counter()
            try:
                batch_env = {
                    name: np.stack([p.leaves[name] for p in group])
                    for name in self.batch_vars}
                outs = eng.evaluate_batched(self.roots, self.shared_env,
                                            batch_env)
            except Exception as exc:
                for p in group:
                    if not p.future.cancelled():
                        p.future.set_exception(exc)
                tr.inc("serve.db_failed", len(group))
                continue
            dt_ms = (time.perf_counter() - t0) * 1e3
            tr.inc("serve.db_batches")
            tr.inc("serve.db_requests", len(group))
            tr.observe("serve.db_batch_size", len(group))
            tr.observe("serve.db_batch_ms", dt_ms)
            now = time.perf_counter()
            for k, p in enumerate(group):
                req_ms = (now - p.t_enqueued) * 1e3
                tr.observe("serve.db_request_ms", req_ms)
                tr.observe("serve.db_queue_ms", (t0 - p.t_enqueued) * 1e3)
                if p.tenant is not None:
                    tr.point("serve.db_request_ms", req_ms, tenant=p.tenant)
                if not p.future.cancelled():
                    p.future.set_result([out[k] for out in outs])
