"""launch layer."""
