"""Pallas TPU kernel: blocked causal attention with online softmax.

Beyond-paper optimisation (DESIGN.md §6): the prefill_32k roofline is
dominated by the quadratic attention; a dense-masked softmax materialises the
(S × S) score matrix in HBM and computes the masked upper triangle anyway.
This kernel streams KV blocks through VMEM with the online-softmax recurrence
(running max m, normaliser l, accumulator in f32 scratch) and *skips*
strictly-future blocks, halving both HBM traffic and MXU work for causal
shapes. GQA is handled in the index_map (query head h reads KV head h // G) —
no materialised repeat of K/V.

grid = (B, Hq, S/blk_q, S/blk_k), KV innermost for accumulation.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

_NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
            scale: float, blk_q: int, blk_k: int, n_k_blocks: int,
            causal: bool):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, _NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Causal: block (qi, ki) contributes iff ki·blk_k ≤ qi·blk_q + blk_q − 1.
    live = (ki * blk_k <= qi * blk_q + blk_q - 1) if causal else True

    @pl.when(live)
    def _body():
        q = q_ref[0, 0].astype(jnp.float32)                      # (blk_q, d)
        k = k_ref[0, 0].astype(jnp.float32)                      # (blk_k, d)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        if causal:
            qpos = qi * blk_q + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 0)
            kpos = ki * blk_k + jax.lax.broadcasted_iota(
                jnp.int32, (blk_q, blk_k), 1)
            s = jnp.where(qpos >= kpos, s, _NEG_INF)
        m_prev = m_scr[...]                                   # (blk_q, 1)
        m_new = jnp.maximum(m_prev, s.max(axis=-1, keepdims=True))
        p = jnp.exp(s - m_new)
        alpha = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * alpha + p.sum(axis=-1, keepdims=True)
        acc_scr[...] = acc_scr[...] * alpha + jnp.dot(
            p, v_ref[0, 0].astype(jnp.float32),
            preferred_element_type=jnp.float32)
        m_scr[...] = m_new

    @pl.when(ki == n_k_blocks - 1)
    def _flush():
        o_ref[0, 0] = (acc_scr[...] / l_scr[...]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "scale", "blk_q",
                                             "blk_k", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, scale: float | None = None,
                    blk_q: int = 128, blk_k: int = 128,
                    interpret: bool = True) -> jax.Array:
    """q: (B, Hq, S, D); k/v: (B, Hkv, S, D), Hq % Hkv == 0 (GQA)."""
    b, hq, s, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0
    group = hq // hkv
    if scale is None:
        scale = d ** -0.5
    blk_q, blk_k = min(blk_q, s), min(blk_k, s)
    if s % blk_q or s % blk_k:
        raise ValueError(f"seq {s} not divisible by blocks {blk_q}/{blk_k}")
    n_k_blocks = s // blk_k
    grid = (b, hq, s // blk_q, n_k_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, scale=scale, blk_q=blk_q, blk_k=blk_k,
                          n_k_blocks=n_k_blocks, causal=causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, blk_q, d),
                         lambda bb, h, i, kk: (bb, h, i, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda bb, h, i, kk: (bb, h // group, kk, 0)),
            pl.BlockSpec((1, 1, blk_k, d),
                         lambda bb, h, i, kk: (bb, h // group, kk, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, blk_q, d),
                               lambda bb, h, i, kk: (bb, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, 1), jnp.float32),
            pltpu.VMEM((blk_q, d), jnp.float32),
        ],
        interpret=interpret,
    )(q, k, v)
