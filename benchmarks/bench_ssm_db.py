"""SSM-in-SQL smoke benchmark: SSD scans and the LRU matrix recurrence.

Times the state-space workloads of ``repro.db.zoo.ssm_to_sql`` across the
two in-database representations and the JAX baseline, checking the ≤1e-4
differential contract against ``nn/ssm.ssd_naive`` on the way:

* **SSD / Mamba-2** — the kron-flattened scalar-decay scan: relational
  (ONE recursive CTE over the (S, N·P) state relation) vs array (ONE
  recursive CTE carrying an array-typed state row) vs an un-jitted
  ``lax.scan``; plus the chunked execution (one query per chunk, state
  carried through the h0 leaf);
* **LRU** — the dense-block ``MatRecurrence`` layer, forward and
  Algorithm-1 gradients, both representations;
* **state-size growth curve** — wall time vs state size N (the N·P state
  columns are the relational recursion's working set).

Emits ``BENCH_ssm_db.json``.  CI runs it on sqlite (tier-1 smoke) and on
duckdb (extras job) and uploads the artifact.

Run:  PYTHONPATH=src python benchmarks/bench_ssm_db.py
CI smoke:  … bench_ssm_db.py --seq 8 --state 2 --headdim 2 --curve 2,4
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax
import jax.numpy as jnp

try:
    from common import timeit            # script mode (CI invocation)
except ImportError:  # pragma: no cover - package mode
    from .common import timeit
from repro import obs
from repro.obs import regress
from repro.db import HAVE_DUCKDB, zoo
from repro.db.sql_engine import SQLEngine
from repro.nn import ssm

TOL = 1e-4


def make_inputs(rng, s, n, p):
    x = rng.randn(s, p).astype(np.float32)
    a = (-rng.rand(s).astype(np.float32))           # log decay ≤ 0
    b = (rng.randn(s, n) * 0.5).astype(np.float32)
    c = (rng.randn(s, n) * 0.5).astype(np.float32)
    return x, a, b, c


def lax_scan_ssd(x, a, b, c):
    """The un-jitted lax.scan baseline (op-by-op dispatch, like the SQL
    engines — the jit/XLA-fused numbers live in the roofline benches)."""
    da = jnp.exp(jnp.asarray(a))

    def step(h, inp):
        xt, dat, bt, ct = inp
        h2 = dat * h + jnp.outer(bt, xt)
        return h2, ct @ h2

    h0 = jnp.zeros((b.shape[1], x.shape[1]))
    _, ys = jax.lax.scan(step, h0, (jnp.asarray(x), da, jnp.asarray(b),
                                    jnp.asarray(c)))
    return jax.block_until_ready(ys)


def engines(backend):
    return [("relational", SQLEngine(backend=backend)),
            ("array", SQLEngine(backend=backend, dialect="array"))]


def bench_ssd(args, backend: str) -> dict:
    rng = np.random.RandomState(0)
    s, n, p = args.seq, args.state, args.headdim
    x, a, b, c = make_inputs(rng, s, n, p)
    y_ref, h_ref = ssm.ssd_naive(jnp.asarray(x[None, :, None, :]),
                                 jnp.asarray(a[None, :, None]),
                                 jnp.asarray(b[None]), jnp.asarray(c[None]))
    y_ref = np.asarray(y_ref)[0, :, 0, :]
    h_ref = np.asarray(h_ref)[0, 0]
    t_jax = timeit(lambda: lax_scan_ssd(x, a, b, c), iters=args.timing_iters)

    out = {"config": {"seq": s, "state": n, "headdim": p,
                      "state_cols": n * p, "chunk": args.chunk},
           "lax_scan_s": t_jax}
    errs = []
    for label, eng in engines(backend):
        y_db, h_db = zoo.run_ssd_in_db(x, a, b, c, engine=eng)
        out[f"{label}_s"] = timeit(
            lambda: zoo.run_ssd_in_db(x, a, b, c, engine=eng),
            iters=args.timing_iters)
        err = max(float(np.abs(y_db - y_ref).max()),
                  float(np.abs(h_db - h_ref).max()))
        out[f"{label}_max_err"] = err
        errs.append(err)
        if label == "relational":
            out["chunked_s"] = timeit(
                lambda: zoo.run_ssd_in_db(x, a, b, c, chunk=args.chunk,
                                          engine=eng),
                iters=args.timing_iters)
            y_ch, h_ch = zoo.run_ssd_in_db(x, a, b, c, chunk=args.chunk,
                                           engine=eng)
            errs.append(max(float(np.abs(y_ch - y_ref).max()),
                            float(np.abs(h_ch - h_ref).max())))
        eng.close()
    out["within_tol"] = bool(max(errs) < TOL)
    return out


def bench_lru(args, backend: str) -> dict:
    rng = np.random.RandomState(1)
    s, d = args.seq, args.state * args.headdim      # comparable state size
    u = rng.randn(s, d).astype(np.float32)
    a = (rng.randn(d, d) * (0.5 / np.sqrt(d))).astype(np.float32)
    wb = (rng.randn(d, d) * 0.5).astype(np.float32)
    wc = (rng.randn(d, d) * 0.5).astype(np.float32)
    y_ref, _ = zoo.lru_ref(u, a, wb, wc)

    def jref():
        bb = jnp.asarray(u) @ jnp.asarray(wb)

        def step(h, bt):
            h2 = h @ jnp.asarray(a) + bt
            return h2, h2

        _, hs = jax.lax.scan(step, jnp.zeros(d), bb)
        return jax.block_until_ready(hs @ jnp.asarray(wc))

    out = {"config": {"seq": s, "d_state": d},
           "lax_scan_s": timeit(jref, iters=args.timing_iters)}
    errs = []
    for label, eng in engines(backend):
        y_db = zoo.run_lru_in_db(u, a, wb, wc, engine=eng)
        out[f"{label}_s"] = timeit(
            lambda: zoo.run_lru_in_db(u, a, wb, wc, engine=eng),
            iters=args.timing_iters)
        errs.append(float(np.abs(y_db - y_ref).max()))
        if label == "relational":  # Algorithm-1 backward, in-database
            out["grads_s"] = timeit(
                lambda: zoo.lru_grads_in_db(u, a, wb, wc, engine=eng),
                iters=args.timing_iters)
        eng.close()
    out["max_err"] = max(errs)
    out["within_tol"] = bool(max(errs) < TOL)
    return out


def bench_curve(args, backend: str) -> list[dict]:
    """Wall time vs state size N at fixed seq/headdim — the growth curve
    of the recursion's working set (N·P state columns per step)."""
    points = []
    for n in args.curve:
        rng = np.random.RandomState(2)
        x, a, b, c = make_inputs(rng, args.seq, n, args.headdim)
        point = {"state": n, "state_cols": n * args.headdim,
                 "lax_scan_s": timeit(lambda: lax_scan_ssd(x, a, b, c),
                                      iters=args.timing_iters)}
        for label, eng in engines(backend):
            zoo.run_ssd_in_db(x, a, b, c, engine=eng)   # warm tables/plans
            point[f"{label}_s"] = timeit(
                lambda: zoo.run_ssd_in_db(x, a, b, c, engine=eng),
                iters=args.timing_iters)
            eng.close()
        points.append(point)
    return points


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seq", type=int, default=12)
    ap.add_argument("--state", type=int, default=4, help="state size N")
    ap.add_argument("--headdim", type=int, default=4, help="head dim P")
    ap.add_argument("--chunk", type=int, default=4)
    ap.add_argument("--curve", default="2,4,8",
                    help="comma-separated N values (empty to skip)")
    ap.add_argument("--timing-iters", type=int, default=3)
    ap.add_argument("--backend", default="sqlite",
                    choices=["sqlite", "duckdb", "auto"])
    ap.add_argument("--out", default="BENCH_ssm_db.json")
    args = ap.parse_args()
    args.curve = [int(v) for v in args.curve.split(",") if v]
    backend = ("duckdb" if HAVE_DUCKDB else "sqlite") \
        if args.backend == "auto" else args.backend

    print(f"== SSM-in-SQL smoke, backend={backend} ==")
    tracer = obs.Tracer()
    with obs.use(tracer):
        ssd = bench_ssd(args, backend)
        print(f"ssd scan:  lax {ssd['lax_scan_s']*1e3:8.1f} ms | rel "
              f"{ssd['relational_s']*1e3:8.1f} ms | array "
              f"{ssd['array_s']*1e3:8.1f} ms | max err "
              f"{max(ssd['relational_max_err'], ssd['array_max_err']):.2e}",
              flush=True)
        lru = bench_lru(args, backend)
        print(f"lru layer: lax {lru['lax_scan_s']*1e3:8.1f} ms | rel "
              f"{lru['relational_s']*1e3:8.1f} ms | array "
              f"{lru['array_s']*1e3:8.1f} ms | max err {lru['max_err']:.2e}",
              flush=True)
        curve = bench_curve(args, backend)
        for pt in curve:
            print(f"  curve N={pt['state']:3d} ({pt['state_cols']:4d} cols): "
                  f"rel {pt['relational_s']*1e3:8.1f} ms | array "
                  f"{pt['array_s']*1e3:8.1f} ms", flush=True)
    trace_path = obs.write_chrome_trace(
        tracer, args.out.rsplit(".", 1)[0] + ".trace.json")
    print(f"perfetto trace -> {trace_path}", flush=True)

    report = {"backend": backend, "have_duckdb": HAVE_DUCKDB,
              "ssd": ssd, "lru": lru, "curve": curve,
              "trace": {"stage_totals": obs.summarize(tracer, top=12),
                        "scan_chunks": obs.stage_breakdown(
                            tracer, root="zoo.ssd_scan")},
              "metrics": {
                  "ssd.relational_s": regress.metric(ssd["relational_s"]),
                  "ssd.array_s": regress.metric(ssd["array_s"]),
                  "lru.relational_s": regress.metric(lru["relational_s"]),
                  "lru.array_s": regress.metric(lru["array_s"]),
                  "lru.grads_s": regress.metric(lru["grads_s"]),
              },
              "checks": {"ssd_within_1e-4": ssd["within_tol"],
                         "lru_within_1e-4": lru["within_tol"]}}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}\nchecks: {report['checks']}")
    return 0 if all(report["checks"].values()) else 1


if __name__ == "__main__":
    raise SystemExit(main())
