"""Differential tests for the in-database execution backend (repro.db).

The SQL path is validated against the JAX engines on the paper's
Section-2.2 MLP graph:

* forward values and Algorithm-1 gradients from ``Engine("sql")`` match
  ``Engine("dense")`` within tolerance;
* the recursive-CTE training loop executed by sqlite matches
  ``sgd_step_fn`` iterate-for-iterate (weights AND in-DB loss trajectory,
  ≤1e-4 per iteration — comfortably met at ~1e-6);
* the stepped Listing-7 INSERT…SELECT execution agrees as well;
* relation round-trips, dialects and adapters behave.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Engine, nn2sql, sgd_step_fn
from repro.core import expr as E
from repro.core import sqlgen
from repro.core.recursive_cte import recursive_cte_py
from repro.core.relational import RelTensor
from repro.db import (HAVE_DUCKDB, SQLiteAdapter, connect, dialect,
                      get_dialect, relation_io)
from repro.db.sql_engine import SQLEngine
from repro.db.train import (infer_in_db, loss_trajectory_in_db,
                            predict_in_db, train_in_db)

RNG = np.random.RandomState(7)
TOL = 1e-4          # acceptance tolerance (observed agreement is ~1e-6)


def mlp(n_rows=20, n_hidden=6, lr=0.05):
    """The Section-2.2 MLP graph (Iris-shaped features/classes)."""
    spec = nn2sql.MLPSpec(n_rows=n_rows, n_features=4, n_hidden=n_hidden,
                          n_classes=3, lr=lr)
    g = nn2sql.build_graph(spec)
    w0 = {k: np.asarray(v) for k, v in nn2sql.init_weights(spec).items()}
    x = RNG.rand(n_rows, 4).astype(np.float32)
    labels = RNG.randint(0, 3, n_rows)
    y = np.eye(3, dtype=np.float32)[labels]
    return g, w0, x, y, labels


# ---------------------------------------------------------------------------
# relation_io round trips
# ---------------------------------------------------------------------------

class TestRelationIO:
    def test_dense_roundtrip(self):
        a = RNG.randn(5, 3)
        assert np.allclose(
            relation_io.rows_to_matrix(relation_io.matrix_to_rows(a), a.shape),
            a)

    def test_rows_are_one_based(self):
        rows = relation_io.matrix_to_rows(np.ones((2, 2)))
        assert min(r[0] for r in rows) == 1 and max(r[0] for r in rows) == 2

    def test_reltensor_roundtrip(self):
        a = jnp.asarray(RNG.randn(4, 6), jnp.float32)
        rt = RelTensor.from_dense(a)
        back = relation_io.rows_to_reltensor(
            relation_io.reltensor_to_rows(rt), rt.shape)
        assert np.allclose(back.to_dense(), a)

    def test_reltensor_padding_dropped(self):
        # a sparse relation with one padding tuple (i == shape[0])
        rt = RelTensor(i=jnp.asarray([0, 2], jnp.int32),
                       j=jnp.asarray([1, 0], jnp.int32),
                       v=jnp.asarray([3.0, 0.0], jnp.float32), shape=(2, 2))
        rows = relation_io.reltensor_to_rows(rt)
        assert rows == [(1, 2, 3.0)]

    def test_db_write_read(self):
        a = RNG.randn(3, 4)
        with connect("sqlite") as ad:
            relation_io.write_matrix(ad, "m", a)
            assert np.allclose(relation_io.read_matrix(ad, "m", a.shape), a)

    def test_json_codec(self):
        a = RNG.randn(2, 5)
        assert np.allclose(dialect.json_to_matrix(dialect.matrix_to_json(a)), a)

    def test_vectorized_pivot_equals_percell_baseline(self):
        a = RNG.randn(7, 5)
        assert relation_io.matrix_to_rows(a) \
            == relation_io.matrix_to_rows_percell(a)
        i, j, v = relation_io.matrix_to_columns(a)
        assert relation_io.columns_to_rows(i, j, v) \
            == relation_io.matrix_to_rows(a)

    def test_vectorized_and_percell_ingestion_agree(self, monkeypatch):
        from repro.db import adapter as adapter_mod
        # force several VALUES batches + several executemany chunks
        monkeypatch.setattr(adapter_mod.SQLiteAdapter, "ROWS_PER_STMT", 7)
        monkeypatch.setattr(adapter_mod, "CHUNK_ROWS", 11)
        a = RNG.randn(6, 9)
        with connect("sqlite") as ad:
            relation_io.write_matrix_percell(ad, "base", a)
            relation_io.write_matrix(ad, "fast", a)
            ad.create_table("generic", relation_io.MATRIX_COLUMNS)
            adapter_mod.Adapter.insert_columns(
                ad, "generic", relation_io.matrix_to_columns(a))
            base = sorted(ad.execute("select i, j, v from base"))
            assert sorted(ad.execute("select i, j, v from fast")) == base
            assert sorted(ad.execute("select i, j, v from generic")) == base

    def test_empty_rows_pivot(self):
        assert relation_io.rows_to_matrix([], (2, 3)).tolist() \
            == [[0.0] * 3] * 2

    def test_json_ingestion_matches_values_path(self, monkeypatch):
        """The json_each table-valued path (engine-side pivot) produces
        the same relation as multi-row VALUES — chunk boundaries included
        — up to sqlite's ~1-ulp text→real parse."""
        from repro.db import adapter as adapter_mod
        a = RNG.randn(7, 5)
        with connect("sqlite") as ad:
            if not ad.supports_json_ingest:  # pragma: no cover
                pytest.skip("sqlite built without JSON1")
            monkeypatch.setattr(adapter_mod.SQLiteAdapter,
                                "JSON_CHUNK_CELLS", 10)  # several chunks
            relation_io.write_matrix_json(ad, "mj", a)
            relation_io.write_matrix(ad, "mv", a)
            jrows = sorted(ad.execute("select i, j, v from mj"))
            vrows = sorted(ad.execute("select i, j, v from mv"))
            assert [(r[0], r[1]) for r in jrows] \
                == [(r[0], r[1]) for r in vrows]
            np.testing.assert_allclose([r[2] for r in jrows],
                                       [r[2] for r in vrows], rtol=1e-12)
            back = relation_io.read_matrix(ad, "mj", a.shape)
            np.testing.assert_allclose(back, a, rtol=1e-12)

    def test_json_ingestion_rejects_non_finite(self):
        """NaN/inf would render as JSON tokens sqlite rejects mid-chunk —
        refused up front so no partially-populated table is left behind."""
        a = np.ones((2, 2))
        a[0, 0] = np.nan
        with connect("sqlite") as ad:
            if not ad.supports_json_ingest:  # pragma: no cover
                pytest.skip("sqlite built without JSON1")
            with pytest.raises(ValueError, match="non-finite"):
                relation_io.write_matrix_json(ad, "mj", a)
            relation_io.write_matrix(ad, "mv", a)      # VALUES path binds it
            assert np.isnan(relation_io.read_matrix(ad, "mv",
                                                    a.shape)[0, 0])

    def test_json_ingestion_row_not_multiple_of_chunk(self, monkeypatch):
        from repro.db import adapter as adapter_mod
        monkeypatch.setattr(adapter_mod.SQLiteAdapter,
                            "JSON_CHUNK_CELLS", 3)  # < one row of 4 cells
        a = RNG.randn(5, 4)
        with connect("sqlite") as ad:
            if not ad.supports_json_ingest:  # pragma: no cover
                pytest.skip("sqlite built without JSON1")
            relation_io.write_matrix_json(ad, "mj", a)
            np.testing.assert_allclose(
                relation_io.read_matrix(ad, "mj", a.shape), a, rtol=1e-12)

    def test_json_ingestion_version_gate(self):
        """The auto-select satellite: ``write_matrix`` routes through the
        engine-side json_each path only on builds whose JSON functions
        are linear (≥ 3.38) — both arms exercised by pinning the detected
        version, spying which ingestion ran, and checking the tables
        agree up to the ~1-ulp text→real parse."""
        a = RNG.randn(6, 3)
        calls = []
        with connect("sqlite") as ad:
            if not ad.supports_json_ingest:  # pragma: no cover
                pytest.skip("sqlite built without JSON1")
            orig_json = ad.insert_matrix_json
            orig_cols = ad.insert_columns
            ad.insert_matrix_json = \
                lambda *args: (calls.append("json"), orig_json(*args))[1]
            ad.insert_columns = \
                lambda *args: (calls.append("values"), orig_cols(*args))[1]

            ad.sqlite_version = (3, 34, 1)       # the container's engine
            assert not ad.prefers_json_ingest
            relation_io.write_matrix(ad, "m_old", a)
            assert calls == ["values"]

            ad.sqlite_version = (3, 38, 0)       # JSON-linear build
            assert ad.prefers_json_ingest
            relation_io.write_matrix(ad, "m_new", a)
            assert calls == ["values", "json"]
            np.testing.assert_allclose(
                relation_io.read_matrix(ad, "m_new", a.shape),
                relation_io.read_matrix(ad, "m_old", a.shape), rtol=1e-12)

    def test_json_ingestion_gate_falls_back_on_non_finite(self):
        """Even on a preferred build, NaN/inf matrices must take the
        VALUES path (sqlite's JSON parser rejects the tokens)."""
        a = np.ones((2, 2))
        a[1, 1] = np.inf
        with connect("sqlite") as ad:
            if not ad.supports_json_ingest:  # pragma: no cover
                pytest.skip("sqlite built without JSON1")
            ad.sqlite_version = (3, 40, 0)
            relation_io.write_matrix(ad, "m_inf", a)   # must not raise
            assert np.isinf(relation_io.read_matrix(ad, "m_inf",
                                                    a.shape)[1, 1])

    def test_json_gate_engine_differential(self):
        """A full SQLEngine evaluation with the json path forced on stays
        ≤1e-4 vs dense (the ulp-level parse drift is far inside TOL)."""
        g, w0, x, y, _ = mlp(n_rows=6)
        loss = g.loss
        env = {**w0, "img": x, "one_hot": y}
        jenv = {k: jnp.asarray(v) for k, v in env.items()}
        ref, = Engine("dense").eval_fn([loss])(jenv)
        eng = SQLEngine(plan_cache_=False)
        eng.adapter.sqlite_version = (3, 38, 0)
        assert eng.adapter.prefers_json_ingest
        out, = eng.evaluate([loss], env)
        np.testing.assert_allclose(out, np.asarray(ref), atol=TOL)
        eng.close()


# ---------------------------------------------------------------------------
# dialects & adapters
# ---------------------------------------------------------------------------

class TestDialects:
    def test_registry(self):
        assert get_dialect("sqlite").name == "sqlite"
        assert get_dialect(get_dialect("sql92")).name == "sql92"
        with pytest.raises(ValueError):
            get_dialect("oracle")

    def test_sql92_uses_generate_series(self):
        sql = sqlgen.to_sql92([E.const(1.0, (2, 3))])
        assert "generate_series(1,2)" in sql and sql.startswith("with ")

    def test_sqlite_emulates_series(self):
        sql = sqlgen.to_sql92([E.const(1.0, (2, 3))], dialect="sqlite")
        assert "generate_series" not in sql
        assert "with recursive" in sql
        # and it actually executes
        with connect("sqlite") as ad:
            rows = ad.execute(sql)
        assert sorted(rows) == [(i, j, 1.0) for i in (1, 2) for j in (1, 2, 3)]

    def test_sqlite_udfs_registered(self):
        with connect("sqlite") as ad:
            assert ad.execute("select greatest(-2, 0)") == [(0,)]
            assert ad.execute("select exp(0.0)") == [(1.0,)]

    def test_bad_identifier_rejected(self):
        with connect("sqlite") as ad:
            with pytest.raises(ValueError):
                ad.create_table("w; drop table w", [("i", "integer")])

    def test_unknown_backend(self):
        with pytest.raises(ValueError):
            connect("mysql")

    def test_duckdb_gated(self):
        if not HAVE_DUCKDB:
            with pytest.raises(ImportError):
                connect("duckdb")
        else:  # pragma: no cover - only with the [db] extra
            with connect("duckdb") as ad:
                assert ad.dialect.name == "duckdb"

    @pytest.mark.skipif(not HAVE_DUCKDB, reason="needs the [db] extra")
    def test_duckdb_register_ingestion(self):  # pragma: no cover - CI job
        """The Arrow/ndarray register-based bulk path (no per-row Python)."""
        a = RNG.randn(40, 30)
        with connect("duckdb") as ad:
            relation_io.write_matrix(ad, "m", a)
            assert ad.execute("select count(*) from m") == [(a.size,)]
            assert np.allclose(relation_io.read_matrix(ad, "m", a.shape), a)


# ---------------------------------------------------------------------------
# forward / gradient differential: Engine("sql") ≡ Engine("dense")
# ---------------------------------------------------------------------------

class TestSQLEngineDifferential:
    def test_engine_kind_wiring(self):
        eng = Engine("sql")
        assert isinstance(eng._sql, SQLEngine)
        with pytest.raises(ValueError):
            Engine("mongodb")
        with pytest.raises(ValueError):
            Engine("dense", backend="sqlite")

    def test_forward_matches_dense(self):
        g, w0, x, y, _ = mlp()
        probs_sql, = Engine("sql").evaluate([g.a_ho], {**w0, "img": x})
        probs_dense, = Engine("dense").eval_fn([g.a_ho])(
            {k: jnp.asarray(v) for k, v in {**w0, "img": x}.items()})
        np.testing.assert_allclose(probs_sql, np.asarray(probs_dense),
                                   atol=TOL)

    def test_algorithm1_gradients_match_dense(self):
        g, w0, x, y, _ = mlp()
        env = {**w0, "img": x, "one_hot": y}
        ls, gs = Engine("sql").value_and_grad_fn(
            g.loss, [g.w_xh, g.w_ho])(env)
        ld, gd = Engine("dense").value_and_grad_fn(g.loss, [g.w_xh, g.w_ho])(
            {k: jnp.asarray(v) for k, v in env.items()})
        np.testing.assert_allclose(ls, np.asarray(ld), atol=TOL)
        for k in ("w_xh", "w_ho"):
            np.testing.assert_allclose(gs[k], np.asarray(gd[k]), atol=TOL)

    def test_building_blocks_each_op(self):
        """Every Listing-4 building block, executed in sqlite vs dense."""
        a = E.var("a", (3, 4))
        b = E.var("b", (3, 4))
        c = E.var("c", (4, 2))
        roots = [E.matmul(a, c), E.hadamard(a, b), E.add(a, b), E.sub(a, b),
                 E.scale(2.5, a), E.transpose(a), E.sigmoid(a), E.square(a),
                 E.relu(a), E.add(E.const(3.0, (3, 4)), a)]
        env = {"a": RNG.randn(3, 4), "b": RNG.randn(3, 4),
               "c": RNG.randn(4, 2)}
        outs_sql = Engine("sql").evaluate(roots, env)
        outs_dense = Engine("dense").evaluate(
            roots, {k: jnp.asarray(v, jnp.float32) for k, v in env.items()})
        for s, d in zip(outs_sql, outs_dense):
            np.testing.assert_allclose(s, np.asarray(d), atol=TOL)

    def test_var_only_root(self):
        env = {"a": RNG.randn(2, 2)}
        out, = Engine("sql").evaluate([E.var("a", (2, 2))], env)
        np.testing.assert_allclose(out, env["a"])

    def test_leaf_digest_invalidated_by_direct_table_write(self):
        """The unchanged-leaf skip must not serve stale data after
        db.train (or anyone) replaces a leaf table directly on the shared
        adapter — create_table invalidates the adapter-level digest."""
        g, w0, x, y, _ = mlp(n_rows=6, n_hidden=3)
        eng = Engine("sql")
        probs1, = eng.evaluate([g.a_ho], {**w0, "img": x})
        train_in_db(g, w0, x + 0.5, y, 1, adapter=eng._sql.adapter,
                    strategy="stepped")   # overwrites the img relation
        probs2, = eng.evaluate([g.a_ho], {**w0, "img": x})
        np.testing.assert_allclose(probs2, probs1, atol=1e-12)
        # appends (no create_table) must invalidate too
        eng._sql.adapter.insert_columns(
            "img", relation_io.matrix_to_columns(np.ones_like(x)))
        probs3, = eng.evaluate([g.a_ho], {**w0, "img": x})
        np.testing.assert_allclose(probs3, probs1, atol=1e-12)

    def test_leaf_digest_separates_shape_and_dtype(self):
        """Same bytes, different logical matrix: a (2,3) float64 buffer
        reshaped to (3,2), or reinterpreted from another dtype, must never
        satisfy the unchanged-leaf skip."""
        from repro.db.sql_engine import _digest
        a = np.arange(6, dtype=np.float64).reshape(2, 3)
        assert _digest(a, "relational") != _digest(a.reshape(3, 2),
                                                   "relational")
        assert _digest(a, "relational") != _digest(
            a.astype(np.float32), "relational")
        assert _digest(a, "relational") != _digest(a, "array")
        # engine level: a reshaped leaf is re-ingested, not skipped
        eng = SQLEngine(plan_cache_=False)
        v23 = E.var("r23", (2, 3))
        v32 = E.var("r32", (3, 2))
        out1, = eng.evaluate([v23], {"r23": a})
        np.testing.assert_allclose(out1, a)
        eng.adapter.matrix_digests["r32"] = \
            eng.adapter.matrix_digests["r23"]  # simulate a digest collision
        out2, = eng.evaluate([v32], {"r32": a.reshape(3, 2)})
        eng.close()
        np.testing.assert_allclose(out2, a.reshape(3, 2))

    def test_sgd_step_fn_surface(self):
        g, w0, x, y, _ = mlp()
        step = sgd_step_fn(g.loss, [g.w_xh, g.w_ho], g.spec.lr, Engine("sql"))
        w1, loss = step(w0, {"img": x, "one_hot": y})
        assert isinstance(loss, float)
        assert not np.allclose(w1["w_xh"], w0["w_xh"])


# ---------------------------------------------------------------------------
# in-database training: the recursive CTE ≡ sgd_step_fn, iterate-for-iterate
# ---------------------------------------------------------------------------

def dense_reference(g, w0, x, y, n_iters):
    step = sgd_step_fn(g.loss, [g.w_xh, g.w_ho], g.spec.lr, Engine("dense"))
    w = {k: jnp.asarray(v) for k, v in w0.items()}
    env = {"img": jnp.asarray(x), "one_hot": jnp.asarray(y)}
    hist, losses = [{k: np.asarray(v) for k, v in w.items()}], []
    for _ in range(n_iters):
        w, l = step(w, env)
        losses.append(float(l))
        hist.append({k: np.asarray(v) for k, v in w.items()})
    return hist, np.asarray(losses)


class TestInDBTraining:
    N = 6

    def test_recursive_cte_matches_sgd_iterate_for_iterate(self):
        """The acceptance criterion: sqlite executes the generated
        recursive-CTE training query; every weight iterate and the in-DB
        loss trajectory match Engine("dense") + sgd_step_fn ≤1e-4."""
        g, w0, x, y, _ = mlp()
        res = train_in_db(g, w0, x, y, self.N)
        assert res.strategy == "recursive"
        assert "with recursive w (iter, w_xh, w_ho)" in res.sql
        assert res.n_iters == self.N
        ref_hist, ref_losses = dense_reference(g, w0, x, y, self.N)
        for it in range(self.N + 1):
            for k in ("w_xh", "w_ho"):
                np.testing.assert_allclose(
                    res.history[it][k], ref_hist[it][k], atol=TOL,
                    err_msg=f"iter {it} {k}")
        traj = loss_trajectory_in_db(g, res.history, x, y)
        np.testing.assert_allclose(traj[:self.N], ref_losses, atol=TOL)
        # training reduced the loss
        assert traj[self.N] < traj[0]

    def test_stepped_listing7_matches_sgd_iterate_for_iterate(self):
        """Listing 7's step as INSERT…SELECT (pure SQL-92 math in sqlite)
        agrees with the dense loop on every iterate."""
        g, w0, x, y, _ = mlp()
        res = train_in_db(g, w0, x, y, self.N, strategy="stepped")
        assert res.strategy == "stepped"
        assert res.sql.lstrip().startswith("with recursive w_")
        ref_hist, _ = dense_reference(g, w0, x, y, self.N)
        for it in range(self.N + 1):
            for k in ("w_xh", "w_ho"):
                np.testing.assert_allclose(
                    res.history[it][k], ref_hist[it][k], atol=TOL,
                    err_msg=f"iter {it} {k}")

    def test_both_strategies_agree(self):
        g, w0, x, y, _ = mlp(n_rows=10, n_hidden=4)
        r1 = train_in_db(g, w0, x, y, 3)
        r2 = train_in_db(g, w0, x, y, 3, strategy="stepped")
        for k in ("w_xh", "w_ho"):
            np.testing.assert_allclose(r1.weights[k], r2.weights[k],
                                       atol=1e-9)

    def test_unknown_strategy(self):
        g, w0, x, y, _ = mlp(n_rows=4, n_hidden=2)
        with pytest.raises(ValueError):
            train_in_db(g, w0, x, y, 1, strategy="magic")

    def test_nn2sql_train_routes_sql_engine(self):
        g, w0, x, y, _ = mlp()
        jw0 = {k: jnp.asarray(v) for k, v in w0.items()}
        final, hist = nn2sql.train(g, jw0, jnp.asarray(x), jnp.asarray(y),
                                   3, Engine("sql"), materialize_history=True)
        ref_hist, _ = dense_reference(g, w0, x, y, 3)
        np.testing.assert_allclose(np.asarray(final["w_xh"]),
                                   ref_hist[3]["w_xh"], atol=TOL)
        assert hist["w_xh"].shape[0] == 4  # base + 3 iterates

    def test_recursive_cte_py_matches_scan_contract(self):
        final, hist = recursive_cte_py(0, lambda s, it: s + it + 1, 4,
                                       materialize_history=True)
        assert final == 10 and hist == [0, 1, 3, 6, 10]
        final, hist = recursive_cte_py(0, lambda s, it: s + 1, 4)
        assert final == 4 and hist is None


# ---------------------------------------------------------------------------
# in-database inference (Listing 8)
# ---------------------------------------------------------------------------

class TestInDBInference:
    def test_infer_matches_dense(self):
        g, w0, x, y, _ = mlp()
        probs = infer_in_db(g, w0, x)
        ref = nn2sql.infer(g, Engine("dense"))(
            {k: jnp.asarray(v) for k, v in w0.items()}, jnp.asarray(x))
        np.testing.assert_allclose(probs, np.asarray(ref), atol=TOL)

    def test_predict_is_highestposition(self):
        g, w0, x, y, _ = mlp()
        labels_db = predict_in_db(g, w0, x)
        probs = infer_in_db(g, w0, x)
        np.testing.assert_array_equal(labels_db, np.argmax(probs, axis=1))

    def test_trained_model_inference_in_db(self):
        """Train in-DB, infer in-DB — the full closed loop."""
        g, w0, x, y, labels = mlp(n_rows=30, lr=0.3)
        res = train_in_db(g, w0, x, y, 25)
        acc_db = float(np.mean(predict_in_db(g, res.weights, x) == labels))
        final, _ = nn2sql.train(
            g, {k: jnp.asarray(v) for k, v in w0.items()},
            jnp.asarray(x), jnp.asarray(y), 25, Engine("dense"))
        probs = nn2sql.infer(g, Engine("dense"))(final, jnp.asarray(x))
        acc_dense = float(nn2sql.accuracy(probs, jnp.asarray(labels)))
        assert abs(acc_db - acc_dense) < 1e-6
