with recursive smax_c0(i, j, v) as (
  select m.i, m.j, exp(m.v - d.mx) / d.den as v
  from zx as m inner join (
    select e.i, e.mx, sum(exp(e2.v - e.mx)) as den
      from (select i, max(v) as mx from zx group by i) e
      inner join zx as e2 on e2.i = e.i
     group by e.i, e.mx
  ) d on m.i = d.i
)
select 0 as r, i, j, v from smax_c0;
