"""Pivoting between dense arrays and the relational representation.

The paper stores a matrix as the relation ``{[i, j, v]}`` (Fig. 1) with
**1-based** indices (``generate_series(1, n)`` in Listing 5); the JAX side
(:class:`repro.core.relational.RelTensor`) is 0-based.  This module is the
boundary: every matrix entering the database is pivoted to 1-based tuples,
everything read back is pivoted to a dense 0-based array.
"""
from __future__ import annotations

import numpy as np

from ..core.relational import RelTensor
from .adapter import Adapter, _check_ident

#: column layout of every matrix table, matching the paper's Fig. 1
MATRIX_COLUMNS = (("i", "integer"), ("j", "integer"), ("v", "double precision"))


# ---------------------------------------------------------------------------
# dense ↔ rows
# ---------------------------------------------------------------------------

def matrix_to_rows(x) -> list[tuple[int, int, float]]:
    """Dense matrix → canonical row-major ``[(i, j, v)]`` (1-based)."""
    a = np.asarray(x, dtype=np.float64)
    if a.ndim != 2:
        raise ValueError(f"expected a matrix, got shape {a.shape}")
    return [(i + 1, j + 1, float(a[i, j]))
            for i in range(a.shape[0]) for j in range(a.shape[1])]


def rows_to_matrix(rows, shape: tuple[int, int]) -> np.ndarray:
    """``[(i, j, v)]`` (1-based, any order, gaps → 0) → dense matrix.

    Missing cells coalesce to 0 — the outer-join semantics of Listing 5's
    one-hot construction.
    """
    out = np.zeros(shape, dtype=np.float64)
    for i, j, v in rows:
        out[int(i) - 1, int(j) - 1] = v
    return out


# ---------------------------------------------------------------------------
# RelTensor ↔ rows (round-trips the JAX relational representation)
# ---------------------------------------------------------------------------

def reltensor_to_rows(rt: RelTensor) -> list[tuple[int, int, float]]:
    """Valid tuples only: padding rows (``i == shape[0]``) are dropped, just
    as the inner join drops them on-device."""
    i = np.asarray(rt.i)
    j = np.asarray(rt.j)
    v = np.asarray(rt.v, dtype=np.float64)
    keep = i < rt.shape[0]
    return [(int(a) + 1, int(b) + 1, float(c))
            for a, b, c in zip(i[keep], j[keep], v[keep])]


def rows_to_reltensor(rows, shape: tuple[int, int]) -> RelTensor:
    """Rows → canonical (dense row-major) RelTensor."""
    return RelTensor.from_dense(
        np.asarray(rows_to_matrix(rows, shape), dtype=np.float32))


# ---------------------------------------------------------------------------
# adapter-level matrix tables
# ---------------------------------------------------------------------------

def write_matrix(adapter: Adapter, name: str, x) -> None:
    """CREATE + bulk INSERT the relation for ``x`` (replacing any old one)."""
    adapter.create_table(name, MATRIX_COLUMNS)
    adapter.bulk_insert(name, matrix_to_rows(x))


def read_matrix(adapter: Adapter, name: str,
                shape: tuple[int, int]) -> np.ndarray:
    rows = adapter.execute(f"select i, j, v from {_check_ident(name)}")
    return rows_to_matrix(rows, shape)


def write_reltensor(adapter: Adapter, name: str, rt: RelTensor) -> None:
    adapter.create_table(name, MATRIX_COLUMNS)
    adapter.bulk_insert(name, reltensor_to_rows(rt))


def read_reltensor(adapter: Adapter, name: str,
                   shape: tuple[int, int]) -> RelTensor:
    rows = adapter.execute(f"select i, j, v from {_check_ident(name)}")
    return rows_to_reltensor(rows, shape)
