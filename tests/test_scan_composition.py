"""Scans must COMPOSE on substitution-semantics engines (sqlite).

sqlite flattens non-recursive CTE references by substitution, so a
recursive member that references the scan-input CTE re-executes it at
every step — and a scan whose input is *itself* a scan would splice one
recursion into another's recursive member.  The fix: ``_render_refs``
counts a ``Recurrence``'s input twice, so the spool pass materialises the
scan input as an engine-side temp table before the main statement.  These
tests pin both halves — the plan shape and the executed numbers.
"""
from __future__ import annotations

import numpy as np

from repro.core import expr as E
from repro.core import sqlgen
from repro.db.dialect import get_dialect
from repro.db.sql_engine import SQLEngine


def _scan(av, bv):
    """Dense reference: s_t = a_t ∘ s_{t-1} + b_t, s_0 = 0."""
    s = np.zeros(av.shape[1])
    out = []
    for t in range(av.shape[0]):
        s = av[t] * s + bv[t]
        out.append(s.copy())
    return np.asarray(out)


def _nested(T=6, C=4, seed=3):
    rng = np.random.RandomState(seed)
    a = E.var("a", (T, C))
    b = E.var("b", (T, C))
    c = E.var("c", (T, C))
    inner = E.recurrence(a, b, name="inner")
    # the inner scan in the COEFFICIENT slot — the composition that used
    # to be substituted into the outer recursive member
    outer = E.recurrence(inner, c, name="outer")
    env = {"a": rng.randn(T, C) * 0.5, "b": rng.randn(T, C),
           "c": rng.randn(T, C) * 0.5}
    return outer, env, _scan(_scan(env["a"], env["b"]), env["c"])


def test_scan_input_is_spooled_on_substitution_dialects():
    outer, _, _ = _nested()
    plan = sqlgen.render_plan([outer], dialect=get_dialect("sqlite"),
                              spool=True, spool_threshold=2)
    assert [t for t, _ in plan.steps] == ["_sp_inner"]
    assert "_sp_inner" in plan.sql


def test_nested_scan_executes_exactly_on_sqlite():
    outer, env, ref = _nested()
    with SQLEngine(plan_cache_=False) as eng:
        assert eng.spool  # sqlite < 3.35: substitution semantics
        got, = eng.evaluate([outer], env)
    np.testing.assert_allclose(got, ref, atol=1e-12)


def test_nested_scan_in_seed_slot_executes_exactly():
    T, C = 5, 3
    rng = np.random.RandomState(9)
    a = E.var("a", (T, C))
    b = E.var("b", (T, C))
    c = E.var("c", (T, C))
    inner = E.recurrence(a, b, name="inner2")
    outer = E.recurrence(c, inner, name="outer2")  # inner seeds b_t
    env = {"a": rng.randn(T, C) * 0.5, "b": rng.randn(T, C),
           "c": rng.randn(T, C) * 0.5}
    ref = _scan(env["c"], _scan(env["a"], env["b"]))
    with SQLEngine(plan_cache_=False) as eng:
        got, = eng.evaluate([outer], env)
    np.testing.assert_allclose(got, ref, atol=1e-12)


def test_scan_reused_downstream_still_exact():
    """The doubled multiplicity must not break single-scan DAGs where the
    scan output itself fans out (spooled as before)."""
    T, C = 5, 3
    rng = np.random.RandomState(4)
    a = E.var("a", (T, C))
    b = E.var("b", (T, C))
    s = E.recurrence(a, b, name="fan")
    root = E.add(s, E.hadamard(s, s))
    env = {"a": rng.randn(T, C) * 0.5, "b": rng.randn(T, C)}
    sv = _scan(env["a"], env["b"])
    with SQLEngine(plan_cache_=False) as eng:
        got, = eng.evaluate([root], env)
    np.testing.assert_allclose(got, sv + sv * sv, atol=1e-12)
