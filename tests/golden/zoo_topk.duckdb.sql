with topk_c0(i, j, v) as (
  select q.i, q.j, case when q.rnk <= 2 then 1.0 else 0.0 end as v
  from (select i, j, v, row_number() over (partition by i order by v desc, j asc) as rnk from zx) q
)
select 0 as r, i, j, v from topk_c0;
