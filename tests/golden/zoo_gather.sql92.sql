with gath_c0(i, j, v) as (
  select g.i, m.j, m.v
  from zidx as g inner join zx as m on m.i = cast(g.v as integer) + 1
)
select 0 as r, i, j, v from gath_c0;
