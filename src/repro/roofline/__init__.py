"""roofline layer."""
