"""Benchmark: in-database backend vs the JAX engines (paper Fig. 4/5 axis).

Measures, per backend, (a) one forward+gradient evaluation and (b) the full
N-iteration training loop of the Section-2.2 MLP:

* ``dense``       — Engine("dense"), jit + lax.scan
* ``relational``  — Engine("relational"), jit + lax.scan
* ``sql``         — SQLEngine on sqlite (and duckdb when installed):
                    recursive-CTE training query + stepped Listing-7

Run:  PYTHONPATH=src python benchmarks/bench_db_backend.py [--rows 60]
(``--trace-out t.json`` additionally captures the in-DB runs with the
``repro.obs`` tracer: prints the per-stage breakdown and writes a
Perfetto-loadable Chrome trace.)
"""
import argparse
import json
import time

import numpy as np
import jax
import jax.numpy as jnp

from repro import obs
from repro.obs import regress
from repro.core import Engine, nn2sql, sgd_step_fn
from repro.db import HAVE_DUCKDB
from repro.db.train import train_in_db


def wall(fn, iters=3):
    fn()  # warm (jit compile / SQL render)
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return sorted(times)[len(times) // 2]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rows", type=int, default=60)
    ap.add_argument("--hidden", type=int, default=10)
    ap.add_argument("--iters", type=int, default=10)
    ap.add_argument("--trace-out", default=None,
                    help="capture the in-DB runs with the repro.obs tracer "
                         "and write a Chrome/Perfetto trace here")
    ap.add_argument("--out", default=None,
                    help="also write the timing table as a JSON report "
                         "with a normalised 'metrics' block "
                         "(benchmarks/check_regression.py input)")
    args = ap.parse_args()

    spec = nn2sql.MLPSpec(n_rows=args.rows, n_features=4,
                          n_hidden=args.hidden, n_classes=3, lr=0.05)
    g = nn2sql.build_graph(spec)
    w0 = {k: np.asarray(v) for k, v in nn2sql.init_weights(spec).items()}
    rng = np.random.RandomState(0)
    x = rng.rand(spec.n_rows, 4).astype(np.float32)
    y = np.eye(3, dtype=np.float32)[rng.randint(0, 3, spec.n_rows)]
    jenv = {"img": jnp.asarray(x), "one_hot": jnp.asarray(y)}
    jw = {k: jnp.asarray(v) for k, v in w0.items()}

    rows = []

    # -- one forward+gradient evaluation -------------------------------------
    for kind in ("dense", "relational", "sql"):
        eng = Engine(kind)
        vg = eng.value_and_grad_fn(g.loss, [g.w_xh, g.w_ho])
        if kind == "sql":
            env = {**w0, "img": x, "one_hot": y}
            t = wall(lambda: vg(env))
        else:
            env = {**jw, **jenv}
            t = wall(lambda: jax.block_until_ready(vg(env)))
        rows.append((f"value_and_grad[{kind}]", t))

    # -- full training loop ---------------------------------------------------
    def jax_loop(kind):
        eng = Engine(kind)
        step = sgd_step_fn(g.loss, [g.w_xh, g.w_ho], spec.lr, eng)

        def run():
            w = jw
            for _ in range(args.iters):
                w, l = step(w, jenv)
            jax.block_until_ready(w)
        return run

    rows.append((f"train[dense, {args.iters} it]", wall(jax_loop("dense"))))
    rows.append((f"train[relational, {args.iters} it]",
                 wall(jax_loop("relational"))))
    rows.append((f"train[sqlite recursive-CTE, {args.iters} it]",
                 wall(lambda: train_in_db(g, w0, x, y, args.iters))))
    rows.append((f"train[sqlite stepped Listing-7, {args.iters} it]",
                 wall(lambda: train_in_db(g, w0, x, y, args.iters,
                                          strategy="stepped"))))
    if HAVE_DUCKDB:  # pragma: no cover - needs the [db] extra
        rows.append((f"train[duckdb Listing-7, {args.iters} it]",
                     wall(lambda: train_in_db(g, w0, x, y, args.iters,
                                              backend="duckdb"))))

    print(f"\nMLP {spec.n_rows}x{spec.n_features}"
          f" h={spec.n_hidden} c={spec.n_classes}")
    print(f"{'benchmark':46s} {'median ms':>10s}")
    for name, t in rows:
        print(f"{name:46s} {t * 1e3:10.2f}")

    if args.out:
        slug = {f"value_and_grad[{k}]": f"value_and_grad.{k}_s"
                for k in ("dense", "relational", "sql")}
        slug.update({
            f"train[dense, {args.iters} it]": "train.dense_s",
            f"train[relational, {args.iters} it]": "train.relational_s",
            f"train[sqlite recursive-CTE, {args.iters} it]":
                "train.sqlite_recursive_s",
            f"train[sqlite stepped Listing-7, {args.iters} it]":
                "train.sqlite_stepped_s",
            f"train[duckdb Listing-7, {args.iters} it]":
                "train.duckdb_s",
        })
        report = {
            "config": {"rows": args.rows, "hidden": args.hidden,
                       "iters": args.iters, "have_duckdb": HAVE_DUCKDB},
            "timings": {name: t for name, t in rows},
            "metrics": {slug[name]: regress.metric(t)
                        for name, t in rows if name in slug},
        }
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)
        print(f"wrote {args.out}")

    if args.trace_out:
        tracer = obs.Tracer()
        with obs.use(tracer):
            train_in_db(g, w0, x, y, args.iters)
        bd = obs.stage_breakdown(tracer, root="train.in_db")
        print(f"\ntraced train.in_db: {bd['wall_s'] * 1e3:.1f} ms wall, "
              f"{bd['attribution']:.1%} attributed")
        for stage, d in bd["stages"].items():
            print(f"  {stage:<22s} {d['pct_of_root']:5.1f}% "
                  f"({d['total_s'] * 1e3:.2f} ms)")
        obs.write_chrome_trace(tracer, args.trace_out)
        print(f"perfetto trace -> {args.trace_out}")


if __name__ == "__main__":
    main()
