with shift_c0(i, j, v) as (
  select a.i, b.j, coalesce(m.v, 0.0) as v
  from (select generate_series as i from generate_series(1,4)) a cross join
       (select generate_series as j from generate_series(1,3)) b
  left join zx as m on m.i = a.i - (1) and m.j = b.j
),
shift_c1(i, j, v) as (
  select a.i, b.j, coalesce(m.v, 0.0) as v
  from (select generate_series as i from generate_series(1,4)) a cross join
       (select generate_series as j from generate_series(1,3)) b
  left join zx as m on m.i = a.i - (-1) and m.j = b.j
)
select 0 as r, i, j, v from shift_c0
union all select 1 as r, i, j, v from shift_c1;
