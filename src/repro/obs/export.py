"""Exporters and aggregations over collected trace spans.

Three consumers, three shapes:

* **Chrome trace / Perfetto** — :func:`chrome_trace` renders the span list
  as the Trace Event Format (``"X"`` complete events, microsecond
  timestamps), loadable in ``chrome://tracing`` and https://ui.perfetto.dev.
  CI uploads one next to every ``BENCH_*.json``.
* **the database itself** — :func:`write_trace_spans` pivots the spans into
  a ``trace_spans`` relation *inside the traced engine*, so the question
  "which stage dominates a training step" is a plain SQL query
  (:data:`STAGE_SQL`) against the same database that ran the workload —
  the SQL4NN "models are data you can query" premise applied to the
  engine's own telemetry.
* **benchmark reports** — :func:`summarize` (per-name totals) and
  :func:`stage_breakdown` (direct children of a root span, with the
  fraction of root wall time they attribute) are the per-stage sections of
  the committed ``BENCH_*.json`` files.
"""
from __future__ import annotations

import json

#: column layout of the in-database span relation (``write_trace_spans``)
TRACE_SPAN_COLUMNS = (
    ("span_id", "integer"), ("parent_id", "integer"), ("name", "text"),
    ("path", "text"), ("t0_us", "double precision"),
    ("dur_us", "double precision"), ("thread", "integer"), ("attrs", "text"),
)

#: the SQL recipe: per-stage totals over the span relation, dominant first
#: (run it against the same connection that executed the traced workload)
STAGE_SQL = (
    "select name, count(*) as n, sum(dur_us) / 1e3 as total_ms\n"
    "  from trace_spans where parent_id is not null\n"
    " group by name order by total_ms desc"
)


def _json_attrs(attrs: dict) -> str:
    """Attrs → JSON, numpy scalars and other exotica stringified."""
    return json.dumps(attrs, default=str, sort_keys=True)


def _tid_map(spans) -> dict:
    """Thread idents → small stable ints (Chrome wants readable tids)."""
    tids: dict = {}
    for s in spans:
        if s.tid not in tids:
            tids[s.tid] = len(tids)
    return tids


# ---------------------------------------------------------------------------
# Chrome trace / Perfetto
# ---------------------------------------------------------------------------

def chrome_trace(tracer) -> dict:
    """Span list → Trace Event Format dict (``"X"`` complete events)."""
    spans = list(tracer.spans)
    tids = _tid_map(spans)
    events = [{
        "name": s.name,
        "cat": "repro",
        "ph": "X",
        "ts": round(s.t0 * 1e6, 3),
        "dur": round(s.duration * 1e6, 3),
        "pid": 0,
        "tid": tids[s.tid],
        "args": {k: (v if isinstance(v, (int, float, str, bool))
                     or v is None else str(v))
                 for k, v in s.attrs.items()},
    } for s in spans]
    counters = tracer.counters
    gauges = tracer.gauges
    return {"traceEvents": events, "displayTimeUnit": "ms",
            "otherData": {"counters": counters, "gauges": gauges,
                          "histograms": tracer.histograms,
                          "metricPoints": [
                              {"seq": p.seq, "t_us": round(p.t * 1e6, 3),
                               "metric": p.metric, "step": p.step,
                               "value": p.value, "labels": p.labels}
                              for p in tracer.points]}}


def write_chrome_trace(tracer, path: str) -> str:
    """Write the Perfetto-loadable JSON; returns ``path``."""
    with open(path, "w") as f:
        json.dump(chrome_trace(tracer), f, indent=1, sort_keys=True)
    return path


# ---------------------------------------------------------------------------
# the trace_spans relation
# ---------------------------------------------------------------------------

def write_trace_spans(adapter, tracer, table: str = "trace_spans") -> int:
    """Store the finished spans as a relation in the target database
    (replacing any previous capture).  Returns the row count.

    The adapter is duck-typed (``create_table`` + ``bulk_insert``), so the
    spans land in whichever engine ran the workload — queryable with
    :data:`STAGE_SQL` on the very connection they measure."""
    spans = list(tracer.spans)
    tids = _tid_map(spans)
    adapter.create_table(table, TRACE_SPAN_COLUMNS)
    adapter.bulk_insert(table, [
        (s.span_id, s.parent_id, s.name, s.path,
         round(s.t0 * 1e6, 3), round(s.duration * 1e6, 3),
         tids[s.tid], _json_attrs(s.attrs))
        for s in spans])
    return len(spans)


# ---------------------------------------------------------------------------
# report aggregations
# ---------------------------------------------------------------------------

def summarize(tracer, top: int | None = None) -> dict:
    """Per-span-name aggregation: ``{name: {count, total_s, mean_s,
    max_s}}``, largest total first (``top`` caps the entries)."""
    agg: dict[str, dict] = {}
    for s in tracer.spans:
        d = agg.setdefault(s.name, {"count": 0, "total_s": 0.0, "max_s": 0.0})
        d["count"] += 1
        d["total_s"] += s.duration
        d["max_s"] = max(d["max_s"], s.duration)
    for d in agg.values():
        d["mean_s"] = d["total_s"] / d["count"]
    ordered = sorted(agg.items(), key=lambda kv: -kv[1]["total_s"])
    if top is not None:
        ordered = ordered[:top]
    return dict(ordered)


def stage_breakdown(tracer, root: str | None = None) -> dict:
    """Attribute a root span's wall time to its *direct* children, grouped
    by name — the per-stage section of the benchmark reports.

    ``root`` selects root spans by name (default: every parentless span).
    ``attribution`` is Σ(child durations) / Σ(root durations): the fraction
    of measured wall time the named stages account for (the acceptance
    criterion asks ≥ 0.9 for one MNIST training iteration)."""
    spans = list(tracer.spans)
    roots = [s for s in spans
             if (s.name == root if root is not None else s.parent_id is None)]
    root_ids = {s.span_id for s in roots}
    root_s = sum(s.duration for s in roots)
    stages: dict[str, dict] = {}
    covered = 0.0
    for s in spans:
        if s.parent_id not in root_ids:
            continue
        d = stages.setdefault(s.name, {"count": 0, "total_s": 0.0})
        d["count"] += 1
        d["total_s"] += s.duration
        covered += s.duration
    for d in stages.values():
        d["pct_of_root"] = (100.0 * d["total_s"] / root_s) if root_s else 0.0
    return {
        "root": root if root is not None else "<top-level>",
        "root_count": len(roots),
        "wall_s": root_s,
        "attributed_s": covered,
        "attribution": (covered / root_s) if root_s else 0.0,
        "stages": dict(sorted(stages.items(),
                              key=lambda kv: -kv[1]["total_s"])),
    }
