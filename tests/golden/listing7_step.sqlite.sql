with recursive w_(iter, id, i, j, v) as (
  select iter, id, i, j, v from w
   where iter = (select max(iter) from w)
),
w_xh(i, j, v) as (
  select i, j, v from w_ where id = 0
   and iter = (select max(iter) from w_)
),
z_xh(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from img as m inner join w_xh as n on m.j = n.i
  group by m.i, n.j
),
a_xh(i, j, v) as (
  select i, j, 1/(1+exp(-v)) as v from z_xh
),
w_ho(i, j, v) as (
  select i, j, v from w_ where id = 1
   and iter = (select max(iter) from w_)
),
z_ho(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from a_xh as m inner join w_ho as n on m.j = n.i
  group by m.i, n.j
),
a_ho(i, j, v) as (
  select i, j, 1/(1+exp(-v)) as v from z_ho
),
diff(i, j, v) as (
  select m.i, m.j, m.v - n.v as v
  from a_ho as m inner join one_hot as n on m.i = n.i and m.j = n.j
),
loss(i, j, v) as (
  select i, j, v*v as v from diff
),
t_c0(i, j, v) as (
  select j as i, i as j, v from img
),
const_c1(i, j, v) as (
  select a.i, b.j, 1.0 as v
  from (with recursive s(x) as (select 1 union all select x+1 from s where x < 4) select x as i from s) a,
       (with recursive s(x) as (select 1 union all select x+1 from s where x < 2) select x as j from s) b
),
dsqr_loss(i, j, v) as (
  select i, j, 2*v as v from diff
),
had_c2(i, j, v) as (
  select m.i, m.j, m.v * n.v as v
  from const_c1 as m inner join dsqr_loss as n on m.i = n.i and m.j = n.j
),
dsig_a_ho(i, j, v) as (
  select i, j, v*(1-v) as v from a_ho
),
had_c3(i, j, v) as (
  select m.i, m.j, m.v * n.v as v
  from had_c2 as m inner join dsig_a_ho as n on m.i = n.i and m.j = n.j
),
t_c4(i, j, v) as (
  select j as i, i as j, v from w_ho
),
mm_c5(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from had_c3 as m inner join t_c4 as n on m.j = n.i
  group by m.i, n.j
),
dsig_a_xh(i, j, v) as (
  select i, j, v*(1-v) as v from a_xh
),
had_c6(i, j, v) as (
  select m.i, m.j, m.v * n.v as v
  from mm_c5 as m inner join dsig_a_xh as n on m.i = n.i and m.j = n.j
),
mm_c7(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from t_c0 as m inner join had_c6 as n on m.j = n.i
  group by m.i, n.j
),
t_c8(i, j, v) as (
  select j as i, i as j, v from a_xh
),
mm_c9(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from t_c8 as m inner join had_c3 as n on m.j = n.i
  group by m.i, n.j
),
d_w(id, i, j, v) as (
    select 0, i, j, v from mm_c7 union all
    select 1, i, j, v from mm_c9
  )
insert into w
select w_.iter + 1, w_.id, w_.i, w_.j,
         w_.v - 0.05 * d_w.v
    from w_, d_w
   where 1 = 1 and w_.id = d_w.id
     and w_.i = d_w.i and w_.j = d_w.j;
