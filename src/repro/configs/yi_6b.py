"""Yi-6B — llama-architecture dense LM with GQA [arXiv:2403.04652; hf]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="yi-6b", family="dense", n_layers=32, d_model=4096, n_heads=32,
    n_kv_heads=4, d_head=128, d_ff=11008, vocab=64000, rope_theta=5e6)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="yi-6b-reduced", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=256,
        rope_theta=5e6)
