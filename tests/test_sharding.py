"""Sharding rules, validated against AbstractMesh (no devices needed)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs.base import SHAPES, get_config
from repro.launch import sharding as sh
from repro.launch.mesh import abstract_mesh


def mesh(multi=False):
    if multi:
        return abstract_mesh((2, 16, 16), ("pod", "data", "model"))
    return abstract_mesh((16, 16), ("data", "model"))


class FakeKey:
    def __init__(self, key):
        self.key = key


def spec(names, shape, m=None, stacked=False, fsdp=True):
    path = tuple(FakeKey(n) for n in names)
    return sh.param_spec(path, shape, m or mesh(), fsdp=fsdp,
                         stacked=stacked)


class TestParamRules:
    def test_column_parallel_qkv(self):
        s = spec(("layers", "attn", "wq"), (32, 4096, 4096), stacked=True)
        assert s[2] == "model" and s[0] is None       # L axis untouched
        assert s[1] == "data"                         # FSDP dim

    def test_row_parallel_out(self):
        s = spec(("layers", "attn", "wo"), (32, 4096, 4096), stacked=True)
        assert s[1] == "model"

    def test_expert_parallel(self):
        s = spec(("layers", "moe", "wi"), (40, 16, 6144, 10752),
                 stacked=True)
        assert s[1] == "model"                        # experts over model

    def test_vocab_parallel_embed(self):
        s = spec(("embed",), (64000, 4096))
        assert s[0] == "model"

    def test_non_divisible_vocab_not_sharded(self):
        s = spec(("embed",), (49155, 4096))           # granite vocab
        assert s[0] is None and s[1] == "data"        # FSDP still applies

    def test_small_params_replicated(self):
        assert spec(("layers", "norm1", "w"), (32, 4096),
                    stacked=True) == P(None, None)
        assert spec(("layers", "attn", "q_norm", "w"), (32, 128),
                    stacked=True) == P(None, None)

    def test_full_tree_shardings_cover_all_archs(self):
        for aid in ("yi_6b", "deepseek_v2_lite_16b", "dbrx_132b",
                    "rwkv6_7b", "zamba2_2_7b"):
            cfg = get_config(aid)
            from repro.launch.specs import params_specs
            shapes = params_specs(cfg)
            tree = sh.param_shardings(shapes, mesh())
            # every leaf got a NamedSharding and dims divide
            def check(sds, ns):
                pspec = ns.spec
                for dim, axes in zip(sds.shape, tuple(pspec) + (None,) *
                                     (len(sds.shape) - len(pspec))):
                    if axes is None:
                        continue
                    axes = (axes,) if isinstance(axes, str) else axes
                    size = int(np.prod([mesh().shape[a] for a in axes]))
                    assert dim % size == 0, (aid, sds.shape, pspec)
            jax.tree.map(check, shapes, tree)


class TestBatchAndCache:
    def test_batch_sharded_over_dp(self):
        b = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32)}
        tree = sh.batch_shardings(b, mesh(multi=True), 256)
        assert tree["tokens"].spec == P(("pod", "data"), None)

    def test_batch_of_one_replicated(self):
        b = {"tokens": jax.ShapeDtypeStruct((1, 1), jnp.int32)}
        tree = sh.batch_shardings(b, mesh(), 1)
        assert tree["tokens"].spec == P()

    @staticmethod
    def _norm(x):
        return x[0] if isinstance(x, tuple) and len(x) == 1 else x

    def test_gqa_cache_heads_not_divisible_uses_seq(self):
        cfg = get_config("qwen2_5_14b")               # kv heads = 8 < 16
        cache = (jax.ShapeDtypeStruct((48, 128, 8, 32768, 128),
                                      jnp.bfloat16),) * 2
        tree = sh.cache_shardings(cache, mesh(), 128, 32768, cfg)
        s = tree[0].spec
        assert self._norm(s[1]) == "data"             # batch over data
        assert self._norm(s[3]) == "model"            # seq picks up model

    def test_long500k_batch1_seq_sharded(self):
        cfg = get_config("zamba2_2_7b")
        cache = (jax.ShapeDtypeStruct((9, 1, 32, 524288, 80),
                                      jnp.bfloat16),)
        tree = sh.cache_shardings(cache, mesh(), 1, 524288, cfg)
        s = tree[0].spec
        assert self._norm(s[2]) == "model"            # 32 kv heads divide
        assert self._norm(s[3]) == "data"             # SP over data

    def test_mla_latent_cache(self):
        cfg = get_config("deepseek_v2_lite_16b")
        cache = (jax.ShapeDtypeStruct((26, 128, 32768, 512), jnp.bfloat16),)
        tree = sh.cache_shardings(cache, mesh(), 128, 32768, cfg)
        s = tree[0].spec
        assert self._norm(s[1]) == "data"
        assert self._norm(s[2]) == "model"
