"""Optimizers + gradient compression."""
from .optimizers import Optimizer, adamw, clip_by_global_norm, global_norm, sgd
from . import compression
