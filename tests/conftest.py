import os
import sys
import tempfile

# NOTE: do NOT set XLA_FLAGS / host device count here — smoke tests and
# benchmarks must see the single real CPU device (the 512-device mesh is
# exclusively the dry-run's, launched as its own process).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

# Isolate the persistent rendered-SQL plan cache: without this, every
# SQLEngine default would read/write the developer's real
# ~/.cache/repro/plan_cache.db — cross-run state that could mask (or
# cause) differential failures.  A per-session temp store keeps the
# persistence code path exercised while staying hermetic.
if "REPRO_PLAN_CACHE" not in os.environ:
    os.environ["REPRO_PLAN_CACHE"] = os.path.join(
        tempfile.mkdtemp(prefix="repro_plan_cache_"), "plans.db")
