"""NN substrate: layers, MoE (relational + array impls), SSMs, model assembly."""
from . import layers, model, moe, ssm

__all__ = ["layers", "model", "moe", "ssm"]
