"""Property tests for the vectorized relation pivots (repro.db.relation_io).

Round-trips dense ↔ rows/columns ↔ RelTensor through the meshgrid/ravel
pivots that replaced the per-cell Python loops, pinning

* shape preservation and canonical row-major order,
* 1-based indexing at the database boundary,
* gaps-coalesce-to-0 (the outer-join semantics of Listing 5),
* vectorized ≡ per-cell baseline,
* chunked adapter ingestion ≡ flat executemany.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                                         "(pip install -e .[test])")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core.relational import RelTensor
from repro.db import adapter as adapter_mod
from repro.db import connect, relation_io

shapes = st.tuples(st.integers(1, 8), st.integers(1, 8))
finite = st.floats(-1e6, 1e6, allow_nan=False, allow_infinity=False,
                   width=32)


@st.composite
def matrices(draw):
    r, c = draw(shapes)
    vals = draw(st.lists(finite, min_size=r * c, max_size=r * c))
    return np.asarray(vals, dtype=np.float64).reshape(r, c)


@st.composite
def sparse_rows(draw):
    """Unique 1-based (i, j) cells with gaps, any order."""
    r, c = draw(shapes)
    cells = draw(st.lists(
        st.tuples(st.integers(1, r), st.integers(1, c)),
        unique=True, max_size=r * c))
    vals = draw(st.lists(finite, min_size=len(cells), max_size=len(cells)))
    return [(i, j, v) for (i, j), v in zip(cells, vals)], (r, c)


class TestDenseRoundTrip:
    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_dense_rows_dense(self, a):
        rows = relation_io.matrix_to_rows(a)
        assert len(rows) == a.size
        np.testing.assert_array_equal(
            relation_io.rows_to_matrix(rows, a.shape), a)

    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_vectorized_equals_percell_baseline(self, a):
        assert relation_io.matrix_to_rows(a) \
            == relation_io.matrix_to_rows_percell(a)

    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_one_based_row_major(self, a):
        rows = relation_io.matrix_to_rows(a)
        assert rows[0][:2] == (1, 1)
        assert rows[-1][:2] == a.shape
        ii = [r[0] for r in rows]
        jj = [r[1] for r in rows]
        assert min(ii) == 1 and max(ii) == a.shape[0]
        assert min(jj) == 1 and max(jj) == a.shape[1]
        assert list(zip(ii, jj)) == sorted(zip(ii, jj))  # canonical order

    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_columns_agree_with_rows(self, a):
        i, j, v = relation_io.matrix_to_columns(a)
        assert relation_io.columns_to_rows(i, j, v) \
            == relation_io.matrix_to_rows(a)


class TestSparseRows:
    @settings(max_examples=50, deadline=None)
    @given(sparse_rows())
    def test_gaps_coalesce_to_zero(self, rows_shape):
        rows, shape = rows_shape
        m = relation_io.rows_to_matrix(rows, shape)
        assert m.shape == shape
        filled = {(i - 1, j - 1): v for i, j, v in rows}
        for (i, j), v in filled.items():
            assert m[i, j] == v
        n_zero_cells = shape[0] * shape[1] - len(filled)
        assert np.count_nonzero(m == 0.0) >= n_zero_cells \
            - sum(v == 0.0 for v in filled.values())

    @settings(max_examples=50, deadline=None)
    @given(sparse_rows())
    def test_any_order(self, rows_shape):
        rows, shape = rows_shape
        np.testing.assert_array_equal(
            relation_io.rows_to_matrix(rows, shape),
            relation_io.rows_to_matrix(rows[::-1], shape))


class TestRelTensorRoundTrip:
    @settings(max_examples=25, deadline=None)
    @given(matrices())
    def test_reltensor_rows_reltensor(self, a):
        a32 = a.astype(np.float32)
        rt = RelTensor.from_dense(a32)
        back = relation_io.rows_to_reltensor(
            relation_io.reltensor_to_rows(rt), rt.shape)
        np.testing.assert_array_equal(np.asarray(back.to_dense()), a32)

    def test_padding_rows_dropped(self):
        import jax.numpy as jnp
        rt = RelTensor(i=jnp.asarray([0, 2], jnp.int32),
                       j=jnp.asarray([1, 0], jnp.int32),
                       v=jnp.asarray([3.0, 0.0], jnp.float32), shape=(2, 2))
        assert relation_io.reltensor_to_rows(rt) == [(1, 2, 3.0)]


class TestAdapterIngestion:
    @settings(max_examples=20, deadline=None)
    @given(matrices())
    def test_write_read_through_sqlite(self, a):
        with connect("sqlite") as ad:
            relation_io.write_matrix(ad, "m", a)
            np.testing.assert_array_equal(
                relation_io.read_matrix(ad, "m", a.shape), a)

    @settings(max_examples=20, deadline=None)
    @given(matrices())
    def test_percell_and_vectorized_paths_agree(self, a):
        with connect("sqlite") as ad:
            relation_io.write_matrix_percell(ad, "base", a)
            relation_io.write_matrix(ad, "fast", a)
            assert sorted(ad.execute("select i, j, v from base")) \
                == sorted(ad.execute("select i, j, v from fast"))

    def test_chunked_executemany_boundaries(self, monkeypatch):
        """Chunk smaller than the matrix forces multiple executemany calls
        (generic path) and multiple VALUES batches (sqlite path)."""
        a = np.arange(42, dtype=np.float64).reshape(6, 7)
        monkeypatch.setattr(adapter_mod, "CHUNK_ROWS", 10)
        monkeypatch.setattr(adapter_mod.SQLiteAdapter, "ROWS_PER_STMT", 5)
        with connect("sqlite") as ad:
            relation_io.write_matrix(ad, "m", a)
            np.testing.assert_array_equal(
                relation_io.read_matrix(ad, "m", a.shape), a)
            # generic (base-class) chunked path too
            ad.create_table("g", relation_io.MATRIX_COLUMNS)
            adapter_mod.Adapter.insert_columns(
                ad, "g", relation_io.matrix_to_columns(a))
            np.testing.assert_array_equal(
                relation_io.read_matrix(ad, "g", a.shape), a)

    def test_empty_and_mismatched_columns(self):
        with connect("sqlite") as ad:
            ad.create_table("m", relation_io.MATRIX_COLUMNS)
            ad.insert_columns("m", (np.empty(0), np.empty(0), np.empty(0)))
            assert ad.execute("select count(*) from m") == [(0,)]
            with pytest.raises(ValueError):
                ad.insert_columns("m", (np.ones(2), np.ones(3), np.ones(2)))

    def test_non_matrix_rejected(self):
        with pytest.raises(ValueError):
            relation_io.matrix_to_columns(np.ones((2, 2, 2)))
        with pytest.raises(ValueError):
            relation_io.matrix_to_rows_percell(np.ones(3))


class TestArrayCodec:
    """The JSON array codec behind the ``array`` dialect: exact round trips
    and UDF algebra laws checked against dense numpy — executed through a
    live sqlite connection, so the properties hold for what the engine
    actually computes, not just the Python functions."""

    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_json_roundtrip_exact(self, a):
        from repro.db.dialect import json_to_matrix, matrix_to_json
        back = json_to_matrix(matrix_to_json(a))
        assert back.shape == a.shape
        np.testing.assert_array_equal(back, a)    # repr round-trip is exact

    @settings(max_examples=50, deadline=None)
    @given(matrices())
    def test_db_write_read_array_representation(self, a):
        with connect("sqlite") as ad:
            relation_io.write_matrix_array(ad, "m", a)
            np.testing.assert_array_equal(
                relation_io.read_matrix_array(ad, "m"), a)

    @settings(max_examples=25, deadline=None)
    @given(matrices())
    def test_transpose_involution_in_engine(self, a):
        from repro.db.dialect import json_to_matrix, matrix_to_json
        with connect("sqlite") as ad:
            (res,), = ad.execute("select mt(mt(?))", (matrix_to_json(a),))
            np.testing.assert_array_equal(json_to_matrix(res), a)

    @settings(max_examples=25, deadline=None)
    @given(st.data())
    def test_matmul_associativity_vs_dense(self, data):
        """(A·B)·C ≡ A·(B·C) ≡ numpy, through the mm UDF on sqlite.  Values
        are kept small so float64 associativity holds to tight tolerance."""
        from repro.db.dialect import json_to_matrix, matrix_to_json
        small = st.floats(-8, 8, allow_nan=False, width=32)
        r, k1, k2, c = (data.draw(st.integers(1, 5)) for _ in range(4))
        draw_m = lambda rr, cc: np.asarray(
            data.draw(st.lists(small, min_size=rr * cc, max_size=rr * cc)),
            dtype=np.float64).reshape(rr, cc)
        a, b, m = draw_m(r, k1), draw_m(k1, k2), draw_m(k2, c)
        ja, jb, jm = (matrix_to_json(x) for x in (a, b, m))
        with connect("sqlite") as ad:
            (left,), = ad.execute("select mm(mm(?, ?), ?)", (ja, jb, jm))
            (right,), = ad.execute("select mm(?, mm(?, ?))", (ja, jb, jm))
        np.testing.assert_allclose(json_to_matrix(left), (a @ b) @ m,
                                   rtol=1e-12, atol=1e-9)
        np.testing.assert_allclose(json_to_matrix(right), a @ (b @ m),
                                   rtol=1e-12, atol=1e-9)
        np.testing.assert_allclose(json_to_matrix(left),
                                   json_to_matrix(right), atol=1e-9)
