"""Run the paper's training and inference loops *inside* the database.

Three fully-in-database execution strategies for the Listing 7/10 training
recursion, picked per engine capability:

``strategy="recursive"`` (default)
    ONE recursive-CTE query performs every iteration.

    * sqlite — the Listing-10 *array* variant
      (:func:`repro.core.sqlgen.training_query_array_calls`): weight state
      rides in one row of array-typed (JSON) columns, matrix algebra comes
      from the registered UDF array extension.  This is the shape sqlite's
      recursive-select restrictions admit.
    * duckdb — Listing 7 verbatim
      (:func:`repro.core.sqlgen.training_query_sql92`): the relational
      ``w(iter, id, i, j, v)`` recursion with pure SQL-92 math.

``strategy="stepped"``
    Listing 7's recursive *step* materialised as ``INSERT INTO w … SELECT``
    (:func:`repro.core.sqlgen.training_step_sql92`), executed once per
    iteration — all matrix math still pure SQL-92 inside the engine; only
    the iteration driver (``recursive_cte_py``) lives outside, exactly the
    role the recursive CTE plays in Listing 7.  Works on every backend.

Inference (Listing 8/11) runs the forward CTEs in-database, including the
``highestposition`` rank-1 comparison as a window function.
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from ..core import expr as E
from ..core import sqlgen
from ..core.recursive_cte import recursive_cte_py
from ..obs import tracer_of
from . import plan_cache, relation_io
from .adapter import Adapter, connect
from .dialect import json_to_matrix, matrix_to_json
from .sql_engine import SQLEngine


def _training_sql(graph, kind: str, adapter: Adapter, render, cache,
                  *key_extra) -> str:
    """Render one of the training statements through the plan cache:
    keyed by the loss DAG's structural signature × renderer fingerprint ×
    dialect × renderer kind × hyper-parameters, so re-running a benchmark
    (or the next training session) skips ``sqlgen`` entirely.  ``cache``
    follows the :func:`repro.db.plan_cache.resolve` convention (None →
    shared default, False → render fresh)."""
    dialect_name = adapter.dialect.name
    cache = plan_cache.resolve(cache)
    tr = tracer_of(adapter)
    with tr.span("sql.render", kind=f"train:{kind}") as sp:
        if cache is None:
            return render()
        key = plan_cache.plan_key(
            [graph.loss], extra=(dialect_name, f"train:{kind}") + key_extra)
        hits0 = cache.hits
        sql = cache.rendered(key, dialect_name, render)
        if tr.enabled:
            sp.set(cache="hit" if cache.hits > hits0 else "miss")
        return sql


@dataclasses.dataclass
class DBTrainResult:
    """Outcome of an in-database training run."""

    weights: dict[str, np.ndarray]        # final iterate
    history: list[dict[str, np.ndarray]]  # every iterate, incl. iter 0
    strategy: str
    sql: str                              # the (last) query that ran
    #: bytes the training recursion materialised (every iterate stays in
    #: the recursive weight relation — the paper's Fig. 5 memory axis)
    cte_bytes: int = 0

    @property
    def n_iters(self) -> int:
        return len(self.history) - 1


def _open(backend: str, path: str, adapter: Adapter | None) -> tuple[Adapter, bool]:
    if adapter is not None:
        return adapter, False
    return connect(backend, path), True


# ---------------------------------------------------------------------------
# training
# ---------------------------------------------------------------------------

def _train_recursive_arrays(graph, weights, x, y_onehot, n_iters,
                            adapter: Adapter, cache=None) -> DBTrainResult:
    """One recursive query over array-typed columns (sqlite-executable)."""
    tr = tracer_of(adapter)
    with tr.span("train.ingest", representation="array"):
        adapter.create_table("weights", [("w_xh", "text"), ("w_ho", "text")])
        adapter.bulk_insert("weights", [(matrix_to_json(weights["w_xh"]),
                                         matrix_to_json(weights["w_ho"]))])
        adapter.create_table("data", [("img", "text"), ("one_hot", "text")])
        adapter.bulk_insert("data",
                            [(matrix_to_json(x), matrix_to_json(y_onehot))])
    sql = _training_sql(
        graph, "array_calls", adapter,
        lambda: sqlgen.training_query_array_calls(graph, n_iters,
                                                  graph.spec.lr),
        cache, n_iters, graph.spec.lr)
    tr.gauge("recursive_cte_depth", n_iters)
    rows = sorted(adapter.execute(sql))  # (iter, w_xh, w_ho)
    with tr.span("train.decode", rows=len(rows)):
        history = [{"w_xh": json_to_matrix(wxh), "w_ho": json_to_matrix(who)}
                   for _it, wxh, who in rows]
        cte_bytes = sum(len(wxh) + len(who) for _it, wxh, who in rows)
    return DBTrainResult(weights=history[-1], history=history,
                         strategy="recursive", sql=sql, cte_bytes=cte_bytes)


def _train_recursive_listing7(graph, weights, x, y_onehot, n_iters,
                              adapter: Adapter, cache=None) -> DBTrainResult:
    """Listing 7 verbatim — engines whose recursive CTEs are set-at-a-time
    and allow the recursive table inside a nested WITH (duckdb)."""
    tr = tracer_of(adapter)
    with tr.span("train.ingest", representation="relational"):
        relation_io.write_matrix(adapter, "img", x)
        relation_io.write_matrix(adapter, "one_hot", y_onehot)
        relation_io.write_matrix(adapter, "w_xh_init", weights["w_xh"])
        relation_io.write_matrix(adapter, "w_ho_init", weights["w_ho"])
    sql = _training_sql(
        graph, "listing7", adapter,
        lambda: sqlgen.training_query_sql92(graph, n_iters, graph.spec.lr,
                                            adapter.dialect),
        cache, n_iters, graph.spec.lr)
    tr.gauge("recursive_cte_depth", n_iters)
    rows = adapter.execute(sql)  # (iter, id, i, j, v)
    with tr.span("train.decode", rows=len(rows)):
        return _history_from_w_rows(rows, graph, sql, "recursive")


def _train_stepped(graph, weights, x, y_onehot, n_iters,
                   adapter: Adapter, cache=None) -> DBTrainResult:
    """Listing 7's step as INSERT…SELECT, iterated by ``recursive_cte_py``."""
    tr = tracer_of(adapter)
    with tr.span("train.ingest", representation="relational"):
        relation_io.write_matrix(adapter, "img", x)
        relation_io.write_matrix(adapter, "one_hot", y_onehot)
        adapter.create_table("w", [("iter", "integer"), ("id", "integer"),
                                   ("i", "integer"), ("j", "integer"),
                                   ("v", "double precision")])
        for wid, key in ((0, "w_xh"), (1, "w_ho")):
            i, j, v = relation_io.matrix_to_columns(weights[key])
            adapter.insert_columns("w", (np.zeros_like(i),
                                         np.full_like(i, wid), i, j, v))
    step_sql = _training_sql(
        graph, "stepped", adapter,
        lambda: sqlgen.training_step_sql92(graph, graph.spec.lr,
                                           adapter.dialect),
        cache, graph.spec.lr)

    def step(_state, _it):
        t0 = time.perf_counter()
        with tr.span("train.step", iter=_it):
            adapter.execute(step_sql)
        if tr.enabled:
            dt = time.perf_counter() - t0
            tr.observe("train.step_ms", dt * 1e3)
            tr.point("train.step_ms", dt * 1e3, step=_it,
                     strategy="stepped")
        return _state

    recursive_cte_py(None, step, n_iters)
    rows = adapter.execute("select iter, id, i, j, v from w")
    with tr.span("train.decode", rows=len(rows)):
        return _history_from_w_rows(rows, graph, step_sql, "stepped")


def _history_from_w_rows(rows, graph, sql, strategy) -> DBTrainResult:
    """Pivot the ``w(iter, id, i, j, v)`` history relation per iterate —
    one stacked fancy-indexed assignment per weight id instead of a Python
    loop over the (iters × cells)-sized relation."""
    shapes = {0: graph.w_xh.shape, 1: graph.w_ho.shape}
    names = {0: "w_xh", 1: "w_ho"}
    arr = np.asarray(rows, dtype=np.float64)
    t = arr[:, 0].astype(np.int64)
    wid = arr[:, 1].astype(np.int64)
    i = arr[:, 2].astype(np.int64) - 1
    j = arr[:, 3].astype(np.int64) - 1
    n_iters = int(t.max())
    stacks = {}
    for w in (0, 1):
        stack = np.zeros((n_iters + 1,) + shapes[w])
        m = wid == w
        stack[t[m], i[m], j[m]] = arr[m, 4]
        stacks[w] = stack
    history = [{names[w]: stacks[w][k] for w in (0, 1)}
               for k in range(n_iters + 1)]
    return DBTrainResult(weights=history[-1], history=history,
                         strategy=strategy, sql=sql,
                         cte_bytes=len(rows) * 5 * 8)  # (iter,id,i,j,v) rows


def train_in_db(graph, weights, x, y_onehot, n_iters: int, *,
                backend: str = "sqlite", path: str = ":memory:",
                adapter: Adapter | None = None,
                strategy: str = "recursive",
                representation: str = "auto",
                plan_cache_=None, shards: int = 1) -> DBTrainResult:
    """Train the Section-2.2 MLP inside the database.  See module docstring
    for the strategy × backend matrix.  ``plan_cache_``: a
    :class:`~repro.db.plan_cache.PlanCache`, ``None`` for the shared
    persistent default, or ``False`` to render the training SQL fresh.

    ``representation`` picks the matrix encoding of the recursive
    strategy: ``"array"`` forces the Listing-10 array recursion (one row
    of array-typed weight columns — what ``SQLEngine(dialect="array")``
    evaluates with), ``"relational"`` forces Listing 7 verbatim (set
    semantics required — duckdb; sqlite falls back to ``stepped``), and
    ``"auto"`` (default) picks whichever the engine can execute.

    ``shards=N`` (N > 1) switches to data-parallel execution
    (:func:`repro.db.shard.train_in_db_sharded`): the batch is partitioned
    across N pooled connections, gradients are reduced by a SQL AllReduce
    on a coordinator connection, and the result is a drop-in for the
    unsharded run (same update — the sum-gradient of the unreduced square
    loss — up to float summation order, ≤ 1e-4 at MNIST scale)."""
    if representation not in ("auto", "array", "relational"):
        raise ValueError(f"unknown representation {representation!r}")
    if shards != 1:
        if adapter is not None:
            raise ValueError(
                "shards > 1 needs its own connection pool — pass "
                "backend/path instead of a single adapter")
        if strategy != "recursive":
            raise ValueError(
                f"sharded training replaces the iteration strategy "
                f"(per-step SQL AllReduce); got strategy={strategy!r}")
        from .shard import train_in_db_sharded
        return train_in_db_sharded(graph, weights, x, y_onehot, n_iters,
                                   shards=shards, backend=backend,
                                   path=path, representation=representation,
                                   plan_cache_=plan_cache_)
    adapter, owned = _open(backend, path, adapter)
    if (representation == "array"
            and not getattr(adapter, "supports_python_udfs", True)):
        if owned:
            adapter.close()
        raise ValueError(
            f"the array representation needs Python UDFs, which "
            f"{type(adapter).__name__} cannot register — use "
            f"representation='relational' (or 'auto')")

    def dispatch() -> DBTrainResult:
        if strategy == "recursive":
            if representation == "array" or (
                    representation == "auto"
                    and not adapter.dialect.supports_listing7
                    and getattr(adapter, "supports_python_udfs", True)):
                return _train_recursive_arrays(
                    graph, weights, x, y_onehot, n_iters, adapter,
                    plan_cache_)
            if adapter.dialect.supports_listing7:
                return _train_recursive_listing7(
                    graph, weights, x, y_onehot, n_iters, adapter,
                    plan_cache_)
            # representation="relational" on an engine without Listing 7:
            # the stepped execution is the same math, materialised per step
            return _train_stepped(graph, weights, x, y_onehot, n_iters,
                                  adapter, plan_cache_)
        if strategy == "stepped":
            if representation == "array":
                raise ValueError("the stepped strategy is relational-only "
                                 "(INSERT…SELECT over the w cell relation)")
            return _train_stepped(graph, weights, x, y_onehot, n_iters,
                                  adapter, plan_cache_)
        raise ValueError(f"unknown strategy {strategy!r}")

    tr = tracer_of(adapter)
    try:
        t0 = time.perf_counter()
        with tr.span("train.in_db", strategy=strategy,
                     representation=representation, n_iters=n_iters,
                     backend=adapter.dialect.name):
            res = dispatch()
        if tr.enabled:       # the run's metric_points time-series entries
            dt = time.perf_counter() - t0
            tr.point("train.iter_ms", dt * 1e3 / max(n_iters, 1),
                     step=n_iters, strategy=res.strategy)
            tr.point("train.cte_bytes", res.cte_bytes, step=n_iters)
            cells = adapter.counters.get("ingest_cells")
            if cells:
                tr.point("train.rows_ingested", cells, step=n_iters)
        return res
    finally:
        if owned:
            adapter.close()


# ---------------------------------------------------------------------------
# inference (Listing 8/11)
# ---------------------------------------------------------------------------

def infer_in_db(graph, weights, x, *, backend: str = "sqlite",
                path: str = ":memory:",
                adapter: Adapter | None = None) -> np.ndarray:
    """Forward pass ``m(x)`` in-database; returns the probability matrix."""
    adapter, owned = _open(backend, path, adapter)
    try:
        eng = SQLEngine(adapter=adapter)
        probs, = eng.evaluate([graph.a_ho], {**weights, "img": x})
        return probs
    finally:
        if owned:
            adapter.close()


def predict_in_db(graph, weights, x, *, backend: str = "sqlite",
                  path: str = ":memory:",
                  adapter: Adapter | None = None) -> np.ndarray:
    """Listing 8's ``highestposition`` as a window function: argmax over the
    output relation, computed by the database.  Returns 0-based labels."""
    adapter, owned = _open(backend, path, adapter)
    try:
        with tracer_of(adapter).span("train.predict"):
            eng = SQLEngine(adapter=adapter)
            eng._write_env([graph.a_ho], {**weights, "img": x})
            tail = (f"select q.i, min(q.j) from (select i, j, v,"
                    f" max(v) over (partition by i) as mv"
                    f" from {graph.a_ho.name}) q"
                    f" where q.v = q.mv group by q.i order by q.i")
            sql = sqlgen.to_sql92([graph.a_ho], select=tail,
                                  dialect=eng.dialect)
            rows = adapter.execute(sql)
            return np.asarray([j - 1 for _i, j in rows], dtype=np.int32)
    finally:
        if owned:
            adapter.close()


def loss_trajectory_in_db(graph, history, x, y_onehot, *,
                          backend: str = "sqlite", path: str = ":memory:",
                          adapter: Adapter | None = None) -> np.ndarray:
    """Mean loss of every weight iterate, each evaluated by the database —
    the per-iteration differential signal against ``sgd_step_fn``."""
    adapter, owned = _open(backend, path, adapter)
    try:
        eng = SQLEngine(adapter=adapter)
        fn = eng.eval_fn([graph.loss])
        tr = tracer_of(adapter)
        losses = []
        for k, w in enumerate(history):
            loss = float(np.mean(fn({**w, "img": x, "one_hot": y_onehot})[0]))
            losses.append(loss)
            tr.point("train.loss", loss, step=k, source="trajectory")
        return np.asarray(losses)
    finally:
        if owned:
            adapter.close()
