"""Train and infer the paper's MLP *inside a database* (``repro.db``).

The closed loop the paper argues for: the expression DAG is transpiled to
SQL, and a real engine (stdlib sqlite3 here; duckdb when installed) runs

1. the recursive-CTE training query — every gradient-descent iteration
   happens inside the database (Listing 7/10),
2. forward inference with the ``highestposition`` argmax as a window
   function (Listing 8),

then the result is differentially checked against ``Engine("dense")``.

Run:  PYTHONPATH=src python examples/train_in_db.py
"""
import numpy as np
import jax.numpy as jnp

from repro.core import Engine, nn2sql
from repro.db import HAVE_DUCKDB, plan_cache
from repro.db.train import (infer_in_db, loss_trajectory_in_db,
                            predict_in_db, train_in_db)

N_ITERS = 30
# lr kept moderate: the database computes in float64, the dense engine in
# float32 — at aggressive learning rates gradient descent amplifies that
# representation gap chaotically (the backends are each self-consistent)
spec = nn2sql.MLPSpec(n_rows=60, n_features=4, n_hidden=10, n_classes=3,
                      lr=0.1)


def iris_like(spec, seed=0):
    """Synthetic Iris-shaped data: 3 Gaussian blobs over 4 features."""
    rng = np.random.RandomState(seed)
    centers = rng.rand(spec.n_classes, spec.n_features)
    labels = rng.randint(0, spec.n_classes, spec.n_rows)
    x = centers[labels] + 0.08 * rng.randn(spec.n_rows, spec.n_features)
    y = np.eye(spec.n_classes, dtype=np.float32)[labels]
    return x.astype(np.float32), y, labels


def main():
    graph = nn2sql.build_graph(spec)
    weights = {k: np.asarray(v)
               for k, v in nn2sql.init_weights(spec).items()}
    x, y, labels = iris_like(spec)
    backend = "duckdb" if HAVE_DUCKDB else "sqlite"
    print(f"== in-database backend: {backend} ==")

    # -- 1. train: one recursive-CTE query, all iterations in-DB -------------
    res = train_in_db(graph, weights, x, y, N_ITERS, backend=backend)

    # the query that actually ran (array variant on sqlite, Listing 7 on
    # duckdb — DBTrainResult carries it either way)
    print(f"\ntraining query ({len(res.sql)} chars), head:")
    print("\n".join(res.sql.splitlines()[:6]), "\n  ...")
    traj = loss_trajectory_in_db(graph, res.history, x, y, backend=backend)
    print(f"\nin-DB loss trajectory ({res.strategy}): "
          f"{traj[0]:.4f} -> {traj[-1]:.4f} over {res.n_iters} iters")

    # -- 2. infer: forward pass + highestposition in-DB -----------------------
    pred = predict_in_db(graph, res.weights, x, backend=backend)
    acc_db = float(np.mean(pred == labels))
    print(f"in-DB accuracy (window-function argmax): {acc_db:.3f}")

    # -- 3. differential check vs the dense JAX engine ------------------------
    jw = {k: jnp.asarray(v) for k, v in weights.items()}
    final, _ = nn2sql.train(graph, jw, jnp.asarray(x), jnp.asarray(y),
                            N_ITERS, Engine("dense"))
    diff = max(np.abs(np.asarray(final[k]) - res.weights[k]).max()
               for k in final)
    print(f"max |w_db - w_dense| after {N_ITERS} iters: {diff:.2e}")
    probs_db = infer_in_db(graph, res.weights, x, backend=backend)
    probs_dense = nn2sql.infer(graph, Engine("dense"))(final, jnp.asarray(x))
    print(f"max |m(x)_db - m(x)_dense|: "
          f"{np.abs(probs_db - np.asarray(probs_dense)).max():.2e}")

    # -- 4. the rendered-SQL plan cache ---------------------------------------
    # training/inference SQL is rendered once per topology × dialect and
    # persisted (~/.cache/repro/plan_cache.db unless REPRO_PLAN_CACHE=off);
    # re-running this example serves every query text from the cache
    st = plan_cache.default_cache().stats
    print(f"\nplan cache: {st['hits']} hits / {st['misses']} misses this "
          f"run, {st['entries']} stored plans ({st['path'] or 'memory'})")


if __name__ == "__main__":
    main()
