"""The cross-representation differential test matrix.

ONE parametrized suite drives every zoo primitive, seeded random DAGs and
the MNIST-shaped MLP (forward, Algorithm-1 gradients, in-DB training step)
through all four representations of the same expression DAG:

* ``dense``      — Engine("dense"), the jnp array backend;
* ``relational`` — Engine("relational"), the on-device RelTensor backend;
* ``sql_rel``    — SQLEngine(), the cell-relational SQL-92 lowering
  executed by sqlite;
* ``sql_array``  — SQLEngine(dialect="array"), the array-typed Listing-10
  lowering over the UDF array extension (the paper's §5/§7 comparison).

Every pair of representations must agree ≤1e-4 on every output.  Shapes
and values come from one seeded generator, so the suite covers a family of
random topologies instead of a hand-picked example per backend.
"""
import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Engine, nn2sql, sgd_step_fn
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db import zoo
from repro.db.sql_engine import SQLEngine
from repro.db.train import train_in_db

TOL = 1e-4

REPRESENTATIONS = ("dense", "relational", "sql_rel", "sql_array")


@pytest.fixture(scope="module")
def sql_engines():
    """One sqlite connection per SQL representation, shared by the module
    (leaf-digest skip keeps re-ingestion cheap across cases)."""
    engines = {"sql_rel": SQLEngine(plan_cache_=False),
               "sql_array": SQLEngine(dialect="array", plan_cache_=False)}
    yield engines
    for eng in engines.values():
        eng.close()


def all_outputs(roots, env, sql_engines) -> dict[str, list[np.ndarray]]:
    jenv = {k: jnp.asarray(v, jnp.float32) for k, v in env.items()}
    outs = {"dense": [np.asarray(o)
                      for o in Engine("dense").eval_fn(roots)(jenv)],
            "relational": [np.asarray(o)
                           for o in Engine("relational").eval_fn(roots)(jenv)]}
    for name in ("sql_rel", "sql_array"):
        outs[name] = sql_engines[name].evaluate(roots, env)
    return outs


def assert_pairwise(outs: dict, context: str) -> None:
    names = list(outs)
    for a in range(len(names)):
        for b in range(a + 1, len(names)):
            for k, (x, y) in enumerate(zip(outs[names[a]], outs[names[b]])):
                np.testing.assert_allclose(
                    x, y, atol=TOL,
                    err_msg=f"{context}: root {k}, "
                            f"{names[a]} vs {names[b]}")


# ---------------------------------------------------------------------------
# seeded case generator: every zoo primitive with random shapes
# ---------------------------------------------------------------------------

def _prim_case(prim: str, rng: np.random.RandomState):
    r, c = int(rng.randint(2, 6)), int(rng.randint(2, 5))
    x = E.var("x", (r, c))
    env = {"x": rng.randn(r, c) * 0.7}
    if prim == "algebra":
        y = E.var("y", (r, c))
        z = E.var("z", (c, int(rng.randint(2, 5))))
        env["y"] = rng.randn(r, c)
        env["z"] = rng.randn(c, z.shape[1])
        roots = [E.matmul(E.hadamard(x, y), z), E.sub(x, y),
                 E.scale(1.5, E.transpose(x)), E.sigmoid(x), E.relu(x),
                 E.square(x), E.recip(E.add(E.square(x), E.const(1.0, (r, c)))),
                 E.add(E.const(2.0, (r, c)), x)]
    elif prim == "rowreduce":
        roots = [E.row_reduce(x, "sum", 1), E.row_reduce(x, "sum", 0),
                 E.row_reduce(x, "max", 1), E.row_reduce(x, "max", 0)]
    elif prim == "softmax":
        roots = [E.softmax(x)]
    elif prim == "argtopk":
        roots = [E.argtopk(x, int(rng.randint(1, c + 1)))]
    elif prim == "gather":
        s = int(rng.randint(2, 6))
        idx = E.var("idx", (s, 1))
        env["idx"] = rng.randint(0, r, size=(s, 1)).astype(np.float64)
        roots = [E.gather(x, idx)]
    elif prim == "scatter":
        n_rows = int(rng.randint(2, 7))
        idx = E.var("idx", (r, 1))
        env["idx"] = rng.randint(0, n_rows, size=(r, 1)).astype(np.float64)
        roots = [E.scatter(x, idx, n_rows)]
    elif prim == "rowshift":
        roots = [E.row_shift(x, 1), E.row_shift(x, -1),
                 E.row_shift(x, int(rng.randint(2, r + 1)))]
    elif prim == "recurrence":
        a, b = E.var("a", (r, c)), E.var("b", (r, c))
        env["a"] = rng.rand(r, c) * 0.5 + 0.2
        env["b"] = rng.randn(r, c)
        roots = [E.recurrence(a, b), E.recurrence(a, b, reverse=True)]
    else:  # pragma: no cover
        raise ValueError(prim)
    return roots, env


PRIMS = ("algebra", "rowreduce", "softmax", "argtopk", "gather", "scatter",
         "rowshift", "recurrence")


@pytest.mark.parametrize("seed", (0, 1))
@pytest.mark.parametrize("prim", PRIMS)
def test_primitive_forward_matrix(prim, seed, sql_engines):
    roots, env = _prim_case(prim, np.random.RandomState(100 * seed + 7))
    outs = all_outputs(roots, env, sql_engines)
    assert_pairwise(outs, f"{prim}[seed={seed}]")


# ---------------------------------------------------------------------------
# seeded random DAGs: composed topologies across the whole IR
# ---------------------------------------------------------------------------

def _random_dag(rng: np.random.RandomState, n_ops: int = 6):
    env: dict[str, np.ndarray] = {}

    def new_var(shape, value):
        name = f"v{len(env)}"
        env[name] = value
        return E.var(name, shape)

    r, c = int(rng.randint(2, 6)), int(rng.randint(2, 5))
    expr = new_var((r, c), rng.randn(r, c) * 0.6)
    for _ in range(n_ops):
        r, c = expr.shape
        op = rng.choice(["matmul", "had", "add", "sigmoid", "relu",
                         "transpose", "softmax", "reduce", "topk", "shift",
                         "gather", "scatter", "recur"])
        if op == "matmul":
            c2 = int(rng.randint(2, 5))
            expr = E.matmul(expr, new_var((c, c2), rng.randn(c, c2) * 0.6))
        elif op == "had":
            expr = E.hadamard(expr, new_var((r, c), rng.randn(r, c) * 0.6))
        elif op == "add":
            expr = E.add(expr, new_var((r, c), rng.randn(r, c) * 0.6))
        elif op == "sigmoid":
            expr = E.sigmoid(expr)
        elif op == "relu":
            expr = E.relu(expr)
        elif op == "transpose":
            expr = E.transpose(expr)
        elif op == "softmax":
            expr = E.softmax(expr)
        elif op == "reduce":
            expr = E.row_reduce(expr, str(rng.choice(["sum", "max"])),
                                int(rng.randint(0, 2)))
        elif op == "topk":
            expr = E.argtopk(expr, int(rng.randint(1, c + 1)))
        elif op == "shift":
            expr = E.row_shift(expr, int(rng.choice([-1, 1, 2])))
        elif op == "gather":
            s = int(rng.randint(2, 6))
            idx = new_var((s, 1),
                          rng.randint(0, r, size=(s, 1)).astype(np.float64))
            expr = E.gather(expr, idx)
        elif op == "scatter":
            n_rows = int(rng.randint(2, 7))
            idx = new_var((r, 1),
                          rng.randint(0, n_rows,
                                      size=(r, 1)).astype(np.float64))
            expr = E.scatter(expr, idx, n_rows)
        elif op == "recur":
            a = new_var((r, c), rng.rand(r, c) * 0.5 + 0.2)
            expr = E.recurrence(a, expr, reverse=bool(rng.randint(0, 2)))
    return [expr], env


@pytest.mark.parametrize("seed", range(4))
def test_random_dag_matrix(seed, sql_engines):
    roots, env = _random_dag(np.random.RandomState(1000 + seed))
    outs = all_outputs(roots, env, sql_engines)
    assert_pairwise(outs, f"random_dag[seed={seed}]")


# ---------------------------------------------------------------------------
# the MNIST-shaped MLP: forward, Algorithm-1 gradients, in-DB training
# ---------------------------------------------------------------------------

def mlp_case(rng):
    g = nn2sql.build_graph(nn2sql.MLPSpec(n_rows=8, n_features=5,
                                          n_hidden=4, n_classes=3, lr=0.1))
    w0 = {k: np.asarray(v) for k, v in nn2sql.init_weights(g.spec).items()}
    x = rng.rand(8, 5)
    y = np.eye(3)[rng.randint(0, 3, 8)]
    return g, {**w0, "img": x, "one_hot": y}


def test_mlp_forward_and_gradients_matrix(sql_engines):
    g, env = mlp_case(np.random.RandomState(5))
    grads = gradients(g.loss, [g.w_xh, g.w_ho])
    roots = [g.a_ho, g.loss, grads[g.w_xh], grads[g.w_ho]]
    outs = all_outputs(roots, env, sql_engines)
    assert_pairwise(outs, "mlp fwd+grad")


def test_mlp_training_step_matrix():
    """One SGD step through every representation's value_and_grad path —
    including Engine('sql', dialect='array'), the array-typed backend."""
    g, env = mlp_case(np.random.RandomState(6))
    w0 = {k: env[k] for k in ("w_xh", "w_ho")}
    data = {"img": env["img"], "one_hot": env["one_hot"]}
    stepped = {}
    for kind, opts in (("dense", {}), ("relational", {}),
                       ("sql", {}), ("sql_array", {"dialect": "array"})):
        eng = Engine("sql" if kind.startswith("sql") else kind, **opts)
        step = sgd_step_fn(g.loss, [g.w_xh, g.w_ho], g.spec.lr, eng)
        w1, loss = step({k: jnp.asarray(v, jnp.float32)
                         if not kind.startswith("sql") else v
                         for k, v in w0.items()}, data)
        stepped[kind] = ({k: np.asarray(v) for k, v in w1.items()},
                        float(np.mean(np.asarray(loss))))
        eng.close()
    ref_w, ref_l = stepped["dense"]
    for kind, (w1, l1) in stepped.items():
        assert abs(l1 - ref_l) < TOL, kind
        for k in ("w_xh", "w_ho"):
            np.testing.assert_allclose(w1[k], ref_w[k], atol=TOL,
                                       err_msg=f"{kind} {k}")


def test_in_db_training_array_representation_matches_dense():
    """The fully-in-database Listing-10 recursion under
    representation='array' tracks the dense SGD loop iterate-for-iterate."""
    g, env = mlp_case(np.random.RandomState(7))
    w0 = {k: env[k] for k in ("w_xh", "w_ho")}
    n = 3
    res = train_in_db(g, w0, env["img"], env["one_hot"], n,
                      representation="array")
    assert res.strategy == "recursive"
    step = sgd_step_fn(g.loss, [g.w_xh, g.w_ho], g.spec.lr, Engine("dense"))
    w = {k: jnp.asarray(v) for k, v in w0.items()}
    data = {"img": jnp.asarray(env["img"]),
            "one_hot": jnp.asarray(env["one_hot"])}
    for it in range(1, n + 1):
        w, _ = step(w, data)
        for k in ("w_xh", "w_ho"):
            np.testing.assert_allclose(res.history[it][k], np.asarray(w[k]),
                                       atol=TOL, err_msg=f"iter {it} {k}")


def test_stepped_array_representation_rejected():
    g, env = mlp_case(np.random.RandomState(8))
    w0 = {k: env[k] for k in ("w_xh", "w_ho")}
    with pytest.raises(ValueError, match="relational-only"):
        train_in_db(g, w0, env["img"], env["one_hot"], 1,
                    strategy="stepped", representation="array")
    with pytest.raises(ValueError, match="representation"):
        train_in_db(g, w0, env["img"], env["one_hot"], 1,
                    representation="sparse")


# ---------------------------------------------------------------------------
# zoo models: MoE (batched expert relation) and RWKV across representations
# ---------------------------------------------------------------------------

def test_moe_batched_relation_matrix(sql_engines):
    """The expert-indexed stacked weight relation ≡ the per-expert tables
    ≡ the jnp oracle, in both SQL representations and dense."""
    cfg = zoo.MoESQLConfig(n_tokens=6, d_model=4, n_experts=3, top_k=2,
                           d_ff=5)
    params = zoo.init_moe_params(cfg)
    x = np.random.RandomState(9).randn(cfg.n_tokens,
                                       cfg.d_model).astype(np.float32)
    want = zoo.moe_ffn_ref(cfg, params, x)
    for batched in (False, True):
        graph = (zoo.moe_ffn_graph_batched if batched
                 else zoo.moe_ffn_graph)(cfg)
        env = (zoo.moe_env_batched if batched else zoo.moe_env)(cfg, params,
                                                                x)
        outs = all_outputs([graph.out], env, sql_engines)
        assert_pairwise(outs, f"moe batched={batched}")
        np.testing.assert_allclose(outs["dense"][0], want, atol=TOL)


def test_moe_batched_gradients_reach_stacked_relation(sql_engines):
    """Algorithm 1 routes per-expert gradients through the adjoint Scatter
    back into ONE stacked weight relation — identical across dense and
    both SQL representations."""
    cfg = zoo.MoESQLConfig(n_tokens=5, d_model=3, n_experts=2, top_k=1,
                           d_ff=4)
    params = zoo.init_moe_params(cfg)
    x = np.random.RandomState(10).randn(cfg.n_tokens,
                                        cfg.d_model).astype(np.float32)
    graph = zoo.moe_ffn_graph_batched(cfg)
    env = zoo.moe_env_batched(cfg, params, x)
    wrt = list(graph.weight_vars)
    grads = gradients(graph.out, wrt)
    roots = [graph.out] + [grads[v] for v in wrt]
    jenv = {k: jnp.asarray(v) for k, v in env.items()}
    want = [np.asarray(o) for o in Engine("dense").eval_fn(roots)(jenv)]
    for name in ("sql_rel", "sql_array"):
        got = sql_engines[name].evaluate(roots, env)
        for g_, w_ in zip(got, want):
            np.testing.assert_allclose(g_, w_, atol=TOL, err_msg=name)
    # every stacked gradient is non-trivial (tokens routed to each expert)
    assert all(np.abs(w).sum() > 0 for w in want[1:])


def test_array_dialect_index_bounds_raise(sql_engines):
    """Out-of-range index relations are a contract violation every eager
    backend must *raise* on (dense raises ValueError): the array UDFs must
    not silently wrap negative indices (np.add.at would) or zero-fill."""
    import sqlite3

    x = E.var("x", (2, 2))
    idx = E.var("idx", (2, 1))
    env = {"x": np.ones((2, 2)), "idx": np.array([[-1.0], [0.0]])}
    eng = sql_engines["sql_array"]
    with pytest.raises(sqlite3.OperationalError):
        eng.evaluate([E.scatter(x, idx, 3)], env)
    with pytest.raises(sqlite3.OperationalError):
        eng.evaluate([E.gather(x, idx)], env)


def test_rwkv_time_mix_matrix(sql_engines):
    """The RWKV-6 time-mix scan — the recursive CTE with ONE array-typed
    state row in the array representation — across all four backends."""
    s, n = 5, 3
    rng = np.random.RandomState(11)
    graph = zoo.rwkv6_time_mix_graph(s, n)
    env = zoo.rwkv6_env(rng.randn(s, n) * 0.5, rng.randn(s, n) * 0.5,
                        rng.randn(s, n) * 0.5, rng.rand(s, n) * 0.5 + 0.3,
                        rng.randn(n) * 0.5, rng.randn(n, n) * 0.3)
    outs = all_outputs([graph.o, graph.state], env, sql_engines)
    assert_pairwise(outs, "rwkv time mix")
