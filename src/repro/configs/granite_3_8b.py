"""Granite-3.0-8B — dense GQA, tied embeddings [hf:ibm-granite/granite-3.0]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="granite-3-8b", family="dense", n_layers=40, d_model=4096,
    n_heads=32, n_kv_heads=8, d_head=128, d_ff=12800, vocab=49155,
    tie_embeddings=True, rope_theta=1e4)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="granite-3-8b-reduced", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=256,
        tie_embeddings=True)
