"""Pallas TPU kernel: one-hot matmul as a row gather (embedding lookup).

Paper §4.1 builds the one-hot relation and multiplies it against a weight
matrix; ``onehot(ids) · E`` touches exactly one row of E per id, so the
TPU-native execution is a scalar-prefetched DMA gather: the id vector is
prefetched (scalar memory), and the BlockSpec index_map steers each grid
step's DMA to the addressed embedding row — HBM traffic is |ids| · d instead
of the |ids| · V one-hot join.

Rows are fetched in blocks of ``blk_t`` ids × full d.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(ids_ref, table_ref, o_ref):
    # The index_map already steered this block's DMA to row ids[i].
    o_ref[...] = table_ref[...]


@functools.partial(jax.jit, static_argnames=("interpret",))
def onehot_embed(ids: jax.Array, table: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """out[t, :] = table[ids[t], :]  (onehot(ids) @ table)."""
    (t,) = ids.shape
    v, d = table.shape
    grid = (t,)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=grid,
        in_specs=[pl.BlockSpec((1, d), lambda i, ids_ref: (ids_ref[i], 0))],
        out_specs=pl.BlockSpec((1, d), lambda i, ids_ref: (i, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((t, d), table.dtype),
        interpret=interpret,
    )(ids.astype(jnp.int32), table)
