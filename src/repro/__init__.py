"""repro: 'The Duck's Brain' — in-database NN training/inference, as a
multi-pod JAX framework. See DESIGN.md."""
__version__ = "1.0.0"
