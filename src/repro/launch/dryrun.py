import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture × input shape)
on the production meshes, record memory/cost/roofline.

The two lines above MUST stay first: jax locks the device count on first
init, and the dry-run needs 512 placeholder host devices. (Smoke tests and
benchmarks must NOT import this module — they see 1 device.)

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun                 # all cells
  ... --arch dbrx_132b --shape train_4k --mesh both            # one cell
  ... --set attn_impl=chunked --set remat=dots                 # perf knobs
  ... --out results/dryrun.json
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp

from repro.configs.base import (ARCH_IDS, SHAPES, get_config,
                                shape_applicable)
from repro.launch.mesh import make_production_mesh
from repro.launch.sharding import (batch_shardings, cache_shardings,
                                   opt_shardings, param_shardings)
from repro.launch.specs import SDS, batch_specs, cache_specs, params_specs
from repro.nn.model import LM
from repro.optim.optimizers import adamw
from repro.roofline.analysis import analyze, model_flops
from repro.train.trainer import make_train_step


def apply_overrides(cfg, overrides: dict):
    """--set key=value knobs; moe.*/ssm.* update the nested specs."""
    moe_kv = {k[4:]: v for k, v in overrides.items()
              if k.startswith("moe.")}
    ssm_kv = {k[4:]: v for k, v in overrides.items()
              if k.startswith("ssm.")}
    top_kv = {k: v for k, v in overrides.items() if "." not in k}
    if moe_kv and cfg.moe is not None:
        cfg = dataclasses.replace(cfg,
                                  moe=dataclasses.replace(cfg.moe, **moe_kv))
    if ssm_kv and cfg.ssm is not None:
        cfg = dataclasses.replace(cfg,
                                  ssm=dataclasses.replace(cfg.ssm, **ssm_kv))
    if top_kv:
        cfg = dataclasses.replace(cfg, **top_kv)
    return cfg


def ssm_scan_corrections(cfg, shape, n_chips: int) -> tuple[float, float]:
    """Analytic per-chip (flops, bytes) for recurrence steps hidden inside
    lax.scan bodies (counted once by cost_analysis). RWKV-6 time-mix state
    ops: ~5·H·N² FLOPs and 2·H·N²·4 B state traffic per token per layer;
    Mamba-2 inter-chunk recurrence: ~3·H·N·P per chunk per layer. Training
    multiplies by 3 (fwd + bwd recompute + grad accumulation of state)."""
    if shape.kind == "decode":
        return 0.0, 0.0          # decode lowers one explicit step per layer
    tokens = shape.global_batch * shape.seq_len
    mult = 3.0 if shape.kind == "train" else 1.0
    fl = by = 0.0
    if cfg.family == "ssm":
        h = cfg.d_model // cfg.ssm.head_dim
        n = cfg.ssm.head_dim
        fl = 5.0 * h * n * n * tokens * cfg.n_layers * mult
        by = 2.0 * h * n * n * 4 * tokens * cfg.n_layers * mult
    elif cfg.family == "hybrid":
        h = cfg.n_heads_mamba()
        n, pdim = cfg.ssm.d_state, cfg.ssm.head_dim
        chunks = tokens / max(cfg.ssm.chunk, 1)
        fl = 3.0 * h * n * pdim * chunks * cfg.n_layers * mult
        by = 2.0 * h * n * pdim * 4 * chunks * cfg.n_layers * mult
    return fl / n_chips, by / n_chips


def build_lowered(cfg, shape, mesh, fsdp: bool = True):
    """Lower one entry point (train_step / prefill / decode_step) for
    ``cfg`` on ``mesh`` with full production shardings."""
    from repro.launch.mesh import data_axes
    from repro.nn.moe import set_moe_mesh
    set_moe_mesh(mesh, data_axes(mesh))     # impl='shard' engine support
    lm = LM(cfg)
    p_shapes = params_specs(cfg)
    psh = param_shardings(p_shapes, mesh, fsdp=fsdp)
    b_shapes = batch_specs(cfg, shape)
    bsh = batch_shardings(b_shapes, mesh, shape.global_batch)
    with mesh:
        if shape.kind == "train":
            opt = adamw(3e-4,
                        mixed_precision=cfg.param_dtype != "float32")
            o_shapes = jax.eval_shape(opt.init, p_shapes)
            osh = opt_shardings(o_shapes, psh, mesh)
            step = make_train_step(lm.loss_fn, opt)
            return jax.jit(step, in_shardings=(psh, osh, bsh),
                           out_shardings=(psh, osh, None),
                           donate_argnums=(0, 1)).lower(
                               p_shapes, o_shapes, b_shapes)
        if shape.kind == "prefill":
            return jax.jit(lm.prefill, in_shardings=(psh, bsh)).lower(
                p_shapes, b_shapes)
        # decode — serve_step: one new token against a seq_len cache
        c_shapes = cache_specs(cfg, shape)
        csh = cache_shardings(c_shapes, mesh, shape.global_batch,
                              shape.seq_len, cfg)
        return jax.jit(
            lm.decode_step,
            in_shardings=(psh, bsh, csh, None),
            out_shardings=(None, csh),
            donate_argnums=(2,)).lower(
                p_shapes, b_shapes, c_shapes, SDS((), jnp.int32))


def _costs(compiled):
    from repro.roofline.analysis import cost_analysis, parse_collectives
    ca = cost_analysis(compiled)  # jax 0.4.3x returns a list of dicts
    colls = parse_collectives(compiled.as_text())
    return (float(ca.get("flops", 0.0)),
            float(ca.get("bytes accessed", 0.0)), colls)


def measure_costs(cfg, shape, mesh, fsdp: bool):
    """Exact-rate cost measurement: XLA's cost analysis counts a lax.scan
    body ONCE (verified in tests/test_roofline.py), so the full scanned
    model under-reports. We compile two UNROLLED shallow variants with the
    real dims — depth L1 and L2 — whose per-layer cost delta is exact, and
    extrapolate affinely: total = c(L1) + (n_units − L1_units)·delta.
    Embedding / LM head / loss land in the base term of both variants."""
    pro = cfg.moe.first_k_dense if cfg.moe else 0
    step = cfg.shared_attn_every if cfg.family == "hybrid" else 1
    l1, l2 = pro + step, pro + 2 * step
    n_units = (cfg.n_layers - pro) // step
    out = []
    for lv in (l1, l2):
        cv = dataclasses.replace(cfg, n_layers=lv, scan_layers=False)
        compiled = build_lowered(cv, shape, mesh, fsdp).compile()
        out.append(_costs(compiled))
    (f1, b1, c1), (f2, b2, c2) = out
    k = n_units - 1
    flops = f1 + k * (f2 - f1)
    hbm = b1 + k * (b2 - b1)
    wire = c1.wire_bytes + k * (c2.wire_bytes - c1.wire_bytes)
    by_kind = {}
    kinds = set(c1.by_kind) | set(c2.by_kind)
    z = {"count": 0, "bytes": 0.0, "wire": 0.0}
    for kd in kinds:
        a, b = c1.by_kind.get(kd, z), c2.by_kind.get(kd, z)
        by_kind[kd] = {m: a[m] + k * (b[m] - a[m])
                       for m in ("count", "bytes", "wire")}
    return flops, hbm, wire, by_kind


def lower_cell(arch_id: str, shape_name: str, *, multi_pod: bool,
               overrides: dict | None = None, fsdp: bool = True):
    """Returns (record dict, compiled) for one (arch × shape × mesh) cell.

    The FULL model (scan-over-layers) is lowered and compiled on the mesh —
    that compile succeeding is the dry-run pass/fail criterion and supplies
    memory_analysis(). FLOP/byte/collective rates come from measure_costs
    (depth-extrapolated, exact); SSM time-scan steps are added analytically
    (ssm_scan_corrections)."""
    cfg = get_config(arch_id)
    if overrides:
        cfg = apply_overrides(cfg, overrides)
    shape = SHAPES[shape_name]
    if not shape_applicable(cfg, shape):
        return {"arch": arch_id, "shape": shape_name,
                "mesh": "2x16x16" if multi_pod else "16x16",
                "status": "skip (full attention)"}, None
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    # the full-model compile (pass/fail + memory_analysis) uses the
    # loop-bounded twins so liveness reflects sequential block reuse;
    # the cost variants below use the unrolled twins for exact FLOPs
    mem_cfg = dataclasses.replace(cfg, flash_impl="scan", ssd_impl="scan")
    compiled = build_lowered(mem_cfg, shape, mesh, fsdp).compile()
    flops, hbm, wire, by_kind = measure_costs(cfg, shape, mesh, fsdp)
    dt = time.time() - t0
    mem = compiled.memory_analysis()
    mf = model_flops(cfg, shape, n_chips)
    xf, xb = ssm_scan_corrections(cfg, shape, n_chips)
    from repro.roofline.analysis import HBM_BW, LINK_BW, PEAK_FLOPS, Roofline
    flops += xf
    hbm += xb
    terms = {"compute": flops / PEAK_FLOPS, "memory": hbm / HBM_BW,
             "collective": wire / LINK_BW}
    rl = Roofline(flops=flops, hbm_bytes=hbm, wire_bytes=wire,
                  compute_s=terms["compute"], memory_s=terms["memory"],
                  collective_s=terms["collective"],
                  bottleneck=max(terms, key=terms.get),
                  model_flops=mf, collectives=by_kind)
    record = {
        "arch": arch_id, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "status": "ok", "compile_s": round(dt, 1),
        "overrides": overrides or {},
        "bytes_per_device": {
            "arguments": mem.argument_size_in_bytes,
            "output": mem.output_size_in_bytes,
            "temp": mem.temp_size_in_bytes,
            "aliased": mem.alias_size_in_bytes,
            "peak_extra": mem.temp_size_in_bytes,
            "total_live": (mem.argument_size_in_bytes +
                           mem.output_size_in_bytes +
                           mem.temp_size_in_bytes -
                           mem.alias_size_in_bytes),
        },
        "flops_per_device": rl.flops,
        "hbm_bytes_per_device": rl.hbm_bytes,
        "wire_bytes_per_device": rl.wire_bytes,
        "collectives": rl.collectives,
        "terms_s": {"compute": rl.compute_s, "memory": rl.memory_s,
                    "collective": rl.collective_s},
        "bottleneck": rl.bottleneck,
        "model_flops_per_device": mf,
        "useful_flop_ratio": round(rl.useful_ratio, 4),
        "roofline_fraction": round(rl.roofline_fraction, 4),
    }
    return record, compiled


def run_cells(archs, shapes, meshes, overrides=None, out_path=None,
              fsdp=True, verbose=True):
    records = []
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                tag = f"{arch} × {shape} × {'2x16x16' if mp else '16x16'}"
                try:
                    rec, _ = lower_cell(arch, shape, multi_pod=mp,
                                        overrides=overrides, fsdp=fsdp)
                except Exception as e:  # a failure here is a system bug
                    rec = {"arch": arch, "shape": shape,
                           "mesh": "2x16x16" if mp else "16x16",
                           "status": f"FAIL: {type(e).__name__}: {e}"}
                    if verbose:
                        traceback.print_exc()
                records.append(rec)
                if verbose:
                    st = rec["status"]
                    extra = ""
                    if st == "ok":
                        t = rec["terms_s"]
                        extra = (f" [{rec['bottleneck']}] "
                                 f"c={t['compute']:.3g}s m={t['memory']:.3g}s"
                                 f" x={t['collective']:.3g}s "
                                 f"compile={rec['compile_s']}s")
                    print(f"{tag:58s} {st}{extra}", flush=True)
                if out_path:
                    with open(out_path, "w") as f:
                        json.dump(records, f, indent=1)
    return records


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", nargs="*", default=ARCH_IDS)
    ap.add_argument("--shape", nargs="*", default=list(SHAPES))
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--set", dest="sets", action="append", default=[],
                    help="cfg override key=value (e.g. attn_impl=chunked)")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args()
    overrides = {}
    for s in args.sets:
        k, v = s.split("=", 1)
        overrides[k] = (int(v) if v.isdigit() else
                        (float(v) if v.replace(".", "").isdigit() else v))
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]
    recs = run_cells(args.arch, args.shape, meshes, overrides or None,
                     args.out, fsdp=not args.no_fsdp)
    n_ok = sum(r["status"] == "ok" for r in recs)
    n_skip = sum(r["status"].startswith("skip") for r in recs)
    n_fail = len(recs) - n_ok - n_skip
    print(f"\n{n_ok} ok / {n_skip} skip / {n_fail} FAIL of {len(recs)}")
    return 1 if n_fail else 0


if __name__ == "__main__":
    raise SystemExit(main())
