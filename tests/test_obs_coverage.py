"""Static guarantee that database work cannot bypass the tracer.

Instrumentation lives *inside* the adapter methods (``execute`` /
``executemany`` / the ingestion cursors), so any call site is span-wrapped
by construction.  What could still rot is the adapter tier itself: a new
method talking to the raw connection without a span, or engine code
reaching past the adapter straight to ``conn``.  Two AST/grep checks pin
both:

1. every function in the ``db/adapters/`` package (and the ``db/adapter.py``
   shim) that executes on the raw connection (``conn.execute`` /
   ``conn.executemany`` / ``conn.cursor``) either opens a span (``span(``
   in its source) or carries an explicit ``# obs: exempt — <reason>``
   marker;
2. across ``src/repro``, raw-connection execution appears only in the
   adapter tier and ``db/plan_cache.py`` (the cache's private sqlite
   store — metadata, not traced workload queries).
"""
import ast
import pathlib
import re

SRC = pathlib.Path(__file__).parent.parent / "src" / "repro"

EXEC_CALL = re.compile(r"conn\.(execute|executemany|cursor)\s*\(")
EXEMPT = re.compile(r"#\s*obs:\s*exempt\s*(—|-)\s*\S")

#: the adapter tier — the back-compat shim plus every backend module
ADAPTER_FILES = sorted((SRC / "db" / "adapters").glob("*.py")) + [
    SRC / "db" / "adapter.py"]

#: the only modules allowed to touch a raw DB-API connection —
#: obs/report.py is the offline capture viewer: it opens a *finished*
#: trace database read-only, so there is no live engine whose spans,
#: counters or slow-query log it could bypass
ALLOWED_RAW = ({"db/adapter.py", "db/plan_cache.py", "obs/report.py"}
               | {f.relative_to(SRC).as_posix() for f in ADAPTER_FILES})


def _functions_with_source(path: pathlib.Path):
    text = path.read_text()
    tree = ast.parse(text)
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.name, ast.get_source_segment(text, node)


def test_adapter_raw_execution_is_span_wrapped_or_exempt():
    offenders = []
    for path in ADAPTER_FILES:
        rel = path.relative_to(SRC).as_posix()
        for name, src in _functions_with_source(path):
            if not EXEC_CALL.search(src):
                continue
            if "span(" in src or EXEMPT.search(src):
                continue
            offenders.append(f"{rel}:{name}")
    assert not offenders, (
        f"adapter functions executing on the raw connection without a span "
        f"or an '# obs: exempt — <reason>' marker: {offenders}")


def test_adapter_core_paths_are_instrumented_not_exempted():
    """The hot paths must be traced for real — an exemption marker on them
    would silently void the whole coverage guarantee.  The raw-driver
    seams (``_execute_raw`` / ``_executemany_raw``) run only under the
    wrappers' spans, so the wrappers themselves must span; overrides that
    delegate to the traced base method (duckdb's ``executemany``) don't
    touch the connection and are checked for the delegation instead."""
    funcs = [f for path in ADAPTER_FILES
             for f in _functions_with_source(path)]
    wrappers = [(n, s) for n, s in funcs
                if n in ("execute", "executemany")]
    assert wrappers, "the execute/executemany wrappers vanished"
    for name, src in wrappers:
        if EXEC_CALL.search(src) or "_raw(" in src:
            assert "span(" in src, f"{name} lost its span"
            assert not EXEMPT.search(src), f"{name} must not be exempt"
        else:
            assert f"Adapter.{name}(" in src or "span(" in src, (
                f"{name} override neither spans nor delegates "
                f"to the traced base")


def test_raw_connection_confined_to_adapter_and_plan_cache():
    offenders = []
    for path in SRC.rglob("*.py"):
        rel = path.relative_to(SRC).as_posix()
        if rel in ALLOWED_RAW:
            continue
        for i, line in enumerate(path.read_text().splitlines(), 1):
            if EXEC_CALL.search(line):
                offenders.append(f"{rel}:{i}: {line.strip()}")
    assert not offenders, (
        "raw-connection execution outside the db/adapters tier "
        "(bypasses spans, counters and the slow-query log):\n"
        + "\n".join(offenders))


def test_every_exemption_has_a_reason():
    for path in ADAPTER_FILES:
        for line in path.read_text().splitlines():
            if "obs: exempt" in line:
                assert EXEMPT.search(line), (
                    f"exemption without a reason: {line!r}")
