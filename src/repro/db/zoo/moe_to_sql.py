"""Mixture-of-Experts routing, dispatch and combine transpiled to SQL.

The router's output *is* the paper's relation ``{[i, j, v]}`` (token i →
expert j with gate v, see ``nn/moe.py``); this module expresses the whole
layer over the zoo IR so ``core.sqlgen`` renders it as one WITH query and
``SQLEngine`` runs it inside sqlite/duckdb:

* **routing** — ``Softmax`` over the router logits, ``ArgTopK`` for the
  top-k indicator (a window rank / correlated count in SQL), and the gate
  renormalisation ``(mask ∘ probs) / Σ_row`` via ``RowReduce`` + ``recip``.
  The DeepSeek "pre" (softmax → top-k → renormalise) and DBRX/Mixtral
  "post" (top-k → softmax over the selected logits) conventions produce
  the *same* renormalised masked probabilities — exp-ratio identity — so
  one graph serves both of ``nn/moe.py``'s router modes.
* **dispatch / combine** — two formulations:
  ``moe_dispatch_graph`` / ``moe_combine_graph`` mirror the Pallas kernels
  (``kernels/moe_dispatch.py``: gather each slot's token row and scale by
  its gate — the join's select clause; ``kernels/ref.moe_combine``: group
  by destination token and sum) over an explicit slot→token index
  relation; ``moe_ffn_graph`` is the fully-in-DB layer, contracting the
  gating matrix against per-expert SwiGLU outputs (the paper's §5 array
  representation of the same relation — no data-dependent structure, so
  the plan caches across batches); ``moe_ffn_graph_batched`` replaces the
  3·E per-expert weight tables with ONE expert-indexed relation per
  parameter kind (expert folded into the row index, blocks selected by
  Gather index relations) — same layer, batched storage, and it lowers
  identically in the relational and array representations.

Capacity dropping (a load-balancing concern, not layer semantics) is not
modelled: differential tests pick configs where nothing overflows, where
``nn/moe.py``'s two impls and this SQL agree exactly.
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ...core import expr as E


@dataclasses.dataclass(frozen=True)
class MoESQLConfig:
    n_tokens: int
    d_model: int
    n_experts: int
    top_k: int
    d_ff: int


@dataclasses.dataclass(frozen=True)
class MoEGraph:
    cfg: MoESQLConfig
    x: E.Var
    out: E.Expr          # (T, d) combined expert output
    gates: E.Expr        # (T, E) renormalised gate matrix
    probs: E.Expr        # (T, E) router softmax
    weight_vars: tuple   # every weight Var, for value_and_grad_fn


def _silu(z: E.Expr) -> E.Expr:
    """silu(z) = z ∘ sig(z) — composed, no new MapFn needed."""
    return E.hadamard(z, E.sigmoid(z))


def router_graph(x: E.Expr, w_router: E.Expr, top_k: int
                 ) -> tuple[E.Expr, E.Expr, E.Expr]:
    """logits → (probs, topk mask, renormalised gates), all (T, E)."""
    e = w_router.shape[1]
    logits = E.matmul(x, w_router, name="router_logits")
    probs = E.softmax(logits, name="router_probs")
    mask = E.argtopk(probs, top_k, name="topk_mask")
    g = E.hadamard(mask, probs, name="gates_raw")
    norm = E.row_reduce(g, "sum", axis=1, name="gate_norm")
    gates = E.hadamard(
        g, E.matmul(E.recip(norm), E.const(1.0, (1, e))), name="gates")
    return probs, mask, gates


def moe_ffn_graph(cfg: MoESQLConfig) -> MoEGraph:
    """The full layer: route, per-expert SwiGLU, gate-weighted combine.

    Per-expert outputs are selected with the unit-basis index relations
    ``sel_e`` (Listing-5 one-hot columns, supplied by :func:`moe_env`):
    column e of the gate matrix is ``gates · sel_e`` — a join against an
    index relation, not a host-side slice."""
    t, d, e, f = cfg.n_tokens, cfg.d_model, cfg.n_experts, cfg.d_ff
    x = E.var("x", (t, d))
    w_router = E.var("w_router", (d, e))
    probs, _mask, gates = router_graph(x, w_router, cfg.top_k)
    weight_vars = [w_router]
    out = None
    for k in range(e):
        wi = E.var(f"wi_{k}", (d, f))
        wg = E.var(f"wg_{k}", (d, f))
        wo = E.var(f"wo_{k}", (f, d))
        weight_vars += [wi, wg, wo]
        y = E.matmul(E.hadamard(E.matmul(x, wi), _silu(E.matmul(x, wg))),
                     wo)
        col = E.matmul(gates, E.var(f"sel_{k}", (e, 1)))       # (T, 1)
        w = E.hadamard(E.matmul(col, E.const(1.0, (1, d))), y)
        out = w if out is None else E.add(out, w)
    return MoEGraph(cfg=cfg, x=x, out=out, gates=gates, probs=probs,
                    weight_vars=tuple(weight_vars))


def moe_ffn_graph_batched(cfg: MoESQLConfig) -> MoEGraph:
    """The full layer over ONE expert-indexed weight relation per parameter
    kind (the ROADMAP's batched per-expert contraction): ``wi_all`` /
    ``wg_all`` are the (E·d, f) row-stack of every expert's matrix,
    ``wo_all`` the (E·f, d) stack — the ``expert`` column of the paper-style
    relation folded into the row index (expert = (i-1) // d).  Expert k's
    block is selected with the stored index relation ``rows_d_k`` /
    ``rows_f_k`` via ``Gather`` — a join, not a host-side slice — so
    Algorithm 1 routes the per-expert gradients back into the stacked
    relation through the adjoint ``Scatter``.  Works identically in the
    relational and the array representation."""
    t, d, e, f = cfg.n_tokens, cfg.d_model, cfg.n_experts, cfg.d_ff
    x = E.var("x", (t, d))
    w_router = E.var("w_router", (d, e))
    probs, _mask, gates = router_graph(x, w_router, cfg.top_k)
    wi_all = E.var("wi_all", (e * d, f))
    wg_all = E.var("wg_all", (e * d, f))
    wo_all = E.var("wo_all", (e * f, d))
    weight_vars = [w_router, wi_all, wg_all, wo_all]
    out = None
    for k in range(e):
        rows_d = E.var(f"rows_d_{k}", (d, 1))
        rows_f = E.var(f"rows_f_{k}", (f, 1))
        wi = E.gather(wi_all, rows_d, name=f"wi_b{k}")
        wg = E.gather(wg_all, rows_d, name=f"wg_b{k}")
        wo = E.gather(wo_all, rows_f, name=f"wo_b{k}")
        y = E.matmul(E.hadamard(E.matmul(x, wi), _silu(E.matmul(x, wg))),
                     wo)
        col = E.matmul(gates, E.var(f"sel_{k}", (e, 1)))       # (T, 1)
        w = E.hadamard(E.matmul(col, E.const(1.0, (1, d))), y)
        out = w if out is None else E.add(out, w)
    return MoEGraph(cfg=cfg, x=x, out=out, gates=gates, probs=probs,
                    weight_vars=tuple(weight_vars))


def moe_dispatch_graph(n_tokens: int, d_model: int, n_slots: int
                       ) -> tuple[E.Expr, E.Var, E.Var, E.Var]:
    """``kernels/moe_dispatch`` as IR: out[s, :] = gate[s] · x[tok[s], :].

    ``slot_token`` is the (S, 1) index relation of 0-based token rows (the
    expert-sorted ``sort_idx``), ``slot_gate`` the (S, 1) gate values.
    Returns (out, x, slot_token, slot_gate)."""
    x = E.var("x", (n_tokens, d_model))
    tok = E.var("slot_token", (n_slots, 1))
    gate = E.var("slot_gate", (n_slots, 1))
    out = E.hadamard(E.gather(x, tok),
                     E.matmul(gate, E.const(1.0, (1, d_model))),
                     name="dispatch")
    return out, x, tok, gate


def moe_combine_graph(n_slots: int, d_model: int, n_tokens: int
                      ) -> tuple[E.Expr, E.Var, E.Var]:
    """``kernels/ref.moe_combine`` as IR: group the slot relation by
    destination token, sum — one Scatter node.  Returns (out, y, tok)."""
    y = E.var("expert_out", (n_slots, d_model))
    tok = E.var("slot_token", (n_slots, 1))
    out = E.scatter(y, tok, n_tokens, name="combine")
    return out, y, tok


# ---------------------------------------------------------------------------
# parameters / env
# ---------------------------------------------------------------------------

def init_moe_params(cfg: MoESQLConfig, seed: int = 0) -> dict[str, np.ndarray]:
    """Small random weights in the ``nn/moe.py`` layout: stacked
    (E, d, f) expert tensors plus the (d, E) router."""
    rng = np.random.RandomState(seed)
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff

    def w(*shape):
        return (rng.randn(*shape) / np.sqrt(shape[0])).astype(np.float32)

    return {"router": w(d, e), "wi": w(e, d, f), "wg": w(e, d, f),
            "wo": w(e, f, d)}


def moe_env(cfg: MoESQLConfig, params: dict, x: np.ndarray) -> dict:
    """Leaf tables for :func:`moe_ffn_graph`: data, weights and the E
    unit-basis selector relations."""
    e = cfg.n_experts
    env = {"x": np.asarray(x), "w_router": np.asarray(params["router"])}
    eye = np.eye(e, dtype=np.float64)
    for k in range(e):
        env[f"wi_{k}"] = np.asarray(params["wi"][k])
        env[f"wg_{k}"] = np.asarray(params["wg"][k])
        env[f"wo_{k}"] = np.asarray(params["wo"][k])
        env[f"sel_{k}"] = eye[:, k:k + 1]
    return env


def moe_env_batched(cfg: MoESQLConfig, params: dict, x: np.ndarray) -> dict:
    """Leaf tables for :func:`moe_ffn_graph_batched`: the stacked
    expert-indexed weight relations, the E unit-basis selectors and the E
    block index relations (values = 0-based rows of expert k's block)."""
    d, e, f = cfg.d_model, cfg.n_experts, cfg.d_ff
    env = {"x": np.asarray(x), "w_router": np.asarray(params["router"]),
           "wi_all": np.asarray(params["wi"]).reshape(e * d, f),
           "wg_all": np.asarray(params["wg"]).reshape(e * d, f),
           "wo_all": np.asarray(params["wo"]).reshape(e * f, d)}
    eye = np.eye(e, dtype=np.float64)
    for k in range(e):
        env[f"sel_{k}"] = eye[:, k:k + 1]
        env[f"rows_d_{k}"] = np.arange(k * d, (k + 1) * d,
                                       dtype=np.float64).reshape(-1, 1)
        env[f"rows_f_{k}"] = np.arange(k * f, (k + 1) * f,
                                       dtype=np.float64).reshape(-1, 1)
    return env


def moe_ffn_ref(cfg: MoESQLConfig, params: dict, x) -> np.ndarray:
    """jnp oracle with the exact graph semantics (softmax → top-k mask →
    renormalise → gate-weighted SwiGLU sum, no capacity) — the timing
    baseline of ``benchmarks/bench_zoo_db.py``.  The differential tests
    additionally pin it against ``nn/moe.py`` + ``kernels/ref.py``."""
    import jax.numpy as jnp
    from ...core import dense

    x = jnp.asarray(x)
    logits = x @ jnp.asarray(params["router"])
    probs = jnp.exp(logits - logits.max(1, keepdims=True))
    probs = probs / probs.sum(1, keepdims=True)
    mask = dense.topk_mask(probs, cfg.top_k)
    g = mask * probs
    gates = g / g.sum(1, keepdims=True)
    h = jnp.einsum("td,edf->tef", x, jnp.asarray(params["wi"]))
    gt = jnp.einsum("td,edf->tef", x, jnp.asarray(params["wg"]))
    ys = jnp.einsum("tef,efd->ted", h * (gt * (1 / (1 + jnp.exp(-gt)))),
                    jnp.asarray(params["wo"]))
    return np.asarray(jnp.einsum("te,ted->td", gates, ys))


def run_moe_in_db(cfg: MoESQLConfig, params: dict, x, *,
                  backend: str = "sqlite", engine=None,
                  batched: bool = False) -> np.ndarray:
    """Evaluate the full MoE layer inside the database; returns (T, d).
    ``batched=True`` uses the expert-indexed stacked weight relations
    (:func:`moe_ffn_graph_batched`) instead of E per-expert tables."""
    from ...obs import tracer_of
    from ..sql_engine import SQLEngine

    graph = moe_ffn_graph_batched(cfg) if batched else moe_ffn_graph(cfg)
    env = (moe_env_batched if batched else moe_env)(cfg, params, x)
    eng = engine if engine is not None else SQLEngine(backend=backend)
    try:
        with tracer_of(eng, eng.adapter).span(
                "zoo.moe_layer", n_experts=cfg.n_experts, top_k=cfg.top_k,
                batched=batched):
            out, = eng.evaluate([graph.out], env)
            return out
    finally:
        if engine is None:
            eng.close()
