"""The zoo IR tier: every new primitive across all three engines.

For each of RowReduce/Softmax/ArgTopK/Gather/Scatter/RowShift/Recurrence:

* dense ≡ relational ≡ in-database (sqlite) within 1e-5,
* Algorithm-1 gradients ≡ jax.grad of the dense evaluation (jax.grad is
  the oracle only — the graphs themselves come from ``core.autodiff``),
* the gradient DAGs (ReduceDeriv indicators, reverse scans, shift
  adjoints) also *execute* in the database,
* tie-breaking and zero-fill conventions agree byte-for-byte between the
  dense semantics and the SQL lowering.
"""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Engine, dense
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db.sql_engine import SQLEngine

TOL = 1e-5
RNG = np.random.RandomState(0)

T, C = 5, 4
XV = RNG.randn(T, C).astype(np.float32)
IDXV = np.array([[3], [0], [1], [1], [4]], dtype=np.float32)
AV = (RNG.rand(T, C) * 0.5).astype(np.float32)
BV = RNG.randn(T, C).astype(np.float32)
ENV = {"x": XV, "idx": IDXV, "a": AV, "b": BV}


def leaves():
    return (E.var("x", (T, C)), E.var("idx", (T, 1)),
            E.var("a", (T, C)), E.var("b", (T, C)))


def build_roots():
    x, idx, a, b = leaves()
    return [
        E.row_reduce(x, "sum", 1), E.row_reduce(x, "max", 1),
        E.row_reduce(x, "sum", 0), E.row_reduce(x, "max", 0),
        E.softmax(x), E.argtopk(x, 2),
        E.gather(x, idx), E.scatter(E.gather(x, idx), idx, T),
        E.row_shift(x, 1), E.row_shift(x, -2), E.row_shift(x, T + 1),
        E.recurrence(a, b), E.recurrence(a, b, reverse=True),
    ]


class TestForwardParity:
    def test_dense_vs_sqlite(self):
        roots = build_roots()
        jenv = {k: jnp.asarray(v) for k, v in ENV.items()}
        ref = [np.asarray(o) for o in dense.evaluate(roots, jenv)]
        with SQLEngine(plan_cache_=False) as eng:
            got = eng.evaluate(roots, ENV)
        for node, r, s in zip(roots, ref, got):
            np.testing.assert_allclose(
                s, r, atol=TOL,
                err_msg=f"{type(node).__name__} sqlite != dense")

    def test_dense_vs_relational(self):
        roots = build_roots()
        jenv = {k: jnp.asarray(v) for k, v in ENV.items()}
        d = Engine("dense").eval_fn(roots)(jenv)
        r = Engine("relational").eval_fn(roots)(jenv)
        for dd, rr in zip(d, r):
            np.testing.assert_allclose(np.asarray(rr), np.asarray(dd),
                                       atol=TOL)

    def test_recurrence_matches_python_scan(self):
        out, = dense.evaluate([E.recurrence(*leaves()[2:])],
                              {"a": jnp.asarray(AV), "b": jnp.asarray(BV)})
        s = np.zeros(C, np.float64)
        for t in range(T):
            s = AV[t] * s + BV[t]
            np.testing.assert_allclose(np.asarray(out)[t], s, atol=TOL)

    def test_reverse_recurrence_is_forward_flipped(self):
        # rev(a, b) = flip(fwd(flip(a), flip(b)))
        a, b = leaves()[2:]
        rev, = dense.evaluate([E.recurrence(a, b, reverse=True)],
                              {"a": jnp.asarray(AV), "b": jnp.asarray(BV)})
        fwd_flipped, = dense.evaluate(
            [E.recurrence(a, b)],
            {"a": jnp.asarray(AV[::-1].copy()),
             "b": jnp.asarray(BV[::-1].copy())})
        np.testing.assert_allclose(np.asarray(rev),
                                   np.asarray(fwd_flipped)[::-1], atol=TOL)

    def test_rowshift_zero_fill(self):
        x = leaves()[0]
        d1, dm2, dover = dense.evaluate(
            [E.row_shift(x, 1), E.row_shift(x, -2), E.row_shift(x, T + 1)],
            {"x": jnp.asarray(XV)})
        assert np.all(np.asarray(d1)[0] == 0)
        np.testing.assert_array_equal(np.asarray(d1)[1:], XV[:-1])
        np.testing.assert_array_equal(np.asarray(dm2)[:-2], XV[2:])
        assert np.all(np.asarray(dm2)[-2:] == 0)
        assert np.all(np.asarray(dover) == 0)

    def test_topk_tie_break_smaller_j_wins(self):
        x = E.var("x", (1, 4))
        tied = np.array([[1.0, 3.0, 3.0, 0.0]], np.float32)
        d, = dense.evaluate([E.argtopk(x, 2)], {"x": jnp.asarray(tied)})
        np.testing.assert_array_equal(np.asarray(d), [[0, 1, 1, 0]])
        with SQLEngine(plan_cache_=False) as eng:
            s, = eng.evaluate([E.argtopk(x, 2)], {"x": tied})
        np.testing.assert_array_equal(s, [[0, 1, 1, 0]])

    def test_sql92_correlated_topk_matches_windowed(self):
        """The strict-SQL-92 correlated-count rendering (no windows) and
        the row_number rendering rank identically — executed on sqlite,
        which can run both."""
        from repro.db.dialect import Sql92Dialect, SqliteDialect
        import sqlite3

        conn = sqlite3.connect(":memory:")
        conn.execute("create table m (i integer, j integer, v real)")
        vals = RNG.randn(3, 5)
        conn.executemany("insert into m values (?, ?, ?)",
                         [(i + 1, j + 1, float(vals[i, j]))
                          for i in range(3) for j in range(5)])
        q92 = Sql92Dialect().topk_mask_select("m", 2) + " order by 1, 2"
        qwin = SqliteDialect().topk_mask_select("m", 2) + " order by 1, 2"
        assert q92 != qwin  # genuinely different renderings
        assert conn.execute(q92).fetchall() == conn.execute(qwin).fetchall()


class TestAutodiff:
    def check(self, build, wrts):
        loss = build()
        grads = gradients(loss, [w for w in wrts])
        groots = [grads[w] for w in wrts]
        jenv = {k: jnp.asarray(v) for k, v in ENV.items()}
        ours = [np.asarray(o) for o in dense.evaluate(groots, jenv)]

        def f(*vals):
            e = dict(jenv)
            for w, val in zip(wrts, vals):
                e[w.name] = val
            out, = dense.evaluate([loss], e)
            return jnp.sum(out)

        oracle = jax.grad(f, argnums=tuple(range(len(wrts))))(
            *[jenv[w.name] for w in wrts])
        for w, o, g in zip(wrts, ours, oracle):
            np.testing.assert_allclose(o, np.asarray(g), atol=1e-4,
                                       err_msg=f"grad wrt {w.name}")
        return groots

    def test_rowreduce_sum_axis1(self):
        x = leaves()[0]
        self.check(lambda: E.row_reduce(E.square(x), "sum", 1), [x])

    def test_rowreduce_sum_axis0(self):
        x = leaves()[0]
        self.check(lambda: E.row_reduce(x, "sum", 0), [x])

    def test_rowreduce_max(self):
        x = leaves()[0]
        self.check(lambda: E.row_reduce(x, "max", 1), [x])
        self.check(lambda: E.row_reduce(x, "max", 0), [x])

    def test_softmax(self):
        x = leaves()[0]
        self.check(lambda: E.softmax(x), [x])

    def test_topk_mask_blocks_gradient_but_gates_flow(self):
        x = leaves()[0]
        self.check(lambda: E.hadamard(E.argtopk(x, 2), E.softmax(x)), [x])

    def test_gather_scatter_adjoint_pair(self):
        x, idx = leaves()[:2]
        self.check(lambda: E.square(E.gather(x, idx)), [x])
        self.check(lambda: E.scatter(E.square(E.gather(x, idx)), idx, T),
                   [x])

    def test_rowshift(self):
        x = leaves()[0]
        self.check(lambda: E.row_shift(E.square(x), 2), [x])
        self.check(lambda: E.row_shift(x, -1), [x])

    def test_recurrence_both_directions(self):
        a, b = leaves()[2:]
        self.check(lambda: E.recurrence(a, b), [a, b])
        self.check(lambda: E.recurrence(a, b, reverse=True), [a, b])
        self.check(lambda: E.square(E.recurrence(a, E.softmax(b))), [a, b])

    def test_gradient_dags_execute_in_db(self):
        """ReduceDeriv, reverse scans and shift adjoints as actual SQL."""
        x, idx, a, b = leaves()
        cases = [
            (E.row_reduce(x, "max", 1), [x]),
            (E.hadamard(E.argtopk(x, 2), E.softmax(x)), [x]),
            (E.scatter(E.square(E.gather(x, idx)), idx, T), [x]),
            (E.square(E.recurrence(a, E.softmax(b))), [a, b]),
        ]
        jenv = {k: jnp.asarray(v) for k, v in ENV.items()}
        for loss, wrts in cases:
            g = gradients(loss, wrts)
            roots = [loss] + [g[w] for w in wrts]
            ref = [np.asarray(o) for o in dense.evaluate(roots, jenv)]
            with SQLEngine(plan_cache_=False) as eng:
                got = eng.evaluate(roots, ENV)
            for r, s in zip(ref, got):
                np.testing.assert_allclose(s, r, atol=TOL)


class TestConstructors:
    def test_shape_and_arg_validation(self):
        x, idx, a, b = leaves()
        with pytest.raises(ValueError):
            E.row_reduce(x, "median")
        with pytest.raises(ValueError):
            E.row_reduce(x, "sum", axis=2)
        with pytest.raises(ValueError):
            E.argtopk(x, 0)
        with pytest.raises(ValueError):
            E.argtopk(x, C + 1)
        with pytest.raises(ValueError):
            E.gather(x, E.var("bad", (3, 2)))
        with pytest.raises(ValueError):
            E.scatter(x, E.var("bad", (T + 1, 1)), T)
        with pytest.raises(ValueError):
            E.recurrence(a, E.var("bad", (T, C + 1)))

    def test_out_of_range_index_raises_eagerly(self):
        x, idx, _a, _b = leaves()
        bad = IDXV.copy()
        bad[0, 0] = T  # one past the last row
        with pytest.raises(ValueError, match="out of range"):
            dense.evaluate([E.gather(x, idx)],
                           {"x": jnp.asarray(XV), "idx": jnp.asarray(bad)})
        with pytest.raises(ValueError, match="out of range"):
            dense.evaluate([E.scatter(x, idx, T - 1)],  # max idx == T-1...
                           {"x": jnp.asarray(XV), "idx": jnp.asarray(IDXV)})

    def test_shapes(self):
        x, idx, a, b = leaves()
        assert E.row_reduce(x, "sum", 1).shape == (T, 1)
        assert E.row_reduce(x, "max", 0).shape == (1, C)
        assert E.gather(x, idx).shape == (T, C)
        assert E.scatter(x, idx, 9).shape == (9, C)
        assert E.softmax(x).shape == x.shape
        assert E.recurrence(a, b).shape == a.shape
