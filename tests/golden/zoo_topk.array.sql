with topk_c0(m) as (
  select mtopk((select m from zx), 2) as m
)
select 0 as r, m from topk_c0;
