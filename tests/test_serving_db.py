"""Multi-tenant batched serving: one plan, B requests.

Differential guarantees for the ``b``-column codec and the serving tier:

* a B-request batched plan matches B independent ``evaluate`` calls
  (≤1e-4; observed exact) for the MLP forward, forward+gradient, and a
  zoo gating layer, on sqlite relational AND array representations
  (duckdb in the CI extras job);
* B=1 and a smaller follow-up batch ride the SAME cached plan — the
  rendered text carries no literal B;
* unbatched (shared-weight) subgraph roots come back tagged ``b = -1``
  and broadcast across the batch;
* the ``SQLBatchServer`` queue resolves per-request futures to exactly
  the sequential results, including a ragged last micro-batch;
* the pool bugfixes hold: WAL mode on file-backed sqlite pools,
  cross-thread connection use, stale ``matrix_cache`` detection across
  pooled connections.
"""
import os

import numpy as np
import pytest

from repro.core import autodiff, nn2sql
from repro.core import expr as E
from repro.core import sqlgen
from repro.db import HAVE_DUCKDB
from repro.db.adapter import ConnectionPool, SQLiteAdapter
from repro.db.plan_cache import PlanCache
from repro.db.sql_engine import SQLEngine
from repro.serving.db_serve import SQLBatchServer

RNG = np.random.RandomState(11)
TOL = 1e-4

BACKENDS = ["sqlite"] + (["duckdb"] if HAVE_DUCKDB else [])


def mlp_graph(n_rows=4, n_hidden=5):
    spec = nn2sql.MLPSpec(n_rows=n_rows, n_features=6, n_hidden=n_hidden,
                          n_classes=3, lr=0.1)
    g = nn2sql.build_graph(spec)
    w = {k: np.asarray(v, dtype=np.float64)
         for k, v in nn2sql.init_weights(spec).items()}
    return g, w, spec


def batch_inputs(spec, nb):
    imgs = RNG.rand(nb, spec.n_rows, spec.n_features)
    labels = RNG.randint(0, spec.n_classes, (nb, spec.n_rows))
    one_hots = np.eye(spec.n_classes)[labels]
    return imgs, one_hots


def sequential(eng, roots, shared, batch_env, nb):
    outs = []
    for k in range(nb):
        env = dict(shared)
        env.update({n: s[k] for n, s in batch_env.items()})
        outs.append(eng.evaluate(roots, env))
    return [np.stack([o[r] for o in outs]) for r in range(len(roots))]


@pytest.mark.parametrize("backend", BACKENDS)
@pytest.mark.parametrize("dialect", [None, "array"])
class TestBatchedDifferential:
    def _engine(self, backend, dialect):
        return SQLEngine(backend, dialect=dialect, plan_cache_=False)

    def test_mlp_forward(self, backend, dialect):
        g, w, spec = mlp_graph()
        imgs, _ = batch_inputs(spec, 8)
        with self._engine(backend, dialect) as eng:
            batched = eng.evaluate_batched([g.a_ho], w, {"img": imgs})
            seq = sequential(eng, [g.a_ho], w, {"img": imgs}, 8)
        assert np.abs(batched[0] - seq[0]).max() <= TOL

    def test_mlp_forward_and_grad(self, backend, dialect):
        g, w, spec = mlp_graph()
        grads = autodiff.gradients(g.loss, [g.w_xh, g.w_ho])
        roots = [g.loss, grads[g.w_xh], grads[g.w_ho]]
        imgs, one_hots = batch_inputs(spec, 3)
        be = {"img": imgs, "one_hot": one_hots}
        with self._engine(backend, dialect) as eng:
            batched = eng.evaluate_batched(roots, w, be)
            seq = sequential(eng, roots, w, be, 3)
        for b, s in zip(batched, seq):
            assert np.abs(b - s).max() <= TOL

    def test_zoo_gating_layer(self, backend, dialect):
        """Softmax → ArgTopK → Hadamard → RowReduce: the MoE gate, whose
        batched spellings partition ranks and denominators per request."""
        x = E.var("x", (4, 6))
        wg = E.var("wg", (6, 5))
        gate = E.softmax(E.matmul(x, wg, name="logits"))
        mask = E.argtopk(gate, 2)
        top = E.hadamard(gate, mask)
        load = E.row_reduce(mask, kind="sum", axis=0)
        roots = [top, load]
        shared = {"wg": RNG.randn(6, 5)}
        xs = RNG.randn(5, 4, 6)
        with self._engine(backend, dialect) as eng:
            batched = eng.evaluate_batched(roots, shared, {"x": xs})
            seq = sequential(eng, roots, shared, {"x": xs}, 5)
        for b, s in zip(batched, seq):
            assert np.abs(b - s).max() <= TOL

    def test_batch_of_one(self, backend, dialect):
        g, w, spec = mlp_graph()
        imgs, _ = batch_inputs(spec, 1)
        with self._engine(backend, dialect) as eng:
            batched = eng.evaluate_batched([g.a_ho], w, {"img": imgs})
            plain = eng.evaluate([g.a_ho], {**w, "img": imgs[0]})
        assert batched[0].shape == (1,) + g.a_ho.shape
        assert np.abs(batched[0][0] - plain[0]).max() <= TOL


class TestOnePlanManySizes:
    def test_plan_cache_shared_across_batch_sizes(self):
        """The tentpole invariant: the rendered text carries no literal B,
        so B=8, B=1 and a ragged B=3 all hit ONE cache entry."""
        g, w, spec = mlp_graph()
        cache = PlanCache(path=None)
        with SQLEngine("sqlite", plan_cache_=cache) as eng:
            for nb in (8, 1, 3):
                imgs, _ = batch_inputs(spec, nb)
                out = eng.evaluate_batched([g.a_ho], w, {"img": imgs})
                assert out[0].shape == (nb,) + g.a_ho.shape
        assert cache.misses == 1 and cache.hits == 2

    def test_batched_key_differs_from_unbatched(self):
        g, w, spec = mlp_graph()
        cache = PlanCache(path=None)
        with SQLEngine("sqlite", plan_cache_=cache) as eng:
            imgs, _ = batch_inputs(spec, 2)
            eng.evaluate_batched([g.a_ho], w, {"img": imgs})
            eng.evaluate([g.a_ho], {**w, "img": imgs[0]})
        assert cache.misses == 2   # batch:<names> is part of the key

    def test_rendered_text_has_no_batch_size(self):
        g, _, _ = mlp_graph()
        sql = sqlgen.to_sql([g.a_ho], batch=("img",))
        for token in ("b = 0", "b = 7", " 8 "):
            assert token not in sql


class TestBroadcastAndErrors:
    def test_unbatched_root_broadcasts(self):
        x = E.var("x", (2, 3))
        w = E.var("w", (3, 3))
        y = E.matmul(x, w, name="y")
        s = E.sigmoid(w)            # no batched leaf upstream
        shared = {"w": RNG.randn(3, 3)}
        xs = RNG.randn(4, 2, 3)
        with SQLEngine("sqlite", plan_cache_=False) as eng:
            ys, ss = eng.evaluate_batched([y, s], shared, {"x": xs})
        expect = 1.0 / (1.0 + np.exp(-shared["w"]))
        assert ss.shape == (4, 3, 3)
        for k in range(4):
            assert np.abs(ss[k] - expect).max() <= TOL
            assert np.abs(ys[k] - xs[k] @ shared["w"]).max() <= TOL

    def test_batched_scan_raises(self):
        a = E.var("a", (4, 3))
        b = E.var("b", (4, 3))
        scan = E.recurrence(a, b)
        with pytest.raises(NotImplementedError):
            sqlgen.to_sql([scan], batch=("b",))

    def test_mismatched_batch_sizes_rejected(self):
        x = E.var("x", (2, 2))
        z = E.var("z", (2, 2))
        y = E.add(x, z)
        with SQLEngine("sqlite", plan_cache_=False) as eng:
            with pytest.raises(ValueError, match="batch size"):
                eng.evaluate_batched(
                    [y], {}, {"x": np.zeros((2, 2, 2)),
                              "z": np.zeros((3, 2, 2))})

    def test_unknown_batch_var_rejected(self):
        x = E.var("x", (2, 2))
        with SQLEngine("sqlite", plan_cache_=False) as eng:
            with pytest.raises(KeyError):
                eng.evaluate_batched([E.sigmoid(x)],
                                     {"x": np.zeros((2, 2))},
                                     {"nope": np.zeros((1, 2, 2))})


class TestBatchServer:
    def _graph(self):
        x = E.var("x", (2, 6))
        w1 = E.var("w1", (6, 5))
        w2 = E.var("w2", (5, 3))
        y = E.softmax(E.matmul(E.sigmoid(E.matmul(x, w1, name="h")),
                               w2, name="o"))
        return y, {"w1": RNG.randn(6, 5), "w2": RNG.randn(5, 3)}

    def test_futures_match_sequential(self):
        y, shared = self._graph()
        xs = [RNG.randn(2, 6) for _ in range(9)]
        with SQLBatchServer([y], ["x"], shared, pool_size=2,
                            plan_cache_=False) as srv:
            futs = [srv.submit({"x": xi}, tenant=f"t{k % 3}")
                    for k, xi in enumerate(xs)]
            got = [f.result(timeout=60) for f in futs]
        with SQLEngine("sqlite", plan_cache_=False) as eng:
            for xi, res in zip(xs, got):
                ref = eng.evaluate([y], {**shared, "x": xi})
                assert np.abs(res[0] - ref[0]).max() <= TOL

    def test_ragged_last_micro_batch(self):
        """max_batch=4, six requests on one worker: the group sequence is
        ragged whatever the window does — every future still resolves to
        its own request's exact result."""
        y, shared = self._graph()
        xs = [RNG.randn(2, 6) for _ in range(6)]
        with SQLBatchServer([y], ["x"], shared, pool_size=1, max_batch=4,
                            window_ms=20.0, plan_cache_=False) as srv:
            futs = [srv.submit({"x": xi}) for xi in xs]
            got = [f.result(timeout=60) for f in futs]
        with SQLEngine("sqlite", plan_cache_=False) as eng:
            for xi, res in zip(xs, got):
                ref = eng.evaluate([y], {**shared, "x": xi})
                assert np.abs(res[0] - ref[0]).max() <= TOL

    def test_bad_request_leaves_rejected(self):
        y, shared = self._graph()
        with SQLBatchServer([y], ["x"], shared, pool_size=1,
                            plan_cache_=False) as srv:
            with pytest.raises(KeyError):
                srv.submit({"wrong": np.zeros((2, 6))})

    def test_missing_shared_env_rejected(self):
        y, _ = self._graph()
        with pytest.raises(KeyError, match="shared_env"):
            SQLBatchServer([y], ["x"], {"w1": np.zeros((6, 5))})


class TestPoolBugfixes:
    def test_file_pool_wal_mode(self, tmp_path):
        db = str(tmp_path / "pool.db")
        pool = ConnectionPool("sqlite", db, size=3)
        try:
            assert len(pool) == 3
            for ad in pool:
                mode, = ad.execute("pragma journal_mode")[0]
                assert str(mode).lower() == "wal"
                assert ad._db_key == pool[0]._db_key
        finally:
            pool.close()

    def test_cross_thread_connection_use(self):
        """check_same_thread=False + the per-connection lock: another
        thread may run statements on this connection."""
        import threading
        ad = SQLiteAdapter(":memory:")
        ad.create_table("t", (("v", "integer"),))
        errs = []

        def work():
            try:
                for k in range(50):
                    ad.execute("insert into t values (?)", (k,))
            except Exception as exc:  # pragma: no cover - the bug
                errs.append(exc)

        ts = [threading.Thread(target=work) for _ in range(4)]
        [t.start() for t in ts]
        [t.join() for t in ts]
        assert not errs
        assert ad.execute("select count(*) from t")[0][0] == 200
        ad.close()

    def test_two_connection_stale_matrix_cache(self, tmp_path):
        """PR-7 regression: adapter A's retained diff base goes stale when
        sibling B rewrites the relation — pre-fix, A's next delta update
        patched only ITS changed cells on top of B's content."""
        from repro.db import relation_io
        db = str(tmp_path / "shared.db")
        a = SQLiteAdapter(db)
        b = SQLiteAdapter(db)
        m0 = np.arange(12, dtype=np.float64).reshape(3, 4)
        relation_io.write_matrix(a, "w", m0)      # A caches m0 as diff base
        a.commit()
        assert "w" in a.matrix_cache
        relation_io.write_matrix(b, "w", m0 + 100.0)   # sibling rewrite
        b.commit()
        m2 = m0.copy()
        m2[0, 0] = -5.0                     # one cell differs from A's base
        assert relation_io.update_matrix_delta(a, "w", m2) is None
        relation_io.write_matrix(a, "w", m2)    # caller fallback
        a.commit()
        got = relation_io.read_matrix(b, "w", (3, 4))
        assert np.array_equal(got, m2)
        a.close()
        b.close()

    def test_shared_digest_adoption_skips_rewrite(self, tmp_path):
        """Two pooled engines fanning out the SAME weights must not
        ping-pong rewrites: the second adopts the first one's write."""
        db = str(tmp_path / "adopt.db")
        x = E.var("x", (2, 3))
        w = E.var("w", (3, 2))
        y = E.matmul(x, w, name="y")
        env = {"x": RNG.randn(2, 3), "w": RNG.randn(3, 2)}
        e1 = SQLEngine(adapter=SQLiteAdapter(db), plan_cache_=False)
        e1.evaluate([y], env)
        e1.adapter.commit()
        e2 = SQLEngine(adapter=SQLiteAdapter(db), plan_cache_=False)
        info = e2._write_env([y], env)
        assert info["skipped"] == 2 and info["bytes_written"] == 0
        # and e1 stays fresh: nothing was mutated under it
        info1 = e1._write_env([y], env)
        assert info1["skipped"] == 2
        e1.close()
        e2.close()

    def test_memory_registry_keys_never_reused(self):
        """A fresh ``:memory:`` adapter must never inherit a dead
        sibling's registry identity: with ``id(self)``-derived keys,
        CPython address reuse let a new empty database "adopt" a shared
        digest and skip the write — then the query found no table."""
        seen = set()
        for _ in range(50):
            ad = SQLiteAdapter(":memory:")
            assert ad._db_key not in seen
            seen.add(ad._db_key)
            ad.close()
        x = E.var("x", (2, 3))
        w = E.var("w", (3, 2))
        y = E.matmul(x, w, name="y")
        env = {"x": RNG.randn(2, 3), "w": RNG.randn(3, 2)}
        for _ in range(3):               # fresh engine each round: must
            with SQLEngine(plan_cache_=False) as eng:   # really ingest
                out, = eng.evaluate([y], env)
            assert np.abs(out - env["x"] @ env["w"]).max() <= TOL


@pytest.mark.skipif(not HAVE_DUCKDB, reason="duckdb not installed")
class TestDuckDBPool:  # pragma: no cover - exercised in the CI extras job
    def test_cursor_pool_and_server(self):
        y = E.sigmoid(E.matmul(E.var("x", (2, 4)), E.var("w", (4, 3)),
                               name="y0"))
        shared = {"w": RNG.randn(4, 3)}
        xs = [RNG.randn(2, 4) for _ in range(5)]
        with SQLBatchServer([y], ["x"], shared, backend="duckdb",
                            pool_size=2, plan_cache_=False) as srv:
            got = [srv({"x": xi}) for xi in xs]
        with SQLEngine("duckdb", plan_cache_=False) as eng:
            for xi, res in zip(xs, got):
                ref = eng.evaluate([y], {**shared, "x": xi})
                assert np.abs(res[0] - ref[0]).max() <= TOL
