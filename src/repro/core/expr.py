"""Matrix-expression IR — the paper's CTE graph.

Every node corresponds to one CTE in the paper's SQL formulation
(Listing 7: ``a_xh``, ``a_ho``, ``l_ho``, ``d_ho``, ``l_xh``, ``d_xh``, ``d_w``):
a named, cached matrix expression. The engines (``core.dense``,
``core.relational``) evaluate the DAG with per-node memoisation — exactly the
"cached expression computed in the forward pass" of the paper's Section 2 —
and ``core.autodiff`` implements Algorithm 1 over these node types.

Node types mirror the paper's building blocks (Listing 4):

  MatMul     X · Y        join on inner index + group-by sum
  Hadamard   X ∘ Y        join on both indices
  Add / Sub  X ± Y        join on both indices
  Scale      c · X        map in the select-clause
  Map        f(X)         map in the select-clause (sigmoid, 1-x, x², …)
  Transpose  Xᵀ           index rename
  Var        leaf         a stored table (weights / data)
  Const      literal      generate_series-style constant matrix

The **DAG-zoo tier** (paper §8 outlook: "the relational building blocks
generalize beyond MLPs") extends the IR beyond dense 2-D algebra — each
node still denotes a dense matrix relation, so the inner-join/dense-cell
invariants of the base tier carry over:

  RowReduce  Σ/max over one axis     GROUP BY with sum()/max(), keepdims
  Softmax    row-wise softmax        exp/max/sum joins (numerically stable)
  ArgTopK    top-k indicator mask    window rank (or correlated count)
  Gather     row-index select        self-join on an index relation
  Scatter    row-index accumulate    join + GROUP BY, zero-filled frame
  RowShift   shift rows, zero fill   index arithmetic + frame left join
  Recurrence s_t = a_t∘s_{t-1}+b_t   recursive CTE (the Listing-7 machinery)

The **matrix-valued recurrence tier** (LRU/S5/Mamba-2 block scans)
generalises the elementwise scan to per-step *matrix* coefficients:

  MatRecurrence s_t = s_{t-1}·A_t + b_t   per-step (D, D) blocks stacked
                                          into one (T·D, D) relation; a
                                          recursive CTE whose tuple holds
                                          the state row (D columns, or
                                          one array-typed value)
  StepOuter     out[tD+k, j] = x[t,k]·y[t,j]   the stacked per-step outer
                                          product — Algorithm 1's ∂A_t

Index relations (the ``idx`` child of Gather/Scatter) are ordinary
``{[i, j, v]}`` matrices of shape (S, 1) whose *values* are 0-based row
numbers — at the SQL boundary the lowering adds the +1 of the 1-based
storage convention.
"""
from __future__ import annotations

import dataclasses
import itertools
import weakref
from typing import Callable, Optional

import jax.numpy as jnp

_counter = itertools.count()

#: nodes whose name came from ``_fresh`` rather than the caller.  SQL
#: rendering (``core.sqlgen``) re-names these deterministically by topo
#: position, so two structurally identical DAGs built at different counter
#: states (different sessions, different test orderings) render to the
#: *same* SQL text — the property the persistent plan cache relies on.
_AUTO_NAMED: "weakref.WeakSet[Expr]" = weakref.WeakSet()


def _fresh(prefix: str) -> str:
    return f"{prefix}_{next(_counter)}"


def mark_auto_named(node: "Expr") -> "Expr":
    """Record that ``node.name`` is generated, not semantic."""
    _AUTO_NAMED.add(node)
    return node


def is_auto_named(node: "Expr") -> bool:
    return node in _AUTO_NAMED


@dataclasses.dataclass(frozen=True, eq=False)
class Expr:
    """Base class. ``shape`` is the logical matrix shape (rows, cols)."""

    name: str
    shape: tuple[int, int]

    # -- operator sugar ----------------------------------------------------
    def __matmul__(self, other: "Expr") -> "Expr":
        return matmul(self, other)

    def __mul__(self, other) -> "Expr":
        if isinstance(other, Expr):
            return hadamard(self, other)
        return scale(float(other), self)

    __rmul__ = __mul__

    def __add__(self, other: "Expr") -> "Expr":
        return add(self, other)

    def __sub__(self, other: "Expr") -> "Expr":
        return sub(self, other)

    @property
    def T(self) -> "Expr":
        return transpose(self)

    def children(self) -> tuple["Expr", ...]:
        return ()


@dataclasses.dataclass(frozen=True, eq=False)
class Var(Expr):
    """Leaf: a stored table (weight matrix or input relation)."""


@dataclasses.dataclass(frozen=True, eq=False)
class Const(Expr):
    """A constant matrix (broadcast scalar), e.g. the ``1`` in ``1 - a``."""

    value: float = 0.0


@dataclasses.dataclass(frozen=True, eq=False)
class MatMul(Expr):
    x: Expr = None
    y: Expr = None

    def children(self):
        return (self.x, self.y)


@dataclasses.dataclass(frozen=True, eq=False)
class Hadamard(Expr):
    x: Expr = None
    y: Expr = None

    def children(self):
        return (self.x, self.y)


@dataclasses.dataclass(frozen=True, eq=False)
class Add(Expr):
    x: Expr = None
    y: Expr = None

    def children(self):
        return (self.x, self.y)


@dataclasses.dataclass(frozen=True, eq=False)
class Sub(Expr):
    x: Expr = None
    y: Expr = None

    def children(self):
        return (self.x, self.y)


@dataclasses.dataclass(frozen=True, eq=False)
class Scale(Expr):
    c: float = 1.0
    x: Expr = None

    def children(self):
        return (self.x,)


@dataclasses.dataclass(frozen=True, eq=False)
class Transpose(Expr):
    x: Expr = None

    def children(self):
        return (self.x,)


@dataclasses.dataclass(frozen=True, eq=False)
class MapFn:
    """An elementwise function with its derivative.

    ``df(x_val, out_val)`` returns f'(x) given the input value and the cached
    output value — e.g. sigmoid's derivative is expressed from the *output*
    (``out∘(1-out)``), matching the paper's Equations 7/9 which reuse the
    cached CTE ``a_ho``/``a_xh`` rather than re-evaluating sig'.
    ``sql(v)`` renders the select-clause expression for sqlgen.
    """

    name: str
    fn: Callable
    df: Callable
    sql: Callable[[str], str]

    @property
    def udf(self) -> str:
        """Name of the function in the UDF array extension
        (``repro.db.dialect.ARRAY_UDFS``) — the array-dialect and
        Listing-10 call renderings both spell ``f(X)`` as ``m<name>(x)``."""
        return f"m{self.name}"


RECIP = MapFn(
    name="recip",
    fn=lambda x: 1.0 / x,
    df=lambda x, out: -out * out,
    sql=lambda v: f"1.0/({v})",
)
SIGMOID = MapFn(
    name="sig",
    fn=lambda x: 1.0 / (1.0 + jnp.exp(-x)),
    df=lambda x, out: out * (1.0 - out),
    sql=lambda v: f"1/(1+exp(-{v}))",
)
SQUARE = MapFn(
    name="sqr",
    fn=lambda x: x * x,
    df=lambda x, out: 2.0 * x,
    sql=lambda v: f"{v}*{v}",
)
RELU = MapFn(
    name="relu",
    fn=lambda x: jnp.maximum(x, 0.0),
    df=lambda x, out: (x > 0.0).astype(x.dtype),
    sql=lambda v: f"greatest({v},0)",
)
ONE_MINUS = MapFn(
    name="one_minus",
    fn=lambda x: 1.0 - x,
    df=lambda x, out: jnp.full_like(x, -1.0),
    sql=lambda v: f"1-{v}",
)

MAP_FNS = {f.name: f for f in (SIGMOID, SQUARE, RELU, ONE_MINUS, RECIP)}


@dataclasses.dataclass(frozen=True, eq=False)
class Map(Expr):
    fn: MapFn = None
    x: Expr = None

    def children(self):
        return (self.x,)


# ---------------------------------------------------------------------------
# DAG-zoo tier (reductions, gather/scatter, shift, scan)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class RowReduce(Expr):
    """Reduce one axis with ``sum`` or ``max``, keepdims: axis=1 collapses
    columns (shape (r, 1)), axis=0 collapses rows (shape (1, c)).  Lowers to
    GROUP BY over the kept index."""

    x: Expr = None
    kind: str = "sum"        # "sum" | "max"
    axis: int = 1

    def children(self):
        return (self.x,)


@dataclasses.dataclass(frozen=True, eq=False)
class Softmax(Expr):
    """Row-wise (axis=1) numerically stable softmax.  Lowers to a join
    against the per-row max/denominator aggregate."""

    x: Expr = None

    def children(self):
        return (self.x,)


@dataclasses.dataclass(frozen=True, eq=False)
class ArgTopK(Expr):
    """The 0/1 indicator of each row's ``k`` largest entries (ties broken
    toward the smaller column index).  This is the relational rendering of
    an arg-result: a set of (i, j) pairs IS a sparse relation of ones —
    Listing 5's one-hot construction — kept dense here so downstream
    inner joins stay aligned.  Non-differentiable (selection): gradients
    flow through the values the mask is *applied to*, never the mask."""

    x: Expr = None
    k: int = 1

    def children(self):
        return (self.x,)


@dataclasses.dataclass(frozen=True, eq=False)
class Gather(Expr):
    """Row-index select: ``out[s, :] = x[idx[s], :]``.  ``idx`` is an index
    relation — an (S, 1) matrix whose values are 0-based row numbers of
    ``x``.  Lowers to a self-join of ``x`` against the index relation.
    Index values MUST lie in 0..rows(x)-1: eager dense/relational
    evaluation raises on violations, jit/SQL behaviour is
    backend-defined (clamp vs. zero-fill)."""

    x: Expr = None
    idx: Expr = None

    def children(self):
        return (self.x, self.idx)


@dataclasses.dataclass(frozen=True, eq=False)
class Scatter(Expr):
    """Row-index accumulate (Gather's adjoint): ``out[r, :] = Σ_{s:
    idx[s]=r} x[s, :]`` with ``shape[0]`` output rows.  Lowers to the join
    + GROUP BY sum, left-joined onto a zero frame so rows that receive no
    tuples stay present (dense-relation invariant)."""

    x: Expr = None
    idx: Expr = None

    def children(self):
        return (self.x, self.idx)


@dataclasses.dataclass(frozen=True, eq=False)
class RowShift(Expr):
    """Shift rows by ``offset`` (positive = down / toward larger i), zero
    fill: ``out[t, :] = x[t - offset, :]`` where defined, else 0.  The
    token-shift of RWKV and the boundary operator of Recurrence's autodiff
    rule."""

    x: Expr = None
    offset: int = 1

    def children(self):
        return (self.x,)


@dataclasses.dataclass(frozen=True, eq=False)
class Recurrence(Expr):
    """Elementwise affine scan down the rows (each column independent):

        forward:  s_t = a_t ∘ s_{t-1} + b_t,   s_0 = 0,   t = 1..T
        reverse:  s_t = a_t ∘ s_{t+1} + b_t,   s_{T+1} = 0,   t = T..1

    A non-zero initial state folds into ``b``: b₁' = a₁ ∘ s₀ + b₁.  Lowers
    to a recursive CTE — the Listing-7 recursion machinery, one tuple per
    (t, j) walking its own column chain (queue semantics compatible)."""

    a: Expr = None
    b: Expr = None
    reverse: bool = False

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True, eq=False)
class MatRecurrence(Expr):
    """Matrix-valued affine scan down the rows (LRU/S5/Mamba-2 blocks):

        forward:  s_t = s_{t-1} · A_t + b_t,   s_0 = 0,   t = 1..T
        reverse:  s_t = s_{t+1} · A_t + b_t,   s_{T+1} = 0,   t = T..1

    with the state a ROW vector s_t ∈ R^{1×D} and ``a`` the (T·D, D)
    stack of per-step square blocks: A_t = a[(t-1)·D : t·D, :].
    ``transposed`` uses A_tᵀ in the step — the Algorithm-1 adjoint scan
    runs with transposed coefficients, no block-transpose node needed.
    A non-zero initial state folds into ``b``: b₁' = s₀·A₁ + b₁.

    Diagonal blocks (the LRU/S5 fast path) ARE the elementwise
    :class:`Recurrence`; this node carries the dense-block case.  Both
    representations lower to ONE genuine recursive CTE whose tuple
    carries the whole state row: D columns with a scalar-subquery matvec
    (relational — cell-granularity recursion cannot mix the D previous
    cells under the single-reference/no-aggregate recursion rules), or
    one array-typed value stepped by the ``mrecurstep`` UDF (array)."""

    a: Expr = None           # (T·D, D) stacked blocks
    b: Expr = None           # (T, D)
    reverse: bool = False
    transposed: bool = False

    def children(self):
        return (self.a, self.b)


@dataclasses.dataclass(frozen=True, eq=False)
class StepOuter(Expr):
    """The stacked per-step outer product: ``out[(t-1)·K + k, j] =
    x[t, k] · y[t, j]`` for x (T, K), y (T, J) — shape (T·K, J).  This is
    the shape of ∂loss/∂A for :class:`MatRecurrence` (one outer product
    of cached state and adjoint per step, stacked like the A relation).
    Lowers to a single equi-join on t with index arithmetic on i."""

    x: Expr = None
    y: Expr = None

    def children(self):
        return (self.x, self.y)


# ---------------------------------------------------------------------------
# constructors with shape checking
# ---------------------------------------------------------------------------

def var(name: str, shape: tuple[int, int]) -> Var:
    return Var(name=name, shape=tuple(shape))


def _named(node: Expr, name: Optional[str]) -> Expr:
    """Register ``node`` as auto-named when the caller gave no name."""
    return node if name else mark_auto_named(node)


def const(value: float, shape: tuple[int, int]) -> Const:
    return mark_auto_named(
        Const(name=_fresh("const"), shape=tuple(shape), value=float(value)))


def matmul(x: Expr, y: Expr, name: Optional[str] = None) -> MatMul:
    if x.shape[1] != y.shape[0]:
        raise ValueError(f"matmul inner dims: {x.shape} @ {y.shape}")
    return _named(MatMul(name=name or _fresh("mm"),
                         shape=(x.shape[0], y.shape[1]), x=x, y=y), name)


def _elementwise(cls, x: Expr, y: Expr, prefix: str, name=None):
    if x.shape != y.shape:
        raise ValueError(f"{prefix} shapes: {x.shape} vs {y.shape}")
    return _named(cls(name=name or _fresh(prefix), shape=x.shape, x=x, y=y),
                  name)


def hadamard(x: Expr, y: Expr, name=None) -> Hadamard:
    return _elementwise(Hadamard, x, y, "had", name)


def add(x: Expr, y: Expr, name=None) -> Add:
    return _elementwise(Add, x, y, "add", name)


def sub(x: Expr, y: Expr, name=None) -> Sub:
    return _elementwise(Sub, x, y, "sub", name)


def scale(c: float, x: Expr, name=None) -> Scale:
    return _named(Scale(name=name or _fresh("scale"), shape=x.shape,
                        c=float(c), x=x), name)


def transpose(x: Expr, name=None) -> Transpose:
    return _named(Transpose(name=name or _fresh("t"),
                            shape=(x.shape[1], x.shape[0]), x=x), name)


def mapfn(fn: MapFn, x: Expr, name=None) -> Map:
    return _named(Map(name=name or _fresh(fn.name), shape=x.shape,
                      fn=fn, x=x), name)


def sigmoid(x: Expr, name=None) -> Map:
    return mapfn(SIGMOID, x, name)


def square(x: Expr, name=None) -> Map:
    return mapfn(SQUARE, x, name)


def relu(x: Expr, name=None) -> Map:
    return mapfn(RELU, x, name)


def recip(x: Expr, name=None) -> Map:
    return mapfn(RECIP, x, name)


def row_reduce(x: Expr, kind: str = "sum", axis: int = 1, name=None
               ) -> RowReduce:
    if kind not in ("sum", "max"):
        raise ValueError(f"row_reduce kind {kind!r}; have 'sum'/'max'")
    if axis not in (0, 1):
        raise ValueError(f"row_reduce axis {axis!r}; have 0/1")
    shape = (x.shape[0], 1) if axis == 1 else (1, x.shape[1])
    return _named(RowReduce(name=name or _fresh(f"r{kind}"), shape=shape,
                            x=x, kind=kind, axis=axis), name)


def softmax(x: Expr, name=None) -> Softmax:
    return _named(Softmax(name=name or _fresh("smax"), shape=x.shape, x=x),
                  name)


def argtopk(x: Expr, k: int, name=None) -> ArgTopK:
    if not 1 <= k <= x.shape[1]:
        raise ValueError(f"argtopk k={k} outside 1..{x.shape[1]}")
    return _named(ArgTopK(name=name or _fresh("topk"), shape=x.shape,
                          x=x, k=int(k)), name)


def gather(x: Expr, idx: Expr, name=None) -> Gather:
    if idx.shape[1] != 1:
        raise ValueError(f"gather index relation must be (S, 1), "
                         f"got {idx.shape}")
    return _named(Gather(name=name or _fresh("gath"),
                         shape=(idx.shape[0], x.shape[1]), x=x, idx=idx),
                  name)


def scatter(x: Expr, idx: Expr, n_rows: int, name=None) -> Scatter:
    if idx.shape != (x.shape[0], 1):
        raise ValueError(f"scatter index relation must be ({x.shape[0]}, 1),"
                         f" got {idx.shape}")
    return _named(Scatter(name=name or _fresh("scat"),
                          shape=(int(n_rows), x.shape[1]), x=x, idx=idx),
                  name)


def row_shift(x: Expr, offset: int = 1, name=None) -> RowShift:
    return _named(RowShift(name=name or _fresh("shift"), shape=x.shape,
                           x=x, offset=int(offset)), name)


def recurrence(a: Expr, b: Expr, reverse: bool = False, name=None
               ) -> Recurrence:
    if a.shape != b.shape:
        raise ValueError(f"recurrence shapes: {a.shape} vs {b.shape}")
    return _named(Recurrence(name=name or _fresh("rec"), shape=a.shape,
                             a=a, b=b, reverse=bool(reverse)), name)


def mat_recurrence(a: Expr, b: Expr, reverse: bool = False,
                   transposed: bool = False, name=None) -> MatRecurrence:
    t, d = b.shape
    if a.shape != (t * d, d):
        raise ValueError(
            f"mat_recurrence coefficient stack must be (T·D, D) = "
            f"({t * d}, {d}) for b {b.shape}, got {a.shape}")
    return _named(MatRecurrence(name=name or _fresh("mrec"), shape=b.shape,
                                a=a, b=b, reverse=bool(reverse),
                                transposed=bool(transposed)), name)


def step_outer(x: Expr, y: Expr, name=None) -> StepOuter:
    if x.shape[0] != y.shape[0]:
        raise ValueError(f"step_outer step counts: {x.shape} vs {y.shape}")
    return _named(StepOuter(name=name or _fresh("souter"),
                            shape=(x.shape[0] * x.shape[1], y.shape[1]),
                            x=x, y=y), name)


# ---------------------------------------------------------------------------
# graph utilities
# ---------------------------------------------------------------------------

def topo_order(*roots: Expr) -> list[Expr]:
    """Deterministic post-order (children before parents), deduplicated."""
    seen: dict[int, Expr] = {}
    order: list[Expr] = []

    def visit(node: Expr):
        if id(node) in seen:
            return
        seen[id(node)] = node
        for c in node.children():
            visit(c)
        order.append(node)

    for r in roots:
        visit(r)
    return order


def free_vars(*roots: Expr) -> list[Var]:
    return [n for n in topo_order(*roots) if isinstance(n, Var)]
