select sig(z_ho) as a_ho, * from (
select (a_xh ** w_ho) as z_ho, * from (
select sig(z_xh) as a_xh, * from (
select (img ** w_xh) as z_xh, * from (
select * from data, weights) q_z_xh) q_a_xh) q_z_ho) q_a_ho;
