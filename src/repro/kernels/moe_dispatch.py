"""Pallas TPU kernel: MoE dispatch — the token→expert relation's join side.

The router emits the relation ``assign(token_i, expert_e, gate_v)`` — the
paper's ``{[i, j, v]}`` matrix at datacenter scale (DESIGN.md §4). Dispatch
gathers each assignment's token row (join on ``i``) and applies the gate
value (select clause), producing the expert-sorted activation buffer that the
per-expert GEMMs consume. The combine side (group-by token, sum) reuses the
``relational_matmul`` aggregation.

Scalar-prefetched gather, one assignment row per grid step.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(idx_ref, x_ref, gates_ref, o_ref):
    o_ref[...] = x_ref[...] * gates_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def moe_dispatch(x: jax.Array, sort_idx: jax.Array, gates: jax.Array, *,
                 interpret: bool = True) -> jax.Array:
    """out[s, :] = gates[s] · x[sort_idx[s], :] for expert-sorted slots s."""
    (slots,) = sort_idx.shape
    _, d = x.shape
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=1,
        grid=(slots,),
        in_specs=[
            pl.BlockSpec((1, d), lambda s, idx_ref: (idx_ref[s], 0)),
            pl.BlockSpec((1, 1), lambda s, idx_ref: (s, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda s, idx_ref: (s, 0)),
    )
    return pl.pallas_call(
        _kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((slots, d), x.dtype),
        interpret=interpret,
    )(sort_idx.astype(jnp.int32), x, gates.reshape(-1, 1))
