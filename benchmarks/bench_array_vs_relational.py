"""Relational vs array representation: the paper's §7 comparison, in-repo.

The same expression DAGs — the MNIST-shaped MLP (forward and Algorithm-1
forward+gradients), the fully-in-DB MoE layer (batched expert-indexed
weight relation) and the RWKV-6 time-mix scan — executed by ONE engine
(sqlite by default) under both matrix representations:

* **relational** — ``SQLEngine()``: one ``{[i, j, v]}`` tuple per cell,
  matmul as join + GROUP BY (Listing 4/7);
* **array** — ``SQLEngine(dialect="array")``: one row per matrix, UDF
  array-extension calls per node, recursive-CTE scans over one
  array-typed state row (Listing 10 / §5).

For each workload we report median wall time per representation, the
speedup, the engine-side storage footprint of the leaf relations
(``page_count × page_size`` — the paper's memory axis) and the max error
against ``Engine("dense")``.  The paper's finding — the array data type
beats the cell relation on matmul-bound stages — is recorded as explicit
checks in the emitted JSON.

Run:  PYTHONPATH=src python benchmarks/bench_array_vs_relational.py
CI smoke:  … bench_array_vs_relational.py --rows 8 --hidden 16 --seq 6
Emits ``BENCH_array_vs_rel.json``.
"""
from __future__ import annotations

import argparse
import json

import numpy as np

import jax.numpy as jnp

try:
    from common import timeit            # script mode (CI invocation)
except ImportError:  # pragma: no cover - package mode
    from .common import timeit
from repro import obs
from repro.obs import regress
from repro.core import Engine, nn2sql
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db import HAVE_DUCKDB, zoo
from repro.db.sql_engine import SQLEngine

TOL = 1e-4


def db_bytes(eng: SQLEngine) -> int:
    """Engine-side footprint of everything materialised so far."""
    try:
        (pages,), = eng.adapter.execute("pragma page_count")
        (size,), = eng.adapter.execute("pragma page_size")
        return int(pages) * int(size)
    except Exception:  # pragma: no cover - duckdb has no sqlite pragmas
        return 0


def run_both(name: str, roots, env, backend: str, iters: int,
             dense_ref=None) -> dict:
    """Time one DAG under both representations on fresh engines."""
    out = {"workload": name}
    if dense_ref is None:
        jenv = {k: jnp.asarray(v, jnp.float32) for k, v in env.items()}
        dense_ref = [np.asarray(o)
                     for o in Engine("dense").eval_fn(roots)(jenv)]
    for rep, opts in (("relational", {}), ("array", {"dialect": "array"})):
        eng = SQLEngine(backend=backend, plan_cache_=False, **opts)
        fn = eng.eval_fn(roots)
        got = fn(env)                                  # warm + differential
        err = max(float(np.abs(g - r).max())
                  for g, r in zip(got, dense_ref))
        out[f"{rep}_s"] = timeit(lambda: fn(env), iters=iters)
        out[f"{rep}_db_bytes"] = db_bytes(eng)
        out[f"{rep}_max_err"] = err
        eng.close()
        # the same workload with the CTE-fusion + spool renderers off —
        # the before/after pair (the default engine fuses)
        eng_uf = SQLEngine(backend=backend, plan_cache_=False,
                           fuse=False, spool=False, **opts)
        fn_uf = eng_uf.eval_fn(roots)
        fn_uf(env)
        out[f"{rep}_unfused_s"] = timeit(lambda: fn_uf(env), iters=iters)
        out[f"{rep}_fused_speedup"] = out[f"{rep}_unfused_s"] / out[f"{rep}_s"]
        eng_uf.close()
    out["speedup_array"] = out["relational_s"] / out["array_s"]
    out["within_tol"] = bool(max(out["relational_max_err"],
                                 out["array_max_err"]) < TOL)
    return out


def bench_mlp(args, backend: str) -> list[dict]:
    """The paper's headline workload: MNIST-shaped MLP, forward (Listing
    6/8 vs 11) and forward+gradient (the Listing 7 vs 10 step body)."""
    spec = nn2sql.MLPSpec(n_rows=args.rows, n_features=args.features,
                          n_hidden=args.hidden, n_classes=args.classes,
                          lr=0.05)
    g = nn2sql.build_graph(spec)
    rng = np.random.RandomState(0)
    env = {k: np.asarray(v) for k, v in nn2sql.init_weights(spec).items()}
    env["img"] = rng.rand(spec.n_rows, spec.n_features)
    env["one_hot"] = np.eye(spec.n_classes)[
        rng.randint(0, spec.n_classes, spec.n_rows)].astype(np.float64)
    grads = gradients(g.loss, [g.w_xh, g.w_ho])
    return [
        run_both("mlp_forward", [g.a_ho], env, backend, args.timing_iters),
        run_both("mlp_forward_grad",
                 [g.loss, grads[g.w_xh], grads[g.w_ho]], env, backend,
                 args.timing_iters),
    ]


def bench_moe(args, backend: str) -> dict:
    cfg = zoo.MoESQLConfig(n_tokens=args.tokens, d_model=args.d_model,
                           n_experts=args.experts, top_k=args.top_k,
                           d_ff=args.d_ff)
    params = zoo.init_moe_params(cfg)
    x = np.random.RandomState(1).randn(cfg.n_tokens,
                                       cfg.d_model).astype(np.float32)
    graph = zoo.moe_ffn_graph_batched(cfg)
    env = zoo.moe_env_batched(cfg, params, x)
    res = run_both("moe_layer_batched", [graph.out], env, backend,
                   args.timing_iters)
    res["config"] = {"tokens": cfg.n_tokens, "d_model": cfg.d_model,
                     "experts": cfg.n_experts, "top_k": cfg.top_k,
                     "d_ff": cfg.d_ff}
    return res


def bench_rwkv(args, backend: str) -> dict:
    s, n = args.seq, args.heads_n
    rng = np.random.RandomState(2)
    graph = zoo.rwkv6_time_mix_graph(s, n)
    env = zoo.rwkv6_env(rng.randn(s, n) * 0.5, rng.randn(s, n) * 0.5,
                        rng.randn(s, n) * 0.5, rng.rand(s, n) * 0.5 + 0.3,
                        rng.randn(n) * 0.5, rng.randn(n, n) * 0.3)
    res = run_both("rwkv_time_mix", [graph.o, graph.state], env, backend,
                   args.timing_iters)
    res["config"] = {"seq": s, "n": n}
    return res


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--rows", type=int, default=32)
    ap.add_argument("--features", type=int, default=784)
    ap.add_argument("--hidden", type=int, default=64)
    ap.add_argument("--classes", type=int, default=10)
    ap.add_argument("--tokens", type=int, default=16)
    ap.add_argument("--d-model", type=int, default=8)
    ap.add_argument("--experts", type=int, default=4)
    ap.add_argument("--top-k", type=int, default=2)
    ap.add_argument("--d-ff", type=int, default=16)
    ap.add_argument("--seq", type=int, default=12)
    ap.add_argument("--heads-n", type=int, default=4)
    ap.add_argument("--timing-iters", type=int, default=3)
    ap.add_argument("--backend", default="sqlite",
                    choices=["sqlite", "duckdb", "auto"])
    ap.add_argument("--out", default="BENCH_array_vs_rel.json")
    args = ap.parse_args()
    backend = ("duckdb" if HAVE_DUCKDB else "sqlite") \
        if args.backend == "auto" else args.backend

    print(f"== relational vs array representation, backend={backend} ==")
    tracer = obs.Tracer()
    with obs.use(tracer):
        results = bench_mlp(args, backend) + [bench_moe(args, backend),
                                              bench_rwkv(args, backend)]
    for r in results:
        print(f"{r['workload']:>18}: relational {r['relational_s']*1e3:9.1f}"
              f" ms | array {r['array_s']*1e3:9.1f} ms | "
              f"array speedup {r['speedup_array']:6.1f}x | max err "
              f"{max(r['relational_max_err'], r['array_max_err']):.2e}",
              flush=True)
    trace_path = obs.write_chrome_trace(
        tracer, args.out.rsplit(".", 1)[0] + ".trace.json")
    print(f"perfetto trace -> {trace_path}", flush=True)

    by_name = {r["workload"]: r for r in results}
    checks = {
        "all_within_1e-4": all(r["within_tol"] for r in results),
        # the paper's §7 finding: the array type wins the matmul-bound
        # stages (the MLP queries are pure matmul+sigmoid chains)
        "array_beats_relational_mlp_forward":
            by_name["mlp_forward"]["speedup_array"] > 1.0,
        "array_beats_relational_mlp_grad":
            by_name["mlp_forward_grad"]["speedup_array"] > 1.0,
    }
    metrics = {}
    for r in results:
        wl = r["workload"]
        metrics[f"{wl}.relational_s"] = regress.metric(r["relational_s"])
        metrics[f"{wl}.array_s"] = regress.metric(r["array_s"])
        metrics[f"{wl}.speedup_array"] = regress.metric(
            r["speedup_array"], "x", "higher")
    report = {"backend": backend, "have_duckdb": HAVE_DUCKDB,
              "mlp_config": {"rows": args.rows, "features": args.features,
                             "hidden": args.hidden, "classes": args.classes},
              "results": results,
              "trace": {"stage_totals": obs.summarize(tracer, top=12),
                        "evaluate": obs.stage_breakdown(
                            tracer, root="sql.evaluate"),
                        "evaluate_ms_hist":
                            tracer.histograms.get("sql.evaluate_ms", {})},
              "metrics": metrics,
              "checks": checks}
    with open(args.out, "w") as f:
        json.dump(report, f, indent=1)
    print(f"wrote {args.out}\nchecks: {checks}")
    return 0 if checks["all_within_1e-4"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
