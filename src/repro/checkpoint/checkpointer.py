"""Sharded, async checkpointing with elastic restore.

Layout: ``<dir>/step_<N>/{manifest.json, shard_<host>.npz}``. Each host
writes only the leaves it owns (process-local shards); the manifest stores
the tree structure + leaf→shard mapping + shapes/dtypes, so restore can
re-assemble on a *different* host count or mesh (elastic scaling): leaves
are loaded host-agnostically and re-placed under the target sharding.

Async: ``save`` snapshots leaves to host memory synchronously (cheap — the
device→host copy) and writes to disk on a background thread, so the train
loop is blocked only for the copy, not the I/O — the standard
fault-tolerance posture at 1000+ nodes.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import threading

import jax
import numpy as np


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


class Checkpointer:
    def __init__(self, directory: str, host_id: int = 0, n_hosts: int = 1,
                 keep: int = 3):
        self.dir = directory
        self.host_id = host_id
        self.n_hosts = n_hosts
        self.keep = keep
        self._thread: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------ save
    def save(self, step: int, tree, blocking: bool = False):
        """Snapshot now, write async (set ``blocking`` for tests)."""
        self.wait()  # one outstanding write at a time
        leaves, treedef = _flatten(tree)
        host_leaves = [np.asarray(x) for x in leaves]  # device→host snapshot
        treedef_str = str(treedef)

        def write():
            path = os.path.join(self.dir, f"step_{step:08d}")
            tmp = path + ".tmp"
            os.makedirs(tmp, exist_ok=True)
            np.savez(os.path.join(tmp, f"shard_{self.host_id}.npz"),
                     **{f"leaf_{i}": a for i, a in enumerate(host_leaves)})
            if self.host_id == 0:
                manifest = {
                    "step": step,
                    "n_leaves": len(host_leaves),
                    "n_hosts": self.n_hosts,
                    "treedef": treedef_str,
                    "shapes": [list(a.shape) for a in host_leaves],
                    "dtypes": [str(a.dtype) for a in host_leaves],
                }
                with open(os.path.join(tmp, "manifest.json"), "w") as f:
                    json.dump(manifest, f)
            if os.path.exists(path):       # idempotent re-save of a step
                shutil.rmtree(tmp, ignore_errors=True)
            else:
                os.replace(tmp, path)      # atomic publish
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if blocking:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = self.list_steps()
        for s in steps[:-self.keep]:
            shutil.rmtree(os.path.join(self.dir, f"step_{s:08d}"),
                          ignore_errors=True)

    # --------------------------------------------------------------- restore
    def list_steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            m = re.fullmatch(r"step_(\d+)", name)
            if m and os.path.exists(os.path.join(self.dir, name,
                                                 "manifest.json")):
                out.append(int(m.group(1)))
        return sorted(out)

    def latest_step(self) -> int | None:
        steps = self.list_steps()
        return steps[-1] if steps else None

    def restore(self, example_tree, step: int | None = None,
                sharding_tree=None):
        """Rebuild the pytree; ``example_tree`` supplies the structure.
        ``sharding_tree`` (optional, same structure) re-places every leaf
        under a *target* sharding — this is the elastic-restore path: the
        checkpoint written on N hosts restores onto any mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {self.dir}")
        path = os.path.join(self.dir, f"step_{step:08d}")
        data = np.load(os.path.join(path, f"shard_{self.host_id}.npz"))
        leaves, treedef = _flatten(example_tree)
        restored = [data[f"leaf_{i}"] for i in range(len(leaves))]
        if sharding_tree is not None:
            shard_leaves = jax.tree.leaves(sharding_tree)
            restored = [jax.device_put(a, s)
                        for a, s in zip(restored, shard_leaves)]
        else:
            restored = [jax.numpy.asarray(a) for a in restored]
        return jax.tree.unflatten(treedef, restored), step
