"""MusicGen-medium — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284]. The EnCodec frontend is a STUB: input_specs() provides
precomputed frame embeddings (assignment spec). Post-embedding the backbone
is MHA + LayerNorm + GELU with sinusoidal positions."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="musicgen-medium", family="audio", n_layers=48, d_model=1536,
    n_heads=24, n_kv_heads=24, d_head=64, d_ff=6144, vocab=2048,
    norm="layernorm", mlp="gelu", rope=False,
    stub_frontend="audio_frames")


def reduced() -> ArchConfig:
    return ArchConfig(
        name="musicgen-reduced", family="audio", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_head=32, d_ff=256, vocab=64,
        norm="layernorm", mlp="gelu", rope=False,
        stub_frontend="audio_frames")
