"""DBRX-132B — 16-expert top-4 fine-grained MoE, GQA kv=8
[hf:databricks/dbrx-base; unverified]. Paper technique applies in full
(relational MoE dispatch)."""
from .base import ArchConfig, MoESpec

CONFIG = ArchConfig(
    name="dbrx-132b", family="moe", n_layers=40, d_model=6144, n_heads=48,
    n_kv_heads=8, d_head=128, d_ff=10752, vocab=100352,
    moe=MoESpec(n_experts=16, top_k=4, d_ff_expert=10752,
                router_softmax="post"),
    rope_theta=5e5)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="dbrx-reduced", family="moe", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=256,
        moe=MoESpec(n_experts=4, top_k=2, d_ff_expert=128,
                    router_softmax="post"))
