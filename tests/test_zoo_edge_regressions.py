"""Edge-case differentials frozen as regressions (previously untested).

Each case pins behaviour that all backends agree on *today* across the
four dialect/engine combinations runnable in-container — dense,
rel_engine, relational SQL (sqlite) and array SQL — plus the sql92
renderings where they execute on a bare connection, and duckdb variants
in the CI extras job:

* ``ArgTopK`` ties exactly at the k boundary (smaller j wins — the
  shared ``order by v desc, j asc`` rank);
* ``Scatter`` duplicate-index accumulation (collisions SUM; untouched
  frame rows stay zero);
* 0-row matrices through the full pivot / ingest / decode path;
* ``Softmax`` at ±750 — naive exp overflows f64 at ~709, the stable
  lowering (subtract the row max) must not produce inf/nan and must
  match the dense reference.
"""
import sqlite3

import numpy as np
import jax.numpy as jnp
import pytest

from repro.core import Engine, dense
from repro.core import expr as E
from repro.db import HAVE_DUCKDB, connect, relation_io
from repro.db.dialect import Sql92Dialect, json_to_matrix, matrix_to_json
from repro.db.sql_engine import SQLEngine

TOL = 1e-5

#: (label, dialect override) pairs; duckdb variants appended in CI
ENGINES = [("sqlite-relational", "sqlite", None),
           ("sqlite-array", "sqlite", "array")]
if HAVE_DUCKDB:
    ENGINES += [("duckdb-relational", "duckdb", None),
                ("duckdb-array", "duckdb", "array")]


def sql_engines():
    return [pytest.param(backend, dialect, id=label)
            for label, backend, dialect in ENGINES]


def all_backends(roots, env):
    """Evaluate ``roots`` on dense, rel_engine and every SQL combination;
    returns {label: [np.ndarray per root]}."""
    jenv = {k: jnp.asarray(v, jnp.float32) for k, v in env.items()}
    outs = {"dense": [np.asarray(o)
                      for o in Engine("dense").eval_fn(roots)(jenv)],
            "rel_engine": [np.asarray(o)
                           for o in Engine("relational").eval_fn(roots)(jenv)]}
    for label, backend, dialect in ENGINES:
        with SQLEngine(backend=backend, dialect=dialect,
                       plan_cache_=False) as eng:
            outs[label] = eng.evaluate(roots, env)
    return outs


class TestArgTopKBoundaryTies:
    # row 0: tie 3.0/3.0 exactly AT the k=2 boundary (j=1 beats j=2);
    # row 1: three-way tie at the boundary; row 2: all equal
    X = np.array([[1.0, 3.0, 3.0, 0.0],
                  [5.0, 2.0, 2.0, 2.0],
                  [7.0, 7.0, 7.0, 7.0]], np.float32)
    WANT_K2 = np.array([[0, 1, 1, 0],
                        [1, 1, 0, 0],
                        [1, 1, 0, 0]], np.float64)

    def test_all_backends_pin_smaller_j(self):
        x = E.var("x", self.X.shape)
        for label, got in all_backends([E.argtopk(x, 2)],
                                       {"x": self.X}).items():
            np.testing.assert_array_equal(
                got[0], self.WANT_K2, err_msg=f"{label} tie-break drifted")

    def test_sql92_correlated_rendering_agrees(self):
        """The window-free sql92 rank executes on a bare connection and
        pins the same boundary ties."""
        conn = sqlite3.connect(":memory:")
        conn.execute("create table m (i integer, j integer, v real)")
        conn.executemany("insert into m values (?,?,?)",
                         [(i + 1, j + 1, float(self.X[i, j]))
                          for i in range(3) for j in range(4)])
        out = np.zeros_like(self.WANT_K2)
        q = Sql92Dialect().topk_mask_select("m", 2)
        for i, j, v in conn.execute(q).fetchall():
            out[int(i) - 1, int(j) - 1] = v
        np.testing.assert_array_equal(out, self.WANT_K2)


class TestScatterDuplicateIndices:
    X = np.array([[1.0, 10.0], [2.0, 20.0], [4.0, 40.0],
                  [8.0, 80.0], [16.0, 160.0]], np.float32)
    IDX = np.array([[0.0], [2.0], [0.0], [2.0], [2.0]], np.float32)
    # rows 0 and 2 collect their collision sums, rows 1 and 3 stay zero
    WANT = np.array([[5.0, 50.0], [0.0, 0.0],
                     [26.0, 260.0], [0.0, 0.0]], np.float64)

    def test_collisions_accumulate_holes_stay_zero(self):
        x = E.var("x", self.X.shape)
        idx = E.var("idx", self.IDX.shape)
        roots = [E.scatter(x, idx, 4)]
        env = {"x": self.X, "idx": self.IDX}
        for label, got in all_backends(roots, env).items():
            np.testing.assert_allclose(
                got[0], self.WANT, atol=TOL,
                err_msg=f"{label} scatter accumulation drifted")


class TestZeroRowMatrices:
    def test_pivot_roundtrip(self):
        a = np.zeros((0, 3))
        i, j, v = relation_io.matrix_to_columns(a)
        assert i.size == j.size == v.size == 0
        np.testing.assert_array_equal(
            relation_io.rows_to_matrix([], (0, 3)), a)
        assert json_to_matrix(matrix_to_json(a)).shape == (0, 3)

    def test_db_write_read_empty(self):
        with connect("sqlite") as ad:
            relation_io.write_matrix(ad, "empty", np.zeros((0, 4)))
            out = relation_io.read_matrix(ad, "empty", (0, 4))
            assert out.shape == (0, 4)
            relation_io.write_matrix_array(ad, "empty_a", np.zeros((0, 4)))
            assert relation_io.read_matrix_array(ad, "empty_a").shape == (0, 4)

    def test_full_graph_path(self):
        """A 0-row batch through matmul / gather / scatter: every backend
        returns the right-shaped empties, the scatter frame stays dense."""
        x = E.var("x", (0, 3))
        w = E.var("w", (3, 2))
        eidx = E.var("eidx", (0, 1))
        wv = np.arange(6, dtype=np.float32).reshape(3, 2)
        env = {"x": np.zeros((0, 3), np.float32), "w": wv,
               "eidx": np.zeros((0, 1), np.float32)}
        roots = [E.matmul(x, w),                       # (0, 2)
                 E.gather(E.var("w", (3, 2)), eidx),   # (0, 2)
                 E.scatter(x, eidx, 4)]                # (4, 3), all zero
        for label, got in all_backends(roots, env).items():
            assert got[0].shape == (0, 2), label
            assert got[1].shape == (0, 2), label
            np.testing.assert_array_equal(got[2],
                                          np.zeros((4, 3)), err_msg=label)


class TestSoftmaxOverflow:
    # exp(750) overflows float64 (max ~709); exp(-1500) underflows to 0
    X = np.array([[750.0, 749.0, -750.0],
                  [-750.0, -749.5, -748.0],
                  [750.0, 750.0, 0.0]], np.float32)

    @staticmethod
    def stable_ref(x):
        x = np.asarray(x, np.float64)
        e = np.exp(x - x.max(axis=1, keepdims=True))
        return e / e.sum(axis=1, keepdims=True)

    def test_no_overflow_and_all_backends_agree(self):
        want = self.stable_ref(self.X)
        x = E.var("x", self.X.shape)
        for label, got in all_backends([E.softmax(x)],
                                       {"x": self.X}).items():
            assert np.isfinite(got[0]).all(), f"{label} overflowed"
            np.testing.assert_allclose(got[0], want, atol=TOL,
                                       err_msg=f"{label} softmax drifted")

    def test_sql92_rendering_is_stable(self):
        """The golden sql92 softmax CTE (executed with a registered exp
        UDF) subtracts the row max — ±750 inputs stay finite."""
        from repro.core import sqlgen
        import math

        conn = sqlite3.connect(":memory:")
        conn.create_function("exp", 1, math.exp, deterministic=True)
        conn.execute("create table x (i integer, j integer, v real)")
        conn.executemany("insert into x values (?,?,?)",
                         [(i + 1, j + 1, float(self.X[i, j]))
                          for i in range(3) for j in range(3)])
        sql = sqlgen.to_sql([E.softmax(E.var("x", self.X.shape),
                                       name="sm")], dialect="sql92")
        out = np.zeros((3, 3))
        for i, j, v in conn.execute(sql.rstrip(";")).fetchall():
            out[int(i) - 1, int(j) - 1] = v
        np.testing.assert_allclose(out, self.stable_ref(self.X), atol=TOL)


class TestNonFiniteScanStates:
    """The packed scan codec (``mat_scan_rendering = "packed"``) carries
    cells as ``printf('%d,%d,%.17g', i, j, v)`` tags — but sqlite stores a
    bound NaN as NULL and printf renders NULL as 0, silently zeroing the
    cell.  The tag now spells non-finite cells explicitly (``nan`` /
    ``Inf``), consistent with how the VALUES ingest gate and the result
    decoder treat them (NULL ⇄ NaN), so non-finite state propagates
    through the scan exactly as dense arithmetic would."""

    T, D = 3, 3

    def _roots_env(self):
        a = E.var("nfa", (self.T * self.D, self.D))
        b = E.var("nfb", (self.T, self.D))
        av = np.tile(np.eye(self.D), (self.T, 1))   # s_t = s_{t-1} + b_t
        bv = np.zeros((self.T, self.D))
        # non-finite cells enter at the LAST step: a matmul over a row
        # holding nan/inf drowns every later column (nan·0 = inf·0 = nan),
        # which would test IEEE mixing rather than the codec round trip
        bv[2] = [np.nan, np.inf, -np.inf]
        return [E.mat_recurrence(a, b)], {"nfa": av, "nfb": bv}

    def test_mat_recurrence_propagates_non_finite(self):
        roots, env = self._roots_env()
        s = np.zeros(self.D)
        rows = []
        for t in range(self.T):
            s = s @ env["nfa"][t * self.D:(t + 1) * self.D] + env["nfb"][t]
            rows.append(s)
        want = np.stack(rows)
        for label, backend, dialect in ENGINES:
            with SQLEngine(backend=backend, dialect=dialect,
                           plan_cache_=False) as eng:
                got, = eng.evaluate(roots, env)
            np.testing.assert_array_equal(
                got, want, err_msg=f"{label} lost a non-finite state cell")

    def test_recurrence_propagates_non_finite(self):
        a = E.var("ra", (3, 2))
        b = E.var("rb", (3, 2))
        env = {"ra": np.ones((3, 2)),
               "rb": np.array([[np.nan, 1.0], [np.inf, 2.0],
                               [3.0, -np.inf]])}
        want = np.array([[np.nan, 1.0], [np.nan, 3.0], [np.nan, -np.inf]])
        for label, backend, dialect in ENGINES:
            with SQLEngine(backend=backend, dialect=dialect,
                           plan_cache_=False) as eng:
                got, = eng.evaluate([E.recurrence(a, b)], env)
            np.testing.assert_array_equal(
                got, want, err_msg=f"{label} lost a non-finite state cell")

    def test_wire_codec_round_trips_non_finite(self):
        from repro.db.dialect import _matrix_to_wire

        a = np.array([[np.nan, np.inf], [-np.inf, 0.0]])
        np.testing.assert_array_equal(json_to_matrix(_matrix_to_wire(a)), a)
        np.testing.assert_array_equal(json_to_matrix(matrix_to_json(a)), a)

    def test_mcellcat_rejects_garbage_tags(self):
        from repro.db.dialect import ARRAY_UDFS

        _nargs, mcellcat = ARRAY_UDFS["mcellcat"]
        with pytest.raises(ValueError, match="unparseable cell tag"):
            mcellcat("1,1,0xQQ", 1, 1)
