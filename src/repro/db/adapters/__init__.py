"""Connection adapters over the engines the container actually has.

One :class:`~repro.db.adapters.base.Adapter` contract, three backends:

``SQLiteAdapter``   — stdlib ``sqlite3``; always available, the default.
``DuckDBAdapter``   — only when the ``duckdb`` package is importable.
``PostgresAdapter`` — only when ``psycopg2`` is importable AND a server
                      DSN is supplied (argument or ``REPRO_PG_DSN``).

``connect`` picks a backend by name; :class:`ConnectionPool` fans one
logical database out to N worker adapters (the substrate under both the
batch server and the data-parallel shard trainer, ``db/shard.py``)."""
from __future__ import annotations

from .base import (CHUNK_ROWS, SLOW_QUERY_ENV, SQL_HEAD, Adapter,
                   _check_ident, log)
from .duckdb import DuckDBAdapter
from .postgres import HAVE_PSYCOPG2, PG_DSN_ENV, PostgresAdapter
from .sqlite import SQLiteAdapter
from ..dialect import HAVE_DUCKDB

__all__ = [
    "Adapter", "SQLiteAdapter", "DuckDBAdapter", "PostgresAdapter",
    "HAVE_PSYCOPG2", "PG_DSN_ENV", "connect", "ConnectionPool",
    "CHUNK_ROWS", "SLOW_QUERY_ENV", "SQL_HEAD", "log",
]


def connect(backend: str = "sqlite", path: str = ":memory:") -> Adapter:
    """Open the requested backend; ``'auto'`` prefers duckdb when present.
    For postgres, ``path`` is the libpq DSN (``REPRO_PG_DSN`` when empty
    or left at the ``":memory:"`` default)."""
    if backend == "auto":
        backend = "duckdb" if HAVE_DUCKDB else "sqlite"
    if backend == "sqlite":
        return SQLiteAdapter(path)
    if backend == "duckdb":
        return DuckDBAdapter(path)
    if backend == "postgres":
        return PostgresAdapter(path)
    raise ValueError(f"unknown backend {backend!r}; "
                     "expected 'sqlite', 'duckdb', 'postgres' or 'auto'")


class ConnectionPool:
    """A fixed set of worker adapters over ONE logical database — the
    connection tier under :class:`repro.serving.db_serve.SQLBatchServer`
    and the shard axis of :func:`repro.db.shard.train_in_db_sharded`.

    * **sqlite file** — one WAL-mode connection per worker: WAL gives many
      concurrent readers plus one writer, ``busy_timeout`` absorbs writer
      collisions, and the shared generation registry keeps the per-
      connection matrix caches coherent (same ``_db_key``).
    * **sqlite** ``:memory:`` — N *independent* databases (stdlib sqlite3
      shares an in-memory DB only through the deprecated ``cache=shared``
      URI); shared leaves must be ingested into every worker — the batch
      server's ``start()`` and the shard trainer's temp-leaf ingestion do.
    * **duckdb** — ONE root connection, ``.cursor()`` per extra worker:
      each cursor is a full connection over the root's catalog with its
      own temp-table namespace.
    * **postgres** — one session per worker on the same DSN: a shared
      server-side catalog (same ``_db_key``) with per-session temp
      namespaces.
    """

    def __init__(self, backend: str = "sqlite", path: str = ":memory:",
                 size: int = 4):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size}")
        self.backend = backend
        self.path = path
        root = connect(backend, path)
        workers = [root]
        for _ in range(size - 1):
            if isinstance(root, DuckDBAdapter):  # pragma: no cover - duckdb
                workers.append(root.cursor_adapter())
            else:
                workers.append(connect(backend, path))
        self.adapters: list[Adapter] = workers

    def __len__(self) -> int:
        return len(self.adapters)

    def __iter__(self):
        return iter(self.adapters)

    def __getitem__(self, i: int) -> Adapter:
        return self.adapters[i]

    def close(self) -> None:
        # workers first, root (duckdb cursor parent) last
        for a in self.adapters[:0:-1]:
            try:
                a.close()
            except Exception:  # pragma: no cover - already-closed cursors
                pass
        self.adapters[0].close()
