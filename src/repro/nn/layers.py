"""Shared NN building blocks (pure JAX, dict-of-arrays params).

Conventions:
  * params are nested dicts of jnp arrays; init functions take an rng key
    and return the dict. Stacked-layer params get a leading L axis and are
    consumed by ``lax.scan`` (scan-over-layers keeps HLO size and compile
    time O(1) in depth — required for the 40-cell dry-run).
  * compute dtype is bf16 (params stored f32, cast at use); softmax,
    normalisation statistics and losses are f32.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

COMPUTE_DTYPE = jnp.bfloat16


def cdt(x):
    return x.astype(COMPUTE_DTYPE)


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def dense_init(key, shape, scale: float | None = None):
    fan_in = shape[0] if len(shape) == 2 else shape[-2]
    scale = scale if scale is not None else fan_in ** -0.5
    return (jax.random.normal(key, shape, jnp.float32) * scale)


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------

def rmsnorm_init(d: int):
    return {"w": jnp.ones((d,), jnp.float32)}


def rmsnorm(p, x, eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * p["w"]).astype(x.dtype)


def layernorm_init(d: int):
    return {"w": jnp.ones((d,), jnp.float32), "b": jnp.zeros((d,), jnp.float32)}


def layernorm(p, x, eps: float = 1e-5):
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    return ((xf - mu) * jax.lax.rsqrt(var + eps) * p["w"] + p["b"]).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary position embedding
# ---------------------------------------------------------------------------

def rope_table(seq_len: int, dim: int, theta: float = 1e4, offset: int = 0):
    pos = jnp.arange(offset, offset + seq_len, dtype=jnp.float32)
    inv = 1.0 / (theta ** (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
    ang = pos[:, None] * inv[None, :]
    return jnp.cos(ang), jnp.sin(ang)  # (S, dim/2)


def apply_rope(x, cos, sin):
    """x: (..., S, d). Rotate-half convention."""
    d2 = x.shape[-1] // 2
    x1, x2 = x[..., :d2], x[..., d2:]
    shape = (1,) * (x.ndim - 2) + cos.shape
    c = cos.reshape(shape).astype(x.dtype)
    s = sin.reshape(shape).astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------

def swiglu_init(key, d: int, ff: int):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"wi": dense_init(k1, (d, ff)), "wg": dense_init(k2, (d, ff)),
            "wo": dense_init(k3, (ff, d))}


def swiglu(p, x):
    h = jnp.dot(x, cdt(p["wi"])) * jax.nn.silu(jnp.dot(x, cdt(p["wg"])))
    return jnp.dot(h, cdt(p["wo"]))


def gelu_mlp_init(key, d: int, ff: int):
    k1, k2 = jax.random.split(key)
    return {"wi": dense_init(k1, (d, ff)), "bi": jnp.zeros((ff,), jnp.float32),
            "wo": dense_init(k2, (ff, d)), "bo": jnp.zeros((d,), jnp.float32)}


def gelu_mlp(p, x):
    h = jax.nn.gelu(jnp.dot(x, cdt(p["wi"])) + cdt(p["bi"]))
    return jnp.dot(h, cdt(p["wo"])) + cdt(p["bo"])


# ---------------------------------------------------------------------------
# attention (GQA, optional qk-norm / qkv-bias); dense-masked jnp path.
# ---------------------------------------------------------------------------

def gqa_init(key, d: int, n_heads: int, n_kv: int, d_head: int,
             qkv_bias: bool = False, qk_norm: bool = False):
    ks = jax.random.split(key, 4)
    p = {"wq": dense_init(ks[0], (d, n_heads * d_head)),
         "wk": dense_init(ks[1], (d, n_kv * d_head)),
         "wv": dense_init(ks[2], (d, n_kv * d_head)),
         "wo": dense_init(ks[3], (n_heads * d_head, d))}
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * d_head,), jnp.float32)
        p["bk"] = jnp.zeros((n_kv * d_head,), jnp.float32)
        p["bv"] = jnp.zeros((n_kv * d_head,), jnp.float32)
    if qk_norm:
        p["q_norm"] = rmsnorm_init(d_head)
        p["k_norm"] = rmsnorm_init(d_head)
    return p


def _split_heads(x, n, d_head):
    b, s, _ = x.shape
    return x.reshape(b, s, n, d_head).transpose(0, 2, 1, 3)  # (B, H, S, dh)


def gqa_project_qkv(p, x, n_heads: int, n_kv: int, d_head: int,
                    cos=None, sin=None):
    q = jnp.dot(x, cdt(p["wq"]))
    k = jnp.dot(x, cdt(p["wk"]))
    v = jnp.dot(x, cdt(p["wv"]))
    if "bq" in p:
        q, k, v = q + cdt(p["bq"]), k + cdt(p["bk"]), v + cdt(p["bv"])
    q = _split_heads(q, n_heads, d_head)
    k = _split_heads(k, n_kv, d_head)
    v = _split_heads(v, n_kv, d_head)
    if "q_norm" in p:
        q = rmsnorm(p["q_norm"], q)
        k = rmsnorm(p["k_norm"], k)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def attend(q, k, v, causal: bool = True, q_offset: int = 0,
           kv_len_mask=None):
    """softmax(q·kᵀ)·v with GQA head grouping. q: (B,Hq,Sq,dh), k/v (B,Hkv,Skv,dh).

    ``q_offset``: absolute position of q[...,0,:] (decode: Skv-1).
    ``kv_len_mask``: optional (B, Skv) validity mask for ragged caches.
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, hkv, group, sq, dh)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) * (dh ** -0.5)
    if causal and sq > 1:
        qpos = q_offset + jnp.arange(sq)
        kpos = jnp.arange(skv)
        mask = qpos[:, None] >= kpos[None, :]
        logits = jnp.where(mask[None, None, None], logits, -1e30)
    if kv_len_mask is not None:
        logits = jnp.where(kv_len_mask[:, None, None, None, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", probs, v.astype(jnp.float32))
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)  # d_v may ≠ d_q (MLA)


def attend_flash(q, k, v, chunk: int = 1024, q_offset: int = 0,
                 causal: bool = True, bf16_scores: bool = False):
    """Online-softmax blocked attention (jnp twin of kernels/flash_attention).

    Unrolled q/kv chunk loops: strictly-future blocks are *not emitted*, so
    the compiled HLO carries only the ~S²/2 causal work and O(chunk²) live
    score blocks — this is what lets prefill_32k fit HBM and is the
    §Perf lever that halves the attention compute term vs a dense mask.
    Unrolled (not lax.scan) so the dry-run's cost_analysis counts every
    block (scan bodies are counted once — see EXPERIMENTS.md §Dry-run).
    """
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = dh ** -0.5
    if sq % chunk or skv % chunk:
        return attend(q, k, v, causal=causal, q_offset=q_offset)
    sdt = jnp.bfloat16 if bf16_scores else jnp.float32
    qg = q.reshape(b, hkv, group, sq, dh)
    outs = []
    for c in range(sq // chunk):
        q_c = qg[:, :, :, c * chunk:(c + 1) * chunk].astype(sdt)
        hi_pos = q_offset + (c + 1) * chunk          # last visible kv + 1
        n_kv = skv // chunk if not causal else -(-hi_pos // chunk)
        m = jnp.full(q_c.shape[:-1], -1e30, jnp.float32)
        l = jnp.zeros(q_c.shape[:-1], jnp.float32)
        acc = jnp.zeros(q_c.shape[:-1] + (v.shape[-1],), jnp.float32)
        for i in range(n_kv):
            k_c = k[:, :, i * chunk:(i + 1) * chunk].astype(sdt)
            v_c = v[:, :, i * chunk:(i + 1) * chunk].astype(sdt)
            # with bf16_scores the S and P blocks — the dominant HBM
            # traffic of long-context attention — stay bf16; the online
            # max/normaliser statistics remain f32 (§Perf lever)
            s = (jnp.einsum("bhgqd,bhkd->bhgqk", q_c, k_c,
                            preferred_element_type=jnp.float32) * scale)
            if causal and (i + 1) * chunk > q_offset + c * chunk:
                qpos = (q_offset + c * chunk +
                        jnp.arange(chunk)[:, None])
                kpos = i * chunk + jnp.arange(chunk)[None, :]
                s = jnp.where((qpos >= kpos)[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None]).astype(sdt)
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1).astype(jnp.float32)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_c,
                preferred_element_type=jnp.float32)
            m = m_new
        outs.append((acc / l[..., None]))
    out = jnp.concatenate(outs, axis=3)
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)


def auto_chunk(seq_len: int) -> int:
    """Flash chunk size: ≥1024, ≤4096, ~seq/8 (bounds HLO size at 32k)."""
    return max(1024, min(4096, seq_len // 8))


def attend_flash_scan(q, k, v, chunk: int = 1024, q_offset: int = 0,
                      causal: bool = True):
    """attend_flash with the kv loop as a ``lax.scan``: identical math,
    but the compiled program provably reuses one block of buffers per
    step — the memory model the dry-run reports (the unrolled twin is
    used for exact FLOP accounting; the Pallas kernel is the TPU runtime
    path). Tested equal to attend_flash."""
    b, hq, sq, dh = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    group = hq // hkv
    scale = dh ** -0.5
    if sq % chunk or skv % chunk:
        return attend(q, k, v, causal=causal, q_offset=q_offset)
    qg = q.reshape(b, hkv, group, sq, dh)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    outs = []
    for c in range(sq // chunk):
        q_c = qg[:, :, :, c * chunk:(c + 1) * chunk].astype(jnp.float32)
        hi_pos = q_offset + (c + 1) * chunk
        n_kv = skv // chunk if not causal else -(-hi_pos // chunk)

        def body(carry, i):
            m, l, acc = carry
            k_c = jax.lax.dynamic_slice_in_dim(kf, i * chunk, chunk, axis=2)
            v_c = jax.lax.dynamic_slice_in_dim(vf, i * chunk, chunk, axis=2)
            s = jnp.einsum("bhgqd,bhkd->bhgqk", q_c, k_c) * scale
            if causal:
                qpos = (q_offset + c * chunk +
                        jnp.arange(chunk)[:, None])
                kpos = i * chunk + jnp.arange(chunk)[None, :]
                s = jnp.where((qpos >= kpos)[None, None, None], s, -1e30)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bhgqk,bhkd->bhgqd", p, v_c)
            return (m_new, l, acc), None

        init = (jnp.full(q_c.shape[:-1], -1e30, jnp.float32),
                jnp.zeros(q_c.shape[:-1], jnp.float32),
                jnp.zeros(q_c.shape[:-1] + (v.shape[-1],), jnp.float32))
        (m, l, acc), _ = jax.lax.scan(body, init, jnp.arange(n_kv))
        outs.append(acc / l[..., None])
    out = jnp.concatenate(outs, axis=3)
    return out.reshape(b, hq, sq, v.shape[-1]).astype(q.dtype)


def attend_chunked(q, k, v, chunk: int = 2048, q_offset: int = 0):
    """Causal attention computed per q-chunk against only the kv prefix it
    can see — skips strictly-future kv, halving score FLOPs vs the dense
    mask (beyond-paper §Perf optimisation; the Pallas flash kernel is the
    TPU-runtime twin of this HLO-level schedule)."""
    b, hq, sq, dh = q.shape
    if sq <= chunk:
        return attend(q, k, v, causal=True, q_offset=q_offset)
    assert sq % chunk == 0
    outs = []
    for c in range(sq // chunk):
        lo = c * chunk
        kv_hi = q_offset + lo + chunk
        outs.append(attend(q[:, :, lo:lo + chunk], k[:, :, :kv_hi],
                           v[:, :, :kv_hi], causal=True,
                           q_offset=q_offset + lo))
    return jnp.concatenate(outs, axis=2)


def merge_heads(x):
    b, h, s, dh = x.shape
    return x.transpose(0, 2, 1, 3).reshape(b, s, h * dh)


# ---------------------------------------------------------------------------
# MLA — DeepSeek-V2 multi-head latent attention (kv_lora compression)
# ---------------------------------------------------------------------------

def mla_init(key, d: int, n_heads: int, kv_lora: int, d_nope: int,
             d_rope: int, d_v: int):
    ks = jax.random.split(key, 6)
    return {
        "wq": dense_init(ks[0], (d, n_heads * (d_nope + d_rope))),
        "wkv_a": dense_init(ks[1], (d, kv_lora)),       # compress
        "kv_a_norm": rmsnorm_init(kv_lora),
        "wk_b": dense_init(ks[2], (kv_lora, n_heads * d_nope)),
        "wv_b": dense_init(ks[3], (kv_lora, n_heads * d_v)),
        "wk_rope": dense_init(ks[4], (d, d_rope)),      # shared rope key
        "wo": dense_init(ks[5], (n_heads * d_v, d)),
    }


def mla_qkv(p, x, n_heads: int, d_nope: int, d_rope: int, d_v: int,
            cos, sin):
    """Returns q (B,H,S,d_nope+d_rope), k (same), v (B,H,S,d_v).

    The latent c_kv (B,S,kv_lora) + shared k_rope (B,S,d_rope) are what a
    serving cache stores — the paper-style memory saving; here we expand to
    full heads for the attention product (absorbed-matmul is a further
    runtime optimisation, see DESIGN.md)."""
    b, s, _ = x.shape
    q = jnp.dot(x, cdt(p["wq"])).reshape(b, s, n_heads, d_nope + d_rope)
    q = q.transpose(0, 2, 1, 3)
    q_nope, q_rope = q[..., :d_nope], q[..., d_nope:]
    q_rope = apply_rope(q_rope, cos, sin)
    q = jnp.concatenate([q_nope, q_rope], axis=-1)

    c_kv = rmsnorm(p["kv_a_norm"], jnp.dot(x, cdt(p["wkv_a"])))
    k_nope = jnp.dot(c_kv, cdt(p["wk_b"])).reshape(b, s, n_heads, d_nope)
    k_nope = k_nope.transpose(0, 2, 1, 3)
    k_rope = apply_rope(jnp.dot(x, cdt(p["wk_rope"]))[:, None], cos, sin)
    k_rope = jnp.broadcast_to(k_rope, (b, n_heads, s, d_rope))
    k = jnp.concatenate([k_nope, k_rope], axis=-1)
    v = jnp.dot(c_kv, cdt(p["wv_b"])).reshape(b, s, n_heads, d_v)
    v = v.transpose(0, 2, 1, 3)
    return q, k, v, c_kv
