"""Thin connection adapters over the engines the container actually has.

One interface, two implementations:

``SQLiteAdapter`` — stdlib ``sqlite3``; always available, the default.
``DuckDBAdapter`` — only when the ``duckdb`` package is importable.

An adapter owns a connection plus the matching :mod:`repro.db.dialect`, and
exposes exactly what the execution backend needs: ``execute`` (rows back),
``create_table``, ``bulk_insert`` and the vectorized ``insert_columns``.
Everything else (SQL rendering, array pivoting) lives in ``dialect`` /
``relation_io`` so the adapters stay thin.  Both matrix representations
ride the same methods: cell-relational ``{[i, j, v]}`` tables through
``insert_columns``, array-representation tables (ONE row, a JSON
array-typed ``m`` column — ``relation_io.ARRAY_COLUMNS``) through
``bulk_insert``; ``matrix_digests`` entries embed the representation, so
an engine switch on a shared connection always rewrites the leaf.

Ingestion strategy per backend (the MNIST-scale bottleneck — see
``benchmarks/bench_mnist_db.py``):

* generic — chunked ``executemany`` over C-level ``zip`` of column
  ``tolist()`` slices (no per-cell Python arithmetic);
* sqlite — multi-row ``INSERT … VALUES (…),(…),…`` batches (fewer
  statement steps; ~3× over the flat per-cell path, which is the floor the
  row-at-a-time storage model allows);
* duckdb — zero-loop registration of the column arrays (Arrow table when
  ``pyarrow`` is importable, pandas/numpy dict otherwise) followed by one
  ``INSERT INTO … SELECT``.
"""
from __future__ import annotations

import itertools
import logging
import os
import re
import sqlite3
import threading
import time
from typing import Iterable, Sequence

import numpy as np

from ..obs import tracer_of
from .dialect import (HAVE_DUCKDB, DuckDBDialect, Sql92Dialect, SqliteDialect,
                      duckdb)

_IDENT = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")

#: rows per executemany chunk (bounds peak Python-object materialisation)
CHUNK_ROWS = 100_000

#: queries slower than this many milliseconds are logged (rendered SQL head
#: + span path) through the ``repro.db`` logger; unset/invalid → disabled
SLOW_QUERY_ENV = "REPRO_SLOW_QUERY_MS"

#: characters of rendered SQL attached to spans and slow-query log lines
SQL_HEAD = 160

log = logging.getLogger("repro.db")


def _slow_threshold_s() -> float | None:
    """Parse ``REPRO_SLOW_QUERY_MS`` (read per query so tests and running
    processes can flip it); None disables the slow-query log."""
    v = os.environ.get(SLOW_QUERY_ENV)
    if not v:
        return None
    try:
        return float(v) / 1e3
    except ValueError:
        return None


def _check_ident(name: str) -> str:
    if not _IDENT.match(name):
        raise ValueError(f"bad SQL identifier: {name!r}")
    return name


#: process-wide table-generation registry: (db_key, table) → generation,
#: bumped by every structured mutation through ANY adapter of the same
#: logical database.  Pooled connections on one file see each other's
#: writes, so per-adapter caches (``matrix_cache`` / ``matrix_digests`` /
#: ``matrix_meta``) are trustworthy only while the generation they were
#: recorded at (``Adapter.matrix_gen``) still matches — the fix for the
#: two-connection stale-delta bug (``update_matrix_delta`` patching cells
#: on top of a sibling's rewrite).
_GEN_LOCK = threading.Lock()
_TABLE_GEN: dict[tuple[str, str], int] = {}
#: unique per-adapter token for non-shared registry keys (``:memory:``
#: databases, temp-table namespaces).  A plain ``id(self)`` is NOT unique
#: over time — CPython reuses addresses, so a fresh ``:memory:`` adapter
#: could inherit a dead sibling's generations/digests and "adopt" tables
#: it never wrote
_CONN_SEQ = itertools.count()
#: (db_key, table) → content digest as last written by ANY adapter.  A
#: pooled worker about to ingest a leaf whose digest already matches can
#: ADOPT the resident table instead of rewriting it — without this, two
#: workers alternating on one shared weight relation would invalidate each
#: other forever (write ping-pong).  Popped on every generation bump.
_TABLE_DIGEST: dict[tuple[str, str], bytes] = {}


class Adapter:
    """Base adapter: a prepared connection + its dialect."""

    dialect: Sql92Dialect
    placeholder = "?"
    #: whether ``insert_matrix_json`` (engine-side json_each expansion) is
    #: available — probed per connection where the backend supports it
    supports_json_ingest = False
    #: whether the engine-side JSON path should be the *default* matrix
    #: ingestion (``relation_io.write_matrix`` consults this) — only where
    #: the runtime engine expands JSON in linear time
    prefers_json_ingest = False

    def __init__(self, conn):
        self.conn = conn
        #: table → content digest of the matrix it stores, maintained by
        #: SQLEngine's leaf ingestion.  Lives on the adapter (not the
        #: engine) so every adapter-level mutation of a table — replace
        #: via create_table or append via bulk_insert/insert_columns, e.g.
        #: db.train writing `img` directly — invalidates the entry, and
        #: engines sharing one connection share the skip.  (Raw
        #: ``execute`` writes are untracked: mutate matrix tables through
        #: the structured methods.)
        self.matrix_digests: dict[str, bytes] = {}
        #: table → (representation, shape) of the matrix it stores — what
        #: the bound-parameter delta path (``relation_io.update_matrix_*``)
        #: checks before updating a resident relation in place
        self.matrix_meta: dict[str, tuple] = {}
        #: table → retained client-side copy of SMALL relational matrices
        #: (``relation_io.DELTA_MAX_CELLS`` gate) — the diff base that turns
        #: a leaf refresh into a prepared UPDATE of only the changed cells
        self.matrix_cache: dict[str, np.ndarray] = {}
        #: table → generation (``table_gen``) at which the caches above
        #: were recorded; ``cache_fresh`` compares it against the shared
        #: registry before any of them is trusted
        self.matrix_gen: dict[str, int] = {}
        #: tracer override for this connection's spans (None → the
        #: module-level active tracer, a no-op unless installed)
        self.tracer = None
        #: serializes ALL raw-connection access AND counter updates —
        #: sqlite connections opened ``check_same_thread=False`` and duckdb
        #: cursors are handed across pool-worker threads; re-entrant so
        #: span-wrapped fast paths may nest ``execute`` calls
        self.lock = threading.RLock()
        #: identity of the logical database for the shared generation
        #: registry; file-backed adapters override with a path key so
        #: sibling connections on one file share generations.  The token
        #: is a process-lifetime-unique sequence number, never id()
        self._conn_token = next(_CONN_SEQ)
        self._db_key = f"conn:{self._conn_token}"
        #: tables created ``temp=True`` — per-connection namespace, keyed
        #: per-adapter in the registry so temp churn never invalidates
        #: sibling connections
        self._temp_tables: set[str] = set()
        #: always-on cheap counters, merged into ``SQLEngine.stats``;
        #: mutate through ``add_counters`` (or under ``self.lock``) — plain
        #: ``+=`` from pool workers drops increments
        self.counters: dict[str, int] = {
            "queries": 0, "statements": 0, "rows_returned": 0,
            "ingest_bytes": 0, "ingest_cells": 0, "slow_queries": 0,
        }
        self.dialect.prepare(conn)

    # -- cross-connection cache coherence -----------------------------------
    def _gen_key(self, name: str) -> tuple[str, str]:
        """Registry key for a table: temp tables are invisible to sibling
        connections, so they key per-adapter; everything else keys per
        logical database."""
        if name in self._temp_tables:
            return (f"tmp:{self._conn_token}", name)
        return (self._db_key, name)

    def table_gen(self, name: str) -> int:
        with _GEN_LOCK:
            return _TABLE_GEN.get(self._gen_key(name), 0)

    def bump_gen(self, name: str) -> None:
        """Advance the table's shared generation (and drop its shared
        digest): every sibling adapter's caches for it become stale."""
        with _GEN_LOCK:
            k = self._gen_key(name)
            _TABLE_GEN[k] = _TABLE_GEN.get(k, 0) + 1
            _TABLE_DIGEST.pop(k, None)

    def cache_fresh(self, name: str) -> bool:
        """Were this adapter's cached digest/meta/diff-copy for ``name``
        recorded at the table's CURRENT generation?  False the moment any
        sibling adapter on the same database mutates the relation."""
        gen = self.matrix_gen.get(name)
        return gen is not None and gen == self.table_gen(name)

    def shared_digest(self, name: str) -> bytes | None:
        with _GEN_LOCK:
            return _TABLE_DIGEST.get(self._gen_key(name))

    def record_digest(self, name: str, digest: bytes) -> None:
        with _GEN_LOCK:
            _TABLE_DIGEST[self._gen_key(name)] = digest

    def add_counters(self, **deltas: int) -> None:
        """Locked read-modify-write of the always-on counters — exact
        totals even when pool workers ingest concurrently."""
        with self.lock:
            for k, v in deltas.items():
                self.counters[k] = self.counters.get(k, 0) + v

    # -- statement execution ------------------------------------------------
    #
    # EVERY statement the backend runs goes through ``execute`` /
    # ``executemany`` (or the span-wrapped fast paths below), so span
    # coverage and the query counters cannot be bypassed by new call sites
    # — ``tests/test_obs_coverage.py`` statically enforces both halves.

    def _finish_stmt(self, sql: str, dt: float, tracer) -> None:
        """Shared statement epilogue: slow-query log (``REPRO_SLOW_QUERY_MS``)
        with the rendered SQL head and the innermost span path."""
        thr = _slow_threshold_s()
        if thr is not None and dt >= thr:
            self.counters["slow_queries"] += 1
            head = " ".join(sql[:SQL_HEAD].split())
            log.warning("slow query %.1f ms (>= %s ms) span=%s sql=%s",
                        dt * 1e3, os.environ.get(SLOW_QUERY_ENV),
                        tracer.current_path() or "<untraced>", head)

    def execute(self, sql: str, params: Sequence = ()) -> list[tuple]:
        """Run one statement, return all result rows (possibly empty).
        Serialized on ``self.lock`` — one connection, many threads."""
        tr = tracer_of(self)
        with tr.span("db.execute") as sp, self.lock:
            t0 = time.perf_counter()
            cur = self.conn.execute(sql, tuple(params))
            try:
                rows = cur.fetchall()
            except Exception:  # statement without a result set
                rows = []
            dt = time.perf_counter() - t0
            self.counters["queries"] += 1
            self.counters["rows_returned"] += len(rows)
            if tr.enabled:
                sp.set(sql=" ".join(sql[:SQL_HEAD].split()), rows=len(rows))
                tr.observe("db.execute_ms", dt * 1e3)
            self._finish_stmt(sql, dt, tr)
        return rows

    def executemany(self, sql: str, rows: Iterable[Sequence]) -> None:
        tr = tracer_of(self)
        with tr.span("db.executemany") as sp, self.lock:
            t0 = time.perf_counter()
            self.conn.executemany(sql, rows)
            dt = time.perf_counter() - t0
            self.counters["statements"] += 1
            if tr.enabled:
                sp.set(sql=" ".join(sql[:SQL_HEAD].split()))
            self._finish_stmt(sql, dt, tr)

    # -- introspection ------------------------------------------------------
    def explain_sql(self, sql: str) -> str:
        """The engine's plan for ``sql`` as text ('' where unsupported) —
        captured once per cached plan by ``SQLEngine`` and stored alongside
        the plan-cache entry."""
        return ""

    def db_bytes(self) -> int | None:
        """Stored size of the database in bytes (None where unknowable) —
        the ``db_bytes`` delta attribute of evaluation spans."""
        return None

    # -- schema / data ------------------------------------------------------
    def forget(self, name: str) -> None:
        """Drop THIS adapter's caches for a table without advancing the
        shared generation — used when this adapter discovers its caches
        are stale: the resident content is a sibling's valid write, and
        bumping here would ping-pong invalidations between workers."""
        self.matrix_digests.pop(name, None)
        self.matrix_meta.pop(name, None)
        self.matrix_cache.pop(name, None)
        self.matrix_gen.pop(name, None)

    def _invalidate(self, name: str) -> None:
        """Forget everything cached about a matrix table — content digest,
        shape metadata and the client-side diff copy — so any structured
        mutation of the relation disables the unchanged-leaf skip AND the
        bound-parameter delta path until the next full registration.  Also
        advances the table's shared generation: sibling pooled adapters'
        caches go stale with ours."""
        self.forget(name)
        self.bump_gen(name)

    def create_table(self, name: str, columns: Sequence[tuple[str, str]],
                     replace: bool = True, temp: bool = False) -> None:
        """``columns`` is [(col_name, sql_type), ...].  ``temp=True``
        creates a per-connection temp table (batched request leaves):
        invisible to sibling connections, so its generation is keyed
        per-adapter and never invalidates their caches."""
        _check_ident(name)
        if replace and not temp and name in self._temp_tables:
            # a temp table shadows the main-schema name on this
            # connection: DROP resolves to the shadow, so one drop below
            # would leave the resident main table colliding with CREATE
            self.execute(f"drop table if exists {name}")
        if temp:
            self._temp_tables.add(name)
        else:
            self._temp_tables.discard(name)
        self._invalidate(name)
        cols = ", ".join(f"{_check_ident(c)} {t}" for c, t in columns)
        kw = "temp table" if temp else "table"
        if replace:
            self.execute(f"drop table if exists {name}")
        self.execute(f"create {kw} {name} ({cols})")

    def bulk_insert(self, name: str, rows: Iterable[Sequence]) -> None:
        self._invalidate(name)
        rows = list(rows)
        if not rows:
            return
        ph = ", ".join([self.placeholder] * len(rows[0]))
        self.executemany(f"insert into {_check_ident(name)} values ({ph})",
                         rows)

    def _prepare_columns(self, name: str, cols: Sequence,
                         dtype=None) -> tuple[list[np.ndarray], int]:
        """Shared ``insert_columns`` preamble: identifier check, digest
        invalidation, array conversion, equal-length validation.  Returns
        ``(columns, n_rows)``; ``n_rows == 0`` means nothing to insert."""
        _check_ident(name)
        self._invalidate(name)
        cols = [np.asarray(c) if dtype is None else np.asarray(c, dtype)
                for c in cols]
        n = cols[0].shape[0] if cols else 0
        if n and any(c.shape != (n,) for c in cols):
            raise ValueError("insert_columns needs equal-length 1-D columns")
        return cols, n

    def insert_columns(self, name: str,
                       cols: Sequence[np.ndarray]) -> None:
        """Vectorized bulk ingestion: one ndarray per column, equal length.

        Generic implementation: chunked ``executemany`` over ``zip`` of
        ``tolist()`` slices — conversion to Python scalars happens in C,
        never per-cell in Python.  Backends override with faster native
        paths."""
        cols, n = self._prepare_columns(name, cols)
        if not n:
            return
        ph = ", ".join([self.placeholder] * len(cols))
        sql = f"insert into {name} values ({ph})"
        for s in range(0, n, CHUNK_ROWS):
            e = min(n, s + CHUNK_ROWS)
            self.executemany(sql, zip(*(c[s:e].tolist() for c in cols)))

    def update_cells(self, name: str, flat_index: np.ndarray,
                     values: np.ndarray, shape: Sequence[int]) -> None:
        """Bound-parameter in-place update of individual matrix cells,
        addressed by 0-based canonical row-major flat index — the prepared
        statement behind the small-leaf delta ingestion path.  Generic
        spelling keys on the (i, j) columns; sqlite overrides with the
        rowid fast path."""
        _check_ident(name)
        self.matrix_digests.pop(name, None)
        self.bump_gen(name)
        cols = int(shape[1])
        i = (flat_index // cols + 1).tolist()
        j = (flat_index % cols + 1).tolist()
        self.executemany(
            f"update {name} set v = {self.placeholder} where"
            f" i = {self.placeholder} and j = {self.placeholder}",
            zip(values.tolist(), i, j))

    # -- lifecycle ----------------------------------------------------------
    def commit(self) -> None:
        with self.lock:
            self.conn.commit()

    def close(self) -> None:
        with self.lock:
            try:  # flush pending inserts — sqlite3 rolls back open txns
                self.conn.commit()
            except Exception:  # pragma: no cover - autocommit (duckdb)
                pass
            self.conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class SQLiteAdapter(Adapter):
    dialect = SqliteDialect()

    #: rows per multi-row VALUES statement; sqlite's bound-parameter limit
    #: is 999 on older builds — 300 rows × 3 cols stays under it
    ROWS_PER_STMT = 300

    #: first sqlite release whose JSON table-functions extract values in
    #: linear time (the 3.38 JSON rewrite); before it ``json_each`` is
    #: O(array length) per row and the engine-side parse loses to VALUES
    #: (measured on this container's 3.34 — ``bench_mnist_db.py``)
    JSON_LINEAR_VERSION = (3, 38)

    #: milliseconds a statement waits on a sibling connection's write lock
    #: before ``database is locked`` — generous: pool writers serialize
    BUSY_TIMEOUT_MS = 30_000

    def __init__(self, path: str = ":memory:"):
        # check_same_thread=False: the adapter serializes every raw-
        # connection access on ``self.lock``, so handing the connection
        # across pool-worker threads is safe — sqlite's own affinity check
        # would raise ProgrammingError on the first cross-thread call
        super().__init__(sqlite3.connect(
            path, timeout=self.BUSY_TIMEOUT_MS / 1e3,
            check_same_thread=False))
        self.path = path
        if path != ":memory:":
            # sibling connections on one file share table generations
            self._db_key = "sqlite:" + os.path.abspath(path)
        #: runtime engine version — instance-level so tests can pin it
        self.sqlite_version = sqlite3.sqlite_version_info
        try:  # table-valued JSON ingestion needs the (default) JSON1 ext.
            # obs: exempt — capability probe at connect time, not a query
            self.conn.execute("select count(*) from json_each('[0]')")
            self.supports_json_ingest = True
        except sqlite3.Error:  # pragma: no cover - JSON1-less builds
            self.supports_json_ingest = False
        try:
            # obs: exempt — connection-mode pragmas at open, not queries
            self.conn.execute(f"pragma busy_timeout = {self.BUSY_TIMEOUT_MS}")
            if path != ":memory:":
                # WAL: many concurrent readers + one writer across the
                # pool's connections (a rollback-journal DB serializes
                # readers behind any writer)
                self.conn.execute("pragma journal_mode = wal")
        except sqlite3.Error:  # pragma: no cover - locked-down builds
            pass

    @property
    def prefers_json_ingest(self) -> bool:
        """Auto-select the engine-side ``json_each`` ingestion on builds
        where it is linear (≥ :data:`JSON_LINEAR_VERSION`); older engines
        keep the multi-row VALUES batching."""
        return (self.supports_json_ingest
                and self.sqlite_version >= self.JSON_LINEAR_VERSION)

    def explain_sql(self, sql: str) -> str:
        """``EXPLAIN QUERY PLAN`` rows as ``id parent: detail`` lines."""
        try:
            rows = self.execute("explain query plan " + sql)
        except Exception:
            return ""
        return "\n".join(f"{r[0]} {r[1]}: {r[-1]}" for r in rows)

    def db_bytes(self) -> int | None:
        try:
            # obs: exempt — size probe read by the tracer itself; spanning
            # it would pollute every evaluation trace with pragma queries
            with self.lock:
                page_count, = (self.conn.execute("pragma page_count")
                               .fetchone())
                page_size, = (self.conn.execute("pragma page_size")
                              .fetchone())
            return int(page_count) * int(page_size)
        except Exception:  # pragma: no cover - pragma-less builds
            return None

    #: cells per bound JSON array.  sqlite ≤3.37 extracts json_each values
    #: in O(array length) per row — one giant array is quadratic; bounded
    #: chunks keep the parse cost linear (and the win grows on ≥3.38
    #: builds, whose JSON table-functions are linear outright).
    JSON_CHUNK_CELLS = 4096

    def insert_matrix_json(self, name: str, x: np.ndarray) -> None:
        """JSON-array ingestion (the ROADMAP's table-valued lever): bind
        row-major JSON array chunks and let the engine expand them with the
        ``json_each`` table-valued function — index arithmetic on ``key``
        recovers the 1-based (i, j) pivot *inside* sqlite, eliminating the
        per-row Python binding of the VALUES path.  Values round-trip
        through sqlite's text→real parse, which may differ by ~1 ulp from
        the bound double (``bench_mnist_db.py`` reports the two paths side
        by side; on this container's 3.34 the engine-side parse roughly
        cancels the client-side saving — the lever pays off on newer
        JSON-optimised builds)."""
        import json

        _check_ident(name)
        self._invalidate(name)
        a = np.asarray(x, dtype=np.float64)
        if a.ndim != 2:
            raise ValueError(f"expected a matrix, got shape {a.shape}")
        if not np.isfinite(a).all():
            # json.dumps would emit NaN/Infinity tokens, which sqlite's
            # JSON parser rejects mid-chunk (partial table); refuse up
            # front — the VALUES path (write_matrix) binds them fine
            raise ValueError("non-finite values cannot ride the JSON "
                             "ingestion path; use write_matrix")
        cols = a.shape[1]
        flat = a.reshape(-1)
        chunk = max(cols, (self.JSON_CHUNK_CELLS // cols) * cols)
        sql = (f"insert into {name} "
               f"select (key + ?) / {cols} + 1, key % {cols} + 1, value "
               f"from json_each(?)")
        tr = tracer_of(self)
        with tr.span("db.ingest_json", table=name, cells=int(a.size)), \
                self.lock:
            cur = self.conn.cursor()
            for s in range(0, flat.size, chunk):
                cur.execute(sql, (s, json.dumps(flat[s:s + chunk].tolist())))
                self.counters["statements"] += 1

    def insert_columns(self, name: str,
                       cols: Sequence[np.ndarray]) -> None:
        """Multi-row VALUES batching: one statement binds ROWS_PER_STMT
        rows, executemany streams the batches.  Parameters are interleaved
        into one flat float list by strided ndarray assignment (ints bind
        fine through float64 — sqlite is dynamically typed and the matrix
        schema only ever compares/joins on equality of exact small ints)."""
        cols, n = self._prepare_columns(name, cols, dtype=np.float64)
        if not n:
            return
        k = len(cols)
        flat = np.empty(n * k)
        for ci, c in enumerate(cols):
            flat[ci::k] = c
        flat = flat.tolist()
        row_ph = "(" + ", ".join(["?"] * k) + ")"
        # never exceed 999 bound parameters per statement, whatever the
        # column count (wider tables than {i,j,v} pass through here too)
        batch = max(1, min(self.ROWS_PER_STMT, 999 // k))
        full, rem = divmod(n, batch)
        tr = tracer_of(self)
        with tr.span("db.ingest_values", table=name, rows=n), self.lock:
            cur = self.conn.cursor()
            if full:
                stride = k * batch
                sql = (f"insert into {name} values "
                       + ", ".join([row_ph] * batch))
                cur.executemany(sql, (flat[s:s + stride]
                                      for s in range(0, full * stride,
                                                     stride)))
                self.counters["statements"] += 1
            if rem:
                sql = (f"insert into {name} values "
                       + ", ".join([row_ph] * rem))
                cur.execute(sql, flat[full * batch * k:])
                self.counters["statements"] += 1

    def update_cells(self, name: str, flat_index: np.ndarray,
                     values: np.ndarray, shape: Sequence[int]) -> None:
        """The rowid fast path: matrix tables are populated in canonical
        row-major order (``relation_io.matrix_to_columns``) and the delta
        path never deletes individual rows, so ``rowid == flat_index + 1``
        — one prepared two-parameter UPDATE per changed cell, no (i, j)
        predicate evaluation."""
        _check_ident(name)
        self.matrix_digests.pop(name, None)
        self.bump_gen(name)
        self.executemany(f"update {name} set v = ? where rowid = ?",
                         zip(values.tolist(), (flat_index + 1).tolist()))


class DuckDBAdapter(Adapter):
    placeholder = "?"

    def __init__(self, path: str = ":memory:"):
        if not HAVE_DUCKDB:  # pragma: no cover - depends on environment
            raise ImportError("duckdb is not installed; "
                              "use backend='sqlite' or pip install repro[db]")
        self.dialect = DuckDBDialect()
        super().__init__(duckdb.connect(path))
        if path != ":memory:":  # pragma: no cover - needs duckdb
            self._db_key = "duckdb:" + os.path.abspath(path)

    def cursor_adapter(self) -> "DuckDBAdapter":  # pragma: no cover - duckdb
        """A pool worker over this connection: ``conn.cursor()`` is a full
        DuckDBPyConnection sharing the root's catalog, with its own temp
        namespace and transaction state — duckdb's one-writer model with
        per-worker cursors.  The worker shares ``_db_key`` (same logical
        database) but carries its own lock and caches.
        """
        # obs: exempt — pool-worker construction, not a query; every
        # statement the worker runs goes through the traced base methods
        other = object.__new__(DuckDBAdapter)
        other.dialect = DuckDBDialect()
        Adapter.__init__(other, self.conn.cursor())
        other._db_key = self._db_key
        return other

    def executemany(self, sql, rows):  # pragma: no cover - needs duckdb
        # tuple-normalise for duckdb's binder, then ride the traced base
        Adapter.executemany(self, sql, [tuple(r) for r in rows])

    def explain_sql(self, sql: str) -> str:  # pragma: no cover - needs duckdb
        """duckdb spells it plain ``EXPLAIN`` (physical plan as text)."""
        try:
            rows = self.execute("explain " + sql)
        except Exception:
            return ""
        return "\n".join(str(r[-1]) for r in rows)

    def insert_columns(self, name, cols):  # pragma: no cover - needs duckdb
        """Register the column arrays as a relation (Arrow when available,
        else a pandas DataFrame built zero-copy from the ndarrays) and run
        ONE ``INSERT INTO … SELECT`` — duckdb's native bulk path; no
        per-row Python at all."""
        cols, n = self._prepare_columns(name, cols)
        if not n:
            return
        names = [f"c{k}" for k in range(len(cols))]
        view = f"_ingest_{name}"
        frame = None
        try:
            import pyarrow as pa
            frame = pa.table({nm: pa.array(c) for nm, c in zip(names, cols)})
        except ImportError:
            try:
                import pandas as pd
                frame = pd.DataFrame(dict(zip(names, cols)))
            except ImportError:
                pass
        if frame is None:  # no columnar frontend — generic chunked path
            Adapter.insert_columns(self, name, cols)
            return
        tr = tracer_of(self)
        with tr.span("db.ingest_register", table=name, rows=n):
            self.conn.register(view, frame)
            try:
                self.execute(f"insert into {name} select * from {view}")
            finally:
                self.conn.unregister(view)


def connect(backend: str = "sqlite", path: str = ":memory:") -> Adapter:
    """Open the requested backend; ``'auto'`` prefers duckdb when present."""
    if backend == "auto":
        backend = "duckdb" if HAVE_DUCKDB else "sqlite"
    if backend == "sqlite":
        return SQLiteAdapter(path)
    if backend == "duckdb":
        return DuckDBAdapter(path)
    raise ValueError(f"unknown backend {backend!r}; "
                     "expected 'sqlite', 'duckdb' or 'auto'")


class ConnectionPool:
    """A fixed set of worker adapters over ONE logical database — the
    connection tier under :class:`repro.serving.db_serve.SQLBatchServer`.

    * **sqlite file** — one WAL-mode connection per worker: WAL gives many
      concurrent readers plus one writer, ``busy_timeout`` absorbs writer
      collisions, and the shared generation registry keeps the per-
      connection matrix caches coherent (same ``_db_key``).
    * **sqlite** ``:memory:`` — N *independent* databases (stdlib sqlite3
      shares an in-memory DB only through the deprecated ``cache=shared``
      URI); shared leaves must be ingested into every worker — the batch
      server's ``start()`` does.
    * **duckdb** — ONE root connection, ``.cursor()`` per extra worker:
      each cursor is a full connection over the root's catalog with its
      own temp-table namespace.
    """

    def __init__(self, backend: str = "sqlite", path: str = ":memory:",
                 size: int = 4):
        if size < 1:
            raise ValueError(f"pool size must be >= 1, got {size}")
        self.backend = backend
        self.path = path
        root = connect(backend, path)
        workers = [root]
        for _ in range(size - 1):
            if isinstance(root, DuckDBAdapter):  # pragma: no cover - duckdb
                workers.append(root.cursor_adapter())
            else:
                workers.append(connect(backend, path))
        self.adapters: list[Adapter] = workers

    def __len__(self) -> int:
        return len(self.adapters)

    def __iter__(self):
        return iter(self.adapters)

    def __getitem__(self, i: int) -> Adapter:
        return self.adapters[i]

    def close(self) -> None:
        # workers first, root (duckdb cursor parent) last
        for a in self.adapters[:0:-1]:
            try:
                a.close()
            except Exception:  # pragma: no cover - already-closed cursors
                pass
        self.adapters[0].close()
