"""Core-library tests: relational algebra, Algorithm-1 autodiff, engines."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis "
                    "(pip install repro[test])")
import hypothesis.strategies as st
from hypothesis import given, settings

from repro.core import Engine, autodiff, dense, nn2sql
from repro.core import expr as E
from repro.core.recursive_cte import history_bytes, recursive_cte
from repro.core.relational import (RelTensor, join_intermediate_bytes,
                                   one_hot, one_hot_dense, relation_bytes)

RNG = np.random.RandomState(0)


def rnd(*shape):
    return jnp.asarray(RNG.randn(*shape), jnp.float32)


# ---------------------------------------------------------------------------
# relational representation (paper §4, Listing 4 building blocks)
# ---------------------------------------------------------------------------

class TestRelTensor:
    def test_roundtrip(self):
        a = rnd(7, 5)
        assert np.allclose(RelTensor.from_dense(a).to_dense(), a)

    def test_matmul_matches_dense(self):
        a, b = rnd(6, 9), rnd(9, 4)
        out = RelTensor.from_dense(a).matmul(RelTensor.from_dense(b))
        np.testing.assert_allclose(out.to_dense(), a @ b, rtol=1e-5)

    def test_transpose_is_index_rename(self):
        a = rnd(5, 8)
        np.testing.assert_allclose(
            RelTensor.from_dense(a).transpose().to_dense(), a.T)

    def test_hadamard_join(self):
        a, b = rnd(4, 6), rnd(4, 6)
        out = RelTensor.from_dense(a).hadamard(RelTensor.from_dense(b))
        np.testing.assert_allclose(out.to_dense(), a * b, rtol=1e-6)

    def test_sparse_matmul_with_padding(self):
        """Padding tuples (i == m) must vanish like non-matching joins."""
        b = rnd(8, 5)
        rows = jnp.array([0, 0, 2, 3, 3, 3], jnp.int32)
        cols = jnp.array([1, 3, 0, 7, 2, 2], jnp.int32)
        vals = rnd(6)
        rel = RelTensor(i=jnp.concatenate([rows, jnp.full((4,), 4,
                                                          jnp.int32)]),
                        j=jnp.concatenate([cols,
                                           jnp.zeros((4,), jnp.int32)]),
                        v=jnp.concatenate([vals, jnp.ones((4,))]),
                        shape=(4, 8))
        expect = np.zeros((4, 5), np.float32)
        for r, c, v in zip(rows, cols, vals):
            expect[int(r)] += float(v) * np.asarray(b[int(c)])
        np.testing.assert_allclose(rel.matmul(RelTensor.from_dense(b))
                                   .to_dense(), expect, rtol=1e-5)

    def test_one_hot_matches_listing5(self):
        labels = jnp.array([0, 2, 1, 2], jnp.int32)
        oh = one_hot(labels, 3).to_dense()
        np.testing.assert_allclose(oh, jax.nn.one_hot(labels, 3))
        assert one_hot_dense(labels, 3).is_canonical()

    def test_memory_model_fig5(self):
        """Fig. 5: relational storage = 3× array; join blow-up = 1000×
        tuples per entry for 1000×1000 matmul."""
        assert relation_bytes((1000, 1000)) == 3 * 1000 * 1000 * 8
        assert (join_intermediate_bytes(1000, 1000, 1000)
                == 1000 ** 3 * 24)

    @given(m=st.integers(2, 6), k=st.integers(2, 6), n=st.integers(2, 6),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def test_matmul_property(self, m, k, n, seed):
        r = np.random.RandomState(seed)
        a = jnp.asarray(r.randn(m, k), jnp.float32)
        b = jnp.asarray(r.randn(k, n), jnp.float32)
        out = RelTensor.from_dense(a).matmul(RelTensor.from_dense(b))
        np.testing.assert_allclose(out.to_dense(), a @ b,
                                   rtol=1e-4, atol=1e-5)

    @given(m=st.integers(2, 6), n=st.integers(2, 6),
           seed=st.integers(0, 2 ** 16))
    @settings(max_examples=15, deadline=None)
    def test_transpose_involution(self, m, n, seed):
        r = np.random.RandomState(seed)
        a = jnp.asarray(r.randn(m, n), jnp.float32)
        rel = RelTensor.from_dense(a)
        np.testing.assert_allclose(rel.transpose().transpose().to_dense(),
                                   a)


# ---------------------------------------------------------------------------
# Algorithm 1 (reverse-mode AD over matrix expressions)
# ---------------------------------------------------------------------------

class TestAlgorithm1:
    def _graph_env(self, rows=12, feats=4, hidden=6, classes=3, seed=0):
        spec = nn2sql.MLPSpec(rows, feats, hidden, classes)
        g = nn2sql.build_graph(spec)
        r = np.random.RandomState(seed)
        env = {"img": jnp.asarray(r.rand(rows, feats), jnp.float32),
               "one_hot": jnp.asarray(
                   jax.nn.one_hot(r.randint(0, classes, rows), classes)),
               **nn2sql.init_weights(spec, seed=1)}
        return g, env

    def test_matches_jax_grad(self):
        g, env = self._graph_env()
        grads = autodiff.gradients(g.loss, [g.w_xh, g.w_ho])
        gx, gh = dense.evaluate([grads[g.w_xh], grads[g.w_ho]], env)

        def loss(wxh, who):
            axh = jax.nn.sigmoid(env["img"] @ wxh)
            aho = jax.nn.sigmoid(axh @ who)
            return jnp.sum((aho - env["one_hot"]) ** 2)

        jx, jh = jax.grad(loss, argnums=(0, 1))(env["w_xh"], env["w_ho"])
        np.testing.assert_allclose(gx, jx, rtol=1e-4, atol=1e-6)
        np.testing.assert_allclose(gh, jh, rtol=1e-4, atol=1e-6)

    def test_matches_paper_equations_6_to_11(self):
        """Algorithm 1's output graph == hand-derived Eqs. 6–11."""
        g, env = self._graph_env()
        alg = autodiff.gradients(g.loss, [g.w_xh, g.w_ho])
        man = nn2sql.manual_gradients(g)
        a = dense.evaluate([alg[g.w_xh], alg[g.w_ho]], env)
        m = dense.evaluate([man[g.w_xh], man[g.w_ho]], env)
        np.testing.assert_allclose(a[0], m[0], rtol=1e-5, atol=1e-7)
        np.testing.assert_allclose(a[1], m[1], rtol=1e-5, atol=1e-7)

    def test_shared_subexpression_accumulates(self):
        """d/dx (x∘x) = 2x·seed — the leaf rule must accumulate."""
        x = E.var("x", (3, 3))
        z = E.hadamard(x, x)
        grads = autodiff.derive(z, E.const(1.0, (3, 3)))
        val = jnp.asarray(RNG.randn(3, 3), jnp.float32)
        (gx,) = dense.evaluate([grads[x]], {"x": val})
        np.testing.assert_allclose(gx, 2 * val, rtol=1e-6)

    @given(rows=st.integers(2, 10), hidden=st.integers(2, 8),
           seed=st.integers(0, 2 ** 10))
    @settings(max_examples=10, deadline=None)
    def test_property_grad_equivalence(self, rows, hidden, seed):
        g, env = self._graph_env(rows=rows, hidden=hidden, seed=seed)
        grads = autodiff.gradients(g.loss, [g.w_xh])
        (gx,) = dense.evaluate([grads[g.w_xh]], env)

        def loss(wxh):
            axh = jax.nn.sigmoid(env["img"] @ wxh)
            aho = jax.nn.sigmoid(axh @ env["w_ho"])
            return jnp.sum((aho - env["one_hot"]) ** 2)

        np.testing.assert_allclose(gx, jax.grad(loss)(env["w_xh"]),
                                   rtol=2e-4, atol=1e-6)


# ---------------------------------------------------------------------------
# engines agree with each other and with the NumPy baseline (Listing 2)
# ---------------------------------------------------------------------------

class TestEngines:
    def test_both_engines_match_numpy_listing2(self):
        spec = nn2sql.MLPSpec(30, 4, 8, 3)
        g = nn2sql.build_graph(spec)
        r = np.random.RandomState(3)
        x = jnp.asarray(r.rand(30, 4), jnp.float32)
        y = jnp.asarray(jax.nn.one_hot(r.randint(0, 3, 30), 3))
        w0 = nn2sql.init_weights(spec)
        wn = nn2sql.numpy_train(np.asarray(x), np.asarray(y), 8, 10)
        for kind in ("dense", "relational"):
            wf, _ = nn2sql.train(g, w0, x, y, 10, Engine(kind))
            np.testing.assert_allclose(wf["w_xh"], wn["w_xh"],
                                       rtol=3e-4, atol=3e-5)
            np.testing.assert_allclose(wf["w_ho"], wn["w_ho"],
                                       rtol=3e-4, atol=3e-5)

    def test_relational_equals_dense_forward(self):
        spec = nn2sql.MLPSpec(20, 4, 5, 3)
        g = nn2sql.build_graph(spec)
        r = np.random.RandomState(7)
        x = jnp.asarray(r.rand(20, 4), jnp.float32)
        w = nn2sql.init_weights(spec)
        outs = {}
        for kind in ("dense", "relational"):
            probs = nn2sql.infer(g, Engine(kind))(w, x)
            outs[kind] = probs
        np.testing.assert_allclose(outs["dense"], outs["relational"],
                                   rtol=1e-5, atol=1e-6)


# ---------------------------------------------------------------------------
# recursive CTE semantics (paper §8)
# ---------------------------------------------------------------------------

class TestRecursiveCTE:
    def test_scan_equals_history_final(self):
        base = {"w": jnp.ones((4,))}
        step = lambda c, it: {"w": c["w"] * 0.5}
        fin1, hist = recursive_cte(base, step, 5, materialize_history=True)
        fin2, none = recursive_cte(base, step, 5)
        assert none is None
        np.testing.assert_allclose(fin1["w"], fin2["w"])
        assert hist["w"].shape == (6, 4)          # base + 5 iterations
        np.testing.assert_allclose(hist["w"][-1], fin1["w"])

    def test_history_memory_grows_linearly(self):
        """The paper's observed UNION-ALL growth (§8)."""
        base = {"w": jnp.ones((128, 128))}
        assert history_bytes(base, 10) == 11 * 128 * 128 * 4
