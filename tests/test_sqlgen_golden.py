"""Golden-file snapshots of the SQL transpiler output (tests/golden/*.sql).

Each file pins the byte-exact rendering of one Listing-5…10 query for one
dialect, so dialect refactors produce a reviewable diff instead of silent
drift.  The snapshots double as a cross-session determinism check: auto
name counters must never leak into rendered SQL (the plan-cache contract).

Regenerate after an INTENTIONAL change with:

    REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_sqlgen_golden.py
"""
import os
import pathlib

import pytest

from repro.core import nn2sql, sqlgen
from repro.core import expr as E
from repro.core.autodiff import gradients

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
UPDATE = os.environ.get("REPRO_UPDATE_GOLDEN", "") not in ("", "0")

#: small fixed spec — big enough for every CTE shape, small enough to read
SPEC = nn2sql.MLPSpec(n_rows=4, n_features=4, n_hidden=3, n_classes=2,
                      lr=0.05)


def graph():
    return nn2sql.build_graph(SPEC)


def forward_roots():
    return [graph().a_ho]


def grad_roots():
    g = graph()
    grads = gradients(g.loss, [g.w_xh, g.w_ho])
    return [g.loss, grads[g.w_xh], grads[g.w_ho]]


def multi(roots, dialect):
    """The SQLEngine statement shape: representation-appropriate multi-root
    tail through the representation-dispatching entry point."""
    return sqlgen.to_sql(roots, select=sqlgen.multi_root_tail(roots, dialect),
                         dialect=dialect)


def fused(roots, dialect):
    """The same statement with the elementwise fusion pass on."""
    return sqlgen.to_sql(roots, select=sqlgen.multi_root_tail(roots, dialect),
                         dialect=dialect, fuse=True)


def fused_spooled_plan(roots, dialect):
    """The full evaluation plan (spool steps + main statement) the engine
    runs under substitution CTE semantics, serialised."""
    return sqlgen.render_plan(
        roots, select=sqlgen.multi_root_tail(roots, dialect),
        dialect=dialect, fuse=True, spool=True).to_text()


CASES = {
    # Listing 5: constant matrix via a series cross join
    "listing5_const.sql92":
        lambda: sqlgen.to_sql92([E.const(1.0, (3, 2))], dialect="sql92"),
    "listing5_const.sqlite":
        lambda: sqlgen.to_sql92([E.const(1.0, (3, 2))], dialect="sqlite"),
    "listing5_const.duckdb":
        lambda: sqlgen.to_sql92([E.const(1.0, (3, 2))], dialect="duckdb"),
    # Listing 6/8: the forward inference query m(x)
    "listing6_forward.sql92":
        lambda: sqlgen.to_sql92(forward_roots(), dialect="sql92"),
    "listing6_forward.sqlite":
        lambda: sqlgen.to_sql92(forward_roots(), dialect="sqlite"),
    "listing6_forward.duckdb":
        lambda: sqlgen.to_sql92(forward_roots(), dialect="duckdb"),
    # Algorithm 1 gradients as one multi-root statement (SQLEngine's shape)
    "gradients_multiroot.sqlite":
        lambda: multi(grad_roots(), "sqlite"),
    # Listing 7: the recursive training query (sql92 / duckdb verbatim)
    "listing7_training.sql92":
        lambda: sqlgen.training_query_sql92(graph(), 10, SPEC.lr, "sql92"),
    "listing7_training.duckdb":
        lambda: sqlgen.training_query_sql92(graph(), 10, SPEC.lr, "duckdb"),
    # Listing 7 stepped: INSERT…SELECT (the sqlite-executable step)
    "listing7_step.sqlite":
        lambda: sqlgen.training_step_sql92(graph(), SPEC.lr, "sqlite"),
    # Listing 10: array-typed recursion (paper operators + UDF calls)
    "listing10_training_arrays.sql":
        lambda: sqlgen.training_query_arrays(graph(), 10, SPEC.lr),
    "listing10_training_array_calls.sqlite":
        lambda: sqlgen.training_query_array_calls(graph(), 10, SPEC.lr),
    # Listing 10 style nested forward select
    "listing10_forward_arrays.sql":
        lambda: sqlgen.to_sql_arrays(forward_roots()),
    # the array dialect: one single-row CTE per node over the UDF extension
    "listing6_forward.array":
        lambda: sqlgen.to_sql(forward_roots(), dialect="array"),
    "gradients_multiroot.array":
        lambda: multi(grad_roots(), "array"),
    # the array-dialect training recursion (training_query routes the
    # array representation to the Listing-10 array-calls rendering)
    "listing10_training.array":
        lambda: sqlgen.training_query(graph(), 10, SPEC.lr, "array"),
    # the elementwise-fusion pass: chains of Map/Add/Sub/Hadamard/Scale
    # collapse into single CTE expressions (every dialect), and the
    # substitution-semantics engines additionally spool multi-referenced
    # intermediates into temp-table steps (plan serialisation snapshot)
    "gradients_multiroot.sql92.fused":
        lambda: fused(grad_roots(), "sql92"),
    "gradients_multiroot.sqlite.fused":
        lambda: fused(grad_roots(), "sqlite"),
    "gradients_multiroot.duckdb.fused":
        lambda: fused(grad_roots(), "duckdb"),
    "gradients_multiroot.array.fused":
        lambda: fused(grad_roots(), "array"),
    "gradients_multiroot.sqlite.plan.fused":
        lambda: fused_spooled_plan(grad_roots(), "sqlite"),
    "gradients_multiroot.array.plan.fused":
        lambda: fused_spooled_plan(grad_roots(), "array"),
}


# -- DAG-zoo tier: every new primitive, every dialect -----------------------

def _zoo_leaves():
    return (E.var("zx", (4, 3)), E.var("zidx", (4, 1)),
            E.var("za", (4, 3)), E.var("zb", (4, 3)))


def _zoo_roots(prim: str):
    zx, zidx, za, zb = _zoo_leaves()
    return {
        "rowreduce": [E.row_reduce(zx, "sum", 1), E.row_reduce(zx, "max", 0)],
        "softmax": [E.softmax(zx)],
        "topk": [E.argtopk(zx, 2)],
        "gather": [E.gather(zx, zidx)],
        "scatter": [E.scatter(zx, zidx, 5)],
        "rowshift": [E.row_shift(zx, 1), E.row_shift(zx, -1)],
        "recurrence": [E.recurrence(za, zb),
                       E.recurrence(za, zb, reverse=True)],
    }[prim]


for _prim in ("rowreduce", "softmax", "topk", "gather", "scatter",
              "rowshift", "recurrence"):
    for _dia in ("sql92", "sqlite", "duckdb", "array"):
        CASES[f"zoo_{_prim}.{_dia}"] = (
            lambda p=_prim, d=_dia: multi(_zoo_roots(p), d))


@pytest.mark.parametrize("name", sorted(CASES))
def test_golden(name):
    rendered = CASES[name]()
    path = GOLDEN_DIR / (name + ".sql" if not name.endswith(".sql")
                         else name)
    if UPDATE:
        GOLDEN_DIR.mkdir(exist_ok=True)
        path.write_text(rendered + "\n")
        pytest.skip(f"regenerated {path.name}")
    assert path.exists(), (f"missing golden file {path}; regenerate with "
                           f"REPRO_UPDATE_GOLDEN=1")
    expected = path.read_text().rstrip("\n")
    assert rendered == expected, (
        f"{name} drifted from tests/golden/{path.name} — if intentional, "
        f"regenerate with REPRO_UPDATE_GOLDEN=1 and review the diff")


def test_rendering_is_counter_independent():
    """Golden stability precondition: shifting the global auto-name counter
    between builds must not change any rendered snapshot."""
    before = {name: fn() for name, fn in CASES.items()}
    nn2sql.build_graph.cache_clear()
    for _ in range(11):
        E.const(0.0, (1, 1))
    after = {name: fn() for name, fn in CASES.items()}
    assert before == after
