"""Per-IR-node SQL profiler: a profiled execution mode for ``SQLEngine``.

An ordinary evaluation runs ONE statement (plus spool steps for
multi-referenced subplans), so the engine's own timing can only attribute
to *stages* (ingest / render / execute / decode).  The profiler exploits
the spooled :class:`repro.core.sqlgen.Plan` machinery with the spool
threshold forced to 1: **every** non-leaf IR node materialises as its own
``create temp table _sp_<node>`` step, so each step's wall time is that
node's self-time (children are already tables by the time it runs).  Per
step the profiler records

* **self time** — the create-statement wall clock,
* **rows / bytes** — ``count(*)`` (relational: × 24 bytes/cell-tuple;
  array: ``sum(length(m))`` of the codec),
* **parsed EXPLAIN** — the engine's plan for the node's statement
  (``EXPLAIN QUERY PLAN`` on sqlite, ``EXPLAIN`` on duckdb),
* **per-node dag signature** — the structural hash of the subDAG rooted at
  the node, so topologically identical nodes group across captures,

and emits the per-IR-node cost table both as a text report
(:meth:`ProfileResult.report`) and as a ``profile_nodes`` relation in the
traced database (:func:`write_profile_nodes`) — a flamegraph of the DAG
you can ``GROUP BY kind`` over (:data:`NODE_SQL`).

Fidelity note: the profiled mode materialises every intermediate, so the
engine cannot pipeline producer into consumer — the *sum* of node times
approximates (usually slightly exceeds) the one-statement cost, while the
*distribution* is what the ordinary plan genuinely spends per subplan.
Profiling overhead (row counts, byte probes, EXPLAIN capture) is measured
separately and reported as the ``probe`` stage, so the attribution
accounting (Σ named nodes + stages over wall time, the ≥95% acceptance
bar) stays honest.
"""
from __future__ import annotations

import dataclasses
import time

from ..core import autodiff
from ..core import expr as E
from ..core import sqlgen
from .tracer import tracer_of

#: column layout of the in-database per-node cost relation
PROFILE_NODE_COLUMNS = (
    ("node", "text"), ("kind", "text"), ("shape", "text"),
    ("self_us", "double precision"), ("rows", "integer"),
    ("bytes", "integer"), ("pct", "double precision"),
    ("node_signature", "text"), ("fused_members", "integer"),
    ("sql_head", "text"), ("explain_text", "text"),
)

#: the SQL recipe: cost by IR node kind over the profile relation
NODE_SQL = (
    "select kind, count(*) as n, sum(self_us) / 1e3 as total_ms,\n"
    "       sum(rows) as rows, sum(pct) as pct\n"
    "  from profile_nodes group by kind order by total_ms desc"
)

#: characters of rendered SQL kept per node in the relation/report
_SQL_HEAD = 160


def _node_kind(node: E.Expr, members: int = 1) -> str:
    kind = type(node).__name__
    if isinstance(node, E.Map) and node.fn is not None:
        kind = f"Map[{node.fn.name}]"
    if members > 1:
        kind = f"{kind}+fused({members})"
    return kind


@dataclasses.dataclass
class NodeCost:
    """One IR node's share of a profiled evaluation."""

    node: str                 # render-time CTE/table name
    kind: str                 # IR class (Map nodes carry the fn name)
    shape: str
    self_s: float
    rows: int
    bytes: int
    pct: float = 0.0          # share of total query time (nodes + tail)
    signature: str = ""       # dag_signature of the subDAG at this node
    fused_members: int = 1    # >1: this step rendered a fused region
    sql_head: str = ""
    explain_text: str = ""


@dataclasses.dataclass
class ProfileResult:
    """Outcome of :func:`profile_evaluate`: outputs + the cost table."""

    outputs: list             # decoded root matrices (same as evaluate())
    nodes: list               # NodeCost, hottest first
    stages: dict              # stage name -> seconds (ingest/render/…)
    wall_s: float
    dag_signature: str
    dialect: str
    rows_returned: int

    @property
    def attributed_s(self) -> float:
        return sum(n.self_s for n in self.nodes) + sum(self.stages.values())

    @property
    def attribution(self) -> float:
        """Fraction of profiled wall time on named nodes/stages (the
        acceptance criterion asks ≥ 0.95)."""
        return (self.attributed_s / self.wall_s) if self.wall_s else 0.0

    def by_kind(self) -> dict:
        agg: dict[str, dict] = {}
        for n in self.nodes:
            d = agg.setdefault(n.kind, {"count": 0, "self_s": 0.0,
                                        "rows": 0, "pct": 0.0})
            d["count"] += 1
            d["self_s"] += n.self_s
            d["rows"] += n.rows
            d["pct"] += n.pct
        return dict(sorted(agg.items(), key=lambda kv: -kv[1]["self_s"]))

    def as_dict(self, top: int | None = None) -> dict:
        """JSON-serialisable summary (the benchmark reports embed this)."""
        nodes = self.nodes[:top] if top else self.nodes
        return {
            "dag_signature": self.dag_signature,
            "dialect": self.dialect,
            "wall_s": self.wall_s,
            "attribution": self.attribution,
            "rows_returned": self.rows_returned,
            "stages_s": dict(self.stages),
            "by_kind": self.by_kind(),
            "nodes": [{"node": n.node, "kind": n.kind, "shape": n.shape,
                       "self_ms": n.self_s * 1e3, "rows": n.rows,
                       "bytes": n.bytes, "pct": n.pct} for n in nodes],
        }

    def report(self, top: int | None = None) -> str:
        """Aligned text cost table, hottest node first."""
        nodes = self.nodes[:top] if top else self.nodes
        width = max([len(n.node) for n in nodes] + [4])
        kwidth = max([len(n.kind) for n in nodes] + [4])
        lines = [
            f"profile of {self.dag_signature[:16]} ({self.dialect}): "
            f"{self.wall_s * 1e3:.1f} ms wall, "
            f"{self.attribution:.1%} attributed",
            f"{'node':<{width}} {'kind':<{kwidth}} {'shape':>9} "
            f"{'self_ms':>9} {'rows':>7} {'bytes':>9} {'pct':>6}",
        ]
        for n in nodes:
            lines.append(
                f"{n.node:<{width}} {n.kind:<{kwidth}} {n.shape:>9} "
                f"{n.self_s * 1e3:>9.2f} {n.rows:>7} {n.bytes:>9} "
                f"{n.pct:>5.1f}%")
        if top and len(self.nodes) > top:
            rest = self.nodes[top:]
            lines.append(f"… {len(rest)} more nodes, "
                         f"{sum(n.self_s for n in rest) * 1e3:.2f} ms")
        stages = ", ".join(f"{k} {v * 1e3:.1f} ms"
                           for k, v in sorted(self.stages.items(),
                                              key=lambda kv: -kv[1]))
        lines.append(f"stages: {stages}")
        return "\n".join(lines)


def _step_bytes(adapter, table: str, rows: int, representation: str) -> int:
    if representation == "array":
        got = adapter.execute(
            f"select coalesce(sum(length(m)), 0) from {table}")
        return int(got[0][0] or 0)
    return rows * 24          # one (i, j, v) tuple ≈ 3 × 8-byte values


def profile_evaluate(engine, roots: list, env: dict) -> ProfileResult:
    """Profiled counterpart of ``SQLEngine.evaluate``: same outputs, plus
    the per-IR-node cost table.  Renders the DAG with every non-leaf node
    spooled (``spool_threshold=1``), times each ``create temp table`` step
    individually, and merges row counts, byte probes, per-node EXPLAIN
    output and per-node dag signatures.

    Works with or without an active tracer; when one is collecting, each
    node step additionally emits a ``profile.node`` span (so profiled runs
    show up in Chrome-trace and ``trace_spans`` exports)."""
    adapter = engine.adapter
    dialect = engine.dialect
    rep = engine.representation
    tr = tracer_of(engine, adapter)
    stages: dict[str, float] = {}

    t_wall0 = time.perf_counter()
    with tr.span("profile.evaluate", dialect=dialect.name,
                 representation=rep) as root_sp:
        t0 = time.perf_counter()
        engine._write_env(roots, env)
        stages["ingest"] = time.perf_counter() - t0

        t0 = time.perf_counter()
        order = E.topo_order(*roots)
        nm = sqlgen.assign_names(order)
        node_by_name = {nm[id(n)]: n for n in order}
        regions, _skip = sqlgen.fuse_dag(roots) if engine.fuse \
            else ({}, set())
        plan = sqlgen.render_plan(
            roots, select=sqlgen.multi_root_tail(roots, dialect),
            dialect=dialect, fuse=engine.fuse, spool=True, spool_threshold=1)
        sig = sqlgen.dag_signature(roots)
        stages["render"] = time.perf_counter() - t0

        nodes: list[NodeCost] = []
        probe_s = 0.0
        for table, sql in plan.steps:
            node = node_by_name.get(table[len("_sp_"):])
            members = len(regions[id(node)][0]) \
                if node is not None and id(node) in regions else 1
            kind = _node_kind(node, members) if node is not None else "?"
            shape = "x".join(str(d) for d in node.shape) \
                if node is not None else ""
            with tr.span("profile.node", node=table[4:], kind=kind) as sp:
                t0 = time.perf_counter()
                adapter.execute(f"drop table if exists {table}")
                adapter.execute(sql)
                self_s = time.perf_counter() - t0
            # measurement probes — profiling overhead, booked separately
            t0 = time.perf_counter()
            rows = int(adapter.execute(
                f"select count(*) from {table}")[0][0])
            nbytes = _step_bytes(adapter, table, rows, rep)
            body = sql.split("\n", 1)[1] if "\n" in sql else sql
            try:
                explain = adapter.explain_sql(body)
            except Exception:
                explain = ""
            node_sig = sqlgen.dag_signature([node])[:16] \
                if node is not None else ""
            probe_s += time.perf_counter() - t0
            sp.set(self_us=round(self_s * 1e6, 3), rows=rows)
            nodes.append(NodeCost(
                node=table[4:], kind=kind, shape=shape, self_s=self_s,
                rows=rows, bytes=nbytes, signature=node_sig,
                fused_members=members,
                sql_head=" ".join(body[:_SQL_HEAD].split()),
                explain_text=explain))

        t0 = time.perf_counter()
        rows_out = adapter.execute(plan.sql)
        stages["tail"] = time.perf_counter() - t0
        t0 = time.perf_counter()
        outputs = engine._decode(rows_out, roots)
        stages["decode"] = time.perf_counter() - t0
        stages["probe"] = probe_s

        query_s = sum(n.self_s for n in nodes) + stages["tail"]
        for n in nodes:
            n.pct = (100.0 * n.self_s / query_s) if query_s else 0.0
        nodes.sort(key=lambda n: -n.self_s)
        wall_s = time.perf_counter() - t_wall0
        root_sp.set(nodes=len(nodes), rows_returned=len(rows_out),
                    wall_ms=round(wall_s * 1e3, 3))

    return ProfileResult(outputs=outputs, nodes=nodes, stages=stages,
                         wall_s=wall_s, dag_signature=sig,
                         dialect=dialect.name, rows_returned=len(rows_out))


def profile_value_and_grad(engine, loss, wrt: list, env: dict
                           ) -> ProfileResult:
    """Profile one training-iteration evaluation: the loss plus its
    Algorithm-1 gradients — exactly the multi-root DAG a ``train.in_db``
    step (or ``value_and_grad_fn`` call) executes."""
    grads = autodiff.gradients(loss, wrt)
    return profile_evaluate(engine, [loss] + [grads[v] for v in wrt], env)


def write_profile_nodes(adapter, result: ProfileResult,
                        table: str = "profile_nodes") -> int:
    """Store the per-node cost table as a relation in the profiled
    database (replacing any previous capture); returns the row count.
    Duck-typed like ``write_trace_spans`` — query with :data:`NODE_SQL`
    on the same connection that ran the workload."""
    adapter.create_table(table, PROFILE_NODE_COLUMNS)
    adapter.bulk_insert(table, [
        (n.node, n.kind, n.shape, round(n.self_s * 1e6, 3), n.rows,
         n.bytes, n.pct, n.signature, n.fused_members, n.sql_head,
         n.explain_text)
        for n in result.nodes])
    return len(result.nodes)
