"""The DAG zoo in SQL (paper §8 outlook).

Transpilers from the marquee non-MLP kernels to executable SQL over the
zoo IR tier (``core.expr``: RowReduce / Softmax / ArgTopK / Gather /
Scatter / RowShift / Recurrence):

* :mod:`~repro.db.zoo.moe_to_sql` — top-k gated MoE routing, dispatch and
  combine (``kernels/moe_dispatch.py`` / ``nn/moe.py`` semantics);
* :mod:`~repro.db.zoo.rwkv_to_sql` — the RWKV-6 time-mix recurrence as a
  recursive CTE and the token-shift channel mix
  (``kernels/rwkv6_scan.py`` semantics);
* :mod:`~repro.db.zoo.ssm_to_sql` — state-space models: the SSD/Mamba-2
  scalar-decay matrix-state scan (kron-flattened, chunked execution) and
  the LRU/S5 layer over the matrix-valued ``MatRecurrence``
  (``nn/ssm.py`` semantics).

Every graph is an ordinary expression DAG: Algorithm-1 autodiff, all
four dialects, the plan cache and ``SQLEngine`` apply unchanged.
"""
from .moe_to_sql import (MoESQLConfig, init_moe_params, moe_combine_graph,
                         moe_dispatch_graph, moe_env, moe_env_batched,
                         moe_ffn_graph, moe_ffn_graph_batched, moe_ffn_ref,
                         router_graph, run_moe_in_db)
from .rwkv_to_sql import (kron_index_relations, run_channel_mix_in_db,
                          run_rwkv6_in_db, rwkv6_env, rwkv6_static_env,
                          rwkv6_time_mix_graph, rwkv_channel_mix_graph,
                          rwkv_channel_mix_ref)
from .ssm_to_sql import (lru_env, lru_grads_in_db, lru_layer_graph, lru_ref,
                         run_lru_in_db, run_ssd_in_db, ssd_env,
                         ssd_kron_relations, ssd_ref, ssd_scan_graph,
                         ssd_static_env)

__all__ = [
    "MoESQLConfig", "init_moe_params", "moe_ffn_graph", "moe_env",
    "moe_ffn_graph_batched", "moe_env_batched",
    "moe_ffn_ref", "moe_dispatch_graph", "moe_combine_graph",
    "router_graph", "run_moe_in_db",
    "kron_index_relations", "rwkv6_time_mix_graph", "rwkv6_env",
    "rwkv6_static_env", "run_rwkv6_in_db", "rwkv_channel_mix_graph",
    "rwkv_channel_mix_ref", "run_channel_mix_in_db",
    "ssd_kron_relations", "ssd_scan_graph", "ssd_static_env", "ssd_env",
    "ssd_ref", "run_ssd_in_db", "lru_layer_graph", "lru_env", "lru_ref",
    "run_lru_in_db", "lru_grads_in_db",
]
