"""Architecture assembly: one LM class covering all ten assigned configs.

Families (configs.base.ArchConfig.family):
  dense   — llama-style GQA + SwiGLU (yi, qwen3, qwen2.5, granite)
  vlm     — dense backbone, stub vision frontend feeds embeddings (internvl2)
  audio   — MHA + LayerNorm + GELU over stub EnCodec frame embeds (musicgen)
  moe     — GQA or MLA attention + routed experts (dbrx, deepseek-v2-lite)
  ssm     — RWKV-6 time/channel mix (rwkv6)
  hybrid  — Mamba-2 backbone + shared attention block (zamba2)

Structure is scan-over-layers (stacked params, leading L axis) so HLO size
and compile time are depth-independent — a hard requirement for the 40-cell
multi-pod dry-run. Heterogeneous layers (DeepSeek's leading dense-FFN layer,
Zamba2's shared block every 6 layers) live outside the scanned stack.

Entry points consumed by the launcher:
  init(key) → params
  loss_fn(params, batch) → (scalar loss, metrics)        [train_4k]
  prefill(params, batch) → (last-token logits, cache)    [prefill_32k]
  decode_step(params, batch, cache, pos) → (logits, cache)  [decode shapes]
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from . import layers as L
from . import moe as M
from . import ssm as S


def _moe_cfg(cfg: ArchConfig) -> M.MoEConfig:
    m = cfg.moe
    return M.MoEConfig(
        n_experts=m.n_experts, top_k=m.top_k, d_model=cfg.d_model,
        d_ff=m.d_ff_expert, n_shared=m.n_shared,
        capacity_factor=m.capacity_factor,
        router_softmax=m.router_softmax, impl=m.impl)


class LM:
    def __init__(self, cfg: ArchConfig):
        self.cfg = cfg

    def _scan(self, body, carry, xs):
        """lax.scan over the layer stack, or the same scan fully unrolled
        when ``cfg.scan_layers`` is False. The dry-run unrolls so that
        cost_analysis counts every layer (rolled scan bodies are counted
        once); training examples scan for O(1)-in-depth compile time.
        Using ``lax.scan(unroll=n)`` — not a hand-written Python loop —
        keeps both paths bitwise identical (same slicing and stacking ops,
        same bf16 rounding), which test_scan_and_unrolled_paths_agree
        pins."""
        if self.cfg.scan_layers:
            return jax.lax.scan(body, carry, xs)
        n = jax.tree.leaves(xs)[0].shape[0]
        return jax.lax.scan(body, carry, xs, unroll=max(n, 1))

    def _attend_full(self, q, k, v):
        """Full-sequence attention dispatch (cfg.attn_impl)."""
        cfg = self.cfg
        s = q.shape[2]
        chunk = cfg.attn_chunk or L.auto_chunk(s)
        if cfg.attn_impl == "flash":
            if cfg.flash_impl == "scan":
                return L.attend_flash_scan(q, k, v, chunk=min(chunk, s))
            return L.attend_flash(q, k, v, chunk=min(chunk, s),
                                  bf16_scores=cfg.attn_bf16_scores)
        if cfg.attn_impl == "chunked":
            return L.attend_chunked(q, k, v, chunk=min(chunk, s))
        return L.attend(q, k, v, causal=True)

    # ------------------------------------------------------------------ init
    def _init_block(self, key) -> dict:
        cfg = self.cfg
        d = cfg.d_model
        norm_init = (L.rmsnorm_init if cfg.norm == "rmsnorm"
                     else L.layernorm_init)
        ks = jax.random.split(key, 4)
        p: dict[str, Any] = {"norm1": norm_init(d), "norm2": norm_init(d)}
        if cfg.family in ("dense", "vlm", "audio") or (
                cfg.family == "moe" and cfg.mla is None):
            p["attn"] = L.gqa_init(ks[0], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.d_head, qkv_bias=cfg.qkv_bias,
                                   qk_norm=cfg.qk_norm)
        elif cfg.family == "moe":                        # MLA
            m = cfg.mla
            p["attn"] = L.mla_init(ks[0], d, cfg.n_heads, m.kv_lora,
                                   m.d_nope, m.d_rope, m.d_v)
        if cfg.family in ("dense", "vlm", "audio"):
            p["mlp"] = (L.swiglu_init(ks[1], d, cfg.d_ff)
                        if cfg.mlp == "swiglu"
                        else L.gelu_mlp_init(ks[1], d, cfg.d_ff))
        elif cfg.family == "moe":
            p["moe"] = M.init_moe(ks[1], _moe_cfg(cfg))
        elif cfg.family == "ssm":
            p["tmix"] = S.rwkv6_init(ks[0], d, d // cfg.ssm.head_dim)
            p["cmix"] = S.rwkv6_channel_mix_init(ks[1], d, cfg.d_ff)
        elif cfg.family == "hybrid":
            p.pop("norm2")
            p["mixer"] = S.mamba2_init(ks[0], d, cfg.n_heads_mamba(),
                                       cfg.ssm.d_state, cfg.ssm.d_conv,
                                       cfg.ssm.expand)
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        d, v = cfg.d_model, cfg.vocab
        k_emb, k_layers, k_head, k_extra = jax.random.split(key, 4)
        n_scanned = cfg.n_layers - (cfg.moe.first_k_dense if cfg.moe else 0)
        layer_keys = jax.random.split(k_layers, n_scanned)
        stacked = jax.vmap(self._init_block)(layer_keys)
        params: dict[str, Any] = {
            "embed": L.dense_init(k_emb, (v, d), scale=0.02),
            "layers": stacked,
            "final_norm": (L.rmsnorm_init(d) if cfg.norm == "rmsnorm"
                           else L.layernorm_init(d)),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = L.dense_init(k_head, (d, v))
        if cfg.moe and cfg.moe.first_k_dense:
            def dense_block(key):
                ks = jax.random.split(key, 2)
                norm_init = (L.rmsnorm_init if cfg.norm == "rmsnorm"
                             else L.layernorm_init)
                p = {"norm1": norm_init(d), "norm2": norm_init(d)}
                if cfg.mla is None:
                    p["attn"] = L.gqa_init(ks[0], d, cfg.n_heads,
                                           cfg.n_kv_heads, cfg.d_head)
                else:
                    m = cfg.mla
                    p["attn"] = L.mla_init(ks[0], d, cfg.n_heads, m.kv_lora,
                                           m.d_nope, m.d_rope, m.d_v)
                p["mlp"] = L.swiglu_init(ks[1], d, cfg.moe.d_ff_dense)
                return p
            params["prologue"] = jax.vmap(dense_block)(
                jax.random.split(k_extra, cfg.moe.first_k_dense))
        if cfg.shared_attn_every:
            ks = jax.random.split(k_extra, 4)
            params["shared_block"] = {
                "in_proj": L.dense_init(ks[0], (2 * d, d)),
                "norm1": L.rmsnorm_init(d), "norm2": L.rmsnorm_init(d),
                "attn": L.gqa_init(ks[1], d, cfg.n_heads, cfg.n_kv_heads,
                                   cfg.d_head),
                "mlp": L.swiglu_init(ks[2], d, cfg.d_ff),
            }
        if cfg.param_dtype == "bfloat16":
            # low-precision parameters: matrices in bf16 (collectives and
            # HBM reads halve); f32 masters live in the optimizer state
            params = jax.tree.map(
                lambda a: a.astype(jnp.bfloat16)
                if a.ndim >= 2 and a.dtype == jnp.float32 else a, params)
        return params

    # ------------------------------------------------------------- embedding
    def embed_inputs(self, params, batch) -> jax.Array:
        """tokens (B,S) → (B,S,d), or pass through stub-frontend embeds."""
        if "embeds" in batch:
            x = batch["embeds"].astype(L.COMPUTE_DTYPE)
        else:
            x = params["embed"][batch["tokens"]].astype(L.COMPUTE_DTYPE)
        if self.cfg.family == "audio" and not self.cfg.rope:
            b, s, d = x.shape
            pos = self._sinusoid(s, d, offset=0)
            x = x + pos[None].astype(x.dtype)
        return x

    @staticmethod
    def _sinusoid(s, d, offset=0):
        pos = jnp.arange(offset, offset + s, dtype=jnp.float32)[:, None]
        i = jnp.arange(0, d, 2, dtype=jnp.float32)[None]
        ang = pos / jnp.power(1e4, i / d)
        return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)

    def unembed(self, params, x) -> jax.Array:
        norm = (L.rmsnorm if self.cfg.norm == "rmsnorm" else L.layernorm)
        x = norm(params["final_norm"], x)
        head = (params["embed"].T if self.cfg.tie_embeddings
                else params["lm_head"])
        return jnp.dot(x, head.astype(x.dtype))

    # ------------------------------------------------------ layer-stack body
    def _attn_block(self, p, x, cos, sin, cache=None, pos=None):
        """Returns (out, new_kv) — new_kv is this call's K/V (full-seq) or
        the updated cache slice (decode)."""
        cfg = self.cfg
        if cfg.mla is not None:
            q, k, v, c_kv = L.mla_qkv(p, x, cfg.n_heads, cfg.mla.d_nope,
                                      cfg.mla.d_rope, cfg.mla.d_v, cos, sin)
            o = self._attend_full(q, k, v)
            return L.merge_heads(o) @ L.cdt(p["wo"]), None
        q, k, v = L.gqa_project_qkv(p, x, cfg.n_heads, cfg.n_kv_heads,
                                    cfg.d_head, cos, sin)
        if cache is None:
            o = self._attend_full(q, k, v)
            return L.merge_heads(o) @ L.cdt(p["wo"]), (k, v)
        # decode: write this step's k/v at pos, attend over valid prefix
        ck, cv = cache
        ck = jax.lax.dynamic_update_slice_in_dim(ck, k.astype(ck.dtype),
                                                 pos, axis=2)
        cv = jax.lax.dynamic_update_slice_in_dim(cv, v.astype(cv.dtype),
                                                 pos, axis=2)
        valid = (jnp.arange(ck.shape[2]) <= pos)[None]
        o = L.attend(q, ck.astype(q.dtype), cv.astype(q.dtype), causal=False,
                     kv_len_mask=jnp.broadcast_to(valid, (x.shape[0],
                                                          ck.shape[2])))
        return L.merge_heads(o) @ L.cdt(p["wo"]), (ck, cv)

    def _block(self, p, x, cos, sin, cache=None, pos=None):
        """One transformer block. Returns (x, aux_loss, new_cache)."""
        cfg = self.cfg
        norm = L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm
        aux = jnp.zeros((), jnp.float32)
        if cfg.family == "ssm":
            o, st_t = S.rwkv6_time_mix(
                p["tmix"], norm(p["norm1"], x),
                cfg.d_model // cfg.ssm.head_dim,
                state=None if cache is None else cache[0])
            x = x + o
            o, st_c = S.rwkv6_channel_mix(
                p["cmix"], norm(p["norm2"], x),
                state=None if cache is None else cache[1])
            x = x + o
            return x, aux, (st_t, st_c)
        if cfg.family == "hybrid":
            dims = (cfg.ssm.expand * cfg.d_model, cfg.ssm.head_dim,
                    cfg.ssm.d_state, cfg.ssm.d_conv)
            o, st = S.mamba2_mixer(p["mixer"], norm(p["norm1"], x), dims,
                                   state=cache, chunk=cfg.ssm.chunk,
                                   ssd_impl=cfg.ssd_impl,
                                   compute_dtype=(jnp.bfloat16
                                                  if cfg.ssm_bf16
                                                  else jnp.float32))
            return x + o, aux, st
        attn_out, kv = self._attn_block(p["attn"], norm(p["norm1"], x),
                                        cos, sin, cache=cache, pos=pos)
        x = x + attn_out
        h = norm(p["norm2"], x)
        if "moe" in p:
            b, s, d = h.shape
            out, aux = M.moe_ffn(p["moe"], h.reshape(b * s, d),
                                 _moe_cfg(cfg))
            x = x + out.reshape(b, s, d)
        else:
            x = x + (L.swiglu(p["mlp"], h) if cfg.mlp == "swiglu"
                     else L.gelu_mlp(p["mlp"], h))
        return x, aux, kv

    def _mla_block_decode(self, p, x, cos, sin, cache, pos):
        """Absorbed-matmul MLA decode: attend in the compressed latent space.
        Cache stores (c_kv (B,S,kv_lora), k_rope (B,S,d_rope)) only — the
        MLA memory saving."""
        cfg, m = self.cfg, self.cfg.mla
        b = x.shape[0]
        a = p["attn"]
        q = jnp.dot(x, L.cdt(a["wq"])).reshape(b, 1, cfg.n_heads,
                                               m.d_nope + m.d_rope)
        q = q.transpose(0, 2, 1, 3)
        q_nope, q_rope = q[..., :m.d_nope], q[..., m.d_nope:]
        q_rope = L.apply_rope(q_rope, cos, sin)
        c_kv_t = L.rmsnorm(a["kv_a_norm"], jnp.dot(x, L.cdt(a["wkv_a"])))
        k_rope_t = L.apply_rope(
            jnp.dot(x, L.cdt(a["wk_rope"]))[:, None], cos, sin)[:, 0]
        ckv, krope = cache
        ckv = jax.lax.dynamic_update_slice_in_dim(
            ckv, c_kv_t.astype(ckv.dtype), pos, axis=1)
        krope = jax.lax.dynamic_update_slice_in_dim(
            krope, k_rope_t.astype(krope.dtype), pos, axis=1)
        # absorbed matmul: q_abs (B,H,kv_lora) = q_nope · wk_bᵀ, so the
        # attention product runs in the compressed latent space
        wk_b = a["wk_b"].reshape(m.kv_lora, cfg.n_heads, m.d_nope)
        q_abs = jnp.einsum("bhd,chd->bhc",
                           q_nope[:, :, 0].astype(jnp.float32),
                           wk_b.astype(jnp.float32))
        logits = (jnp.einsum("bhc,bsc->bhs", q_abs,
                             ckv.astype(jnp.float32)) +
                  jnp.einsum("bhr,bsr->bhs",
                             q_rope[:, :, 0].astype(jnp.float32),
                             krope.astype(jnp.float32)))
        logits = logits * ((m.d_nope + m.d_rope) ** -0.5)
        valid = (jnp.arange(ckv.shape[1]) <= pos)[None, None]
        logits = jnp.where(valid, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1)
        lat = jnp.einsum("bhs,bsc->bhc", probs, ckv.astype(jnp.float32))
        wv_b = a["wv_b"].reshape(m.kv_lora, cfg.n_heads, m.d_v)
        o = jnp.einsum("bhc,chd->bhd", lat, wv_b.astype(jnp.float32))
        o = o.reshape(b, 1, cfg.n_heads * m.d_v).astype(x.dtype)
        return jnp.dot(o, L.cdt(a["wo"])), (ckv, krope)

    # ------------------------------------------------------------- forward
    def _scan_blocks(self, params, x, cos, sin):
        cfg = self.cfg

        def body(carry, lp):
            xx, aux = carry
            out, a, _ = self._block(lp, xx, cos, sin)
            return (out, aux + a), None

        if cfg.remat == "full":
            body = jax.checkpoint(body, prevent_cse=False)
        elif cfg.remat == "dots":
            body = jax.checkpoint(
                body, prevent_cse=False,
                policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)
        (x, aux), _ = self._scan(body, (x, jnp.zeros((), jnp.float32)),
                                 params["layers"])
        return x, aux

    def backbone(self, params, batch):
        """Full-sequence forward up to (but excluding) the LM head.
        Returns (hidden (B,S,d), aux_loss)."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        s = x.shape[1]
        cos, sin = (L.rope_table(s, self._rope_dim(), cfg.rope_theta)
                    if cfg.rope else (None, None))
        aux = jnp.zeros((), jnp.float32)
        if "prologue" in params:
            def pro_body(carry, lp):
                xx, a = carry
                out, a2, _ = self._block(lp, xx, cos, sin)
                return (out, a + a2), None
            (x, aux), _ = self._scan(pro_body, (x, aux),
                                     params["prologue"])
        if cfg.shared_attn_every:
            x, aux = self._hybrid_forward(params, x, cos, sin)
        else:
            x, aux2 = self._scan_blocks(params, x, cos, sin)
            aux = aux + aux2
        return x, aux

    def forward(self, params, batch):
        """Full-sequence forward → (logits (B,S,V), aux_loss)."""
        x, aux = self.backbone(params, batch)
        return self.unembed(params, x), aux

    def _hybrid_forward(self, params, x, cos, sin):
        """Zamba2: scan 6-layer Mamba segments, shared attn block between."""
        cfg = self.cfg
        x0 = x
        period = cfg.shared_attn_every
        n_seg = cfg.n_layers // period
        aux = jnp.zeros((), jnp.float32)
        seg_params = jax.tree.map(
            lambda a: a.reshape((n_seg, period) + a.shape[1:]),
            params["layers"])
        for seg in range(n_seg):
            x, _ = self._shared_block(params["shared_block"], x, x0,
                                      cos, sin)
            lp_seg = jax.tree.map(lambda a: a[seg], seg_params)

            def body(carry, lp):
                out, _, _ = self._block(lp, carry, cos, sin)
                return out, None
            body_fn = (jax.checkpoint(body, prevent_cse=False)
                       if cfg.remat != "none" else body)
            x, _ = self._scan(body_fn, x, lp_seg)
        return x, aux

    def _shared_block(self, p, x, x0, cos, sin, cache=None, pos=None):
        """Zamba2 shared block: concat(hidden, embeddings) → 2d→d proj →
        attn + MLP, residual back into the Mamba stream."""
        h = jnp.concatenate([x, x0], axis=-1) @ L.cdt(p["in_proj"])
        a_in = L.rmsnorm(p["norm1"], h)
        attn_out, kv = self._attn_block(p["attn"], a_in, cos, sin,
                                        cache=cache, pos=pos)
        h = h + attn_out
        h = h + L.swiglu(p["mlp"], L.rmsnorm(p["norm2"], h))
        return x + h, kv

    def _rope_dim(self):
        return (self.cfg.mla.d_rope if self.cfg.mla is not None
                else self.cfg.d_head)

    # ------------------------------------------------------------- training
    def loss_fn(self, params, batch):
        if self.cfg.loss_impl == "chunked":
            return self._loss_chunked(params, batch)
        logits, aux = self.forward(params, batch)
        labels = batch["labels"]
        logits = logits.astype(jnp.float32)
        lse = jax.nn.logsumexp(logits, axis=-1)
        if self.cfg.loss_impl == "onehot":
            # gold logit via masked sum — unlike take_along_axis this never
            # gathers across the vocab(model)-sharded dim: GSPMD lowers the
            # reduction to a partial sum + psum (§Perf lever)
            vpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                            logits.ndim - 1)
            gold = jnp.sum(jnp.where(vpos == labels[..., None], logits,
                                     0.0), axis=-1)
        else:
            gold = jnp.take_along_axis(logits, labels[..., None],
                                       axis=-1)[..., 0]
        ce = jnp.mean(lse - gold)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    def _loss_chunked(self, params, batch):
        """Vocab-streamed cross-entropy: the (B,S,V) f32 logits tensor is
        never materialised — logsumexp and the gold logit accumulate over
        vocab chunks (beyond-paper memory optimisation, §Perf)."""
        cfg = self.cfg
        x, aux = self.backbone(params, batch)
        norm = (L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm)
        xn = norm(params["final_norm"], x)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"])
        labels = batch["labels"]
        b, s_, _ = xn.shape
        run_max = jnp.full((b, s_), -1e30, jnp.float32)
        run_se = jnp.zeros((b, s_), jnp.float32)
        gold = jnp.zeros((b, s_), jnp.float32)
        v = cfg.vocab
        chunk = cfg.loss_chunk
        for lo in range(0, v, chunk):
            hi = min(v, lo + chunk)
            lc = jnp.dot(xn, head[:, lo:hi].astype(xn.dtype)
                         ).astype(jnp.float32)
            m_new = jnp.maximum(run_max, lc.max(axis=-1))
            run_se = (run_se * jnp.exp(run_max - m_new)
                      + jnp.exp(lc - m_new[..., None]).sum(axis=-1))
            run_max = m_new
            in_rng = (labels >= lo) & (labels < hi)
            idx = jnp.clip(labels - lo, 0, hi - lo - 1)
            gval = jnp.take_along_axis(lc, idx[..., None], axis=-1)[..., 0]
            gold = gold + jnp.where(in_rng, gval, 0.0)
        ce = jnp.mean(jnp.log(run_se) + run_max - gold)
        return ce + 0.01 * aux, {"ce": ce, "aux": aux}

    # ------------------------------------------------------------- serving
    def init_cache(self, batch_size: int, max_len: int) -> Any:
        cfg = self.cfg
        ls = cfg.n_layers - (cfg.moe.first_k_dense if cfg.moe else 0)
        if cfg.family == "ssm":
            d, nh = cfg.d_model, cfg.d_model // cfg.ssm.head_dim
            n = cfg.ssm.head_dim
            z = lambda *s: jnp.zeros(s, jnp.float32)
            return ((z(ls, batch_size, 1, d),
                     z(ls, batch_size, nh, n, n)),
                    z(ls, batch_size, 1, d))
        if cfg.family == "hybrid":
            di = cfg.ssm.expand * cfg.d_model
            nh = di // cfg.ssm.head_dim
            z = lambda *s: jnp.zeros(s, jnp.float32)
            mamba = (z(cfg.n_layers, batch_size, cfg.ssm.d_conv - 1,
                       di + 2 * cfg.ssm.d_state),
                     z(cfg.n_layers, batch_size, nh, cfg.ssm.d_state,
                       cfg.ssm.head_dim))
            n_seg = cfg.n_layers // cfg.shared_attn_every
            attn = (jnp.zeros((n_seg, batch_size, cfg.n_kv_heads, max_len,
                               cfg.d_head), L.COMPUTE_DTYPE),
                    jnp.zeros((n_seg, batch_size, cfg.n_kv_heads, max_len,
                               cfg.d_head), L.COMPUTE_DTYPE))
            return (mamba, attn)
        if cfg.mla is not None:
            z = lambda *s: jnp.zeros(s, L.COMPUTE_DTYPE)
            lat = (z(ls, batch_size, max_len, cfg.mla.kv_lora),
                   z(ls, batch_size, max_len, cfg.mla.d_rope))
            if cfg.moe and cfg.moe.first_k_dense:
                pro = (z(cfg.moe.first_k_dense, batch_size, max_len,
                         cfg.mla.kv_lora),
                       z(cfg.moe.first_k_dense, batch_size, max_len,
                         cfg.mla.d_rope))
                return (pro, lat)
            return lat
        kv = lambda n: jnp.zeros((n, batch_size, cfg.n_kv_heads, max_len,
                                  cfg.d_head), L.COMPUTE_DTYPE)
        return (kv(ls), kv(ls))

    def decode_step(self, params, batch, cache, pos):
        """One token for every sequence. batch: {"tokens": (B,1)} or
        {"embeds": (B,1,d)}; pos: scalar int32 — current write position."""
        cfg = self.cfg
        x = self.embed_inputs_decode(params, batch, pos)
        cos, sin = (self._rope_at(pos) if cfg.rope else (None, None))
        if cfg.family == "ssm":
            (tm, cm) = cache

            def body(carry, lp_st):
                lp, st_t, st_c = lp_st
                out, _, (nt, nc) = self._block(
                    lp, carry, cos, sin,
                    cache=((st_t[0], st_t[1]), st_c))
                return out, ((nt[0], nt[1]), nc)
            x, new_states = self._scan(
                body, x, (params["layers"], (tm[0], tm[1]), cm))
            new_cache = ((new_states[0][0], new_states[0][1]),
                         new_states[1])
            return self.unembed(params, x), new_cache
        if cfg.family == "hybrid":
            return self._decode_hybrid(params, x, cache, pos, cos, sin)
        if cfg.mla is not None:
            return self._decode_mla(params, x, cache, pos, cos, sin)

        ck, cv = cache

        def body(carry, lp_kv):
            lp, k_l, v_l = lp_kv
            out, _, (nk, nv) = self._block(lp, carry, cos, sin,
                                           cache=(k_l, v_l), pos=pos)
            return out, (nk, nv)
        x, (nk, nv) = self._scan(body, x, (params["layers"], ck, cv))
        return self.unembed(params, x), (nk, nv)

    def _decode_mla(self, params, x, cache, pos, cos, sin):
        cfg = self.cfg
        if cfg.moe and cfg.moe.first_k_dense:
            pro_cache, lat_cache = cache

            def pbody(carry, lp_kv):
                lp, c1, c2 = lp_kv
                out, nc = self._mla_block_and_ffn(lp, carry, cos, sin,
                                                  (c1, c2), pos, dense=True)
                return out, nc
            x, new_pro = self._scan(
                pbody, x, (params["prologue"], pro_cache[0], pro_cache[1]))
        else:
            lat_cache = cache
            new_pro = None

        def body(carry, lp_kv):
            lp, c1, c2 = lp_kv
            out, nc = self._mla_block_and_ffn(lp, carry, cos, sin,
                                              (c1, c2), pos, dense=False)
            return out, nc
        x, new_lat = self._scan(
            body, x, (params["layers"], lat_cache[0], lat_cache[1]))
        new_cache = (new_lat if new_pro is None else (new_pro, new_lat))
        return self.unembed(params, x), new_cache

    def _mla_block_and_ffn(self, p, x, cos, sin, cache, pos, dense):
        cfg = self.cfg
        norm = L.rmsnorm if cfg.norm == "rmsnorm" else L.layernorm
        o, new_cache = self._mla_block_decode(p, norm(p["norm1"], x),
                                              cos, sin, cache, pos)
        x = x + o
        h = norm(p["norm2"], x)
        if dense or "mlp" in p:
            x = x + L.swiglu(p["mlp"], h)
        else:
            b, s, d = h.shape
            out, _ = M.moe_ffn(p["moe"], h.reshape(b * s, d), _moe_cfg(cfg))
            x = x + out.reshape(b, s, d)
        return x, new_cache

    def _decode_hybrid(self, params, x, cache, pos, cos, sin):
        cfg = self.cfg
        (conv_st, h_st), (ak, av) = cache
        x0 = x
        period = cfg.shared_attn_every
        n_seg = cfg.n_layers // period
        seg_params = jax.tree.map(
            lambda a: a.reshape((n_seg, period) + a.shape[1:]),
            params["layers"])
        conv_sg = conv_st.reshape((n_seg, period) + conv_st.shape[1:])
        h_sg = h_st.reshape((n_seg, period) + h_st.shape[1:])
        new_conv, new_h, new_ak, new_av = [], [], [], []
        for seg in range(n_seg):
            x, (nk, nv) = self._shared_block(
                params["shared_block"], x, x0, cos, sin,
                cache=(ak[seg], av[seg]), pos=pos)
            new_ak.append(nk)
            new_av.append(nv)
            lp_seg = jax.tree.map(lambda a: a[seg], seg_params)

            def body(carry, lp_st):
                lp, cst, hst = lp_st
                out, _, (nc, nh) = self._block(lp, carry, cos, sin,
                                               cache=(cst, hst))
                return out, (nc, nh)
            x, (nc, nh) = self._scan(
                body, x, (lp_seg, conv_sg[seg], h_sg[seg]))
            new_conv.append(nc)
            new_h.append(nh)
        new_cache = ((jnp.concatenate(new_conv), jnp.concatenate(new_h)),
                     (jnp.stack(new_ak), jnp.stack(new_av)))
        return self.unembed(params, x), new_cache

    def embed_inputs_decode(self, params, batch, pos):
        if "embeds" in batch:
            x = batch["embeds"].astype(L.COMPUTE_DTYPE)
        else:
            x = params["embed"][batch["tokens"]].astype(L.COMPUTE_DTYPE)
        if self.cfg.family == "audio" and not self.cfg.rope:
            d = x.shape[-1]
            pos_f = jnp.arange(0, d, 2, dtype=jnp.float32)
            ang = pos.astype(jnp.float32) / jnp.power(1e4, pos_f / d)
            pe = jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None]
            x = x + pe.astype(x.dtype)
        return x

    def _rope_at(self, pos):
        dim = self._rope_dim()
        inv = 1.0 / (self.cfg.rope_theta **
                     (jnp.arange(0, dim, 2, dtype=jnp.float32) / dim))
        ang = pos.astype(jnp.float32) * inv
        return jnp.cos(ang)[None], jnp.sin(ang)[None]

    def prefill(self, params, batch):
        """Full-context forward that also materialises the decode cache.
        Returns (last-position logits, cache)."""
        cfg = self.cfg
        x = self.embed_inputs(params, batch)
        b, s, _ = x.shape
        cos, sin = (L.rope_table(s, self._rope_dim(), cfg.rope_theta)
                    if cfg.rope else (None, None))
        if cfg.family == "ssm":
            def body(carry, lp):
                out, _, st = self._block(lp, carry, cos, sin, cache=None)
                return out, st
            x, states = self._scan(body, x, params["layers"])
            # scan stacks each state leaf along L
            cache = ((states[0][0], states[0][1]), states[1])
            return self.unembed(params, x[:, -1:]), cache
        if cfg.family == "hybrid":
            return self._prefill_hybrid(params, x, cos, sin)
        if cfg.mla is not None:
            def body(carry, lp):
                xx = carry
                norm = L.rmsnorm
                h = norm(lp["norm1"], xx)
                q, k, v, c_kv = L.mla_qkv(lp["attn"], h, cfg.n_heads,
                                          cfg.mla.d_nope, cfg.mla.d_rope,
                                          cfg.mla.d_v, cos, sin)
                o = self._attend_full(q, k, v)
                xx = xx + L.merge_heads(o) @ L.cdt(lp["attn"]["wo"])
                hh = norm(lp["norm2"], xx)
                if "moe" in lp:
                    bb, ss, dd = hh.shape
                    out, _ = M.moe_ffn(lp["moe"], hh.reshape(bb * ss, dd),
                                       _moe_cfg(cfg))
                    xx = xx + out.reshape(bb, ss, dd)
                else:
                    xx = xx + L.swiglu(lp["mlp"], hh)
                k_rope = jnp.dot(h, L.cdt(lp["attn"]["wk_rope"]))
                k_rope = L.apply_rope(k_rope[:, None], cos, sin)[:, 0]
                return xx, (c_kv, k_rope)
            if "prologue" in params:
                x, pro_cache = self._scan(body, x, params["prologue"])
            x, lat_cache = self._scan(body, x, params["layers"])
            cache = ((pro_cache, lat_cache) if "prologue" in params
                     else lat_cache)
            return self.unembed(params, x[:, -1:]), cache

        def body(carry, lp):
            out, _, kv = self._block(lp, carry, cos, sin)
            return out, kv
        x, (ks, vs) = self._scan(body, x, params["layers"])
        return self.unembed(params, x[:, -1:]), (ks, vs)

    def _prefill_hybrid(self, params, x, cos, sin):
        cfg = self.cfg
        x0 = x
        period = cfg.shared_attn_every
        n_seg = cfg.n_layers // period
        seg_params = jax.tree.map(
            lambda a: a.reshape((n_seg, period) + a.shape[1:]),
            params["layers"])
        convs, hs, aks, avs = [], [], [], []
        for seg in range(n_seg):
            x, (k, v) = self._shared_block(params["shared_block"], x, x0,
                                           cos, sin)
            aks.append(k)
            avs.append(v)
            lp_seg = jax.tree.map(lambda a: a[seg], seg_params)

            def body(carry, lp):
                out, _, st = self._block(lp, carry, cos, sin)
                return out, st
            x, (nc, nh) = self._scan(body, x, lp_seg)
            convs.append(nc)
            hs.append(nh)
        cache = ((jnp.concatenate(convs), jnp.concatenate(hs)),
                 (jnp.stack(aks), jnp.stack(avs)))
        return self.unembed(params, x[:, -1:]), cache
