"""Data pipeline.

Two worlds, per the paper and the assignment:

1. The paper's datasets — Fisher's Iris (4 features, 3 classes, 150 rows)
   and MNIST-shaped image classification (784 features, 10 classes). No
   files ship with this container, so we generate *synthetic but
   structured* stand-ins (separable Gaussian clusters) with the exact
   shapes the paper benchmarks; the paper's evaluation is runtime/memory,
   not accuracy, so cluster data preserves everything that matters while
   keeping the repo hermetic. The paper replicates Iris to scale the input
   (§6.2) — ``replicate`` does the same.

2. LM token streams for the assigned architectures: a deterministic,
   host-shardable synthetic token source (hash of (step, position)) plus
   the stub frontends (EnCodec frames / ViT patches) required by the
   ``[audio]``/``[vlm]`` entries.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# paper datasets (synthetic, shape-faithful)
# ---------------------------------------------------------------------------

def make_iris(n_rows: int = 150, seed: int = 0):
    """4 features scaled to [0, 1] (paper divides by 10), 3 classes."""
    rng = np.random.RandomState(seed)
    per = n_rows // 3
    centers = rng.rand(3, 4) * 0.6 + 0.2
    xs, ys = [], []
    for c in range(3):
        n = per if c < 2 else n_rows - 2 * per
        xs.append(centers[c] + rng.randn(n, 4) * 0.05)
        ys.append(np.full((n,), c, np.int32))
    x = np.clip(np.concatenate(xs), 0, 1).astype(np.float32)
    y = np.concatenate(ys)
    order = rng.permutation(n_rows)
    return jnp.asarray(x[order]), jnp.asarray(y[order])


def make_mnist_like(n_rows: int = 6000, seed: int = 0):
    """784 features in [0,1], 10 classes (paper uses a 6000-tuple excerpt)."""
    rng = np.random.RandomState(seed)
    protos = rng.rand(10, 784).astype(np.float32)
    y = rng.randint(0, 10, n_rows).astype(np.int32)
    x = protos[y] * 0.5 + rng.rand(n_rows, 784).astype(np.float32) * 0.5
    return jnp.asarray(x), jnp.asarray(y)


def replicate(x, y, factor: int):
    """Paper §6.2: 'we replicate the Iris flower data set … to enable a
    flexible input size'."""
    return (jnp.concatenate([x] * factor, axis=0),
            jnp.concatenate([y] * factor, axis=0))


def one_hot_labels(y, n_classes: int):
    return jax.nn.one_hot(y, n_classes, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# LM token pipeline
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class TokenPipeline:
    """Deterministic synthetic token stream, shardable across hosts:
    batch row r of step s is a pure function of (seed, s, r), so every host
    can materialise exactly its shard — no coordination, and restart after
    failure reproduces the same stream (fault-tolerance requirement)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    n_hosts: int = 1

    def __post_init__(self):
        if self.global_batch % self.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.local_batch = self.global_batch // self.n_hosts

    def batch_at(self, step: int) -> dict:
        row0 = self.host_id * self.local_batch
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        keys = jax.random.split(key, self.global_batch)
        local = keys[row0:row0 + self.local_batch]
        toks = jax.vmap(lambda k: jax.random.randint(
            k, (self.seq_len + 1,), 0, self.vocab))(local)
        return {"tokens": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}


def stub_frontend_batch(kind: str, batch_size: int, seq_len: int,
                        d_model: int, vocab: int, seed: int = 0) -> dict:
    """Precomputed modality-frontend embeddings (assignment: the frontend is
    a STUB; ``input_specs()`` provides frame/patch embeddings)."""
    key = jax.random.PRNGKey(seed)
    k1, k2 = jax.random.split(key)
    embeds = jax.random.normal(k1, (batch_size, seq_len, d_model),
                               jnp.float32)
    labels = jax.random.randint(k2, (batch_size, seq_len), 0, vocab)
    return {"embeds": embeds, "labels": labels.astype(jnp.int32)}
