"""Tracing and metrics for the in-database execution stack.

The engine's evaluation (§7 of the paper is *nothing but* runtime and
memory measurement) was a black box: the plan cache evicted silently, the
MNIST benchmark reported one end-to-end number with no per-stage
attribution.  This module is the instrument panel:

``Tracer``
    Nested spans with a context-manager API.  Spans are thread-safe (a
    thread-local stack keeps nesting per thread; finished spans land in one
    shared list under a lock) and carry free-form attributes set at open
    (``tracer.span("db.execute", sql=head)``) or later (``sp.set(rows=n)``).
    Counters and gauges ride the same object (``inc`` / ``gauge``), as do
    log-spaced-bucket histograms (``observe`` — p50/p95/p99 with no
    per-sample storage) and the ``metric_points`` time-series (``point`` —
    training loss, tokens/s, cache hit rate; see
    :mod:`repro.obs.metrics`).

``NullTracer``
    The zero-cost default.  ``span()`` returns a shared no-op singleton
    whose ``__enter__``/``__exit__``/``set`` do nothing — instrumented code
    runs one attribute lookup and an empty ``with`` per span, so the
    disabled overhead on a warm ``SQLEngine.evaluate`` stays well under the
    2% budget (guarded by ``tests/test_obs.py``).

The *active* tracer is a module global (``current()`` / ``install()`` /
the ``use()`` context manager); engines and adapters additionally accept a
``tracer`` attribute that overrides the global for their own spans
(:func:`tracer_of` resolves it).  Exporters live in
:mod:`repro.obs.export`: Chrome-trace/Perfetto JSON, and the
``trace_spans`` relation written back *into the database being traced*, so
plain SQL answers "which stage dominates a training step".
"""
from __future__ import annotations

import threading
import time
from contextlib import contextmanager

from . import metrics as _metrics


class Span:
    """One timed, attributed interval.  Context manager: entering records
    the start time and the position in the per-thread span stack (parent
    linkage + slash-joined ``path``); exiting records the end time and
    appends the finished span to the tracer's shared list.

    Exit is exception-safe: a raise inside the ``with`` closes the span
    with ``error``/``exc_type`` attributes, and any *abandoned* descendant
    still sitting on the thread-local stack (a span opened inside this one
    whose ``__exit__`` never ran — e.g. a generator torn down mid-flight)
    is force-closed and exported too, so one failed query can never leave
    the stack dirty for the next call."""

    __slots__ = ("tracer", "name", "attrs", "span_id", "parent_id", "path",
                 "t0", "t1", "tid", "_closed")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict):
        self.tracer = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = None
        self.parent_id = None
        self.path = name
        self.t0 = None
        self.t1 = None
        self.tid = None
        self._closed = False

    def set(self, **attrs) -> "Span":
        """Attach attributes to an open (or finished) span."""
        self.attrs.update(attrs)
        return self

    @property
    def duration(self) -> float:
        """Seconds between enter and exit (0.0 while still open)."""
        if self.t0 is None or self.t1 is None:
            return 0.0
        return self.t1 - self.t0

    def __enter__(self) -> "Span":
        tr = self.tracer
        stack = tr._stack()
        self.tid = threading.get_ident()
        with tr._lock:
            tr._next_id += 1
            self.span_id = tr._next_id
        if stack:
            self.parent_id = stack[-1].span_id
            self.path = stack[-1].path + "/" + self.name
        stack.append(self)
        self.t0 = tr._clock()
        return self

    def _close(self, now) -> None:
        """Finalise once: stamp the end time and publish to the shared
        list.  Idempotent — a span force-closed during an enclosing span's
        abnormal unwind must not re-export if its own ``__exit__`` runs
        later out of order."""
        if self._closed:
            return
        self._closed = True
        if self.t1 is None:
            self.t1 = now
        with self.tracer._lock:
            self.tracer.spans.append(self)

    def __exit__(self, exc_type=None, exc=None, tb=None) -> bool:
        if self._closed:
            return False
        tr = self.tracer
        now = tr._clock()
        self.t1 = now
        if exc_type is not None:
            self.attrs.setdefault("error", True)
            self.attrs.setdefault("exc_type", exc_type.__name__)
        stack = tr._stack()
        # pop self — and close any abandoned descendants above it first,
        # marking them so the export shows where the unwind cut through.
        # (If self is not on this thread's stack at all, leave it alone.)
        if any(s is self for s in stack):
            while stack:
                top = stack.pop()
                if top is self:
                    break
                top.attrs.setdefault("abandoned", True)
                if exc_type is not None:
                    top.attrs.setdefault("error", True)
                    top.attrs.setdefault("exc_type", exc_type.__name__)
                top._close(now)
        self._close(now)
        return False

    def __repr__(self) -> str:  # pragma: no cover - debugging aid
        return (f"Span({self.path!r}, {self.duration * 1e3:.3f} ms, "
                f"attrs={self.attrs!r})")


class _NoopSpan:
    """The shared do-nothing span of the disabled tracer."""

    __slots__ = ()
    duration = 0.0
    attrs: dict = {}

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


NOOP_SPAN = _NoopSpan()


class NullTracer:
    """Disabled tracer: every operation is a no-op (the default)."""

    enabled = False
    spans: tuple = ()

    def span(self, name: str, **attrs):
        return NOOP_SPAN

    def inc(self, name: str, n=1) -> None:
        pass

    def gauge(self, name: str, value) -> None:
        pass

    def observe(self, name: str, value) -> None:
        pass

    def point(self, metric: str, value, step=None, **labels) -> None:
        pass

    def current_path(self) -> str:
        return ""

    def clear(self) -> None:
        pass

    @property
    def counters(self) -> dict:
        return {}

    @property
    def gauges(self) -> dict:
        return {}

    @property
    def histograms(self) -> dict:
        return {}

    @property
    def points(self) -> tuple:
        return ()


class Tracer(NullTracer):
    """Collecting tracer.  ``clock`` is injectable for deterministic tests
    (the Chrome-trace golden file pins exporter output byte-for-byte)."""

    enabled = True

    def __init__(self, clock=None):
        self._clock = clock if clock is not None else time.perf_counter
        self._lock = threading.Lock()
        self._local = threading.local()
        self._next_id = 0
        self.spans: list[Span] = []
        self._counters: dict[str, float] = {}
        self._gauges: dict[str, float] = {}
        self._hists: dict[str, _metrics.Histogram] = {}
        self._points: list[_metrics.MetricPoint] = []

    # -- spans --------------------------------------------------------------
    def _stack(self) -> list:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    def span(self, name: str, **attrs) -> Span:
        return Span(self, name, attrs)

    def current_path(self) -> str:
        """Slash-joined path of the innermost open span on this thread."""
        stack = self._stack()
        return stack[-1].path if stack else ""

    # -- counters / gauges --------------------------------------------------
    def inc(self, name: str, n=1) -> None:
        with self._lock:
            self._counters[name] = self._counters.get(name, 0) + n

    def gauge(self, name: str, value) -> None:
        with self._lock:
            self._gauges[name] = value

    @property
    def counters(self) -> dict:
        with self._lock:
            return dict(self._counters)

    @property
    def gauges(self) -> dict:
        with self._lock:
            return dict(self._gauges)

    # -- histograms / time-series -------------------------------------------
    def observe(self, name: str, value) -> None:
        """Feed one sample into the named log-spaced-bucket histogram
        (:class:`repro.obs.metrics.Histogram` — p50/p95/p99 with no
        per-sample storage)."""
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = _metrics.Histogram()
            h.observe(value)

    def point(self, metric: str, value, step=None, **labels) -> None:
        """Append one time-series observation (training loss, tokens/s,
        cache hit rate …).  ``step`` is the caller's iteration counter;
        timestamps use the tracer clock so points align with spans."""
        with self._lock:
            self._points.append(_metrics.MetricPoint(
                seq=len(self._points), t=self._clock(), metric=metric,
                step=step, value=float(value), labels=labels))

    def histogram(self, name: str) -> _metrics.Histogram | None:
        """The live histogram object (None if nothing observed yet)."""
        with self._lock:
            return self._hists.get(name)

    @property
    def histograms(self) -> dict:
        """Snapshot per metric: count/sum/min/max/mean/p50/p90/p95/p99."""
        with self._lock:
            return {k: h.snapshot() for k, h in sorted(self._hists.items())}

    @property
    def points(self) -> list:
        with self._lock:
            return list(self._points)

    # -- lifecycle ----------------------------------------------------------
    def clear(self) -> None:
        """Drop finished spans, counters, gauges, histograms and metric
        points (open spans keep their stack so an enclosing ``with`` still
        closes cleanly)."""
        with self._lock:
            self.spans = []
            self._counters = {}
            self._gauges = {}
            self._hists = {}
            self._points = []


# ---------------------------------------------------------------------------
# the module-level active tracer
# ---------------------------------------------------------------------------

_NULL = NullTracer()
_active: NullTracer = _NULL


def current() -> NullTracer:
    """The active tracer (a :class:`NullTracer` unless one is installed)."""
    return _active


def install(tracer=None) -> NullTracer:
    """Install ``tracer`` as the process-wide active tracer (``None``
    restores the zero-cost no-op default).  Returns the installed tracer."""
    global _active
    _active = tracer if tracer is not None else _NULL
    return _active


@contextmanager
def use(tracer):
    """Scope a tracer: active inside the ``with``, previous one restored
    after — how benchmarks and tests turn tracing on."""
    prev = _active
    install(tracer)
    try:
        yield tracer
    finally:
        install(prev)


def tracer_of(*objs) -> NullTracer:
    """Resolve the tracer for instrumented code: the first non-``None``
    ``tracer`` attribute among ``objs`` (engine- or adapter-level override),
    else the module-level active tracer."""
    for o in objs:
        t = getattr(o, "tracer", None)
        if t is not None:
            return t
    return _active
