with z_xh(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from img as m inner join w_xh as n on m.j = n.i
  group by m.i, n.j
),
a_xh(i, j, v) as (
  select i, j, 1/(1+exp(-v)) as v from z_xh
),
z_ho(i, j, v) as (
  select m.i, n.j, sum(m.v*n.v) as v
  from a_xh as m inner join w_ho as n on m.j = n.i
  group by m.i, n.j
),
a_ho(i, j, v) as (
  select i, j, 1/(1+exp(-v)) as v from z_ho
)
select * from a_ho order by i, j;
