"""Three-term roofline from a compiled dry-run artifact (no hardware).

    compute    = HLO_FLOPs_per_chip / peak_FLOP/s
    memory     = HLO_bytes_per_chip / HBM_bw
    collective = wire_bytes_per_chip / link_bw

TPU v5e constants (assignment): 197 TFLOP/s bf16, 819 GB/s HBM,
~50 GB/s/link ICI.

``cost_analysis()`` on the SPMD-partitioned executable reports *per-device*
FLOPs/bytes (verified in tests). Collective bytes are not in cost_analysis:
we parse ``compiled.as_text()`` (post-partitioning HLO) and sum the result
sizes of all-reduce / all-gather / reduce-scatter / all-to-all /
collective-permute ops, converted to wire bytes with ring-algorithm factors:

    all-reduce      2·(g−1)/g · bytes      (reduce-scatter + all-gather)
    all-gather      (g−1)/g · result
    reduce-scatter  (g−1)   · result       (operand = g · result)
    all-to-all      (g−1)/g · bytes
    collective-permute  1 · bytes

where g = participants per replica group (parsed from the instruction).
"""
from __future__ import annotations

import dataclasses
import re

PEAK_FLOPS = 197e12        # bf16 per chip
HBM_BW = 819e9             # bytes/s per chip
LINK_BW = 50e9             # bytes/s per ICI link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
    "c64": 8, "c128": 16,
}

_COLL_RE = re.compile(
    r"=\s*(?:\()?((?:[a-z0-9]+\[[0-9,]*\][^ ]*(?:,\s*)?)+)(?:\))?\s*"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([^}]*)\}")


def _shape_bytes(shapes_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(shapes_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _group_size(line: str) -> int:
    m = _GROUPS_RE.search(line)
    if m:
        return int(m.group(2))
    m = _GROUPS_LIST_RE.search(line)
    if m:
        return len([x for x in m.group(1).split(",") if x.strip()])
    return 1


@dataclasses.dataclass
class CollectiveStats:
    by_kind: dict
    wire_bytes: float      # per-chip bytes crossing links

    def total_result_bytes(self) -> float:
        return sum(v["bytes"] for v in self.by_kind.values())


def parse_collectives(hlo_text: str) -> CollectiveStats:
    by_kind: dict[str, dict] = {}
    wire = 0.0
    for line in hlo_text.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        shapes_str, kind = m.group(1), m.group(2)
        nbytes = _shape_bytes(shapes_str)
        g = max(_group_size(line), 1)
        if kind == "all-reduce":
            w = 2.0 * (g - 1) / g * nbytes
        elif kind == "all-gather":
            w = (g - 1) / g * nbytes
        elif kind == "reduce-scatter":
            w = float(g - 1) * nbytes
        elif kind == "all-to-all":
            w = (g - 1) / g * nbytes
        else:  # collective-permute
            w = float(nbytes)
        rec = by_kind.setdefault(kind, {"count": 0, "bytes": 0.0,
                                        "wire": 0.0})
        rec["count"] += 1
        rec["bytes"] += nbytes
        rec["wire"] += w
        wire += w
    return CollectiveStats(by_kind=by_kind, wire_bytes=wire)


@dataclasses.dataclass
class Roofline:
    flops: float                  # per chip
    hbm_bytes: float              # per chip
    wire_bytes: float             # per chip
    compute_s: float
    memory_s: float
    collective_s: float
    bottleneck: str
    model_flops: float = 0.0      # 6·N·D (per chip share)
    collectives: dict | None = None

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def useful_ratio(self) -> float:
        return self.model_flops / self.flops if self.flops else 0.0

    @property
    def roofline_fraction(self) -> float:
        """Fraction of the compute roofline achieved at the modelled step
        time: MODEL_FLOPS / (peak · step_time)."""
        return (self.model_flops / PEAK_FLOPS) / self.step_s \
            if self.step_s else 0.0


def cost_analysis(compiled) -> dict:
    """Version-tolerant ``compiled.cost_analysis()``.

    jax ≤0.4.30 returns a dict, jax 0.4.31–0.4.3x returns a ONE-element
    list of dicts (one per executable), newer jax returns a dict again;
    ``None`` shows up for executables without cost info.  Always returns a
    plain (possibly empty) dict."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return dict(ca or {})


def analyze(compiled, model_flops_per_chip: float = 0.0,
            extra_flops: float = 0.0, extra_bytes: float = 0.0) -> Roofline:
    """``extra_*``: analytic corrections for lax.scan bodies that XLA's
    cost analysis counts once instead of ×trip-count (the SSM time scans —
    see EXPERIMENTS.md §Dry-run 'accounting' note)."""
    ca = cost_analysis(compiled)
    flops = float(ca.get("flops", 0.0)) + extra_flops
    hbm = float(ca.get("bytes accessed", 0.0)) + extra_bytes
    colls = parse_collectives(compiled.as_text())
    compute_s = flops / PEAK_FLOPS
    memory_s = hbm / HBM_BW
    coll_s = colls.wire_bytes / LINK_BW
    terms = {"compute": compute_s, "memory": memory_s,
             "collective": coll_s}
    bottleneck = max(terms, key=terms.get)
    return Roofline(flops=flops, hbm_bytes=hbm,
                    wire_bytes=colls.wire_bytes, compute_s=compute_s,
                    memory_s=memory_s, collective_s=coll_s,
                    bottleneck=bottleneck,
                    model_flops=model_flops_per_chip,
                    collectives=colls.by_kind)


def model_flops(cfg, shape, n_chips: int) -> float:
    """MODEL_FLOPS per chip: 6·N·D for training (fwd+bwd), 2·N·D for
    inference, with N = active params (MoE: routed top-k + shared)."""
    n_active = cfg.n_active_params()
    tokens = shape.global_batch * (shape.seq_len if shape.kind == "train"
                                   else (shape.seq_len if shape.kind ==
                                         "prefill" else 1))
    mult = 6.0 if shape.kind == "train" else 2.0
    return mult * n_active * tokens / n_chips
