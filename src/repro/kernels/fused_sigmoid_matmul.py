"""Pallas TPU kernel: fused ``sig(X · W)`` — one layer of the paper's model.

Paper §6.3.2: "No optimisation leads to one separate call to the BLAS library
for each operation, which decreases performance. In the future, we plan the
query optimiser to detect and combine subsequent matrix operations … to be
executed as a single library call."  This kernel is that combined call on
TPU: a blocked MXU matmul whose epilogue applies the sigmoid while the output
tile is still in VMEM, so the activation never round-trips to HBM.

grid = (m/blk_m, n/blk_n, k/blk_k); f32 accumulation in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, w_ref, o_ref, acc_ref, *, n_k_blocks: int):
    kk = pl.program_id(2)

    @pl.when(kk == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(x_ref[...].astype(jnp.float32),
                            w_ref[...].astype(jnp.float32),
                            preferred_element_type=jnp.float32)

    @pl.when(kk == n_k_blocks - 1)
    def _epilogue():
        z = acc_ref[...]
        o_ref[...] = (1.0 / (1.0 + jnp.exp(-z))).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("blk_m", "blk_n", "blk_k", "interpret"))
def fused_sigmoid_matmul(x: jax.Array, w: jax.Array, *, blk_m: int = 128,
                         blk_n: int = 128, blk_k: int = 128,
                         interpret: bool = True) -> jax.Array:
    m, k = x.shape
    k2, n = w.shape
    assert k == k2
    blk_m, blk_n, blk_k = min(blk_m, m), min(blk_n, n), min(blk_k, k)
    if m % blk_m or n % blk_n or k % blk_k:
        raise ValueError(f"dims ({m},{k},{n}) not divisible by blocks "
                         f"({blk_m},{blk_k},{blk_n})")
    n_k_blocks = k // blk_k
    grid = (m // blk_m, n // blk_n, n_k_blocks)
    return pl.pallas_call(
        functools.partial(_kernel, n_k_blocks=n_k_blocks),
        grid=grid,
        in_specs=[
            pl.BlockSpec((blk_m, blk_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((blk_k, blk_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((blk_m, blk_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        scratch_shapes=[pltpu.VMEM((blk_m, blk_n), jnp.float32)],
        interpret=interpret,
    )(x, w)
