#!/usr/bin/env python
"""The CI perf-regression gate over committed ``BENCH_*.json`` baselines.

Two modes:

``--baseline B.json --fresh F.json`` (repeatable)
    Compare explicit report pairs — both directions gated: a slower time
    OR a collapsed speedup beyond the tolerance band fails.  This is the
    mode for like-for-like runs (same problem sizes).

``--smoke``
    Re-run the headline benchmarks at CI-friendly reduced sizes
    (seconds, not minutes) and compare against the committed full-scale
    baselines.  Only ``lower``-is-better metrics (absolute times) are
    gated: the smoke problem is strictly smaller, so a fresh time
    exceeding the full-scale baseline by the tolerance factor means a
    genuine engine-level slowdown, while derived ratios (speedups,
    attribution fractions) legitimately shrink at toy sizes and are
    reported informationally only.

Exit status 0 = no regression; 1 = at least one metric regressed (or a
baseline headline metric disappeared).  ``--out PREFIX`` additionally
writes ``PREFIX.md`` / ``PREFIX.json`` — the delta table CI uploads as an
artifact.

    python benchmarks/check_regression.py --smoke --out perf_delta
    python benchmarks/check_regression.py \
        --baseline BENCH_db_mnist.json --fresh /tmp/fresh_mnist.json
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
if os.path.isdir(os.path.join(ROOT, "src")):
    sys.path.insert(0, os.path.join(ROOT, "src"))

from repro.obs import regress  # noqa: E402

#: smoke mode: (committed baseline at repo root, benchmark argv tail)
SMOKE = (
    ("BENCH_db_mnist.json",
     ["benchmarks/bench_mnist_db.py", "--rows", "8", "--hidden", "32",
      "--iters", "1", "--timing-iters", "1", "--curve", "1,2"]),
    ("BENCH_array_vs_rel.json",
     ["benchmarks/bench_array_vs_relational.py", "--rows", "8",
      "--features", "64", "--hidden", "16", "--tokens", "8", "--seq", "6",
      "--timing-iters", "1"]),
    ("BENCH_serving_db.json",
     ["benchmarks/bench_serving_db.py", "--counts", "1,2,8",
      "--requests", "24", "--clients", "4", "--timing-iters", "2",
      "--min-speedup", "2.0"]),
    ("BENCH_shard_db.json",
     ["benchmarks/bench_shard_db.py", "--rows", "32", "--iters", "2",
      "--shards", "1,2", "--repeats", "1"]),
)


def _report_backend(report: dict) -> str | None:
    """The engine a report actually ran on: the ``fallback_backend``
    stamp when the requested backend was unavailable, else the config."""
    fb = report.get("metrics", {}).get("fallback_backend")
    if isinstance(fb, str):
        return fb
    return report.get("config", {}).get("backend")


def _load(path: str) -> dict:
    with open(path) as f:
        return json.load(f)


def _smoke_run(script_args: list[str], out_path: str) -> dict:
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(ROOT, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    cmd = [sys.executable, script_args[0], *script_args[1:],
           "--out", out_path]
    subprocess.run(cmd, cwd=ROOT, env=env, check=True,
                   stdout=subprocess.DEVNULL)
    return _load(out_path)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--baseline", action="append", default=[],
                    help="committed baseline report (pairs with --fresh)")
    ap.add_argument("--fresh", action="append", default=[],
                    help="freshly produced report to judge")
    ap.add_argument("--smoke", action="store_true",
                    help="re-run headline benchmarks at reduced size and "
                         "gate absolute times against committed baselines")
    ap.add_argument("--tolerance", type=float, default=1.5,
                    help="regression band: fail beyond this factor "
                         "(default 1.5x)")
    ap.add_argument("--out", default=None,
                    help="write PREFIX.md / PREFIX.json delta artifacts")
    args = ap.parse_args(argv)
    if len(args.baseline) != len(args.fresh):
        ap.error("--baseline and --fresh must pair up")
    if not args.smoke and not args.baseline:
        ap.error("nothing to do: pass --smoke and/or --baseline/--fresh")

    sections = []            # (title, deltas)
    for b_path, f_path in zip(args.baseline, args.fresh):
        base, fresh = _load(b_path), _load(f_path)
        bb, fb = _report_backend(base), _report_backend(fresh)
        title = (f"{os.path.basename(b_path)} vs "
                 f"{os.path.basename(f_path)}")
        if bb and fb and bb != fb:
            # a fallback run against a baseline from a different engine
            # measures the backend swap, not a regression — report the
            # deltas but gate nothing
            deltas = regress.compare(base, fresh, tolerance=args.tolerance,
                                     gate_directions=(),
                                     fail_on_missing=False)
            title += f" (backends differ: {bb} vs {fb} — not gated)"
        else:
            deltas = regress.compare(base, fresh,
                                     tolerance=args.tolerance)
        sections.append((title, deltas))

    if args.smoke:
        with tempfile.TemporaryDirectory() as tmp:
            for base_name, script_args in SMOKE:
                base_path = os.path.join(ROOT, base_name)
                base = _load(base_path)
                fresh = _smoke_run(
                    script_args,
                    os.path.join(tmp, "fresh_" + base_name))
                bb, fb = _report_backend(base), _report_backend(fresh)
                title = f"{base_name} (smoke, times only)"
                if bb and fb and bb != fb:
                    gate = ()
                    title = (f"{base_name} (smoke, backends differ: "
                             f"{bb} vs {fb} — not gated)")
                else:
                    gate = ("lower",)
                deltas = regress.compare(
                    base, fresh, tolerance=args.tolerance,
                    gate_directions=gate, fail_on_missing=False)
                sections.append((title, deltas))

    failed = False
    tables = []
    for title, deltas in sections:
        tables.append(regress.delta_table(deltas, title=title))
        failed = failed or any(d.failed for d in deltas)
    report = "\n\n".join(tables)
    print(report)
    verdict = "REGRESSION DETECTED" if failed else "no regressions"
    print(f"\nperf gate: {verdict} "
          f"(tolerance {args.tolerance:g}x, {len(sections)} comparisons)")

    if args.out:
        with open(args.out + ".md", "w") as f:
            f.write("# Perf-regression gate\n\n```\n" + report
                    + f"\n```\n\nverdict: **{verdict}**\n")
        with open(args.out + ".json", "w") as f:
            json.dump({
                "failed": failed,
                "tolerance": args.tolerance,
                "sections": [{
                    "title": title,
                    "deltas": [vars(d) for d in deltas],
                } for title, deltas in sections],
            }, f, indent=2, sort_keys=True)
    return 1 if failed else 0


if __name__ == "__main__":
    sys.exit(main())
