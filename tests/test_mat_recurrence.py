"""The matrix-valued recurrence tier: MatRecurrence + StepOuter.

Differential contract across every backend:

* forward, all four (reverse × transposed) variants: dense ≡ rel_engine ≡
  relational SQL (sqlite) ≡ array SQL ≡ a per-step numpy oracle;
* the sql92 rendering of the scan is genuinely executable (the unrolled
  chain needs no series/UDFs — it runs verbatim on a bare connection);
* Algorithm-1 gradients (the transposed-coefficient adjoint scan +
  StepOuter ∂A stacks) ≡ jax.grad of the dense evaluation, and the
  gradient DAGs *execute* in both representations;
* diagonal blocks reproduce the elementwise Recurrence (the LRU/S5
  diagonal fast path IS the existing scan);
* static attributes (reverse, transposed) key distinct plans;
* duckdb (CI extras job): both representations execute on a real duckdb
  connection — the array scan with NO Python aggregate.
"""
import sqlite3

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core import Engine, dense, sqlgen
from repro.core import expr as E
from repro.core.autodiff import gradients
from repro.db import HAVE_DUCKDB
from repro.db.sql_engine import SQLEngine

TOL = 1e-4
RNG = np.random.RandomState(11)

T, D = 5, 3
AV = (RNG.randn(T * D, D) * 0.4).astype(np.float32)   # stacked blocks
BV = RNG.randn(T, D).astype(np.float32)
ENV = {"a": AV, "b": BV}
JENV = {k: jnp.asarray(v) for k, v in ENV.items()}

VARIANTS = [(False, False), (False, True), (True, False), (True, True)]


def leaves():
    return E.var("a", (T * D, D)), E.var("b", (T, D))


def ref_scan(av, bv, reverse=False, transposed=False) -> np.ndarray:
    """Per-step numpy oracle: s_t = s_{t∓1} · A_t(ᵀ) + b_t."""
    t_rows, d = np.asarray(bv).shape
    blocks = np.asarray(av, np.float64).reshape(t_rows, d, d)
    s = np.zeros(d)
    out = np.zeros((t_rows, d))
    order = range(t_rows) if not reverse else range(t_rows - 1, -1, -1)
    for t in order:
        blk = blocks[t].T if transposed else blocks[t]
        s = s @ blk + np.asarray(bv, np.float64)[t]
        out[t] = s
    return out


class TestForward:
    @pytest.mark.parametrize("reverse,transposed", VARIANTS)
    def test_dense_matches_oracle(self, reverse, transposed):
        a, b = leaves()
        out, = dense.evaluate(
            [E.mat_recurrence(a, b, reverse=reverse, transposed=transposed)],
            JENV)
        np.testing.assert_allclose(np.asarray(out),
                                   ref_scan(AV, BV, reverse, transposed),
                                   atol=1e-5)

    @pytest.mark.parametrize("reverse,transposed", VARIANTS)
    def test_all_engines_agree(self, reverse, transposed):
        a, b = leaves()
        roots = [E.mat_recurrence(a, b, reverse=reverse,
                                  transposed=transposed)]
        ref = ref_scan(AV, BV, reverse, transposed)
        d_out, = Engine("dense").eval_fn(roots)(JENV)
        r_out, = Engine("relational").eval_fn(roots)(JENV)
        np.testing.assert_allclose(np.asarray(d_out), ref, atol=1e-5)
        np.testing.assert_allclose(np.asarray(r_out), ref, atol=1e-5)
        with SQLEngine(plan_cache_=False) as eng:
            s_out, = eng.evaluate(roots, ENV)
        np.testing.assert_allclose(s_out, ref, atol=1e-5)
        with SQLEngine(dialect="array", plan_cache_=False) as eng:
            ar_out, = eng.evaluate(roots, ENV)
        np.testing.assert_allclose(ar_out, ref, atol=1e-5)

    def test_sql92_rendering_executes_verbatim(self):
        """The scan CTE references only the leaf tables — no series, no
        UDFs — so the golden sql92 dialect text runs on a bare connection
        (the paper's portable-SQL claim at scan granularity), and it is a
        genuine recursive CTE (one tuple per step carrying the state
        row)."""
        a, b = leaves()
        sql = sqlgen.to_sql([E.mat_recurrence(a, b, name="ms")],
                            dialect="sql92")
        assert sql.startswith("with recursive")
        assert f"ms_scan(t, {', '.join(f's{j}' for j in range(1, D + 1))})" \
            in sql
        conn = sqlite3.connect(":memory:")
        for nm, m in (("a", AV), ("b", BV)):
            conn.execute(f"create table {nm} (i integer, j integer, v real)")
            conn.executemany(
                f"insert into {nm} values (?,?,?)",
                [(i + 1, j + 1, float(m[i, j]))
                 for i in range(m.shape[0]) for j in range(m.shape[1])])
        out = np.zeros((T, D))
        for i, j, v in conn.execute(sql.rstrip(";")).fetchall():
            out[int(i) - 1, int(j) - 1] = v
        np.testing.assert_allclose(out, ref_scan(AV, BV), atol=1e-5)

    def test_diagonal_blocks_reproduce_elementwise_recurrence(self):
        """LRU/S5 diagonal fast path: a stack of diagonal blocks computes
        exactly the elementwise Recurrence over the diagonals."""
        diag = (RNG.rand(T, D) * 0.8).astype(np.float32)
        stack = np.zeros((T * D, D), np.float32)
        for t in range(T):
            stack[t * D:(t + 1) * D] = np.diag(diag[t])
        a, b = leaves()
        mat, = dense.evaluate([E.mat_recurrence(a, b)],
                              {"a": jnp.asarray(stack), "b": JENV["b"]})
        elem, = dense.evaluate(
            [E.recurrence(E.var("d", (T, D)), E.var("b", (T, D)))],
            {"d": jnp.asarray(diag), "b": JENV["b"]})
        np.testing.assert_allclose(np.asarray(mat), np.asarray(elem),
                                   atol=1e-5)

    def test_step_outer_all_engines(self):
        x = E.var("b", (T, D))            # reuse the (T, D) leaves
        y = E.var("b2", (T, 2))
        yv = RNG.randn(T, 2).astype(np.float32)
        env = {"b": BV, "b2": yv}
        ref = (BV.astype(np.float64)[:, :, None]
               * yv.astype(np.float64)[:, None, :]).reshape(T * D, 2)
        out, = dense.evaluate([E.step_outer(x, y)],
                              {k: jnp.asarray(v) for k, v in env.items()})
        np.testing.assert_allclose(np.asarray(out), ref, atol=1e-5)
        with SQLEngine(plan_cache_=False) as eng:
            s, = eng.evaluate([E.step_outer(x, y)], env)
        np.testing.assert_allclose(s, ref, atol=1e-5)
        with SQLEngine(dialect="array", plan_cache_=False) as eng:
            ar, = eng.evaluate([E.step_outer(x, y)], env)
        np.testing.assert_allclose(ar, ref, atol=1e-5)


class TestAutodiff:
    @pytest.mark.parametrize("reverse,transposed", VARIANTS)
    def test_gradients_match_jax_oracle(self, reverse, transposed):
        a, b = leaves()
        loss = E.square(E.mat_recurrence(a, b, reverse=reverse,
                                         transposed=transposed))
        g = gradients(loss, [a, b])
        ours = [np.asarray(o)
                for o in dense.evaluate([g[a], g[b]], JENV)]

        def f(av, bv):
            out, = dense.evaluate([loss], {"a": av, "b": bv})
            return jnp.sum(out)

        oa, ob = jax.grad(f, argnums=(0, 1))(JENV["a"], JENV["b"])
        np.testing.assert_allclose(ours[0], np.asarray(oa), atol=TOL)
        np.testing.assert_allclose(ours[1], np.asarray(ob), atol=TOL)

    @pytest.mark.parametrize("reverse,transposed", VARIANTS)
    def test_gradient_dags_execute_in_both_representations(self, reverse,
                                                           transposed):
        a, b = leaves()
        loss = E.square(E.mat_recurrence(a, b, reverse=reverse,
                                         transposed=transposed))
        g = gradients(loss, [a, b])
        roots = [loss, g[a], g[b]]
        ref = [np.asarray(o) for o in dense.evaluate(roots, JENV)]
        with SQLEngine(plan_cache_=False) as eng:
            got_rel = eng.evaluate(roots, ENV)
        with SQLEngine(dialect="array", plan_cache_=False) as eng:
            got_arr = eng.evaluate(roots, ENV)
        for r, s, ar in zip(ref, got_rel, got_arr):
            np.testing.assert_allclose(s, r, atol=TOL)
            np.testing.assert_allclose(ar, r, atol=TOL)

    def test_composes_with_surrounding_graph(self):
        """The scan inside a larger DAG (projections either side), grads
        flowing to every leaf."""
        a, b = leaves()
        w = E.var("w", (D, 2))
        wv = RNG.randn(D, 2).astype(np.float32) * 0.5
        env = dict(ENV, w=wv)
        jenv = {k: jnp.asarray(v) for k, v in env.items()}
        loss = E.square(E.matmul(E.mat_recurrence(a, b), w))
        g = gradients(loss, [a, b, w])

        def f(av, bv, wv_):
            out, = dense.evaluate([loss], {"a": av, "b": bv, "w": wv_})
            return jnp.sum(out)

        oracle = jax.grad(f, argnums=(0, 1, 2))(
            jenv["a"], jenv["b"], jenv["w"])
        roots = [g[a], g[b], g[w]]
        ours = dense.evaluate(roots, jenv)
        for o, j in zip(ours, oracle):
            np.testing.assert_allclose(np.asarray(o), np.asarray(j),
                                       atol=TOL)
        with SQLEngine(plan_cache_=False) as eng:
            got = eng.evaluate(roots, env)
        for s, j in zip(got, oracle):
            np.testing.assert_allclose(s, np.asarray(j), atol=TOL)


class TestConstructorsAndPlans:
    def test_shape_validation(self):
        a, b = leaves()
        with pytest.raises(ValueError):
            E.mat_recurrence(E.var("bad", (T * D + 1, D)), b)
        with pytest.raises(ValueError):
            E.mat_recurrence(E.var("bad", (T * D, D + 1)), b)
        with pytest.raises(ValueError):
            E.step_outer(E.var("x", (T, D)), E.var("y", (T + 1, D)))
        assert E.mat_recurrence(a, b).shape == (T, D)
        assert E.step_outer(b, b).shape == (T * D, D)

    def test_static_attributes_key_distinct_plans(self):
        a, b = leaves()
        sig = lambda **kw: sqlgen.dag_signature([E.mat_recurrence(a, b, **kw)])
        assert sig() != sig(reverse=True)
        assert sig() != sig(transposed=True)
        assert sig(reverse=True) != sig(reverse=True, transposed=True)
        assert sig() == sig()                     # twins still share

    def test_auto_named_scan_renders_deterministically(self):
        """Session-portability: two structural twins render to identical
        SQL despite different auto-name counter states."""
        def build():
            a, b = leaves()
            return [E.mat_recurrence(a, b)]
        r1 = build()
        for _ in range(5):
            E.const(0.0, (1, 1))                  # shift the counter
        r2 = build()
        for d in ("sqlite", "array"):
            assert sqlgen.to_sql(r1, dialect=d) == sqlgen.to_sql(r2, dialect=d)


@pytest.mark.skipif(not HAVE_DUCKDB, reason="duckdb not installed")
class TestDuckDB:
    """The CI duckdb-extras differential: scans in both representations on
    a real duckdb connection — the array Recurrence/MatRecurrence with no
    Python aggregate (native group_concat + the mrowcat scalar)."""

    @pytest.mark.parametrize("dialect", [None, "array"])
    def test_mat_recurrence_fwd_bwd(self, dialect):
        a, b = leaves()
        loss = E.square(E.mat_recurrence(a, b))
        g = gradients(loss, [a, b])
        roots = [loss, g[a], g[b]]
        ref = [np.asarray(o) for o in dense.evaluate(roots, JENV)]
        with SQLEngine(backend="duckdb", dialect=dialect,
                       plan_cache_=False) as eng:
            got = eng.evaluate(roots, ENV)
        for r, s in zip(ref, got):
            np.testing.assert_allclose(s, r, atol=TOL)

    def test_elementwise_recurrence_array_dialect(self):
        """The previously sqlite-only array-dialect scan (ROADMAP item):
        Recurrence through the array dialect on duckdb."""
        a = E.var("a", (T, D))
        b = E.var("b", (T, D))
        env = {"a": (RNG.rand(T, D) * 0.5).astype(np.float32), "b": BV}
        roots = [E.recurrence(a, b), E.recurrence(a, b, reverse=True)]
        ref = [np.asarray(o) for o in dense.evaluate(
            roots, {k: jnp.asarray(v) for k, v in env.items()})]
        with SQLEngine(backend="duckdb", dialect="array",
                       plan_cache_=False) as eng:
            got = eng.evaluate(roots, env)
        for r, s in zip(ref, got):
            np.testing.assert_allclose(s, r, atol=TOL)
