"""Quickstart: the paper end-to-end in 60 seconds.

Transforms Iris into the relational representation (§4.1), trains the
2-layer sigmoid network by gradient descent inside a recursive CTE (§4.2)
on BOTH execution engines, evaluates prediction accuracy (§4.3), and
prints the actual SQL-92 + SQL/Array queries the transpiler generates —
Listings 7 and 10 of the paper, derived automatically by Algorithm 1.

    PYTHONPATH=src python examples/quickstart.py
"""
import time

from repro.core import Engine, nn2sql, sqlgen
from repro.core.relational import one_hot_dense
from repro.data import make_iris

ITERS = 300
HIDDEN = 20


def main():
    x, y = make_iris()
    y_oh = one_hot_dense(y, 3).to_dense()        # Listing 5: outer join
    spec = nn2sql.MLPSpec(n_rows=150, n_features=4, n_hidden=HIDDEN,
                          n_classes=3, lr=0.05)
    graph = nn2sql.build_graph(spec)
    w0 = nn2sql.init_weights(spec)

    for kind in ("dense", "relational"):
        eng = Engine(kind)
        t0 = time.perf_counter()
        wf, _ = nn2sql.train(graph, w0, x, y_oh, ITERS, eng)
        dt = time.perf_counter() - t0
        probs = nn2sql.infer(graph, eng)(wf, x)
        acc = float(nn2sql.accuracy(probs, y))
        rep = "array data type (Section 5)" if kind == "dense" \
            else "relational / SQL-92 (Section 4)"
        print(f"[{rep}] {ITERS} iterations in {dt:.2f}s — "
              f"accuracy {acc:.3f}")

    print("\n--- generated SQL-92 training query (Listing 7) "
          "[first 40 lines] ---")
    sql = sqlgen.training_query_sql92(graph, ITERS, spec.lr)
    print("\n".join(sql.splitlines()[:40]))
    print("  ...")
    print("\n--- generated SQL+Arrays training query (Listing 10) "
          "[first 15 lines] ---")
    print("\n".join(sqlgen.training_query_arrays(
        graph, ITERS, spec.lr).splitlines()[:15]))
    print("  ...")


if __name__ == "__main__":
    main()
