"""Reverse-mode automatic differentiation over matrix expressions.

This is the paper's Algorithm 1 verbatim::

    function DERIVE(Z, seed)
      if   Z = X + Y  then DERIVE(X, seed); DERIVE(Y, seed)
      elif Z = X ∘ Y  then DERIVE(X, seed ∘ y); DERIVE(Y, seed ∘ x)
      elif Z = X · Y  then DERIVE(X, seed · yᵀ); DERIVE(Y, xᵀ · seed)
      elif Z = f(X)   then DERIVE(X, seed ∘ f'(x))
      else  ∂/∂Z ← ∂/∂Z + seed

Lower-case letters (``x``, ``y``) are the *cached forward values*: in the
output gradient graph they appear as references to forward-pass nodes, which
the engines evaluate once and memoise — each shared node is one CTE, and the
derivative CTEs reuse it, exactly as Listing 7 reuses ``a_xh``/``a_ho``.

``f'(x)`` needs access to both the input value and the cached output value
(sigmoid: ``out ∘ (1-out)``); we introduce a ``MapDeriv`` marker node that the
engines evaluate from the memoised forward values.
"""
from __future__ import annotations

import dataclasses

from . import expr as E


@dataclasses.dataclass(frozen=True, eq=False)
class MapDeriv(E.Expr):
    """f'(x) evaluated from the cached forward values of ``x`` (and ``f(x)``)."""

    fn: E.MapFn = None
    x: E.Expr = None          # the input of the Map node
    fx: E.Expr = None         # the Map node itself (cached output)

    def children(self):
        # Both are forward nodes; listing them keeps topo_order correct.
        return (self.x, self.fx)


@dataclasses.dataclass(frozen=True, eq=False)
class ReduceDeriv(E.Expr):
    """The argmax indicator of a cached max-RowReduce: 1 where ``x`` equals
    its row's (axis=1) / column's (axis=0) cached maximum, else 0.  Ties
    all receive 1 (the subgradient convention every engine and the SQL
    lowering share — what matters for the differential tests is that the
    three backends agree)."""

    x: E.Expr = None          # the input of the RowReduce node
    red: E.Expr = None        # the RowReduce node itself (cached max)
    axis: int = 1

    def children(self):
        return (self.x, self.red)


def _expand(reduced: E.Expr, axis: int, shape: tuple[int, int]) -> E.Expr:
    """Broadcast a keepdims reduce back to ``shape`` with a ones matmul:
    (r, 1) · 1_{1×c} for axis=1, 1_{r×1} · (1, c) for axis=0 — no new node
    type needed, the constant ones matrix is Listing 5's series cross
    join."""
    if axis == 1:
        return E.matmul(reduced, E.const(1.0, (1, shape[1])))
    return E.matmul(E.const(1.0, (shape[0], 1)), reduced)


def derive(z: E.Expr, seed: E.Expr, grads: dict[E.Var, E.Expr] | None = None
           ) -> dict[E.Var, E.Expr]:
    """Algorithm 1. Returns {leaf Var: gradient expression}."""
    if grads is None:
        grads = {}

    if isinstance(z, E.Add):
        derive(z.x, seed, grads)
        derive(z.y, seed, grads)
    elif isinstance(z, E.Sub):
        derive(z.x, seed, grads)
        derive(z.y, E.scale(-1.0, seed), grads)
    elif isinstance(z, E.Hadamard):
        derive(z.x, E.hadamard(seed, z.y), grads)
        derive(z.y, E.hadamard(seed, z.x), grads)
    elif isinstance(z, E.MatMul):
        derive(z.x, E.matmul(seed, E.transpose(z.y)), grads)
        derive(z.y, E.matmul(E.transpose(z.x), seed), grads)
    elif isinstance(z, E.Map):
        fprime = MapDeriv(name=f"d{z.fn.name}_{z.name}", shape=z.shape,
                          fn=z.fn, x=z.x, fx=z)
        if E.is_auto_named(z):  # name embeds z's counter suffix
            E.mark_auto_named(fprime)
        derive(z.x, E.hadamard(seed, fprime), grads)
    elif isinstance(z, E.Scale):
        derive(z.x, E.scale(z.c, seed), grads)
    elif isinstance(z, E.Transpose):
        derive(z.x, E.transpose(seed), grads)
    elif isinstance(z, E.RowReduce):
        bseed = _expand(seed, z.axis, z.x.shape)      # broadcast back
        if z.kind == "sum":
            derive(z.x, bseed, grads)
        else:                                          # max: argmax indicator
            ind = ReduceDeriv(name=f"dmax_{z.name}", shape=z.x.shape,
                              x=z.x, red=z, axis=z.axis)
            if E.is_auto_named(z):  # name embeds z's counter suffix
                E.mark_auto_named(ind)
            derive(z.x, E.hadamard(bseed, ind), grads)
    elif isinstance(z, E.Softmax):
        # d/dx softmax(x) @ g = s ∘ (g − rowsum(g ∘ s)·1ᵀ), s cached
        gs = E.hadamard(seed, z)
        rowsum = E.row_reduce(gs, "sum", axis=1)
        derive(z.x, E.hadamard(z, E.sub(seed, _expand(rowsum, 1, z.shape))),
               grads)
    elif isinstance(z, E.ArgTopK):
        pass  # selection mask: zero gradient everywhere (like Const)
    elif isinstance(z, E.Gather):
        derive(z.x, E.scatter(seed, z.idx, z.x.shape[0]), grads)
    elif isinstance(z, E.Scatter):
        derive(z.x, E.gather(seed, z.idx), grads)
    elif isinstance(z, E.RowShift):
        derive(z.x, E.row_shift(seed, -z.offset), grads)
    elif isinstance(z, E.Recurrence):
        # The adjoint of an affine scan is the same scan run the other way:
        #   λ_t = g_t + a_{t+1} ∘ λ_{t+1}  (forward z; mirrored if reverse)
        # then ∂b = λ and ∂a_t = λ_t ∘ s_{t∓1} with s the cached output.
        step = -1 if not z.reverse else 1
        a_next = E.row_shift(z.a, step)       # a_next[t] = a[t+1] (fwd case)
        lam = E.recurrence(a_next, seed, reverse=not z.reverse)
        s_prev = E.row_shift(z, -step)        # s_prev[t] = s[t-1] (fwd case)
        derive(z.b, lam, grads)
        derive(z.a, E.hadamard(lam, s_prev), grads)
    elif isinstance(z, E.MatRecurrence):
        # Matrix-valued scan adjoint: the same scan the other way with
        # TRANSPOSED coefficients (forward z, row-vector state s):
        #   λ_t = g_t + λ_{t+1} · A_{t+1}ᵀ
        # then ∂b = λ and ∂A_t = s_{t-1}ᵀ λ_t — one outer product per
        # step, stacked like the A relation (StepOuter).  The block shift
        # A_{t+1} is a RowShift of the stack by a whole block (±D rows,
        # zero-filled — exactly the λ boundary condition); transposition
        # is the scan's own `transposed` flag, flipped.
        d = z.b.shape[1]
        step = -1 if not z.reverse else 1
        a_next = E.row_shift(z.a, step * d)   # block t ↦ block t+1 (fwd)
        lam = E.mat_recurrence(a_next, seed, reverse=not z.reverse,
                               transposed=not z.transposed)
        s_prev = E.row_shift(z, -step)        # s_prev[t] = s[t-1] (fwd)
        derive(z.b, lam, grads)
        if z.transposed:                      # s_t = s_{t-1}·A_tᵀ + b_t
            derive(z.a, E.step_outer(lam, s_prev), grads)
        else:
            derive(z.a, E.step_outer(s_prev, lam), grads)
    elif isinstance(z, E.Const):
        pass  # constants carry no gradient
    elif isinstance(z, E.Var):
        if z in grads:
            grads[z] = E.add(grads[z], seed)
        else:
            grads[z] = seed
    else:  # pragma: no cover
        raise TypeError(f"unknown node {type(z)}")
    return grads


def gradients(loss: E.Expr, wrt: list[E.Var]) -> dict[E.Var, E.Expr]:
    """Gradient graphs of a scalar-per-entry loss w.r.t. ``wrt``.

    The paper seeds with the derivative of the mean-squared-error
    (Equation 6, ``l_ho = 2(a_ho - y)``); calling ``derive`` on the full loss
    expression ``(m(x)-y)^∘2`` with an all-ones seed produces the identical
    graph via the f(X) rule on ``sqr``.
    """
    ones = E.const(1.0, loss.shape)
    grads = derive(loss, ones)
    missing = [v for v in wrt if v not in grads]
    if missing:
        raise ValueError(f"no gradient flows to {[v.name for v in missing]}")
    return {v: grads[v] for v in wrt}
