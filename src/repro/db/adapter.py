"""Thin connection adapters over the engines the container actually has.

One interface, two implementations:

``SQLiteAdapter`` — stdlib ``sqlite3``; always available, the default.
``DuckDBAdapter`` — only when the ``duckdb`` package is importable.

An adapter owns a connection plus the matching :mod:`repro.db.dialect`, and
exposes exactly what the execution backend needs: ``execute`` (rows back),
``create_table`` and ``bulk_insert``.  Everything else (SQL rendering, array
pivoting) lives in ``dialect`` / ``relation_io`` so the adapters stay thin.
"""
from __future__ import annotations

import re
import sqlite3
from typing import Iterable, Sequence

from .dialect import (HAVE_DUCKDB, DuckDBDialect, Sql92Dialect, SqliteDialect,
                      duckdb)

_IDENT = re.compile(r"^[A-Za-z_][A-Za-z0-9_]*$")


def _check_ident(name: str) -> str:
    if not _IDENT.match(name):
        raise ValueError(f"bad SQL identifier: {name!r}")
    return name


class Adapter:
    """Base adapter: a prepared connection + its dialect."""

    dialect: Sql92Dialect
    placeholder = "?"

    def __init__(self, conn):
        self.conn = conn
        self.dialect.prepare(conn)

    # -- statement execution ------------------------------------------------
    def execute(self, sql: str, params: Sequence = ()) -> list[tuple]:
        """Run one statement, return all result rows (possibly empty)."""
        cur = self.conn.execute(sql, tuple(params))
        try:
            return cur.fetchall()
        except Exception:  # statement without a result set
            return []

    def executemany(self, sql: str, rows: Iterable[Sequence]) -> None:
        self.conn.executemany(sql, rows)

    # -- schema / data ------------------------------------------------------
    def create_table(self, name: str, columns: Sequence[tuple[str, str]],
                     replace: bool = True) -> None:
        """``columns`` is [(col_name, sql_type), ...]."""
        _check_ident(name)
        cols = ", ".join(f"{_check_ident(c)} {t}" for c, t in columns)
        if replace:
            self.execute(f"drop table if exists {name}")
        self.execute(f"create table {name} ({cols})")

    def bulk_insert(self, name: str, rows: Iterable[Sequence]) -> None:
        rows = list(rows)
        if not rows:
            return
        ph = ", ".join([self.placeholder] * len(rows[0]))
        self.executemany(f"insert into {_check_ident(name)} values ({ph})",
                         rows)

    # -- lifecycle ----------------------------------------------------------
    def commit(self) -> None:
        self.conn.commit()

    def close(self) -> None:
        try:  # flush pending inserts — sqlite3 rolls back open transactions
            self.conn.commit()
        except Exception:  # pragma: no cover - autocommit engines (duckdb)
            pass
        self.conn.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


class SQLiteAdapter(Adapter):
    dialect = SqliteDialect()

    def __init__(self, path: str = ":memory:"):
        super().__init__(sqlite3.connect(path))


class DuckDBAdapter(Adapter):
    placeholder = "?"

    def __init__(self, path: str = ":memory:"):
        if not HAVE_DUCKDB:  # pragma: no cover - depends on environment
            raise ImportError("duckdb is not installed; "
                              "use backend='sqlite' or pip install repro[db]")
        self.dialect = DuckDBDialect()
        super().__init__(duckdb.connect(path))

    def executemany(self, sql, rows):  # pragma: no cover - needs duckdb
        self.conn.executemany(sql, [tuple(r) for r in rows])


def connect(backend: str = "sqlite", path: str = ":memory:") -> Adapter:
    """Open the requested backend; ``'auto'`` prefers duckdb when present."""
    if backend == "auto":
        backend = "duckdb" if HAVE_DUCKDB else "sqlite"
    if backend == "sqlite":
        return SQLiteAdapter(path)
    if backend == "duckdb":
        return DuckDBAdapter(path)
    raise ValueError(f"unknown backend {backend!r}; "
                     "expected 'sqlite', 'duckdb' or 'auto'")
