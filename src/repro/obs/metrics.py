"""Histogram metrics and the ``metric_points`` time-series relation.

Two shapes of numeric telemetry ride the tracer next to spans:

``Histogram``
    Distribution sketch over fixed **log-spaced buckets** — p50/p95/p99
    without per-sample storage.  A value lands in bucket
    ``floor(log(v) / log(GROWTH))`` (``GROWTH = 2**(1/8)``), so memory is
    one counter per occupied power-of-1.09 band and the relative error of
    any reported percentile is bounded by ``sqrt(GROWTH) - 1`` (~4.4%).
    ``Tracer.observe(name, value)`` feeds one; the engine observes
    per-statement execution time, serving observes decode-step latency.

``MetricPoint`` / ``write_metric_points``
    An append-only time-series: training loss, gradient norm, plan-cache
    hit rate, rows ingested, serving tokens/s — one ``(seq, t, metric,
    step, value, labels)`` record per observation, appended by
    ``db/train.py``, ``SQLEngine`` and ``serving/engine.py`` each step.
    :func:`write_metric_points` pivots the series into a ``metric_points``
    relation *inside the traced database* (same stance as
    ``trace_spans``): training curves become a ``GROUP BY metric`` away.

Both are collected only when a collecting tracer is active — the
:class:`~repro.obs.tracer.NullTracer` no-ops ``observe``/``point``.
"""
from __future__ import annotations

import dataclasses
import json
import math

#: per-bucket growth factor: 8 buckets per octave — percentile values are
#: exact to within sqrt(GROWTH) ≈ 4.5% relative error
GROWTH = 2.0 ** 0.125

_LOG_GROWTH = math.log(GROWTH)

#: column layout of the in-database time-series relation
METRIC_POINT_COLUMNS = (
    ("seq", "integer"), ("t_us", "double precision"), ("metric", "text"),
    ("step", "integer"), ("value", "double precision"), ("labels", "text"),
)

#: the SQL recipe: one summary row per metric over the time-series relation
METRIC_SQL = (
    "select metric, count(*) as n, min(value) as lo, max(value) as hi,\n"
    "       avg(value) as mean\n"
    "  from metric_points group by metric order by metric"
)


class Histogram:
    """Log-spaced-bucket distribution sketch (no per-sample storage).

    Not synchronised — the owning :class:`~repro.obs.tracer.Tracer` calls
    ``observe`` under its lock.  Non-positive values are counted in a
    dedicated underflow bucket (they have no logarithm) and reported as
    the exact ``min`` when they dominate a percentile.
    """

    __slots__ = ("counts", "n", "total", "vmin", "vmax", "underflow")

    def __init__(self):
        self.counts: dict[int, int] = {}
        self.n = 0
        self.total = 0.0
        self.vmin = math.inf
        self.vmax = -math.inf
        self.underflow = 0           # values <= 0

    def observe(self, value) -> None:
        v = float(value)
        self.n += 1
        self.total += v
        self.vmin = min(self.vmin, v)
        self.vmax = max(self.vmax, v)
        if v <= 0.0:
            self.underflow += 1
            return
        idx = int(math.floor(math.log(v) / _LOG_GROWTH))
        self.counts[idx] = self.counts.get(idx, 0) + 1

    def percentile(self, p: float) -> float:
        """Value at percentile ``p`` (0–100): geometric bucket midpoint,
        clamped to the observed [min, max] so the tails are exact."""
        if self.n == 0:
            return 0.0
        rank = max(1, math.ceil(self.n * p / 100.0))
        if rank <= self.underflow:
            return self.vmin
        cum = self.underflow
        for idx in sorted(self.counts):
            cum += self.counts[idx]
            if cum >= rank:
                mid = math.exp((idx + 0.5) * _LOG_GROWTH)
                return min(max(mid, self.vmin), self.vmax)
        return self.vmax

    def snapshot(self) -> dict:
        """Summary dict — what ``Tracer.histograms`` and the Chrome-trace
        export carry per metric."""
        if self.n == 0:
            return {"count": 0}
        return {
            "count": self.n,
            "sum": self.total,
            "mean": self.total / self.n,
            "min": self.vmin,
            "max": self.vmax,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p95": self.percentile(95),
            "p99": self.percentile(99),
        }


@dataclasses.dataclass(frozen=True)
class MetricPoint:
    """One time-series observation (``t`` on the tracer's clock, so points
    align with span timestamps in the same capture)."""

    seq: int
    t: float
    metric: str
    step: int | None
    value: float
    labels: dict

    def as_row(self) -> tuple:
        return (self.seq, round(self.t * 1e6, 3), self.metric, self.step,
                self.value, json.dumps(self.labels, default=str,
                                       sort_keys=True))


def write_metric_points(adapter, tracer, table: str = "metric_points") -> int:
    """Store the collected time-series as a relation in the target database
    (replacing any previous capture); returns the row count.  Duck-typed
    like ``write_trace_spans``: any object with ``create_table`` +
    ``bulk_insert`` works, so the points land in the engine that produced
    them and :data:`METRIC_SQL` runs on the same connection."""
    points = list(tracer.points)
    adapter.create_table(table, METRIC_POINT_COLUMNS)
    adapter.bulk_insert(table, [p.as_row() for p in points])
    return len(points)


def percentiles_from_values(values, ps=(50, 90, 95, 99)) -> dict:
    """Exact percentiles of a raw value list (nearest-rank) — what the
    report CLI computes when it has the ``metric_points`` rows rather than
    a live histogram."""
    vs = sorted(float(v) for v in values)
    if not vs:
        return {f"p{p}": 0.0 for p in ps}
    return {f"p{p}": vs[min(len(vs) - 1,
                            max(0, math.ceil(len(vs) * p / 100.0) - 1))]
            for p in ps}
