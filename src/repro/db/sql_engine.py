"""The third execution backend: evaluate the expression DAG *in a database*.

``SQLEngine`` exposes the same surface as :class:`repro.core.engine.Engine`
(``evaluate`` / ``eval_fn`` / ``value_and_grad_fn``) but instead of running
XLA ops it

1. pivots every leaf matrix into an ``{[i, j, v]}`` table with the
   vectorized ingestion path (:mod:`repro.db.relation_io`) — unchanged
   leaves (training data across iterations) are detected by content digest
   and not re-written,
2. renders the DAG — including Algorithm-1 gradient graphs — as one WITH
   query, one CTE per node, through the persistent plan cache
   (:mod:`repro.db.plan_cache`): rendering is paid once per topology ×
   dialect, across iterations AND processes, and
3. executes it on the connected engine and pivots the result tuples back
   into dense arrays (one fancy-indexed assignment per root).

It is reachable as ``Engine("sql")``; training loops route through
:mod:`repro.db.train` (the recursive-CTE loop runs entirely in-database).
Because every query is executed, this backend also golden-hardens the
transpiler: any ``sqlgen`` regression turns into a failing differential
test rather than a silently wrong string.
"""
from __future__ import annotations

import hashlib
import os
import threading
import time
from typing import Callable

import numpy as np

from ..core import autodiff
from ..core import expr as E
from ..core import sqlgen
from ..obs import tracer_of
from . import plan_cache, relation_io
from .adapter import Adapter, connect
from .dialect import get_dialect, json_to_matrix


def _split_tagged(rows, roots: list[E.Expr]) -> list[np.ndarray]:
    """``(r, i, j, v)`` union rows → a dense matrix per root (vectorized)."""
    outs = [np.zeros(root.shape, dtype=np.float64) for root in roots]
    if not len(rows):
        return outs
    arr = np.asarray(rows, dtype=np.float64)
    r = arr[:, 0].astype(np.int64)
    i = arr[:, 1].astype(np.int64) - 1
    j = arr[:, 2].astype(np.int64) - 1
    for k, out in enumerate(outs):
        m = r == k
        out[i[m], j[m]] = arr[m, 3]
    return outs


def _digest(x, representation: str = "relational") -> bytes:
    """Content digest of a leaf matrix.  Shape, source dtype AND the
    representation are folded in next to the raw bytes: a (2,3) vs (3,2)
    reshape, an int8 vs uint8 reinterpretation, or an adapter shared
    between a relational and an array engine must never serve the
    unchanged-leaf skip across such pairs (the stored relations differ
    even when the buffer bytes agree)."""
    raw = np.asarray(x)
    a = np.ascontiguousarray(raw, dtype=np.float64)
    meta = repr((a.shape, raw.dtype.str, representation)).encode()
    return hashlib.sha256(a.tobytes() + meta).digest()


def _env_flag(name: str, default: bool) -> bool:
    val = os.environ.get(name)
    if val is None:
        return default
    return val.strip().lower() not in ("0", "off", "false", "no", "")


class SQLEngine:
    """Evaluate expression DAGs inside sqlite (default) or duckdb."""

    kind = "sql"

    def __init__(self, backend: str = "sqlite", path: str = ":memory:",
                 adapter: Adapter | None = None, plan_cache_=None,
                 dialect=None, tracer=None, fuse: bool | None = None,
                 spool: bool | None = None, temp_leaves: bool = False):
        """``plan_cache_``: a :class:`repro.db.plan_cache.PlanCache`,
        ``None`` for the shared persistent default, or ``False`` to render
        every query from scratch.

        ``dialect``: override the adapter's rendering dialect — pass
        ``"array"`` for the array-typed representation (paper §5/§7: same
        engine, one row per matrix, UDF calls per node) while the adapter
        still supplies the connection.  ``None`` keeps the adapter's
        native relational dialect.

        ``tracer``: a :class:`repro.obs.Tracer` to pin to this engine (and
        its adapter).  ``None`` (default) defers to the ambient tracer
        (:func:`repro.obs.use` / :func:`repro.obs.install`), which is a
        zero-cost no-op unless one was installed.

        ``fuse``: run the :func:`repro.core.sqlgen.fuse_dag` peephole pass
        before rendering (default on; ``REPRO_SQL_FUSE=0`` disables).
        ``spool``: materialise multi-referenced subplans as temp tables
        before the main statement — defaults to whether the dialect's
        engine flattens CTEs by substitution (sqlite < 3.35 re-executes
        every reference); ``REPRO_SQL_SPOOL`` overrides.

        ``temp_leaves``: ingest every leaf relation as a per-connection
        TEMP table.  The shard tier (``db/shard.py``) runs one engine per
        pooled connection with this on: each shard's weights and batch
        partition live in its own temp namespace, so shards never shadow
        a shared catalog, never contend for the main database's write
        lock, and never invalidate each other's matrix caches (temp
        generations key per-adapter)."""
        self.adapter = adapter if adapter is not None else connect(backend, path)
        if dialect is None:
            self.dialect = self.adapter.dialect
        else:
            self.dialect = get_dialect(dialect)
            if self.dialect is not self.adapter.dialect:
                self.dialect.prepare(self.adapter.conn)
        self.representation = self.dialect.representation
        self.fuse = _env_flag("REPRO_SQL_FUSE", True) if fuse is None \
            else bool(fuse)
        if spool is None:
            self.spool = _env_flag(
                "REPRO_SQL_SPOOL",
                getattr(self.dialect, "cte_materialization", "native")
                == "substitution")
        else:
            self.spool = bool(spool)
        self.temp_leaves = bool(temp_leaves)
        self.plans = plan_cache.resolve(plan_cache_)
        self.tracer = tracer
        if tracer is not None:
            self.adapter.tracer = tracer
        self._eval_steps = 0      # traced-evaluation counter (metric_points)
        self._steps_lock = threading.Lock()   # exact totals under the pool

    # -- representation conversion (Engine-compatible no-ops) ---------------
    def lift(self, x):
        return x

    def lower(self, x):
        return x

    # -- evaluation ---------------------------------------------------------
    def _write_env(self, roots: list[E.Expr], env: dict,
                   names=None) -> dict:
        """Materialise every free Var of the DAG as its stored relation.
        Leaves whose content digest matches what is already in the database
        are skipped — in an iteration loop only the weights move, the data
        relations are ingested once.  Changed leaves whose relation is
        already resident go through the bound-parameter delta path
        (:func:`repro.db.relation_io.update_matrix_delta` /
        ``update_matrix_array``) instead of DROP+CREATE re-ingestion.
        Digests live on the adapter (``matrix_digests``) and are trusted
        only while the table's shared generation is unchanged
        (``adapter.cache_fresh``) — a sibling pooled connection's write
        flips them stale; if the sibling wrote exactly the content we
        want (shared weights, fanned out), the leaf is ADOPTED without a
        rewrite.  ``names`` restricts ingestion to a subset of the free
        Vars (the batched path writes its request leaves separately).
        Returns the ingest accounting the ``sql.ingest`` span reports."""
        stored = self.adapter.matrix_digests
        array_rep = self.representation == "array"
        info = {"leaves": 0, "skipped": 0, "delta_updates": 0,
                "bytes_written": 0, "bytes_saved": 0}
        for v in E.free_vars(*roots):
            if names is not None and v.name not in names:
                continue
            if v.name not in env:
                raise KeyError(f"env missing leaf table {v.name!r}")
            raw = env[v.name]
            info["leaves"] += 1
            d = _digest(raw, self.representation)
            a = np.ascontiguousarray(np.asarray(raw, dtype=np.float64))
            fresh = self.adapter.cache_fresh(v.name)
            if fresh and stored.get(v.name) == d:
                info["skipped"] += 1
                info["bytes_saved"] += a.nbytes
                continue
            if not fresh:
                # drop OUR stale caches (no generation bump — the
                # resident content is a sibling's valid write) …
                self.adapter.forget(v.name)
                if self.adapter.shared_digest(v.name) == d:
                    # … and if the sibling wrote exactly this content,
                    # adopt the resident table instead of rewriting it
                    # (cache=False: its ingestion path may have round-
                    # tripped values, so no diff base is kept)
                    stored[v.name] = d
                    if a.ndim == 2:
                        relation_io._register_matrix(
                            self.adapter, v.name, a, self.representation,
                            cache=False)
                    info["skipped"] += 1
                    info["bytes_saved"] += a.nbytes
                    continue
            stored.pop(v.name, None)
            if array_rep:
                if relation_io.update_matrix_array(self.adapter, v.name, a):
                    info["delta_updates"] += 1
                else:
                    relation_io.write_matrix_array(self.adapter, v.name, a,
                                                   temp=self.temp_leaves)
                info["bytes_written"] += a.nbytes
            else:
                written = relation_io.update_matrix_delta(
                    self.adapter, v.name, a)
                if written is None:
                    relation_io.write_matrix(self.adapter, v.name, a,
                                             temp=self.temp_leaves)
                    info["bytes_written"] += a.nbytes
                else:
                    info["delta_updates"] += 1
                    info["bytes_written"] += written
                    info["bytes_saved"] += a.nbytes - written
            stored[v.name] = d
            self.adapter.record_digest(v.name, d)
        return info

    def _render(self, roots: list[E.Expr], batch=None) -> sqlgen.Plan:
        """Multi-root evaluation plan via the plan cache (or direct on
        miss): spool steps first, then the main WITH query.  ``batch``
        names the batched leaf Vars — part of the cache key, but the
        batch *size* never appears in the rendered text."""
        if self.plans is not None:
            return self.plans.dag_plan(roots, self.dialect,
                                       tail="multi_root", fuse=self.fuse,
                                       spool=self.spool,
                                       batch=batch or ())
        return sqlgen.render_plan(
            roots,
            select=sqlgen.multi_root_tail(roots, self.dialect, batch=batch),
            dialect=self.dialect, fuse=self.fuse, spool=self.spool,
            batch=batch)

    def _plan_key(self, roots: list[E.Expr]) -> str:
        """The cache key ``evaluate`` queries run under (multi-root tail).
        The fuse/spool renderer switches are part of the key — a cached
        fused plan is never served to an unfused engine or vice versa."""
        return plan_cache.plan_key(
            roots, extra=(self.dialect.name, "tail:multi_root",
                          f"fuse:{int(self.fuse)}",
                          f"spool:{int(self.spool)}"))

    def _run_plan(self, plan: sqlgen.Plan):
        """Execute a plan's spool steps (drop + create temp table — temp
        relations persist on the connection across evaluations) and then
        the main statement, returning its rows."""
        for table, sql in plan.steps:
            self.adapter.execute(f"drop table if exists {table}")
            self.adapter.execute(sql)
        return self.adapter.execute(plan.sql)

    def _ensure_explained(self, key: str, sql: str) -> None:
        """Capture the engine's EXPLAIN output for a cached plan, once.
        Must run *after* ``_write_env`` — sqlite's EXPLAIN QUERY PLAN
        resolves table names.  A failed capture records ``''`` so it is
        not retried on every call."""
        if self.plans is None or self.plans.get_explain(key) is not None:
            return
        try:
            text = self.adapter.explain_sql(sql)
        except Exception:
            text = ""
        self.plans.record_explain(key, text)

    def explain(self, roots: list[E.Expr]) -> str:
        """The engine's plan for this DAG (EXPLAIN QUERY PLAN on sqlite,
        EXPLAIN on duckdb).  Leaf tables must exist — evaluate the DAG (or
        call after a training run) first; returns ``''`` where the engine
        cannot explain the query.  Spooled plans explain the main
        statement (temp tables exist once the DAG has been evaluated)."""
        plan = self._render(roots)
        if self.plans is not None:
            key = self._plan_key(roots)
            self._ensure_explained(key, plan.sql)
            return self.plans.get_explain(key) or ""
        try:
            return self.adapter.explain_sql(plan.sql)
        except Exception:
            return ""

    def _decode(self, rows, roots: list[E.Expr]) -> list[np.ndarray]:
        """Result rows → one dense matrix per root.  Relational: tagged
        ``(r, i, j, v)`` cell tuples.  Array: one ``(r, m)`` row per root,
        ``m`` the JSON array codec."""
        if self.representation != "array":
            return _split_tagged(rows, roots)
        outs = [np.zeros(root.shape, dtype=np.float64) for root in roots]
        for r, m in rows:
            outs[int(r)] = json_to_matrix(m)
        return outs

    def _root_attrs(self, roots: list[E.Expr]) -> dict:
        """Per-IR-node attribution carried by the evaluation root span.
        Only computed when a collecting tracer is active (dag_signature
        hashes the whole DAG — never on the no-op path)."""
        return {
            "root": getattr(roots[0], "name", None) or type(roots[0]).__name__,
            "n_roots": len(roots),
            "dag_signature": sqlgen.dag_signature(roots)[:16],
            "dialect": self.dialect.name,
            "representation": self.representation,
        }

    def _record_eval_metrics(self, tr, dt_s: float, ingest: dict) -> None:
        """Per-evaluation telemetry on a collecting tracer: the latency
        histogram plus the ``metric_points`` time-series entries (plan-cache
        hit rate, bytes ingested) the regression/report layer reads."""
        with self._steps_lock:
            self._eval_steps += 1
            step = self._eval_steps
        tr.observe("sql.evaluate_ms", dt_s * 1e3)
        tr.point("sql.evaluate_ms", dt_s * 1e3, step=step,
                 dialect=self.dialect.name)
        if ingest.get("bytes_written"):
            tr.point("sql.ingest_bytes", ingest["bytes_written"], step=step)
        if self.plans is not None:
            seen = self.plans.hits + self.plans.misses
            if seen:
                tr.point("plan_cache.hit_rate", self.plans.hits / seen,
                         step=step)

    def evaluate(self, roots: list[E.Expr], env: dict) -> list[np.ndarray]:
        """One round trip: write leaves, run ONE multi-root query, read back.

        The query unions every root's tuples tagged with the root position,
        so shared CTEs (forward values reused by Algorithm 1's backward
        pass) are rendered — and executable by the engine — exactly once.
        """
        tr = tracer_of(self, self.adapter)
        if not tr.enabled:
            self._write_env(roots, env)
            rows = self._run_plan(self._render(roots))
            return self._decode(rows, roots)
        t_eval0 = time.perf_counter()
        with tr.span("sql.evaluate", **self._root_attrs(roots)) as root_sp:
            bytes0 = self.adapter.db_bytes()
            with tr.span("sql.ingest") as ing_sp:
                ingest = self._write_env(roots, env)
                ing_sp.set(**ingest)
            hits0 = self.plans.hits if self.plans is not None else 0
            with tr.span("sql.render") as sp:
                plan = self._render(roots)
                if self.plans is not None:
                    sp.set(cache="hit" if self.plans.hits > hits0 else "miss")
            for table, sql in plan.steps:      # spool before EXPLAIN — the
                self.adapter.execute(f"drop table if exists {table}")
                self.adapter.execute(sql)      # main stmt names the tables
            if self.plans is not None:
                with tr.span("sql.explain"):
                    self._ensure_explained(self._plan_key(roots), plan.sql)
            rows = self.adapter.execute(plan.sql)
            with tr.span("sql.decode"):
                outs = self._decode(rows, roots)
            bytes1 = self.adapter.db_bytes()
            root_sp.set(rows_returned=len(rows),
                        spool_steps=len(plan.steps),
                        db_bytes=(None if bytes0 is None or bytes1 is None
                                  else bytes1 - bytes0))
            self._record_eval_metrics(tr, time.perf_counter() - t_eval0,
                                      ingest)
            return outs

    def evaluate_rows(self, roots: list[E.Expr], env: dict) -> list[tuple]:
        """Like :meth:`evaluate`, but return the RAW tagged result rows —
        relational ``(r, i, j, v)`` / array ``(r, m)`` — without the dense
        decode.  The export half of cross-connection gradient shipping
        (``db/shard.py``): the coordinator re-ingests the tuples verbatim
        (``relation_io.ship_grad_rows``), so pivoting to dense here would
        be round-trip waste.  The whole round trip holds the adapter lock
        — one shard thread per connection serializes cleanly."""
        tr = tracer_of(self, self.adapter)
        with self.adapter.lock:
            if not tr.enabled:
                self._write_env(roots, env)
                return self._run_plan(self._render(roots))
            t_eval0 = time.perf_counter()
            with tr.span("sql.evaluate_rows",
                         **self._root_attrs(roots)) as root_sp:
                with tr.span("sql.ingest") as ing_sp:
                    ingest = self._write_env(roots, env)
                    ing_sp.set(**ingest)
                with tr.span("sql.render"):
                    plan = self._render(roots)
                rows = self._run_plan(plan)
                root_sp.set(rows_returned=len(rows),
                            spool_steps=len(plan.steps))
                self._record_eval_metrics(tr, time.perf_counter() - t_eval0,
                                          ingest)
                return rows

    # -- batched (multi-tenant) evaluation ----------------------------------
    def _write_batch(self, batch_env: dict) -> int:
        """Ingest the batched request leaves — ``name → (B, rows, cols)``
        stack — as per-connection TEMP tables carrying the ``b`` column.
        Returns B.  Temp tables shadow any resident relation of the same
        name for this connection only, so pooled siblings (and later
        unbatched evaluations, which re-create the main table) are
        unaffected."""
        sizes = set()
        for name, stack in batch_env.items():
            a = np.asarray(stack, dtype=np.float64)
            if a.ndim != 3:
                raise ValueError(
                    f"batched leaf {name!r} must be a (B, rows, cols) "
                    f"stack, got shape {a.shape}")
            sizes.add(int(a.shape[0]))
            if self.representation == "array":
                relation_io.write_matrix_array_batch(self.adapter, name, a)
            else:
                relation_io.write_matrix_batch(self.adapter, name, a)
        if len(sizes) != 1:
            raise ValueError(
                f"batched leaves disagree on batch size: {sorted(sizes)}")
        return sizes.pop()

    def _decode_batched(self, rows, roots: list[E.Expr],
                        nb: int) -> list[np.ndarray]:
        """Result rows → one ``(B, rows, cols)`` stack per root.  Batched
        roots arrive with their 0-based ``b``; roots of unbatched (shared)
        subgraphs are tagged ``b = -1`` — computed once by the engine,
        broadcast across the batch here."""
        outs = [np.zeros((nb,) + root.shape, dtype=np.float64)
                for root in roots]
        if self.representation == "array":
            for r, b, m in rows:
                mat = json_to_matrix(m)
                if int(b) < 0:
                    outs[int(r)][:] = mat
                else:
                    outs[int(r)][int(b)] = mat
            return outs
        if not len(rows):
            return outs
        arr = np.asarray(rows, dtype=np.float64)
        r = arr[:, 0].astype(np.int64)
        b = arr[:, 1].astype(np.int64)
        i = arr[:, 2].astype(np.int64) - 1
        j = arr[:, 3].astype(np.int64) - 1
        for k, out in enumerate(outs):
            m = (r == k) & (b >= 0)
            out[b[m], i[m], j[m]] = arr[m, 4]
            mb = (r == k) & (b < 0)
            if mb.any():
                base = np.zeros(roots[k].shape, dtype=np.float64)
                base[i[mb], j[mb]] = arr[mb, 4]
                out[:] = base
        return outs

    def evaluate_batched(self, roots: list[E.Expr], env: dict,
                         batch_env: dict) -> list[np.ndarray]:
        """ONE query, B independent requests (the multi-tenant tier).

        ``batch_env`` maps request-leaf names to ``(B, rows, cols)``
        stacks; ``env`` supplies the shared leaves (weights) exactly as in
        :meth:`evaluate` — they are ingested once and joined without a
        ``b`` predicate, so every request reads the same resident
        relations.  Returns one ``(B, rows, cols)`` stack per root,
        request ``k`` of the output identical (≤ float64 noise) to
        ``evaluate`` on request ``k`` alone.  The rendered plan carries no
        literal B — one cached entry serves every batch size, including
        B=1 and a ragged final micro-batch.  The whole round trip holds
        the adapter lock: concurrent callers serialize per connection
        (use a :class:`repro.db.adapter.ConnectionPool` to overlap)."""
        if not batch_env:
            raise ValueError("batch_env must name at least one batched leaf")
        batch = tuple(sorted(batch_env))
        free = {v.name for v in E.free_vars(*roots)}
        unknown = set(batch) - free
        if unknown:
            raise KeyError(f"batched leaves not free in the DAG: "
                           f"{sorted(unknown)}")
        shared = free - set(batch)
        tr = tracer_of(self, self.adapter)
        with self.adapter.lock:
            if not tr.enabled:
                self._write_env(roots, env, names=shared)
                nb = self._write_batch(batch_env)
                rows = self._run_plan(self._render(roots, batch=batch))
                return self._decode_batched(rows, roots, nb)
            t_eval0 = time.perf_counter()
            with tr.span("sql.evaluate_batched",
                         **self._root_attrs(roots)) as root_sp:
                with tr.span("sql.ingest") as ing_sp:
                    ingest = self._write_env(roots, env, names=shared)
                    nb = self._write_batch(batch_env)
                    ing_sp.set(batch=nb, **ingest)
                with tr.span("sql.render"):
                    plan = self._render(roots, batch=batch)
                rows = self._run_plan(plan)
                with tr.span("sql.decode"):
                    outs = self._decode_batched(rows, roots, nb)
                root_sp.set(rows_returned=len(rows), batch=nb,
                            spool_steps=len(plan.steps))
                self._record_eval_metrics(
                    tr, time.perf_counter() - t_eval0, ingest)
                return outs

    def eval_fn(self, roots: list[E.Expr]) -> Callable:
        """Evaluator with the Engine.eval_fn contract (no jit — the
        "compilation" is the SQL rendering, done once here and reused from
        the plan cache across topologically identical graphs)."""
        plan = self._render(roots)
        explained = [self.plans is None]  # explain once, after tables exist

        def fn(env: dict) -> list[np.ndarray]:
            tr = tracer_of(self, self.adapter)
            if not tr.enabled:
                self._write_env(roots, env)
                return self._decode(self._run_plan(plan), roots)
            t_eval0 = time.perf_counter()
            with tr.span("sql.evaluate", **self._root_attrs(roots)) as root_sp:
                with tr.span("sql.ingest") as ing_sp:
                    ingest = self._write_env(roots, env)
                    ing_sp.set(**ingest)
                for table, sql in plan.steps:
                    self.adapter.execute(f"drop table if exists {table}")
                    self.adapter.execute(sql)
                if not explained[0]:
                    with tr.span("sql.explain"):
                        self._ensure_explained(self._plan_key(roots),
                                               plan.sql)
                    explained[0] = True
                rows = self.adapter.execute(plan.sql)
                with tr.span("sql.decode"):
                    outs = self._decode(rows, roots)
                root_sp.set(rows_returned=len(rows),
                            spool_steps=len(plan.steps))
                self._record_eval_metrics(tr, time.perf_counter() - t_eval0,
                                          ingest)
                return outs

        return fn

    def value_and_grad_fn(self, loss: E.Expr, wrt: list[E.Var]) -> Callable:
        """env → (loss value, {var name: gradient}), gradients from
        Algorithm 1 rendered as CTEs and executed in-database."""
        grads = autodiff.gradients(loss, wrt)
        roots = [loss] + [grads[v] for v in wrt]
        fn = self.eval_fn(roots)

        steps = [0]

        def vg(env: dict):
            outs = fn(env)
            tr = tracer_of(self, self.adapter)
            if tr.enabled:        # the training curve, straight off the DAG
                steps[0] += 1
                tr.point("train.loss", float(np.mean(outs[0])),
                         step=steps[0])
                gn = float(np.sqrt(sum(float(np.sum(g * g))
                                       for g in outs[1:])))
                tr.point("train.grad_norm", gn, step=steps[0])
            return outs[0], {v.name: g for v, g in zip(wrt, outs[1:])}

        return vg

    # -- profiled execution mode --------------------------------------------
    def profile(self, roots: list[E.Expr], env: dict):
        """Profiled evaluation: same outputs as :meth:`evaluate`, plus a
        per-IR-node cost table (:class:`repro.obs.profiler.ProfileResult`)
        — every non-leaf node runs as its own timed temp-table step."""
        from ..obs import profiler
        return profiler.profile_evaluate(self, roots, env)

    def profile_value_and_grad(self, loss: E.Expr, wrt: list[E.Var],
                               env: dict):
        """Profile the loss + Algorithm-1 gradient DAG — the exact
        multi-root query one ``train.in_db`` iteration executes."""
        from ..obs import profiler
        return profiler.profile_value_and_grad(self, loss, wrt, env)

    # -- introspection ------------------------------------------------------
    @property
    def stats(self) -> dict:
        """One merged counter view over the whole engine: plan-cache
        hit/miss/eviction counters (the LRU no longer evicts silently),
        adapter query/ingestion counters, and — when a collecting tracer is
        pinned — its counters/gauges.  Flat convenience keys up front for
        the common questions; the nested dicts carry everything."""
        cache = self.plans.stats if self.plans is not None else {}
        adapter = dict(self.adapter.counters)
        out = {
            "cache_hits": cache.get("hits", 0),
            "cache_misses": cache.get("misses", 0),
            "cache_evictions": (cache.get("evictions", 0)
                                + cache.get("evictions_disk", 0)),
            "queries": adapter.get("queries", 0),
            "ingest_bytes": adapter.get("ingest_bytes", 0),
            "plan_cache": cache,
            "adapter": adapter,
        }
        db_bytes = self.adapter.db_bytes()
        if db_bytes is not None:
            out["db_bytes"] = db_bytes
        tr = self.tracer
        if tr is not None and tr.enabled:
            out["tracer"] = {"spans": len(tr.spans),
                             "counters": tr.counters, "gauges": tr.gauges}
        return out

    @staticmethod
    def merged_stats(engines: "list[SQLEngine]") -> dict:
        """Shard-aware stats: sum the integer counters of N per-shard
        engines (plan-cache counters are shared, so they are taken from
        the first engine rather than multiply counted).  What
        ``train_in_db(shards=N)`` reports as its engine view."""
        if not engines:
            return {}
        first = engines[0].stats
        shared_cache = {e.plans for e in engines if e.plans is not None}
        out = {"shards": len(engines),
               "plan_cache": first.get("plan_cache", {}),
               "cache_hits": first.get("cache_hits", 0),
               "cache_misses": first.get("cache_misses", 0)}
        adapter_total: dict = {}
        for e in engines:
            for k, v in e.adapter.counters.items():
                adapter_total[k] = adapter_total.get(k, 0) + v
        out["adapter"] = adapter_total
        out["queries"] = adapter_total.get("queries", 0)
        out["ingest_bytes"] = adapter_total.get("ingest_bytes", 0)
        if len(shared_cache) > 1:  # distinct caches — sum them honestly
            out["cache_hits"] = sum(e.plans.hits for e in engines
                                    if e.plans is not None)
            out["cache_misses"] = sum(e.plans.misses for e in engines
                                      if e.plans is not None)
        return out

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self.adapter.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
