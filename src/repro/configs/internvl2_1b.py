"""InternVL2-1B — InternViT + 0.5B-class LM backbone [arXiv:2404.16821].
The vision tower is a STUB: input_specs() provides precomputed patch
embeddings mixed into the token stream; only the LM backbone (24L, d=896,
14H GQA kv=2) is modelled."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="internvl2-1b", family="vlm", n_layers=24, d_model=896, n_heads=14,
    n_kv_heads=2, d_head=64, d_ff=4864, vocab=151655, tie_embeddings=True,
    rope_theta=1e6, stub_frontend="vision_patches")


def reduced() -> ArchConfig:
    return ArchConfig(
        name="internvl2-reduced", family="vlm", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=256,
        tie_embeddings=True, stub_frontend="vision_patches")
