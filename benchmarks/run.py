"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. Also emits the roofline summary
from the dry-run results file when present (results/dryrun_baseline.json).
"""
from __future__ import annotations

import json
import os
import sys

from . import paper_figures as F


def main() -> None:
    suites = [
        F.fig5_matmul_memory,
        F.fig6_iris_training,
        F.fig78_training_memory,
        F.fig9_mnist_training,
        F.fig10_inference,
        F.fig1113_mnist_memory,
        F.table1_sizes,
        F.cte_growth,
    ]
    print("name,us_per_call,derived")
    for suite in suites:
        for r in suite():
            print(f"{r['name']},{r['us_per_call']:.1f},\"{r['derived']}\"")
            sys.stdout.flush()
    # roofline summary appendix (from the dry-run, if it has been run)
    path = os.path.join(os.path.dirname(__file__), "..", "results",
                        "dryrun_baseline.json")
    if os.path.exists(path):
        with open(path) as f:
            recs = json.load(f)
        for r in recs:
            if r.get("status") != "ok" or r.get("mesh") != "16x16":
                continue
            t = r["terms_s"]
            step = max(t.values())
            print(f"roofline/{r['arch']}_{r['shape']},{step * 1e6:.1f},"
                  f"\"bottleneck={r['bottleneck']} "
                  f"frac={r.get('roofline_fraction', 0):.3f}\"")


if __name__ == "__main__":
    main()
