"""The observability subsystem: spans, exporters, counters, slow-query log.

Covers the ISSUE-6 satellite checklist: span nesting/ordering, the no-op
overhead guard (< 2% of a warm ``SQLEngine.evaluate``), a Chrome-trace
export golden (deterministic via an injected clock), the ``trace_spans``
relation round-trip on sqlite (and duckdb where installed), the
``REPRO_SLOW_QUERY_MS`` logging knob, plan-cache eviction counters, the
merged ``SQLEngine.stats`` view, and EXPLAIN capture per cached plan.

Regenerate the golden after an INTENTIONAL exporter change with:

    REPRO_UPDATE_GOLDEN=1 PYTHONPATH=src python -m pytest tests/test_obs.py
"""
import json
import logging
import os
import pathlib
import threading
import time

import numpy as np
import pytest

from repro import obs
from repro.core import expr as E
from repro.db.plan_cache import PlanCache
from repro.db.sql_engine import SQLEngine

GOLDEN_DIR = pathlib.Path(__file__).parent / "golden"
UPDATE = os.environ.get("REPRO_UPDATE_GOLDEN", "") not in ("", "0")


def small_dag():
    a = E.var("a", (3, 4))
    b = E.var("b", (4, 2))
    return E.matmul(a, b, name="c"), {
        "a": np.arange(12.0).reshape(3, 4), "b": np.ones((4, 2))}


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------

def test_span_nesting_order_and_paths():
    tr = obs.Tracer()
    with tr.span("outer", k=1):
        with tr.span("mid"):
            with tr.span("inner"):
                pass
        with tr.span("mid2"):
            pass
    names = [s.name for s in tr.spans]          # completion order
    assert names == ["inner", "mid", "mid2", "outer"]
    paths = {s.name: s.path for s in tr.spans}
    assert paths["inner"] == "outer/mid/inner"
    assert paths["mid2"] == "outer/mid2"
    by_name = {s.name: s for s in tr.spans}
    assert by_name["inner"].parent_id == by_name["mid"].span_id
    assert by_name["mid"].parent_id == by_name["outer"].span_id
    assert by_name["outer"].parent_id is None
    assert by_name["outer"].attrs == {"k": 1}
    # children are contained in the parent interval
    assert by_name["outer"].t0 <= by_name["inner"].t0
    assert by_name["inner"].t1 <= by_name["outer"].t1


def test_span_set_and_duration():
    tr = obs.Tracer()
    with tr.span("s") as sp:
        sp.set(rows=7)
    assert tr.spans[0].attrs["rows"] == 7
    assert tr.spans[0].duration >= 0.0


def test_thread_safety_per_thread_stacks():
    tr = obs.Tracer()
    barrier = threading.Barrier(2)

    def work(tag):
        with tr.span(f"root-{tag}"):
            barrier.wait()
            with tr.span(f"child-{tag}"):
                pass

    ts = [threading.Thread(target=work, args=(i,)) for i in range(2)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert len(tr.spans) == 4
    by_name = {s.name: s for s in tr.spans}
    for i in range(2):
        # nesting never crosses threads, even with interleaved opens
        assert by_name[f"child-{i}"].parent_id == by_name[f"root-{i}"].span_id
        assert by_name[f"child-{i}"].path == f"root-{i}/child-{i}"
    assert len({s.span_id for s in tr.spans}) == 4


def test_counters_and_gauges():
    tr = obs.Tracer()
    tr.inc("q")
    tr.inc("q", 2)
    tr.gauge("depth", 5)
    tr.gauge("depth", 9)
    assert tr.counters == {"q": 3}
    assert tr.gauges == {"depth": 9}
    tr.clear()
    assert tr.counters == {} and tr.gauges == {} and tr.spans == []


def test_use_restores_previous_tracer():
    assert not obs.current().enabled
    tr = obs.Tracer()
    with obs.use(tr):
        assert obs.current() is tr
        with tr.span("x"):
            pass
    assert not obs.current().enabled
    assert [s.name for s in tr.spans] == ["x"]


def test_tracer_of_prefers_pinned_attribute():
    class Holder:
        tracer = None

    h = Holder()
    assert obs.tracer_of(h) is obs.current()
    h.tracer = tr = obs.Tracer()
    assert obs.tracer_of(h) is tr
    assert obs.tracer_of(object(), h) is tr


# ---------------------------------------------------------------------------
# chrome-trace export (golden, deterministic clock)
# ---------------------------------------------------------------------------

def test_chrome_trace_golden():
    t = [0.0]

    def clock():
        t[0] += 0.001      # every timestamp read advances exactly 1 ms
        return t[0]

    tr = obs.Tracer(clock=clock)
    with tr.span("sql.evaluate", root="c", dialect="sqlite"):
        with tr.span("sql.ingest"):
            pass
        with tr.span("db.execute", rows=6):
            pass
    tr.inc("queries", 2)
    tr.gauge("recursive_cte_depth", 3)
    text = json.dumps(obs.chrome_trace(tr), indent=1, sort_keys=True) + "\n"
    path = GOLDEN_DIR / "obs_chrome_trace.json"
    if UPDATE:
        path.write_text(text)
    assert path.exists(), "golden missing — run with REPRO_UPDATE_GOLDEN=1"
    assert text == path.read_text()


def test_write_chrome_trace_loads_back(tmp_path):
    tr = obs.Tracer()
    with tr.span("a"):
        pass
    out = obs.write_chrome_trace(tr, str(tmp_path / "t.json"))
    data = json.loads(pathlib.Path(out).read_text())
    assert data["traceEvents"][0]["name"] == "a"
    assert data["traceEvents"][0]["ph"] == "X"


# ---------------------------------------------------------------------------
# trace_spans relation round-trip
# ---------------------------------------------------------------------------

def _roundtrip_trace_spans(backend):
    root, env = small_dag()
    tr = obs.Tracer()
    eng = SQLEngine(backend=backend, plan_cache_=False, tracer=tr)
    with eng:
        out, = eng.evaluate([root], env)
        assert np.allclose(out, env["a"] @ env["b"])
        n_before = len(tr.spans)
        n = obs.write_trace_spans(eng.adapter, tr)
        # the write itself runs through the traced adapter — the exported
        # snapshot is everything finished *before* it
        assert n == n_before > 0
        rows = eng.adapter.execute(
            "select count(*), count(distinct span_id) from trace_spans")
        assert rows[0][0] == rows[0][1] == n
        stages = eng.adapter.execute(obs.STAGE_SQL)
        names = [r[0] for r in stages]
        assert "db.execute" in names
        # root spans excluded, children attributed
        assert "sql.evaluate" not in names
        # attrs column is valid JSON
        attrs = eng.adapter.execute(
            "select attrs from trace_spans where name = 'sql.evaluate'")
        assert json.loads(attrs[0][0])["dialect"] == eng.dialect.name


def test_trace_spans_relation_sqlite():
    _roundtrip_trace_spans("sqlite")


def test_trace_spans_relation_duckdb():
    pytest.importorskip("duckdb")
    _roundtrip_trace_spans("duckdb")


# ---------------------------------------------------------------------------
# engine integration: span topology, stats, explain
# ---------------------------------------------------------------------------

def test_evaluate_span_topology_and_attribution():
    root, env = small_dag()
    tr = obs.Tracer()
    eng = SQLEngine(plan_cache_=PlanCache(path=None), tracer=tr)
    with eng:
        eng.evaluate([root], env)
    roots = [s for s in tr.spans if s.name == "sql.evaluate"]
    assert len(roots) == 1
    assert roots[0].attrs["root"] == "c"
    assert roots[0].attrs["representation"] == "relational"
    assert roots[0].attrs["rows_returned"] == 6
    assert len(roots[0].attrs["dag_signature"]) == 16
    child_names = {s.name for s in tr.spans
                   if s.parent_id == roots[0].span_id}
    assert {"sql.ingest", "sql.render", "sql.explain",
            "db.execute", "sql.decode"} <= child_names
    bd = obs.stage_breakdown(tr, root="sql.evaluate")
    assert bd["root_count"] == 1
    assert 0.0 < bd["attribution"] <= 1.0
    assert set(bd["stages"]) == child_names


def test_engine_stats_merged_view():
    root, env = small_dag()
    cache = PlanCache(path=None)
    tr = obs.Tracer()
    eng = SQLEngine(plan_cache_=cache, tracer=tr)
    with eng:
        eng.evaluate([root], env)
        eng.evaluate([root], env)
        st = eng.stats
    assert st["cache_misses"] == 1 and st["cache_hits"] == 1
    assert st["queries"] >= 2
    assert st["ingest_bytes"] > 0
    assert st["plan_cache"]["entries"] == 1
    assert st["adapter"]["rows_returned"] >= 12
    assert st["db_bytes"] > 0
    assert st["tracer"]["spans"] == len(tr.spans)


def test_plan_cache_eviction_counters():
    cache = PlanCache(path=None, cap=2)
    cache.put("k1", "sql1")
    cache.put("k2", "sql2")
    assert cache.evictions == 0
    cache.put("k3", "sql3")
    assert cache.evictions == 1
    assert cache.get("k1") is None          # the LRU victim
    st = cache.stats
    assert st["evictions"] == 1 and st["entries"] == 2
    # misses counted for the failed get above
    assert st["misses"] == 1


def test_plan_cache_disk_eviction_counter(tmp_path):
    cache = PlanCache(path=str(tmp_path / "plans.db"), cap=2)
    for k in ("k1", "k2", "k3", "k4"):
        cache.put(k, "select 1")
    assert cache.evictions_disk >= 2
    assert len(cache) == 2
    cache.close()


def test_explain_captured_once_per_plan(tmp_path):
    root, env = small_dag()
    cache = PlanCache(path=str(tmp_path / "plans.db"))
    eng = SQLEngine(plan_cache_=cache, tracer=obs.Tracer())
    with eng:
        eng.evaluate([root], env)
        key = eng._plan_key([root])
        text = cache.get_explain(key)
        assert text and "scan" in text.lower()
        assert eng.explain([root]) == text
        # persisted alongside the plan: a fresh cache on the same file
        # serves the explain without re-capturing
        eng.evaluate([root], env)
        assert cache.stats["explains"] == 1
    reopened = PlanCache(path=str(tmp_path / "plans.db"))
    assert reopened.get_explain(key) == text
    reopened.close()
    cache.close()


def test_explain_without_cache_direct():
    root, env = small_dag()
    eng = SQLEngine(plan_cache_=False)
    with eng:
        eng.evaluate([root], env)
        assert "scan" in eng.explain([root]).lower()


# ---------------------------------------------------------------------------
# slow-query logging (REPRO_SLOW_QUERY_MS)
# ---------------------------------------------------------------------------

def test_slow_query_logging(monkeypatch, caplog):
    root, env = small_dag()
    monkeypatch.setenv("REPRO_SLOW_QUERY_MS", "0")
    tr = obs.Tracer()
    eng = SQLEngine(plan_cache_=False, tracer=tr)
    with eng, caplog.at_level(logging.WARNING, logger="repro.db"):
        eng.evaluate([root], env)
    assert caplog.records, "threshold 0 must flag every query"
    msg = caplog.records[-1].getMessage()
    assert "slow query" in msg
    assert "span=" in msg and "sql.evaluate" in msg   # span path attribution
    assert "sql=" in msg
    assert eng.adapter.counters["slow_queries"] > 0


def test_slow_query_disabled_by_default(monkeypatch, caplog):
    root, env = small_dag()
    monkeypatch.delenv("REPRO_SLOW_QUERY_MS", raising=False)
    eng = SQLEngine(plan_cache_=False)
    with eng, caplog.at_level(logging.WARNING, logger="repro.db"):
        eng.evaluate([root], env)
    assert not caplog.records


def test_slow_query_untraced_path(monkeypatch, caplog):
    root, env = small_dag()
    monkeypatch.setenv("REPRO_SLOW_QUERY_MS", "0")
    eng = SQLEngine(plan_cache_=False)       # no tracer anywhere
    with eng, caplog.at_level(logging.WARNING, logger="repro.db"):
        eng.evaluate([root], env)
    assert "span=<untraced>" in caplog.records[-1].getMessage()


# ---------------------------------------------------------------------------
# no-op overhead guard
# ---------------------------------------------------------------------------

class _CountingNull(obs.NullTracer):
    """Disabled tracer that counts no-op span constructions — measures the
    exact number of no-op spans a disabled warm evaluate pays for."""

    def __init__(self):
        self.calls = 0

    def span(self, name, **attrs):
        self.calls += 1
        return obs.NOOP_SPAN


def test_noop_overhead_under_budget():
    """Disabled-tracer cost must stay < 2% of a warm evaluate.

    Measured deterministically: count the no-op spans the *disabled* warm
    path actually constructs (the enabled path takes different branches),
    multiply by the isolated per-span no-op cost, and compare against the
    measured warm evaluate time — no A/B timing race."""
    root, env = small_dag()
    eng = SQLEngine(plan_cache_=PlanCache(path=None))
    with eng:
        eng.evaluate([root], env)            # cold: render + explain
        counting = _CountingNull()
        eng.tracer = counting
        eng.adapter.tracer = counting
        eng.evaluate([root], env)
        spans_per_eval = counting.calls
        eng.tracer = None
        eng.adapter.tracer = None
        eng.evaluate([root], env)            # warm up the default path
        t0 = time.perf_counter()
        reps = 5
        for _ in range(reps):
            eng.evaluate([root], env)
        warm_s = (time.perf_counter() - t0) / reps

    null = obs.current()
    assert not null.enabled
    n = 10_000
    t0 = time.perf_counter()
    for _ in range(n):
        with null.span("x", a=1):
            pass
    per_span = (time.perf_counter() - t0) / n
    overhead = per_span * spans_per_eval
    assert overhead < 0.02 * warm_s, (
        f"no-op span overhead {overhead * 1e6:.1f}µs ≥ 2% of warm "
        f"evaluate {warm_s * 1e3:.2f}ms ({spans_per_eval} spans)")


# ---------------------------------------------------------------------------
# summarize / stage_breakdown shapes
# ---------------------------------------------------------------------------

def test_summarize_orders_by_total():
    t = [0.0]

    def clock():
        t[0] += 1.0
        return t[0]

    tr = obs.Tracer(clock=clock)
    with tr.span("big"):            # 5 clock ticks inside → longest
        with tr.span("small"):
            pass
        with tr.span("small"):
            pass
    s = obs.summarize(tr)
    assert list(s) == ["big", "small"]
    assert s["small"]["count"] == 2
    assert s["small"]["mean_s"] == pytest.approx(s["small"]["total_s"] / 2)
    assert list(obs.summarize(tr, top=1)) == ["big"]


def test_stage_breakdown_empty_tracer():
    bd = obs.stage_breakdown(obs.Tracer(), root="nope")
    assert bd["root_count"] == 0 and bd["attribution"] == 0.0


# ---------------------------------------------------------------------------
# training-loop spans
# ---------------------------------------------------------------------------

def test_train_in_db_span_attribution():
    from repro.core import nn2sql
    from repro.db.train import train_in_db

    spec = nn2sql.MLPSpec(n_rows=4, n_features=4, n_hidden=3, n_classes=2,
                          lr=0.05)
    graph = nn2sql.build_graph(spec)
    rng = np.random.default_rng(0)
    weights = {"w_xh": rng.normal(size=(4, 3)) * 0.1,
               "w_ho": rng.normal(size=(3, 2)) * 0.1}
    x = rng.normal(size=(4, 4))
    y = np.eye(2)[rng.integers(0, 2, size=4)]
    tr = obs.Tracer()
    with obs.use(tr):
        res = train_in_db(graph, weights, x, y, n_iters=2,
                          plan_cache_=False)
    assert res.n_iters == 2
    roots = [s for s in tr.spans if s.name == "train.in_db"]
    assert len(roots) == 1 and roots[0].attrs["n_iters"] == 2
    bd = obs.stage_breakdown(tr, root="train.in_db")
    assert {"train.ingest", "sql.render", "db.execute",
            "train.decode"} <= set(bd["stages"])
    assert bd["attribution"] >= 0.9          # the acceptance criterion
    assert tr.gauges.get("recursive_cte_depth") == 2


# ---------------------------------------------------------------------------
# exception-safe span finalization (ISSUE-8 satellite)
# ---------------------------------------------------------------------------

def test_span_exception_closes_with_error_attrs():
    tr = obs.Tracer()
    with pytest.raises(ValueError):
        with tr.span("outer"):
            with tr.span("inner"):
                raise ValueError("boom")
    by_name = {s.name: s for s in tr.spans}
    assert set(by_name) == {"outer", "inner"}
    for s in by_name.values():
        assert s.attrs["error"] is True
        assert s.attrs["exc_type"] == "ValueError"
        assert s.t1 is not None and s.duration >= 0.0
    assert tr._stack() == []                 # clean for the next call
    # the failed spans still appear in the exports
    events = obs.chrome_trace(tr)["traceEvents"]
    assert {e["name"] for e in events} == {"outer", "inner"}
    assert all(e["args"]["error"] for e in events)


def test_span_abandoned_descendant_force_closed():
    tr = obs.Tracer()
    with tr.span("parent"):
        tr.span("leaked").__enter__()        # __exit__ never runs
    by_name = {s.name: s for s in tr.spans}
    assert by_name["leaked"].attrs["abandoned"] is True
    assert "abandoned" not in by_name["parent"].attrs
    assert tr._stack() == []
    # out-of-order late exit of the force-closed span must not double-export
    with tr.span("p2"):
        leaked = tr.span("leaked2").__enter__()
    leaked.__exit__(None, None, None)
    assert sum(1 for s in tr.spans if s.name == "leaked2") == 1


def test_span_exception_in_traced_evaluate_keeps_stack_clean():
    root, env = small_dag()
    tr = obs.Tracer()
    eng = SQLEngine(backend="sqlite", plan_cache_=False, tracer=tr)
    with eng:
        with pytest.raises(KeyError):
            eng.evaluate([root], {"a": env["a"]})     # missing leaf "b"
        assert tr._stack() == []
        failed = [s for s in tr.spans if s.attrs.get("error")]
        assert any(s.name == "sql.evaluate" for s in failed)
        out, = eng.evaluate([root], env)              # next call unharmed
        assert np.allclose(out, env["a"] @ env["b"])
        ok = [s for s in tr.spans if s.name == "sql.evaluate"
              and not s.attrs.get("error")]
        assert len(ok) == 1 and ok[0].parent_id is None


# ---------------------------------------------------------------------------
# histograms + metric points (repro.obs.metrics)
# ---------------------------------------------------------------------------

def test_histogram_percentiles_vs_numpy():
    rng = np.random.default_rng(7)
    samples = np.concatenate([rng.lognormal(0.0, 1.0, 4000),
                              rng.uniform(5.0, 50.0, 1000)])
    h = obs.Histogram()
    for v in samples:
        h.observe(float(v))
    for p in (50, 90, 95, 99):
        exact = float(np.percentile(samples, p))
        got = h.percentile(p)
        # log-bucket growth 2**(1/8) bounds relative error by ~4.4%; allow
        # a little slack for the nearest-rank-vs-interpolation difference
        assert abs(got - exact) / exact < 0.06, (p, got, exact)
    snap = h.snapshot()
    assert snap["count"] == len(samples)
    assert snap["min"] == pytest.approx(samples.min())
    assert snap["max"] == pytest.approx(samples.max())
    assert snap["mean"] == pytest.approx(samples.mean())


def test_histogram_edge_cases():
    h = obs.Histogram()
    assert h.snapshot() == {"count": 0}
    assert h.percentile(50) == 0.0
    for v in (0.0, -3.0, 2.0):
        h.observe(v)
    assert h.underflow == 2
    assert h.percentile(50) == -3.0          # underflow reports exact min
    assert h.percentile(99) == pytest.approx(2.0, rel=0.1)  # bucket midpoint
    single = obs.Histogram()
    single.observe(42.0)
    assert single.percentile(50) == pytest.approx(42.0)


def test_histogram_and_counters_concurrent_threads():
    tr = obs.Tracer()
    n_threads, n_each = 8, 500

    def work(tag):
        for i in range(n_each):
            tr.observe("lat_ms", 1.0 + (i % 7))
            tr.inc("ops")
            if i % 50 == 0:
                tr.point("progress", i, step=i, worker=tag)

    ts = [threading.Thread(target=work, args=(k,))
          for k in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert tr.counters["ops"] == n_threads * n_each
    snap = tr.histograms["lat_ms"]
    assert snap["count"] == n_threads * n_each
    assert snap["min"] == 1.0 and snap["max"] == 7.0
    pts = tr.points
    assert len(pts) == n_threads * (n_each // 50)
    assert sorted(p.seq for p in pts) == list(range(len(pts)))


def test_null_tracer_metrics_are_noops():
    null = obs.NullTracer()
    null.observe("x", 1.0)
    null.point("x", 1.0, step=1, tag="a")
    assert null.histograms == {} and null.points == ()


def _roundtrip_metric_points(backend):
    t = [0.0]

    def clock():
        t[0] += 0.5
        return t[0]

    tr = obs.Tracer(clock=clock)
    tr.point("train.loss", 2.5, step=0)
    tr.point("train.loss", 1.25, step=1, source="test")
    tr.point("serve.tokens_per_s", 100.0)
    eng = SQLEngine(backend=backend, plan_cache_=False)
    with eng:
        n = obs.write_metric_points(eng.adapter, tr)
        assert n == 3
        rows = eng.adapter.execute(
            "select seq, metric, step, value, labels from metric_points"
            " order by seq")
        assert [r[1] for r in rows] == ["train.loss", "train.loss",
                                        "serve.tokens_per_s"]
        assert rows[1][2] == 1 and rows[1][3] == 1.25
        assert json.loads(rows[1][4]) == {"source": "test"}
        assert rows[2][2] is None
        summary = eng.adapter.execute(obs.METRIC_SQL)
        by_metric = {r[0]: r for r in summary}
        assert by_metric["train.loss"][1] == 2       # count
        assert by_metric["train.loss"][4] == pytest.approx(1.875)  # mean
        # timestamps ride the tracer clock (µs), so they align with spans
        assert eng.adapter.execute(
            "select t_us from metric_points where seq = 0")[0][0] \
            == pytest.approx(0.5e6)


def test_metric_points_relation_sqlite():
    _roundtrip_metric_points("sqlite")


def test_metric_points_relation_duckdb():
    pytest.importorskip("duckdb")
    _roundtrip_metric_points("duckdb")


def test_engine_emits_metric_points_and_histograms():
    root, env = small_dag()
    tr = obs.Tracer()
    eng = SQLEngine(backend="sqlite", tracer=tr)
    with eng:
        fn = eng.eval_fn([root])
        fn(env)
        fn(env)
    metrics = {p.metric for p in tr.points}
    assert "sql.evaluate_ms" in metrics
    assert "plan_cache.hit_rate" in metrics
    assert tr.histograms["sql.evaluate_ms"]["count"] == 2
    assert tr.histograms["db.execute_ms"]["count"] > 0
    steps = [p.step for p in tr.points if p.metric == "sql.evaluate_ms"]
    assert steps == [1, 2]


def test_train_in_db_emits_time_series():
    from repro.core import nn2sql
    from repro.db.train import train_in_db, loss_trajectory_in_db

    spec = nn2sql.MLPSpec(n_rows=4, n_features=4, n_hidden=3, n_classes=2,
                          lr=0.05)
    graph = nn2sql.build_graph(spec)
    rng = np.random.default_rng(0)
    weights = {"w_xh": rng.normal(size=(4, 3)) * 0.1,
               "w_ho": rng.normal(size=(3, 2)) * 0.1}
    x = rng.normal(size=(4, 4))
    y = np.eye(2)[rng.integers(0, 2, size=4)]
    tr = obs.Tracer()
    with obs.use(tr):
        res = train_in_db(graph, weights, x, y, n_iters=2,
                          plan_cache_=False)
        loss_trajectory_in_db(graph, res.history, x, y)
    by_metric = {}
    for p in tr.points:
        by_metric.setdefault(p.metric, []).append(p)
    assert "train.iter_ms" in by_metric
    assert "train.cte_bytes" in by_metric
    losses = by_metric["train.loss"]
    assert len(losses) == len(res.history)
    assert [p.step for p in losses] == list(range(len(res.history)))
    # the trajectory is the training curve: monotone for this tiny MLP
    assert losses[-1].value <= losses[0].value


# ---------------------------------------------------------------------------
# the per-IR-node profiler (repro.obs.profiler)
# ---------------------------------------------------------------------------

def _train_step_fixture():
    from repro.core import nn2sql

    spec = nn2sql.MLPSpec(n_rows=8, n_features=6, n_hidden=5, n_classes=3,
                          lr=0.05)
    graph = nn2sql.build_graph(spec)
    rng = np.random.default_rng(3)
    env = {"w_xh": rng.normal(size=(6, 5)) * 0.3,
           "w_ho": rng.normal(size=(5, 3)) * 0.3,
           "img": rng.normal(size=(8, 6)),
           "one_hot": np.eye(3)[rng.integers(0, 3, size=8)]}
    return graph, env


def test_profiler_node_table_matches_evaluate():
    graph, env = _train_step_fixture()
    eng = SQLEngine(backend="sqlite", plan_cache_=False)
    with eng:
        res = eng.profile_value_and_grad(graph.loss,
                                         [graph.w_xh, graph.w_ho], env)
        vg = eng.value_and_grad_fn(graph.loss, [graph.w_xh, graph.w_ho])
        loss, grads = vg(env)
    assert np.allclose(res.outputs[0], loss)
    assert np.allclose(res.outputs[1], grads["w_xh"])
    assert np.allclose(res.outputs[2], grads["w_ho"])
    # one cost row per non-leaf plan step, each with real measurements
    assert len(res.nodes) > 5
    kinds = {n.kind.split("+")[0].split("[")[0] for n in res.nodes}
    assert "MatMul" in kinds
    for n in res.nodes:
        assert n.self_s >= 0.0 and n.rows > 0 and n.bytes > 0
        assert n.signature and len(n.signature) == 16
        assert n.sql_head
    assert sum(n.pct for n in res.nodes) == pytest.approx(100.0, abs=1e-6) \
        or res.stages["tail"] > 0
    # sorted hottest-first, report renders every section
    assert res.nodes == sorted(res.nodes, key=lambda n: -n.self_s)
    text = res.report(top=5)
    assert "profile of" in text and "stages:" in text
    assert res.dialect == "sqlite"


def test_profiler_attribution_training_iteration():
    # the acceptance criterion: >= 95% of a profiled train-step DAG's wall
    # time lands on named IR nodes/stages.  A realistically-sized DAG —
    # on the micro fixture the per-step fixed overhead is a visible
    # fraction of a few-ms wall clock and the bound gets jittery under
    # full-suite load
    from repro.core import nn2sql

    spec = nn2sql.MLPSpec(n_rows=16, n_features=256, n_hidden=32,
                          n_classes=10, lr=0.05)
    graph = nn2sql.build_graph(spec)
    rng = np.random.default_rng(3)
    env = {"w_xh": rng.normal(size=(256, 32)) * 0.1,
           "w_ho": rng.normal(size=(32, 10)) * 0.1,
           "img": rng.normal(size=(16, 256)),
           "one_hot": np.eye(10)[rng.integers(0, 10, size=16)]}
    eng = SQLEngine(backend="sqlite", plan_cache_=False)
    with eng:
        res = eng.profile_value_and_grad(graph.loss,
                                         [graph.w_xh, graph.w_ho], env)
    assert res.attribution >= 0.95, res.stages
    assert res.attribution <= 1.05      # sanity: no double-booking
    assert set(res.stages) == {"ingest", "render", "tail", "decode",
                               "probe"}


def _profile_nodes_relation(backend):
    graph, env = _train_step_fixture()
    eng = SQLEngine(backend=backend, plan_cache_=False)
    with eng:
        res = eng.profile_value_and_grad(graph.loss,
                                         [graph.w_xh, graph.w_ho], env)
        n = obs.write_profile_nodes(eng.adapter, res)
        assert n == len(res.nodes)
        by_kind = eng.adapter.execute(obs.NODE_SQL)
        assert sum(r[1] for r in by_kind) == n
        kinds = [r[0] for r in by_kind]
        assert any(k.startswith("MatMul") for k in kinds)
        # hottest-kind ordering matches the in-memory aggregation
        agg = res.by_kind()
        assert kinds[0] == next(iter(agg))
        sig, = eng.adapter.execute(
            "select count(distinct node_signature) from profile_nodes")[0]
        assert sig > 1                  # per-node signatures, not the DAG's


def test_profiler_profile_nodes_relation_sqlite():
    _profile_nodes_relation("sqlite")


def test_profiler_profile_nodes_relation_duckdb():
    pytest.importorskip("duckdb")
    _profile_nodes_relation("duckdb")


def test_profiler_array_dialect():
    root, env = small_dag()
    eng = SQLEngine(backend="sqlite", dialect="array", plan_cache_=False)
    with eng:
        res = eng.profile([E.sigmoid(root)], env)
    assert np.allclose(res.outputs[0],
                       1.0 / (1.0 + np.exp(-(env["a"] @ env["b"]))))
    assert res.dialect == "array"
    assert all(n.rows == 1 for n in res.nodes)     # one row per matrix
    assert all(n.bytes > 0 for n in res.nodes)     # codec length probe


def test_profiler_emits_spans_under_tracer():
    graph, env = _train_step_fixture()
    tr = obs.Tracer()
    eng = SQLEngine(backend="sqlite", plan_cache_=False, tracer=tr)
    with eng:
        res = eng.profile_value_and_grad(graph.loss,
                                         [graph.w_xh, graph.w_ho], env)
    node_spans = [s for s in tr.spans if s.name == "profile.node"]
    assert len(node_spans) == len(res.nodes)
    roots = [s for s in tr.spans if s.name == "profile.evaluate"]
    assert len(roots) == 1
    assert all(s.parent_id == roots[0].span_id for s in node_spans)
    assert all("self_us" in s.attrs and "rows" in s.attrs
               for s in node_spans)


def test_profiler_spool_threshold_renders_every_node():
    from repro.core import sqlgen
    root, _env = small_dag()
    y = E.sigmoid(root)
    plan_all = sqlgen.render_plan(
        [y], dialect=None, spool=True, spool_threshold=1)
    plan_shared = sqlgen.render_plan(
        [y], dialect=None, spool=True)
    # threshold 1: every non-leaf node becomes its own temp-table step;
    # default threshold only spools multi-referenced nodes (none here)
    assert len(plan_all.steps) == 2
    assert len(plan_shared.steps) == 0
    assert all(t.startswith("_sp_") for t, _sql in plan_all.steps)


# ---------------------------------------------------------------------------
# the report CLI (python -m repro.obs.report)
# ---------------------------------------------------------------------------

def _reported_capture(tmp_path, backend="sqlite"):
    from repro.db.adapter import connect

    graph, env = _train_step_fixture()
    tr = obs.Tracer()
    db_path = str(tmp_path / "cap.db")
    ad = connect(backend, db_path)
    with obs.use(tr):
        eng = SQLEngine(adapter=ad)
        vg = eng.value_and_grad_fn(graph.loss, [graph.w_xh, graph.w_ho])
        vg(env)
        res = eng.profile_value_and_grad(graph.loss,
                                         [graph.w_xh, graph.w_ho], env)
    obs.write_trace_spans(ad, tr)
    obs.write_metric_points(ad, tr)
    obs.write_profile_nodes(ad, res)
    trace_path = obs.write_chrome_trace(tr, str(tmp_path / "cap.json"))
    ad.close()
    return db_path, trace_path


def test_report_cli_on_database(tmp_path, capsys):
    from repro.obs import report

    db_path, _ = _reported_capture(tmp_path)
    assert report.main([db_path, "--top", "5"]) == 0
    out = capsys.readouterr().out
    assert "observability report (database)" in out
    assert "stage breakdown" in out and "db.execute" in out
    assert "hottest IR nodes" in out and "MatMul" in out
    assert "metric percentiles" in out and "train.loss" in out


def test_report_cli_on_chrome_trace(tmp_path, capsys):
    from repro.obs import report

    _, trace_path = _reported_capture(tmp_path)
    assert report.main([trace_path]) == 0
    out = capsys.readouterr().out
    assert "observability report (chrome-trace)" in out
    assert "profile" in out or "MatMul" in out
    assert "sql.evaluate_ms" in out


# ---------------------------------------------------------------------------
# exact counter totals under threads (the pool-readiness bugfix)
# ---------------------------------------------------------------------------

def test_tracer_counters_exact_under_threads():
    tr = obs.Tracer()
    n_threads, n_iters = 8, 200

    def work():
        for _ in range(n_iters):
            tr.inc("c")
            tr.inc("big", 3)
            tr.observe("h", 1.0)

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert tr.counters["c"] == n_threads * n_iters
    assert tr.counters["big"] == 3 * n_threads * n_iters
    assert tr.histograms["h"]["count"] == n_threads * n_iters


def test_adapter_counters_exact_under_threads():
    """adapter.counters read-modify-writes are serialized on the
    connection lock (execute) / add_counters — totals must be exact."""
    from repro.db.adapter import SQLiteAdapter

    ad = SQLiteAdapter(":memory:")
    ad.create_table("t", (("v", "integer"),))
    base = ad.counters["queries"]
    n_threads, n_iters = 6, 100

    def work():
        for k in range(n_iters):
            ad.execute("insert into t values (?)", (k,))
            ad.add_counters(ingest_cells=2)

    ts = [threading.Thread(target=work) for _ in range(n_threads)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert ad.counters["queries"] - base == n_threads * n_iters
    assert ad.counters["ingest_cells"] == 2 * n_threads * n_iters
    ad.close()


def test_engine_eval_steps_exact_under_threads(tmp_path):
    """SQLEngine._eval_steps feeds metric_points step indices; N traced
    evaluations from N threads must land N distinct steps."""
    x = E.var("x", (2, 2))
    y = E.sigmoid(x)
    tr = obs.Tracer()
    engines = [SQLEngine("sqlite", plan_cache_=False, tracer=tr)
               for _ in range(4)]
    # one engine per thread (separate connections), shared step counter
    shared_lock = engines[0]._steps_lock
    for e in engines[1:]:
        e._steps_lock = shared_lock
        e.__dict__["_eval_steps"] = 0

    def bump_like(e):
        for _ in range(25):
            e.evaluate([y], {"x": np.eye(2)})

    ts = [threading.Thread(target=bump_like, args=(e,)) for e in engines]
    [t.start() for t in ts]
    [t.join() for t in ts]
    steps = [p.step for p in tr.points if p.metric == "sql.evaluate_ms"]
    assert len(steps) == 4 * 25
    for e in engines:
        e.close()
