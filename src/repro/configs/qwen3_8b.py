"""Qwen3-8B — dense GQA with per-head qk-norm [hf:Qwen/Qwen3-8B]."""
from .base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-8b", family="dense", n_layers=36, d_model=4096, n_heads=32,
    n_kv_heads=8, d_head=128, d_ff=12288, vocab=151936, qk_norm=True,
    rope_theta=1e6)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="qwen3-8b-reduced", family="dense", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=2, d_head=32, d_ff=256, vocab=256,
        qk_norm=True, rope_theta=1e6)
