with smax_c0(m) as (
  select msoftmax((select m from zx)) as m
)
select 0 as r, m from smax_c0;
