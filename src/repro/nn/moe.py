"""Mixture-of-Experts with the paper's two matrix representations.

The router's output *is* the paper's relation ``{[i, j, v]}``: token i is
assigned to expert j with gate value v. The two execution strategies the
paper benchmarks against each other (relational vs array data type) both
exist here, selectable per config — the dry-run/§Perf measures them at
datacenter scale:

``impl="einsum"`` — the ARRAY representation (paper Section 5): the
    assignment is materialised per token-group as a dense one-hot
    dispatch/combine tensor (g, E, C) and dispatch/combine are einsums
    (GShard-style). Fully pjit-friendly, but pays O(E·C/k) redundant
    multiply-adds per token — the array analogue of the paper's join
    blow-up (Fig. 5): the one-hot matrix materialises every (token, slot)
    cell even though only k per token are live.

``impl="sort"`` — the RELATIONAL representation (paper Section 4): the
    assignment stays a sparse relation; dispatch is the *join* (gather rows
    by token id), the per-expert rank comes from a sort (the paper's §8
    sort-based aggregation), and combine is the *group-by token, sum* — a
    segment sum. O(T·k·d) data movement, no redundant FLOPs.
    ``kernels/moe_dispatch`` + ``kernels/relational_matmul`` are the Pallas
    twins of the gather and segment-sum.

Tokens are processed in GROUPS (GShard's group dimension): capacity,
sorting and dispatch are all group-local, so with groups sharded over the
data axes every device handles its own relation and the only cross-device
traffic is the expert-parallel all-to-all. Both impls drop overflow beyond
expert capacity with identical rank-major priority, so their outputs match
exactly (tested).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from .layers import cdt, dense_init


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_model: int
    d_ff: int                 # per-expert hidden
    n_shared: int = 0         # shared experts (DeepSeek)
    capacity_factor: float = 1.25
    router_softmax: str = "pre"   # "pre": softmax→topk (DeepSeek);
                                  # "post": topk→softmax (DBRX/Mixtral)
    impl: str = "einsum"
    group_size: int = 2048


def init_moe(key, cfg: MoEConfig):
    ks = jax.random.split(key, 5)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    p = {
        "router": dense_init(ks[0], (d, e)),
        "wi": dense_init(ks[1], (e, d, f)),
        "wg": dense_init(ks[2], (e, d, f)),
        "wo": dense_init(ks[3], (e, f, d)),
    }
    if cfg.n_shared:
        p["shared"] = {
            "wi": dense_init(ks[4], (d, cfg.n_shared * f)),
            "wg": dense_init(jax.random.fold_in(ks[4], 1),
                             (d, cfg.n_shared * f)),
            "wo": dense_init(jax.random.fold_in(ks[4], 2),
                             (cfg.n_shared * f, d)),
        }
    return p


def _route(p, x, cfg: MoEConfig):
    """Top-k routing over flat tokens. Returns (gates, idx, aux_loss)."""
    logits = jnp.dot(x.astype(jnp.float32), p["router"].astype(jnp.float32))
    if cfg.router_softmax == "pre":
        probs = jax.nn.softmax(logits, axis=-1)
        gates, idx = jax.lax.top_k(probs, cfg.top_k)
        gates = gates / jnp.sum(gates, axis=-1, keepdims=True)
    else:
        top_logits, idx = jax.lax.top_k(logits, cfg.top_k)
        gates = jax.nn.softmax(top_logits, axis=-1)
        probs = jax.nn.softmax(logits, axis=-1)
    # Switch-style load-balancing aux loss (fraction × mean prob).
    me = jnp.mean(probs, axis=tuple(range(probs.ndim - 1)))
    ce = jnp.mean(
        jnp.sum(jax.nn.one_hot(idx, cfg.n_experts, dtype=jnp.float32),
                axis=-2), axis=tuple(range(idx.ndim - 1)))
    aux = cfg.n_experts * jnp.sum(me * ce) / cfg.top_k
    return gates, idx, aux


def _capacity(group: int, cfg: MoEConfig) -> int:
    c = int(group * cfg.top_k * cfg.capacity_factor / cfg.n_experts)
    return max(8, -(-c // 8) * 8)  # round up to 8


def _expert_ffn(p, xs):
    """xs: (..., E, C, d) → SwiGLU per expert."""
    h = jnp.einsum("...ecd,edf->...ecf", xs, cdt(p["wi"]))
    g = jnp.einsum("...ecd,edf->...ecf", xs, cdt(p["wg"]))
    return jnp.einsum("...ecf,efd->...ecd", h * jax.nn.silu(g),
                      cdt(p["wo"]))


# ---------------------------------------------------------------------------
# array representation: dense one-hot dispatch/combine (GShard), grouped
# ---------------------------------------------------------------------------

def _moe_einsum(p, xg, cfg: MoEConfig, gates, idx):
    """xg: (G, g, d); gates/idx: (G, g, k)."""
    _, g, d = xg.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(g, cfg)
    pos_offset = jnp.zeros(idx.shape[:1] + (e,), jnp.int32)     # (G, E)
    dispatch = None
    combine = None
    for r in range(k):
        mask_r = jax.nn.one_hot(idx[..., r], e, dtype=jnp.int32)  # (G,g,E)
        pos_r = jnp.cumsum(mask_r, axis=1) - 1 + pos_offset[:, None]
        pos_offset = pos_offset + jnp.sum(mask_r, axis=1)
        pos_tok = jnp.sum(mask_r * pos_r, axis=-1)                # (G, g)
        keep = pos_tok < cap
        oh_pos = jax.nn.one_hot(jnp.where(keep, pos_tok, cap), cap,
                                dtype=jnp.float32)                # (G,g,C)
        d_r = mask_r.astype(jnp.float32)[..., :, None] * oh_pos[..., None, :]
        dispatch = d_r if dispatch is None else dispatch + d_r
        combine = (d_r * gates[..., r][..., None, None]
                   if combine is None
                   else combine + d_r * gates[..., r][..., None, None])
    xs = jnp.einsum("gsec,gsd->gecd", dispatch.astype(xg.dtype), xg)
    ys = _expert_ffn(p, xs)
    return jnp.einsum("gsec,gecd->gsd", combine.astype(xg.dtype), ys)


# ---------------------------------------------------------------------------
# relational representation: sort (join) + segment sum (group-by), grouped
# ---------------------------------------------------------------------------

def _moe_sort_one(p, x, cfg: MoEConfig, gates, idx):
    """x: (g, d); gates/idx: (g, k) — one group's relation."""
    g, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    cap = _capacity(g, cfg)
    # the relation, rank-major to match the einsum path's drop priority
    expert_s = idx.T.reshape(-1)                    # (S,) S = k·g
    token_s = jnp.tile(jnp.arange(g, dtype=jnp.int32), k)
    gate_s = gates.T.reshape(-1)
    order = jnp.argsort(expert_s, stable=True)      # sort-based aggregation
    expert_sorted = expert_s[order]
    token_sorted = token_s[order]
    gate_sorted = gate_s[order]
    counts = jnp.bincount(expert_s, length=e)
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    pos_sorted = (jnp.arange(k * g, dtype=jnp.int32)
                  - seg_start[expert_sorted])
    keep = pos_sorted < cap
    # JOIN: gather token rows; scatter into per-expert capacity buckets
    xs_slots = x[token_sorted]
    buf = jnp.zeros((e, cap, d), x.dtype)
    buf = buf.at[expert_sorted, jnp.where(keep, pos_sorted, cap)].add(
        xs_slots, mode="drop")
    ys = _expert_ffn(p, buf)
    # gather back per slot; GROUP BY token, SUM (segment sum)
    y_slots = ys[expert_sorted, pos_sorted] * keep[:, None]
    weighted = y_slots.astype(jnp.float32) * gate_sorted[:, None]
    out = jax.ops.segment_sum(weighted, token_sorted, num_segments=g)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# relational representation with ENGINE SUPPORT: shard_map expert-owner plan
# ---------------------------------------------------------------------------
# The paper's conclusion — the relational representation needs engine
# support (sort-based aggregation, §8) — repeats at cluster scale: under
# pure GSPMD the sort/scatter plan communicates *more* than the one-hot
# einsum (measured, EXPERIMENTS.md §Perf). shard_map is that engine
# support: each (data, model) device routes its token shard, fills
# capacity buckets ONLY for the experts it owns, runs the local expert
# GEMMs, and partial-combines; a single psum over 'model' replaces both
# the dispatch all-to-all and the one-hot einsums.

_SHARD_CTX: dict = {"mesh": None, "dp": None}


def set_moe_mesh(mesh, dp_axes):
    """Install the mesh for impl='shard' (dryrun/trainer call this)."""
    _SHARD_CTX["mesh"] = mesh
    _SHARD_CTX["dp"] = dp_axes


def _moe_sort_local(p_wi, p_wg, p_wo, x, cfg, gates, idx, e_lo, e_loc,
                    cap):
    """Bucket-fill + expert GEMM + combine for the local expert range
    [e_lo, e_lo + e_loc). Slots outside the range drop like non-matching
    join tuples."""
    g, d = x.shape
    k = cfg.top_k
    expert_s = idx.T.reshape(-1) - e_lo
    token_s = jnp.tile(jnp.arange(g, dtype=jnp.int32), k)
    gate_s = gates.T.reshape(-1)
    owned = (expert_s >= 0) & (expert_s < e_loc)
    expert_s = jnp.where(owned, expert_s, e_loc)        # park in drop bucket
    order = jnp.argsort(expert_s, stable=True)
    expert_sorted = expert_s[order]
    token_sorted = token_s[order]
    gate_sorted = jnp.where(owned[order], gate_s[order], 0.0)
    counts = jnp.bincount(expert_s, length=e_loc + 1)
    seg_start = jnp.concatenate([jnp.zeros((1,), counts.dtype),
                                 jnp.cumsum(counts)[:-1]])
    pos_sorted = (jnp.arange(k * g, dtype=jnp.int32)
                  - seg_start[expert_sorted])
    keep = (pos_sorted < cap) & (expert_sorted < e_loc)
    xs_slots = x[token_sorted]
    buf = jnp.zeros((e_loc, cap, d), x.dtype)
    buf = buf.at[jnp.where(keep, expert_sorted, e_loc),
                 jnp.where(keep, pos_sorted, cap)].add(xs_slots,
                                                       mode="drop")
    h = jnp.einsum("ecd,edf->ecf", buf, cdt(p_wi))
    gt = jnp.einsum("ecd,edf->ecf", buf, cdt(p_wg))
    ys = jnp.einsum("ecf,efd->ecd", h * jax.nn.silu(gt), cdt(p_wo))
    y_slots = ys[jnp.where(keep, expert_sorted, 0),
                 jnp.where(keep, pos_sorted, 0)] * keep[:, None]
    weighted = y_slots.astype(jnp.float32) * gate_sorted[:, None]
    out = jax.ops.segment_sum(weighted, token_sorted, num_segments=g)
    return out.astype(x.dtype)


def _moe_shard(p, x, cfg: MoEConfig):
    """shard_map expert-owner execution. x: (T, d) flat tokens."""
    from jax.sharding import PartitionSpec as P

    mesh, dp = _SHARD_CTX["mesh"], _SHARD_CTX["dp"]
    mp = mesh.shape["model"]
    e_loc = cfg.n_experts // mp
    t = x.shape[0]
    dp_n = 1
    for a in dp:
        dp_n *= mesh.shape[a]
    t_loc = t // dp_n if t % dp_n == 0 else t
    cap = _capacity(t_loc, cfg)

    def local(x_loc, router, wi, wg, wo):
        gates, idx, _ = _route({"router": router}, x_loc, cfg)
        e_lo = jax.lax.axis_index("model") * e_loc
        partial = _moe_sort_local(wi, wg, wo, x_loc, cfg, gates, idx,
                                  e_lo, e_loc, cap)
        return jax.lax.psum(partial, "model")

    x_spec = P(dp, None) if t % dp_n == 0 else P(None, None)
    return jax.shard_map(
        local, mesh=mesh,
        in_specs=(x_spec, P(), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=x_spec)(x, p["router"], p["wi"], p["wg"], p["wo"])


def moe_ffn(p, x, cfg: MoEConfig):
    """x: (T, d) flat tokens → (out (T, d), aux_loss)."""
    t, d = x.shape
    g = min(cfg.group_size, t)
    if t % g:
        g = t                                        # tiny/odd batches
    xg = x.reshape(t // g, g, d)
    gates, idx, aux = _route(p, xg, cfg)
    if cfg.impl == "shard" and _SHARD_CTX["mesh"] is not None:
        out = _moe_shard(p, x, cfg)
    elif cfg.impl == "einsum":
        out = _moe_einsum(p, xg, cfg, gates, idx).reshape(t, d)
    elif cfg.impl in ("sort", "shard"):              # shard falls back
        out = jax.vmap(
            lambda xx, gg, ii: _moe_sort_one(p, xx, cfg, gg, ii)
        )(xg, gates, idx).reshape(t, d)
    else:
        raise ValueError(cfg.impl)
    if cfg.n_shared:
        sh = p["shared"]
        h = jnp.dot(x, cdt(sh["wi"])) * jax.nn.silu(jnp.dot(x, cdt(sh["wg"])))
        out = out + jnp.dot(h, cdt(sh["wo"]))
    return out, aux
