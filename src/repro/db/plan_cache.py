"""Persistent cache of rendered SQL plans.

Rendering the expression DAG to SQL (``core.sqlgen``) is pure string work,
but a training loop pays it on every ``train_in_db`` call and every process
start — while the *topology* of the query never changes between iterations
(the ROADMAP's "persistent ``repro.db`` cache of rendered SQL").  This
module stores rendered statements keyed by

    ``dag_signature(roots) × dialect × select-tail kind``

(:func:`repro.core.sqlgen.dag_signature` — structural, explicit names only),
in a two-level store: a process-local dict in front of a sqlite file that
survives sessions.  Because ``sqlgen`` renders auto-named nodes
deterministically by topo position, a plan rendered by one process is
byte-valid in any other — leaf (Var) table names are part of the signature.

Environment:

``REPRO_PLAN_CACHE``
    Path of the persistent store.  Default
    ``~/.cache/repro/plan_cache.db``; set to ``off`` (or ``0``) to keep the
    cache memory-only.

``REPRO_PLAN_CACHE_CAP``
    LRU capacity (entries) of both layers; default 512.  Every distinct
    (DAG × dialect × tail) topology is one entry — rendered SQL for deep
    scan graphs runs to tens of KB, so an uncapped store grows without
    bound under topology-churning workloads (per-(T, D) MatRecurrence
    plans, state-size sweeps).  Eviction is least-recently-*used*: the
    in-process dict keeps exact recency, the persistent table is pruned
    on insert by its ``last_used`` column (touched on every hit).
"""
from __future__ import annotations

import collections
import hashlib
import inspect
import os
import sqlite3
import threading
import time

from ..core import expr as E
from ..core import sqlgen

_ENV = "REPRO_PLAN_CACHE"
_CAP_ENV = "REPRO_PLAN_CACHE_CAP"
_DISABLED = {"off", "0", "none", "disabled"}

#: default LRU capacity (entries) of the in-process AND persistent layers
DEFAULT_CAP = 512

_FINGERPRINT: str | None = None


def renderer_fingerprint() -> str:
    """Content hash of the rendering code — part of every plan key, so a
    cached plan can never outlive the code that produced it (a persistent
    store otherwise serves stale SQL after transpiler fixes).  Rendered
    text depends on ``core.sqlgen`` (structure), ``core.autodiff`` (the
    gradient DAGs baked into training queries keyed on the loss DAG alone)
    and ``db.dialect`` (map/const/series spellings) — all three sources
    are hashed."""
    global _FINGERPRINT
    if _FINGERPRINT is None:
        from ..core import autodiff
        from . import dialect as dialect_mod
        chunks = []
        for mod in (sqlgen, autodiff, dialect_mod):
            try:
                chunks.append(inspect.getsource(mod))
            except (OSError, TypeError):  # pragma: no cover - frozen installs
                chunks.append(getattr(mod, "__file__", "") or "unknown")
        _FINGERPRINT = hashlib.sha256("\0".join(chunks).encode()) \
            .hexdigest()[:16]
    return _FINGERPRINT


def plan_key(roots: list[E.Expr], extra=()) -> str:
    """The cache key: structural DAG signature × renderer fingerprint ×
    caller extras (dialect, tail/renderer kind, hyper-parameters)."""
    return sqlgen.dag_signature(roots,
                                extra=(renderer_fingerprint(),) + tuple(extra))


def default_path() -> str | None:
    """Resolve the persistent-store path (None → memory-only)."""
    p = os.environ.get(_ENV)
    if p is not None:
        return None if p.strip().lower() in _DISABLED else p
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "plan_cache.db")


class PlanCache:
    """Two-level plan store: in-process dict over an optional sqlite file.

    The sqlite layer is best-effort — any failure to open or write it
    (read-only home, concurrent lock) silently degrades to memory-only, so
    the execution backend never breaks on cache trouble.

    Both layers are LRU-capped at ``cap`` entries (default
    :data:`DEFAULT_CAP`, overridable via ``REPRO_PLAN_CACHE_CAP``): the
    in-process dict evicts its least-recently-used key on insert, and
    every insert prunes the persistent ``plans`` table down to the cap by
    ``last_used``.  Hits record recency in memory only; the pending
    touches are flushed to disk right before each pruning pass (and on
    close), so the hot working set survives topology churn while the
    get() hot path never writes.
    """

    def __init__(self, path: str | None = "default", cap: int | None = None):
        if path == "default":
            path = default_path()
        if cap is None:
            try:  # cache trouble never breaks the backend — bad env too
                cap = int(os.environ.get(_CAP_ENV, DEFAULT_CAP))
            except ValueError:
                cap = DEFAULT_CAP
        self.cap = max(1, int(cap))
        self.path = path
        #: serializes BOTH layers: the OrderedDict's move_to_end/popitem
        #: and the persistent store's touch-flush → insert → prune
        #: sequence are read-modify-write — racing pool workers could
        #: evict a just-loaded hot plan or double-insert.  Re-entrant so
        #: ``rendered`` may call ``get``/``put`` while holding it.
        self._lock = threading.RLock()
        self.hits = 0
        self.misses = 0
        #: entries dropped by the in-process LRU / the persistent prune —
        #: eviction is no longer silent (surfaced via ``stats`` and merged
        #: into ``SQLEngine.stats``)
        self.evictions = 0
        self.evictions_disk = 0
        self._mem: collections.OrderedDict[str, str] = collections.OrderedDict()
        self._touched: set[str] = set()   # hit recency pending disk flush
        #: key → captured engine plan text (EXPLAIN QUERY PLAN / EXPLAIN),
        #: stored alongside the rendered SQL; '' means capture unsupported
        self._explains: dict[str, str] = {}
        self._conn = None
        if path:
            try:
                os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
                # the store is accessed from whichever pool worker hits
                # it; all access is serialized on self._lock
                self._conn = sqlite3.connect(path, check_same_thread=False)
                self._conn.execute(
                    "create table if not exists plans ("
                    " key text primary key, dialect text, sql text,"
                    " created real, last_used real, explain_text text)")
                cols = [r[1] for r in self._conn.execute(
                    "pragma table_info(plans)")]
                if "last_used" not in cols:  # pre-LRU store: migrate in place
                    self._conn.execute("alter table plans"
                                       " add column last_used real")
                    self._conn.execute("update plans set last_used = created")
                if "explain_text" not in cols:  # pre-obs store: migrate
                    self._conn.execute("alter table plans"
                                       " add column explain_text text")
                self._conn.commit()
            except Exception:  # pragma: no cover - env-dependent degradation
                self._conn = None

    # -- store --------------------------------------------------------------
    def _mem_insert(self, key: str, sql: str) -> None:
        self._mem[key] = sql
        self._mem.move_to_end(key)
        while len(self._mem) > self.cap:
            dropped, _ = self._mem.popitem(last=False)
            self._explains.pop(dropped, None)
            self.evictions += 1

    def _flush_touched(self) -> None:
        """Write the recency of keys touched since the last flush.  Hits
        stay pure in-memory operations (the cache's whole point is a
        cheap hot path — one UPDATE + fsync per get() would cost more
        than the render it saves); the persistent ``last_used`` only
        needs to be current when it is *read*, i.e. right before a
        put()'s pruning pass and at close()."""
        if self._conn is None or not self._touched:
            return
        now = time.time()
        self._conn.executemany(
            "update plans set last_used = ? where key = ?",
            [(now, k) for k in self._touched])
        self._touched.clear()

    def get(self, key: str) -> str | None:
        with self._lock:
            sql = self._mem.get(key)
            if sql is None and self._conn is not None:
                try:
                    row = self._conn.execute(
                        "select sql from plans where key = ?",
                        (key,)).fetchone()
                except Exception:  # pragma: no cover
                    row = None
                if row:
                    sql = row[0]
                    self._mem_insert(key, sql)
            if sql is None:
                self.misses += 1
            else:
                self.hits += 1
                if key in self._mem:
                    self._mem.move_to_end(key)
                if self._conn is not None:  # pending flush; else unbounded
                    self._touched.add(key)
            return sql

    def put(self, key: str, sql: str, dialect: str = "") -> None:
        with self._lock:
            self._mem_insert(key, sql)
            if self._conn is None:
                return
            try:
                self._flush_touched()   # recency must be current for prune
                # stamp AFTER the flush: the new plan must not look colder
                # than the just-flushed hits, or an at-cap prune would
                # evict the plan being inserted
                now = time.time()
                self._conn.execute(
                    "insert or replace into plans"
                    " (key, dialect, sql, created, last_used, explain_text)"
                    " values (?, ?, ?, ?, ?, ?)",
                    (key, dialect, sql, now, now, self._explains.get(key)))
                n = self._conn.execute(
                    "select count(*) from plans").fetchone()[0]
                if n > self.cap:  # prune the coldest down to the cap
                    self._conn.execute(
                        "delete from plans where key in (select key from"
                        " plans order by last_used asc, created asc"
                        " limit ?)", (n - self.cap,))
                    self.evictions_disk += n - self.cap
                self._conn.commit()
            except Exception:  # pragma: no cover
                pass

    # -- engine plan introspection -------------------------------------------
    def record_explain(self, key: str, text: str) -> None:
        """Attach the engine's EXPLAIN output to a cached plan (captured
        once per plan by the SQLEngine; '' marks capture as unsupported so
        it is not retried).  Persisted next to the rendered SQL."""
        with self._lock:
            self._explains[key] = text
            if self._conn is None:
                return
            try:
                self._conn.execute(
                    "update plans set explain_text = ? where key = ?",
                    (text, key))
                self._conn.commit()
            except Exception:  # pragma: no cover
                pass

    def get_explain(self, key: str) -> str | None:
        """EXPLAIN text for a cached plan (None: never captured)."""
        with self._lock:
            text = self._explains.get(key)
            if text is None and self._conn is not None:
                try:
                    row = self._conn.execute(
                        "select explain_text from plans where key = ?",
                        (key,)).fetchone()
                except Exception:  # pragma: no cover
                    row = None
                if row and row[0] is not None:
                    text = row[0]
                    self._explains[key] = text
            return text

    def clear(self) -> None:
        with self._lock:
            self._mem.clear()
            self._touched.clear()
            self._explains.clear()
            if self._conn is None:
                return
            try:
                self._conn.execute("delete from plans")
                self._conn.commit()
            except Exception:  # pragma: no cover
                pass

    def __len__(self) -> int:
        with self._lock:
            if self._conn is not None:
                try:
                    return self._conn.execute(
                        "select count(*) from plans").fetchone()[0]
                except Exception:  # pragma: no cover
                    pass
            return len(self._mem)

    @property
    def stats(self) -> dict:
        return {"hits": self.hits, "misses": self.misses,
                "evictions": self.evictions,
                "evictions_disk": self.evictions_disk,
                "explains": len(self._explains),
                "entries": len(self), "cap": self.cap, "path": self.path}

    def close(self) -> None:
        with self._lock:
            if self._conn is None:
                return
            try:
                self._flush_touched()
                self._conn.commit()
            except Exception:  # pragma: no cover
                pass
            try:
                self._conn.close()
            except Exception:  # pragma: no cover
                pass
            self._conn = None

    # -- rendering through the cache ----------------------------------------
    def rendered(self, key: str, dialect_name: str, render) -> str:
        """``render()`` is called only on a miss; its output is stored.
        Held under the lock end-to-end so concurrent misses on one key
        render once — the second worker hits the first one's insert."""
        with self._lock:
            sql = self.get(key)
            if sql is None:
                sql = render()
                self.put(key, sql, dialect_name)
            return sql

    def dag_sql(self, roots: list[E.Expr], dialect, tail: str = "last") -> str:
        """Rendered WITH query for ``roots``; ``tail`` ∈ {'last',
        'multi_root'} selects the statement tail kind (part of the key).
        The dialect name keys the entry, so the same DAG under different
        representations (``sqlite`` cell-relational vs ``array``) can
        never share — or cross-serve — a cached plan."""
        if tail not in ("last", "multi_root"):
            raise ValueError(f"unknown tail kind {tail!r}")
        key = plan_key(roots, extra=(dialect.name, f"tail:{tail}"))
        select = (sqlgen.multi_root_tail(roots, dialect)
                  if tail == "multi_root" else None)
        return self.rendered(
            key, dialect.name,
            lambda: sqlgen.to_sql(roots, select=select, dialect=dialect))

    def dag_plan(self, roots: list[E.Expr], dialect, tail: str = "last",
                 fuse: bool = False, spool: bool = False,
                 batch=()) -> sqlgen.Plan:
        """Rendered evaluation :class:`repro.core.sqlgen.Plan` (spool
        steps + main statement) for ``roots``.  ``fuse`` and ``spool`` are
        folded into the key alongside dialect and tail, so a fused plan is
        never served to an unfused renderer (and vice versa) — the stored
        value is the plan's text serialisation, shared across processes
        like any other entry.  ``batch`` names the batched leaf Vars
        (multi-tenant serving): the WHICH-leaves-carry-``b`` set keys the
        entry, but the batch *size* does not appear in the rendered text —
        one cached plan serves any B."""
        if tail not in ("last", "multi_root"):
            raise ValueError(f"unknown tail kind {tail!r}")
        batch = tuple(sorted(batch)) if batch else ()
        extra = [dialect.name, f"tail:{tail}", f"fuse:{int(fuse)}",
                 f"spool:{int(spool)}"]
        if batch:
            extra.append("batch:" + ",".join(batch))
        key = plan_key(roots, extra=tuple(extra))
        select = (sqlgen.multi_root_tail(roots, dialect, batch=batch or None)
                  if tail == "multi_root" else None)
        text = self.rendered(
            key, dialect.name,
            lambda: sqlgen.render_plan(roots, select=select, dialect=dialect,
                                       fuse=fuse, spool=spool,
                                       batch=batch or None).to_text())
        return sqlgen.Plan.from_text(text)


_default: PlanCache | None = None


def default_cache() -> PlanCache:
    """The process-wide shared cache (persistent unless disabled via env)."""
    global _default
    if _default is None:
        _default = PlanCache()
    return _default


def resolve(cache) -> PlanCache | None:
    """Normalise a user-supplied cache argument: None → shared default,
    False → caching off, or a :class:`PlanCache` instance."""
    if cache is None:
        return default_cache()
    if cache is False:
        return None
    return cache
