"""RWKV recurrences transpiled to SQL (recursive-CTE scans).

Two of the RWKV-6 building blocks (``kernels/rwkv6_scan.py``,
``nn/ssm.py``) over the zoo IR:

* **time mix** — the matrix-state recurrence

      o_t = r_t · (S_{t-1} + diag(u) k_t v_tᵀ);   S_t = diag(w_t) S_{t-1} + k_t v_tᵀ

  Each state cell evolves independently:  S_t[a,b] = w_t[a]·S_{t-1}[a,b]
  + k_t[a]·v_t[b], so flattening (a, b) → column a·N+b turns the whole
  (N×N)-state scan into ONE elementwise affine ``Recurrence`` over an
  (S, N²) relation — a single recursive CTE, every column walking its own
  chain.  The flattening itself is relational: Kronecker *index
  relations* (0/1 matrices ``kron_a``/``kron_b``, :func:`kron_index_relations`)
  broadcast k over b and v over a via plain matmul joins, and the output
  contraction Σ_a is the matmul against ``kron_bᵀ``.

* **channel mix** — token shift (``RowShift``) + mix/σ/relu² FFN, no
  recursion beyond the shift.

Both are differentially tested against ``kernels/ref.py`` /  the jnp
references below (≤1e-4).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from ...core import expr as E


# ---------------------------------------------------------------------------
# index relations
# ---------------------------------------------------------------------------

def kron_index_relations(n: int) -> dict[str, np.ndarray]:
    """The 0/1 broadcast relations of the (a, b) → a·N+b flattening:

    ``kron_a``  (N, N²): [a, a·N+b] = 1 — left factor, repeats over b;
    ``kron_b``  (N, N²): [b, a·N+b] = 1 — right factor, tiles over a.

    ``x @ kron_a`` spreads a length-N row over the N² state columns by the
    *a* index, ``x @ kron_b`` by the *b* index; ``y @ kron_bᵀ`` sums a
    state row over *a* for each b.  These are stored index relations — the
    sparse join partners of the paper's one-hot construction."""
    ka = np.zeros((n, n * n))
    kb = np.zeros((n, n * n))
    for a in range(n):
        ka[a, a * n:(a + 1) * n] = 1.0
    for b in range(n):
        kb[b, b::n] = 1.0
    return {"kron_a": ka, "kron_b": kb}


def _first_row_indicator(rows: int) -> np.ndarray:
    e1 = np.zeros((rows, 1))
    e1[0, 0] = 1.0
    return e1


# ---------------------------------------------------------------------------
# time mix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class RWKVGraph:
    seq: int
    n: int
    o: E.Expr            # (S, N) per-token output
    state: E.Expr        # (S, N²) post-update state trajectory
    leaves: tuple        # the r/k/v/w/u/s0 Vars


def rwkv6_time_mix_graph(seq: int, n: int) -> RWKVGraph:
    """One head's RWKV-6 time-mix recurrence as a single-scan DAG.

    Leaf relations: ``r``/``k``/``v``/``w`` (S, N), ``u`` (1, N),
    ``s0`` (1, N²) initial state (row-major flattened), plus the static
    index relations of :func:`rwkv6_static_env`."""
    nn = n * n
    r = E.var("r", (seq, n))
    k = E.var("k", (seq, n))
    v = E.var("v", (seq, n))
    w = E.var("w", (seq, n))
    u = E.var("u", (1, n))
    s0 = E.var("s0", (1, nn))
    ka = E.var("kron_a", (n, nn))
    kb = E.var("kron_b", (n, nn))
    e1 = E.var("e_first", (seq, 1))

    decay = E.matmul(w, ka, name="decay_flat")             # w[t,a] over b
    kv = E.hadamard(E.matmul(k, ka), E.matmul(v, kb), name="kv_flat")
    s0_row1 = E.matmul(e1, s0)            # (S, N²), s0 in row 1, else 0
    b_eff = E.add(kv, E.hadamard(decay, s0_row1))   # fold s0 into step 1
    state = E.recurrence(decay, b_eff, name="state_scan")  # S_t, post-update
    s_prev = E.add(E.row_shift(state, 1), s0_row1, name="state_prev")

    r_flat = E.matmul(r, ka)                               # r[t,a] over b
    term1 = E.matmul(E.hadamard(r_flat, s_prev), E.transpose(kb))
    u_rows = E.matmul(E.const(1.0, (seq, 1)), u)           # (S, N)
    bonus = E.row_reduce(E.hadamard(E.hadamard(r, k), u_rows), "sum",
                         axis=1, name="bonus")             # Σ_a r·u·k
    term2 = E.hadamard(E.matmul(bonus, E.const(1.0, (1, n))), v)
    o = E.add(term1, term2, name="o")
    return RWKVGraph(seq=seq, n=n, o=o, state=state,
                     leaves=(r, k, v, w, u, s0))


def rwkv6_static_env(seq: int, n: int) -> dict[str, np.ndarray]:
    env = kron_index_relations(n)
    env["e_first"] = _first_row_indicator(seq)
    return env


def rwkv6_env(r, k, v, w, u, s0) -> dict[str, np.ndarray]:
    """Leaf tables from (S, N) inputs, (N,) u and (N, N) s0."""
    seq, n = np.asarray(r).shape
    env = rwkv6_static_env(seq, n)
    env.update(r=np.asarray(r), k=np.asarray(k), v=np.asarray(v),
               w=np.asarray(w), u=np.asarray(u).reshape(1, n),
               s0=np.asarray(s0).reshape(1, n * n))
    return env


def run_rwkv6_in_db(r, k, v, w, u, s0, *, backend: str = "sqlite",
                    engine=None) -> tuple[np.ndarray, np.ndarray]:
    """The time-mix recurrence inside the database: returns
    (o (S, N), s_fin (N, N)) like ``kernels/ref.rwkv6_scan`` per head."""
    from ...obs import tracer_of
    from ..sql_engine import SQLEngine

    seq, n = np.asarray(r).shape
    graph = rwkv6_time_mix_graph(seq, n)
    env = rwkv6_env(r, k, v, w, u, s0)
    eng = engine if engine is not None else SQLEngine(backend=backend)
    try:
        with tracer_of(eng, eng.adapter).span("zoo.rwkv6_time_mix",
                                              seq=seq, n=n):
            o, states = eng.evaluate([graph.o, graph.state], env)
            return o, states[-1].reshape(n, n)
    finally:
        if engine is None:
            eng.close()


# ---------------------------------------------------------------------------
# channel mix
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ChannelMixGraph:
    seq: int
    d: int
    d_ff: int
    out: E.Expr
    leaves: tuple


def rwkv_channel_mix_graph(seq: int, d: int, d_ff: int) -> ChannelMixGraph:
    """RWKV channel mix: token-shift mixing, k = relu(xk·Wk)², out =
    σ(xr·Wr) ∘ (k·Wv).  The token shift is ``RowShift`` — the shifted
    relation is the same table with its row index displaced by one."""
    x = E.var("x", (seq, d))
    mu_k = E.var("mu_k", (1, d))
    mu_r = E.var("mu_r", (1, d))
    wk = E.var("wk", (d, d_ff))
    wv = E.var("wv", (d_ff, d))
    wr = E.var("wr", (d, d))
    xx = E.row_shift(x, 1, name="token_shift")
    ones_col = E.const(1.0, (seq, 1))
    ones_mat = E.const(1.0, (seq, d))
    mk = E.matmul(ones_col, mu_k)
    mr = E.matmul(ones_col, mu_r)
    xk = E.add(E.hadamard(x, mk), E.hadamard(xx, E.sub(ones_mat, mk)))
    xr = E.add(E.hadamard(x, mr), E.hadamard(xx, E.sub(ones_mat, mr)))
    kk = E.square(E.relu(E.matmul(xk, wk)))
    out = E.hadamard(E.sigmoid(E.matmul(xr, wr)), E.matmul(kk, wv),
                     name="cmix_out")
    return ChannelMixGraph(seq=seq, d=d, d_ff=d_ff, out=out,
                           leaves=(x, mu_k, mu_r, wk, wv, wr))


def rwkv_channel_mix_ref(x, mu_k, mu_r, wk, wv, wr) -> np.ndarray:
    """NumPy oracle of :func:`rwkv_channel_mix_graph`."""
    x = np.asarray(x, dtype=np.float64)
    xx = np.zeros_like(x)
    xx[1:] = x[:-1]
    xk = x * mu_k + xx * (1.0 - mu_k)
    xr = x * mu_r + xx * (1.0 - mu_r)
    kk = np.square(np.maximum(xk @ np.asarray(wk), 0.0))
    return (1.0 / (1.0 + np.exp(-(xr @ np.asarray(wr))))) * (kk @ np.asarray(wv))


def run_channel_mix_in_db(x, mu_k, mu_r, wk, wv, wr, *,
                          backend: str = "sqlite", engine=None) -> np.ndarray:
    from ...obs import tracer_of
    from ..sql_engine import SQLEngine

    seq, d = np.asarray(x).shape
    graph = rwkv_channel_mix_graph(seq, d, np.asarray(wk).shape[1])
    env = {"x": np.asarray(x), "mu_k": np.asarray(mu_k).reshape(1, d),
           "mu_r": np.asarray(mu_r).reshape(1, d), "wk": np.asarray(wk),
           "wv": np.asarray(wv), "wr": np.asarray(wr)}
    eng = engine if engine is not None else SQLEngine(backend=backend)
    try:
        with tracer_of(eng, eng.adapter).span("zoo.channel_mix",
                                              seq=seq, d=d):
            out, = eng.evaluate([graph.out], env)
            return out
    finally:
        if engine is None:
            eng.close()
