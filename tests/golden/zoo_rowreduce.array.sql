with rsum_c0(m) as (
  select mreduce((select m from zx), 'sum', 1) as m
),
rmax_c1(m) as (
  select mreduce((select m from zx), 'max', 0) as m
)
select 0 as r, m from rsum_c0
union all select 1 as r, m from rmax_c1;
