with gath_c0(m) as (
  select mgather((select m from zx), (select m from zidx)) as m
)
select 0 as r, m from gath_c0;
