with recursive rec_c0(i, j, v) as (
  select m.i, m.j, m.v from zb as m where m.i = 1
  union all
  select r.i + 1, r.j, am.v * r.v + bm.v
    from rec_c0 as r
    inner join za as am on am.i = r.i + 1 and am.j = r.j
    inner join zb as bm on bm.i = r.i + 1 and bm.j = r.j
),
rec_c1(i, j, v) as (
  select m.i, m.j, m.v from zb as m where m.i = 4
  union all
  select r.i - 1, r.j, am.v * r.v + bm.v
    from rec_c1 as r
    inner join za as am on am.i = r.i - 1 and am.j = r.j
    inner join zb as bm on bm.i = r.i - 1 and bm.j = r.j
)
select 0 as r, i, j, v from rec_c0
union all select 1 as r, i, j, v from rec_c1;
