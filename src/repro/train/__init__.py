"""Training loop + fault tolerance."""
from .trainer import StragglerMonitor, Trainer, make_train_step
