"""Sharded async checkpointing with elastic restore."""
from .checkpointer import Checkpointer
