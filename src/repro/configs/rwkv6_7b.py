"""RWKV-6 (Finch) 7B — attention-free, data-dependent vector decay
[arXiv:2404.05892]. Sub-quadratic: runs long_500k. The paper's
matmul-as-join technique is inapplicable to the recurrence
(DESIGN.md §Arch-applicability)."""
from .base import ArchConfig, SSMSpec

CONFIG = ArchConfig(
    name="rwkv6-7b", family="ssm", n_layers=32, d_model=4096, n_heads=64,
    n_kv_heads=64, d_head=64, d_ff=14336, vocab=65536, norm="layernorm",
    rope=False, ssm=SSMSpec(head_dim=64), sub_quadratic=True)


def reduced() -> ArchConfig:
    return ArchConfig(
        name="rwkv6-reduced", family="ssm", n_layers=2, d_model=128,
        n_heads=4, n_kv_heads=4, d_head=32, d_ff=256, vocab=256,
        norm="layernorm", rope=False, ssm=SSMSpec(head_dim=32),
        sub_quadratic=True)
