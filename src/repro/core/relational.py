"""Relational matrix representation and relational-algebra execution.

The paper stores a matrix as the relation ``{[i, j, v]}`` (Fig. 1) and maps
matrix algebra onto relational algebra (Listing 4):

  matmul      γ_{m.i, n.j, sum(m.v·n.v)}(m ⋈_{m.j = n.i} n)
  hadamard    m ⋈_{m.i = n.i ∧ m.j = n.j} n,  select m.v·n.v
  transpose   select i as j, j as i, v
  f(X)        select i, j, f(v)

TPU adaptation (DESIGN.md §2): the database's hash join + hash aggregation has
no analogue in VMEM, so we execute the join as a *sort-merge join over the
canonically sorted relation* (a gather of the matching inner tuples) and the
group-by as a *segment sum* over the sorted outer index — the sort-based
aggregation with continuous output that the paper's §8 proposes as future
work. The join intermediate (``nnz(A) × n`` tuples before aggregation — the
thousandfold blow-up of Fig. 5) is explicit in this formulation and is what
`benchmarks/fig5_matmul_memory.py` measures.

Matrices are stored *densely* in the relation (no CSR — §6.2.2 of the paper),
in canonical row-major order. A ``RelTensor`` may also carry fewer valid
tuples than its capacity (``nnz``) for genuinely sparse relations such as the
one-hot matrix or the MoE token→expert assignment; padding rows carry an
out-of-range ``i`` so the group-by drops them (scatter-drop semantics).
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp


@partial(jax.tree_util.register_dataclass,
         data_fields=("i", "j", "v"), meta_fields=("shape",))
@dataclasses.dataclass
class RelTensor:
    """The relation {[i, j, v]} with logical matrix shape ``shape``."""

    i: jax.Array          # int32[cap] row index; == shape[0] marks padding
    j: jax.Array          # int32[cap] col index
    v: jax.Array          # float[cap] value
    shape: tuple[int, int]

    @property
    def capacity(self) -> int:
        return self.i.shape[0]

    # -- constructors -------------------------------------------------------
    @staticmethod
    def from_dense(x: jax.Array) -> "RelTensor":
        """Pivot a dense matrix into the canonical sorted relation."""
        m, n = x.shape
        i = jnp.repeat(jnp.arange(m, dtype=jnp.int32), n)
        j = jnp.tile(jnp.arange(n, dtype=jnp.int32), m)
        return RelTensor(i=i, j=j, v=x.reshape(-1), shape=(m, n))

    def to_dense(self) -> jax.Array:
        """Materialise the relation as a dense matrix (outer-join + coalesce:
        missing cells become 0, as in Listing 5's one-hot construction)."""
        m, n = self.shape
        out = jnp.zeros((m, n), dtype=self.v.dtype)
        return out.at[self.i, self.j].add(self.v, mode="drop")

    def is_canonical(self) -> bool:
        m, n = self.shape
        return self.capacity == m * n

    # -- relational building blocks (Listing 4) ------------------------------
    def transpose(self) -> "RelTensor":
        """``select i as j, j as i, v`` + canonical re-sort.

        The index rename is free; re-establishing the canonical sort order
        (the clustered index) is a permutation known from the shape alone.
        """
        m, n = self.shape
        key = self.j * m + self.i  # int32: capacities here stay < 2^31
        order = jnp.argsort(key)
        return RelTensor(i=self.j[order], j=self.i[order], v=self.v[order],
                         shape=(n, m))

    def map(self, fn) -> "RelTensor":
        """``select i, j, f(v)`` — elementwise function application."""
        return RelTensor(i=self.i, j=self.j, v=fn(self.v), shape=self.shape)

    def _aligned(self, other: "RelTensor") -> None:
        if self.shape != other.shape or self.capacity != other.capacity:
            raise ValueError(
                f"elementwise join needs aligned relations: "
                f"{self.shape}/{self.capacity} vs {other.shape}/{other.capacity}")

    def hadamard(self, other: "RelTensor") -> "RelTensor":
        """Join on both indices; with both relations in canonical sorted
        order the equi-join is the identity alignment (sort-merge join)."""
        self._aligned(other)
        return RelTensor(i=self.i, j=self.j, v=self.v * other.v, shape=self.shape)

    def add(self, other: "RelTensor") -> "RelTensor":
        self._aligned(other)
        return RelTensor(i=self.i, j=self.j, v=self.v + other.v, shape=self.shape)

    def sub(self, other: "RelTensor") -> "RelTensor":
        self._aligned(other)
        return RelTensor(i=self.i, j=self.j, v=self.v - other.v, shape=self.shape)

    def scale(self, c: float) -> "RelTensor":
        return RelTensor(i=self.i, j=self.j, v=self.v * c, shape=self.shape)

    def matmul(self, other: "RelTensor") -> "RelTensor":
        """γ_{m.i, n.j, sum(m.v·n.v)}(m ⋈_{m.j = n.i} n).

        1. JOIN  — for each tuple ``(i, k, v)`` of ``self`` gather the ``n``
           tuples of ``other`` with inner index ``k`` (sort-merge join against
           the canonical order). The joined intermediate has
           ``capacity(self) × n`` tuples — the paper's Fig. 5 blow-up.
        2. GROUP BY (m.i, n.j) with sum — a segment sum over the sorted outer
           row index. Padding tuples (``i == m``) are dropped (scatter-drop),
           mirroring the inner join discarding non-matching tuples.
        """
        if self.shape[1] != other.shape[0]:
            raise ValueError(f"matmul: {self.shape} @ {other.shape}")
        if not other.is_canonical():
            raise ValueError("rhs of the join must be the canonical relation")
        m, k = self.shape
        n = other.shape[1]
        rhs_rows = other.v.reshape(k, n)              # clustered by inner index
        joined = self.v[:, None] * rhs_rows[self.j]   # (cap, n) join result
        out = jax.ops.segment_sum(joined, self.i, num_segments=m)  # group-by
        return RelTensor.from_dense(out)

    def matmul_intermediate_tuples(self, other: "RelTensor") -> int:
        """Size (in tuples) of the join result before aggregation — the
        quantity Fig. 5 measures ("1000 tuples per entry")."""
        return self.capacity * other.shape[1]


# ---------------------------------------------------------------------------
# data transformation (paper §4.1)
# ---------------------------------------------------------------------------

def one_hot(labels: jax.Array, num_classes: int) -> RelTensor:
    """Listing 5: the sparse relation of ones. ``to_dense`` performs the
    outer join against the full index frame + coalesce(·, 0)."""
    rows = labels.shape[0]
    return RelTensor(
        i=jnp.arange(rows, dtype=jnp.int32),
        j=labels.astype(jnp.int32),
        v=jnp.ones((rows,), dtype=jnp.float32),
        shape=(rows, num_classes),
    )


def one_hot_dense(labels: jax.Array, num_classes: int) -> RelTensor:
    """The materialised (canonical) one-hot relation, as Listing 5 stores it."""
    return RelTensor.from_dense(one_hot(labels, num_classes).to_dense())


def features_to_relation(table: jax.Array) -> RelTensor:
    """Pivot an input table's attributes into the relation (Fig. 3):
    column index j = attribute position, row index i = row number."""
    return RelTensor.from_dense(table)


# ---------------------------------------------------------------------------
# memory model (paper §6.1 / Table 1)
# ---------------------------------------------------------------------------

BYTES_PER_INDEX = 8   # the paper assumes 8 B per index attribute
BYTES_PER_VALUE = 8   # double precision


def relation_bytes(shape: tuple[int, int]) -> int:
    """Storage of the canonical relation: 3 attributes × 8 B per tuple —
    the threefold overhead of §6.2.2."""
    return shape[0] * shape[1] * (2 * BYTES_PER_INDEX + BYTES_PER_VALUE)


def array_bytes(shape: tuple[int, int]) -> int:
    """Storage of the array data type: 8 B per entry."""
    return shape[0] * shape[1] * BYTES_PER_VALUE


def join_intermediate_bytes(m: int, k: int, n: int) -> int:
    """Join result of the matmul before aggregation: m·k tuples each joined
    with n partners, 3 attributes each (i, j, product)."""
    return m * k * n * (2 * BYTES_PER_INDEX + BYTES_PER_VALUE)
