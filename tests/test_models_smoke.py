"""Per-architecture smoke tests (assignment deliverable f).

Each of the ten assigned architectures is instantiated with a REDUCED
config of the same family and runs one forward/train/prefill/decode step on
CPU, asserting output shapes and no NaNs. The FULL configs are exercised
only via the dry-run (ShapeDtypeStruct, no allocation).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ARCH_IDS, get_config
from repro.nn.model import LM

KEY = jax.random.PRNGKey(0)
B, S = 2, 32


def make_batch(cfg):
    if cfg.stub_frontend:
        return {"embeds": jax.random.normal(KEY, (B, S, cfg.d_model),
                                            jnp.float32),
                "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(KEY, (B, S), 0, cfg.vocab),
            "labels": jax.random.randint(KEY, (B, S), 0, cfg.vocab)}


@pytest.fixture(scope="module")
def models():
    return {}


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_full_config_dimensions_match_assignment(arch_id):
    cfg = get_config(arch_id)
    expect = {
        "yi_6b": (32, 4096, 32, 4, 11008, 64000),
        "qwen3_8b": (36, 4096, 32, 8, 12288, 151936),
        "qwen2_5_14b": (48, 5120, 40, 8, 13824, 152064),
        "granite_3_8b": (40, 4096, 32, 8, 12800, 49155),
        "deepseek_v2_lite_16b": (27, 2048, 16, 16, 1408, 102400),
        "dbrx_132b": (40, 6144, 48, 8, 10752, 100352),
        "musicgen_medium": (48, 1536, 24, 24, 6144, 2048),
        "rwkv6_7b": (32, 4096, 64, 64, 14336, 65536),
        "internvl2_1b": (24, 896, 14, 2, 4864, 151655),
        "zamba2_2_7b": (54, 2560, 32, 32, 10240, 32000),
    }[arch_id]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, f"{arch_id}: {got} != {expect}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_train_step(arch_id):
    cfg = get_config(arch_id, reduced=True)
    lm = LM(cfg)
    params = lm.init(KEY)
    batch = make_batch(cfg)
    loss, metrics = jax.jit(lm.loss_fn)(params, batch)
    assert loss.shape == () and bool(jnp.isfinite(loss)), arch_id
    assert bool(jnp.isfinite(metrics["ce"]))
    grads = jax.grad(lambda p: lm.loss_fn(p, batch)[0])(params)
    gnorm = sum(float(jnp.sum(jnp.abs(g.astype(jnp.float32))))
                for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0, arch_id


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_prefill_and_decode(arch_id):
    cfg = get_config(arch_id, reduced=True)
    lm = LM(cfg)
    params = lm.init(KEY)
    batch = make_batch(cfg)
    logits, cache = jax.jit(lm.prefill)(params, batch)
    assert logits.shape == (B, 1, cfg.vocab), arch_id
    assert bool(jnp.isfinite(logits).all()), arch_id
    cache0 = lm.init_cache(B, S)
    db = ({"embeds": batch["embeds"][:, :1]} if cfg.stub_frontend
          else {"tokens": batch["tokens"][:, :1]})
    lg, cache1 = jax.jit(lm.decode_step)(params, db, cache0, jnp.int32(0))
    assert lg.shape == (B, 1, cfg.vocab) and bool(jnp.isfinite(lg).all())
    # second step must accept the returned cache (stable pytree/dtypes)
    lg2, _ = jax.jit(lm.decode_step)(params, db, cache1, jnp.int32(1))
    assert bool(jnp.isfinite(lg2).all())


@pytest.mark.parametrize("arch_id", ["yi_6b", "deepseek_v2_lite_16b",
                                     "rwkv6_7b", "zamba2_2_7b"])
def test_prefill_matches_decode_path(arch_id):
    """Greedy next-token from prefill == from token-by-token decode."""
    cfg = get_config(arch_id, reduced=True)
    lm = LM(cfg)
    params = lm.init(KEY)
    toks = jax.random.randint(jax.random.PRNGKey(7), (1, 8), 0, cfg.vocab)
    logits_p, _ = jax.jit(lm.prefill)(params, {"tokens": toks})
    cache = lm.init_cache(1, 16)
    for t in range(8):
        logits_d, cache = jax.jit(lm.decode_step)(
            params, {"tokens": toks[:, t:t + 1]}, cache, jnp.int32(t))
    np.testing.assert_allclose(
        np.asarray(logits_p[0, 0], np.float32),
        np.asarray(logits_d[0, 0], np.float32), rtol=0.08, atol=0.05)
    assert int(jnp.argmax(logits_p)) == int(jnp.argmax(logits_d)), arch_id


def test_scan_and_unrolled_paths_agree():
    """cfg.scan_layers=False (dry-run accounting path) ≡ scanned."""
    import dataclasses
    cfg = get_config("qwen3_8b", reduced=True)
    batch = make_batch(cfg)
    lm_scan = LM(dataclasses.replace(cfg, scan_layers=True))
    lm_loop = LM(dataclasses.replace(cfg, scan_layers=False))
    params = lm_scan.init(KEY)
    l1, _ = jax.jit(lm_scan.loss_fn)(params, batch)
    l2, _ = jax.jit(lm_loop.loss_fn)(params, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-4)


def test_attention_impls_agree():
    """flash ≡ chunked ≡ dense masked attention."""
    import dataclasses
    cfg = get_config("yi_6b", reduced=True)
    batch = make_batch(cfg)
    outs = []
    params = None
    for impl in ("dense", "chunked", "flash"):
        lm = LM(dataclasses.replace(cfg, attn_impl=impl, attn_chunk=8))
        params = params if params is not None else lm.init(KEY)
        logits, _ = lm.forward(params, batch)
        outs.append(np.asarray(logits, np.float32))
    np.testing.assert_allclose(outs[0], outs[1], rtol=3e-2, atol=2e-2)
    np.testing.assert_allclose(outs[0], outs[2], rtol=3e-2, atol=2e-2)
