with recursive w (iter, w_xh, w_ho) as (
  select 0, w_xh, w_ho from weights
  union all
  select w.iter + 1,
         msub(w.w_xh, mscale(0.05, mm(mt(data.img), mhad(mm(mhad(mhad(mconst(4,2,1.0), msqrd(msub(msig(mm(msig(mm(data.img, w.w_xh)), w.w_ho)), data.one_hot))), msigd(msig(mm(msig(mm(data.img, w.w_xh)), w.w_ho)))), mt(w.w_ho)), msigd(msig(mm(data.img, w.w_xh))))))),
         msub(w.w_ho, mscale(0.05, mm(mt(msig(mm(data.img, w.w_xh))), mhad(mhad(mconst(4,2,1.0), msqrd(msub(msig(mm(msig(mm(data.img, w.w_xh)), w.w_ho)), data.one_hot))), msigd(msig(mm(msig(mm(data.img, w.w_xh)), w.w_ho)))))))
    from w, data
   where w.iter < 10
)
select iter, w_xh, w_ho from w;
