"""The third execution backend: evaluate the expression DAG *in a database*.

``SQLEngine`` exposes the same surface as :class:`repro.core.engine.Engine`
(``evaluate`` / ``eval_fn`` / ``value_and_grad_fn``) but instead of running
XLA ops it

1. pivots every leaf matrix into an ``{[i, j, v]}`` table
   (:mod:`repro.db.relation_io`),
2. renders the DAG — including Algorithm-1 gradient graphs — as one WITH
   query, one CTE per node (:func:`repro.core.sqlgen.to_sql92`), and
3. executes it on the connected engine and pivots the result tuples back
   into dense arrays.

It is reachable as ``Engine("sql")``; training loops route through
:mod:`repro.db.train` (the recursive-CTE loop runs entirely in-database).
Because every query is executed, this backend also golden-hardens the
transpiler: any ``sqlgen`` regression turns into a failing differential
test rather than a silently wrong string.
"""
from __future__ import annotations

from typing import Callable

import numpy as np

from ..core import autodiff, sqlgen
from ..core import expr as E
from . import relation_io
from .adapter import Adapter, connect


def _split_tagged(rows, roots: list[E.Expr]) -> list[np.ndarray]:
    """One pass over ``(r, i, j, v)`` union rows → a dense matrix per root."""
    outs = [np.zeros(root.shape, dtype=np.float64) for root in roots]
    for r, i, j, v in rows:
        outs[r][int(i) - 1, int(j) - 1] = v
    return outs


class SQLEngine:
    """Evaluate expression DAGs inside sqlite (default) or duckdb."""

    kind = "sql"

    def __init__(self, backend: str = "sqlite", path: str = ":memory:",
                 adapter: Adapter | None = None):
        self.adapter = adapter if adapter is not None else connect(backend, path)
        self.dialect = self.adapter.dialect

    # -- representation conversion (Engine-compatible no-ops) ---------------
    def lift(self, x):
        return x

    def lower(self, x):
        return x

    # -- evaluation ---------------------------------------------------------
    def _write_env(self, roots: list[E.Expr], env: dict) -> None:
        """Materialise every free Var of the DAG as its stored relation."""
        for v in E.free_vars(*roots):
            if v.name not in env:
                raise KeyError(f"env missing leaf table {v.name!r}")
            relation_io.write_matrix(self.adapter, v.name, env[v.name])

    def evaluate(self, roots: list[E.Expr], env: dict) -> list[np.ndarray]:
        """One round trip: write leaves, run ONE multi-root query, read back.

        The query unions every root's tuples tagged with the root position,
        so shared CTEs (forward values reused by Algorithm 1's backward
        pass) are rendered — and executable by the engine — exactly once.
        """
        self._write_env(roots, env)
        sql = sqlgen.to_sql92(roots, select=sqlgen.multi_root_select(roots),
                              dialect=self.dialect)
        rows = self.adapter.execute(sql)
        return _split_tagged(rows, roots)

    def eval_fn(self, roots: list[E.Expr]) -> Callable:
        """Evaluator with the Engine.eval_fn contract (no jit — the
        "compilation" is the SQL rendering, done once here)."""
        sql = sqlgen.to_sql92(roots, select=sqlgen.multi_root_select(roots),
                              dialect=self.dialect)

        def fn(env: dict) -> list[np.ndarray]:
            self._write_env(roots, env)
            return _split_tagged(self.adapter.execute(sql), roots)

        return fn

    def value_and_grad_fn(self, loss: E.Expr, wrt: list[E.Var]) -> Callable:
        """env → (loss value, {var name: gradient}), gradients from
        Algorithm 1 rendered as CTEs and executed in-database."""
        grads = autodiff.gradients(loss, wrt)
        roots = [loss] + [grads[v] for v in wrt]
        fn = self.eval_fn(roots)

        def vg(env: dict):
            outs = fn(env)
            return outs[0], {v.name: g for v, g in zip(wrt, outs[1:])}

        return vg

    # -- lifecycle ----------------------------------------------------------
    def close(self) -> None:
        self.adapter.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
